"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, operators and tile sizes; exact
equality is required for int dtypes and sum/max/min, allclose for float
prod (reassociation).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.reduce_blocks import block_combine, stack_reduce
from compile.kernels.ref import combine_ref, stack_reduce_ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("ci")

DTYPES = [np.float32, np.int32, np.float64]
OPS = ["sum", "max", "min", "prod"]


def _arr(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        return rng.integers(-1000, 1000, size=shape).astype(dtype)
    return (rng.standard_normal(shape) * 10).astype(dtype)


@hypothesis.given(
    m=st.integers(min_value=1, max_value=5000),
    dtype=st.sampled_from(DTYPES),
    op=st.sampled_from(OPS),
    tile=st.sampled_from([64, 256, 2048]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_combine_matches_ref(m, dtype, op, tile, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m,), dtype)
    y = _arr(rng, (m,), dtype)
    got = np.asarray(block_combine(jnp.asarray(x), jnp.asarray(y), op=op, tile=tile))
    want = np.asarray(combine_ref(jnp.asarray(x), jnp.asarray(y), op=op))
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)


@hypothesis.given(
    w=st.integers(min_value=1, max_value=9),
    m=st.integers(min_value=1, max_value=3000),
    dtype=st.sampled_from(DTYPES),
    op=st.sampled_from(OPS),
    tile=st.sampled_from([128, 2048]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stack_reduce_matches_ref(w, m, dtype, op, tile, seed):
    rng = np.random.default_rng(seed)
    if op == "prod":
        # keep magnitudes tame for float prod
        xs = (rng.uniform(0.5, 1.5, size=(w, m))).astype(dtype)
    else:
        xs = _arr(rng, (w, m), dtype)
    got = np.asarray(stack_reduce(jnp.asarray(xs), op=op, tile=tile))
    want = np.asarray(stack_reduce_ref(jnp.asarray(xs), op=op))
    assert got.shape == want.shape
    if op == "prod" and np.issubdtype(dtype, np.floating):
        np.testing.assert_allclose(got, want, rtol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m", [1, 63, 64, 65, 2048, 2049, 10_000])
def test_block_combine_edge_lengths(m):
    x = jnp.arange(m, dtype=jnp.float32)
    y = jnp.ones((m,), dtype=jnp.float32)
    got = np.asarray(block_combine(x, y, op="sum"))
    np.testing.assert_array_equal(got, np.arange(m, dtype=np.float32) + 1)


def test_stack_reduce_single_row():
    xs = jnp.arange(10, dtype=jnp.int32)[None, :]
    np.testing.assert_array_equal(np.asarray(stack_reduce(xs)), np.arange(10))


def test_sum_commutative_associative_int():
    # The collectives rely on ⊕ being commutative; int sum is exact.
    rng = np.random.default_rng(7)
    xs = rng.integers(-99, 99, size=(6, 500)).astype(np.int32)
    a = np.asarray(stack_reduce(jnp.asarray(xs), op="sum"))
    b = np.asarray(stack_reduce(jnp.asarray(xs[::-1].copy()), op="sum"))
    np.testing.assert_array_equal(a, b)
