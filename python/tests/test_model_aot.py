"""Layer-2 model shapes + AOT pipeline tests: lowering produces loadable
HLO text, the scan pipeline matches the stack fold, and the vjp artifact
encodes the reduce/broadcast duality."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_pipeline_reduce_matches_stack():
    rng = np.random.default_rng(3)
    xs = rng.integers(-50, 50, size=(7, 300)).astype(np.int32)
    a = np.asarray(model.pipeline_reduce(jnp.asarray(xs), op="sum"))
    b = np.asarray(model.reduce_stack(jnp.asarray(xs), op="sum"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, xs.sum(axis=0))


def test_reduce_pair_vjp_is_broadcast():
    # d(sum-combine)/dx = identity on both inputs: the adjoint of a
    # reduction is a broadcast (Observation 1.3's duality).
    x = jnp.arange(100, dtype=jnp.float32)
    y = 2 * x + 1
    out, ct_x, ct_y = model.reduce_pair_vjp(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + y))
    np.testing.assert_array_equal(np.asarray(ct_x), np.ones(100, np.float32))
    np.testing.assert_array_equal(np.asarray(ct_y), np.ones(100, np.float32))


@pytest.mark.parametrize("op,dt,m", [("sum", "f32", 1024), ("max", "i32", 512)])
def test_lower_pair_produces_hlo_text(op, dt, m):
    text = aot.lower_pair(op, dt, m)
    assert text.startswith("HloModule")
    # No Mosaic custom-calls may survive (interpret=True requirement).
    assert "custom-call" not in text or "Mosaic" not in text


def test_lower_stack_produces_hlo_text():
    text = aot.lower_stack("sum", "f32", 4, 256)
    assert text.startswith("HloModule")


def test_hlo_text_parses_back():
    # Round-trip the interchange format: the emitted text must parse back
    # into an HloModule (the same parse the Rust runtime performs via
    # HloModuleProto::from_text_file). Full execute-from-text is covered
    # on the Rust side (rust/tests/runtime_xla.rs).
    from jax._src.lib import xla_client as xc

    text = aot.lower_pair("sum", "f32", 128)
    module = xc._xla.hlo_module_from_text(text)
    reparsed = module.to_string()
    assert "HloModule" in reparsed
    # Two f32[128] parameters and a tuple root must survive the round-trip.
    assert reparsed.count("parameter(") >= 2 or "parameter" in reparsed


def test_build_writes_manifest(tmp_path):
    # Shrink the variant lists for test speed.
    old_pair, old_stack, old_vjp = aot.PAIR_VARIANTS, aot.STACK_VARIANTS, aot.VJP_VARIANTS
    aot.PAIR_VARIANTS = [("sum", "f32", 64)]
    aot.STACK_VARIANTS = [("sum", "f32", 2, 64)]
    aot.VJP_VARIANTS = []
    try:
        manifest = aot.build(str(tmp_path))
    finally:
        aot.PAIR_VARIANTS, aot.STACK_VARIANTS, aot.VJP_VARIANTS = (
            old_pair,
            old_stack,
            old_vjp,
        )
    assert set(manifest) == {"pair.sum.f32.64.hlo.txt", "stack.sum.f32.2x64.hlo.txt"}
    assert os.path.exists(tmp_path / "manifest.json")
    for name in manifest:
        assert (tmp_path / name).read_text().startswith("HloModule")


def test_grad_through_pipeline():
    # Autodiff flows through the scan-of-kernels pipeline.
    xs = jnp.ones((4, 32), jnp.float32)
    g = jax.grad(lambda t: model.pipeline_reduce(t).sum())(xs)
    np.testing.assert_array_equal(np.asarray(g), np.ones((4, 32), np.float32))
