"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO
text artifacts for the Rust PJRT runtime. Never imported at run time."""
