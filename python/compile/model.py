"""Layer 2 — the JAX compute graph around the Layer-1 kernels.

The reduction collectives apply ⊕ per received block per round; this
module expresses the three shapes that computation takes, all calling the
Pallas kernels so everything lowers into a single HLO module per variant:

* :func:`reduce_pair` — one round's combine: ``acc ⊕ incoming``.
* :func:`reduce_stack` — a whole phase's combine: fold ``w`` partials.
* :func:`pipeline_reduce` — the reversed-schedule chain: a `lax.scan`
  over rounds feeding :func:`reduce_pair`, which XLA fuses into one loop
  (the shape of the root's accumulation over `n-1+q` rounds).
* :func:`reduce_pair_vjp` — the backward view: the adjoint of reduction
  *is* broadcast (the paper's duality, Observation 1.3, in autodiff
  form). Exported so the artifact set exercises fwd and bwd.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.reduce_blocks import block_combine, stack_reduce


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def reduce_pair(acc, incoming, op: str = "sum"):
    """One communication round's combine of two equal-length blocks."""
    return block_combine(acc, incoming, op=op)


def _reduce_pair_fwd(acc, incoming, op):
    return reduce_pair(acc, incoming, op), None


def _reduce_pair_bwd(op, _res, ct):
    # The adjoint of a sum-reduction is a broadcast of the cotangent to
    # every contributor — Observation 1.3's bcast/reduce duality, stated
    # in autodiff. (Only ⊕ = sum is linear; other ops would need residuals.)
    assert op == "sum", "reverse-mode is defined for the linear op 'sum' only"
    return ct, ct


reduce_pair.defvjp(_reduce_pair_fwd, _reduce_pair_bwd)


def reduce_stack(xs, op: str = "sum"):
    """Fold a stack ``xs[w, m]`` of partial blocks (one phase's worth)."""
    return stack_reduce(xs, op=op)


def pipeline_reduce(xs, op: str = "sum"):
    """Sequentially fold ``xs[rounds, m]`` the way the root accumulates
    partial blocks over the reversed schedule's rounds."""

    def step(acc, x):
        return reduce_pair(acc, x, op=op), None

    acc0 = xs[0]
    acc, _ = jax.lax.scan(step, acc0, xs[1:])
    return acc


def reduce_pair_vjp(acc, incoming):
    """Value and input-cotangents of ``sum``-combine: the bwd pass of a
    reduction is a broadcast of the output cotangent to both inputs."""
    y, vjp = jax.vjp(lambda a, b: reduce_pair(a, b, op="sum"), acc, incoming)
    ct_a, ct_b = vjp(jnp.ones_like(y))
    return y, ct_a, ct_b
