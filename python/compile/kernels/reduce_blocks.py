"""Layer 1 — Pallas kernels for the reduction-operator hot spot.

The paper's reduction collectives (`MPI_Reduce`, `MPI_Reduce_scatter`)
apply a commutative operator ⊕ to every received block, once per block
per round. That per-block combine is the compute hot spot of the whole
stack; here it is written as Pallas kernels:

* :func:`block_combine` — ``out = x ⊕ y`` over one block, tiled so each
  tile fits VMEM (grid over the block dimension).
* :func:`stack_reduce` — ``out = ⊕_w xs[w, :]`` over a stack of ``w``
  partial blocks in a single streaming pass (one tile of every partial is
  resident at a time; the combine chain stays in registers/VMEM).

Hardware adaptation (paper targets CPU clusters, see DESIGN.md
§Hardware-Adaptation): the combine is bandwidth-bound, so the kernels are
structured as single-pass streams with `BlockSpec`-tiled HBM↔VMEM
movement and no MXU involvement. On this image Pallas must run with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls), so the
tests validate numerics and the AOT pipeline, not TPU wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default tile: 8 KiB of f32 — comfortably VMEM-resident with double
#: buffering on any TPU generation.
DEFAULT_TILE = 2048

_COMBINE = {
    "sum": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "prod": lambda a, b: a * b,
}


def _combine_kernel(x_ref, y_ref, o_ref, *, op: str):
    o_ref[...] = _COMBINE[op](x_ref[...], y_ref[...])


def _pad_to(x, tile):
    m = x.shape[-1]
    pad = (-m) % tile
    if pad == 0:
        return x, m
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width), m


@functools.partial(jax.jit, static_argnames=("op", "tile"))
def block_combine(x, y, op: str = "sum", tile: int = DEFAULT_TILE):
    """``x ⊕ y`` elementwise over two 1-D blocks of equal length.

    Arbitrary lengths are handled by padding to the tile size (the pad
    lanes are combined too and then dropped — harmless for elementwise
    ops).
    """
    assert x.shape == y.shape and x.ndim == 1
    xp, m = _pad_to(x, tile)
    yp, _ = _pad_to(y, tile)
    grid = xp.shape[0] // tile
    out = pl.pallas_call(
        functools.partial(_combine_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct(xp.shape, xp.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(xp, yp)
    return out[:m]


def _stack_kernel(x_ref, o_ref, *, op: str, w: int):
    acc = x_ref[0, :]
    for i in range(1, w):
        acc = _COMBINE[op](acc, x_ref[i, :])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("op", "tile"))
def stack_reduce(xs, op: str = "sum", tile: int = DEFAULT_TILE):
    """Reduce ``xs[w, m]`` over axis 0 in one streaming pass.

    The grid runs over ``m`` tiles; each grid step loads the same tile of
    all ``w`` partials (one `BlockSpec` block of shape ``(w, tile)``) and
    folds them, so every input element is read exactly once.
    """
    assert xs.ndim == 2
    w = xs.shape[0]
    xp, m = _pad_to(xs, tile)
    grid = xp.shape[1] // tile
    out = pl.pallas_call(
        functools.partial(_stack_kernel, op=op, w=w),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1],), xs.dtype),
        grid=(grid,),
        in_specs=[pl.BlockSpec((w, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(xp)
    return out[:m]
