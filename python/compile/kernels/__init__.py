"""Layer-1 Pallas kernels and their pure-jnp reference oracle."""

from . import ref  # noqa: F401
from .reduce_blocks import block_combine, stack_reduce  # noqa: F401
