"""Pure-jnp reference oracle for the reduction kernels (Layer 1
correctness baseline).

Every Pallas kernel in this package must be numerically identical (up to
dtype-exact equality for these element-wise ops) to the functions here;
`python/tests/` sweeps shapes, dtypes and operators with hypothesis.
"""

import jax.numpy as jnp

#: Operator name -> elementwise combine on two arrays.
OPS = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "prod": jnp.multiply,
}


def combine_ref(x, y, op: str = "sum"):
    """Elementwise combine of two equally-shaped blocks."""
    return OPS[op](x, y)


def stack_reduce_ref(xs, op: str = "sum"):
    """Reduce a stack of partial blocks ``xs[w, m]`` over axis 0."""
    if op == "sum":
        return jnp.sum(xs, axis=0)
    if op == "max":
        return jnp.max(xs, axis=0)
    if op == "min":
        return jnp.min(xs, axis=0)
    if op == "prod":
        return jnp.prod(xs, axis=0)
    raise ValueError(f"unknown op {op!r}")
