"""AOT pipeline: lower the Layer-2 functions (with their Layer-1 Pallas
kernels inlined) to **HLO text** artifacts for the Rust PJRT runtime.

HLO text, not serialized ``HloModuleProto``: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming convention (the Rust loader globs and parses these —
keep in sync with ``rust/src/runtime/artifacts.rs``)::

    <fn>.<op>.<dtype>.<shape>.hlo.txt
    e.g. pair.sum.f32.4096.hlo.txt        (two f32[4096] inputs)
         stack.sum.f32.8x4096.hlo.txt     (one f32[8,4096] input)
         pair_vjp.sum.f32.4096.hlo.txt    (fwd+bwd, three outputs)

``manifest.json`` lists every artifact for humans/tools; the Rust side
relies only on the filenames.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}

#: (op, dtype, block elements) variants of the pairwise combine.
PAIR_VARIANTS = [
    ("sum", "f32", 1024),
    ("sum", "f32", 4096),
    ("sum", "f32", 16384),
    ("sum", "f32", 65536),
    ("sum", "i32", 4096),
    ("sum", "i32", 16384),
    ("max", "f32", 4096),
    ("max", "i32", 4096),
]

#: (op, dtype, width, block elements) variants of the stacked fold.
STACK_VARIANTS = [
    ("sum", "f32", 4, 4096),
    ("sum", "f32", 8, 4096),
    ("sum", "f32", 8, 16384),
    ("sum", "i32", 8, 4096),
    ("max", "f32", 8, 4096),
]

#: block elements of the fwd+bwd artifact.
VJP_VARIANTS = [("sum", "f32", 4096)]


def to_hlo_text(lowered, return_tuple: bool = False) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    Single-output functions are lowered WITHOUT a tuple wrapper so the
    Rust side can execute via the (measured 3.4x faster) PjRtBuffer path
    and read the array result directly; multi-output functions (the vjp)
    keep return_tuple=True."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_pair(op: str, dtype: str, m: int) -> str:
    spec = jax.ShapeDtypeStruct((m,), DTYPES[dtype])
    fn = lambda a, b: model.reduce_pair(a, b, op=op)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_stack(op: str, dtype: str, w: int, m: int) -> str:
    spec = jax.ShapeDtypeStruct((w, m), DTYPES[dtype])
    fn = lambda xs: model.reduce_stack(xs, op=op)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_pair_vjp(dtype: str, m: int) -> str:
    spec = jax.ShapeDtypeStruct((m,), DTYPES[dtype])
    return to_hlo_text(jax.jit(model.reduce_pair_vjp).lower(spec, spec), return_tuple=True)


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}

    def emit(name: str, text: str, entry: dict):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = dict(entry, bytes=len(text))
        print(f"  wrote {name} ({len(text)} chars)")

    for op, dt, m in PAIR_VARIANTS:
        emit(
            f"pair.{op}.{dt}.{m}.hlo.txt",
            lower_pair(op, dt, m),
            {"fn": "pair", "op": op, "dtype": dt, "shape": [m], "inputs": 2},
        )
    for op, dt, w, m in STACK_VARIANTS:
        emit(
            f"stack.{op}.{dt}.{w}x{m}.hlo.txt",
            lower_stack(op, dt, w, m),
            {"fn": "stack", "op": op, "dtype": dt, "shape": [w, m], "inputs": 1},
        )
    for op, dt, m in VJP_VARIANTS:
        emit(
            f"pair_vjp.{op}.{dt}.{m}.hlo.txt",
            lower_pair_vjp(dt, m),
            {"fn": "pair_vjp", "op": op, "dtype": dt, "shape": [m], "inputs": 2},
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"AOT-lowering {len(PAIR_VARIANTS) + len(STACK_VARIANTS) + len(VJP_VARIANTS)} "
          f"variants to {args.out_dir}")
    manifest = build(args.out_dir)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
