//! Irregular all-broadcast (`MPI_Allgatherv`) on the paper's three input
//! distributions (Fig. 2): regular, irregular (`(i mod 3)·m/p`) and
//! degenerate (one rank holds everything) — new circulant algorithm vs
//! the native ring, both through one `Communicator`, on the
//! small-cluster hierarchical cost model.
//!
//! The paper's headline: the circulant algorithm's running time is
//! largely *independent of the distribution* (close to a plain bcast of
//! the same total volume), while the native algorithm degrades by ~100x
//! on the degenerate case.
//!
//! ```sh
//! cargo run --release --example allgatherv_irregular -- [p] [m_total]
//! ```

use circulant_bcast::collectives::tuning;
use circulant_bcast::comm::{Algo, AllgathervReq, CommBuilder};
use circulant_bcast::coordinator::Dist;
use circulant_bcast::sim::HierarchicalCost;

fn main() {
    let mut args = std::env::args().skip(1);
    let p: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(9 * 32);
    let m: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(1 << 18);
    let elem = 4usize;
    let comm = CommBuilder::new(p).cost_model(HierarchicalCost::small_cluster(32)).build();
    let n = tuning::allgatherv_blocks_paper(m, p, 40.0);

    println!("allgatherv p={p}, total m={m} x {elem}B, n={n} blocks, cluster cost model");
    println!(
        "{:>12} {:>16} {:>14} {:>12}",
        "distribution", "circulant(ms)", "ring(ms)", "ring/circ"
    );

    for dist in [Dist::Regular, Dist::Irregular, Dist::Degenerate] {
        let counts = dist.counts(p, m);
        let inputs: Vec<Vec<i32>> = counts
            .iter()
            .enumerate()
            .map(|(r, &c)| (0..c).map(|i| (r * 7919 + i) as i32).collect())
            .collect();

        let new = comm
            .allgatherv(
                AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(n).elem_bytes(elem),
            )
            .expect("circulant sim");
        let ring = comm
            .allgatherv(AllgathervReq::new(&inputs).algo(Algo::Ring).elem_bytes(elem))
            .expect("ring sim");
        for r in 0..p {
            for j in 0..p {
                assert_eq!(new.buffers[r][j], inputs[j], "circulant wrong at r={r} j={j}");
                assert_eq!(ring.buffers[r][j], inputs[j], "ring wrong at r={r} j={j}");
            }
        }
        println!(
            "{:>12} {:>16.4} {:>14.4} {:>11.1}x",
            format!("{dist:?}"),
            new.time() * 1e3,
            ring.time() * 1e3,
            ring.time() / new.time()
        );
    }
    println!("\n(circulant rounds are n-1+q regardless of distribution; the ring always");
    println!(" pays p-1 rounds and, degenerately, moves the whole buffer every round)");
}
