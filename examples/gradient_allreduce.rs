//! End-to-end driver: data-parallel gradient all-reduce for a real
//! ~124M-parameter transformer (GPT-2-small shapes), bucketed the way DDP
//! buckets gradients, over a simulated 32-node × 8-rank cluster — the
//! workload the paper's collectives exist to serve.
//!
//! All layers compose here:
//!   * one persistent `Communicator` (the service handle: O(log p)
//!     schedules computed once, cached across every bucket),
//!   * the circulant reduce-scatter + all-gather pipeline (Obs. 1.4 +
//!     Alg. 7) with the paper's block-count rule,
//!   * the one-ported machine simulator + hierarchical cost model,
//!   * the AOT XLA artifact (Pallas-authored ⊕) numerically verifying one
//!     bucket's reduction,
//!   * the ring baseline (what native NCCL/MPI-style allreduce does),
//!     selected per request via `Algo::Ring` on the same handle.
//!
//! Headline metrics reported (recorded in EXPERIMENTS.md §E2E):
//!   per-step gradient sync time (simulated), circulant vs ring; round
//!   counts; schedule-computation overhead per rank (µs, the paper's
//!   Table 4 quantity in situ) and the cache hit receipts.
//!
//! Payloads are scaled 1024:1 (elements) with β scaled 1024:1 so the
//! simulated times are exact for the full 124M-parameter model while the
//! simulation stays laptop-sized; correctness is checked on real data at
//! the scaled size.
//!
//! ```sh
//! cargo run --release --example gradient_allreduce
//! ```

use std::sync::Arc;
use std::time::Instant;

use circulant_bcast::collectives::{tuning, SumOp};
use circulant_bcast::comm::{Algo, AllreduceReq, CommBuilder, ReduceReq};
use circulant_bcast::runtime::{XlaRuntime, XlaSumOp};
use circulant_bcast::schedule::{ceil_log2, Schedule, Skips};
use circulant_bcast::sim::{HierarchicalCost, LinearCost};

/// GPT-2-small (124M) parameter tensors: (name, elements).
fn gpt2_small_tensors() -> Vec<(&'static str, usize)> {
    let d = 768usize;
    let v = 50257usize;
    let ctx = 1024usize;
    let mut t = vec![("wte", v * d), ("wpe", ctx * d)];
    for _ in 0..12 {
        t.push(("attn.qkv.w", d * 3 * d));
        t.push(("attn.qkv.b", 3 * d));
        t.push(("attn.proj.w", d * d));
        t.push(("attn.proj.b", d));
        t.push(("mlp.fc.w", d * 4 * d));
        t.push(("mlp.fc.b", 4 * d));
        t.push(("mlp.proj.w", 4 * d * d));
        t.push(("mlp.proj.b", d));
        t.push(("ln1", 2 * d));
        t.push(("ln2", 2 * d));
    }
    t.push(("lnf", 2 * d));
    t
}

/// Greedy DDP-style bucketing: fill ~`cap` elements per bucket.
fn buckets(tensors: &[(&str, usize)], cap: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut cur = 0usize;
    for &(_, n) in tensors {
        if cur + n > cap && cur > 0 {
            out.push(cur);
            cur = 0;
        }
        cur += n;
    }
    if cur > 0 {
        out.push(cur);
    }
    out
}

fn main() {
    const SCALE: usize = 1024; // element scale-down; beta scaled up to match
    let p = 256usize;
    let cores = 8usize; // ranks per node
    let elem = 4usize; // f32 gradients

    let tensors = gpt2_small_tensors();
    let total: usize = tensors.iter().map(|&(_, n)| n).sum();
    let bucket_cap = 25 * (1 << 20) / elem; // 25 MB buckets, DDP default
    let bucket_sizes = buckets(&tensors, bucket_cap);
    println!(
        "model: {} tensors, {:.1}M params ({:.0} MB of f32 grads), {} buckets",
        tensors.len(),
        total as f64 / 1e6,
        (total * elem) as f64 / 1e6,
        bucket_sizes.len()
    );

    // Hierarchical machine, beta scaled to compensate element scaling.
    let base = HierarchicalCost::vega(cores);
    let cost = HierarchicalCost {
        cores,
        intra: LinearCost { alpha: base.intra.alpha, beta: base.intra.beta * SCALE as f64 },
        inter: LinearCost { alpha: base.inter.alpha, beta: base.inter.beta * SCALE as f64 },
        nic_share: base.nic_share,
    };
    let q = ceil_log2(p);
    println!("cluster: p={p} ranks ({} nodes x {cores}), q={q}\n", p / cores);

    // --- schedule-computation overhead, the paper's Table-4 quantity ---
    let sk = Skips::new(p);
    let t0 = Instant::now();
    for r in 0..p {
        std::hint::black_box(Schedule::compute(&sk, r));
    }
    let per_rank_us = t0.elapsed().as_secs_f64() / p as f64 * 1e6;
    println!("schedule computation: {per_rank_us:.3} µs per rank (recv+send, O(log p))");

    // --- the persistent service handle: one Communicator for all buckets
    let comm = CommBuilder::new(p).cost_model(cost).build();

    // --- per-bucket allreduce: circulant vs ring, same handle ---
    let mut tot_circ = 0.0f64;
    let mut tot_ring = 0.0f64;
    let mut tot_rounds_circ = 0usize;
    let mut tot_rounds_ring = 0usize;
    println!(
        "\n{:>7} {:>10} {:>16} {:>14} {:>8}",
        "bucket", "elems(M)", "circulant(ms)", "ring(ms)", "speedup"
    );
    for (bi, &sz) in bucket_sizes.iter().enumerate() {
        let m = (sz / SCALE).max(p); // scaled payload
        let n = tuning::allgatherv_blocks_paper(m, p, 40.0);
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..m).map(|i| ((r * 31 + i * 7) % 997) as f32 * 1e-3).collect())
            .collect();
        let expect: Vec<f32> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();

        // New: circulant reduce-scatter + all-gather.
        let res = comm
            .allreduce(
                AllreduceReq::new(&inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(n)
                    .elem_bytes(elem),
            )
            .expect("circ");
        for b in &res.buffers {
            assert!(b.iter().zip(&expect).all(|(a, e)| (a - e).abs() < 1e-2));
        }
        // Baseline: ring reduce-scatter + ring all-gather, same handle.
        let ring = comm
            .allreduce(
                AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Ring).elem_bytes(elem),
            )
            .expect("ring");

        tot_circ += res.time();
        tot_ring += ring.time();
        tot_rounds_circ += res.rounds;
        tot_rounds_ring += ring.rounds;
        println!(
            "{bi:>7} {:>10.2} {:>16.3} {:>14.3} {:>7.2}x",
            sz as f64 / 1e6,
            res.time() * 1e3,
            ring.time() * 1e3,
            ring.time() / res.time()
        );
    }
    println!(
        "\nper-step gradient sync ({:.0} MB): circulant {:.2} ms ({} rounds) vs ring {:.2} ms ({} rounds) -> {:.2}x",
        (total * elem) as f64 / 1e6,
        tot_circ * 1e3,
        tot_rounds_circ,
        tot_ring * 1e3,
        tot_rounds_ring,
        tot_ring / tot_circ
    );
    let (hits, misses) = comm.cache().stats();
    println!("schedule cache across all buckets: {hits} hits, {misses} misses");

    // --- XLA-verified reduction on one bucket (three-layer compose) ---
    match XlaRuntime::new() {
        Ok(rt) => {
            let rt = Arc::new(rt);
            let m = 4096usize;
            let pp = 16usize;
            let inputs: Vec<Vec<f32>> =
                (0..pp).map(|r| (0..m).map(|i| ((r + i) % 13) as f32).collect()).collect();
            let expect: Vec<f32> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            let xla_comm =
                CommBuilder::new(pp).cost_model(LinearCost::hpc_default()).build();
            let res = xla_comm
                .reduce(
                    ReduceReq::new(0, &inputs, Arc::new(XlaSumOp::new(rt)))
                        .algo(Algo::Circulant)
                        .blocks(4)
                        .elem_bytes(elem),
                )
                .expect("xla reduce");
            assert_eq!(res.buffers, expect);
            println!("XLA-executed ⊕ (Pallas-authored artifact): bucket reduction verified ✓");
        }
        Err(e) => println!("(XLA verification skipped: {e})"),
    }

    println!("\nE2E OK — record these numbers in EXPERIMENTS.md §E2E");
}
