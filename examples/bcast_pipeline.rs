//! Pipelined broadcast deep-dive: sweep the block count `n` for a fixed
//! message on a fixed machine and watch the pipeline trade-off — too few
//! blocks wastes bandwidth-overlap, too many pays latency; the paper's
//! `F·sqrt(m/q)` rule and the α-β model optimum both land near the
//! valley. Also compares against the binomial-tree and van de Geijn
//! baselines (the two native-MPI regimes).
//!
//! ```sh
//! cargo run --release --example bcast_pipeline -- [p] [m_elems]
//! ```

use circulant_bcast::collectives::baselines::{binomial_bcast_sim, vdg_bcast_sim};
use circulant_bcast::collectives::{bcast_sim, tuning};
use circulant_bcast::schedule::ceil_log2;
use circulant_bcast::sim::LinearCost;

fn main() {
    let mut args = std::env::args().skip(1);
    let p: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(200);
    let m: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(1 << 20);
    let elem = 4usize; // MPI_INT
    let cost = LinearCost::hpc_default();
    let q = ceil_log2(p);

    let data: Vec<i32> = (0..m as i32).collect();
    println!("broadcast p={p} (q={q}), m={m} x {elem}B, alpha={}, beta={}", cost.alpha, cost.beta);
    println!("{:>8} {:>8} {:>14} {:>12}", "n", "rounds", "sim_time(ms)", "note");

    let n_paper = tuning::bcast_blocks_paper(m, p, 70.0);
    let n_model = tuning::bcast_blocks_model(m, p, elem, cost.alpha, cost.beta);

    let mut best = (f64::INFINITY, 0usize);
    let mut n = 1usize;
    while n <= m.min(1 << 14) {
        let res = bcast_sim(p, 0, &data, n, elem, &cost).expect("sim");
        assert!(res.buffers.iter().all(|b| b == &data));
        let ms = res.stats.time * 1e3;
        if res.stats.time < best.0 {
            best = (res.stats.time, n);
        }
        let mut note = String::new();
        if n == n_paper {
            note.push_str(" <- paper F=70 rule");
        }
        if n == n_model {
            note.push_str(" <- alpha-beta optimum");
        }
        println!("{n:>8} {:>8} {:>14.4} {note}", res.stats.rounds, ms);
        n *= 2;
    }

    // Exact rule points (may fall between the powers of two above).
    for (label, nn) in [("paper rule", n_paper), ("model optimum", n_model)] {
        let res = bcast_sim(p, 0, &data, nn, elem, &cost).expect("sim");
        println!(
            "{label:>14}: n={nn:<6} rounds={:<6} sim_time={:.4} ms",
            res.stats.rounds,
            res.stats.time * 1e3
        );
    }

    let (bt, _) = binomial_bcast_sim(p, 0, &data, elem, &cost).unwrap();
    let (vt, _) = vdg_bcast_sim(p, 0, &data, elem, &cost).unwrap();
    println!("\nbaselines:");
    println!("  binomial tree : rounds={:<6} sim_time={:.4} ms", bt.rounds, bt.time * 1e3);
    println!("  van de Geijn  : rounds={:<6} sim_time={:.4} ms", vt.rounds, vt.time * 1e3);
    println!(
        "  circulant best: n={} sim_time={:.4} ms  (speedup {:.2}x over binomial, {:.2}x over vdG)",
        best.1,
        best.0 * 1e3,
        bt.time / best.0,
        vt.time / best.0
    );
}
