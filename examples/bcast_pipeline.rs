//! Pipelined broadcast deep-dive: sweep the block count `n` for a fixed
//! message on a fixed machine and watch the pipeline trade-off — too few
//! blocks wastes bandwidth-overlap, too many pays latency; the paper's
//! `F·sqrt(m/q)` rule and the α-β model optimum both land near the
//! valley. Also compares against the binomial-tree and van de Geijn
//! baselines (the two native-MPI regimes), all through one
//! `Communicator` (the sweep is exactly the repeated traffic the
//! schedule cache exists for).
//!
//! ```sh
//! cargo run --release --example bcast_pipeline -- [p] [m_elems]
//! ```

use circulant_bcast::collectives::tuning;
use circulant_bcast::comm::{Algo, BcastReq, CommBuilder};
use circulant_bcast::sim::LinearCost;

fn main() {
    let mut args = std::env::args().skip(1);
    let p: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(200);
    let m: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(1 << 20);
    let elem = 4usize; // MPI_INT
    let cost = LinearCost::hpc_default();
    let comm = CommBuilder::new(p).cost_model(cost.clone()).build();
    let q = comm.q();

    let data: Vec<i32> = (0..m as i32).collect();
    println!("broadcast p={p} (q={q}), m={m} x {elem}B, alpha={}, beta={}", cost.alpha, cost.beta);
    println!("{:>8} {:>8} {:>14} {:>12}", "n", "rounds", "sim_time(ms)", "note");

    let n_paper = tuning::bcast_blocks_paper(m, p, 70.0);
    let n_model = tuning::bcast_blocks_model(m, p, elem, cost.alpha, cost.beta);

    let run = |n: usize| {
        comm.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n).elem_bytes(elem))
            .expect("sim")
    };

    let mut best = (f64::INFINITY, 0usize);
    let mut n = 1usize;
    while n <= m.min(1 << 14) {
        let res = run(n);
        assert!(res.buffers.iter().all(|b| b == &data));
        let ms = res.time() * 1e3;
        if res.time() < best.0 {
            best = (res.time(), n);
        }
        let mut note = String::new();
        if n == n_paper {
            note.push_str(" <- paper F=70 rule");
        }
        if n == n_model {
            note.push_str(" <- alpha-beta optimum");
        }
        println!("{n:>8} {:>8} {:>14.4} {note}", res.rounds, ms);
        n *= 2;
    }

    // Exact rule points (may fall between the powers of two above).
    for (label, nn) in [("paper rule", n_paper), ("model optimum", n_model)] {
        let res = run(nn);
        println!(
            "{label:>14}: n={nn:<6} rounds={:<6} sim_time={:.4} ms",
            res.rounds,
            res.time() * 1e3
        );
    }

    let bt = comm.bcast(BcastReq::new(0, &data).algo(Algo::Binomial).elem_bytes(elem)).unwrap();
    let vt = comm.bcast(BcastReq::new(0, &data).algo(Algo::VanDeGeijn).elem_bytes(elem)).unwrap();
    println!("\nbaselines:");
    println!("  binomial tree : rounds={:<6} sim_time={:.4} ms", bt.rounds, bt.time() * 1e3);
    println!("  van de Geijn  : rounds={:<6} sim_time={:.4} ms", vt.rounds, vt.time() * 1e3);
    println!(
        "  circulant best: n={} sim_time={:.4} ms  (speedup {:.2}x over binomial, {:.2}x over vdG)",
        best.1,
        best.0 * 1e3,
        bt.time() / best.0,
        vt.time() / best.0
    );
    let (hits, misses) = comm.cache().stats();
    println!("  schedule cache over the whole sweep: {hits} hits, {misses} misses");
}
