//! Print the receive/send schedule table for any `p` in the exact layout
//! of the paper's Tables 1–3, and verify the doubling laws
//! (Observations 2 and 6) between `p` and `2p`.
//!
//! ```sh
//! cargo run --release --example schedule_table -- 17
//! ```

use circulant_bcast::schedule::doubling::{double_recv_schedules, double_send_schedules};
use circulant_bcast::schedule::{recv_schedule, send_schedule, Skips};

fn print_table(p: usize) {
    let sk = Skips::new(p);
    let q = sk.q();
    let recvs: Vec<_> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
    let sends: Vec<_> = (0..p).map(|r| send_schedule(&sk, r)).collect();

    println!("schedules for p = {p} (q = {q}, skips {:?})", sk.as_slice());
    print!("{:<15}", "r:");
    (0..p).for_each(|r| print!("{r:>4}"));
    println!();
    print!("{:<15}", "b:");
    recvs.iter().for_each(|s| print!("{:>4}", s.baseblock));
    println!();
    for k in 0..q {
        print!("recvblock[{k}]:  ");
        recvs.iter().for_each(|s| print!("{:>4}", s.blocks[k]));
        println!();
    }
    for k in 0..q {
        print!("sendblock[{k}]:  ");
        sends.iter().for_each(|s| print!("{:>4}", s.blocks[k]));
        println!();
    }
}

fn main() {
    let p: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(17);

    print_table(p);

    // Observation 2 + 6: doubling p -> 2p reproduces the directly
    // computed 2p schedules (the Tables 2 -> 3 relationship).
    let sk = Skips::new(p);
    let sk2 = Skips::new(2 * p);
    let recvs: Vec<_> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
    let sends: Vec<_> = (0..p).map(|r| send_schedule(&sk, r)).collect();
    let dr = double_recv_schedules(p, &recvs);
    let ds = double_send_schedules(p, &sends);
    let ok = (0..2 * p).all(|r| {
        dr[r].blocks == recv_schedule(&sk2, r).blocks
            && ds[r].blocks == send_schedule(&sk2, r).blocks
    });
    println!(
        "\ndoubling check p={p} -> {}: {}",
        2 * p,
        if ok { "doubled schedules == directly computed (Obs. 2 + 6)" } else { "MISMATCH" }
    );
    assert!(ok);

    // Violation census for this p (Theorem 3).
    let max_viol = (0..p).map(|r| send_schedule(&sk, r).violations).max().unwrap_or(0);
    let total: usize = (0..p).map(|r| send_schedule(&sk, r).violations).sum();
    println!("send-schedule violations: total {total}, max per rank {max_viol} (bound: 4)");
}
