//! Reduction with the ⊕ executed by the AOT-compiled XLA artifact — the
//! full three-layer stack on one workload: Pallas kernel (build time) →
//! HLO text artifact → Rust PJRT runtime → reversed-schedule MPI_Reduce
//! over the simulated machine, driven through a `Communicator`. Also
//! cross-checks against the native Rust operator and reports per-combine
//! overhead.
//!
//! Requires `make artifacts` and a build with the `xla` cargo feature.
//!
//! ```sh
//! cargo run --release --features xla --example reduce_xla -- [p] [m_elems]
//! ```

use std::sync::Arc;
use std::time::Instant;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{Algo, CommBuilder, ReduceReq};
use circulant_bcast::runtime::{DType, XlaRuntime, XlaSumOp};
use circulant_bcast::sim::LinearCost;

fn main() {
    let mut args = std::env::args().skip(1);
    let p: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(17);
    let m: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(1 << 16);
    let n = 8usize;

    let rt = Arc::new(
        XlaRuntime::new().expect("run `make artifacts` first (and build with --features xla)"),
    );
    println!("PJRT platform: {}; {} artifacts", rt.platform(), rt.artifacts().len());
    let compiled = rt.compile_all().expect("compile");
    println!("compiled {compiled} executables (cached for the hot path)");

    let inputs: Vec<Vec<f32>> =
        (0..p).map(|r| (0..m).map(|i| ((r + 1) * (i % 1000)) as f32 * 1e-3).collect()).collect();

    let comm = CommBuilder::new(p).cost_model(LinearCost::hpc_default()).build();

    // Native Rust ⊕.
    let t0 = Instant::now();
    let native = comm
        .reduce(
            ReduceReq::new(0, &inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(n)
                .elem_bytes(4),
        )
        .expect("native");
    let t_native = t0.elapsed();

    // XLA-executed ⊕ (the artifact authored by the Pallas kernel) — same
    // communicator, so the schedules are already cached.
    let t0 = Instant::now();
    let xla = comm
        .reduce(
            ReduceReq::new(0, &inputs, Arc::new(XlaSumOp::new(rt.clone())))
                .algo(Algo::Circulant)
                .blocks(n)
                .elem_bytes(4),
        )
        .expect("xla");
    let t_xla = t0.elapsed();

    let max_err = native
        .buffers
        .iter()
        .zip(&xla.buffers)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "reduce p={p} m={m} n={n}: rounds={} (optimal), native ⊕ wall {:.1} ms, \
         XLA ⊕ wall {:.1} ms, max |diff| = {max_err:e}",
        native.rounds,
        t_native.as_secs_f64() * 1e3,
        t_xla.as_secs_f64() * 1e3,
    );
    assert!(max_err == 0.0, "XLA and native disagree");

    // Microbenchmark the bare combine path (per-call overhead).
    let x: Vec<f32> = (0..4096).map(|i| i as f32).collect();
    let y = x.clone();
    let iters = 200;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = rt.pair_combine("sum", DType::F32, &x, &y, 0.0).unwrap();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "bare XLA pair-combine (4096 f32): {:.1} µs/call ({:.1} MB/s effective)",
        per * 1e6,
        (2.0 * 4096.0 * 4.0) / per / 1e6
    );
    println!("OK — three-layer stack verified end to end");
}
