//! Quickstart: build one `Communicator`, then broadcast, reduce and
//! all-reduce through it — the 60-second tour of the library.
//!
//! The handle is the point: it owns the O(log p) schedules behind a
//! cache, so the second call (and every call at every root after it)
//! reuses them instead of recomputing.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use circulant_bcast::collectives::{tuning, SumOp};
use circulant_bcast::comm::{AllreduceReq, BcastReq, CommBuilder, ReduceReq};
use circulant_bcast::schedule::{verify_all, Schedule, Skips};
use circulant_bcast::sim::LinearCost;

fn main() {
    let p = 17; // any number of processors — no power-of-two restriction
    let sk = Skips::new(p);
    println!("p = {p}: q = {} rounds/phase, circulant skips {:?}", sk.q(), sk.as_slice());

    // 1. O(log p) per-processor schedule computation (Theorems 2 + 3).
    let sched = Schedule::compute(&sk, 3);
    println!(
        "rank 3: baseblock={} recv={:?} send={:?}",
        sched.baseblock, sched.recv, sched.send
    );

    // 2. Machine-check the four correctness conditions for this p.
    let rep = verify_all(p);
    assert!(rep.ok());
    println!(
        "verified: all 4 conditions hold; max violations/rank = {} (≤ 4 by Theorem 3)",
        rep.max_violations
    );

    // 3. One Communicator serves every collective (Observation 1): built
    //    once per p, it owns the skip table, the schedule cache and the
    //    cost model.
    let comm = CommBuilder::new(p).cost_model(LinearCost::hpc_default()).build();

    // 4. Pipelined broadcast of 1 MiB from rank 0 in the optimal
    //    n-1+q rounds, with the paper's block-count rule.
    let m = 1 << 18; // 256 Ki elements
    let n = tuning::bcast_blocks_paper(m, p, 70.0);
    let data: Vec<i64> = (0..m as i64).collect();
    let out = comm
        .bcast(BcastReq::new(0, &data).blocks(n).elem_bytes(4))
        .expect("machine model violated");
    assert!(out.all_received());
    assert!(out.buffers.iter().all(|b| b == &data));
    println!(
        "bcast  m={m} n={n} ({:?}): {} rounds (optimal {}), simulated {:.3} ms",
        out.algo,
        out.rounds,
        n - 1 + sk.q(),
        out.time() * 1e3
    );

    // 5. The same schedules, reversed, implement MPI_Reduce — and thanks
    //    to the cache, this call recomputes nothing.
    let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64; m]).collect();
    let red = comm
        .reduce(ReduceReq::new(0, &inputs, Arc::new(SumOp)).blocks(n).elem_bytes(4))
        .unwrap();
    assert_eq!(red.buffers[0], (0..p as i64).sum::<i64>());
    println!(
        "reduce m={m} n={n}: {} rounds, simulated {:.3} ms — root got the sum",
        red.rounds,
        red.time() * 1e3
    );

    // 6. All-reduce = reduce-scatter + all-gather on one schedule table.
    let ar = comm.allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).elem_bytes(4)).unwrap();
    assert!(ar.buffers.iter().all(|b| b[0] == (0..p as i64).sum::<i64>()));
    println!(
        "allreduce m={m}: {} rounds, simulated {:.3} ms — every rank has the sum",
        ar.rounds,
        ar.time() * 1e3
    );

    // 7. The receipts: repeated traffic hits the schedule cache.
    let (hits, misses) = comm.cache().stats();
    println!("schedule cache after 3 collectives: {hits} hits, {misses} misses");
}
