//! Quickstart: compute a schedule, broadcast a buffer, reduce it back —
//! the 60-second tour of the library.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use circulant_bcast::collectives::{bcast_sim, reduce_sim, tuning, SumOp};
use circulant_bcast::schedule::{verify_all, Schedule, Skips};
use circulant_bcast::sim::LinearCost;

fn main() {
    let p = 17; // any number of processors — no power-of-two restriction
    let sk = Skips::new(p);
    println!("p = {p}: q = {} rounds/phase, circulant skips {:?}", sk.q(), sk.as_slice());

    // 1. O(log p) per-processor schedule computation (Theorems 2 + 3).
    let sched = Schedule::compute(&sk, 3);
    println!(
        "rank 3: baseblock={} recv={:?} send={:?}",
        sched.baseblock, sched.recv, sched.send
    );

    // 2. Machine-check the four correctness conditions for this p.
    let rep = verify_all(p);
    assert!(rep.ok());
    println!(
        "verified: all 4 conditions hold; max violations/rank = {} (≤ 4 by Theorem 3)",
        rep.max_violations
    );

    // 3. Pipelined broadcast of 1 MiB from rank 0 in the optimal
    //    n-1+q rounds, with the paper's block-count rule.
    let m = 1 << 18; // 256 Ki f32-sized elements = 1 MiB
    let n = tuning::bcast_blocks_paper(m, p, 70.0);
    let data: Vec<i64> = (0..m as i64).collect();
    let cost = LinearCost::hpc_default();
    let res = bcast_sim(p, 0, &data, n, 4, &cost).expect("machine model violated");
    assert!(res.buffers.iter().all(|b| b == &data));
    println!(
        "bcast  m={m} n={n}: {} rounds (optimal {}), simulated {:.3} ms",
        res.stats.rounds,
        n - 1 + sk.q(),
        res.stats.time * 1e3
    );

    // 4. The same schedules, reversed, implement MPI_Reduce.
    let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64; m]).collect();
    let red = reduce_sim(&inputs, 0, n, Arc::new(SumOp), 4, &cost).unwrap();
    assert_eq!(red.buffer[0], (0..p as i64).sum::<i64>());
    println!(
        "reduce m={m} n={n}: {} rounds, simulated {:.3} ms — root got the sum",
        red.stats.rounds,
        red.stats.time * 1e3
    );
}
