//! Table 4 reproduction: schedule-computation cost, old (O(log³p)-class)
//! vs new (O(log p)) algorithms, over ranges of p up to ~2.1M.
//!
//! The paper computes receive+send schedules for *all* r for *all* p in
//! each range; we sample p within each range (and ranks for huge p) to
//! keep bench wall-time sane, and report the same two headline columns:
//! total time (scaled) and **per-processor µs** — the number an MPI
//! library pays at communicator creation.
//!
//! (hand-rolled harness=false bench: criterion is not in the offline
//! vendored crate set — see DESIGN.md §Substitutions.)

use std::time::Instant;

use circulant_bcast::schedule::baseline::schedules_oldstyle;
use circulant_bcast::schedule::{recv_schedule, send_schedule, Skips};

/// (range label, representative p values, ranks to sample per p or None=all)
fn ranges() -> Vec<(&'static str, Vec<usize>, Option<usize>)> {
    vec![
        ("[1, 17000]", vec![1000, 8500, 17000], None),
        ("[16000, 33000]", vec![16001, 24500, 33000], None),
        ("[64000, 73000]", vec![64001, 68500, 73000], None),
        ("[131000, 140000]", vec![131001, 140000], Some(32768)),
        ("[262000, 267000]", vec![262001, 267000], Some(32768)),
        ("[524000, 529000]", vec![524001, 529000], Some(32768)),
        ("[1048000, 1050000]", vec![1048001, 1050000], Some(16384)),
        ("[2097000, 2099000]", vec![2097001, 2099000], Some(16384)),
    ]
}

fn bench_new(p: usize, ranks: Option<usize>) -> (f64, usize) {
    let sk = Skips::new(p);
    let count = ranks.unwrap_or(p).min(p);
    let stride = (p / count).max(1);
    let t = Instant::now();
    let mut done = 0usize;
    let mut r = 0usize;
    while r < p && done < count {
        std::hint::black_box(recv_schedule(&sk, r));
        std::hint::black_box(send_schedule(&sk, r));
        r += stride;
        done += 1;
    }
    (t.elapsed().as_secs_f64(), done)
}

fn bench_old(p: usize, ranks: Option<usize>) -> (f64, usize) {
    let sk = Skips::new(p);
    // The old algorithm is ~10-20x slower; sample fewer ranks and scale.
    let count = ranks.unwrap_or(p).min(p).min(4096);
    let stride = (p / count).max(1);
    let t = Instant::now();
    let mut done = 0usize;
    let mut r = 0usize;
    while r < p && done < count {
        std::hint::black_box(schedules_oldstyle(&sk, r));
        r += stride;
        done += 1;
    }
    (t.elapsed().as_secs_f64(), done)
}

fn main() {
    println!("=== Table 4: schedule computation, old O(log^3 p) vs new O(log p) ===");
    println!("(per-processor microseconds, recv+send schedules; sampled ranks)");
    println!(
        "{:<22} {:>14} {:>14} {:>10}",
        "proc range p", "old (µs/proc)", "new (µs/proc)", "old/new"
    );
    for (label, ps, ranks) in ranges() {
        let mut old_us = 0.0;
        let mut new_us = 0.0;
        let mut cnt = 0usize;
        for &p in &ps {
            let (to, no) = bench_old(p, ranks);
            let (tn, nn) = bench_new(p, ranks);
            old_us += to / no as f64 * 1e6;
            new_us += tn / nn as f64 * 1e6;
            cnt += 1;
        }
        old_us /= cnt as f64;
        new_us /= cnt as f64;
        println!(
            "{label:<22} {:>14.3} {:>14.3} {:>9.1}x",
            old_us,
            new_us,
            old_us / new_us
        );
    }
    println!();
    println!("paper (Table 4, Xeon E3-1225 3.3GHz): old 2.77..10.66 µs/proc,");
    println!("new 0.33..0.61 µs/proc, ratio ~8x..17x growing with log p.");
}
