//! Ablation: schedule caching economics ([12]'s communicator caching).
//!
//! With the old O(log³p)-class computation, caching schedules on the
//! communicator was *necessary*; with the new O(log p) algorithms it is
//! merely nice. This bench quantifies both: per-call cost of cached vs
//! uncached schedule access, old vs new computation, and the number of
//! repeated collective calls needed to amortise one cache insertion.

use std::time::Instant;

use circulant_bcast::schedule::baseline::schedules_oldstyle;
use circulant_bcast::schedule::{Schedule, ScheduleCache, Skips};

fn main() {
    println!("=== Ablation: schedule cache (communicator caching, ref [12]) ===\n");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>12}",
        "p", "new (µs)", "old (µs)", "cached (µs)", "amortize@"
    );
    for p in [100usize, 10_007, 1 << 17, (1 << 20) + 1] {
        let sk = Skips::new(p);
        let iters = 2000usize;

        // Uncached, new algorithm.
        let t = Instant::now();
        for i in 0..iters {
            std::hint::black_box(Schedule::compute(&sk, (i * 7919) % p));
        }
        let new_us = t.elapsed().as_secs_f64() / iters as f64 * 1e6;

        // Uncached, old algorithm (fewer iters, it's slow).
        let old_iters = 200usize;
        let t = Instant::now();
        for i in 0..old_iters {
            std::hint::black_box(schedules_oldstyle(&sk, (i * 7919) % p));
        }
        let old_us = t.elapsed().as_secs_f64() / old_iters as f64 * 1e6;

        // Cached access (hot).
        let cache = ScheduleCache::new();
        let hot_ranks: Vec<usize> = (0..64).map(|i| (i * 131) % p).collect();
        for &r in &hot_ranks {
            cache.get(p, r);
        }
        let t = Instant::now();
        for i in 0..iters {
            std::hint::black_box(cache.get(p, hot_ranks[i % hot_ranks.len()]));
        }
        let cached_us = t.elapsed().as_secs_f64() / iters as f64 * 1e6;

        // Calls needed for the cache to beat recomputing (new algorithm):
        // insertion ≈ new_us + map overhead; each hit saves new_us - cached_us.
        let amort = if new_us > cached_us {
            ((new_us + cached_us) / (new_us - cached_us)).ceil() as usize
        } else {
            usize::MAX
        };
        println!(
            "{p:>10} {new_us:>14.3} {old_us:>14.3} {cached_us:>14.3} {amort:>12}",
        );
    }
    println!("\n(the paper's point quantified: with O(log p) computation the cache");
    println!(" saves little; with the old algorithm it was the difference between");
    println!(" microseconds and tens of microseconds per communicator per rank)");
}
