//! Per-rank schedule build cost — the paper's headline O(log p) claim as
//! receipts for the SPMD rank plane: what ONE rank pays to compute its
//! own recv+send schedule (`recv_schedule_into` + `send_schedule_into`,
//! exactly `RankComm`'s rooted hot path), sampled across ranks, for p
//! from 2^10 up to 2^20. The per-rank cost must stay essentially flat —
//! it grows only with q = ceil(log2 p), i.e. ~2x over the whole sweep —
//! while whole-machine precomputation grows a millionfold.
//!
//! Usage: `cargo bench --bench rank_schedule -- [MAX_EXP]`
//! (default 20; CI's `spmd-smoke` job runs the full sweep and gates on
//! the JSON below.)
//!
//! A machine-readable record is written to `BENCH_rank_schedule.json`
//! (override with `CBCAST_BENCH_JSON=path`): per-p sampled ranks,
//! ns/rank and ns/rank/q — what the CI flatness gate reads — plus a
//! recv-core vs send-core split (`recv_ns_per_rank` /
//! `send_ns_per_rank`, each timed on its own over the same sampled
//! ranks) so a regression in one Algorithm is attributable.

use std::hint::black_box;
use std::io::Write;
use std::time::Instant;

use circulant_bcast::schedule::{
    ceil_log2, recv_schedule_into, send_schedule_into, Skips,
};

/// Ranks sampled per p (evenly strided; every rank when p is smaller).
const SAMPLES: usize = 4096;
/// Repetitions per sampled rank, to lift tiny timings out of clock noise.
const REPS: usize = 8;

struct Row {
    p: usize,
    q: usize,
    sampled: usize,
    ns_per_rank: f64,
    ns_per_rank_per_q: f64,
    recv_ns_per_rank: f64,
    send_ns_per_rank: f64,
}

fn main() {
    let max_exp: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20)
        .clamp(10, 24);

    println!("=== rank_schedule: per-rank O(log p) schedule build (the RankComm hot path) ===");
    println!(
        "({} sampled ranks x {REPS} reps per p; p up to 2^{max_exp}; \
         recv_schedule_into + send_schedule_into per rank)\n",
        SAMPLES
    );
    println!(
        "{:>10} {:>4} {:>9} {:>14} {:>16} {:>10} {:>10}",
        "p", "q", "sampled", "ns/rank", "ns/rank/q", "recv(ns)", "send(ns)"
    );

    let mut rows: Vec<Row> = Vec::new();
    let mut recv = [0i64; 64];
    let mut send = [0i64; 64];
    for exp in 10..=max_exp {
        // Off-by-one p exercises the non-power-of-two schedule structure.
        let p = (1usize << exp) + usize::from(exp % 2 == 1);
        let q = ceil_log2(p);
        let sk = Skips::new(p);
        let stride = (p / SAMPLES).max(1);
        let ranks: Vec<usize> =
            (0..SAMPLES).map(|i| i * stride).take_while(|&r| r < p).collect();
        let sampled = ranks.len();

        // Combined: the RankComm rooted hot path (what the flatness
        // gate reads — semantics unchanged from earlier receipts).
        let t = Instant::now();
        for &r in &ranks {
            for _ in 0..REPS {
                let bb = recv_schedule_into(&sk, r, &mut recv);
                send_schedule_into(&sk, r, bb, &mut send);
                black_box((&recv, &send));
            }
        }
        let ns_per_rank = t.elapsed().as_nanos() as f64 / (sampled * REPS) as f64;

        // Split receipts: each core timed on its own over the same
        // ranks (send gets its baseblocks precomputed outside the
        // timed region), so a regression is attributable to one side.
        let t = Instant::now();
        for &r in &ranks {
            for _ in 0..REPS {
                let bb = recv_schedule_into(&sk, r, &mut recv);
                black_box((&recv, bb));
            }
        }
        let recv_ns = t.elapsed().as_nanos() as f64 / (sampled * REPS) as f64;
        let bbs: Vec<usize> =
            ranks.iter().map(|&r| recv_schedule_into(&sk, r, &mut recv)).collect();
        let t = Instant::now();
        for (&r, &bb) in ranks.iter().zip(&bbs) {
            for _ in 0..REPS {
                send_schedule_into(&sk, r, bb, &mut send);
                black_box(&send);
            }
        }
        let send_ns = t.elapsed().as_nanos() as f64 / (sampled * REPS) as f64;

        let per_q = ns_per_rank / q as f64;
        println!(
            "{p:>10} {q:>4} {sampled:>9} {ns_per_rank:>14.1} {per_q:>16.2} \
             {recv_ns:>10.1} {send_ns:>10.1}"
        );
        rows.push(Row {
            p,
            q,
            sampled,
            ns_per_rank,
            ns_per_rank_per_q: per_q,
            recv_ns_per_rank: recv_ns,
            send_ns_per_rank: send_ns,
        });
    }

    let json_path = std::env::var("CBCAST_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_rank_schedule.json".to_string());
    write_json(&json_path, &rows).expect("write bench json");

    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    println!(
        "\nflatness: {:.1} ns/rank at p = {} -> {:.1} ns/rank at p = {} \
         (x{:.2}; q grew x{:.2})",
        first.ns_per_rank,
        first.p,
        last.ns_per_rank,
        last.p,
        last.ns_per_rank / first.ns_per_rank,
        last.q as f64 / first.q as f64
    );
    println!("-> {json_path}");
    println!("(this is RankComm's per-call schedule cost: O(log p) per rank, no table,");
    println!(" no communication — the paper's Theorems 2-3 discipline, measured.)");
}

/// Hand-rolled JSON (the crate is dependency-free; no serde).
fn write_json(path: &str, rows: &[Row]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let max_ns = rows.iter().map(|r| r.ns_per_rank).fold(0.0f64, f64::max);
    let ratio = rows[rows.len() - 1].ns_per_rank / rows[0].ns_per_rank;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"rank_schedule\",")?;
    writeln!(f, "  \"samples\": {SAMPLES},")?;
    writeln!(f, "  \"reps\": {REPS},")?;
    writeln!(f, "  \"max_ns_per_rank\": {max_ns:.3},")?;
    writeln!(f, "  \"last_over_first_ratio\": {ratio:.4},")?;
    writeln!(f, "  \"entries\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"p\": {}, \"q\": {}, \"sampled\": {}, \"ns_per_rank\": {:.3}, \
             \"ns_per_rank_per_q\": {:.4}, \"recv_ns_per_rank\": {:.3}, \
             \"send_ns_per_rank\": {:.3}}}{comma}",
            r.p, r.q, r.sampled, r.ns_per_rank, r.ns_per_rank_per_q, r.recv_ns_per_rank,
            r.send_ns_per_rank
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}
