//! Figure 2 reproduction: irregular all-broadcast (MPI_Allgatherv), new
//! (circulant, G = 40) vs native (ring), p = 36×32 = 1152 MPI processes,
//! on the small-cluster cost model, for the paper's three problem types:
//! regular, irregular ((i mod 3)·m/p) and degenerate (rank 0 has all).
//!
//! One `Communicator` drives all sizes, distributions and algorithms;
//! the p = 1152 schedule table is computed once and cache-served after.
//!
//! The headline shapes to reproduce: (a) the new algorithm's time is
//! nearly independent of the distribution and close to a plain bcast of
//! the same volume; (b) the native algorithm degenerates by ~two orders
//! of magnitude on the degenerate problem.

use circulant_bcast::collectives::tuning;
use circulant_bcast::comm::{Algo, AllgathervReq, BackendKind, BcastReq, CommBuilder};
use circulant_bcast::coordinator::Dist;
use circulant_bcast::sim::{HierarchicalCost, LinearCost};

const SCALE: usize = 256;
const ELEM: usize = 4;

fn main() {
    let nodes = 36usize;
    let cores = 32usize;
    let p = nodes * cores;
    let base = HierarchicalCost::small_cluster(cores);
    let cost = HierarchicalCost {
        cores,
        intra: LinearCost { alpha: base.intra.alpha, beta: base.intra.beta * SCALE as f64 },
        inter: LinearCost { alpha: base.inter.alpha, beta: base.inter.beta * SCALE as f64 },
        nic_share: base.nic_share,
    };
    // CBCAST_BACKEND selects the execution backend (the bcast reference
    // rides the engine's fast path; allgatherv falls back to lockstep
    // under the engine backend — see comm::request::Algo docs).
    let backend = BackendKind::from_env();
    let comm = CommBuilder::new(p).cost_model(cost).backend(backend).build();
    let sizes: [usize; 5] = [1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22];

    println!(
        "=== Figure 2: Allgatherv, new (circulant, G=40) vs native (ring) [{} backend] ===",
        backend.name()
    );
    println!("p = {nodes}x{cores} = {p}, small-cluster hierarchical model, MPI_INT\n");
    println!(
        "{:>10} {:>12} {:>6} {:>12} {:>12} {:>8} {:>14}",
        "m (ints)", "dist", "n", "new (ms)", "native(ms)", "ratio", "bcast-ref(ms)"
    );

    for &m in &sizes {
        let ms_total = (m / SCALE).max(p);
        // Reference: a plain broadcast of the same total volume (the
        // paper's "in the ballpark of MPI_Bcast" claim).
        let nb = tuning::bcast_blocks_paper(m, p, 70.0).min(ms_total);
        let ref_data: Vec<i32> = (0..ms_total as i32).collect();
        let bref = comm
            .bcast(BcastReq::new(0, &ref_data).algo(Algo::Circulant).blocks(nb).elem_bytes(ELEM))
            .expect("bcast ref");

        for dist in [Dist::Regular, Dist::Irregular, Dist::Degenerate] {
            let counts = dist.counts(p, ms_total);
            let inputs: Vec<Vec<i32>> = counts
                .iter()
                .enumerate()
                .map(|(r, &c)| (0..c).map(|i| (r * 31 + i) as i32).collect())
                .collect();
            let n = tuning::allgatherv_blocks_paper(m, p, 40.0).clamp(1, 64);
            let new = comm
                .allgatherv(
                    AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(n).elem_bytes(ELEM),
                )
                .expect("new");
            let ring = comm
                .allgatherv(AllgathervReq::new(&inputs).algo(Algo::Ring).elem_bytes(ELEM))
                .expect("ring");
            println!(
                "{:>10} {:>12} {:>6} {:>12.3} {:>12.3} {:>7.1}x {:>14.3}",
                m,
                format!("{dist:?}"),
                n,
                new.time() * 1e3,
                ring.time() * 1e3,
                ring.time() / new.time(),
                bref.time() * 1e3,
            );
        }
        println!();
    }
    let (hits, misses) = comm.cache().stats();
    println!("(schedule cache across the sweep: {hits} hits, {misses} misses)");
    println!("paper: native degenerates ~100x on the degenerate problem; the new");
    println!("implementation is nearly distribution-independent and bcast-like.");
}
