//! Ablation (future work, §4 / ref [15]): flat circulant broadcast vs the
//! two-level hierarchical decomposition on node×core machines.
//!
//! Expectation: flat wins on shallow hierarchies (its rounds are fewer:
//! one pipeline instead of two in sequence), hierarchical wins as the
//! inter/intra gap steepens and cores-per-node grow — mapping out where
//! the paper's "hierarchical versions" become worthwhile.

use circulant_bcast::collectives::hierarchical::{flat_bcast_time, hier_bcast_sim};
use circulant_bcast::sim::HierarchicalCost;

fn main() {
    println!("=== Ablation: flat circulant vs two-level hierarchical bcast ===\n");
    let m = 1 << 20; // 4 MB of MPI_INT
    let data: Vec<i32> = (0..m as i32).collect();

    println!(
        "{:>8} {:>8} {:>10} {:>14} {:>14} {:>10}",
        "nodes", "cores", "steepness", "flat (ms)", "hier (ms)", "hier/flat"
    );
    for (nodes, cores) in [(200usize, 4usize), (200, 16), (36, 32), (16, 64)] {
        for steep in [1.0f64, 4.0, 16.0] {
            let mut cost = HierarchicalCost::vega(cores);
            cost.inter.beta *= steep;
            let flat = flat_bcast_time(nodes, cores, &data, 0, 4, &cost).expect("flat");
            let hier = hier_bcast_sim(nodes, cores, &data, 0, 0, 4, &cost).expect("hier");
            println!(
                "{nodes:>8} {cores:>8} {steep:>9.0}x {:>14.3} {:>14.3} {:>10.2}",
                flat.time * 1e3,
                hier.time() * 1e3,
                hier.time() / flat.time
            );
        }
        println!();
    }
    println!("(ratio < 1: the hierarchical decomposition wins — the regime the");
    println!(" paper defers to future work; ratio > 1: the flat one-level");
    println!(" pipeline is already the right answer)");
}
