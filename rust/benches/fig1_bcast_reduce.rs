//! Figure 1 reproduction: MPI_Bcast and MPI_Reduce, new (circulant
//! pipelined) vs native (binomial / van-de-Geijn, whichever the tuned
//! module would pick), on VEGA-like configurations p = 200×1, 200×4 and
//! 200×128 MPI processes, MPI_INT payloads, F = 70.
//!
//! One `Communicator` per configuration drives the whole size sweep —
//! the schedules for a given p are computed once and served from the
//! cache for every message size and algorithm thereafter.
//!
//! Payload elements are scaled `SCALE:1` with β scaled inversely, so the
//! simulated times equal the full-size run while the lockstep simulation
//! stays in memory. We report simulated milliseconds per (config, m);
//! the paper's claim to reproduce is the *shape*: the new algorithm wins
//! for mid/large m by 3–4x, and the gap persists at full nodes.

use std::sync::Arc;

use circulant_bcast::collectives::{tuning, SumOp};
use circulant_bcast::comm::{Algo, BackendKind, BcastReq, CommBuilder, ReduceReq};
use circulant_bcast::sim::{HierarchicalCost, LinearCost};

const SCALE: usize = 1024;
const ELEM: usize = 4; // MPI_INT

fn scaled_cost(cores: usize) -> HierarchicalCost {
    let base = HierarchicalCost::vega(cores);
    HierarchicalCost {
        cores,
        intra: LinearCost { alpha: base.intra.alpha, beta: base.intra.beta * SCALE as f64 },
        inter: LinearCost { alpha: base.inter.alpha, beta: base.inter.beta * SCALE as f64 },
        nic_share: base.nic_share,
    }
}

fn main() {
    // (label, nodes, cores). The paper's 200x128 = 25600 ranks is heavy
    // for a lockstep simulation sweep; 200x16 = 3200 preserves the
    // hierarchy contrast (full-node NIC sharing) at tractable cost. The
    // 200x1 and 200x4 configs match the paper exactly.
    let configs = [("200x1", 200usize, 1usize), ("200x4", 200, 4), ("200x16", 200, 16)];
    // Total message sizes in MPI_INT elements (full-size, pre-scaling).
    let sizes: [usize; 6] = [1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24];

    // Any backend drives the sweep (CBCAST_BACKEND=lockstep|threaded|engine);
    // simulated times are backend-independent, only wall time changes.
    let backend = BackendKind::from_env();
    println!(
        "=== Figure 1: Bcast + Reduce, new (circulant, F=70) vs native [{} backend] ===",
        backend.name()
    );
    for (label, nodes, cores) in configs {
        let p = nodes * cores;
        let comm =
            CommBuilder::new(p).cost_model(scaled_cost(cores)).backend(backend).build();
        println!("\n--- p = {label} ({p} ranks), hierarchical VEGA-like model ---");
        println!(
            "{:>12} {:>6} {:>12} {:>12} {:>8} | {:>12} {:>12} {:>8}",
            "m (ints)", "n", "bcast new", "bcast nat", "ratio", "red new", "red nat", "ratio"
        );
        for &m in &sizes {
            let ms = (m / SCALE).max(p.min(m));
            let n = tuning::bcast_blocks_paper(m, p, 70.0).min(ms.max(1));
            let data: Vec<i32> = (0..ms as i32).collect();

            // --- Bcast: new vs best-native (binomial vs vdG, tuned pick).
            let new_b = comm
                .bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n).elem_bytes(ELEM))
                .expect("bcast");
            let bino = comm
                .bcast(BcastReq::new(0, &data).algo(Algo::Binomial).elem_bytes(ELEM))
                .expect("bino");
            let vdg = comm
                .bcast(BcastReq::new(0, &data).algo(Algo::VanDeGeijn).elem_bytes(ELEM))
                .expect("vdg");
            let native_b = bino.time().min(vdg.time());

            // --- Reduce: new (reversed schedules) vs binomial reduce.
            let inputs: Vec<Vec<i32>> = (0..p).map(|_| data.clone()).collect();
            let new_r = comm
                .reduce(
                    ReduceReq::new(0, &inputs, Arc::new(SumOp))
                        .algo(Algo::Circulant)
                        .blocks(n)
                        .elem_bytes(ELEM),
                )
                .expect("reduce");
            let nat_r = comm
                .reduce(
                    ReduceReq::new(0, &inputs, Arc::new(SumOp))
                        .algo(Algo::Binomial)
                        .elem_bytes(ELEM),
                )
                .expect("binred");

            println!(
                "{:>12} {:>6} {:>10.3}ms {:>10.3}ms {:>7.2}x | {:>10.3}ms {:>10.3}ms {:>7.2}x",
                m,
                n,
                new_b.time() * 1e3,
                native_b * 1e3,
                native_b / new_b.time(),
                new_r.time() * 1e3,
                nat_r.time() * 1e3,
                nat_r.time() / new_r.time(),
            );
        }
        let (hits, misses) = comm.cache().stats();
        println!("(schedule cache for {label}: {hits} hits, {misses} misses)");
    }
    println!("\npaper: new implementation faster than native OpenMPI 4.1.5 by >4x / >3x");
    println!("(1 and 4 ppn) and ~3x at full nodes for large m; crossover at small m.");
}
