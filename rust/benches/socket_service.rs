//! Socket-service bench: one in-process `cbcastd`-style daemon
//! (Unix-domain socket, bounded admission queue) driven by several
//! concurrent client threads, each submitting a seeded traffic mix of
//! all five collective kinds over the wire protocol. Reports sustained
//! ops/sec, client-observed p50/p99 latency, and the number of
//! admission refusals the bounded queue issued under the concurrent
//! load.
//!
//! Usage: `cargo bench --bench socket_service -- [CLIENTS] [OPS] [P]`
//! (default 4 clients × 32 ops at p = 64; the queue is kept deliberately
//! shallow so backpressure is exercised, not just measured at zero).
//!
//! Receipts asserted on every run (deterministic, honour `TESTKIT_SEED`):
//! every successful reply's digest + statistics are bit-identical to a
//! solo run of the same op spec on a fresh communicator, and every
//! failed reply fails with the identical error string. Numbers land in
//! `BENCH_socket_service.json` (override with `CBCAST_BENCH_JSON=path`).

use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use circulant_bcast::comm::CommBuilder;
use circulant_bcast::service::{serve_unix, summarize, ServiceClient, ServiceConfig, ServiceReply};
use circulant_bcast::testkit::{run_mix_blocking, traffic_mix, MixOptions, Rng};

fn bench_sock() -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cbcast-bench-{}.sock", std::process::id()));
    p
}

/// One client thread: connect as its own tenant, submit every op with
/// reject-and-retry, verify each terminal reply against a solo run.
/// Returns (ok, failed, rejections, per-op latencies in ms).
fn client_thread(
    path: PathBuf,
    tenant: String,
    p: usize,
    n_ops: usize,
    seed: u64,
) -> (usize, usize, usize, Vec<f64>) {
    let mut client = ServiceClient::connect_unix_retry(&path, &tenant, Duration::from_secs(10))
        .expect("client connect");
    let mix = traffic_mix(&mut Rng::new(seed), p, n_ops, &MixOptions::default());
    let (mut ok, mut failed, mut rejections) = (0usize, 0usize, 0usize);
    let mut latencies_ms = Vec::with_capacity(n_ops);
    for (i, op) in mix.ops.iter().enumerate() {
        let t = Instant::now();
        let reply = loop {
            match client.call(i as u64, op).expect("wire call") {
                ServiceReply::Rejected { retry_after_ms } => {
                    rejections += 1;
                    std::thread::sleep(Duration::from_millis(retry_after_ms.max(1) as u64));
                }
                reply => break reply,
            }
        };
        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
        match (reply, summarize(&solo)) {
            (ServiceReply::Ok(got), Ok(want)) => {
                assert_eq!(got, want, "{tenant} op #{i} diverged from solo run");
                ok += 1;
            }
            (ServiceReply::Err(got), Err(want)) => {
                assert_eq!(got, want, "{tenant} op #{i} failed differently from solo run");
                failed += 1;
            }
            (got, want) => panic!("{tenant} op #{i}: daemon said {got:?}, solo said {want:?}"),
        }
    }
    client.bye().expect("bye");
    (ok, failed, rejections, latencies_ms)
}

fn main() {
    let mut args = std::env::args().skip(1);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let per_client: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(32).max(1);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(64).max(2);
    let base_seed: u64 = std::env::var("TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let path = bench_sock();
    // Shallow queue: with `clients` tenants pipelining into a
    // `clients`-slot queue during the gather window, refusals are part
    // of the workload, not a failure mode.
    let cfg = ServiceConfig {
        p,
        queue_cap: clients.max(2),
        gather: Duration::from_millis(2),
        retry_after: Duration::from_millis(1),
        ..ServiceConfig::default()
    };
    let handle = serve_unix(&path, cfg).expect("bind daemon");

    println!("=== socket_service: {clients} clients × {per_client} ops, p = {p} ===");
    println!(
        "(uds daemon, queue_cap = {}, every reply verified against a solo run)\n",
        clients.max(2)
    );

    let start = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let path = path.clone();
            let tenant = format!("bench-{c}");
            let seed = base_seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(c as u64 + 1)
                .max(1);
            std::thread::spawn(move || client_thread(path, tenant, p, per_client, seed))
        })
        .collect();
    let (mut ok, mut failed, mut rejections) = (0usize, 0usize, 0usize);
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(clients * per_client);
    for w in workers {
        let (o, f, r, lat) = w.join().expect("client thread");
        ok += o;
        failed += f;
        rejections += r;
        latencies_ms.extend(lat);
    }
    let elapsed_s = start.elapsed().as_secs_f64();

    handle.shutdown();
    let metrics = handle.join();

    // ---- Receipts (deterministic).
    let total = clients * per_client;
    assert_eq!(ok + failed, total, "every op must get exactly one terminal reply");
    assert_eq!(metrics.completed + metrics.failed, total);
    assert_eq!(metrics.rejected, rejections, "daemon and clients must agree on refusals");
    assert_eq!(metrics.tenants.len(), clients, "one usage row per tenant");

    latencies_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * q).round() as usize];
    let ops_per_sec = total as f64 / elapsed_s.max(1e-9);

    println!("{:>24} {:>12}", "ops (ok / failed)", format!("{ok} / {failed}"));
    println!("{:>24} {:>12}", "admission rejections", rejections);
    println!("{:>24} {:>12.3}", "elapsed (s)", elapsed_s);
    println!("{:>24} {:>12.1}", "ops/sec", ops_per_sec);
    println!("{:>24} {:>12.3}", "p50 latency (ms)", pct(0.50));
    println!("{:>24} {:>12.3}", "p99 latency (ms)", pct(0.99));
    println!(
        "\nall {total} replies bit-identical to solo runs across {} batches",
        metrics.batches
    );

    let json_path = std::env::var("CBCAST_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_socket_service.json".to_string());
    let (p50, p99) = (pct(0.50), pct(0.99));
    write_json(
        &json_path, p, clients, total, ok, failed, rejections, elapsed_s, ops_per_sec, p50, p99,
    )
    .expect("write bench json");
    println!("→ {json_path}");
}

/// Hand-rolled JSON (the crate is dependency-free; no serde).
#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    p: usize,
    clients: usize,
    ops: usize,
    ok: usize,
    failed: usize,
    rejections: usize,
    elapsed_s: f64,
    ops_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"socket_service\",")?;
    writeln!(f, "  \"p\": {p},")?;
    writeln!(f, "  \"clients\": {clients},")?;
    writeln!(f, "  \"ops\": {ops},")?;
    writeln!(f, "  \"ok\": {ok},")?;
    writeln!(f, "  \"failed\": {failed},")?;
    writeln!(f, "  \"rejections\": {rejections},")?;
    writeln!(f, "  \"elapsed_s\": {elapsed_s:.3},")?;
    writeln!(f, "  \"ops_per_sec\": {ops_per_sec:.1},")?;
    writeln!(f, "  \"p50_ms\": {p50_ms:.3},")?;
    writeln!(f, "  \"p99_ms\": {p99_ms:.3},")?;
    writeln!(f, "  \"verified\": true")?;
    writeln!(f, "}}")?;
    Ok(())
}
