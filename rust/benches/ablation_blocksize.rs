//! Ablation: block-count selection (the paper's §3 tuning problem).
//!
//! Sweeps n for a fixed (p, m) and compares three policies: the paper's
//! `F·sqrt(m/q)` rule (F = 70), the α-β model optimum, and the empirical
//! best from the sweep — quantifying how much the closed-form rules
//! leave on the table (the paper calls choosing n "a highly interesting
//! problem outside the scope of this work"). One `Communicator` per p:
//! the sweep itself is pure schedule-cache traffic.

use circulant_bcast::collectives::tuning;
use circulant_bcast::comm::{Algo, BcastReq, CommBuilder};
use circulant_bcast::sim::LinearCost;

fn main() {
    println!("=== Ablation: block-count policy for pipelined bcast ===\n");
    let cost = LinearCost::hpc_default();
    let elem = 4usize;

    println!(
        "{:>6} {:>12} {:>10} {:>14} {:>10} {:>14} {:>10} {:>14}",
        "p", "m", "n_paper", "t_paper(ms)", "n_model", "t_model(ms)", "n_best", "t_best(ms)"
    );
    for p in [64usize, 200, 1000] {
        let comm = CommBuilder::new(p).cost_model(cost.clone()).build();
        for m in [1usize << 14, 1 << 18, 1 << 21] {
            let data: Vec<i32> = (0..m as i32).collect();
            let run = |n: usize| {
                comm.bcast(
                    BcastReq::new(0, &data)
                        .algo(Algo::Circulant)
                        .blocks(n.max(1))
                        .elem_bytes(elem),
                )
                .expect("sim")
                .time()
            };

            let n_paper = tuning::bcast_blocks_paper(m, p, 70.0);
            let n_model = tuning::bcast_blocks_model(m, p, elem, cost.alpha, cost.beta);
            let t_paper = run(n_paper);
            let t_model = run(n_model);

            // Sweep powers of two plus the two candidates' neighbourhoods.
            let mut best = (f64::INFINITY, 1usize);
            let mut n = 1usize;
            while n <= m.min(1 << 13) {
                let t = run(n);
                if t < best.0 {
                    best = (t, n);
                }
                n *= 2;
            }
            for cand in [n_paper / 2, n_paper, n_paper * 2, n_model / 2, n_model, n_model * 2] {
                if cand >= 1 && cand <= m {
                    let t = run(cand);
                    if t < best.0 {
                        best = (t, cand);
                    }
                }
            }

            println!(
                "{p:>6} {m:>12} {n_paper:>10} {:>14.4} {n_model:>10} {:>14.4} {:>10} {:>14.4}",
                t_paper * 1e3,
                t_model * 1e3,
                best.1,
                best.0 * 1e3
            );
        }
    }
    println!("\n(expect: model optimum within a few % of the sweep best; the paper's");
    println!(" F-rule within ~2x — good enough given F is a per-system constant)");
}
