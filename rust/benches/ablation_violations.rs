//! Ablation: the cost of send-schedule *violations* (Theorem 3).
//!
//! Each violation falls back to one O(log p) receive-schedule
//! computation. This bench measures (a) the violation frequency census
//! across p, and (b) the send-schedule cost split between
//! violation-free ranks and ranks with k violations — quantifying what
//! the ≤4 bound buys, and what a power-of-two p (0 violations) saves.

use std::time::Instant;

use circulant_bcast::schedule::{send_schedule, Skips};

fn main() {
    println!("=== Ablation: send-schedule violations (Theorem 3) ===\n");

    // (a) census across representative p.
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "p", "viol=0", "viol=1", "viol=2", "viol=3", "viol=4", "mean"
    );
    for p in [17usize, 100, 1000, 10_007, 65_537, 262_147, 1 << 20, (1 << 20) + 1] {
        let sk = Skips::new(p);
        let samples = 20_000.min(p);
        let stride = (p / samples).max(1);
        let mut hist = [0usize; 5];
        let mut total = 0usize;
        let mut count = 0usize;
        let mut r = 0usize;
        while r < p && count < samples {
            let v = send_schedule(&sk, r).violations;
            hist[v] += 1;
            total += v;
            count += 1;
            r += stride;
        }
        println!(
            "{p:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8.3}",
            hist[0], hist[1], hist[2], hist[3], hist[4],
            total as f64 / count as f64
        );
    }

    // (b) cost: power-of-two (violation-free) vs worst neighbours.
    println!("\nper-rank send-schedule cost (ns), violation-free vs violating p:");
    println!("{:>12} {:>14} {:>10}", "p", "ns/rank", "mean viol");
    for p in [1usize << 16, (1 << 16) + 1, 1 << 20, (1 << 20) + 1] {
        let sk = Skips::new(p);
        let samples = 20_000.min(p);
        let stride = (p / samples).max(1);
        let mut viol = 0usize;
        let t = Instant::now();
        let mut count = 0usize;
        let mut r = 0usize;
        while r < p && count < samples {
            viol += std::hint::black_box(send_schedule(&sk, r)).violations;
            count += 1;
            r += stride;
        }
        let ns = t.elapsed().as_secs_f64() / count as f64 * 1e9;
        println!("{p:>12} {:>14.1} {:>10.3}", ns, viol as f64 / count as f64);
    }
    println!("\n(expect: power-of-two p cheapest — zero violations; odd p pay a");
    println!(" small constant factor, never more than 4 recv-schedule fallbacks)");
}
