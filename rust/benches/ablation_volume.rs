//! Ablation (Observation 1.4): communication volume of the circulant
//! all-reduction vs recursive halving with power-of-two folding [16],
//! across p — quantifying the paper's "almost twice the communication
//! volume for certain numbers of processes". Both algorithms run through
//! the same `Communicator` (`Algo::Circulant` vs
//! `Algo::RecursiveHalving`).

use std::sync::Arc;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{Algo, CommBuilder, ReduceScatterBlockReq};
use circulant_bcast::sim::UnitCost;

fn main() {
    println!("=== Ablation: reduce-scatter volume, circulant vs recursive halving ===\n");
    let chunk = 64usize;
    println!(
        "{:>8} {:>14} {:>14} {:>8} {:>16} {:>16} {:>8}",
        "p", "circ bytes", "rh bytes", "ratio", "circ max/rank", "rh max/rank", "ratio"
    );
    for p in [15usize, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129] {
        let comm = CommBuilder::new(p).cost_model(UnitCost).build();
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..p * chunk).map(|i| (r + i) as i64).collect())
            .collect();
        let circ = comm
            .reduce_scatter_block(
                ReduceScatterBlockReq::new(&inputs, chunk, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(1)
                    .elem_bytes(8),
            )
            .expect("circ");
        let rh = comm
            .reduce_scatter_block(
                ReduceScatterBlockReq::new(&inputs, chunk, Arc::new(SumOp))
                    .algo(Algo::RecursiveHalving)
                    .elem_bytes(8),
            )
            .expect("rh");
        // sanity: identical results
        let sums: Vec<i64> =
            (0..p * chunk).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        for r in 0..p {
            assert_eq!(circ.buffers[r], sums[r * chunk..(r + 1) * chunk].to_vec());
            assert_eq!(rh.buffers[r], sums[r * chunk..(r + 1) * chunk].to_vec());
        }
        println!(
            "{p:>8} {:>14} {:>14} {:>8.2} {:>16} {:>16} {:>8.2}",
            circ.stats.bytes,
            rh.stats.bytes,
            rh.stats.bytes as f64 / circ.stats.bytes as f64,
            circ.stats.max_rank_bytes,
            rh.stats.max_rank_bytes,
            rh.stats.max_rank_bytes as f64 / circ.stats.max_rank_bytes as f64,
        );
    }
    println!("\n(circulant: always exactly p-1 blocks per port — optimal for every p;");
    println!(" recursive halving: optimal at powers of two, up to ~1.5-2x per-port");
    println!(" volume just below powers of two — the paper's Observation 1.4 point)");
}
