//! Repeated-traffic amortisation: the reason the `Communicator` exists.
//!
//! A service handling heavy repeated collective traffic issues many calls
//! on the same communicator — same `p`, varying roots and payloads. The
//! legacy `*_sim` functions rebuilt the world and recomputed every rank's
//! schedule per call; the `Communicator` computes each relative-rank
//! schedule once and serves every later call (and every root — schedules
//! are root-relative) from the cache.
//!
//! This bench quantifies that: B repeated broadcasts with rotating roots
//! through (a) one persistent `Communicator` and (b) a fresh throwaway
//! one per call (the legacy behavior), reporting per-call wall time and
//! the cache hit/miss receipts that prove schedules are reused, not
//! recomputed.

use std::time::Instant;

use circulant_bcast::comm::{Algo, BackendKind, BcastReq, CommBuilder, Communicator};
use circulant_bcast::sim::UnitCost;

const CALLS: usize = 64;
const N_BLOCKS: usize = 4;

fn persistent(p: usize, data: &[i32], backend: BackendKind) -> (f64, f64, u64, u64) {
    let comm = CommBuilder::new(p).cost_model(UnitCost).backend(backend).build();
    let run = |comm: &Communicator, root: usize| {
        let t = Instant::now();
        let out = comm
            .bcast(BcastReq::new(root, data).algo(Algo::Circulant).blocks(N_BLOCKS))
            .expect("bcast");
        assert_eq!(out.buffers[(root + 1) % p], data);
        t.elapsed().as_secs_f64()
    };
    let first = run(&comm, 0);
    let t = Instant::now();
    for call in 1..CALLS {
        run(&comm, call % p);
    }
    let rest = t.elapsed().as_secs_f64() / (CALLS - 1) as f64;
    let (hits, misses) = comm.cache().stats();
    (first, rest, hits, misses)
}

fn throwaway(p: usize, data: &[i32], backend: BackendKind) -> f64 {
    let t = Instant::now();
    for call in 0..CALLS {
        let comm = CommBuilder::new(p).cost_model(UnitCost).backend(backend).build();
        let out = comm
            .bcast(BcastReq::new(call % p, data).algo(Algo::Circulant).blocks(N_BLOCKS))
            .expect("bcast");
        assert_eq!(out.buffers[(call % p + 1) % p], data);
    }
    t.elapsed().as_secs_f64() / CALLS as f64
}

fn main() {
    // The cache receipts below hold for every backend: all of them serve
    // the shared all-ranks ScheduleTable from the same cache (resident
    // for every p benched here — the byte cap admits up to the old
    // p = 4096 boundary), so the accounting is backend-independent.
    let backend = BackendKind::from_env();
    println!(
        "=== Repeated traffic: persistent Communicator vs per-call rebuild [{} backend] ===",
        backend.name()
    );
    println!("{CALLS} broadcasts per config, roots rotating over all ranks\n");
    println!(
        "{:>8} {:>14} {:>16} {:>16} {:>9} {:>16}",
        "p", "first(µs)", "steady(µs/call)", "rebuild(µs/call)", "speedup", "cache hit/miss"
    );
    for p in [64usize, 256, 1024, 4096] {
        let data: Vec<i32> = (0..256).collect();
        let (first, steady, hits, misses) = persistent(p, &data, backend);
        let rebuild = throwaway(p, &data, backend);
        println!(
            "{p:>8} {:>14.1} {:>16.1} {:>16.1} {:>8.2}x {:>10}/{}",
            first * 1e6,
            steady * 1e6,
            rebuild * 1e6,
            rebuild / steady,
            hits,
            misses
        );
        // The receipts: after the first call touched every relative rank,
        // every later call (any root) is a pure cache hit.
        assert_eq!(misses as usize, p, "p={p}: exactly one miss per relative rank");
        assert_eq!(
            hits as usize,
            (CALLS - 1) * p,
            "p={p}: every subsequent call fully cache-served"
        );
    }
    println!("\n(steady-state calls skip schedule computation entirely: one cached");
    println!(" entry per relative rank serves every root — the speedup is the");
    println!(" schedule-computation share of a call, which grows with p)");
}
