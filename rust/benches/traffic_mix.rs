//! Traffic-mix bench: a heterogeneous batch of concurrent collectives
//! (all five kinds; 3/4 of the ops on eight disjoint rank-window slots,
//! 1/4 on the full machine) executed (a) sequentially — each op alone
//! through the blocking API on a communicator of its window size — and
//! (b) batched through the traffic plane's port-ledger scheduler, with
//! co-scheduled rounds sharded over `CBCAST_THREADS` scoped threads.
//!
//! Usage: `cargo bench --bench traffic_mix -- [N_OPS] [P_EXP]`
//! (default 64 ops at p = 2^12 — the release-smoke configuration; CI
//! runs it at `CBCAST_THREADS=1` and `=8`).
//!
//! Receipts asserted on every run (deterministic, honour `TESTKIT_SEED`):
//! every op's batched outcome is bit-identical to its sequential run,
//! and the aggregate machine-round count is strictly below the
//! sequential round sum (the disjoint-window slots overlap). Wall-clock
//! and overlap-model numbers are recorded in `BENCH_traffic_mix.json`
//! (override with `CBCAST_BENCH_JSON=path`) — the acceptance target is
//! batched ≤ 0.75× sequential wall-clock at `CBCAST_THREADS=8`.

use std::io::Write;
use std::time::Instant;

use circulant_bcast::comm::{BatchReport, CommBuilder, Communicator};
use circulant_bcast::schedule::{configured_threads, verify_one_ported_trace};
use circulant_bcast::sim::LinearCost;
use circulant_bcast::testkit::{
    run_mix_blocking, submit_mix_op, traffic_mix, MixOptions, MixOutcome, Rng, TrafficMix,
};

/// Disjoint window slots the windowed ops cycle through.
const SLOTS: usize = 8;

fn machine(p: usize) -> Communicator {
    CommBuilder::new(p).cost_model(LinearCost::hpc_default()).build()
}

/// The bench workload: `traffic_mix` kinds/sizes/payloads, with windows
/// re-pinned so three quarters of the ops land on disjoint slots (true
/// concurrency) and the rest span the full machine (port time-sharing).
fn bench_mix(rng: &mut Rng, p: usize, n_ops: usize) -> TrafficMix {
    let opts = MixOptions { max_m: 256, max_blocks: 8, window_pct: 0, auto_pct: 10 };
    let mut mix = traffic_mix(rng, p, n_ops, &opts);
    let slot = p / SLOTS;
    for (i, op) in mix.ops.iter_mut().enumerate() {
        if slot > 0 && i % 4 != 3 {
            op.window = Some(((i % SLOTS) * slot, slot));
            op.root %= slot;
        }
    }
    mix
}

fn main() {
    let mut args = std::env::args().skip(1);
    let n_ops: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(64).max(2);
    let p_exp: u32 = args.next().and_then(|s| s.parse().ok()).unwrap_or(12).clamp(4, 16);
    let p = 1usize << p_exp;
    let threads = configured_threads();
    let mut rng = Rng::from_env();
    let mix = bench_mix(&mut rng, p, n_ops);

    println!("=== traffic_mix: {n_ops} concurrent ops, p = 2^{p_exp} = {p} ===");
    println!(
        "({} windowed ops on {SLOTS} disjoint slots of {} ranks, {} full-machine; \
         scheduler on {threads} thread(s))\n",
        mix.ops.iter().filter(|o| o.window.is_some()).count(),
        p / SLOTS,
        mix.ops.iter().filter(|o| o.window.is_none()).count(),
    );

    // ---- Sequential baseline: each op alone through the blocking API
    // on a communicator of its window size (built lazily, shared per
    // size — the strongest sequential opponent: schedules amortised).
    let mut seq_comms: std::collections::HashMap<usize, Communicator> =
        std::collections::HashMap::new();
    let t = Instant::now();
    let sequential: Vec<MixOutcome> = mix
        .ops
        .iter()
        .map(|op| {
            let ranks = op.ranks(p);
            let comm = seq_comms.entry(ranks).or_insert_with(|| machine(ranks));
            run_mix_blocking(comm, op)
        })
        .collect();
    let sequential_ms = t.elapsed().as_secs_f64() * 1e3;

    // ---- Batched: one submit per op, one run for the whole workload.
    let comm = machine(p);
    let t = Instant::now();
    let mut traffic = comm.traffic().record_trace(true);
    let handles: Vec<_> = mix
        .ops
        .iter()
        .map(|op| submit_mix_op(&mut traffic, op).expect("bench mixes are well-formed"))
        .collect();
    let report: BatchReport = traffic.run().expect("batch run");
    let batched: Vec<MixOutcome> = handles.into_iter().map(|h| h.take()).collect();
    let batched_ms = t.elapsed().as_secs_f64() * 1e3;

    // ---- Receipts (deterministic).
    verify_one_ported_trace(p, report.trace.as_ref().unwrap()).expect("one-ported trace");
    let mut seq_rounds_sum = 0usize;
    let mut seq_messages = 0usize;
    for (i, (b, s)) in batched.iter().zip(&sequential).enumerate() {
        assert_eq!(b, s, "op #{i} {:?} diverged from its sequential run", mix.ops[i]);
        match s {
            MixOutcome::Done { rounds, messages, .. } => {
                seq_rounds_sum += rounds;
                seq_messages += messages;
            }
            MixOutcome::Failed(e) => panic!("op #{i} failed sequentially: {e}"),
        }
    }
    assert_eq!(
        report.agg.messages, seq_messages,
        "overlap reschedules rounds, never messages"
    );
    assert!(
        report.machine_rounds() < seq_rounds_sum,
        "disjoint-window overlap must beat the sequential round sum \
         ({} machine rounds vs {seq_rounds_sum})",
        report.machine_rounds()
    );

    let ratio = batched_ms / sequential_ms;
    println!("{:>28} {:>12} {:>12}", "", "sequential", "batched");
    println!("{:>28} {:>12.1} {:>12.1}", "wall-clock (ms)", sequential_ms, batched_ms);
    println!("{:>28} {:>12} {:>12}", "rounds", seq_rounds_sum, report.machine_rounds());
    println!("{:>28} {:>12} {:>12}", "messages", seq_messages, report.agg.messages);
    println!(
        "\nbatched/sequential wall-clock ratio: {ratio:.3} at {threads} thread(s) \
         (acceptance: ≤ 0.75 at CBCAST_THREADS=8)"
    );
    println!(
        "overlap-model completion time: {:.6} s over {} active machine rounds",
        report.agg.time, report.agg.active_rounds
    );

    let json_path = std::env::var("CBCAST_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_traffic_mix.json".to_string());
    write_json(&json_path, p, n_ops, threads, sequential_ms, batched_ms, seq_rounds_sum, &report)
        .expect("write bench json");
    println!("→ {json_path}");
}

/// Hand-rolled JSON (the crate is dependency-free; no serde).
#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    p: usize,
    n_ops: usize,
    threads: usize,
    sequential_ms: f64,
    batched_ms: f64,
    seq_rounds_sum: usize,
    report: &BatchReport,
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"traffic_mix\",")?;
    writeln!(f, "  \"p\": {p},")?;
    writeln!(f, "  \"ops\": {n_ops},")?;
    writeln!(f, "  \"threads\": {threads},")?;
    writeln!(f, "  \"sequential_ms\": {sequential_ms:.3},")?;
    writeln!(f, "  \"batched_ms\": {batched_ms:.3},")?;
    writeln!(f, "  \"ratio\": {:.4},", batched_ms / sequential_ms)?;
    writeln!(f, "  \"machine_rounds\": {},", report.machine_rounds())?;
    writeln!(f, "  \"sequential_rounds_sum\": {seq_rounds_sum},")?;
    writeln!(f, "  \"active_rounds\": {},", report.agg.active_rounds)?;
    writeln!(f, "  \"messages\": {},", report.agg.messages)?;
    writeln!(f, "  \"bytes\": {},", report.agg.bytes)?;
    writeln!(f, "  \"max_rank_bytes\": {},", report.agg.max_rank_bytes)?;
    writeln!(f, "  \"overlap_time_s\": {:.9},", report.agg.time)?;
    writeln!(f, "  \"failed_ops\": {}", report.failed())?;
    writeln!(f, "}}")?;
    Ok(())
}
