//! Engine scale trajectory: full-network broadcast simulation from
//! p = 2^10 up to p = 2^20 (n = 64 blocks) on the sparse engine, with a
//! lockstep-`Network` comparison while the lockstep simulator is still
//! feasible. This is the receipts bench for the schedule-plane tentpole:
//! **build** (the parallel all-ranks `ScheduleTable` fill — chunked over
//! `CBCAST_THREADS` scoped threads, violation-memoized, shared-baseblock)
//! and **run** (the active-set simulation, scratch-reused) are timed and
//! reported separately, so the table-fill speedup is visible on its own.
//!
//! Usage: `cargo bench --bench engine_scale -- [MAX_EXP]`
//! where MAX_EXP bounds the largest p = 2^MAX_EXP (default 20; CI smoke
//! runs 17 at CBCAST_THREADS=1 and =4 and asserts the parallel build is
//! not slower, plus CBCAST_BUILD_KERNEL=scalar vs =lanes and asserts
//! the vectorized build is not slower). Simulated results are
//! cross-checked per size: round count must be the optimal n - 1 + q
//! and, where the lockstep run exists, all statistics must match
//! exactly.
//!
//! A machine-readable record is written to `BENCH_engine_scale.json`
//! (override with `CBCAST_BENCH_JSON=path`): per-p build/run times plus
//! totals, threads, the construction kernel and message counts — what
//! CI diffs across thread counts and kernels and what the acceptance
//! receipts are read from.

use std::io::Write;
use std::sync::Arc;
use std::time::Instant;

use circulant_bcast::collectives::bcast::build_bcast_procs;
use circulant_bcast::collectives::common::{BlockGeometry, ScheduleSource};
use circulant_bcast::schedule::{
    ceil_log2, configured_build_kernel, configured_threads, BuildKernel, ScheduleTable, Skips,
};
use circulant_bcast::sim::{CirculantEngine, EngineScratch, LinearCost, Network, RunStats};

const N_BLOCKS: usize = 64;
/// Elements per block (payload lengths only drive byte accounting).
const BLOCK_ELEMS: usize = 16;
const ELEM_BYTES: usize = 4;
/// Largest p the lockstep comparison runs at (beyond this it dominates
/// the bench's wall time, which is exactly the point).
const LOCKSTEP_MAX_EXP: u32 = 13;

struct Row {
    p: usize,
    q: usize,
    rounds: usize,
    build_ms: f64,
    run_ms: f64,
    messages: usize,
    bytes: usize,
}

fn main() {
    let max_exp: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20)
        .clamp(10, 24);
    let threads = configured_threads();
    let kernel = configured_build_kernel();
    let kernel_name = match kernel {
        BuildKernel::Scalar => "scalar",
        BuildKernel::Lanes => "lanes",
    };
    let cost = LinearCost::hpc_default();
    let m = N_BLOCKS * BLOCK_ELEMS;
    let mut rows: Vec<Row> = Vec::new();
    let mut scratch = EngineScratch::<u32>::new();

    println!("=== engine_scale: full-network bcast simulation, n = {N_BLOCKS} blocks ===");
    println!(
        "(p up to 2^{max_exp}; schedule-plane build on {threads} thread(s), \
         {kernel_name} kernel; lockstep Network comparison up to 2^{LOCKSTEP_MAX_EXP})\n"
    );
    println!(
        "{:>10} {:>8} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "p", "rounds", "build(ms)", "run(ms)", "lockstep(ms)", "messages", "msgs/µs"
    );

    for exp in 10..=max_exp {
        // Off-by-one p exercises the non-power-of-two schedule structure.
        let p = (1usize << exp) + usize::from(exp % 2 == 1);
        let q = ceil_log2(p);
        let sk = Arc::new(Skips::new(p));
        let geom = BlockGeometry::new(m, N_BLOCKS);

        // Build: the all-ranks flat schedule arena, in parallel, with
        // the configured construction kernel.
        let t = Instant::now();
        let table = Arc::new(ScheduleTable::build_with_kernel(&sk, threads, kernel));
        let build_ms = t.elapsed().as_secs_f64() * 1e3;

        // Run: active-set simulation over the shared plane, reusing one
        // scratch across all sizes (allocation-free after the largest).
        let eng = CirculantEngine::new(table, 0, geom);
        let t = Instant::now();
        let stats = eng.run_bcast_with(&mut scratch, ELEM_BYTES, &cost).expect("engine bcast");
        let run_ms = t.elapsed().as_secs_f64() * 1e3;
        assert_eq!(stats.rounds, N_BLOCKS - 1 + q, "p={p}: rounds must be optimal");

        let lockstep_ms = if exp <= LOCKSTEP_MAX_EXP {
            let src = ScheduleSource::Direct(&sk);
            let data: Vec<u32> = (0..m as u32).collect();
            let t = Instant::now();
            let mut procs = build_bcast_procs(&src, 0, geom, &data);
            let lstats: RunStats =
                Network::new(p).run(&mut procs, ELEM_BYTES, &cost).expect("lockstep bcast");
            let ms = t.elapsed().as_secs_f64() * 1e3;
            assert_eq!(lstats.rounds, stats.rounds, "p={p}");
            assert_eq!(lstats.messages, stats.messages, "p={p}");
            assert_eq!(lstats.bytes, stats.bytes, "p={p}");
            assert_eq!(lstats.active_rounds, stats.active_rounds, "p={p}");
            assert_eq!(lstats.max_rank_bytes, stats.max_rank_bytes, "p={p}");
            assert!((lstats.time - stats.time).abs() < 1e-9, "p={p}");
            format!("{ms:>12.1}")
        } else {
            format!("{:>12}", "-")
        };

        println!(
            "{:>10} {:>8} {:>12.1} {:>12.1} {} {:>12} {:>10.1}",
            p,
            stats.rounds,
            build_ms,
            run_ms,
            lockstep_ms,
            stats.messages,
            stats.messages as f64 / (run_ms * 1e3),
        );
        rows.push(Row {
            p,
            q,
            rounds: stats.rounds,
            build_ms,
            run_ms,
            messages: stats.messages,
            bytes: stats.bytes,
        });
    }

    let json_path = std::env::var("CBCAST_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_engine_scale.json".to_string());
    write_json(&json_path, threads, kernel_name, &rows).expect("write bench json");
    let total_build: f64 = rows.iter().map(|r| r.build_ms).sum();
    let total_run: f64 = rows.iter().map(|r| r.run_ms).sum();
    println!(
        "\ntotals: build {total_build:.1} ms, run {total_run:.1} ms, \
         end-to-end {:.1} ms ({threads} thread(s), {kernel_name} kernel) → {json_path}",
        total_build + total_run
    );
    println!("(build = parallel ScheduleTable fill (chunked, violation-memoized,");
    println!(" {kernel_name} kernel); run = active-set simulation over the shared plane;");
    println!(" lockstep = Network with per-rank procs. Identical statistics where");
    println!(" both run — the differential receipts.)");
}

/// Hand-rolled JSON (the crate is dependency-free; no serde).
fn write_json(path: &str, threads: usize, kernel: &str, rows: &[Row]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let total_build: f64 = rows.iter().map(|r| r.build_ms).sum();
    let total_run: f64 = rows.iter().map(|r| r.run_ms).sum();
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"engine_scale\",")?;
    writeln!(f, "  \"n_blocks\": {N_BLOCKS},")?;
    writeln!(f, "  \"block_elems\": {BLOCK_ELEMS},")?;
    writeln!(f, "  \"threads\": {threads},")?;
    writeln!(f, "  \"kernel\": \"{kernel}\",")?;
    writeln!(f, "  \"total_build_ms\": {total_build:.3},")?;
    writeln!(f, "  \"total_run_ms\": {total_run:.3},")?;
    writeln!(f, "  \"total_ms\": {:.3},", total_build + total_run)?;
    writeln!(f, "  \"entries\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"p\": {}, \"q\": {}, \"rounds\": {}, \"build_ms\": {:.3}, \
             \"run_ms\": {:.3}, \"messages\": {}, \"bytes\": {}}}{comma}",
            r.p, r.q, r.rounds, r.build_ms, r.run_ms, r.messages, r.bytes
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}
