//! Engine scale trajectory: full-network broadcast simulation from
//! p = 2^10 up to p = 2^20 (n = 64 blocks) on the sparse engine, with a
//! lockstep-`Network` comparison while the lockstep simulator is still
//! feasible. This is the receipts bench for the `sim::engine` tentpole:
//! the lockstep driver's per-round `0..p` scans and per-message `Vec`
//! clones stop around a few thousand ranks; the engine's active-set
//! worklist plus offset-passing arena carries the same machine-model
//! simulation to the paper's 2^20 regime in seconds.
//!
//! Usage: `cargo bench --bench engine_scale -- [MAX_EXP]`
//! where MAX_EXP bounds the largest p = 2^MAX_EXP (default 20; CI smoke
//! runs 17). Simulated results are cross-checked per size: round count
//! must be the optimal n - 1 + q and, where the lockstep run exists, all
//! statistics must match exactly.

use std::sync::Arc;
use std::time::Instant;

use circulant_bcast::collectives::bcast::build_bcast_procs;
use circulant_bcast::collectives::common::{BlockGeometry, ScheduleSource};
use circulant_bcast::schedule::{ceil_log2, Skips};
use circulant_bcast::sim::{CirculantEngine, LinearCost, Network, RunStats};

const N_BLOCKS: usize = 64;
/// Elements per block (payload lengths only drive byte accounting).
const BLOCK_ELEMS: usize = 16;
const ELEM_BYTES: usize = 4;
/// Largest p the lockstep comparison runs at (beyond this it dominates
/// the bench's wall time, which is exactly the point).
const LOCKSTEP_MAX_EXP: u32 = 13;

fn main() {
    let max_exp: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20)
        .clamp(10, 24);
    let cost = LinearCost::hpc_default();
    let m = N_BLOCKS * BLOCK_ELEMS;

    println!("=== engine_scale: full-network bcast simulation, n = {N_BLOCKS} blocks ===");
    println!("(p up to 2^{max_exp}; lockstep Network comparison up to 2^{LOCKSTEP_MAX_EXP})\n");
    println!(
        "{:>10} {:>8} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "p", "rounds", "build(ms)", "engine(ms)", "lockstep(ms)", "messages", "msgs/µs"
    );

    for exp in 10..=max_exp {
        // Off-by-one p exercises the non-power-of-two schedule structure.
        let p = (1usize << exp) + usize::from(exp % 2 == 1);
        let q = ceil_log2(p);
        let sk = Arc::new(Skips::new(p));
        let src = ScheduleSource::Direct(&sk);
        let geom = BlockGeometry::new(m, N_BLOCKS);

        let t = Instant::now();
        let eng = CirculantEngine::new(&src, 0, geom);
        let build_ms = t.elapsed().as_secs_f64() * 1e3;

        let t = Instant::now();
        let stats = eng.run_bcast(ELEM_BYTES, &cost).expect("engine bcast");
        let engine_ms = t.elapsed().as_secs_f64() * 1e3;
        assert_eq!(stats.rounds, N_BLOCKS - 1 + q, "p={p}: rounds must be optimal");

        let lockstep_ms = if exp <= LOCKSTEP_MAX_EXP {
            let data: Vec<u32> = (0..m as u32).collect();
            let t = Instant::now();
            let mut procs = build_bcast_procs(&src, 0, geom, &data);
            let lstats: RunStats =
                Network::new(p).run(&mut procs, ELEM_BYTES, &cost).expect("lockstep bcast");
            let ms = t.elapsed().as_secs_f64() * 1e3;
            assert_eq!(lstats.rounds, stats.rounds, "p={p}");
            assert_eq!(lstats.messages, stats.messages, "p={p}");
            assert_eq!(lstats.bytes, stats.bytes, "p={p}");
            assert_eq!(lstats.active_rounds, stats.active_rounds, "p={p}");
            assert_eq!(lstats.max_rank_bytes, stats.max_rank_bytes, "p={p}");
            assert!((lstats.time - stats.time).abs() < 1e-9, "p={p}");
            format!("{ms:>12.1}")
        } else {
            format!("{:>12}", "-")
        };

        println!(
            "{:>10} {:>8} {:>12.1} {:>12.1} {} {:>12} {:>10.1}",
            p,
            stats.rounds,
            build_ms,
            engine_ms,
            lockstep_ms,
            stats.messages,
            stats.messages as f64 / (engine_ms * 1e3),
        );
    }
    println!("\n(build = schedule arena fill via recv/send_schedule_into, O(p log p);");
    println!(" engine = active-set simulation; lockstep = Network with per-rank procs.");
    println!(" Identical statistics where both run — the differential receipts.)");
}
