//! Cost-plane crossover receipts: for every point of a (machine, p, m)
//! grid, run each candidate rooted-collective family explicitly under a
//! configured LogP machine, read the `LogPClock`-measured completion
//! time off `RunStats::logp_time`, and record what `Algo::Auto`'s
//! closed-form argmin (`Algo::resolve_with`) would have picked next to
//! the *measured* winner. CI's `costmodel-smoke` gate asserts that Auto
//! matches the measured winner on >= 80% of the grid and never loses by
//! more than 25% — the acceptance receipts for cost-driven selection.
//!
//! Usage: `cargo bench --bench costmodel`
//! A machine-readable record is written to `BENCH_costmodel.json`
//! (override with `CBCAST_BENCH_JSON=path`).

use std::io::Write;
use std::sync::Arc;

use circulant_bcast::collectives::tuning::{
    predict_binomial, predict_circulant, predict_opttree, predict_vdg,
};
use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    resolve_blocks, Algo, BcastReq, CommBuilder, Communicator, Kind, ReduceReq, TuningParams,
};
use circulant_bcast::sim::{LogPParams, UnitCost};

const ELEM_BYTES: usize = 8;

/// One grid point's receipts.
struct Row {
    machine: &'static str,
    kind: Kind,
    p: usize,
    m: usize,
    n: usize,
    auto_pick: Algo,
    winner: Algo,
    auto_time: f64,
    winner_time: f64,
    /// (algo, predicted, measured) per candidate.
    candidates: Vec<(Algo, f64, f64)>,
}

impl Row {
    fn matched(&self) -> bool {
        self.auto_pick == self.winner
    }

    /// How much slower Auto's pick ran than the measured winner.
    fn loss(&self) -> f64 {
        if self.winner_time > 0.0 {
            self.auto_time / self.winner_time
        } else {
            1.0
        }
    }
}

fn algo_name(a: Algo) -> &'static str {
    match a {
        Algo::Circulant => "circulant",
        Algo::Binomial => "binomial",
        Algo::VanDeGeijn => "vdg",
        Algo::OptTree => "opttree",
        Algo::Ring => "ring",
        Algo::RecursiveHalving => "rhalving",
        Algo::Auto => "auto",
    }
}

fn comm(p: usize, params: LogPParams) -> Communicator {
    let tuning = TuningParams { logp: Some(params), ..TuningParams::default() };
    CommBuilder::new(p).cost_model(UnitCost).tuning(tuning).build()
}

/// Measured LogP completion of one explicit (kind, algo) run.
fn measure(c: &Communicator, kind: Kind, algo: Algo, p: usize, m: usize) -> f64 {
    let out = match kind {
        Kind::Bcast => {
            let data: Vec<i64> = (0..m as i64).map(|i| i * 7 % 1009).collect();
            c.bcast(BcastReq::new(0, &data).algo(algo).elem_bytes(ELEM_BYTES))
                .expect("bcast candidate")
        }
        Kind::Reduce => {
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| ((r * 31 + i * 7) % 1009) as i64).collect())
                .collect();
            let req = ReduceReq::new(0, &inputs, Arc::new(SumOp)).algo(algo);
            c.reduce(req.elem_bytes(ELEM_BYTES)).expect("reduce candidate")
        }
        other => unreachable!("bench only sweeps rooted collectives, got {other:?}"),
    };
    out.stats.logp_time.expect("cost plane attached")
}

/// Run one grid point: every candidate family explicitly, Auto's pick
/// next to the measured winner.
fn run_point(machine: &'static str, params: LogPParams, kind: Kind, p: usize, m: usize) -> Row {
    let c = comm(p, params);
    let total = m * ELEM_BYTES;
    let tp = TuningParams { logp: Some(params), ..TuningParams::default() };
    let n = resolve_blocks(kind, p, m, &tp, None);
    let family: &[Algo] = match kind {
        Kind::Bcast => &[Algo::Circulant, Algo::Binomial, Algo::VanDeGeijn, Algo::OptTree],
        _ => &[Algo::Circulant, Algo::Binomial, Algo::OptTree],
    };
    let candidates: Vec<(Algo, f64, f64)> = family
        .iter()
        .map(|&algo| {
            let predicted = match algo {
                Algo::Circulant => predict_circulant(p, n, total, &params),
                Algo::Binomial => predict_binomial(p, total, &params),
                Algo::VanDeGeijn => predict_vdg(p, total, &params),
                Algo::OptTree => predict_opttree(p, total, &params),
                a => unreachable!("{a:?} is not in the rooted candidate family"),
            };
            (algo, predicted, measure(&c, kind, algo, p, m))
        })
        .collect();
    let auto_pick = Algo::Auto.resolve_with(kind, p, m, ELEM_BYTES, None, &tp);
    let mut winner = candidates[0];
    for &cand in &candidates[1..] {
        if cand.2 < winner.2 {
            winner = cand;
        }
    }
    let auto_time = candidates.iter().find(|t| t.0 == auto_pick).expect("in family").2;
    Row {
        machine,
        kind,
        p,
        m,
        n,
        auto_pick,
        winner: winner.0,
        auto_time,
        winner_time: winner.2,
        candidates,
    }
}

fn print_row(r: &Row) {
    println!(
        "{:<12} {:>7} {:>4} {:>8} {:>5} {:>11} {:>11} {:>12.2} {:>12.2} {:>6}",
        r.machine,
        format!("{:?}", r.kind),
        r.p,
        r.m,
        r.n,
        algo_name(r.auto_pick),
        algo_name(r.winner),
        r.auto_time * 1e6,
        r.winner_time * 1e6,
        if r.matched() { "yes" } else { "NO" },
    );
}

fn main() {
    let machines: [(&'static str, LogPParams); 3] = [
        ("default", LogPParams::default()),
        // Long-haul wire: latency dominates, trees win longer.
        ("fat-latency", LogPParams::new(2e-5, 5e-7, 1e-7)),
        // Thin pipe: the per-packet gap dominates, pipelining wins earlier.
        ("thin-pipe", LogPParams::new(2e-6, 5e-7, 1e-6)),
    ];
    let mut rows: Vec<Row> = Vec::new();
    println!("=== costmodel: Auto's predicted argmin vs the LogPClock-measured winner ===\n");
    println!(
        "{:<12} {:>7} {:>4} {:>8} {:>5} {:>11} {:>11} {:>12} {:>12} {:>6}",
        "machine", "kind", "p", "m", "n", "auto", "winner", "auto(us)", "winner(us)", "match"
    );
    for (machine, params) in machines {
        for p in [8usize, 24, 64] {
            for m in [16usize, 1024, 8192, 131072] {
                for kind in [Kind::Bcast, Kind::Reduce] {
                    let row = run_point(machine, params, kind, p, m);
                    print_row(&row);
                    rows.push(row);
                }
            }
        }
    }

    let matches = rows.iter().filter(|r| r.matched()).count();
    let fraction = matches as f64 / rows.len() as f64;
    let worst = rows.iter().map(Row::loss).fold(1.0f64, f64::max);
    println!(
        "\nAuto matched the measured winner on {matches}/{} points ({:.0}%), \
         worst loss {:.1}% over the winner",
        rows.len(),
        fraction * 100.0,
        (worst - 1.0) * 100.0
    );

    let json_path = std::env::var("CBCAST_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_costmodel.json".to_string());
    write_json(&json_path, &rows, fraction, worst).expect("write bench json");
    println!("→ {json_path}");
}

fn candidate_json(c: &(Algo, f64, f64)) -> String {
    let (algo, predicted, measured) = *c;
    format!(
        "{{\"algo\": \"{}\", \"predicted\": {predicted:e}, \"measured\": {measured:e}}}",
        algo_name(algo)
    )
}

/// Hand-rolled JSON (the crate is dependency-free; no serde).
fn write_json(path: &str, rows: &[Row], fraction: f64, worst: f64) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"costmodel\",")?;
    writeln!(f, "  \"points\": {},", rows.len())?;
    writeln!(f, "  \"match_fraction\": {fraction:.4},")?;
    writeln!(f, "  \"worst_loss\": {worst:.4},")?;
    writeln!(f, "  \"entries\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        let cands: Vec<String> = r.candidates.iter().map(candidate_json).collect();
        writeln!(
            f,
            "    {{\"machine\": \"{}\", \"kind\": \"{:?}\", \"p\": {}, \"m\": {}, \"n\": {}, \
             \"auto\": \"{}\", \"winner\": \"{}\", \"auto_time\": {:e}, \"winner_time\": {:e}, \
             \"match\": {}, \"loss\": {:.4}, \"candidates\": [{}]}}{comma}",
            r.machine,
            r.kind,
            r.p,
            r.m,
            r.n,
            algo_name(r.auto_pick),
            algo_name(r.winner),
            r.auto_time,
            r.winner_time,
            r.matched(),
            r.loss(),
            cands.join(", "),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}
