//! Differential SPMD parity suite: for a seeded `(p, n, root, kind)`
//! grid — p over 1, powers of two ±1 and primes — the per-rank
//! `RankComm` outputs over **all three** transports (`ThreadTransport`,
//! the real one-thread-per-rank runtime; `LoopbackTransport`, the
//! lockstep round-barrier replay; and `SocketTransport`, real OS
//! sockets with length-prefixed frames) must be bit-identical to the
//! god-view `Communicator` outcomes on the lockstep and engine
//! backends: payloads, completion, and the full `RunStats` accounting.
//!
//! This is the receipt for the rank plane's core claim: recomputing each
//! rank's schedule independently in O(log p) (no shared table, no
//! communication) yields exactly the schedules — and therefore exactly
//! the collectives — the whole-machine plane produces.
//!
//! Deterministic by default; honors `TESTKIT_SEED` (CI runs the fixed
//! three-seed matrix).

use std::sync::Arc;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::rank::{
    spmd_allgatherv, spmd_allreduce, spmd_bcast, spmd_reduce, spmd_reduce_scatter,
};
use circulant_bcast::comm::{
    Algo, AllgathervReq, AllreduceReq, BackendKind, BcastReq, CommBuilder, Communicator,
    ReduceReq, ReduceScatterReq, TransportKind,
};
use circulant_bcast::schedule::Skips;
use circulant_bcast::sim::{RunStats, UnitCost};
use circulant_bcast::testkit::{install_seed_reporter, Rng};

fn comm(p: usize, backend: BackendKind) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).backend(backend).build()
}

/// Socket worlds are a full p·(p−1) mesh of socketpair fd ends; cap
/// in-process socket parity at 24 ranks (552 fds) to stay well inside
/// the default 1024-fd soft limit. The p = 64 case is the `#[ignore]`d
/// release smoke.
const SOCKET_P_CAP: usize = 24;

/// The backends every case is checked against (beyond the lockstep
/// base): engine and SPMD always, the wire plane when the fd budget
/// allows.
fn diff_backends(p: usize) -> Vec<BackendKind> {
    let mut backends = vec![BackendKind::Engine, BackendKind::Spmd];
    if p <= SOCKET_P_CAP {
        backends.push(BackendKind::Socket);
    }
    backends
}

fn assert_stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.active_rounds, b.active_rounds, "{ctx}: active_rounds");
    assert_eq!(a.messages, b.messages, "{ctx}: messages");
    assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
    assert_eq!(a.max_rank_bytes, b.max_rank_bytes, "{ctx}: max_rank_bytes");
    assert!((a.time - b.time).abs() < 1e-12, "{ctx}: time {} vs {}", a.time, b.time);
}

#[derive(Debug, Clone, Copy)]
struct Case {
    p: usize,
    root: usize,
    m: usize,
    n: usize,
    kind: usize,
}

fn gen_case(rng: &mut Rng) -> Case {
    // p = 1, powers of two and their ±1 neighbours, primes.
    let p = match rng.range(0, 4) {
        0 => 1,
        1 => 1 << rng.range(1, 5),
        2 => (1 << rng.range(1, 5)) + 1,
        3 => (1 << rng.range(2, 5)) - 1,
        _ => [3, 7, 13, 17, 19, 23, 29, 31][rng.range(0, 7)],
    };
    Case {
        p,
        root: rng.range(0, p - 1),
        m: rng.range(0, 120),
        n: rng.range(1, 10),
        kind: rng.range(0, 4),
    }
}

/// God-view truth on lockstep + engine, SPMD over both transports, all
/// compared bit for bit.
fn check_case(c: &Case) {
    let ctx = format!("{c:?}");
    let sk = Arc::new(Skips::new(c.p));
    match c.kind {
        // ----- bcast -----
        0 => {
            let data: Vec<i64> = (0..c.m as i64).map(|i| i * 7 - 11).collect();
            let run = |backend| {
                comm(c.p, backend)
                    .bcast(
                        BcastReq::new(c.root, &data)
                            .algo(Algo::Circulant)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in diff_backends(c.p) {
                let out = run(backend);
                assert_eq!(out.algo, base.algo, "{ctx} [{backend:?}]: algo");
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_eq!(out.all_received(), base.all_received(), "{ctx} [{backend:?}]");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
            let (lstats, lbufs) = spmd_bcast(
                &sk,
                c.root,
                &data,
                c.n,
                8,
                &UnitCost,
                TransportKind::Loopback,
                None,
            )
            .unwrap_or_else(|e| panic!("{ctx} [loopback]: {e}"));
            assert_eq!(lbufs, base.buffers, "{ctx} [loopback]: payload");
            assert_stats_eq(&lstats, &base.stats, &format!("{ctx} [loopback]"));
        }
        // ----- reduce -----
        1 => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r * 41 + i * 13) % 509) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .reduce(
                        ReduceReq::new(c.root, &inputs, Arc::new(SumOp))
                            .algo(Algo::Circulant)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in diff_backends(c.p) {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
            let (lstats, lbuf) = spmd_reduce(
                &sk,
                c.root,
                &inputs,
                c.n,
                Arc::new(SumOp),
                8,
                &UnitCost,
                TransportKind::Loopback,
                None,
            )
            .unwrap_or_else(|e| panic!("{ctx} [loopback]: {e}"));
            assert_eq!(lbuf, base.buffers, "{ctx} [loopback]: payload");
            assert_stats_eq(&lstats, &base.stats, &format!("{ctx} [loopback]"));
        }
        // ----- allgatherv (irregular counts derived from the case) -----
        2 => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..(c.m + r * 3) % 60).map(|i| (r * 1000 + i) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .allgatherv(
                        AllgathervReq::new(&inputs)
                            .algo(Algo::Circulant)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in diff_backends(c.p) {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
            let (lstats, lbufs) =
                spmd_allgatherv(&sk, &inputs, c.n, 8, &UnitCost, TransportKind::Loopback, None)
                    .unwrap_or_else(|e| panic!("{ctx} [loopback]: {e}"));
            assert_eq!(lbufs, base.buffers, "{ctx} [loopback]: payload");
            assert_stats_eq(&lstats, &base.stats, &format!("{ctx} [loopback]"));
        }
        // ----- reduce-scatter (irregular counts) -----
        3 => {
            let counts: Vec<usize> = (0..c.p).map(|r| (c.m + r * 5) % 23).collect();
            let total: usize = counts.iter().sum();
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..total).map(|i| ((r + 3) * (i + 1) % 401) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .reduce_scatter(
                        ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp))
                            .algo(Algo::Circulant)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in diff_backends(c.p) {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
            let (lstats, lchunks) = spmd_reduce_scatter(
                &sk,
                &inputs,
                &counts,
                c.n,
                Arc::new(SumOp),
                8,
                &UnitCost,
                TransportKind::Loopback,
                None,
            )
            .unwrap_or_else(|e| panic!("{ctx} [loopback]: {e}"));
            assert_eq!(lchunks, base.buffers, "{ctx} [loopback]: payload");
            assert_stats_eq(&lstats, &base.stats, &format!("{ctx} [loopback]"));
        }
        // ----- allreduce -----
        _ => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r + 1) * (i + 1) % 333) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .allreduce(
                        AllreduceReq::new(&inputs, Arc::new(SumOp))
                            .algo(Algo::Circulant)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in diff_backends(c.p) {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
            // Loopback direct fan-out: per-phase stats must recombine to
            // the god-view aggregate.
            let (rs, ag, lbufs) = spmd_allreduce(
                &sk,
                &inputs,
                c.n,
                Arc::new(SumOp),
                8,
                &UnitCost,
                TransportKind::Loopback,
                None,
            )
            .unwrap_or_else(|e| panic!("{ctx} [loopback]: {e}"));
            assert_eq!(lbufs, base.buffers, "{ctx} [loopback]: payload");
            assert_eq!(rs.rounds + ag.rounds, base.stats.rounds, "{ctx} [loopback]");
            assert_eq!(
                rs.active_rounds + ag.active_rounds,
                base.stats.active_rounds,
                "{ctx} [loopback]"
            );
            assert_eq!(rs.messages + ag.messages, base.stats.messages, "{ctx} [loopback]");
            assert_eq!(rs.bytes + ag.bytes, base.stats.bytes, "{ctx} [loopback]");
            assert_eq!(
                rs.max_rank_bytes + ag.max_rank_bytes,
                base.stats.max_rank_bytes,
                "{ctx} [loopback]"
            );
            assert!(
                (rs.time + ag.time - base.stats.time).abs() < 1e-12,
                "{ctx} [loopback]: time"
            );
        }
    }
}

#[test]
fn seeded_random_grid_spmd_matches_god_view() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for _ in 0..30 {
        let c = gen_case(&mut rng);
        check_case(&c);
    }
}

#[test]
fn degenerate_and_boundary_cases() {
    // What a random grid can miss: p = 1 (zero rounds on every plane),
    // empty payloads, more blocks than elements, non-zero roots at
    // non-powers-of-two p, every collective kind.
    let fixed = [
        Case { p: 1, root: 0, m: 10, n: 3, kind: 0 },
        Case { p: 1, root: 0, m: 10, n: 1, kind: 1 },
        Case { p: 1, root: 0, m: 7, n: 2, kind: 4 },
        Case { p: 2, root: 1, m: 33, n: 4, kind: 0 },
        Case { p: 17, root: 16, m: 0, n: 5, kind: 0 },
        Case { p: 17, root: 3, m: 3, n: 9, kind: 0 },
        Case { p: 18, root: 9, m: 100, n: 5, kind: 1 },
        Case { p: 31, root: 0, m: 50, n: 6, kind: 2 },
        Case { p: 13, root: 0, m: 40, n: 3, kind: 3 },
        Case { p: 9, root: 0, m: 61, n: 2, kind: 4 },
        Case { p: 33, root: 20, m: 64, n: 7, kind: 0 },
    ];
    for c in fixed {
        check_case(&c);
    }
}

#[test]
fn spmd_backend_serves_non_circulant_algos_too() {
    // Under BackendKind::Spmd, non-circulant algorithms run their
    // generic state machines over ThreadTransport — same results as
    // lockstep.
    let p = 13usize;
    let data: Vec<i64> = (0..200).collect();
    let base = comm(p, BackendKind::Lockstep)
        .bcast(BcastReq::new(3, &data).algo(Algo::Binomial))
        .unwrap();
    let out = comm(p, BackendKind::Spmd)
        .bcast(BcastReq::new(3, &data).algo(Algo::Binomial))
        .unwrap();
    assert_eq!(out.buffers, base.buffers);
    assert_stats_eq(&out.stats, &base.stats, "binomial under spmd");
}

/// The wire-plane parity grid (socket side of the seeded matrix):
/// seeded `(p, n, root, kind)` cases clamped to the socketpair fd
/// budget, each run through the full differential check — which at
/// these sizes includes `BackendKind::Socket`, i.e. real OS sockets
/// carrying every schedule message — plus the direct rank-plane
/// fan-out over `TransportKind::Socket`. Buffers AND stats must be
/// bit-identical to lockstep.
#[test]
fn socket_parity() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    let mut checked = 0usize;
    while checked < 20 {
        let c = gen_case(&mut rng);
        if c.p > SOCKET_P_CAP {
            continue;
        }
        check_case(&c);
        checked += 1;
    }

    // The direct SPMD entry point over real sockets, p = 1 (a world of
    // zero links) and a prime.
    for p in [1usize, 11] {
        let sk = Arc::new(Skips::new(p));
        let data: Vec<i64> = (0..64).map(|i| i * 3 - 40).collect();
        let base = comm(p, BackendKind::Lockstep)
            .bcast(BcastReq::new(p - 1, &data).algo(Algo::Circulant).blocks(4).elem_bytes(8))
            .unwrap();
        let (stats, bufs) =
            spmd_bcast(&sk, p - 1, &data, 4, 8, &UnitCost, TransportKind::Socket, None)
                .unwrap_or_else(|e| panic!("p={p} [socket direct]: {e}"));
        assert_eq!(bufs, base.buffers, "p={p} [socket direct]: payload");
        assert_stats_eq(&stats, &base.stats, &format!("p={p} [socket direct]"));
    }
}

/// Release smoke (CI `socket-smoke` job): p = 64 over real socketpairs
/// is 64·63 = 4032 fd ends — beyond the default 1024-fd soft limit, so
/// `#[ignore]`d in the default run (the CI job raises `ulimit -n`
/// before opting in).
#[test]
#[ignore]
fn smoke_p64_socket_transport() {
    install_seed_reporter();
    let p = 64usize;
    let data: Vec<i64> = (0..1024).map(|i| (i * 37) % 1013).collect();
    let base = comm(p, BackendKind::Lockstep)
        .bcast(BcastReq::new(17, &data).algo(Algo::Circulant).blocks(6).elem_bytes(8))
        .unwrap();
    let out = comm(p, BackendKind::Socket)
        .bcast(BcastReq::new(17, &data).algo(Algo::Circulant).blocks(6).elem_bytes(8))
        .unwrap();
    assert_eq!(out.buffers, base.buffers);
    assert_stats_eq(&out.stats, &base.stats, "p=64 socket bcast");
    assert!(out.all_received());

    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..256).map(|i| ((r + 1) * (i + 1)) as i64 % 7919).collect()).collect();
    let base = comm(p, BackendKind::Lockstep)
        .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(4))
        .unwrap();
    let out = comm(p, BackendKind::Socket)
        .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(4))
        .unwrap();
    assert_eq!(out.buffers, base.buffers);
    assert_stats_eq(&out.stats, &base.stats, "p=64 socket allreduce");
}

/// Release smoke (CI `spmd-smoke` job): p = 512 real rank threads over
/// `ThreadTransport`, full payload + stats parity against the lockstep
/// god view. `#[ignore]`d in the default run — 512 OS threads per call
/// is deliberate load, not unit-test fare.
#[test]
#[ignore]
fn smoke_p512_thread_transport() {
    install_seed_reporter();
    let p = 512usize;
    let data: Vec<i64> = (0..2048).map(|i| (i * 37) % 1013).collect();
    let base = comm(p, BackendKind::Lockstep)
        .bcast(BcastReq::new(129, &data).algo(Algo::Circulant).blocks(8).elem_bytes(8))
        .unwrap();
    let out = comm(p, BackendKind::Spmd)
        .bcast(BcastReq::new(129, &data).algo(Algo::Circulant).blocks(8).elem_bytes(8))
        .unwrap();
    assert_eq!(out.buffers, base.buffers);
    assert_stats_eq(&out.stats, &base.stats, "p=512 bcast");
    assert!(out.all_received());

    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..512).map(|i| ((r + 1) * (i + 1)) as i64 % 7919).collect())
        .collect();
    let base = comm(p, BackendKind::Lockstep)
        .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(4))
        .unwrap();
    let out = comm(p, BackendKind::Spmd)
        .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(4))
        .unwrap();
    assert_eq!(out.buffers, base.buffers);
    assert_stats_eq(&out.stats, &base.stats, "p=512 allreduce");
}
