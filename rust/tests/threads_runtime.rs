//! The threaded runtime: every rank a real OS thread, channels instead of
//! lockstep — validates that the schedules need no global synchrony
//! (round numbers are tags, not barriers), exactly as MPI processes
//! behave.

use circulant_bcast::collectives::bcast::BcastProc;
use circulant_bcast::collectives::common::{BlockGeometry, World};
use circulant_bcast::collectives::reduce::ReduceProc;
use circulant_bcast::collectives::SumOp;
use circulant_bcast::sim::run_threaded;
use std::sync::Arc;

#[test]
fn threaded_bcast_small() {
    for p in [2usize, 5, 9, 17] {
        let m = 64usize;
        let n = 4usize;
        let data: Vec<i64> = (0..m as i64).collect();
        let world = World::new(p);
        let geom = BlockGeometry::new(m, n);
        let procs: Vec<BcastProc<i64>> = (0..p)
            .map(|r| {
                BcastProc::new(&world, r, 0, geom, if r == 0 { Some(&data[..]) } else { None })
            })
            .collect();
        let done = run_threaded(procs);
        for (r, pr) in done.into_iter().enumerate() {
            assert!(pr.complete(), "p={p} rank {r} incomplete");
            assert_eq!(pr.into_buffer(), data, "p={p} rank {r}");
        }
    }
}

#[test]
fn threaded_bcast_nonzero_root() {
    let p = 18usize;
    let m = 90usize;
    let n = 6usize;
    let root = 11usize;
    let data: Vec<i64> = (0..m as i64).map(|i| i * i).collect();
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let procs: Vec<BcastProc<i64>> = (0..p)
        .map(|r| {
            let buf = if r == root { Some(&data[..]) } else { None };
            BcastProc::new(&world, r, root, geom, buf)
        })
        .collect();
    for pr in run_threaded(procs) {
        assert_eq!(pr.into_buffer(), data);
    }
}

#[test]
fn threaded_reduce() {
    let p = 17usize;
    let m = 50usize;
    let n = 5usize;
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..m).map(|i| (r * 7 + i) as i64).collect())
        .collect();
    let want: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let procs: Vec<ReduceProc<i64>> = (0..p)
        .map(|r| ReduceProc::new(&world, r, 0, geom, &inputs[r], Arc::new(SumOp)))
        .collect();
    let done = run_threaded(procs);
    let root = done.into_iter().next().unwrap();
    assert_eq!(root.into_buffer(), want);
}

#[test]
fn threaded_matches_lockstep() {
    // Same collective, both backends of one Communicator, identical
    // results and identical cost accounting.
    use circulant_bcast::comm::{Algo, BackendKind, BcastReq, CommBuilder};
    use circulant_bcast::sim::UnitCost;
    let p = 13usize;
    let m = 77usize;
    let n = 7usize;
    let data: Vec<i64> = (0..m as i64).map(|i| i * 31 % 101).collect();

    let mk = || BcastReq::new(3, &data).algo(Algo::Circulant).blocks(n).elem_bytes(8);
    let lockstep = CommBuilder::new(p)
        .cost_model(UnitCost)
        .backend(BackendKind::Lockstep)
        .build()
        .bcast(mk())
        .unwrap();
    let threaded = CommBuilder::new(p)
        .cost_model(UnitCost)
        .backend(BackendKind::Threaded)
        .build()
        .bcast(mk())
        .unwrap();
    assert_eq!(lockstep.buffers, threaded.buffers);
    assert_eq!(lockstep.stats.messages, threaded.stats.messages);
    assert_eq!(lockstep.stats.bytes, threaded.stats.bytes);
    assert_eq!(lockstep.stats.rounds, threaded.stats.rounds);
    assert_eq!(lockstep.stats.active_rounds, threaded.stats.active_rounds);
    assert!((lockstep.stats.time - threaded.stats.time).abs() < 1e-12);

    // And the raw proc-level threaded driver agrees too.
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let procs: Vec<BcastProc<i64>> = (0..p)
        .map(|r| BcastProc::new(&world, r, 3, geom, if r == 3 { Some(&data[..]) } else { None }))
        .collect();
    let raw: Vec<Vec<i64>> =
        run_threaded(procs).into_iter().map(|pr| pr.into_buffer()).collect();
    assert_eq!(lockstep.buffers, raw);
}

#[test]
fn threaded_many_ranks() {
    // Stress: 64 OS threads, bigger pipeline.
    let p = 64usize;
    let m = 256usize;
    let n = 16usize;
    let data: Vec<i64> = (0..m as i64).collect();
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let procs: Vec<BcastProc<i64>> = (0..p)
        .map(|r| BcastProc::new(&world, r, 0, geom, if r == 0 { Some(&data[..]) } else { None }))
        .collect();
    for pr in run_threaded(procs) {
        assert!(pr.complete());
    }
}

#[test]
fn threaded_allgatherv() {
    use circulant_bcast::collectives::allgatherv::{AllgathervProc, ScheduleTable};
    let p = 12usize;
    let counts: Vec<usize> = (0..p).map(|i| (i % 3) * 8).collect();
    let inputs: Vec<Vec<i64>> = counts
        .iter()
        .enumerate()
        .map(|(r, &c)| (0..c).map(|i| (r * 100 + i) as i64).collect())
        .collect();
    let world = World::new(p);
    let table = ScheduleTable::build(&world, 3);
    let counts = Arc::new(counts);
    let procs: Vec<AllgathervProc<i64>> = (0..p)
        .map(|r| AllgathervProc::new(table.clone(), counts.clone(), r, &inputs[r]))
        .collect();
    let done = run_threaded(procs);
    for (r, pr) in done.into_iter().enumerate() {
        let bufs = pr.into_buffers();
        for j in 0..p {
            assert_eq!(bufs[j], inputs[j], "rank {r} root {j}");
        }
    }
}

#[test]
fn threaded_reduce_scatter() {
    use circulant_bcast::collectives::allgatherv::ScheduleTable;
    use circulant_bcast::collectives::reduce_scatter::ReduceScatterProc;
    let p = 9usize;
    let chunk = 6usize;
    let counts = Arc::new(vec![chunk; p]);
    let total = p * chunk;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..total).map(|i| ((r + 1) * (i + 1)) as i64 % 251).collect()).collect();
    let sums: Vec<i64> = (0..total).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let world = World::new(p);
    let table = ScheduleTable::build(&world, 2);
    let procs: Vec<ReduceScatterProc<i64>> = (0..p)
        .map(|r| {
            ReduceScatterProc::new(table.clone(), counts.clone(), r, &inputs[r], Arc::new(SumOp))
        })
        .collect();
    let done = run_threaded(procs);
    for (r, pr) in done.into_iter().enumerate() {
        assert_eq!(pr.into_chunk(), sums[r * chunk..(r + 1) * chunk].to_vec(), "rank {r}");
    }
}
