//! Integration: the PJRT runtime executes the AOT artifacts correctly and
//! backs the reduction collectives end to end (Python authored the HLO at
//! build time; only Rust runs here).
//!
//! Gated on the `xla` cargo feature: the offline build image has no `xla`
//! crate, so the default build compiles the stub runtime and these tests
//! (which need the real PJRT client + `make artifacts`) are skipped
//! entirely.

#![cfg(feature = "xla")]

use std::sync::Arc;

use circulant_bcast::collectives::ReduceOp;
use circulant_bcast::comm::{
    Algo, CommBuilder, Communicator, ReduceReq, ReduceScatterBlockReq,
};
use circulant_bcast::runtime::{DType, XlaRuntime, XlaSumOp};
use circulant_bcast::sim::LinearCost;

fn comm(p: usize) -> Communicator {
    CommBuilder::new(p).cost_model(LinearCost::hpc_default()).build()
}

fn runtime() -> Arc<XlaRuntime> {
    Arc::new(XlaRuntime::new().expect("artifacts missing — run `make artifacts`"))
}

#[test]
fn discovers_expected_artifacts() {
    let rt = runtime();
    assert!(rt.artifacts().len() >= 10, "got {}", rt.artifacts().len());
    assert!(rt.select_pair("sum", DType::F32, 1000).is_some());
    assert!(rt.select_pair("sum", DType::I32, 1000).is_some());
    assert!(rt.select_pair("max", DType::F32, 1000).is_some());
}

#[test]
fn pair_combine_exact_block() {
    let rt = runtime();
    let art = rt.select_pair("sum", DType::F32, 4096).unwrap();
    let m = art.block_len();
    let x: Vec<f32> = (0..m).map(|i| i as f32).collect();
    let y: Vec<f32> = (0..m).map(|i| 2.0 * i as f32).collect();
    let out = rt.pair_combine("sum", DType::F32, &x, &y, 0.0).unwrap();
    for i in 0..m {
        assert_eq!(out[i], 3.0 * i as f32);
    }
}

#[test]
fn pair_combine_odd_lengths_padded() {
    let rt = runtime();
    for m in [1usize, 7, 1023, 1025, 5000, 70000, 100001] {
        let x: Vec<f32> = (0..m).map(|i| (i % 97) as f32).collect();
        let y: Vec<f32> = (0..m).map(|i| (i % 13) as f32).collect();
        let out = rt.pair_combine("sum", DType::F32, &x, &y, 0.0).unwrap();
        assert_eq!(out.len(), m);
        for i in 0..m {
            assert_eq!(out[i], x[i] + y[i], "m={m} i={i}");
        }
    }
}

#[test]
fn pair_combine_i32() {
    let rt = runtime();
    let m = 9999usize;
    let x: Vec<i32> = (0..m as i32).collect();
    let y: Vec<i32> = (0..m as i32).map(|i| -2 * i).collect();
    let out = rt.pair_combine("sum", DType::I32, &x, &y, 0).unwrap();
    for i in 0..m {
        assert_eq!(out[i], -(i as i32));
    }
}

#[test]
fn max_combine_with_identity_pad() {
    let rt = runtime();
    let m = 5001usize;
    let x: Vec<f32> = (0..m).map(|i| (i % 31) as f32 - 15.0).collect();
    let y: Vec<f32> = (0..m).map(|i| (i % 17) as f32 - 8.0).collect();
    let out = rt.pair_combine("max", DType::F32, &x, &y, f32::NEG_INFINITY).unwrap();
    for i in 0..m {
        assert_eq!(out[i], x[i].max(y[i]), "i={i}");
    }
}

#[test]
fn xla_op_matches_native_sum() {
    let rt = runtime();
    let op = XlaSumOp::new(rt);
    let mut acc: Vec<f32> = (0..3000).map(|i| i as f32 * 0.5).collect();
    let incoming: Vec<f32> = (0..3000).map(|i| i as f32 * 0.25).collect();
    let want: Vec<f32> = acc.iter().zip(&incoming).map(|(a, b)| a + b).collect();
    ReduceOp::<f32>::combine(&op, &mut acc, &incoming);
    assert_eq!(acc, want);
}

#[test]
fn reduce_collective_with_xla_operator() {
    // The full paper pipeline: reversed-schedule MPI_Reduce with the ⊕
    // executed by the AOT-compiled XLA module.
    let rt = runtime();
    let op = Arc::new(XlaSumOp::new(rt));
    let p = 9usize;
    let m = 600usize;
    let inputs: Vec<Vec<f32>> = (0..p)
        .map(|r| (0..m).map(|i| (r * 7 + i) as f32 * 0.125).collect())
        .collect();
    let expect: Vec<f32> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let out = comm(p)
        .reduce(ReduceReq::new(0, &inputs, op).algo(Algo::Circulant).blocks(4).elem_bytes(4))
        .unwrap();
    assert_eq!(out.buffers.len(), m);
    for i in 0..m {
        assert!((out.buffers[i] - expect[i]).abs() < 1e-3, "i={i}");
    }
}

#[test]
fn reduce_scatter_with_xla_operator() {
    let rt = runtime();
    let op = Arc::new(XlaSumOp::new(rt));
    let p = 8usize;
    let chunk = 50usize;
    let inputs: Vec<Vec<i32>> = (0..p)
        .map(|r| (0..p * chunk).map(|i| (r as i32 + 1) * (i as i32 % 11)).collect())
        .collect();
    let sums: Vec<i32> =
        (0..p * chunk).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let out = comm(p)
        .reduce_scatter_block(
            ReduceScatterBlockReq::new(&inputs, chunk, op)
                .algo(Algo::Circulant)
                .blocks(2)
                .elem_bytes(4),
        )
        .unwrap();
    for r in 0..p {
        assert_eq!(out.buffers[r], sums[r * chunk..(r + 1) * chunk].to_vec(), "rank {r}");
    }
}

#[test]
fn compile_all_artifacts() {
    let rt = runtime();
    let n = rt.compile_all().unwrap();
    assert_eq!(n, rt.artifacts().len());
}

#[test]
fn stack_reduce_matches_pairwise() {
    // The whole-phase combine (reduce_stack artifact) must agree with a
    // chain of pairwise combines.
    let rt = runtime();
    let w = 8usize;
    for m in [100usize, 4096, 5000] {
        let xs: Vec<Vec<f32>> = (0..w)
            .map(|r| (0..m).map(|i| ((r * 13 + i) % 101) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let got = rt.stack_reduce("sum", DType::F32, &refs, 0.0).unwrap();
        let mut want = xs[0].clone();
        for x in &xs[1..] {
            let out = rt.pair_combine("sum", DType::F32, &want, x, 0.0).unwrap();
            want = out;
        }
        assert_eq!(got, want, "m={m}");
        // and against native
        let native: Vec<f32> =
            (0..m).map(|i| xs.iter().map(|v| v[i]).sum()).collect();
        assert_eq!(got, native, "m={m}");
    }
}

#[test]
fn stack_reduce_i32_and_max() {
    let rt = runtime();
    let w = 8usize;
    let m = 2000usize;
    let xs: Vec<Vec<i32>> =
        (0..w).map(|r| (0..m).map(|i| ((r + i) % 37) as i32 - 18).collect()).collect();
    let refs: Vec<&[i32]> = xs.iter().map(|v| v.as_slice()).collect();
    let sum = rt.stack_reduce("sum", DType::I32, &refs, 0).unwrap();
    let want: Vec<i32> = (0..m).map(|i| xs.iter().map(|v| v[i]).sum()).collect();
    assert_eq!(sum, want);

    let xf: Vec<Vec<f32>> = xs.iter().map(|v| v.iter().map(|&x| x as f32).collect()).collect();
    let reff: Vec<&[f32]> = xf.iter().map(|v| v.as_slice()).collect();
    let mx = rt.stack_reduce("max", DType::F32, &reff, f32::NEG_INFINITY).unwrap();
    let wantf: Vec<f32> = (0..m)
        .map(|i| xf.iter().map(|v| v[i]).fold(f32::NEG_INFINITY, f32::max))
        .collect();
    assert_eq!(mx, wantf);
}
