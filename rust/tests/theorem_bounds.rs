//! Machine-checks of the paper's complexity theorems on the instrumented
//! algorithms:
//!
//! * Theorem 2 / Lemma 5: `ALLBLOCKS` performs at most `q - 1` recursive
//!   calls per processor.
//! * Lemma 6: total while-loop scans bounded linearly in `q` (we check
//!   `3q + R`; see the accounting note in `schedule::recv`).
//! * Theorem 3: at most **4** send-schedule violations per processor,
//!   each resolved by one receive-schedule computation.
//! * The aggregate O(p log p) behaviour of computing all schedules.

use circulant_bcast::schedule::{recv_schedule, send_schedule, Skips};

#[test]
fn lemma5_recursions_dense() {
    for p in 2..=3000 {
        let sk = Skips::new(p);
        let limit = sk.q().saturating_sub(1);
        for r in 0..p {
            let s = recv_schedule(&sk, r);
            assert!(
                s.stats.recursions <= limit,
                "p={p} r={r}: R={} > {limit}",
                s.stats.recursions
            );
        }
    }
}

#[test]
fn lemma6_scans_dense() {
    let mut worst_ratio = 0.0f64;
    for p in 2..=3000 {
        let sk = Skips::new(p);
        let q = sk.q();
        for r in 0..p {
            let s = recv_schedule(&sk, r);
            assert!(
                s.stats.scans <= 3 * q + s.stats.recursions,
                "p={p} r={r}: scans={} R={}",
                s.stats.scans,
                s.stats.recursions
            );
            worst_ratio = worst_ratio
                .max((s.stats.scans - s.stats.recursions) as f64 / q as f64);
        }
    }
    // Empirically ~2.5; certify it stays strictly linear in q.
    assert!(worst_ratio <= 3.0, "worst (scans-R)/q = {worst_ratio}");
}

#[test]
fn theorem3_violations_dense() {
    let mut histogram = [0usize; 6];
    for p in 2..=3000 {
        let sk = Skips::new(p);
        for r in 0..p {
            let v = send_schedule(&sk, r).violations;
            assert!(v <= 4, "p={p} r={r}: {v} violations");
            histogram[v] += 1;
        }
    }
    // Violations must actually occur somewhere (the bound is not vacuous)
    // and small counts must dominate large ones (each violation is O(1)
    // per processor; 0/1 are the common cases, 3/4 the rare tail).
    assert!(histogram[1] + histogram[2] + histogram[3] + histogram[4] > 0);
    assert!(
        histogram[0] + histogram[1] > 10 * (histogram[3] + histogram[4]),
        "histogram: {histogram:?}"
    );
}

#[test]
fn theorem3_violations_large_sampled() {
    for p in [(1usize << 18) + 3, (1 << 20) + 1, (1 << 22) + 5] {
        let sk = Skips::new(p);
        for i in 0..2000 {
            let r = (i * 48_611) % p;
            let v = send_schedule(&sk, r).violations;
            assert!(v <= 4, "p={p} r={r}: {v}");
        }
    }
}

#[test]
fn schedule_cost_grows_logarithmically() {
    // Work per processor (scans + violations·q) must grow like q, not q²:
    // compare mean work at p≈2^10 and p≈2^20 — ratio should be ≈2, far
    // below the ≈4 of a quadratic algorithm. (Wall-clock is checked in
    // the Table 4 bench; this is the machine-independent version.)
    let work = |p: usize| -> f64 {
        let sk = Skips::new(p);
        let samples = 512.min(p);
        let mut total = 0usize;
        for i in 0..samples {
            let r = (i * 2_654_435_761) % p;
            let s = recv_schedule(&sk, r);
            let v = send_schedule(&sk, r).violations;
            total += s.stats.scans + v * sk.q();
        }
        total as f64 / samples as f64
    };
    let w10 = work((1 << 10) + 1);
    let w20 = work((1 << 20) + 1);
    let ratio = w20 / w10;
    assert!(
        ratio < 3.2,
        "per-processor work grew superlinearly in q: w10={w10:.1} w20={w20:.1} ratio={ratio:.2}"
    );
}

#[test]
fn baseline_work_is_superlinear_in_q() {
    // Sanity for Table 4's contrast: the old-style send computation costs
    // ~q receive schedules, so its work ratio 2^10 -> 2^20 should be ≈4.
    use circulant_bcast::schedule::baseline::schedules_oldstyle;
    use std::time::Instant;
    let time = |p: usize| {
        let sk = Skips::new(p);
        let t = Instant::now();
        for i in 0..256 {
            let r = (i * 7919) % p;
            std::hint::black_box(schedules_oldstyle(&sk, r));
        }
        t.elapsed().as_secs_f64()
    };
    let t10 = time((1 << 10) + 1);
    let t20 = time((1 << 20) + 1);
    // Expect ≳ 2.5x (q³ scaling gives 8x; allow slack for constants).
    assert!(
        t20 / t10 > 1.8,
        "old-style baseline did not show superlinear scaling: {:.2}",
        t20 / t10
    );
}
