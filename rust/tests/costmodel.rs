//! The cost plane contract (integration level):
//!
//! * **Back-compat** — with no LogP parameters configured, `Algo::Auto`
//!   reproduces the paper's §3 picks verbatim over a (kind, p, m,
//!   blocks) grid.
//! * **Cross-validation** — circulant runs under a configured machine
//!   carry `RunStats::logp_time`, keep the optimal `n - 1 + q` round
//!   count, and the predicted time is monotone in each of L, o, g.
//! * **OptTree** — `Algo::OptTree` is bit-identical across the lockstep,
//!   engine, SPMD and threaded backends, and its measured `logp_time`
//!   equals the greedy construction's own completion label (the
//!   `predict_opttree` closed form is exact, not an estimate).
//! * **Cost-driven Auto** — with a machine configured, `Algo::Auto`
//!   follows the predicted-cost argmin: trees for small rooted
//!   payloads, the pipelined circulant for large ones, and explicit
//!   block counts still pin the circulant pipeline.

use std::sync::Arc;

use circulant_bcast::collectives::tuning::predict_opttree;
use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    Algo, BackendKind, BcastReq, CommBuilder, Communicator, Kind, ReduceReq, TuningParams,
};
use circulant_bcast::schedule::ceil_log2;
use circulant_bcast::sim::{LogPParams, UnitCost};

/// Explicit tuning literal — never `TuningParams::default()` for the
/// `logp` field, which reads the `CBCAST_LOGP_*` env knobs and would
/// race with whatever environment the test harness runs under.
fn tuning(logp: Option<LogPParams>) -> TuningParams {
    TuningParams { logp, ..TuningParams::default() }
}

fn comm(p: usize, logp: Option<LogPParams>) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).tuning(tuning(logp)).build()
}

// -------------------------------------------------------------------
// Back-compat: no machine configured => the legacy rules, verbatim.
// -------------------------------------------------------------------

#[test]
fn auto_without_logp_is_the_legacy_rule_verbatim() {
    let tp = tuning(None);
    for kind in
        [Kind::Bcast, Kind::Reduce, Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce]
    {
        for p in [2usize, 5, 17, 64, 257] {
            for m in [0usize, 1, 7, 64, 4096, 1 << 16] {
                for blocks in [None, Some(4)] {
                    let legacy = Algo::Auto.resolve(kind, m, 8, blocks);
                    let picked = Algo::Auto.resolve_with(kind, p, m, 8, blocks, &tp);
                    assert_eq!(picked, legacy, "{kind:?} p={p} m={m} blocks={blocks:?}");
                }
            }
        }
    }
}

#[test]
fn no_machine_means_no_logp_time() {
    let data: Vec<i64> = (0..340).collect();
    let c = comm(17, None);
    let out = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(5)).unwrap();
    assert_eq!(out.stats.logp_time, None);
}

// -------------------------------------------------------------------
// Cross-validation: the clock against the simulator's round counts.
// -------------------------------------------------------------------

#[test]
fn circulant_runs_carry_a_logp_time_at_optimal_rounds() {
    let params = LogPParams::default();
    let (p, n) = (17usize, 8usize);
    let q = ceil_log2(p);
    let data: Vec<i64> = (0..640).map(|i| i * 3 - 5).collect();
    let c = comm(p, Some(params));

    let req = BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n).elem_bytes(8);
    let out = c.bcast(req).unwrap();
    assert!(out.all_received());
    assert_eq!(out.stats.rounds, n - 1 + q, "bcast keeps the optimal round count");
    let t_bcast = out.stats.logp_time.expect("cost plane attached to bcast");
    assert!(t_bcast > 0.0);

    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..640).map(|i| ((r + 1) * (i + 3) % 271) as i64).collect())
        .collect();
    let req = ReduceReq::new(3, &inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(n);
    let red = c.reduce(req.elem_bytes(8)).unwrap();
    assert_eq!(red.stats.rounds, n - 1 + q, "reduce keeps the optimal round count");
    assert!(red.stats.logp_time.expect("cost plane attached to reduce") > 0.0);
}

#[test]
fn measured_logp_time_is_monotone_in_each_knob() {
    // Multi-packet blocks (2048 elems / 4 blocks = 4 KiB blocks) so the
    // per-packet gap g is visible on the wire, not only at the ports.
    let base = LogPParams::default();
    let data: Vec<i64> = (0..2048).collect();
    let time = |params: LogPParams| {
        let req = BcastReq::new(0, &data).algo(Algo::Circulant).blocks(4).elem_bytes(8);
        comm(13, Some(params)).bcast(req).unwrap().stats.logp_time.unwrap()
    };
    let t0 = time(base);
    assert!(time(LogPParams::new(base.l * 10.0, base.o, base.g)) > t0, "monotone in L");
    assert!(time(LogPParams::new(base.l, base.o * 10.0, base.g)) > t0, "monotone in o");
    assert!(time(LogPParams::new(base.l, base.o, base.g * 10.0)) > t0, "monotone in g");
}

// -------------------------------------------------------------------
// OptTree: backend parity and exactness of the closed-form predictor.
// -------------------------------------------------------------------

#[test]
fn opttree_bit_identical_across_backends() {
    let params = LogPParams::default();
    for p in [5usize, 8, 13] {
        let data: Vec<i64> = (0..96).map(|i| i * 5 - 7).collect();
        let root = 2 % p;
        let run = |backend| {
            let c = CommBuilder::new(p)
                .cost_model(UnitCost)
                .tuning(tuning(Some(params)))
                .backend(backend)
                .build();
            c.bcast(BcastReq::new(root, &data).algo(Algo::OptTree).elem_bytes(8)).unwrap()
        };
        let base = run(BackendKind::Lockstep);
        assert!(base.all_received(), "p={p}");
        assert!(base.buffers.iter().all(|b| b == &data), "p={p}");
        assert!(base.stats.logp_time.is_some(), "p={p}");
        for backend in [BackendKind::Engine, BackendKind::Spmd, BackendKind::Threaded] {
            let out = run(backend);
            assert_eq!(out.algo, base.algo, "p={p} {backend:?}");
            assert_eq!(out.buffers, base.buffers, "p={p} {backend:?}");
            assert_eq!(out.stats.rounds, base.stats.rounds, "p={p} {backend:?}");
            assert_eq!(out.stats.messages, base.stats.messages, "p={p} {backend:?}");
            assert_eq!(out.stats.bytes, base.stats.bytes, "p={p} {backend:?}");
            assert_eq!(
                out.stats.logp_time,
                base.stats.logp_time,
                "p={p} {backend:?}: the predicted time must be backend-invariant"
            );
        }
    }
}

#[test]
fn opttree_reduce_agrees_across_backends() {
    let params = LogPParams::default();
    let p = 9usize;
    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..48).map(|i| ((r * 37 + i * 11) % 401) as i64).collect())
        .collect();
    let expect: Vec<i64> = (0..48).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let run = |backend| {
        let c = CommBuilder::new(p)
            .cost_model(UnitCost)
            .tuning(tuning(Some(params)))
            .backend(backend)
            .build();
        let req = ReduceReq::new(4, &inputs, Arc::new(SumOp)).algo(Algo::OptTree);
        c.reduce(req.elem_bytes(8)).unwrap()
    };
    let base = run(BackendKind::Lockstep);
    assert_eq!(base.buffers, expect);
    for backend in [BackendKind::Engine, BackendKind::Spmd, BackendKind::Threaded] {
        let out = run(backend);
        assert_eq!(out.buffers, expect, "{backend:?}");
        assert_eq!(out.stats.logp_time, base.stats.logp_time, "{backend:?}");
    }
}

#[test]
fn opttree_measured_time_is_the_tree_completion_label() {
    // The greedy construction's completion label IS the LogP time of its
    // own schedule: replaying the tree's round trace through the clock
    // must reproduce it (up to float association noise).
    let params = LogPParams::default();
    for p in [2usize, 6, 13, 32] {
        let m = 512usize; // 4 KiB payload: multi-packet on the wire
        let data: Vec<i64> = (0..m as i64).collect();
        let c = comm(p, Some(params));
        let out = c.bcast(BcastReq::new(0, &data).algo(Algo::OptTree).elem_bytes(8)).unwrap();
        let predicted = predict_opttree(p, m * 8, &params);
        let measured = out.stats.logp_time.unwrap();
        assert!(
            (measured - predicted).abs() <= 1e-9 * predicted.max(1e-12),
            "p={p}: measured={measured} predicted={predicted}"
        );
    }
}

// -------------------------------------------------------------------
// Cost-driven Auto: the argmin over the candidate families.
// -------------------------------------------------------------------

#[test]
fn cost_driven_auto_picks_trees_small_and_pipeline_large() {
    let params = LogPParams::default();
    let tp = tuning(Some(params));

    // Small rooted payload: the Karp tree is LogP-optimal.
    assert_eq!(Algo::Auto.resolve_with(Kind::Bcast, 64, 8, 8, None, &tp), Algo::OptTree);
    // Huge payload: the pipelined circulant amortizes the latency.
    assert_eq!(Algo::Auto.resolve_with(Kind::Bcast, 64, 1 << 20, 8, None, &tp), Algo::Circulant);
    // An explicit block count is a request for the pipeline, machine or no.
    assert_eq!(Algo::Auto.resolve_with(Kind::Bcast, 64, 8, 8, Some(4), &tp), Algo::Circulant);
    // The all-collectives only ever choose between circulant and ring.
    for kind in [Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce] {
        for m in [8usize, 1 << 16] {
            let pick = Algo::Auto.resolve_with(kind, 24, m, 8, None, &tp);
            let ok = pick == Algo::Circulant || pick == Algo::Ring;
            assert!(ok, "{kind:?} m={m}: picked {pick:?}");
        }
    }

    // End to end: the resolved algorithm is reported on the outcome.
    let data: Vec<i64> = (0..64).collect();
    let c = comm(64, Some(params));
    let out = c.bcast(BcastReq::new(0, &data).algo(Algo::Auto).elem_bytes(8)).unwrap();
    assert_eq!(out.algo, Algo::OptTree);
    assert!(out.buffers.iter().all(|b| b == &data));
}
