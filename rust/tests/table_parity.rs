//! Differential parity for the parallel-built schedule plane: the flat
//! all-ranks `ScheduleTable` must match the serial per-rank
//! `recv_schedule` / `send_schedule` cores bit for bit — every row,
//! every baseblock — over a seeded random grid of p (powers of two ±1,
//! primes, p = 1, uniform draws) and thread counts 1, 2 and 8 (chunk
//! boundaries shift with the thread count, so each count exercises a
//! different memo/chunk layout against the same serial truth).
//!
//! Deterministic by default; set `TESTKIT_SEED` to explore other grids
//! (CI runs a fixed seed matrix).

use std::sync::Arc;

use circulant_bcast::schedule::{
    recv_schedule, send_schedule, Schedule, ScheduleCache, ScheduleTable, Skips,
};
use circulant_bcast::testkit::{install_seed_reporter, Rng};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Assert the table rows of every rank equal the serial cores' output.
fn assert_table_matches_serial(p: usize, threads: usize) {
    let sk = Arc::new(Skips::new(p));
    let table = ScheduleTable::build_with_threads(&sk, threads);
    assert_eq!(table.p(), p, "threads={threads}");
    assert_eq!(table.q(), sk.q());
    assert_eq!(table.bytes(), 2 * p * sk.q());
    for r in 0..p {
        let rs = recv_schedule(&sk, r);
        let ss = send_schedule(&sk, r);
        let trecv: Vec<i64> = table.recv_row(r).iter().map(|&v| v as i64).collect();
        let tsend: Vec<i64> = table.send_row(r).iter().map(|&v| v as i64).collect();
        assert_eq!(trecv, rs.blocks, "recv p={p} r={r} threads={threads}");
        assert_eq!(tsend, ss.blocks, "send p={p} r={r} threads={threads}");
        assert_eq!(table.baseblock(r), rs.baseblock, "baseblock p={p} r={r}");
        // The materialised compatibility shape agrees too.
        assert_eq!(table.schedule(r), Schedule::compute(&sk, r), "schedule p={p} r={r}");
    }
}

fn gen_p(rng: &mut Rng) -> usize {
    match rng.range(0, 4) {
        0 => 1,
        // Powers of two and their neighbours (up to 2^11 keeps the
        // serial O(p log p) cross-check fast across the whole grid).
        1 => {
            let base = 1usize << rng.range(1, 11);
            match rng.range(0, 2) {
                0 => base - 1,
                1 => base,
                _ => base + 1,
            }
        }
        2 => [2usize, 3, 5, 7, 13, 17, 31, 61, 127, 251, 509, 1021, 2039][rng.range(0, 12)],
        _ => rng.range(1, 1500),
    }
    .max(1)
}

#[test]
fn seeded_random_grid_matches_serial_cores() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for _ in 0..25 {
        let p = gen_p(&mut rng);
        for threads in THREAD_COUNTS {
            assert_table_matches_serial(p, threads);
        }
    }
}

#[test]
fn fixed_boundary_grid_matches_serial_cores() {
    // The cases a random grid can miss: p = 1 and 2, the paper's table
    // sizes, dense non-powers around chunk-divisibility edges.
    for p in [1usize, 2, 3, 4, 9, 17, 18, 97, 100, 1023, 1024, 1025] {
        for threads in THREAD_COUNTS {
            assert_table_matches_serial(p, threads);
        }
    }
}

#[test]
fn thread_counts_build_identical_arenas() {
    install_seed_reporter();
    // Beyond matching the serial cores rank-by-rank, the whole arena is
    // bitwise equal across thread counts (a cheap whole-plane check at a
    // larger p than the per-rank grid).
    let mut rng = Rng::from_env();
    for _ in 0..3 {
        let p = 2048 + rng.range(0, 2048);
        let sk = Arc::new(Skips::new(p));
        let base = ScheduleTable::build_with_threads(&sk, 1);
        for threads in [2usize, 8] {
            let t = ScheduleTable::build_with_threads(&sk, threads);
            for r in 0..p {
                assert_eq!(t.recv_row(r), base.recv_row(r), "p={p} r={r} threads={threads}");
                assert_eq!(t.send_row(r), base.send_row(r), "p={p} r={r} threads={threads}");
                assert_eq!(t.baseblock(r), base.baseblock(r), "p={p} r={r}");
            }
        }
    }
}

#[test]
fn cache_serves_table_rows_verbatim() {
    install_seed_reporter();
    // The cache's table and single-rank entry points serve the same rows
    // the serial cores produce (the get() path goes through the table
    // under the default cap).
    let cache = ScheduleCache::new();
    let mut rng = Rng::from_env();
    for _ in 0..8 {
        let p = gen_p(&mut rng);
        let sk = cache.skips(p);
        let table = cache.table(&sk);
        for r in 0..p {
            assert_eq!(*cache.get(p, r), table.schedule(r), "p={p} r={r}");
            assert_eq!(table.schedule(r), Schedule::compute(&sk, r), "p={p} r={r}");
        }
    }
}
