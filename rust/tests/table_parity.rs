//! Differential parity for the parallel-built schedule plane: the flat
//! all-ranks `ScheduleTable` must match the serial per-rank
//! `recv_schedule` / `send_schedule` cores bit for bit — every row,
//! every baseblock — over a seeded random grid of p (powers of two ±1,
//! primes, p = 1, uniform draws) and thread counts 1, 2 and 8 (chunk
//! boundaries shift with the thread count, so each count exercises a
//! different memo/chunk layout against the same serial truth). The
//! construction-kernel axis rides the same grids: the batch-vectorized
//! lane kernel (`BuildKernel::Lanes`, vectors of 8 ranks) must build
//! the same arena as the scalar kernel bit for bit, with the
//! lane-boundary p and chunk sizes pinned explicitly.
//!
//! Deterministic by default; set `TESTKIT_SEED` to explore other grids
//! (CI runs a fixed seed matrix).

use std::sync::Arc;

use circulant_bcast::schedule::{
    recv_schedule, send_schedule, BuildKernel, Schedule, ScheduleCache, ScheduleTable, Skips,
};
use circulant_bcast::testkit::{install_seed_reporter, Rng};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Assert the table rows of every rank equal the serial cores' output.
fn assert_table_matches_serial(p: usize, threads: usize) {
    let sk = Arc::new(Skips::new(p));
    let table = ScheduleTable::build_with_threads(&sk, threads);
    assert_eq!(table.p(), p, "threads={threads}");
    assert_eq!(table.q(), sk.q());
    assert_eq!(table.bytes(), 2 * p * sk.q());
    for r in 0..p {
        let rs = recv_schedule(&sk, r);
        let ss = send_schedule(&sk, r);
        let trecv: Vec<i64> = table.recv_row(r).iter().map(|&v| v as i64).collect();
        let tsend: Vec<i64> = table.send_row(r).iter().map(|&v| v as i64).collect();
        assert_eq!(trecv, rs.blocks, "recv p={p} r={r} threads={threads}");
        assert_eq!(tsend, ss.blocks, "send p={p} r={r} threads={threads}");
        assert_eq!(table.baseblock(r), rs.baseblock, "baseblock p={p} r={r}");
        // The materialised compatibility shape agrees too.
        assert_eq!(table.schedule(r), Schedule::compute(&sk, r), "schedule p={p} r={r}");
    }
}

fn gen_p(rng: &mut Rng) -> usize {
    match rng.range(0, 4) {
        0 => 1,
        // Powers of two and their neighbours (up to 2^11 keeps the
        // serial O(p log p) cross-check fast across the whole grid).
        1 => {
            let base = 1usize << rng.range(1, 11);
            match rng.range(0, 2) {
                0 => base - 1,
                1 => base,
                _ => base + 1,
            }
        }
        2 => [2usize, 3, 5, 7, 13, 17, 31, 61, 127, 251, 509, 1021, 2039][rng.range(0, 12)],
        _ => rng.range(1, 1500),
    }
    .max(1)
}

#[test]
fn seeded_random_grid_matches_serial_cores() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for _ in 0..25 {
        let p = gen_p(&mut rng);
        for threads in THREAD_COUNTS {
            assert_table_matches_serial(p, threads);
        }
    }
}

#[test]
fn fixed_boundary_grid_matches_serial_cores() {
    // The cases a random grid can miss: p = 1 and 2, the paper's table
    // sizes, dense non-powers around chunk-divisibility edges.
    for p in [1usize, 2, 3, 4, 9, 17, 18, 97, 100, 1023, 1024, 1025] {
        for threads in THREAD_COUNTS {
            assert_table_matches_serial(p, threads);
        }
    }
}

#[test]
fn thread_counts_build_identical_arenas() {
    install_seed_reporter();
    // Beyond matching the serial cores rank-by-rank, the whole arena is
    // bitwise equal across thread counts (a cheap whole-plane check at a
    // larger p than the per-rank grid).
    let mut rng = Rng::from_env();
    for _ in 0..3 {
        let p = 2048 + rng.range(0, 2048);
        let sk = Arc::new(Skips::new(p));
        let base = ScheduleTable::build_with_threads(&sk, 1);
        for threads in [2usize, 8] {
            let t = ScheduleTable::build_with_threads(&sk, threads);
            for r in 0..p {
                assert_eq!(t.recv_row(r), base.recv_row(r), "p={p} r={r} threads={threads}");
                assert_eq!(t.send_row(r), base.send_row(r), "p={p} r={r} threads={threads}");
                assert_eq!(t.baseblock(r), base.baseblock(r), "p={p} r={r}");
            }
        }
    }
}

/// Assert the vectorized lane kernel builds the same table as the
/// scalar kernel — arena, baseblocks and the violation tally, bit for
/// bit — at one (p, threads) point.
fn assert_kernels_agree(p: usize, threads: usize) {
    let sk = Arc::new(Skips::new(p));
    let scalar = ScheduleTable::build_with_kernel(&sk, threads, BuildKernel::Scalar);
    let lanes = ScheduleTable::build_with_kernel(&sk, threads, BuildKernel::Lanes);
    assert_eq!(
        scalar.violations(),
        lanes.violations(),
        "violation tally p={p} threads={threads}"
    );
    for r in 0..p {
        assert_eq!(scalar.recv_row(r), lanes.recv_row(r), "recv p={p} r={r} threads={threads}");
        assert_eq!(scalar.send_row(r), lanes.send_row(r), "send p={p} r={r} threads={threads}");
        assert_eq!(scalar.baseblock(r), lanes.baseblock(r), "baseblock p={p} r={r}");
    }
}

#[test]
fn lane_boundary_grid_scalar_and_lanes_agree() {
    // The lane kernel walks ranks in vectors of 8: p straddling every
    // multiple of the lane width up to a few vectors — plus thread
    // counts that land chunk boundaries at lane ± 1 (p = 15/16/17 at
    // threads = 2 give chunks of 8/8/9; 63/64/65 at threads = 8 give
    // 8/8/9) — are exactly where a masked tail lane, a clamp-padded
    // rank or a mid-vector chunk split could diverge from the scalar
    // walk.
    let ps = [
        7usize, 8, 9, 15, 16, 17, 23, 24, 25, 31, 32, 33, 63, 64, 65, 127, 128, 129, 1023,
        1024, 1025,
    ];
    for p in ps {
        for threads in THREAD_COUNTS {
            assert_kernels_agree(p, threads);
        }
    }
}

#[test]
fn seeded_grid_scalar_and_lanes_agree() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for _ in 0..10 {
        let p = gen_p(&mut rng);
        let threads = THREAD_COUNTS[rng.range(0, 2)];
        assert_kernels_agree(p, threads);
    }
}

#[test]
fn raw_rows_stay_in_the_half_open_skip_range() {
    // The raw-entry range contract is **half-open**: every arena entry
    // encodes a signed skip index in [-q, q) — `-q` (the q-th negative
    // round) occurs, `+q` never does (positive rounds stop at q − 1).
    // Regression for the doc/code mismatch that claimed a closed
    // [-q, q] range; both kernels are held to it.
    for p in [1usize, 2, 3, 5, 8, 9, 16, 17, 100, 509, 1024, 1025] {
        let sk = Arc::new(Skips::new(p));
        let q = sk.q() as i64;
        for kernel in [BuildKernel::Scalar, BuildKernel::Lanes] {
            let t = ScheduleTable::build_with_kernel(&sk, 1, kernel);
            for r in 0..p {
                for &v in t.recv_row(r).iter().chain(t.send_row(r)) {
                    let v = v as i64;
                    assert!(
                        -q <= v && v < q,
                        "p={p} r={r}: raw entry {v} outside [-{q}, {q}) ({kernel:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn cache_serves_table_rows_verbatim() {
    install_seed_reporter();
    // The cache's table and single-rank entry points serve the same rows
    // the serial cores produce (the get() path goes through the table
    // under the default cap).
    let cache = ScheduleCache::new();
    let mut rng = Rng::from_env();
    for _ in 0..8 {
        let p = gen_p(&mut rng);
        let sk = cache.skips(p);
        let table = cache.table(&sk);
        for r in 0..p {
            assert_eq!(*cache.get(p, r), table.schedule(r), "p={p} r={r}");
            assert_eq!(table.schedule(r), Schedule::compute(&sk, r), "p={p} r={r}");
        }
    }
}
