//! Exhaustive schedule verification — the Appendix-B style "finite,
//! exhaustive proof": for every p in a dense range, compute all p receive
//! and send schedules and machine-check the four correctness conditions
//! plus the doubling laws. Larger p are covered by sampled checks
//! (the paper verified up to ~2^20 and a band around 2^24).

use std::sync::Arc;

use circulant_bcast::collectives::common::BlockGeometry;
use circulant_bcast::schedule::doubling::{double_recv_schedules, double_send_schedules};
use circulant_bcast::schedule::{
    ceil_log2, recv_schedule, send_schedule, verify_all, verify_sampled, Skips,
};
use circulant_bcast::sim::{CirculantEngine, UnitCost};

#[test]
fn all_p_up_to_2048() {
    for p in 1..=2048 {
        let rep = verify_all(p);
        assert!(
            rep.ok(),
            "p={p}: {} failures, first: {:?}",
            rep.failures.len(),
            rep.failures.first()
        );
    }
}

#[test]
fn dense_band_around_4096() {
    for p in 4000..=4200 {
        assert!(verify_all(p).ok(), "p={p}");
    }
}

#[test]
fn powers_of_two_and_neighbours_to_2_20() {
    for e in 2..=20usize {
        let base = 1usize << e;
        for p in [base - 1, base, base + 1] {
            // Sampled for large p (full tables above 2^14 get slow in CI).
            if p <= 1 << 12 {
                assert!(verify_all(p).ok(), "p={p}");
            } else {
                let ranks: Vec<usize> = (0..256).map(|i| (i * 7919) % p).collect();
                let rep = verify_sampled(p, &ranks);
                assert!(rep.ok(), "p={p}: {:?}", rep.failures.first());
            }
        }
    }
}

#[test]
fn sampled_multimillion() {
    // The paper's largest verified range: p ≈ 2^21 and a band near 16M.
    for p in [(1usize << 21) + 1, (1 << 21) + 12345, (1 << 24) + 7] {
        let ranks: Vec<usize> = (0..128).map(|i| (i * 104_729) % p).collect();
        let rep = verify_sampled(p, &ranks);
        assert!(rep.ok(), "p={p}: {:?}", rep.failures.first());
        assert!(rep.max_violations <= 4);
    }
}

#[test]
fn sampled_band_up_to_2_20() {
    // Dense-ish sampled coverage of the 2^17..2^20 band the full-table
    // checker cannot reach in CI: 128 sampled ranks per p.
    for p in [
        (1usize << 17) + 1,
        (1 << 18) + 12345,
        (1 << 19) + 7,
        (1 << 20) - 1,
        1 << 20,
        (1 << 20) + 1,
    ] {
        let ranks: Vec<usize> = (0..128).map(|i| (i * 104_729 + 11) % p).collect();
        let rep = verify_sampled(p, &ranks);
        assert!(rep.ok(), "p={p}: {:?}", rep.failures.first());
        assert!(rep.max_violations <= 4, "p={p}");
    }
}

/// `verify_all`-style *full-network* validation at scales where the
/// lockstep simulator is infeasible: the sparse engine simulates every
/// rank of a complete broadcast and reduction, enforcing the machine
/// model (one-portedness, expectation cross-checks, completion) as it
/// goes. An `Ok` run certifies that the full p-rank schedule family
/// composes into a working collective — the simulation analogue of the
/// four schedule conditions.
#[test]
fn engine_full_network_simulation_large_p() {
    for p in [(1usize << 14) + 5, (1 << 16) - 1, (1 << 17) + 9] {
        let sk = Arc::new(Skips::new(p));
        let n = 8usize;
        let q = ceil_log2(p);
        // Parallel-built schedule plane → engine (the production path).
        let eng = CirculantEngine::from_skips(&sk, 3 % p, BlockGeometry::new(n * 4, n));
        let stats = eng.run_bcast(4, &UnitCost).expect("full-network bcast must complete");
        assert_eq!(stats.rounds, n - 1 + q, "p={p}");
        // Every non-root rank receives at least its n blocks and at most
        // one message per round.
        assert!(stats.messages >= (p - 1) * n, "p={p}");
        assert!(stats.messages <= (p - 1) * stats.rounds, "p={p}");
        assert!(stats.active_rounds <= stats.rounds, "p={p}");
    }
}

#[test]
fn engine_full_network_reduce_mid_p() {
    // The reversed-schedule path, full network at a scale the lockstep
    // driver handles only slowly: correctness of the root's reduction
    // certifies the reversed composition end to end.
    use circulant_bcast::collectives::SumOp;
    let p = (1usize << 12) + 3;
    let sk = Arc::new(Skips::new(p));
    let n = 4usize;
    let m = 8usize;
    let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64; m]).collect();
    let eng = CirculantEngine::from_skips(&sk, 17, BlockGeometry::new(m, n));
    let (stats, buf) = eng.run_reduce(&inputs, &SumOp, 8, &UnitCost).unwrap();
    let want = (p * (p - 1) / 2) as i64;
    assert_eq!(buf, vec![want; m]);
    assert_eq!(stats.rounds, n - 1 + ceil_log2(p));
}

#[test]
fn doubling_laws_dense() {
    // Observations 2 + 6: doubling any correct p-schedule gives the
    // directly computed 2p-schedule.
    for p in 2..=512 {
        let sk = Skips::new(p);
        let recvs: Vec<_> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
        let sends: Vec<_> = (0..p).map(|r| send_schedule(&sk, r)).collect();
        let sk2 = Skips::new(2 * p);
        let dr = double_recv_schedules(p, &recvs);
        let ds = double_send_schedules(p, &sends);
        for r in 0..2 * p {
            assert_eq!(dr[r].blocks, recv_schedule(&sk2, r).blocks, "recv p={p} r={r}");
            assert_eq!(ds[r].blocks, send_schedule(&sk2, r).blocks, "send p={p} r={r}");
        }
    }
}

#[test]
fn oldstyle_baselines_identical_schedules() {
    // The O(log² p)/O(log³ p) baselines must produce byte-identical
    // schedules (the paper's point: same schedules, faster computation).
    use circulant_bcast::schedule::baseline;
    for p in [3usize, 17, 100, 1000, 1023, 1024, 1025] {
        let sk = Skips::new(p);
        for r in (0..p).step_by(1 + p / 64) {
            assert_eq!(
                baseline::recv_schedule_oldstyle(&sk, r).blocks,
                recv_schedule(&sk, r).blocks,
                "recv p={p} r={r}"
            );
            assert_eq!(
                baseline::send_schedule_from_recv(&sk, r).blocks,
                send_schedule(&sk, r).blocks,
                "send p={p} r={r}"
            );
        }
    }
}
