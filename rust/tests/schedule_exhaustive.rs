//! Exhaustive schedule verification — the Appendix-B style "finite,
//! exhaustive proof": for every p in a dense range, compute all p receive
//! and send schedules and machine-check the four correctness conditions
//! plus the doubling laws. Larger p are covered by sampled checks
//! (the paper verified up to ~2^20 and a band around 2^24).

use circulant_bcast::schedule::doubling::{double_recv_schedules, double_send_schedules};
use circulant_bcast::schedule::{
    recv_schedule, send_schedule, verify_all, verify_sampled, Skips,
};

#[test]
fn all_p_up_to_2048() {
    for p in 1..=2048 {
        let rep = verify_all(p);
        assert!(
            rep.ok(),
            "p={p}: {} failures, first: {:?}",
            rep.failures.len(),
            rep.failures.first()
        );
    }
}

#[test]
fn dense_band_around_4096() {
    for p in 4000..=4200 {
        assert!(verify_all(p).ok(), "p={p}");
    }
}

#[test]
fn powers_of_two_and_neighbours_to_2_20() {
    for e in 2..=20usize {
        let base = 1usize << e;
        for p in [base - 1, base, base + 1] {
            // Sampled for large p (full tables above 2^14 get slow in CI).
            if p <= 1 << 12 {
                assert!(verify_all(p).ok(), "p={p}");
            } else {
                let ranks: Vec<usize> = (0..256).map(|i| (i * 7919) % p).collect();
                let rep = verify_sampled(p, &ranks);
                assert!(rep.ok(), "p={p}: {:?}", rep.failures.first());
            }
        }
    }
}

#[test]
fn sampled_multimillion() {
    // The paper's largest verified range: p ≈ 2^21 and a band near 16M.
    for p in [(1usize << 21) + 1, (1 << 21) + 12345, (1 << 24) + 7] {
        let ranks: Vec<usize> = (0..128).map(|i| (i * 104_729) % p).collect();
        let rep = verify_sampled(p, &ranks);
        assert!(rep.ok(), "p={p}: {:?}", rep.failures.first());
        assert!(rep.max_violations <= 4);
    }
}

#[test]
fn doubling_laws_dense() {
    // Observations 2 + 6: doubling any correct p-schedule gives the
    // directly computed 2p-schedule.
    for p in 2..=512 {
        let sk = Skips::new(p);
        let recvs: Vec<_> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
        let sends: Vec<_> = (0..p).map(|r| send_schedule(&sk, r)).collect();
        let sk2 = Skips::new(2 * p);
        let dr = double_recv_schedules(p, &recvs);
        let ds = double_send_schedules(p, &sends);
        for r in 0..2 * p {
            assert_eq!(dr[r].blocks, recv_schedule(&sk2, r).blocks, "recv p={p} r={r}");
            assert_eq!(ds[r].blocks, send_schedule(&sk2, r).blocks, "send p={p} r={r}");
        }
    }
}

#[test]
fn oldstyle_baselines_identical_schedules() {
    // The O(log² p)/O(log³ p) baselines must produce byte-identical
    // schedules (the paper's point: same schedules, faster computation).
    use circulant_bcast::schedule::baseline;
    for p in [3usize, 17, 100, 1000, 1023, 1024, 1025] {
        let sk = Skips::new(p);
        for r in (0..p).step_by(1 + p / 64) {
            assert_eq!(
                baseline::recv_schedule_oldstyle(&sk, r).blocks,
                recv_schedule(&sk, r).blocks,
                "recv p={p} r={r}"
            );
            assert_eq!(
                baseline::send_schedule_from_recv(&sk, r).blocks,
                send_schedule(&sk, r).blocks,
                "send p={p} r={r}"
            );
        }
    }
}
