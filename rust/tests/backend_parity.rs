//! Differential backend-parity suite: for a seeded random grid of
//! (p, n, root, kind, algo) — including non-powers-of-two p and p = 1 —
//! the lockstep `Network`, the threaded runtime and the sparse `Engine`
//! must produce identical `Outcome` payloads, `all_received` flags and
//! `RunStats` round/message/byte counts. The engine's word-packed
//! receive marks, staged deliveries and memcmp completion checks ride
//! under every reduction case here; a dedicated scale case crosses the
//! sharded parallel-delivery threshold as well.
//!
//! Deterministic by default; set `TESTKIT_SEED` to explore other grids
//! (CI runs a fixed seed matrix).

use std::sync::Arc;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    Algo, AllgathervReq, AllreduceReq, BackendKind, BcastReq, CommBuilder, Communicator,
    ReduceReq, ReduceScatterReq,
};
use circulant_bcast::sim::{RunStats, UnitCost};
use circulant_bcast::testkit::{install_seed_reporter, Rng};

const BACKENDS: [BackendKind; 3] =
    [BackendKind::Lockstep, BackendKind::Threaded, BackendKind::Engine];

fn comm(p: usize, backend: BackendKind) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).backend(backend).build()
}

fn assert_stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.active_rounds, b.active_rounds, "{ctx}: active_rounds");
    assert_eq!(a.messages, b.messages, "{ctx}: messages");
    assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
    assert_eq!(a.max_rank_bytes, b.max_rank_bytes, "{ctx}: max_rank_bytes");
    assert!((a.time - b.time).abs() < 1e-12, "{ctx}: time {} vs {}", a.time, b.time);
}

#[derive(Debug, Clone, Copy)]
struct Case {
    p: usize,
    root: usize,
    m: usize,
    n: usize,
    kind: usize,
    algo: Algo,
}

fn gen_case(rng: &mut Rng) -> Case {
    // Mix powers of two, their neighbours, primes and p = 1.
    let p = match rng.range(0, 5) {
        0 => 1,
        1 => 1 << rng.range(1, 5),
        2 => (1 << rng.range(1, 5)) + 1,
        3 => [3, 7, 13, 17, 19, 23, 29, 31][rng.range(0, 7)],
        _ => rng.range(2, 40),
    };
    Case {
        p,
        root: rng.range(0, p - 1),
        m: rng.range(0, 150),
        n: rng.range(1, 12),
        kind: rng.range(0, 4),
        algo: if rng.chance(1, 4) { Algo::Auto } else { Algo::Circulant },
    }
}

fn check_case(c: &Case) {
    let ctx = format!("{c:?}");
    match c.kind {
        // ----- bcast -----
        0 => {
            let data: Vec<i64> = (0..c.m as i64).map(|i| i * 7 - 11).collect();
            let run = |backend| {
                comm(c.p, backend)
                    .bcast(
                        BcastReq::new(c.root, &data)
                            .algo(c.algo)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in BACKENDS {
                let out = run(backend);
                assert_eq!(out.algo, base.algo, "{ctx} [{backend:?}]: algo");
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_eq!(
                    out.all_received(),
                    base.all_received(),
                    "{ctx} [{backend:?}]: all_received"
                );
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
        }
        // ----- reduce -----
        1 => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r * 41 + i * 13) % 509) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .reduce(
                        ReduceReq::new(c.root, &inputs, Arc::new(SumOp))
                            .algo(c.algo)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in BACKENDS {
                let out = run(backend);
                assert_eq!(out.algo, base.algo, "{ctx} [{backend:?}]: algo");
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_eq!(
                    out.all_received(),
                    base.all_received(),
                    "{ctx} [{backend:?}]: all_received"
                );
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
        }
        // ----- allgatherv (irregular counts derived from the case) -----
        2 => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..(c.m + r * 3) % 60).map(|i| (r * 1000 + i) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .allgatherv(
                        AllgathervReq::new(&inputs).algo(c.algo).blocks(c.n).elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in BACKENDS {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_eq!(
                    out.all_received(),
                    base.all_received(),
                    "{ctx} [{backend:?}]: all_received"
                );
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
        }
        // ----- reduce-scatter (irregular counts) -----
        3 => {
            let counts: Vec<usize> = (0..c.p).map(|r| (c.m + r * 5) % 23).collect();
            let total: usize = counts.iter().sum();
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..total).map(|i| ((r + 3) * (i + 1) % 401) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .reduce_scatter(
                        ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp))
                            .algo(c.algo)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in BACKENDS {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
        }
        // ----- allreduce -----
        _ => {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r + 1) * (i + 1) % 333) as i64).collect())
                .collect();
            let run = |backend| {
                comm(c.p, backend)
                    .allreduce(
                        AllreduceReq::new(&inputs, Arc::new(SumOp))
                            .algo(c.algo)
                            .blocks(c.n)
                            .elem_bytes(8),
                    )
                    .unwrap_or_else(|e| panic!("{ctx} [{backend:?}]: {e}"))
            };
            let base = run(BackendKind::Lockstep);
            for backend in BACKENDS {
                let out = run(backend);
                assert_eq!(out.buffers, base.buffers, "{ctx} [{backend:?}]: payload");
                assert_stats_eq(&out.stats, &base.stats, &format!("{ctx} [{backend:?}]"));
            }
        }
    }
}

#[test]
fn seeded_random_grid_all_backends_agree() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for _ in 0..40 {
        let c = gen_case(&mut rng);
        check_case(&c);
    }
}

#[test]
fn degenerate_and_boundary_cases_agree() {
    // The cases a random grid can miss: p = 1, a single block, m = 0,
    // m < n (empty blocks), non-zero roots at non-powers-of-two p.
    let fixed = [
        Case { p: 1, root: 0, m: 10, n: 3, kind: 0, algo: Algo::Circulant },
        Case { p: 1, root: 0, m: 10, n: 1, kind: 1, algo: Algo::Circulant },
        Case { p: 1, root: 0, m: 7, n: 2, kind: 4, algo: Algo::Circulant },
        Case { p: 2, root: 1, m: 33, n: 4, kind: 0, algo: Algo::Circulant },
        Case { p: 17, root: 16, m: 0, n: 5, kind: 0, algo: Algo::Circulant },
        Case { p: 17, root: 3, m: 3, n: 9, kind: 0, algo: Algo::Circulant },
        Case { p: 18, root: 9, m: 100, n: 5, kind: 1, algo: Algo::Circulant },
        Case { p: 23, root: 11, m: 64, n: 7, kind: 0, algo: Algo::Auto },
        Case { p: 31, root: 0, m: 50, n: 6, kind: 2, algo: Algo::Circulant },
        Case { p: 13, root: 0, m: 40, n: 3, kind: 3, algo: Algo::Circulant },
        Case { p: 9, root: 0, m: 61, n: 2, kind: 4, algo: Algo::Circulant },
    ];
    for c in fixed {
        check_case(&c);
    }
}

/// Restores `CBCAST_THREADS` on drop — including on assertion panic, so
/// a failure in the thread-count sweep cannot contaminate later tests.
struct ThreadEnvGuard(Option<String>);

impl ThreadEnvGuard {
    fn set() -> Self {
        ThreadEnvGuard(std::env::var("CBCAST_THREADS").ok())
    }
}

impl Drop for ThreadEnvGuard {
    fn drop(&mut self) {
        match self.0.take() {
            Some(v) => std::env::set_var("CBCAST_THREADS", v),
            None => std::env::remove_var("CBCAST_THREADS"),
        }
    }
}

#[test]
fn backends_agree_at_every_thread_count() {
    install_seed_reporter();
    // The schedule plane builds in parallel (CBCAST_THREADS) and the
    // engine shards large delivery rounds across the same thread count;
    // none of that may be observable: at thread counts 1, 2 and 8 every
    // backend must produce the same outcome as the single-threaded
    // lockstep baseline. The env var is process-global, so concurrently
    // running tests read whichever count is current — harmless for
    // correctness (every count must be right, and their seeded case
    // generation is unaffected), and the guard restores the previous
    // value even if an assertion here panics.
    let _guard = ThreadEnvGuard::set();
    let fixed = [
        Case { p: 17, root: 5, m: 120, n: 6, kind: 0, algo: Algo::Circulant },
        Case { p: 18, root: 9, m: 100, n: 5, kind: 1, algo: Algo::Circulant },
        Case { p: 23, root: 0, m: 64, n: 4, kind: 2, algo: Algo::Circulant },
        Case { p: 13, root: 0, m: 40, n: 3, kind: 3, algo: Algo::Circulant },
        Case { p: 9, root: 0, m: 61, n: 2, kind: 4, algo: Algo::Circulant },
        Case { p: 1, root: 0, m: 10, n: 3, kind: 0, algo: Algo::Circulant },
    ];
    for threads in ["1", "2", "8"] {
        std::env::set_var("CBCAST_THREADS", threads);
        for c in fixed {
            check_case(&c);
        }
    }
}

#[test]
fn packed_reduce_path_matches_lockstep_above_the_delivery_shard_threshold() {
    // The engine's reduction path stages blocks into a word-packed
    // scratch, queues 16-byte deliveries whose combine lengths are
    // re-derived from the block geometry at application time, and
    // checks completion with a packed-count memcmp. None of that may
    // be observable. p > 4096 pushes a mid-reduction round's delivery
    // queue past the engine's parallel-delivery threshold, so the
    // sharded application path runs too — payloads and every statistic
    // must still match the lockstep baseline exactly. (The small-p
    // grids above keep the serial delivery path honest; this is the
    // sharded one.)
    let p = 4099usize; // prime, non-power-of-two, above the shard cut
    let m = 32usize;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..m).map(|i| ((r * 19 + i * 7) % 1009) as i64).collect()).collect();
    let run = |backend| {
        comm(p, backend)
            .reduce(
                ReduceReq::new(7, &inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(8)
                    .elem_bytes(8),
            )
            .unwrap_or_else(|e| panic!("p={p} [{backend:?}]: {e}"))
    };
    let base = run(BackendKind::Lockstep);
    let out = run(BackendKind::Engine);
    assert_eq!(out.buffers, base.buffers, "packed reduce payload at p={p}");
    assert_eq!(out.all_received(), base.all_received());
    assert_stats_eq(&out.stats, &base.stats, &format!("packed reduce p={p}"));
}

#[test]
fn auto_resolution_is_backend_independent() {
    // Algo::Auto must resolve identically under every backend (the
    // small-payload binomial fallback included), so outcomes agree.
    let data_small: Vec<i32> = (0..16).collect();
    let data_large: Vec<i32> = (0..10_000).collect();
    for data in [&data_small, &data_large] {
        let base = comm(9, BackendKind::Lockstep)
            .bcast(BcastReq::new(2, data))
            .unwrap();
        for backend in BACKENDS {
            let out = comm(9, backend).bcast(BcastReq::new(2, data)).unwrap();
            assert_eq!(out.algo, base.algo, "{backend:?} data_len={}", data.len());
            assert_eq!(out.buffers, base.buffers);
            assert_stats_eq(&out.stats, &base.stats, &format!("{backend:?}"));
        }
    }
}
