//! Coordinator end-to-end: plan → execute → validate → metrics, across
//! kinds, algorithms, distributions and cost models — the paths the
//! `cbcast` CLI and the benches drive.

use circulant_bcast::coordinator::{
    parse_cost, plan, Algo, Dist, Engine, Kind, Request, TuningParams,
};
use circulant_bcast::schedule::ceil_log2;
use circulant_bcast::sim::UnitCost;

#[test]
fn full_matrix_small() {
    let eng = Engine::new();
    let kinds = [Kind::Bcast, Kind::Reduce, Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce];
    for kind in kinds {
        for p in [1usize, 2, 9, 17] {
            let mut req = Request::new(kind, p, 340);
            req.blocks = Some(3);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?} p={p}");
        }
    }
    assert_eq!(eng.metrics.total(), (kinds.len() * 4) as u64);
}

#[test]
fn auto_tuning_produces_sane_block_counts() {
    let tp = TuningParams::default();
    for p in [16usize, 200, 25600] {
        for m in [1usize << 10, 1 << 16, 1 << 22] {
            let req = Request::new(Kind::Bcast, p, m);
            let pl = plan(&req, &tp);
            assert!(pl.n >= 1 && pl.n <= m, "p={p} m={m}: n={}", pl.n);
            assert_eq!(pl.q, ceil_log2(p));
            assert_eq!(pl.predicted_rounds, pl.n - 1 + pl.q);
        }
    }
}

#[test]
fn predicted_rounds_match_simulated() {
    let eng = Engine::new();
    for (kind, algo) in [
        (Kind::Bcast, Algo::Circulant),
        (Kind::Bcast, Algo::Binomial),
        (Kind::Bcast, Algo::VanDeGeijn),
        (Kind::Allgatherv, Algo::Ring),
        (Kind::ReduceScatter, Algo::Ring),
    ] {
        let mut req = Request::new(kind, 17, 680);
        req.algo = algo;
        req.blocks = Some(5);
        let rep = eng.run(&req, &UnitCost).unwrap();
        assert_eq!(
            rep.stats.rounds, rep.plan.predicted_rounds,
            "{kind:?}/{algo:?}: sim {} vs plan {}",
            rep.stats.rounds, rep.plan.predicted_rounds
        );
    }
}

#[test]
fn distributions_all_valid() {
    let eng = Engine::new();
    for dist in [Dist::Regular, Dist::Irregular, Dist::Degenerate] {
        for kind in [Kind::Allgatherv, Kind::ReduceScatter] {
            let mut req = Request::new(kind, 12, 480);
            req.dist = dist;
            req.blocks = Some(4);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?} {dist:?}");
        }
    }
}

#[test]
fn hierarchical_cost_orders_algorithms_sanely() {
    // On the VEGA-like model with a large message, the circulant pipeline
    // must beat the binomial tree (Fig. 1's headline).
    let eng = Engine::new();
    let p = 200usize;
    let m = 1 << 20;
    let cost = parse_cost("vega:4").unwrap();

    let mut new = Request::new(Kind::Bcast, p, m);
    new.algo = Algo::Circulant;
    let t_new = eng.run(&new, cost.as_ref()).unwrap().sim_time;

    let mut nat = Request::new(Kind::Bcast, p, m);
    nat.algo = Algo::Binomial;
    let t_nat = eng.run(&nat, cost.as_ref()).unwrap().sim_time;

    assert!(
        t_new < t_nat,
        "circulant ({t_new:.6}s) should beat binomial ({t_nat:.6}s) at m={m}"
    );
}

#[test]
fn schedule_cache_reuse() {
    let eng = Engine::new();
    let cache = eng.cache.clone();
    // Warm.
    for r in 0..17 {
        cache.get(17, r);
    }
    let (h0, m0) = cache.stats();
    for r in 0..17 {
        cache.get(17, r);
    }
    let (h1, m1) = cache.stats();
    assert_eq!(m1, m0, "no new misses on re-request");
    assert_eq!(h1 - h0, 17);
}

#[test]
fn cost_parsing_round_trip() {
    for spec in ["unit", "linear", "linear:2e-6:1e-10", "vega:128", "cluster:32"] {
        let c = parse_cost(spec).unwrap_or_else(|| panic!("{spec} should parse"));
        assert!(c.msg_time(0, 1, 1024) > 0.0);
    }
}
