//! Differential traffic-mix fuzz suite: for seeded random multi-op
//! workloads (all five collective kinds, random roots/sizes/windows,
//! arbitrary arrival order), every op's batched `Outcome` must be
//! bit-identical — payloads, completion flags, resolved algorithm,
//! rounds, full statistics, and error kind/round on failures — to
//! running the same op alone on a fresh `Communicator` of its window
//! size, at every tested scheduler thread count. Every batched run's
//! port trace is additionally checked against the cross-op one-ported
//! oracle. Failing cases shrink to the smallest failing op subset (then
//! to one scheduler thread) before reporting.
//!
//! Deterministic by default; honours `TESTKIT_SEED` (CI runs a 3-seed
//! matrix), and every panic reports the effective seed.

use circulant_bcast::comm::{Algo, BackendKind, CommBuilder, Communicator, Kind};
use circulant_bcast::schedule::verify_one_ported_trace;
use circulant_bcast::sim::UnitCost;
use circulant_bcast::testkit::{
    forall_shrink, install_seed_reporter, run_mix_blocking, submit_mix_op, traffic_mix,
    MixOp, MixOptions, MixOutcome, Rng, TrafficMix,
};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn machine(p: usize, backend: BackendKind) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).backend(backend).build()
}

/// Execute `mix` as one batch at `threads` scheduler threads; verify
/// the recorded port trace; return per-op outcomes in submission order.
fn run_batched(
    mix: &TrafficMix,
    backend: BackendKind,
    threads: usize,
) -> Result<Vec<MixOutcome>, String> {
    let comm = machine(mix.p, backend);
    let mut traffic = comm.traffic().threads(threads).record_trace(true);
    let mut handles = Vec::with_capacity(mix.ops.len());
    for (i, op) in mix.ops.iter().enumerate() {
        handles.push(
            submit_mix_op(&mut traffic, op).map_err(|e| format!("op #{i} submit: {e}"))?,
        );
    }
    let report = traffic.run().map_err(|e| format!("batch run: {e}"))?;
    verify_one_ported_trace(mix.p, report.trace.as_ref().expect("trace recording on"))
        .map_err(|v| format!("one-ported trace violated: {v:?}"))?;
    Ok(handles.into_iter().map(|h| h.take()).collect())
}

/// The sequential side: each op alone, on a fresh communicator of its
/// window size.
fn run_sequential(mix: &TrafficMix, backend: BackendKind) -> Vec<MixOutcome> {
    mix.ops
        .iter()
        .map(|op| run_mix_blocking(&machine(op.ranks(mix.p), backend), op))
        .collect()
}

fn check_parity(mix: &TrafficMix, backend: BackendKind, threads: usize) -> Result<(), String> {
    let batched = run_batched(mix, backend, threads)?;
    let sequential = run_sequential(mix, backend);
    for (i, (b, s)) in batched.iter().zip(&sequential).enumerate() {
        if b != s {
            return Err(format!(
                "op #{i} {:?} diverged (backend {backend:?}, threads {threads}):\n  batched:    \
                 {b:?}\n  sequential: {s:?}",
                mix.ops[i]
            ));
        }
    }
    Ok(())
}

#[derive(Debug, Clone)]
struct Case {
    mix: TrafficMix,
    threads: usize,
}

/// Shrink to the smallest failing op subset first (halves, then single
/// drops), then to one scheduler thread.
fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    let ops = &c.mix.ops;
    if ops.len() > 1 {
        let half = ops.len() / 2;
        for sub in [&ops[..half], &ops[half..]] {
            out.push(Case {
                mix: TrafficMix { p: c.mix.p, ops: sub.to_vec() },
                threads: c.threads,
            });
        }
        for i in 0..ops.len() {
            let mut rest = ops.clone();
            rest.remove(i);
            out.push(Case { mix: TrafficMix { p: c.mix.p, ops: rest }, threads: c.threads });
        }
    }
    if c.threads != 1 {
        out.push(Case { mix: c.mix.clone(), threads: 1 });
    }
    out
}

/// The suite's p grid: 1, powers of two and neighbours, primes, and
/// ordinary sizes (the 2^14 end is covered by `large_p_bcast_reduce`,
/// where lockstep feasibility bounds the mix).
fn gen_p(rng: &mut Rng) -> usize {
    match rng.range(0, 4) {
        0 => 1,
        1 => 1 << rng.range(1, 5),
        2 => {
            let b = 1usize << rng.range(1, 5);
            if rng.chance(1, 2) {
                b + 1
            } else {
                b - 1
            }
        }
        3 => [3, 5, 7, 13, 17, 19, 23, 29, 31, 37, 41][rng.range(0, 10)],
        _ => rng.range(2, 48),
    }
}

#[test]
fn batched_matches_sequential_fuzz() {
    install_seed_reporter();
    let mut t = 0usize;
    forall_shrink(
        24,
        |rng| {
            let p = gen_p(rng);
            let n_ops = rng.range(1, 8);
            t += 1;
            Case {
                mix: traffic_mix(rng, p, n_ops, &MixOptions::default()),
                threads: THREAD_COUNTS[t % THREAD_COUNTS.len()],
            }
        },
        |c| check_parity(&c.mix, BackendKind::Lockstep, c.threads),
        shrink_case,
    );
}

#[test]
fn engine_backend_batched_matches_sequential() {
    install_seed_reporter();
    let mut t = 0usize;
    forall_shrink(
        10,
        |rng| {
            let p = gen_p(rng);
            let n_ops = rng.range(1, 5);
            t += 1;
            Case {
                mix: traffic_mix(rng, p, n_ops, &MixOptions::default()),
                threads: THREAD_COUNTS[t % THREAD_COUNTS.len()],
            }
        },
        |c| check_parity(&c.mix, BackendKind::Engine, c.threads),
        shrink_case,
    );
}

#[test]
fn thirty_two_ops_agree_at_every_thread_count() {
    // The issue's upper bound: 32 concurrent ops on one machine. Beyond
    // sequential parity, the three thread counts must agree with each
    // other exactly (scheduling is deterministic; threading only shards
    // the per-round work).
    install_seed_reporter();
    let mut rng = Rng::from_env();
    let mix = traffic_mix(&mut rng, 33, 32, &MixOptions::default());
    let sequential = run_sequential(&mix, BackendKind::Lockstep);
    let mut per_thread = Vec::new();
    for threads in THREAD_COUNTS {
        let batched = run_batched(&mix, BackendKind::Lockstep, threads).unwrap();
        for (i, (b, s)) in batched.iter().zip(&sequential).enumerate() {
            assert_eq!(b, s, "op #{i} {:?} at threads={threads}", mix.ops[i]);
        }
        per_thread.push(batched);
    }
    assert_eq!(per_thread[0], per_thread[1]);
    assert_eq!(per_thread[0], per_thread[2]);
}

#[test]
fn large_p_bcast_reduce_parity() {
    // The 2^14 end of the grid. Only the O(p·rounds) kinds are feasible
    // on the lockstep sequential side at this scale; windows put one op
    // on a prime-sized sub-machine.
    install_seed_reporter();
    let mut rng = Rng::from_env();
    let p = (1 << 14) + 1;
    let ops = vec![
        MixOp {
            kind: Kind::Bcast,
            window: None,
            root: rng.range(0, p - 1),
            m: 24,
            blocks: Some(4),
            algo: Algo::Circulant,
            data_seed: rng.next_u64(),
        },
        MixOp {
            kind: Kind::Bcast,
            window: Some((3, 8191)),
            root: 17,
            m: 16,
            blocks: Some(3),
            algo: Algo::Circulant,
            data_seed: rng.next_u64(),
        },
        MixOp {
            kind: Kind::Reduce,
            window: Some((8200, 4096)),
            root: 5,
            m: 8,
            blocks: Some(2),
            algo: Algo::Circulant,
            data_seed: rng.next_u64(),
        },
    ];
    let mix = TrafficMix { p, ops };
    for threads in [1usize, 8] {
        check_parity(&mix, BackendKind::Lockstep, threads).unwrap();
    }
}

#[test]
fn disjoint_window_mix_takes_max_not_sum() {
    // Five ops — one of each kind — over five disjoint windows: nobody
    // ever stalls, so the batch's machine rounds equal the longest op's
    // local rounds (strictly below the sequential sum).
    install_seed_reporter();
    let mut rng = Rng::from_env();
    let p = 40usize;
    let kinds = [
        Kind::Bcast,
        Kind::Reduce,
        Kind::Allgatherv,
        Kind::ReduceScatter,
        Kind::Allreduce,
    ];
    let ops: Vec<MixOp> = kinds
        .iter()
        .enumerate()
        .map(|(w, &kind)| MixOp {
            kind,
            window: Some((8 * w, 8)),
            root: rng.range(0, 7),
            m: 20,
            blocks: Some(1 + w),
            algo: Algo::Circulant,
            data_seed: rng.next_u64(),
        })
        .collect();
    let mix = TrafficMix { p, ops };

    let comm = machine(p, BackendKind::Lockstep);
    let mut traffic = comm.traffic().threads(4).record_trace(true);
    let handles: Vec<_> = mix
        .ops
        .iter()
        .map(|op| submit_mix_op(&mut traffic, op).unwrap())
        .collect();
    let report = traffic.run().unwrap();
    verify_one_ported_trace(p, report.trace.as_ref().unwrap()).unwrap();

    let sequential = run_sequential(&mix, BackendKind::Lockstep);
    let mut max_rounds = 0usize;
    let mut sum_rounds = 0usize;
    for ((h, s), op) in handles.into_iter().zip(&sequential).zip(&mix.ops) {
        let b = h.take();
        assert_eq!(&b, s, "{op:?}");
        let MixOutcome::Done { rounds, .. } = s else {
            panic!("sequential op failed: {s:?}");
        };
        max_rounds = max_rounds.max(*rounds);
        sum_rounds += *rounds;
    }
    assert_eq!(
        report.machine_rounds(),
        max_rounds,
        "disjoint windows never stall: batch rounds = max over ops"
    );
    assert!(
        report.machine_rounds() < sum_rounds,
        "aggregate machine rounds must beat the sequential sum"
    );
    // Every op was scheduled from machine round 0.
    for op in &report.ops {
        assert!(op.ok);
        assert_eq!(op.machine_span.map(|(first, _)| first), Some(0));
    }
}

#[test]
fn shuffled_submission_preserves_per_op_outcomes() {
    // Arrival-order permutation invariance at the suite level: the same
    // ops submitted in reversed and rotated order produce the same
    // per-op outcome multiset (each op keeps its own result; only the
    // machine spans may move). The property suite fuzzes this further.
    install_seed_reporter();
    let mut rng = Rng::from_env();
    let mix = traffic_mix(&mut rng, 19, 6, &MixOptions::default());
    let base = run_batched(&mix, BackendKind::Lockstep, 2).unwrap();
    for rotation in [1usize, 3] {
        let mut ops = mix.ops.clone();
        ops.rotate_left(rotation);
        let rotated = TrafficMix { p: mix.p, ops };
        let outcomes = run_batched(&rotated, BackendKind::Lockstep, 2).unwrap();
        for (i, out) in outcomes.iter().enumerate() {
            let orig = (i + rotation) % mix.ops.len();
            assert_eq!(out, &base[orig], "op {orig} changed under rotation {rotation}");
        }
    }
}
