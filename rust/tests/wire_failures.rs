//! Wire-level failure injection for the socket transport
//! (`rust/src/comm/socket.rs`): impostor endpoints speak raw bytes at a
//! real rendezvous and the tests pin the *typed* failure every fault
//! maps to — handshake mismatches are `io::Error`s at the constructor
//! or `TransportError::Shutdown` after assembly, truncation poisons the
//! world, a corrupted or replayed `DATA` frame is healed by the v3
//! reliability layer (discard + retransmit, dedup window) without
//! poisoning anything, and a peer that dies mid-schedule surfaces in
//! the lockstep vocabulary as `SimError::MissingMessage`. The v3 frame
//! layout — CRC32 trailer, sequence/ACK header — is re-derived here by
//! hand, byte for byte, so these tests double as an independent check
//! of the wire format documented in the module docs.

use std::io::{Read, Write};
use std::net::Shutdown;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use circulant_bcast::comm::{fresh_world_id, SocketTransport, Transport, TransportError};
use circulant_bcast::sim::SimError;

// -- The wire format, reconstructed independently of the crate ---------

const MAGIC: u32 = 0x4342_5731; // "CBW1"
const VERSION: u16 = 3;
const FT_HELLO: u8 = 1;
const FT_DATA: u8 = 2;
const ELEM_BYTES_I64: u32 = 8;

/// CRC32 (IEEE, reflected 0xEDB8_8320) over `[type][body]` — the same
/// polynomial the crate uses, implemented independently.
fn crc32(kind: u8, body: &[u8]) -> u32 {
    let mut c: u32 = !0;
    for &b in std::iter::once(&kind).chain(body.iter()) {
        c ^= b as u32;
        for _ in 0..8 {
            let mask = (c & 1).wrapping_neg();
            c = (c >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !c
}

/// v3 frame: `[len: u32][type: u8][body][crc: u32]`, len counting
/// type + body + crc.
fn seal(kind: u8, body: &[u8]) -> Vec<u8> {
    let crc = crc32(kind, body);
    let mut out = Vec::with_capacity(body.len() + 9);
    out.extend_from_slice(&((body.len() + 5) as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(body);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// v3 HELLO: 34-byte body `(magic, version, p, rank, world_id,
/// elem_bytes, epoch)` — 43 bytes on the wire once sealed.
fn hello(version: u16, p: u32, rank: u32, world_id: u64) -> Vec<u8> {
    let mut body = Vec::with_capacity(34);
    body.extend_from_slice(&MAGIC.to_le_bytes());
    body.extend_from_slice(&version.to_le_bytes());
    body.extend_from_slice(&p.to_le_bytes());
    body.extend_from_slice(&rank.to_le_bytes());
    body.extend_from_slice(&world_id.to_le_bytes());
    body.extend_from_slice(&ELEM_BYTES_I64.to_le_bytes());
    body.extend_from_slice(&0u64.to_le_bytes()); // epoch
    seal(FT_HELLO, &body)
}

/// v3 DATA: `(seq, ack, round, src, dst, count, payload)`.
fn data(seq: u64, ack: u64, round: u32, src: u32, dst: u32, payload: &[i64]) -> Vec<u8> {
    let mut body = Vec::with_capacity(32 + payload.len() * 8);
    body.extend_from_slice(&seq.to_le_bytes());
    body.extend_from_slice(&ack.to_le_bytes());
    body.extend_from_slice(&round.to_le_bytes());
    body.extend_from_slice(&src.to_le_bytes());
    body.extend_from_slice(&dst.to_le_bytes());
    body.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for v in payload {
        body.extend_from_slice(&v.to_le_bytes());
    }
    seal(FT_DATA, &body)
}

// -- Harness ----------------------------------------------------------

fn temp_world_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cbwire-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create rendezvous dir");
    dir
}

/// Dial `dir/rank-0.sock`, retrying until the rank under test binds it.
fn dial_rank0(dir: &Path) -> UnixStream {
    let path = dir.join("rank-0.sock");
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match UnixStream::connect(&path) {
            Ok(s) => return s,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(2))
            }
            Err(e) => panic!("rank 0 never bound {path:?}: {e}"),
        }
    }
}

const TIMEOUT: Duration = Duration::from_secs(5);

// -- Handshake faults -------------------------------------------------

/// A dialer claiming a different world id must be refused at rendezvous
/// time: the acceptor's constructor fails with a typed handshake error
/// instead of assembling a world that silently mixes two jobs' traffic.
#[test]
fn acceptor_rejects_hello_from_the_wrong_world() {
    let dir = temp_world_dir("wrong-world");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION, 2, 1, wid ^ 1)).unwrap();

    let err = rank0.join().unwrap().expect_err("wrong world id must not assemble");
    let msg = err.to_string();
    assert!(msg.contains("handshake") && msg.contains("world id"), "got: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same refusal for a protocol-version mismatch — the version field is
/// load-bearing, not decorative.
#[test]
fn acceptor_rejects_hello_with_wrong_protocol_version() {
    let dir = temp_world_dir("wrong-version");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION + 1, 2, 1, wid)).unwrap();

    let err = rank0.join().unwrap().expect_err("wrong version must not assemble");
    let msg = err.to_string();
    assert!(msg.contains("handshake") && msg.contains("version"), "got: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The dial side validates the acceptor's answering `HELLO`
/// asynchronously: a mismatched world id poisons the dialer's world,
/// so its next verb fails `Shutdown` with the handshake diagnosis
/// instead of deadlocking against traffic from the wrong job.
#[test]
fn dialer_poisons_on_answering_hello_from_the_wrong_world() {
    let dir = temp_world_dir("bad-answer");
    let wid = fresh_world_id();
    let listener = UnixListener::bind(dir.join("rank-0.sock")).unwrap();
    let rank1 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(1, 2, wid, &dir, TIMEOUT))
    };
    let (mut conn, _) = listener.accept().unwrap();
    // Swallow rank 1's HELLO (4 len + 1 type + 34 body + 4 crc bytes),
    // then answer as rank 0 of a *different* world.
    let mut buf = [0u8; 43];
    conn.read_exact(&mut buf).unwrap();
    conn.write_all(&hello(VERSION, 2, 0, wid ^ 1)).unwrap();

    let mut t = rank1.join().unwrap().expect("dial side assembles before validating");
    match t.recv(0, 0) {
        Err(TransportError::Shutdown { reason, .. }) => {
            assert!(reason.contains("handshake") && reason.contains("world id"), "got: {reason}")
        }
        other => panic!("expected Shutdown with handshake diagnosis, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A reordered rendezvous — the link's first frame is `DATA`, with the
/// `HELLO` notionally still in flight behind it — is refused at the
/// constructor: the handshake cannot be inferred from data traffic, so
/// the acceptor demands `HELLO` first, by type.
#[test]
fn reordered_rendezvous_with_data_before_hello_is_refused() {
    let dir = temp_world_dir("data-first");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&data(1, 0, 0, 1, 0, &[1, 2])).unwrap();

    let err = rank0.join().unwrap().expect_err("DATA before HELLO must not assemble");
    let msg = err.to_string();
    assert!(msg.contains("expected HELLO"), "got: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A *second* `HELLO` on an established link is a protocol violation —
/// a duplicated rendezvous frame cannot be healed by retransmission
/// semantics (HELLO carries no sequence number), so the world poisons
/// with the duplicate-HELLO diagnosis.
#[test]
fn duplicate_hello_on_an_established_link_poisons_the_world() {
    let dir = temp_world_dir("dup-hello");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION, 2, 1, wid)).unwrap();
    let mut t = rank0.join().unwrap().expect("valid HELLO assembles");
    // The wire replays the HELLO after the link is up.
    impostor.write_all(&hello(VERSION, 2, 1, wid)).unwrap();

    match t.recv(0, 1) {
        Err(TransportError::Shutdown { reason, .. }) => {
            assert!(reason.contains("duplicate HELLO"), "got: {reason}")
        }
        other => panic!("expected Shutdown on duplicate HELLO, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// -- Frame faults -----------------------------------------------------

/// A frame whose stream ends mid-body is a *truncation*, not a clean
/// close: the reader poisons the world with the diagnosis and the
/// blocked receive fails `Shutdown` instead of timing out.
#[test]
fn truncated_frame_poisons_the_receiver() {
    let dir = temp_world_dir("truncated");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION, 2, 1, wid)).unwrap();
    // A DATA frame claiming 41 bytes of type + body + crc, delivering 8.
    let mut torn = Vec::new();
    torn.extend_from_slice(&41u32.to_le_bytes());
    torn.push(FT_DATA);
    torn.extend_from_slice(&[0u8; 7]);
    impostor.write_all(&torn).unwrap();
    impostor.shutdown(Shutdown::Write).unwrap();

    let mut t = rank0.join().unwrap().expect("valid HELLO assembles");
    match t.recv(0, 1) {
        Err(TransportError::Shutdown { reason, .. }) => {
            assert!(reason.contains("truncated"), "got: {reason}")
        }
        other => panic!("expected Shutdown on truncation, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupted frame is a *transient* fault, not a protocol violation:
/// the CRC check discards it silently and the (simulated) sender's
/// retransmission of the same sequence number delivers. The world stays
/// healthy — no poison, no crash-marking — and the endpoint counts the
/// checksum failure.
#[test]
fn corrupted_frame_is_discarded_and_the_resend_delivers() {
    let dir = temp_world_dir("crc-resend");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION, 2, 1, wid)).unwrap();
    let mut t = rank0.join().unwrap().expect("valid HELLO assembles");

    // Seal a valid frame, then flip one payload bit — the length
    // prefix still describes the frame, so only the CRC catches it.
    let mut corrupted = data(1, 0, 0, 1, 0, &[70, 71, 72]);
    let last = corrupted.len() - 6; // inside the payload, before the crc
    corrupted[last] ^= 0x10;
    impostor.write_all(&corrupted).unwrap();
    // ... and the retransmission, byte-identical to the original.
    impostor.write_all(&data(1, 0, 0, 1, 0, &[70, 71, 72])).unwrap();

    assert_eq!(t.recv(0, 1).expect("resend heals the corruption"), vec![70, 71, 72]);
    assert!(t.failed_peers().is_empty(), "a corrupted frame must not crash-mark the peer");
    let faults = t.wire_faults().expect("socket transport surfaces wire faults");
    assert!(faults.crc_fails >= 1, "checksum failure must be counted: {faults}");
    drop(impostor);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The dedup window: a `DATA` frame replayed N times (duplicated by
/// the wire, or a retransmission whose original already won) delivers
/// exactly once. The mailbox never sees the copies — so the schedule's
/// one-message-per-round invariant (`ReceivePortBusy`) keeps meaning a
/// genuinely broken schedule — and each drop is counted.
#[test]
fn replayed_data_frames_deduplicate_to_one_delivery() {
    let dir = temp_world_dir("dedup");
    let wid = fresh_world_id();
    let rank0 = {
        let dir = dir.clone();
        std::thread::spawn(move || SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT))
    };
    let mut impostor = dial_rank0(&dir);
    impostor.write_all(&hello(VERSION, 2, 1, wid)).unwrap();
    let mut t = rank0.join().unwrap().expect("valid HELLO assembles");

    let frame = data(1, 0, 0, 1, 0, &[42, 43]);
    for _ in 0..5 {
        impostor.write_all(&frame).unwrap();
    }
    // A genuinely fresh frame behind the replay storm still delivers.
    impostor.write_all(&data(2, 0, 1, 1, 0, &[44, 45])).unwrap();

    assert_eq!(t.recv(0, 1).expect("first copy delivers"), vec![42, 43]);
    assert_eq!(t.recv(1, 1).expect("fresh frame delivers after the storm"), vec![44, 45]);
    assert!(t.failed_peers().is_empty(), "duplicates must not crash-mark the peer");
    let faults = t.wire_faults().expect("socket transport surfaces wire faults");
    assert!(faults.dup_drops >= 4, "four replayed copies must be dropped: {faults}");
    drop(impostor);
    let _ = std::fs::remove_dir_all(&dir);
}

// -- Dead peers -------------------------------------------------------

/// A peer that completes round 0 and then crashes (dropped endpoint, no
/// BYE, no ABORT) must surface in the lockstep vocabulary: the round-0
/// message still delivers, the round-1 receive fails
/// `MissingMessage` — not a raw I/O error, not a full receive-deadline
/// stall.
#[test]
fn peer_death_mid_schedule_is_missing_message() {
    let dir = temp_world_dir("dead-peer");
    let wid = fresh_world_id();
    let rank1 = {
        let dir = dir.clone();
        std::thread::spawn(move || {
            let mut t = SocketTransport::<i64>::uds_world(1, 2, wid, &dir, TIMEOUT)
                .expect("rank 1 assembles");
            t.send(0, 0, vec![7, 11]).expect("round-0 send");
            // Dropped without close(): a crashed rank.
        })
    };
    let mut t =
        SocketTransport::<i64>::uds_world(0, 2, wid, &dir, TIMEOUT).expect("rank 0 assembles");
    assert_eq!(t.recv(0, 1).expect("round 0 delivers before the crash"), vec![7, 11]);
    rank1.join().unwrap();
    match t.recv(1, 1) {
        Err(TransportError::Machine(SimError::MissingMessage {
            round: 1,
            expected_from: 1,
            ..
        })) => {}
        other => panic!("expected MissingMessage from the dead rank, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
