//! Service-daemon failure injection and concurrency acceptance
//! (`rust/src/service/`): several tenants hammer one daemon at once and
//! every reply must stay bit-identical to a solo run of the same op
//! spec; a slow-loris connection must be cut without disturbing healthy
//! tenants; a malformed op must fail alone while its co-batched
//! neighbours complete — the traffic plane's per-op isolation contract,
//! observed through the wire.

use std::io::Write;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use circulant_bcast::comm::{global_wire_faults, CommBuilder, FaultPlan, Kind, WireFaults};
use circulant_bcast::service::{
    serve_tcp, serve_unix, summarize, ServiceClient, ServiceConfig, ServiceReply,
};
use circulant_bcast::testkit::{
    install_seed_reporter, run_mix_blocking, traffic_mix, MixOp, MixOptions, Rng,
};

fn temp_sock(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cbcastd-it-{tag}-{}.sock", std::process::id()));
    p
}

/// Call with reject-and-retry, then assert the terminal reply is
/// bit-identical to a solo run of the same spec on a fresh machine.
fn call_and_verify(client: &mut ServiceClient, id: u64, op: &MixOp, p: usize) -> bool {
    let reply = client.call_admitted(id, op).expect("wire call");
    let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
    match (reply, summarize(&solo)) {
        (ServiceReply::Ok(got), Ok(want)) => {
            assert_eq!(got, want, "op {id} ({op:?}) diverged from its solo run");
            true
        }
        (ServiceReply::Err(got), Err(want)) => {
            assert_eq!(got, want, "op {id} ({op:?}) failed differently from its solo run");
            false
        }
        (got, want) => panic!("op {id}: daemon said {got:?}, solo said {want:?}"),
    }
}

/// The acceptance workload: four tenants, each pumping 16 mixed ops
/// concurrently into one daemon (64 ops total, batched under the shared
/// port ledger), every reply checked against a solo run.
#[test]
fn concurrent_tenants_all_match_their_solo_runs() {
    install_seed_reporter();
    let p = 16usize;
    let (clients, per_client) = (4usize, 16usize);
    let path = temp_sock("acceptance");
    let cfg = ServiceConfig {
        p,
        gather: Duration::from_millis(5),
        client_timeout: Duration::from_millis(2000),
        ..ServiceConfig::default()
    };
    let handle = serve_unix(&path, cfg).unwrap();

    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let path = path.clone();
            std::thread::spawn(move || {
                let tenant = format!("tenant-{c}");
                let mut client =
                    ServiceClient::connect_unix_retry(&path, &tenant, Duration::from_secs(5))
                        .expect("connect");
                let mut rng = Rng::new(0xACCE97 + c as u64);
                let mix = traffic_mix(&mut rng, p, per_client, &MixOptions::default());
                let mut ok = 0usize;
                for (i, op) in mix.ops.iter().enumerate() {
                    ok += usize::from(call_and_verify(&mut client, i as u64, op, p));
                }
                client.bye().expect("bye");
                ok
            })
        })
        .collect();
    let total_ok: usize = workers.into_iter().map(|w| w.join().expect("client thread")).sum();

    handle.shutdown();
    let metrics = handle.join();
    let total = clients * per_client;
    assert_eq!(metrics.admitted, total, "every op admitted (retries re-admit)");
    assert_eq!(metrics.completed + metrics.failed, total);
    assert_eq!(metrics.completed, total_ok);
    assert_eq!(metrics.tenants.len(), clients, "one usage row per tenant: {:?}", metrics.tenants);
    for c in 0..clients {
        let label = format!("tenant-{c}");
        let row = metrics.tenants.iter().find(|t| t.tenant == label).unwrap();
        assert_eq!(row.ops, per_client, "tenant {label} billed per op: {row:?}");
    }
}

/// A slow-loris connection — valid hello, then a frame that starts and
/// never finishes — is dropped at the mid-frame deadline, while a
/// healthy tenant on the same daemon keeps completing verified work.
#[test]
fn slow_loris_is_dropped_while_healthy_work_completes() {
    let p = 8usize;
    let path = temp_sock("loris");
    let cfg = ServiceConfig {
        p,
        client_timeout: Duration::from_millis(200),
        ..ServiceConfig::default()
    };
    let handle = serve_unix(&path, cfg).unwrap();
    let mut healthy =
        ServiceClient::connect_unix_retry(&path, "healthy", Duration::from_secs(5)).unwrap();

    // Hand-rolled service CHELLO (magic "CBW1", version 1, tenant), then
    // one byte of a next frame's length prefix — and silence.
    let mut loris = std::os::unix::net::UnixStream::connect(&path).unwrap();
    let tenant = b"loris";
    let mut chello = Vec::new();
    chello.extend_from_slice(&(1u32 + 4 + 2 + 4 + tenant.len() as u32).to_le_bytes());
    chello.push(0x10);
    chello.extend_from_slice(&0x4342_5731u32.to_le_bytes());
    chello.extend_from_slice(&1u16.to_le_bytes());
    chello.extend_from_slice(&(tenant.len() as u32).to_le_bytes());
    chello.extend_from_slice(tenant);
    loris.write_all(&chello).unwrap();
    loris.write_all(&[3u8]).unwrap(); // a frame begins… and stalls

    // The healthy tenant's work is unaffected while the loris stalls.
    let mix = traffic_mix(&mut Rng::new(5), p, 4, &MixOptions::default());
    for (i, op) in mix.ops.iter().enumerate() {
        call_and_verify(&mut healthy, i as u64, op, p);
    }

    // The daemon cuts the loris at the mid-frame deadline.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if handle.metrics().dropped >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "slow-loris connection was never dropped");
        std::thread::sleep(Duration::from_millis(10));
    }
    healthy.bye().unwrap();
    handle.shutdown();
    let metrics = handle.join();
    assert_eq!(metrics.completed + metrics.failed, 4);
    assert_eq!(metrics.dropped, 1);
    assert!(metrics.tenants.iter().all(|t| t.tenant != "loris"), "a dropped loris bills nothing");
}

/// Per-op isolation through the wire: a malformed spec co-batched with
/// healthy ops fails alone — with the same error a solo run produces —
/// while its neighbours complete bit-identically.
#[test]
fn malformed_op_fails_alone_in_a_shared_batch() {
    let p = 12usize;
    let path = temp_sock("isolation");
    let cfg = ServiceConfig {
        p,
        gather: Duration::from_millis(100),
        client_timeout: Duration::from_millis(2000),
        ..ServiceConfig::default()
    };
    let handle = serve_unix(&path, cfg).unwrap();
    let mut client =
        ServiceClient::connect_unix_retry(&path, "mixed", Duration::from_secs(5)).unwrap();

    let mut mix = traffic_mix(&mut Rng::new(11), p, 5, &MixOptions::default());
    // A broadcast whose root lies outside its own rank window: rejected
    // with the same `BadRequest` a solo run of the spec produces. (The
    // kind is pinned — unrooted collectives ignore `root`.)
    mix.ops[2].kind = Kind::Bcast;
    mix.ops[2].window = Some((0, 4));
    mix.ops[2].root = 7;

    // Pipeline all five inside one gather window, then collect.
    for (i, op) in mix.ops.iter().enumerate() {
        client.submit(i as u64, op).unwrap();
    }
    let mut verdicts = vec![None; mix.ops.len()];
    while verdicts.iter().any(|v| v.is_none()) {
        let (id, reply) = client.recv_reply().unwrap();
        let op = &mix.ops[id as usize];
        let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
        match (reply, summarize(&solo)) {
            (ServiceReply::Ok(got), Ok(want)) => {
                assert_eq!(got, want, "op #{id} diverged");
                verdicts[id as usize] = Some(true);
            }
            (ServiceReply::Err(got), Err(want)) => {
                assert_eq!(got, want, "op #{id} failed differently");
                verdicts[id as usize] = Some(false);
            }
            (ServiceReply::Rejected { .. }, _) => {
                client.submit(id, op).unwrap();
            }
            (got, want) => panic!("op #{id}: daemon said {got:?}, solo said {want:?}"),
        }
    }
    assert_eq!(verdicts[2], Some(false), "the malformed op must fail");
    let healthy_ok =
        verdicts.iter().enumerate().filter(|(i, _)| *i != 2).all(|(_, v)| *v == Some(true));
    assert!(healthy_ok, "co-batched healthy ops must all complete: {verdicts:?}");
    client.bye().unwrap();
    handle.shutdown();
    handle.join();
}

/// Two daemons in one process report **independent** wire-fault
/// counters: the chaos'd daemon's startup self-probe moves its own
/// `ServiceMetrics::wire` row, while a plain daemon serving real work
/// at the same time reports all zeros — even though the process-global
/// debug aggregate (`global_wire_faults`) has absorbed the chaos
/// daemon's healing by then. This is the regression test for the
/// cross-contamination bug where every daemon's stats line re-read the
/// process-global counters and so reported its neighbours' faults as
/// its own.
#[test]
fn two_daemons_report_independent_wire_counters() {
    let p = 4usize;
    let chaos_path = temp_sock("wire-chaos");
    let plain_path = temp_sock("wire-plain");
    let chaos_cfg = ServiceConfig {
        p,
        client_timeout: Duration::from_millis(2000),
        chaos: Some(FaultPlan::new(0x1D013).drop_per_10k(1_500).corrupt_per_10k(1_500, 3)),
        ..ServiceConfig::default()
    };
    let chaos_handle = serve_unix(&chaos_path, chaos_cfg).unwrap();
    let plain_cfg =
        ServiceConfig { p, client_timeout: Duration::from_millis(2000), ..ServiceConfig::default() };
    let plain_handle = serve_unix(&plain_path, plain_cfg).unwrap();

    // Both daemons serve verified work side by side.
    for (path, tenant) in [(&chaos_path, "chaotic"), (&plain_path, "calm")] {
        let mut client =
            ServiceClient::connect_unix_retry(path, tenant, Duration::from_secs(5)).unwrap();
        let mix = traffic_mix(&mut Rng::new(31), p, 2, &MixOptions::default());
        for (i, op) in mix.ops.iter().enumerate() {
            call_and_verify(&mut client, i as u64, op, p);
        }
        client.bye().unwrap();
    }

    chaos_handle.shutdown();
    plain_handle.shutdown();
    let chaos_metrics = chaos_handle.join();
    let plain_metrics = plain_handle.join();

    // The chaos daemon's self-probe healed injected faults — in *its*
    // counters. The heavy plan makes a zero-fault probe implausible.
    assert!(
        chaos_metrics.wire.any(),
        "the chaos daemon's probe must land in its own wire row: {}",
        chaos_metrics.wire
    );
    // The plain daemon saw none of it, even though the process-global
    // aggregate in this very process has absorbed the probe's healing.
    assert_eq!(
        plain_metrics.wire,
        WireFaults::default(),
        "a fault-free daemon must report zeros, not its neighbour's faults"
    );
    assert!(
        global_wire_faults().any(),
        "the process-global debug aggregate still absorbs every world"
    );
}

/// The same service speaks TCP: an ephemeral-port daemon serves a
/// verified op over `127.0.0.1`.
#[test]
fn tcp_daemon_round_trips() {
    let p = 8usize;
    let cfg =
        ServiceConfig { p, client_timeout: Duration::from_millis(2000), ..Default::default() };
    let handle = serve_tcp("127.0.0.1:0", cfg).unwrap();
    let addr = handle.addr().expect("tcp daemon reports its address");
    let mut client = ServiceClient::connect_tcp(&addr.to_string(), "tcp-tenant").unwrap();
    assert_eq!(client.p(), p);
    let mix = traffic_mix(&mut Rng::new(21), p, 3, &MixOptions::default());
    for (i, op) in mix.ops.iter().enumerate() {
        call_and_verify(&mut client, i as u64, op, p);
    }
    client.bye().unwrap();
    handle.shutdown();
    handle.join();
}
