//! Property-based tests on the schedule algorithms (using the in-house
//! `testkit` harness — the offline substitute for proptest; see DESIGN.md
//! §Substitutions). Random p up to multi-million, random ranks.

use circulant_bcast::schedule::{
    all_baseblocks, baseblock, canonical_sequence, recv_schedule, send_schedule, Skips,
};
use circulant_bcast::testkit::{forall, forall_shrink, Rng};

fn random_p(rng: &mut Rng) -> usize {
    // Mix dense small p with exponentially distributed large p.
    match rng.range(0, 3) {
        0 => rng.range(2, 300),
        1 => rng.range(300, 10_000),
        2 => 1usize << rng.range(10, 22),
        _ => (1usize << rng.range(10, 22)) + rng.range(1, 1000),
    }
}

#[test]
fn prop_condition3_random_p_and_rank() {
    forall(
        400,
        |rng| {
            let p = random_p(rng);
            let r = rng.range(0, p - 1);
            (p, r)
        },
        |&(p, r)| {
            let sk = Skips::new(p);
            let q = sk.q() as i64;
            let s = recv_schedule(&sk, r);
            let mut got = s.blocks.clone();
            got.sort_unstable();
            let mut want: Vec<i64> = (-q..0).collect();
            if r != 0 {
                let b = s.baseblock as i64;
                want.retain(|&v| v != b - q);
                want.push(b);
                want.sort_unstable();
            }
            if got == want {
                Ok(())
            } else {
                Err(format!("condition 3 violated: got {got:?} want {want:?}"))
            }
        },
    );
}

#[test]
fn prop_conditions12_random_edges() {
    forall(
        300,
        |rng| {
            let p = random_p(rng);
            let r = rng.range(0, p - 1);
            (p, r)
        },
        |&(p, r)| {
            let sk = Skips::new(p);
            let send = send_schedule(&sk, r);
            for k in 0..sk.q() {
                let t = sk.to_proc(r, k);
                let tr = recv_schedule(&sk, t);
                if send.blocks[k] != tr.blocks[k] {
                    return Err(format!(
                        "cond 2: k={k} send={} but recv_t={}",
                        send.blocks[k], tr.blocks[k]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_condition4_random() {
    forall(
        300,
        |rng| {
            let p = random_p(rng);
            let r = rng.range(1, p - 1).max(1);
            (p, r)
        },
        |&(p, r)| {
            let sk = Skips::new(p);
            let q = sk.q() as i64;
            let recv = recv_schedule(&sk, r);
            let send = send_schedule(&sk, r);
            let b = send.baseblock as i64;
            for k in 0..sk.q() {
                let v = send.blocks[k];
                let ok = v == b - q || (0..k).any(|j| recv.blocks[j] == v);
                if !ok {
                    return Err(format!("cond 4: k={k} block={v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_canonical_sequence_decomposes_r() {
    forall(
        500,
        |rng| {
            let p = random_p(rng);
            let r = rng.range(0, p - 1);
            (p, r)
        },
        |&(p, r)| {
            let sk = Skips::new(p);
            let seq = canonical_sequence(&sk, r);
            let sum: usize = seq.iter().map(|&e| sk.skip(e)).sum();
            if sum != r {
                return Err(format!("sums to {sum}, want {r}"));
            }
            if seq.windows(2).any(|w| w[0] >= w[1]) {
                return Err("indices not strictly increasing".into());
            }
            if r > 0 && seq[0] != baseblock(&sk, r) {
                return Err("first index is not the baseblock".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_baseblocks_matches_pointwise_with_shrink() {
    forall_shrink(
        200,
        |rng| random_p(rng).min(1 << 18),
        |&p| {
            let sk = Skips::new(p);
            let fast = all_baseblocks(&sk);
            for r in (0..p).step_by(1 + p / 512) {
                if fast[r] != baseblock(&sk, r) {
                    return Err(format!("mismatch at r={r}: {} vs {}", fast[r], baseblock(&sk, r)));
                }
            }
            Ok(())
        },
        |&p| if p > 2 { vec![p / 2, p - 1] } else { vec![] },
    );
}

#[test]
fn prop_instrumentation_bounds_random() {
    forall(
        400,
        |rng| {
            let p = random_p(rng);
            let r = rng.range(0, p - 1);
            (p, r)
        },
        |&(p, r)| {
            let sk = Skips::new(p);
            let s = recv_schedule(&sk, r);
            let v = send_schedule(&sk, r).violations;
            if s.stats.recursions > sk.q().saturating_sub(1) {
                return Err(format!("recursions {} > q-1", s.stats.recursions));
            }
            if s.stats.scans > 3 * sk.q() + s.stats.recursions {
                return Err(format!("scans {} > 3q+R", s.stats.scans));
            }
            if v > 4 {
                return Err(format!("{v} violations > 4"));
            }
            Ok(())
        },
    );
}
