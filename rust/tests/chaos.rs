//! The differential chaos grid: the receipt for the PR's core claim
//! that **transient wire faults do not change results and do not
//! shrink the world**.
//!
//! Seeded `(p, n, kind, fault plan)` cases run the same collective
//! twice — once over `TransportKind::Socket` (fault-free) and once
//! over `TransportKind::ChaosSocket` with per-10k drop / duplicate /
//! reorder / delay / corrupt rates up to 10% injected under the socket
//! layer — and every payload and every verb-level statistic must be
//! bit-identical. Retransmissions, CRC discards and dedup drops are
//! invisible at the verb layer by design; they surface only in the
//! [`WireFaults`] counters — asserted here on the **per-world**
//! `ElasticReport::wire` scope, never on the process-global debug
//! aggregate, so the whole file is safe under parallel test execution
//! (concurrent socket tests in other files move the globals freely).
//!
//! The elastic driver rides the same worlds: a chaos'd
//! `elastic_bcast` must complete at **epoch 0** with zero shrinks,
//! while a blackholed rank — the one fault no retransmission heals —
//! must exhaust the retry budget, escalate into the membership shrink
//! path, and leave survivor payloads bit-identical to a fresh run at
//! the smaller size.
//!
//! Deterministic by default; honors `TESTKIT_SEED` (the CI
//! `chaos-smoke` job runs the `#[ignore]`d p = 16 case across the
//! fixed three-seed matrix).

use std::sync::Arc;
use std::time::Duration;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::rank::{spmd_allreduce, spmd_bcast, spmd_reduce};
use circulant_bcast::comm::{elastic_bcast, elastic_reduce, CrashPlan, FaultPlan, TransportKind};
use circulant_bcast::schedule::Skips;
use circulant_bcast::sim::{RunStats, UnitCost};
use circulant_bcast::testkit::{effective_seed, install_seed_reporter, Rng};

const TIMEOUT: Duration = Duration::from_secs(10);

fn assert_stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.active_rounds, b.active_rounds, "{ctx}: active_rounds");
    assert_eq!(a.messages, b.messages, "{ctx}: messages");
    assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
    assert_eq!(a.max_rank_bytes, b.max_rank_bytes, "{ctx}: max_rank_bytes");
    assert!((a.time - b.time).abs() < 1e-12, "{ctx}: time {} vs {}", a.time, b.time);
}

/// A random plan with every fault kind active and rates up to ~10%
/// drop — heavy enough to exercise the reliability layer on every
/// case, far below the retry budget's exhaustion point.
fn gen_plan(rng: &mut Rng) -> FaultPlan {
    FaultPlan::new(rng.next_u64())
        .drop_per_10k(rng.range(100, 1000) as u32)
        .dup_per_10k(rng.range(0, 400) as u32)
        .reorder_per_10k(rng.range(0, 400) as u32)
        .delay_per_10k(rng.range(0, 200) as u32, 3)
        .corrupt_per_10k(rng.range(100, 1000) as u32, rng.range(1, 4) as u32)
}

/// One differential case: fault-free socket world vs the same world
/// with `plan` injected under it. Payloads and stats bit-identical.
fn check_case(p: usize, m: usize, n: usize, coll: usize, plan: FaultPlan, ctx: &str) {
    let sk = Arc::new(Skips::new(p));
    let clean = TransportKind::Socket;
    let chaos = TransportKind::ChaosSocket(plan);
    match coll {
        0 => {
            let data: Vec<i64> = (0..m as i64).map(|i| i * 7 - 11).collect();
            let root = p - 1;
            let (cs, cb) = spmd_bcast(&sk, root, &data, n, 8, &UnitCost, clean, None)
                .unwrap_or_else(|e| panic!("{ctx} [clean bcast]: {e}"));
            let (xs, xb) = spmd_bcast(&sk, root, &data, n, 8, &UnitCost, chaos, None)
                .unwrap_or_else(|e| panic!("{ctx} [chaos bcast]: {e}"));
            assert_eq!(xb, cb, "{ctx}: bcast payload");
            assert_stats_eq(&xs, &cs, &format!("{ctx}: bcast"));
        }
        1 => {
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| ((r * 41 + i * 13) % 509) as i64).collect())
                .collect();
            let (cs, cb) =
                spmd_reduce(&sk, 0, &inputs, n, Arc::new(SumOp), 8, &UnitCost, clean, None)
                    .unwrap_or_else(|e| panic!("{ctx} [clean reduce]: {e}"));
            let (xs, xb) =
                spmd_reduce(&sk, 0, &inputs, n, Arc::new(SumOp), 8, &UnitCost, chaos, None)
                    .unwrap_or_else(|e| panic!("{ctx} [chaos reduce]: {e}"));
            assert_eq!(xb, cb, "{ctx}: reduce payload");
            assert_stats_eq(&xs, &cs, &format!("{ctx}: reduce"));
        }
        _ => {
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| ((r + 1) * (i + 1) % 333) as i64).collect())
                .collect();
            let (crs, cag, cb) =
                spmd_allreduce(&sk, &inputs, n, Arc::new(SumOp), 8, &UnitCost, clean, None)
                    .unwrap_or_else(|e| panic!("{ctx} [clean allreduce]: {e}"));
            let (xrs, xag, xb) =
                spmd_allreduce(&sk, &inputs, n, Arc::new(SumOp), 8, &UnitCost, chaos, None)
                    .unwrap_or_else(|e| panic!("{ctx} [chaos allreduce]: {e}"));
            assert_eq!(xb, cb, "{ctx}: allreduce payload");
            assert_stats_eq(&xrs, &crs, &format!("{ctx}: allreduce rs phase"));
            assert_stats_eq(&xag, &cag, &format!("{ctx}: allreduce ag phase"));
        }
    }
}

/// The seeded differential grid: transient faults up to 10% leave
/// every collective bit-identical to the fault-free run.
#[test]
fn chaos_grid_matches_fault_free_runs() {
    install_seed_reporter();
    let mut rng = Rng::from_env();
    for i in 0..6 {
        let p = [2, 3, 4, 5, 7, 8, 9, 11][rng.range(0, 7)];
        let m = rng.range(1, 96);
        let n = rng.range(1, 6);
        let coll = rng.range(0, 2);
        let plan = gen_plan(&mut rng);
        check_case(p, m, n, coll, plan, &format!("case {i}: p={p} m={m} n={n} coll={coll}"));
    }
}

/// A fixed heavy case that provably exercises the reliability layer:
/// with ~16% of frames dropped or corrupted over a broadcast's many
/// hundreds of frames, **this world's own** fault counters must move —
/// the healing is real, not a plan that never fired. (The verb-level
/// parity stays covered by the differential grid above; this test reads
/// the per-run `ElasticReport::wire` scope, so counters tripped by
/// concurrent socket tests elsewhere in the suite cannot mask a plan
/// that silently never fired — the old process-global delta could.)
#[test]
fn heavy_chaos_moves_the_wire_fault_counters() {
    let p = 8;
    let data: Vec<i64> = (0..256).map(|i| i * 7 - 11).collect();
    let plan = FaultPlan::new(0xD1CE).drop_per_10k(800).corrupt_per_10k(800, 3);
    check_case(p, 256, 4, 2, plan, "heavy: p=8 m=256 n=4 allreduce");

    // The same plan under the elastic driver, whose report carries the
    // run-scoped counters: zero shrink budget proves the heavy faults
    // all healed in place, and the wire row proves they happened.
    let report = elastic_bcast(
        p,
        0,
        &data,
        4,
        TransportKind::ChaosSocket(plan),
        &CrashPlan::none(),
        0,
        TIMEOUT,
    )
    .expect("heavy chaos must heal without a shrink budget");
    assert!(report.changes.is_empty(), "no epochs may be consumed: {:?}", report.changes);
    assert!(
        report.wire.retransmits > 0 || report.wire.crc_fails > 0,
        "a 16% fault rate over hundreds of frames must trip this world's \
         counters (wire {})",
        report.wire
    );
}

/// The elastic driver over a chaos world: transient faults heal under
/// the collective, so recovery never triggers — zero shrinks, epoch 0,
/// payloads bit-identical to the fault-free elastic run.
#[test]
fn elastic_world_under_chaos_consumes_no_epochs() {
    let p = 6;
    let data: Vec<i64> = (0..96).map(|i| i * 5 - 17).collect();
    let plan = FaultPlan::new(0xC0FFEE).drop_per_10k(500).dup_per_10k(250).corrupt_per_10k(500, 2);
    let chaos = elastic_bcast(
        p,
        2,
        &data,
        4,
        TransportKind::ChaosSocket(plan),
        &CrashPlan::none(),
        2,
        TIMEOUT,
    )
    .expect("chaos'd elastic bcast heals without shrinking");
    assert!(chaos.changes.is_empty(), "no epochs may be consumed: {:?}", chaos.changes);
    assert_eq!(chaos.membership.epoch(), 0);
    assert_eq!(chaos.membership.p(), p);

    let clean = elastic_bcast(
        p,
        2,
        &data,
        4,
        TransportKind::Socket,
        &CrashPlan::none(),
        0,
        TIMEOUT,
    )
    .expect("fault-free elastic bcast");
    assert_eq!(chaos.root, clean.root);
    assert_eq!(chaos.buffers, clean.buffers, "chaos world must match the fault-free world");
}

/// The escalation path: a blackholed rank is the one fault no
/// retransmission heals. The retry budget exhausts, the membership
/// plane shrinks by exactly that rank (one epoch), and the restarted
/// reduction on the survivor world is bit-identical to a fresh
/// (p − 1)-rank run over the survivors' inputs. The victim is the
/// highest rank so the rebuilt dense world leaves the blackhole with
/// nothing to swallow.
#[test]
fn a_blackholed_rank_escalates_into_the_shrink_path() {
    let p = 4;
    let victim = 3;
    let n = 64;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| Rng::new(0xB1A0 + r as u64).vec_i64(n, -999, 999)).collect();
    let report = elastic_reduce(
        p,
        1,
        &inputs,
        4,
        Arc::new(SumOp),
        TransportKind::ChaosSocket(FaultPlan::new(7).blackhole(victim)),
        &CrashPlan::none(),
        2,
        TIMEOUT,
    )
    .expect("blackhole must shrink, then complete");

    assert_eq!(report.changes.len(), 1, "exactly one shrink: {:?}", report.changes);
    assert_eq!(report.changes[0].failed, vec![victim]);
    assert_eq!(report.membership.epoch(), 1);
    assert_eq!(report.membership.p(), p - 1);
    assert_eq!(report.root, 1, "the root survived and keeps serving");

    let fresh = elastic_reduce(
        p - 1,
        1,
        &inputs[..p - 1],
        4,
        Arc::new(SumOp),
        TransportKind::Socket,
        &CrashPlan::none(),
        0,
        TIMEOUT,
    )
    .expect("fresh survivor-world reduce");
    assert_eq!(
        report.buffers, fresh.buffers,
        "survivor payloads must be bit-identical to a fresh (p − 1) run"
    );

    assert!(
        report.wire.escalations > 0,
        "budget exhaustion must be counted as an escalation in this \
         world's own counters (wire {})",
        report.wire
    );
}

/// Release smoke (CI `chaos-smoke` job): p = 16 over real socketpairs
/// with 5% drop + 5% corrupt, seeded from the CI matrix via
/// `TESTKIT_SEED`. Socket parity AND zero membership epochs consumed.
/// `#[ignore]`d in the default run — a 16·15-end mesh built four times
/// over is deliberate load (the CI job raises `ulimit -n` first).
#[test]
#[ignore]
fn chaos_smoke_p16() {
    install_seed_reporter();
    let plan = FaultPlan::new(effective_seed()).drop_per_10k(500).corrupt_per_10k(500, 3);
    check_case(16, 512, 6, 0, plan, "smoke: p=16 bcast");
    check_case(16, 256, 4, 2, plan, "smoke: p=16 allreduce");

    let data: Vec<i64> = (0..256).map(|i| (i * 37) % 1013).collect();
    let report = elastic_bcast(
        16,
        5,
        &data,
        4,
        TransportKind::ChaosSocket(plan),
        &CrashPlan::none(),
        2,
        TIMEOUT,
    )
    .expect("chaos smoke: elastic bcast heals");
    assert!(report.changes.is_empty(), "zero epochs consumed: {:?}", report.changes);
    assert_eq!(report.membership.epoch(), 0);
    for (g, buf) in &report.buffers {
        assert_eq!(buf, &data, "rank {g} payload");
    }
    assert!(
        report.wire.retransmits > 0 || report.wire.crc_fails > 0,
        "5% + 5% fault rates must exercise the reliability layer \
         (wire {})",
        report.wire
    );
}
