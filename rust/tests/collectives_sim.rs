//! Cross-collective integration over the lockstep simulator, driven
//! through the typed `Communicator` API: round optimality, volume
//! accounting, consistency between the collectives (bcast∘reduce,
//! allgather vs p× bcast, allreduce vs reduce+bcast), and machine-model
//! enforcement on the full grid of paper-relevant sizes.

use std::sync::Arc;

use circulant_bcast::collectives::{tuning, SumOp};
use circulant_bcast::comm::{
    Algo, AllgathervReq, AllreduceReq, BcastReq, CommBuilder, Communicator, ReduceReq,
    ReduceScatterReq,
};
use circulant_bcast::schedule::ceil_log2;
use circulant_bcast::sim::{LinearCost, UnitCost};

fn comm(p: usize) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).build()
}

#[test]
fn bcast_round_optimality_grid() {
    // n - 1 + ceil(log2 p) rounds, for every p and n in the grid.
    for p in [2usize, 3, 5, 9, 17, 33, 64, 100, 129] {
        let c = comm(p);
        let q = ceil_log2(p);
        for n in [1usize, 2, q.max(1), 2 * q.max(1) + 1, 17] {
            let data: Vec<i32> = (0..(n * 3) as i32).collect();
            let out = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n)).unwrap();
            assert_eq!(out.rounds, n - 1 + q, "p={p} n={n}");
            assert!(out.all_received());
            assert!(out.buffers.iter().all(|b| b == &data));
        }
    }
}

#[test]
fn bcast_volume_is_p_minus_1_blocks_per_block() {
    // Every non-root receives each of the n blocks exactly once: total
    // messages = (p-1) * n (plus nothing else — no metadata, no dups).
    for p in [5usize, 9, 17, 33] {
        let c = comm(p);
        for n in [1usize, 4, 9] {
            let m = n * 8;
            let data: Vec<i32> = (0..m as i32).collect();
            let out = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n)).unwrap();
            assert_eq!(out.stats.messages, (p - 1) * n, "p={p} n={n}");
        }
    }
}

#[test]
fn reduce_equals_transposed_bcast_volume() {
    // Reduction is the exact reverse of broadcast: same message count.
    for p in [5usize, 9, 17] {
        let c = comm(p);
        let n = 6usize;
        let m = 60usize;
        let data: Vec<i64> = (0..m as i64).collect();
        let b = c
            .bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n).elem_bytes(8))
            .unwrap();
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| data.clone()).collect();
        let r = c
            .reduce(
                ReduceReq::new(0, &inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(n)
                    .elem_bytes(8),
            )
            .unwrap();
        assert_eq!(b.stats.messages, r.stats.messages, "p={p}");
        assert_eq!(b.stats.rounds, r.stats.rounds);
        assert_eq!(b.stats.bytes, r.stats.bytes);
    }
}

#[test]
fn allgather_agrees_with_p_broadcasts() {
    // All-broadcast must deliver exactly what p separate broadcasts would.
    let p = 9usize;
    let c = comm(p);
    let mlocal = 12usize;
    let inputs: Vec<Vec<i32>> = (0..p)
        .map(|r| (0..mlocal).map(|i| (r * 100 + i) as i32).collect())
        .collect();
    let ag = c.allgather(AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(3)).unwrap();
    for root in 0..p {
        let b = c
            .bcast(BcastReq::new(root, &inputs[root]).algo(Algo::Circulant).blocks(3))
            .unwrap();
        for r in 0..p {
            assert_eq!(ag.buffers[r][root], b.buffers[r], "root={root} rank={r}");
        }
    }
    // And in the same n-1+q rounds as ONE broadcast (the paper's point).
    let q = ceil_log2(p);
    assert_eq!(ag.rounds, 3 - 1 + q);
}

#[test]
fn allreduce_agrees_with_reduce_then_bcast() {
    let p = 17usize;
    let c = comm(p);
    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..170).map(|i| ((r * 13 + i * 7) % 101) as i64).collect())
        .collect();
    let ar = c
        .allreduce(
            AllreduceReq::new(&inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(4)
                .elem_bytes(8),
        )
        .unwrap();
    let red = c
        .reduce(
            ReduceReq::new(0, &inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(4)
                .elem_bytes(8),
        )
        .unwrap();
    let bc = c
        .bcast(BcastReq::new(0, &red.buffers).algo(Algo::Circulant).blocks(4).elem_bytes(8))
        .unwrap();
    for r in 0..p {
        assert_eq!(ar.buffers[r], bc.buffers[r], "rank {r}");
    }
}

#[test]
fn reduce_scatter_then_allgather_is_allreduce() {
    // The library's own composition is checked in `allreduce`; here we
    // compose manually with *different* block counts per phase.
    let p = 8usize;
    let c = comm(p);
    let chunk = 9usize;
    let m = p * chunk;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..m).map(|i| (r + i) as i64).collect()).collect();
    let sums: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let counts = vec![chunk; p];
    let rs = c
        .reduce_scatter(
            ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(2)
                .elem_bytes(8),
        )
        .unwrap();
    let ag = c
        .allgatherv(AllgathervReq::new(&rs.buffers).algo(Algo::Circulant).blocks(5).elem_bytes(8))
        .unwrap();
    for r in 0..p {
        let got: Vec<i64> = ag.buffers[r].iter().flatten().copied().collect();
        assert_eq!(got, sums, "rank {r}");
    }
}

#[test]
fn circulant_beats_binomial_for_large_messages() {
    // The pipelining payoff — the core Fig. 1 claim, under the linear
    // model: for large m the circulant pipeline beats the binomial tree
    // by close to q/2 and the crossover sits at small m.
    let p = 64usize;
    let cost = LinearCost::hpc_default();
    let c = CommBuilder::new(p).cost_model(cost.clone()).build();
    let m = 1 << 18;
    let data: Vec<i32> = (0..m as i32).collect();
    let n = tuning::bcast_blocks_model(m, p, 4, cost.alpha, cost.beta);
    let circ = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(n)).unwrap();
    let bino = c.bcast(BcastReq::new(0, &data).algo(Algo::Binomial)).unwrap();
    assert!(
        circ.time() * 2.0 < bino.time(),
        "pipelined {:.6}s should be >2x faster than binomial {:.6}s",
        circ.time(),
        bino.time()
    );
    // Small message: binomial (= circulant with n=1) is the right call.
    let small: Vec<i32> = (0..64).collect();
    let c1 = c.bcast(BcastReq::new(0, &small).algo(Algo::Circulant).blocks(1)).unwrap();
    let b1 = c.bcast(BcastReq::new(0, &small).algo(Algo::Binomial)).unwrap();
    assert_eq!(c1.rounds, b1.rounds);
}

#[test]
fn degenerate_allgatherv_round_bound() {
    // Fig. 2's degenerate case: circulant still takes n-1+q rounds and
    // every rank receives the owner's full buffer.
    let p = 33usize;
    let c = comm(p);
    let q = ceil_log2(p);
    let mut inputs: Vec<Vec<i32>> = vec![Vec::new(); p];
    inputs[7] = (0..500).collect();
    let n = 5usize;
    let out = c.allgatherv(AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(n)).unwrap();
    assert_eq!(out.rounds, n - 1 + q);
    for r in 0..p {
        assert_eq!(out.buffers[r][7], inputs[7], "rank {r}");
    }
}

#[test]
fn elem_bytes_scale_volume_not_rounds() {
    let p = 9usize;
    let c = comm(p);
    let data: Vec<i64> = (0..90).collect();
    let a = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(5).elem_bytes(1)).unwrap();
    let b = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(5).elem_bytes(8)).unwrap();
    assert_eq!(a.stats.rounds, b.stats.rounds);
    assert_eq!(a.stats.messages, b.stats.messages);
    assert_eq!(a.stats.bytes * 8, b.stats.bytes);
}
