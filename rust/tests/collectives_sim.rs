//! Cross-collective integration over the lockstep simulator: round
//! optimality, volume accounting, consistency between the collectives
//! (bcast∘reduce, allgather vs p× bcast, allreduce vs reduce+bcast), and
//! machine-model enforcement on the full grid of paper-relevant sizes.

use std::sync::Arc;

use circulant_bcast::collectives::baselines::binomial_bcast_sim;
use circulant_bcast::collectives::{
    allgather_sim, allgatherv_sim, allreduce_sim, bcast_sim, reduce_scatter_sim, reduce_sim,
    SumOp,
};
use circulant_bcast::schedule::ceil_log2;
use circulant_bcast::sim::{LinearCost, UnitCost};

#[test]
fn bcast_round_optimality_grid() {
    // n - 1 + ceil(log2 p) rounds, for every p and n in the grid.
    for p in [2usize, 3, 5, 9, 17, 33, 64, 100, 129] {
        let q = ceil_log2(p);
        for n in [1usize, 2, q.max(1), 2 * q.max(1) + 1, 17] {
            let data: Vec<i32> = (0..(n * 3) as i32).collect();
            let res = bcast_sim(p, 0, &data, n, 4, &UnitCost).unwrap();
            assert_eq!(res.stats.rounds, n - 1 + q, "p={p} n={n}");
            assert!(res.buffers.iter().all(|b| b == &data));
        }
    }
}

#[test]
fn bcast_volume_is_p_minus_1_blocks_per_block() {
    // Every non-root receives each of the n blocks exactly once: total
    // messages = (p-1) * n (plus nothing else — no metadata, no dups).
    for p in [5usize, 9, 17, 33] {
        for n in [1usize, 4, 9] {
            let m = n * 8;
            let data: Vec<i32> = (0..m as i32).collect();
            let res = bcast_sim(p, 0, &data, n, 4, &UnitCost).unwrap();
            assert_eq!(res.stats.messages, (p - 1) * n, "p={p} n={n}");
        }
    }
}

#[test]
fn reduce_equals_transposed_bcast_volume() {
    // Reduction is the exact reverse of broadcast: same message count.
    for p in [5usize, 9, 17] {
        let n = 6usize;
        let m = 60usize;
        let data: Vec<i64> = (0..m as i64).collect();
        let b = bcast_sim(p, 0, &data, n, 8, &UnitCost).unwrap();
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| data.clone()).collect();
        let r = reduce_sim(&inputs, 0, n, Arc::new(SumOp), 8, &UnitCost).unwrap();
        assert_eq!(b.stats.messages, r.stats.messages, "p={p}");
        assert_eq!(b.stats.rounds, r.stats.rounds);
        assert_eq!(b.stats.bytes, r.stats.bytes);
    }
}

#[test]
fn allgather_agrees_with_p_broadcasts() {
    // All-broadcast must deliver exactly what p separate broadcasts would.
    let p = 9usize;
    let mlocal = 12usize;
    let inputs: Vec<Vec<i32>> = (0..p)
        .map(|r| (0..mlocal).map(|i| (r * 100 + i) as i32).collect())
        .collect();
    let ag = allgather_sim(&inputs, 3, 4, &UnitCost).unwrap();
    for root in 0..p {
        let b = bcast_sim(p, root, &inputs[root], 3, 4, &UnitCost).unwrap();
        for r in 0..p {
            assert_eq!(ag.buffers[r][root], b.buffers[r], "root={root} rank={r}");
        }
    }
    // And in the same n-1+q rounds as ONE broadcast (the paper's point).
    let q = ceil_log2(p);
    assert_eq!(ag.stats.rounds, 3 - 1 + q);
}

#[test]
fn allreduce_agrees_with_reduce_then_bcast() {
    let p = 17usize;
    let m = 170usize;
    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..m).map(|i| ((r * 13 + i * 7) % 101) as i64).collect())
        .collect();
    let ar = allreduce_sim(&inputs, 4, Arc::new(SumOp), 8, &UnitCost).unwrap();
    let red = reduce_sim(&inputs, 0, 4, Arc::new(SumOp), 8, &UnitCost).unwrap();
    let bc = bcast_sim(p, 0, &red.buffer, 4, 8, &UnitCost).unwrap();
    for r in 0..p {
        assert_eq!(ar.buffers[r], bc.buffers[r], "rank {r}");
    }
}

#[test]
fn reduce_scatter_then_allgather_is_allreduce() {
    // The library's own composition is checked in allreduce_sim; here we
    // compose manually with *different* block counts per phase.
    let p = 8usize;
    let chunk = 9usize;
    let m = p * chunk;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..m).map(|i| (r + i) as i64).collect()).collect();
    let sums: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let counts = vec![chunk; p];
    let rs = reduce_scatter_sim(&inputs, &counts, 2, Arc::new(SumOp), 8, &UnitCost).unwrap();
    let ag = allgatherv_sim(&rs.chunks, 5, 8, &UnitCost).unwrap();
    for r in 0..p {
        let got: Vec<i64> = ag.buffers[r].iter().flatten().copied().collect();
        assert_eq!(got, sums, "rank {r}");
    }
}

#[test]
fn circulant_beats_binomial_for_large_messages() {
    // The pipelining payoff — the core Fig. 1 claim, under the linear
    // model: for large m the circulant pipeline beats the binomial tree
    // by close to q/2 and the crossover sits at small m.
    let p = 64usize;
    let cost = LinearCost::hpc_default();
    let m = 1 << 18;
    let data: Vec<i32> = (0..m as i32).collect();
    let n = circulant_bcast::collectives::tuning::bcast_blocks_model(m, p, 4, cost.alpha, cost.beta);
    let circ = bcast_sim(p, 0, &data, n, 4, &cost).unwrap();
    let (bino, _) = binomial_bcast_sim(p, 0, &data, 4, &cost).unwrap();
    assert!(
        circ.stats.time * 2.0 < bino.time,
        "pipelined {:.6}s should be >2x faster than binomial {:.6}s",
        circ.stats.time,
        bino.time
    );
    // Small message: binomial (= circulant with n=1) is the right call.
    let small: Vec<i32> = (0..64).collect();
    let c1 = bcast_sim(p, 0, &small, 1, 4, &cost).unwrap();
    let (b1, _) = binomial_bcast_sim(p, 0, &small, 4, &cost).unwrap();
    assert_eq!(c1.stats.rounds, b1.rounds);
}

#[test]
fn degenerate_allgatherv_round_bound() {
    // Fig. 2's degenerate case: circulant still takes n-1+q rounds and
    // every rank receives the owner's full buffer.
    let p = 33usize;
    let q = ceil_log2(p);
    let mut inputs: Vec<Vec<i32>> = vec![Vec::new(); p];
    inputs[7] = (0..500).collect();
    let n = 5usize;
    let res = allgatherv_sim(&inputs, n, 4, &UnitCost).unwrap();
    assert_eq!(res.stats.rounds, n - 1 + q);
    for r in 0..p {
        assert_eq!(res.buffers[r][7], inputs[7], "rank {r}");
    }
}

#[test]
fn elem_bytes_scale_volume_not_rounds() {
    let p = 9usize;
    let data: Vec<i64> = (0..90).collect();
    let a = bcast_sim(p, 0, &data, 5, 1, &UnitCost).unwrap();
    let b = bcast_sim(p, 0, &data, 5, 8, &UnitCost).unwrap();
    assert_eq!(a.stats.rounds, b.stats.rounds);
    assert_eq!(a.stats.messages, b.stats.messages);
    assert_eq!(a.stats.bytes * 8, b.stats.bytes);
}
