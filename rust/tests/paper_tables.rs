//! Golden tests: the schedules computed by Algorithms 2–6 must equal the
//! paper's published Tables 1, 2 and 3 *exactly* — the strongest anchor
//! that this implementation is the paper's algorithm and not merely a
//! correct broadcast schedule.

use circulant_bcast::schedule::{baseblock, recv_schedule, send_schedule, Skips};

fn recv_table(p: usize) -> Vec<Vec<i64>> {
    let sk = Skips::new(p);
    (0..sk.q()).map(|k| (0..p).map(|r| recv_schedule(&sk, r).blocks[k]).collect()).collect()
}

fn send_table(p: usize) -> Vec<Vec<i64>> {
    let sk = Skips::new(p);
    (0..sk.q()).map(|k| (0..p).map(|r| send_schedule(&sk, r).blocks[k]).collect()).collect()
}

fn bb_row(p: usize) -> Vec<usize> {
    let sk = Skips::new(p);
    (0..p).map(|r| baseblock(&sk, r)).collect()
}

#[test]
fn table1_p17() {
    assert_eq!(bb_row(17), [5, 0, 1, 2, 0, 3, 0, 1, 2, 4, 0, 1, 2, 0, 3, 0, 1]);
    let recv = recv_table(17);
    assert_eq!(recv[0], [-4, 0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5]);
    assert_eq!(recv[1], [-5, -4, 1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2]);
    assert_eq!(recv[2], [-2, -2, -2, 2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3]);
    assert_eq!(recv[3], [-1, -3, -3, -2, -2, 3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1]);
    assert_eq!(recv[4], [-3, -1, -1, -1, -1, -1, -1, -1, -1, 4, 0, 1, 2, 0, 3, 0, 1]);
    let send = send_table(17);
    assert_eq!(send[0], [0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4]);
    assert_eq!(send[1], [1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5, -4]);
    assert_eq!(send[2], [2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2, -2, -2]);
    assert_eq!(send[3], [3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1, -3, -3, -2, -2]);
    assert_eq!(send[4], [4, 0, 1, 2, 0, 3, 0, 1, -3, -1, -1, -1, -1, -1, -1, -1, -1]);
}

#[test]
fn table2_p9() {
    assert_eq!(bb_row(9), [4, 0, 1, 2, 0, 3, 0, 1, 2]);
    let recv = recv_table(9);
    assert_eq!(recv[0], [-2, 0, -4, -3, -2, -4, -1, -4, -3]);
    assert_eq!(recv[1], [-3, -2, 1, -4, -3, -2, -2, -1, -4]);
    assert_eq!(recv[2], [-1, -3, -2, 2, 0, -3, -3, -2, -1]);
    assert_eq!(recv[3], [-4, -1, -1, -1, -1, 3, 0, 1, 2]);
    let send = send_table(9);
    assert_eq!(send[0], [0, -4, -3, -2, -4, -1, -4, -3, -2]);
    assert_eq!(send[1], [1, -4, -3, -2, -2, -1, -4, -3, -2]);
    assert_eq!(send[2], [2, 0, -3, -3, -2, -1, -1, -3, -2]);
    assert_eq!(send[3], [3, 0, 1, 2, -4, -1, -1, -1, -1]);
}

#[test]
fn table3_p18() {
    assert_eq!(bb_row(18), [5, 0, 1, 2, 0, 3, 0, 1, 2, 4, 0, 1, 2, 0, 3, 0, 1, 2]);
    let recv = recv_table(18);
    assert_eq!(recv[0], [-3, 0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4]);
    assert_eq!(recv[1], [-4, -3, 1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5]);
    assert_eq!(recv[2], [-2, -4, -3, 2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2]);
    assert_eq!(recv[3], [-5, -2, -2, -2, -2, 3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1]);
    assert_eq!(recv[4], [-1, -1, -1, -1, -1, -1, -1, -1, -1, 4, 0, 1, 2, 0, 3, 0, 1, 2]);
    let send = send_table(18);
    assert_eq!(send[0], [0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4, -3]);
    assert_eq!(send[1], [1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5, -4, -3]);
    assert_eq!(send[2], [2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2, -2, -4, -3]);
    assert_eq!(send[3], [3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1, -5, -2, -2, -2, -2]);
    assert_eq!(send[4], [4, 0, 1, 2, 0, 3, 0, 1, 2, -1, -1, -1, -1, -1, -1, -1, -1, -1]);
}

#[test]
fn paper_skips_examples() {
    // §2.1's always-true facts plus the Lemma 3 example skips for p = 11.
    assert_eq!(Skips::new(17).as_slice(), &[1, 2, 3, 5, 9, 17]);
    assert_eq!(Skips::new(11).as_slice(), &[1, 2, 3, 6, 11]);
    for p in 2..100 {
        let sk = Skips::new(p);
        assert_eq!(sk.skip(0), 1);
        assert_eq!(sk.skip(1), 2);
        assert_eq!(sk.skip(sk.q()), p);
    }
}

#[test]
fn paper_violation_examples_p17() {
    // End of §2.3: "there are, for instance, send schedule violations ...
    // for processor r = 3 and ... r = 8" — both must show violations (our
    // instrumentation counts them; round attribution may differ).
    let sk = Skips::new(17);
    assert!(send_schedule(&sk, 3).violations >= 1);
    assert!(send_schedule(&sk, 8).violations >= 1);
    // Power-of-two: the hypercube case, never any violation.
    let sk16 = Skips::new(16);
    for r in 0..16 {
        assert_eq!(send_schedule(&sk16, r).violations, 0);
    }
}
