//! The recovery suite: shrink-and-recover when ranks die.
//!
//! The paper's communication-free O(log p) schedule computation makes
//! elastic membership cheap — each survivor rebuilds its (p − 1)-rank
//! schedule rows locally, nothing is redistributed. This suite pins the
//! recovery plane's guarantees end to end:
//!
//! * a single rank crashing mid-broadcast shrinks the world by exactly
//!   that rank, and the survivors' payloads are **bit-identical to a
//!   fresh run at the shrunken size** — on both the threaded world
//!   (suspicion-board detection) and the wire world (EOF-without-BYE
//!   link accounting), at p ∈ {8, 2^k ± 1};
//! * a dead **root** is replaced by the lowest surviving rank, which
//!   serves the payload in the restarted epoch;
//! * a **two-failure cascade** (a second rank dying during the first
//!   recovery's restarted epoch) shrinks twice and still completes;
//! * a failure inside a **windowed traffic batch** restarts only the
//!   ops whose windows intersect the dead rank — disjoint-window ops
//!   keep their (bit-identical) results;
//! * the shrink budget is enforced: a world out of budget surfaces the
//!   typed [`CommError::MembershipChanged`] instead of looping.
//!
//! Deterministic by default; honors `TESTKIT_SEED` (CI runs the fixed
//! three-seed matrix). The multi-process analogue — real killed
//! processes over UDS — is the `recovery-smoke` release CI job driving
//! `cbcastd rank`.

use std::time::Duration;

use circulant_bcast::comm::{
    elastic_bcast, elastic_reduce, CommBuilder, CommError, CrashPlan, IbcastReq, Membership,
    RankComm, TransportKind,
};
use circulant_bcast::schedule::Skips;
use circulant_bcast::testkit::{install_seed_reporter, Rng};
use std::sync::Arc;

/// Short enough that a test-sized crash is detected quickly, long
/// enough that a loaded CI host never starves a healthy rank into a
/// false timeout before its peer's messages arrive.
const TIMEOUT: Duration = Duration::from_secs(5);

fn payload(n: usize, seed: u64) -> Vec<i64> {
    Rng::new(seed).vec_i64(n, -999, 999)
}

/// The recovery guarantee, checked exhaustively: run `elastic_bcast`
/// with `plan`, assert the final world lost exactly `expect_failed`
/// (original-world ids), and that every survivor's payload equals the
/// root's data — which a fresh run at the final size trivially
/// produces, so bit-identity to that fresh run follows (and is also
/// asserted directly against a no-fault elastic run at p′).
fn assert_recovers(
    p: usize,
    root: usize,
    kind: TransportKind,
    plan: &CrashPlan,
    expect_failed: &[usize],
    expect_root: usize,
    seed: u64,
) {
    let data = payload(96, seed);
    let report = elastic_bcast(p, root, &data, 4, kind, plan, 4, TIMEOUT)
        .unwrap_or_else(|e| panic!("p = {p} {kind:?}: recovery failed: {e}"));
    let p2 = p - expect_failed.len();
    assert_eq!(report.membership.p(), p2, "world must shrink by the dead ranks");
    assert_eq!(report.root, expect_root);
    let survivors: Vec<usize> = (0..p).filter(|r| !expect_failed.contains(r)).collect();
    assert_eq!(report.membership.members(), &survivors[..]);
    assert_eq!(report.buffers.len(), p2);
    for (g, buf) in &report.buffers {
        assert_eq!(buf, &data, "rank {g} (p = {p}, {kind:?})");
    }
    // Bit-identity to a fresh run at the shrunken size, pinned
    // directly: a fault-free elastic run over p′ fresh ranks.
    let fresh = elastic_bcast(p2, 0, &data, 4, kind, &CrashPlan::none(), 0, TIMEOUT)
        .unwrap_or_else(|e| panic!("fresh p = {p2} {kind:?} run failed: {e}"));
    for ((_, recovered), (_, fresh)) in report.buffers.iter().zip(fresh.buffers.iter()) {
        assert_eq!(recovered, fresh, "recovered world must match a fresh p' world");
    }
}

// ---------------------------------------------------------------------
// Membership + RankComm shrink units
// ---------------------------------------------------------------------

#[test]
fn rankcomm_shrink_matches_fresh_construction() {
    install_seed_reporter();
    // RankComm::shrink must renumber exactly like building fresh
    // (p − |failed|)-rank handles: same p, same dense rank.
    for p in [2usize, 5, 8, 9, 17] {
        let sk = Arc::new(Skips::new(p));
        for victim in [0, p / 2, p - 1] {
            for r in 0..p {
                let rc = RankComm::new(p, r, sk.clone());
                let shrunk = rc.shrink(&[victim]);
                if r == victim {
                    assert!(shrunk.is_none(), "a dead rank has no survivor handle");
                } else {
                    let s = shrunk.unwrap();
                    assert_eq!(s.p(), p - 1);
                    let expect = if r < victim { r } else { r - 1 };
                    assert_eq!(s.rank(), expect, "p = {p}, victim {victim}, rank {r}");
                }
            }
        }
    }
}

#[test]
fn membership_survives_paper_grid_shrinks() {
    install_seed_reporter();
    // p over powers of two ± 1 — the schedule-interesting sizes.
    for p in [3usize, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33] {
        let m = Membership::new(p);
        let victim = p / 2;
        let (m1, change) = m.shrink(&[victim]);
        assert_eq!(m1.p(), p - 1);
        assert_eq!(change.failed, vec![victim]);
        assert_eq!(change.epoch, 1);
        for g in 0..p {
            match m1.dense(g) {
                None => assert_eq!(g, victim),
                Some(d) => assert_eq!(m1.global(d), g),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Single crash mid-broadcast: threads and wire, p ∈ {8, 2^k ± 1}
// ---------------------------------------------------------------------

#[test]
fn single_crash_mid_bcast_recovers_on_threads() {
    install_seed_reporter();
    for (p, victim) in [(8usize, 5usize), (7, 3), (9, 6)] {
        let plan = CrashPlan::none().crash(0, victim, 1);
        assert_recovers(p, 0, TransportKind::Threads, &plan, &[victim], 0, 0xA11CE + p as u64);
    }
}

#[test]
fn single_crash_mid_bcast_recovers_on_sockets() {
    install_seed_reporter();
    // Wire worlds are full socketpair meshes (p·(p−1) fd ends) and each
    // epoch builds a fresh one; the same p grid as threads stays well
    // inside the default fd limit.
    for (p, victim) in [(8usize, 5usize), (7, 3), (9, 6)] {
        let plan = CrashPlan::none().crash(0, victim, 1);
        assert_recovers(p, 0, TransportKind::Socket, &plan, &[victim], 0, 0xB0B + p as u64);
    }
}

#[test]
fn crash_before_any_round_recovers_too() {
    install_seed_reporter();
    // crash_round 0: the victim dies before it communicates at all —
    // the pure-silence case (no partial sends to help detection).
    let plan = CrashPlan::none().crash(0, 2, 0);
    assert_recovers(8, 0, TransportKind::Threads, &plan, &[2], 0, 0x51E7);
}

// ---------------------------------------------------------------------
// Root death: the lowest survivor takes over
// ---------------------------------------------------------------------

#[test]
fn dead_root_is_replaced_by_lowest_survivor() {
    install_seed_reporter();
    // Root 2 dies; rank 0 is the lowest survivor and serves the data
    // in the restarted epoch. (The driver hands the payload to
    // whichever rank is root each epoch — the god-view stand-in for
    // "the payload is replicated/recoverable", which is what lets a
    // root death be survivable at all.)
    let plan = CrashPlan::none().crash(0, 2, 1);
    assert_recovers(8, 2, TransportKind::Threads, &plan, &[2], 0, 0x0007);
    let plan = CrashPlan::none().crash(0, 2, 1);
    assert_recovers(8, 2, TransportKind::Socket, &plan, &[2], 0, 0x0008);
}

#[test]
fn elastic_reduce_survives_a_dead_root() {
    use circulant_bcast::collectives::SumOp;
    install_seed_reporter();
    // elastic_reduce rides the same harvest → shrink → re-elect →
    // restart skeleton as elastic_bcast. Root 3 dies mid-reduction;
    // rank 0 (the lowest survivor) takes over, every survivor
    // re-contributes its original input, and the recovered sum equals
    // a fresh p′ = 7 reduction over the survivors' inputs — rank 3's
    // contribution is genuinely lost with it.
    let p = 8usize;
    let n = 64usize;
    let inputs: Vec<Vec<i64>> = (0..p).map(|r| payload(n, 0x5EED + r as u64)).collect();
    for kind in [TransportKind::Threads, TransportKind::Socket] {
        let plan = CrashPlan::none().crash(0, 3, 1);
        let report = elastic_reduce(
            p,
            3,
            &inputs,
            4,
            Arc::new(SumOp),
            kind,
            &plan,
            2,
            TIMEOUT,
        )
        .unwrap_or_else(|e| panic!("{kind:?}: reduce recovery failed: {e}"));
        assert_eq!(report.changes.len(), 1);
        assert_eq!(report.changes[0].failed, vec![3]);
        assert_eq!(report.membership.p(), p - 1);
        assert_eq!(report.root, 0, "the lowest survivor takes over a dead root");
        let want: Vec<i64> = (0..n)
            .map(|i| (0..p).filter(|&r| r != 3).map(|r| inputs[r][i]).sum())
            .collect();
        let (_, got) =
            report.buffers.iter().find(|(g, _)| *g == 0).expect("new root's payload");
        assert_eq!(got, &want, "{kind:?}: survivors' sum, without the dead rank");

        // Bit-identity to a fresh run at the shrunken size: the same
        // survivors' inputs reduced on a fresh 7-rank world.
        let survivor_inputs: Vec<Vec<i64>> =
            (0..p).filter(|&r| r != 3).map(|r| inputs[r].clone()).collect();
        let fresh = elastic_reduce(
            p - 1,
            0,
            &survivor_inputs,
            4,
            Arc::new(SumOp),
            kind,
            &CrashPlan::none(),
            0,
            TIMEOUT,
        )
        .unwrap_or_else(|e| panic!("{kind:?}: fresh p−1 reduce failed: {e}"));
        let (_, fresh_root) =
            fresh.buffers.iter().find(|(g, _)| *g == 0).expect("fresh root's payload");
        assert_eq!(got, fresh_root, "{kind:?}: recovered reduce must match fresh p′");
    }
}

// ---------------------------------------------------------------------
// Two-failure cascade: a second death during recovery
// ---------------------------------------------------------------------

#[test]
fn two_failure_cascade_shrinks_twice() {
    install_seed_reporter();
    // Epoch 0: rank 4 dies. Epoch 1 (the recovery run): rank 7 dies
    // too. The world must shrink twice — 9 → 8 → 7 — and complete.
    let data = payload(96, 0xCA5CADE);
    let plan = CrashPlan::none().crash(0, 4, 1).crash(1, 7, 1);
    let report =
        elastic_bcast(9, 0, &data, 4, TransportKind::Threads, &plan, 4, TIMEOUT).unwrap();
    assert_eq!(report.changes.len(), 2, "two shrinks: {:?}", report.changes);
    assert_eq!(report.membership.p(), 7);
    assert_eq!(report.membership.epoch(), 2);
    assert_eq!(report.changes[0].failed, vec![4]);
    assert_eq!(report.changes[1].failed, vec![7]);
    for (g, buf) in &report.buffers {
        assert_eq!(buf, &data, "rank {g}");
    }
}

#[test]
fn shrink_budget_exhaustion_is_typed() {
    install_seed_reporter();
    // Budget 1, two planned deaths: the second shrink is refused and
    // the caller gets the membership receipt, not a hang or a panic.
    let data = payload(48, 0xB7D6E7);
    let plan = CrashPlan::none().crash(0, 1, 1).crash(1, 2, 1);
    let err = elastic_bcast(5, 0, &data, 2, TransportKind::Threads, &plan, 1, TIMEOUT)
        .expect_err("budget 1 cannot absorb two failures");
    match err {
        CommError::MembershipChanged { epoch, failed, survivors } => {
            assert_eq!(epoch, 2);
            assert_eq!(failed, vec![2]);
            assert_eq!(survivors, vec![0, 3, 4], "original-world ids");
        }
        other => panic!("expected MembershipChanged, got {other}"),
    }
}

#[test]
fn loopback_has_no_detector_and_says_so() {
    install_seed_reporter();
    let data = payload(8, 1);
    let err = elastic_bcast(
        4,
        0,
        &data,
        1,
        TransportKind::Loopback,
        &CrashPlan::none(),
        1,
        TIMEOUT,
    )
    .expect_err("loopback cannot drive recovery");
    assert!(matches!(err, CommError::BadRequest(_)), "{err}");
}

// ---------------------------------------------------------------------
// Mid-batch failure: disjoint-window ops keep their results
// ---------------------------------------------------------------------

#[test]
fn restart_set_spares_disjoint_windows() {
    install_seed_reporter();
    // A windowed traffic batch on the god-view plane: ops over
    // [0, 4), [4, 4) and the full machine. Rank 5 "dies" after the
    // batch: the checkpoint accessors must restart exactly the ops
    // whose windows contain rank 5, and the disjoint ops' outcomes —
    // already delivered — must be bit-identical to solo runs.
    let p = 8usize;
    let comm = CommBuilder::new(p).build();
    let data_a = payload(32, 0xAAA);
    let data_b = payload(32, 0xBBB);
    let data_c = payload(32, 0xCCC);
    let mut traffic = comm.traffic();
    let pa = traffic
        .submit(IbcastReq::new(0, data_a.clone()).window(0, 4))
        .unwrap();
    let pb = traffic
        .submit(IbcastReq::new(1, data_b.clone()).window(4, 4))
        .unwrap();
    let pc = traffic.submit(IbcastReq::new(0, data_c.clone())).unwrap();
    let report = traffic.run().unwrap();
    assert_eq!(report.completed_ops(), vec![0, 1, 2], "all three completed");

    // Rank 5 dies. Window [0,4) is disjoint; [4,4) and the full
    // machine intersect.
    assert_eq!(report.restart_set(&[5]), vec![1, 2]);
    // A rank outside every window (none here, p = 8 is covered) —
    // but a hypothetical failure of rank 0 intersects ops 0 and 2.
    assert_eq!(report.restart_set(&[0]), vec![0, 2]);

    // The spared op's delivered buffers are untouched and correct.
    let out_a = pa.wait().unwrap();
    for (r, buf) in out_a.buffers.iter().enumerate() {
        assert_eq!(buf, &data_a, "window rank {r}");
    }
    // The intersecting ops delivered too (the death came *after* the
    // batch) — restart_set is the daemon's replay decision, not a
    // verdict on these buffers.
    assert!(pb.wait().is_ok());
    assert!(pc.wait().is_ok());

    // And the replay itself: rerun the restart set on the shrunken
    // world, windows remapped. [4,4) loses rank 5 -> dense (4,3); the
    // full machine becomes p = 7.
    let m = Membership::new(p);
    let (m1, _) = m.shrink(&[5]);
    let (b_base, b_len) = m1.remap_window(4, 4).unwrap();
    assert_eq!((b_base, b_len), (4, 3));
    let comm7 = CommBuilder::new(m1.p()).build();
    let mut replay = comm7.traffic();
    let rb = replay
        .submit(IbcastReq::new(1, data_b.clone()).window(b_base, b_len))
        .unwrap();
    let rc = replay.submit(IbcastReq::new(0, data_c.clone())).unwrap();
    replay.run().unwrap();
    let out_b = rb.wait().unwrap();
    assert_eq!(out_b.buffers.len(), 3, "the remapped window kept 3 of 4 ranks");
    for buf in &out_b.buffers {
        assert_eq!(buf, &data_b);
    }
    let out_c = rc.wait().unwrap();
    assert_eq!(out_c.buffers.len(), 7);
    for buf in &out_c.buffers {
        assert_eq!(buf, &data_c);
    }
}

// ---------------------------------------------------------------------
// Failure during a windowed batch on the elastic driver's worlds
// ---------------------------------------------------------------------

#[test]
fn no_fault_elastic_runs_match_plain_spmd() {
    install_seed_reporter();
    // elastic_bcast with an empty plan must degenerate to a plain run
    // at every paper-grid size — the recovery plane costs nothing when
    // nobody dies.
    for p in [1usize, 2, 3, 8, 9] {
        let data = payload(64, 0xD06 + p as u64);
        let report = elastic_bcast(
            p,
            0,
            &data,
            4,
            TransportKind::Threads,
            &CrashPlan::none(),
            0,
            TIMEOUT,
        )
        .unwrap();
        assert!(report.changes.is_empty());
        assert_eq!(report.membership.epoch(), 0);
        assert_eq!(report.buffers.len(), p);
        for (g, buf) in &report.buffers {
            assert_eq!(buf, &data, "p = {p}, rank {g}");
        }
    }
}
