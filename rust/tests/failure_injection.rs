//! Failure injection: corrupt schedules and misbehaving ranks must be
//! *detected* by the machine-model enforcement, not silently tolerated —
//! the simulator doubles as a schedule validator, and these tests prove
//! the validator actually fires.

use std::sync::Arc;

use std::time::Duration;

use circulant_bcast::collectives::bcast::BcastProc;
use circulant_bcast::collectives::common::{BlockGeometry, World};
use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    Algo, BcastReq, CommBuilder, CommError, IbcastReq, IreduceReq, LoopbackTransport, Outcome,
    RankComm, ReduceReq, ThreadTransport, Transport, TransportError,
};
use circulant_bcast::schedule::{verify_one_ported_trace, Skips};
use circulant_bcast::sim::network::{Msg, Network, RankProc, RunStats, SimError};
use circulant_bcast::sim::UnitCost;
use circulant_bcast::testkit::install_seed_reporter;

/// Wraps a proc and tampers with its behaviour.
struct Tamper<P> {
    inner: P,
    /// Redirect round-0 send to this target.
    redirect_to: Option<usize>,
    /// Suppress all sends.
    mute: bool,
    /// Send one extra unsolicited message in round 0.
    extra_to: Option<usize>,
}

impl<P: RankProc<u32>> RankProc<u32> for Tamper<P> {
    fn send(&mut self, round: usize) -> Option<Msg<u32>> {
        if self.mute {
            // Drain the inner state machine anyway (keeps its bookkeeping
            // coherent) but drop the message.
            let _ = self.inner.send(round);
            return None;
        }
        let msg = self.inner.send(round);
        if round == 0 {
            if let Some(t) = self.extra_to {
                // Unsolicited message (possibly while inner sends nothing).
                return Some(Msg { to: t, data: vec![99] });
            }
            if let Some(t) = self.redirect_to {
                return match msg {
                    Some(mut m) => {
                        m.to = t;
                        Some(m)
                    }
                    None => Some(Msg { to: t, data: vec![1, 2, 3] }),
                };
            }
        }
        msg
    }
    fn expects(&self, round: usize) -> Option<usize> {
        self.inner.expects(round)
    }
    fn recv(&mut self, round: usize, from: usize, data: Vec<u32>) {
        self.inner.recv(round, from, data);
    }
    fn rounds(&self) -> usize {
        self.inner.rounds()
    }
}

fn procs(p: usize, m: usize, n: usize) -> Vec<BcastProc<u32>> {
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let data: Vec<u32> = (0..m as u32).collect();
    (0..p)
        .map(|r| BcastProc::new(&world, r, 0, geom, if r == 0 { Some(&data[..]) } else { None }))
        .collect()
}

fn wrap(
    inner: Vec<BcastProc<u32>>,
    f: impl Fn(usize) -> (Option<usize>, bool, Option<usize>),
) -> Vec<Tamper<BcastProc<u32>>> {
    inner
        .into_iter()
        .enumerate()
        .map(|(r, p)| {
            let (redirect_to, mute, extra_to) = f(r);
            Tamper { inner: p, redirect_to, mute, extra_to }
        })
        .collect()
}

#[test]
fn muted_sender_detected_as_missing_message() {
    // Rank 1 (the root's first target) never sends: some receiver expecting
    // a block must trip MissingMessage within a few rounds... in round 0
    // the root's own message still arrives at rank 1; rank 1's silence is
    // noticed by ITS receiver later.
    let p = 9usize;
    let mut t = wrap(procs(p, 36, 4), |r| (None, r == 1, None));
    let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
    assert!(
        matches!(err, SimError::MissingMessage { .. }),
        "expected MissingMessage, got {err:?}"
    );
}

#[test]
fn redirected_message_detected() {
    // Rank 1 redirects its round-0 message to the wrong target: either the
    // target's port is unexpectedly busy, the target did not expect it, or
    // the true receiver starves — all must be caught.
    let p = 9usize;
    for wrong_target in [3usize, 5, 7] {
        let mut t = wrap(procs(p, 36, 4), |r| {
            (if r == 1 { Some(wrong_target) } else { None }, false, None)
        });
        let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
        assert!(
            matches!(
                err,
                SimError::UnexpectedMessage { .. }
                    | SimError::ReceivePortBusy { .. }
                    | SimError::MissingMessage { .. }
            ),
            "wrong_target={wrong_target}: got {err:?}"
        );
    }
}

#[test]
fn unsolicited_message_detected() {
    // A rank that sends when its schedule says not to must be caught.
    let p = 17usize;
    // rank 12 sends an unsolicited message to rank 4 in round 0.
    let mut t = wrap(procs(p, 34, 2), |r| (None, false, if r == 12 { Some(4) } else { None }));
    let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::UnexpectedMessage { .. } | SimError::ReceivePortBusy { .. }
        ),
        "got {err:?}"
    );
}

#[test]
fn corrupted_schedule_blocks_panic_on_use_before_receive() {
    // Force a rank to "send" a block it cannot have: BcastProc panics with
    // a schedule-violation diagnostic (caught here via catch_unwind).
    struct EarlySender {
        inner: BcastProc<u32>,
    }
    impl RankProc<u32> for EarlySender {
        fn send(&mut self, round: usize) -> Option<Msg<u32>> {
            if round == 0 && self.inner.rank == 5 {
                // Ask the inner proc for a later round's send, which needs
                // a block rank 5 has not received yet in round 0.
                return self.inner.send(3);
            }
            self.inner.send(round)
        }
        fn expects(&self, round: usize) -> Option<usize> {
            self.inner.expects(round)
        }
        fn recv(&mut self, round: usize, from: usize, data: Vec<u32>) {
            self.inner.recv(round, from, data);
        }
        fn rounds(&self) -> usize {
            self.inner.rounds()
        }
    }
    let p = 17usize;
    let inner = procs(p, 68, 8);
    let mut t: Vec<EarlySender> = inner.into_iter().map(|i| EarlySender { inner: i }).collect();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = Network::new(p).run(&mut t, 4, &UnitCost);
    }));
    assert!(res.is_err(), "sending an unreceived block must panic with a diagnostic");
}

#[test]
fn clean_run_has_no_failures() {
    // Control: the untampered system runs to completion.
    let p = 9usize;
    let mut t = wrap(procs(p, 36, 4), |_| (None, false, None));
    let stats = Network::new(p).run(&mut t, 4, &UnitCost).unwrap();
    assert_eq!(stats.rounds, 4 - 1 + 4);
}

// ---------------------------------------------------------------------
// Traffic plane: a violation injected mid-batch must surface in exactly
// the offending op's Outcome (same error, same local round as its
// sequential run) while co-scheduled ops complete unaffected.
// ---------------------------------------------------------------------

/// Outcome assembly for tampered bcast procs submitted through
/// `TrafficEngine::submit_procs` (only reached if the op completes —
/// i.e. by the untampered control).
fn tamper_assemble(
    p: usize,
    m: usize,
) -> impl FnOnce(RunStats, Vec<Tamper<BcastProc<u32>>>) -> Result<Outcome<Vec<Vec<u32>>>, CommError>
       + Send
       + 'static {
    move |stats, procs| {
        let buffers: Vec<Vec<u32>> =
            procs.into_iter().map(|t| t.inner.into_buffer()).collect();
        let complete = buffers.len() == p && buffers.iter().all(|b| b.len() == m);
        Ok(Outcome {
            rounds: stats.rounds,
            stats,
            buffers,
            algo: Algo::Circulant,
            complete,
            machine_span: None,
        })
    }
}

/// The shared scenario: a batch of [healthy bcast, tampered bcast
/// (tamper chosen by `tamper`), healthy reduce]. Asserts the tampered
/// op fails with exactly `expected` (its solo lockstep error) and both
/// healthy ops match their solo runs bit for bit.
fn check_mid_batch_isolation(
    tamper: impl Fn(usize) -> (Option<usize>, bool, Option<usize>) + Copy,
) {
    install_seed_reporter();
    let p = 9usize;
    let (m, n) = (36usize, 4usize);

    // Sequential truth: the tampered op alone on the lockstep Network.
    let mut solo = wrap(procs(p, m, n), tamper);
    let expected = Network::new(p).run(&mut solo, 4, &UnitCost).unwrap_err();

    let comm = CommBuilder::new(p).cost_model(UnitCost).build();
    let data: Vec<i64> = (0..50).map(|i| i * 3 - 11).collect();
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..20).map(|i| ((r + 1) * (i + 3)) as i64 % 71).collect()).collect();

    for threads in [1usize, 4] {
        let mut traffic = comm.traffic().threads(threads).record_trace(true);
        let healthy_bcast = traffic
            .submit(IbcastReq::new(2, data.clone()).algo(Algo::Circulant).blocks(3))
            .unwrap();
        let tampered = traffic
            .submit_procs(None, wrap(procs(p, m, n), tamper), 4, tamper_assemble(p, m))
            .unwrap();
        let healthy_reduce = traffic
            .submit(
                IreduceReq::new(0, inputs.clone(), Arc::new(SumOp)).algo(Algo::Circulant).blocks(2),
            )
            .unwrap();
        let report = traffic.run().unwrap();

        // The executed trace still respects the cross-op discipline
        // (the erroring round's messages were discarded, mirroring the
        // lockstep mid-round abort).
        verify_one_ported_trace(p, report.trace.as_ref().unwrap()).unwrap();

        // Offending op: exactly the sequential error (kind AND round).
        match tampered.wait() {
            Err(CommError::Sim(e)) => {
                assert_eq!(e, expected, "threads={threads}: batched error must match solo")
            }
            other => panic!("tampered op must fail with the solo SimError, got {other:?}"),
        }
        assert_eq!(report.failed(), 1, "threads={threads}");
        assert!(report.ops[0].ok && !report.ops[1].ok && report.ops[2].ok);

        // Co-scheduled ops: unaffected, bit-identical to solo runs.
        let got_b = healthy_bcast.wait().unwrap();
        let solo_b =
            comm.bcast(BcastReq::new(2, &data).algo(Algo::Circulant).blocks(3)).unwrap();
        assert_eq!(got_b.buffers, solo_b.buffers, "threads={threads}");
        assert_eq!(got_b.stats.messages, solo_b.stats.messages);
        assert_eq!(got_b.stats.bytes, solo_b.stats.bytes);
        assert_eq!(got_b.rounds, solo_b.rounds);
        assert!(got_b.all_received());

        let got_r = healthy_reduce.wait().unwrap();
        let solo_r = comm
            .reduce(ReduceReq::new(0, &inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(2))
            .unwrap();
        assert_eq!(got_r.buffers, solo_r.buffers, "threads={threads}");
        assert_eq!(got_r.stats.messages, solo_r.stats.messages);
        assert_eq!(got_r.rounds, solo_r.rounds);
    }
}

#[test]
fn traffic_redirected_message_isolated_to_offending_op() {
    // Rank 1 redirects its round-0 message to rank 5.
    check_mid_batch_isolation(|r| (if r == 1 { Some(5) } else { None }, false, None));
}

#[test]
fn traffic_muted_sender_isolated_to_offending_op() {
    // Rank 1 never sends: a receiver downstream starves.
    check_mid_batch_isolation(|r| (None, r == 1, None));
}

#[test]
fn traffic_unsolicited_sender_isolated_to_offending_op() {
    // Rank 5 sends an unsolicited round-0 message to rank 7.
    check_mid_batch_isolation(|r| (None, false, if r == 5 { Some(7) } else { None }));
}

// ---------------------------------------------------------------------
// SPMD rank plane: transport misuse and tampered ranks. The transport
// must reject round-discipline violations, surface wrong-peer
// deliveries in the lockstep SimError vocabulary, and — the key
// liveness property — shut the whole world down when one rank
// misbehaves, so no healthy rank's mailbox ever deadlocks.
// ---------------------------------------------------------------------

#[test]
fn spmd_out_of_round_sends_rejected_on_both_transports() {
    // Second send in a round, and a send for an earlier round, are
    // caller-side discipline violations on every transport.
    let mut tw = ThreadTransport::<u32>::world(3);
    let mut t0 = tw.remove(0);
    t0.send(2, 1, vec![1]).unwrap();
    assert!(matches!(
        t0.send(2, 1, vec![2]),
        Err(TransportError::OutOfRound { round: 2, .. })
    ));
    assert!(matches!(
        t0.send(0, 2, vec![3]),
        Err(TransportError::OutOfRound { round: 0, .. })
    ));

    let mut lw = LoopbackTransport::<u32>::world(3);
    let mut l0 = lw.remove(0);
    // Sealing a round forbids sending into it afterwards.
    l0.flush(0).unwrap();
    assert!(matches!(
        l0.send(0, 1, vec![1]),
        Err(TransportError::OutOfRound { round: 0, .. })
    ));
}

#[test]
fn spmd_wrong_peer_recv_is_the_lockstep_unexpected_message() {
    let mut w = ThreadTransport::<u32>::world(3);
    let mut t2 = w.pop().unwrap();
    let mut t1 = w.pop().unwrap();
    t1.send(0, 2, vec![7]).unwrap();
    t2.flush(0).unwrap();
    match t2.recv(0, 0) {
        Err(TransportError::Machine(SimError::UnexpectedMessage {
            round: 0,
            to: 2,
            from: 1,
            expected: Some(0),
        })) => {}
        other => panic!("expected the lockstep UnexpectedMessage, got {other:?}"),
    }
    // The violation poisoned the world: the innocent sender does not
    // hang on its own receive, it sees the shutdown.
    assert!(matches!(t1.recv(0, 2), Err(TransportError::Shutdown { .. })));
}

/// Forwarding transport that silently drops this rank's sends — the
/// SPMD analogue of the muted-sender tamper above (and a demonstration
/// that `Transport` is pluggable enough for fault injectors).
struct Mute<Tr>(Tr);

impl<T, Tr: Transport<T>> Transport<T> for Mute<Tr> {
    fn p(&self) -> usize {
        self.0.p()
    }
    fn rank(&self) -> usize {
        self.0.rank()
    }
    fn send(&mut self, _round: usize, _peer: usize, _data: Vec<T>) -> Result<(), TransportError> {
        Ok(()) // dropped on the floor
    }
    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        self.0.flush(round)
    }
    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        self.0.recv(round, peer)
    }
    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        self.0.close(error)
    }
}

/// One bad rank (rank 1, muted) in a p = 9 SPMD broadcast: some victim
/// must surface the solo lockstep error (`MissingMessage` on the
/// loopback transport, a timeout-shutdown on the free-running thread
/// transport), every healthy rank must return — not deadlock — and the
/// whole world must come down cleanly.
#[test]
fn spmd_tampered_rank_fails_alone_and_world_shuts_down() {
    install_seed_reporter();
    let p = 9usize;
    let (m, n) = (36usize, 4usize);
    let sk = Arc::new(Skips::new(p));
    let data: Vec<u32> = (0..m as u32).collect();

    // Solo truth: the same tamper on the lockstep Network.
    let mut solo = wrap(procs(p, m, n), |r| (None, r == 1, None));
    let solo_err = Network::new(p).run(&mut solo, 4, &UnitCost).unwrap_err();
    assert!(matches!(solo_err, SimError::MissingMessage { .. }));

    // Loopback: the victim's error is in the same vocabulary (a
    // MissingMessage at the barrier — no timeouts involved).
    let world = LoopbackTransport::<u32>::world_with_timeout(p, Duration::from_secs(10));
    let results = run_tampered_bcast(world, &sk, &data, n, 1);
    assert_outcomes(&results, p, |e| {
        matches!(
            e,
            CommError::Transport(TransportError::Machine(SimError::MissingMessage { .. }))
        )
    });

    // ThreadTransport: free-running, so the starved victim times out;
    // the timeout poisons the world and everyone returns promptly.
    let world = ThreadTransport::<u32>::world_with_timeout(p, Duration::from_millis(300));
    let results = run_tampered_bcast(world, &sk, &data, n, 1);
    assert_outcomes(&results, p, |e| {
        matches!(e, CommError::Transport(TransportError::Timeout { .. }))
    });
}

/// Drive a p-rank SPMD bcast with rank `bad` muted; returns per-rank
/// results (the scope returning at all is the no-deadlock receipt).
fn run_tampered_bcast<Tr: Transport<u32> + Send>(
    world: Vec<Tr>,
    sk: &Arc<Skips>,
    data: &[u32],
    n: usize,
    bad: usize,
) -> Vec<Result<(), CommError>> {
    let p = sk.p();
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(r, mut tr)| {
                let sk = sk.clone();
                s.spawn(move || {
                    let rc = RankComm::new(p, r, sk);
                    let mut buf =
                        if r == 0 { data.to_vec() } else { vec![0u32; data.len()] };
                    if r == bad {
                        rc.bcast(&mut Mute(tr), 0, &mut buf, n).map(|_| ())
                    } else {
                        rc.bcast(&mut tr, 0, &mut buf, n).map(|_| ())
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    })
}

/// At least one rank fails, at least one failure matches the expected
/// solo shape, and every failure is either that shape, a shutdown echo,
/// a timeout, or a completion-check `Incomplete` — nothing hangs, and
/// nothing succeeds that should not (the root and early receivers may
/// legitimately finish before the world comes down).
fn assert_outcomes(
    results: &[Result<(), CommError>],
    p: usize,
    expected: impl Fn(&CommError) -> bool,
) {
    assert_eq!(results.len(), p);
    let errors: Vec<&CommError> =
        results.iter().filter_map(|r| r.as_ref().err()).collect();
    assert!(!errors.is_empty(), "a tampered world must not fully succeed");
    assert!(
        errors.iter().any(|e| expected(e)),
        "no error matched the expected solo shape: {errors:?}"
    );
    for &e in &errors {
        assert!(
            expected(e)
                || matches!(
                    e,
                    CommError::Transport(
                        TransportError::Shutdown { .. } | TransportError::Timeout { .. }
                    )
                )
                || matches!(e, CommError::Incomplete { .. }),
            "unexpected error shape: {e:?}"
        );
    }
}

/// Run the untampered control world; every rank's final buffer.
fn run_clean_bcast<Tr: Transport<u32> + Send>(
    world: Vec<Tr>,
    sk: &Arc<Skips>,
    data: &[u32],
    n: usize,
) -> Vec<Result<Vec<u32>, CommError>> {
    let p = sk.p();
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(r, mut tr)| {
                let sk = sk.clone();
                s.spawn(move || {
                    let rc = RankComm::new(p, r, sk);
                    let mut buf =
                        if r == 0 { data.to_vec() } else { vec![0u32; data.len()] };
                    rc.bcast(&mut tr, 0, &mut buf, n).map(|_| buf)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn spmd_untampered_world_completes_cleanly() {
    // Control for the tamper scenario: the identical setup, no mute —
    // every rank completes with the full payload on both transports.
    let p = 9usize;
    let (m, n) = (36usize, 4usize);
    let sk = Arc::new(Skips::new(p));
    let data: Vec<u32> = (0..m as u32).collect();
    let thread_world = ThreadTransport::<u32>::world(p);
    let loop_world = LoopbackTransport::<u32>::world(p);
    for (label, results) in [
        ("threads", run_clean_bcast(thread_world, &sk, &data, n)),
        ("loopback", run_clean_bcast(loop_world, &sk, &data, n)),
    ] {
        for (r, res) in results.iter().enumerate() {
            let buf = res.as_ref().unwrap_or_else(|e| panic!("{label} rank {r}: {e}"));
            assert_eq!(buf, &data, "{label} rank={r}");
        }
    }
}

#[test]
fn traffic_untampered_custom_procs_complete() {
    // Control: the same proc set, untampered, submitted through the
    // custom-op escape hatch, completes with the full payload.
    install_seed_reporter();
    let p = 9usize;
    let (m, n) = (36usize, 4usize);
    let comm = CommBuilder::new(p).cost_model(UnitCost).build();
    let mut traffic = comm.traffic().threads(2);
    let clean = wrap(procs(p, m, n), |_| (None, false, None));
    let handle = traffic.submit_procs(None, clean, 4, tamper_assemble(p, m)).unwrap();
    let report = traffic.run().unwrap();
    let out = handle.wait().unwrap();
    assert!(out.all_received());
    assert_eq!(out.rounds, n - 1 + 4);
    let want: Vec<u32> = (0..m as u32).collect();
    for buf in &out.buffers {
        assert_eq!(buf, &want);
    }
    assert_eq!(report.failed(), 0);
    assert_eq!(out.machine_span, Some((0, out.rounds - 1)));
}
