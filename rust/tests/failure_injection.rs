//! Failure injection: corrupt schedules and misbehaving ranks must be
//! *detected* by the machine-model enforcement, not silently tolerated —
//! the simulator doubles as a schedule validator, and these tests prove
//! the validator actually fires.

use circulant_bcast::collectives::bcast::BcastProc;
use circulant_bcast::collectives::common::{BlockGeometry, World};
use circulant_bcast::sim::network::{Msg, Network, RankProc, SimError};
use circulant_bcast::sim::UnitCost;

/// Wraps a proc and tampers with its behaviour.
struct Tamper<P> {
    inner: P,
    /// Redirect round-0 send to this target.
    redirect_to: Option<usize>,
    /// Suppress all sends.
    mute: bool,
    /// Send one extra unsolicited message in round 0.
    extra_to: Option<usize>,
}

impl<P: RankProc<u32>> RankProc<u32> for Tamper<P> {
    fn send(&mut self, round: usize) -> Option<Msg<u32>> {
        if self.mute {
            // Drain the inner state machine anyway (keeps its bookkeeping
            // coherent) but drop the message.
            let _ = self.inner.send(round);
            return None;
        }
        let msg = self.inner.send(round);
        if round == 0 {
            if let Some(t) = self.extra_to {
                // Unsolicited message (possibly while inner sends nothing).
                return Some(Msg { to: t, data: vec![99] });
            }
            if let Some(t) = self.redirect_to {
                return match msg {
                    Some(mut m) => {
                        m.to = t;
                        Some(m)
                    }
                    None => Some(Msg { to: t, data: vec![1, 2, 3] }),
                };
            }
        }
        msg
    }
    fn expects(&self, round: usize) -> Option<usize> {
        self.inner.expects(round)
    }
    fn recv(&mut self, round: usize, from: usize, data: Vec<u32>) {
        self.inner.recv(round, from, data);
    }
    fn rounds(&self) -> usize {
        self.inner.rounds()
    }
}

fn procs(p: usize, m: usize, n: usize) -> Vec<BcastProc<u32>> {
    let world = World::new(p);
    let geom = BlockGeometry::new(m, n);
    let data: Vec<u32> = (0..m as u32).collect();
    (0..p)
        .map(|r| BcastProc::new(&world, r, 0, geom, if r == 0 { Some(&data[..]) } else { None }))
        .collect()
}

fn wrap(
    inner: Vec<BcastProc<u32>>,
    f: impl Fn(usize) -> (Option<usize>, bool, Option<usize>),
) -> Vec<Tamper<BcastProc<u32>>> {
    inner
        .into_iter()
        .enumerate()
        .map(|(r, p)| {
            let (redirect_to, mute, extra_to) = f(r);
            Tamper { inner: p, redirect_to, mute, extra_to }
        })
        .collect()
}

#[test]
fn muted_sender_detected_as_missing_message() {
    // Rank 1 (the root's first target) never sends: some receiver expecting
    // a block must trip MissingMessage within a few rounds... in round 0
    // the root's own message still arrives at rank 1; rank 1's silence is
    // noticed by ITS receiver later.
    let p = 9usize;
    let mut t = wrap(procs(p, 36, 4), |r| (None, r == 1, None));
    let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
    assert!(
        matches!(err, SimError::MissingMessage { .. }),
        "expected MissingMessage, got {err:?}"
    );
}

#[test]
fn redirected_message_detected() {
    // Rank 1 redirects its round-0 message to the wrong target: either the
    // target's port is unexpectedly busy, the target did not expect it, or
    // the true receiver starves — all must be caught.
    let p = 9usize;
    for wrong_target in [3usize, 5, 7] {
        let mut t = wrap(procs(p, 36, 4), |r| {
            (if r == 1 { Some(wrong_target) } else { None }, false, None)
        });
        let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
        assert!(
            matches!(
                err,
                SimError::UnexpectedMessage { .. }
                    | SimError::ReceivePortBusy { .. }
                    | SimError::MissingMessage { .. }
            ),
            "wrong_target={wrong_target}: got {err:?}"
        );
    }
}

#[test]
fn unsolicited_message_detected() {
    // A rank that sends when its schedule says not to must be caught.
    let p = 17usize;
    // rank 12 sends an unsolicited message to rank 4 in round 0.
    let mut t = wrap(procs(p, 34, 2), |r| (None, false, if r == 12 { Some(4) } else { None }));
    let err = Network::new(p).run(&mut t, 4, &UnitCost).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::UnexpectedMessage { .. } | SimError::ReceivePortBusy { .. }
        ),
        "got {err:?}"
    );
}

#[test]
fn corrupted_schedule_blocks_panic_on_use_before_receive() {
    // Force a rank to "send" a block it cannot have: BcastProc panics with
    // a schedule-violation diagnostic (caught here via catch_unwind).
    struct EarlySender {
        inner: BcastProc<u32>,
    }
    impl RankProc<u32> for EarlySender {
        fn send(&mut self, round: usize) -> Option<Msg<u32>> {
            if round == 0 && self.inner.rank == 5 {
                // Ask the inner proc for a later round's send, which needs
                // a block rank 5 has not received yet in round 0.
                return self.inner.send(3);
            }
            self.inner.send(round)
        }
        fn expects(&self, round: usize) -> Option<usize> {
            self.inner.expects(round)
        }
        fn recv(&mut self, round: usize, from: usize, data: Vec<u32>) {
            self.inner.recv(round, from, data);
        }
        fn rounds(&self) -> usize {
            self.inner.rounds()
        }
    }
    let p = 17usize;
    let inner = procs(p, 68, 8);
    let mut t: Vec<EarlySender> = inner.into_iter().map(|i| EarlySender { inner: i }).collect();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = Network::new(p).run(&mut t, 4, &UnitCost);
    }));
    assert!(res.is_err(), "sending an unreceived block must panic with a diagnostic");
}

#[test]
fn clean_run_has_no_failures() {
    // Control: the untampered system runs to completion.
    let p = 9usize;
    let mut t = wrap(procs(p, 36, 4), |_| (None, false, None));
    let stats = Network::new(p).run(&mut t, 4, &UnitCost).unwrap();
    assert_eq!(stats.rounds, 4 - 1 + 4);
}
