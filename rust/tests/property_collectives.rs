//! Property-based tests on the collectives, driven through the typed
//! `Communicator` API: random (p, root, m, n, backend) — data integrity,
//! round optimality and machine-model cleanliness on every draw, with
//! shrinking to a minimal counterexample on failure (backend shrinks to
//! lockstep first, so a reported minimum isolates backend-specific bugs).
//! All cases of a property share one `ScheduleCache`, exactly as a
//! long-running service would. Deterministic by default; every property
//! honors `TESTKIT_SEED` through `Rng::from_env` (CI runs a seed matrix).

use std::sync::Arc;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    Algo, AllgathervReq, AllreduceReq, BackendKind, BcastReq, CommBuilder, Communicator,
    ReduceReq, ReduceScatterReq,
};
use circulant_bcast::schedule::{ceil_log2, verify_one_ported_trace, ScheduleCache};
use circulant_bcast::sim::UnitCost;
use circulant_bcast::testkit::{
    forall_shrink, submit_mix_op, traffic_mix, MixOptions, MixOutcome, Rng, TrafficMix,
};

#[derive(Debug, Clone)]
struct Case {
    p: usize,
    root: usize,
    m: usize,
    n: usize,
    backend: BackendKind,
}

/// Backends weighted towards the cheap ones (a threaded or SPMD case
/// spawns `p` OS threads); the engine and rank-plane paths get steady
/// coverage.
fn gen_backend(rng: &mut Rng) -> BackendKind {
    match rng.range(0, 7) {
        0..=3 => BackendKind::Lockstep,
        4 | 5 => BackendKind::Engine,
        6 => BackendKind::Threaded,
        _ => BackendKind::Spmd,
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let p = rng.range(1, 40);
    Case {
        p,
        root: rng.range(0, p - 1),
        m: rng.range(0, 200),
        n: rng.range(1, 24),
        backend: gen_backend(rng),
    }
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    if c.backend != BackendKind::Lockstep {
        out.push(Case { backend: BackendKind::Lockstep, ..c.clone() });
    }
    if c.p > 1 {
        out.push(Case { p: c.p / 2 + 1, root: c.root % (c.p / 2 + 1), ..c.clone() });
    }
    if c.m > 0 {
        out.push(Case { m: c.m / 2, ..c.clone() });
    }
    if c.n > 1 {
        out.push(Case { n: c.n / 2, ..c.clone() });
    }
    if c.root > 0 {
        out.push(Case { root: 0, ..c.clone() });
    }
    out
}

fn comm_for(cache: &Arc<ScheduleCache>, p: usize, backend: BackendKind) -> Communicator {
    CommBuilder::new(p).cache(cache.clone()).cost_model(UnitCost).backend(backend).build()
}

#[test]
fn prop_bcast_delivers_everything() {
    let cache = Arc::new(ScheduleCache::new());
    forall_shrink(
        250,
        gen_case,
        |c| {
            let data: Vec<i64> = (0..c.m as i64).map(|i| i * 3 - 7).collect();
            let out = comm_for(&cache, c.p, c.backend)
                .bcast(BcastReq::new(c.root, &data).algo(Algo::Circulant).blocks(c.n).elem_bytes(8))
                .map_err(|e| format!("comm error: {e}"))?;
            if !out.all_received() {
                return Err("not all ranks complete".into());
            }
            for (r, buf) in out.buffers.iter().enumerate() {
                if buf != &data {
                    return Err(format!("rank {r} got wrong data"));
                }
            }
            if c.p > 1 && out.rounds != c.n - 1 + ceil_log2(c.p) {
                return Err(format!("rounds {} not optimal", out.rounds));
            }
            Ok(())
        },
        shrink_case,
    );
}

#[test]
fn prop_reduce_sums_correctly() {
    let cache = Arc::new(ScheduleCache::new());
    forall_shrink(
        200,
        gen_case,
        |c| {
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r * 37 + i * 11) % 256) as i64).collect())
                .collect();
            let want: Vec<i64> =
                (0..c.m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            let out = comm_for(&cache, c.p, c.backend)
                .reduce(
                    ReduceReq::new(c.root, &inputs, Arc::new(SumOp))
                        .algo(Algo::Circulant)
                        .blocks(c.n)
                        .elem_bytes(8),
                )
                .map_err(|e| format!("comm error: {e}"))?;
            if out.buffers != want {
                return Err("wrong reduction at root".into());
            }
            Ok(())
        },
        shrink_case,
    );
}

#[test]
fn prop_allgatherv_random_counts() {
    let cache = Arc::new(ScheduleCache::new());
    forall_shrink(
        150,
        |rng| {
            let p = rng.range(1, 24);
            let n = rng.range(1, 12);
            // counts with zeros, spikes, and ordinary values
            let counts: Vec<usize> = (0..p)
                .map(|_| match rng.range(0, 4) {
                    0 => 0,
                    1 => rng.range(1, 5),
                    2 => rng.range(5, 40),
                    _ => rng.range(40, 120),
                })
                .collect();
            (counts, n, gen_backend(rng))
        },
        |(counts, n, backend)| {
            let p = counts.len();
            let inputs: Vec<Vec<i32>> = counts
                .iter()
                .enumerate()
                .map(|(r, &c)| (0..c).map(|i| (r * 1000 + i) as i32).collect())
                .collect();
            let out = comm_for(&cache, p, *backend)
                .allgatherv(AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(*n))
                .map_err(|e| format!("comm error: {e}"))?;
            for r in 0..p {
                for j in 0..p {
                    if out.buffers[r][j] != inputs[j] {
                        return Err(format!("rank {r} root {j} mismatch"));
                    }
                }
            }
            Ok(())
        },
        |(counts, n, backend)| {
            let mut out = Vec::new();
            if *backend != BackendKind::Lockstep {
                out.push((counts.clone(), *n, BackendKind::Lockstep));
            }
            if counts.len() > 1 {
                out.push((counts[..counts.len() / 2 + 1].to_vec(), *n, *backend));
            }
            if *n > 1 {
                out.push((counts.clone(), n / 2, *backend));
            }
            out.push((counts.iter().map(|c| c / 2).collect(), *n, *backend));
            out
        },
    );
}

#[test]
fn prop_reduce_scatter_random_counts() {
    let cache = Arc::new(ScheduleCache::new());
    forall_shrink(
        120,
        |rng| {
            let p = rng.range(1, 20);
            let n = rng.range(1, 8);
            let counts: Vec<usize> = (0..p).map(|_| rng.range(0, 30)).collect();
            (counts, n, gen_backend(rng))
        },
        |(counts, n, backend)| {
            let p = counts.len();
            let total: usize = counts.iter().sum();
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..total).map(|i| ((r + 2) * (i + 1) % 500) as i64).collect())
                .collect();
            let sums: Vec<i64> =
                (0..total).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            let out = comm_for(&cache, p, *backend)
                .reduce_scatter(
                    ReduceScatterReq::new(&inputs, counts, Arc::new(SumOp))
                        .algo(Algo::Circulant)
                        .blocks(*n)
                        .elem_bytes(8),
                )
                .map_err(|e| format!("comm error: {e}"))?;
            let mut off = 0;
            for r in 0..p {
                if out.buffers[r] != sums[off..off + counts[r]] {
                    return Err(format!("rank {r} chunk wrong"));
                }
                off += counts[r];
            }
            Ok(())
        },
        |(counts, n, backend)| {
            let mut out = Vec::new();
            if *backend != BackendKind::Lockstep {
                out.push((counts.clone(), *n, BackendKind::Lockstep));
            }
            if counts.len() > 1 {
                out.push((counts[..counts.len() / 2 + 1].to_vec(), *n, *backend));
            }
            if *n > 1 {
                out.push((counts.clone(), n / 2, *backend));
            }
            out
        },
    );
}

#[test]
fn prop_allreduce_random() {
    let cache = Arc::new(ScheduleCache::new());
    forall_shrink(
        120,
        gen_case,
        |c| {
            if c.m == 0 {
                return Ok(()); // nothing to reduce
            }
            let inputs: Vec<Vec<i64>> = (0..c.p)
                .map(|r| (0..c.m).map(|i| ((r + 1) * (i + 1) % 333) as i64).collect())
                .collect();
            let want: Vec<i64> =
                (0..c.m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            let out = comm_for(&cache, c.p, c.backend)
                .allreduce(
                    AllreduceReq::new(&inputs, Arc::new(SumOp))
                        .algo(Algo::Circulant)
                        .blocks(c.n)
                        .elem_bytes(8),
                )
                .map_err(|e| format!("comm error: {e}"))?;
            for (r, buf) in out.buffers.iter().enumerate() {
                if buf != &want {
                    return Err(format!("rank {r} mismatch"));
                }
            }
            Ok(())
        },
        shrink_case,
    );
}

/// Run a mix as one batch on a fresh machine; return (per-op outcomes,
/// verified machine-round trace length).
fn run_mix_batched(mix: &TrafficMix, threads: usize) -> Result<(Vec<MixOutcome>, usize), String> {
    let comm = CommBuilder::new(mix.p).cost_model(UnitCost).build();
    let mut traffic = comm.traffic().threads(threads).record_trace(true);
    let mut handles = Vec::with_capacity(mix.ops.len());
    for op in &mix.ops {
        handles.push(submit_mix_op(&mut traffic, op).map_err(|e| format!("submit: {e}"))?);
    }
    let report = traffic.run().map_err(|e| format!("run: {e}"))?;
    let trace = report.trace.as_ref().expect("trace recording on");
    verify_one_ported_trace(mix.p, trace)
        .map_err(|v| format!("one-ported trace violated: {v:?}"))?;
    Ok((handles.into_iter().map(|h| h.take()).collect(), trace.len()))
}

#[test]
fn prop_traffic_respects_cross_op_port_discipline() {
    // The tentpole invariant as a property: whatever mix of kinds,
    // windows, sizes and arrival orders is thrown at the batch
    // scheduler, no machine round of the executed trace has any rank
    // sending twice or receiving twice — across ALL co-scheduled ops —
    // and the trace spans exactly the reported machine rounds.
    forall_shrink(
        40,
        |rng| {
            let p = rng.range(1, 28);
            let mix = traffic_mix(rng, p, rng.range(1, 6), &MixOptions::default());
            (mix, [1usize, 2, 8][rng.range(0, 2)])
        },
        |(mix, threads)| {
            let (outcomes, trace_rounds) = run_mix_batched(mix, *threads)?;
            if outcomes.iter().any(|o| matches!(o, MixOutcome::Failed(_))) {
                return Err("healthy mix op failed".into());
            }
            if trace_rounds == 0 && mix.ops.iter().any(|op| op.ranks(mix.p) > 1) {
                return Err("multi-rank ops executed in zero machine rounds".into());
            }
            Ok(())
        },
        |(mix, threads)| {
            let mut out = Vec::new();
            if mix.ops.len() > 1 {
                for i in 0..mix.ops.len() {
                    let mut ops = mix.ops.clone();
                    ops.remove(i);
                    out.push((TrafficMix { p: mix.p, ops }, *threads));
                }
            }
            if *threads != 1 {
                out.push((mix.clone(), 1));
            }
            out
        },
    );
}

#[test]
fn prop_arrival_order_permutation_invariance() {
    // Same mix, shuffled submission order ⇒ same per-op payloads and
    // statistics (each op's outcome is its own; only machine spans may
    // move with the schedule).
    forall_shrink(
        25,
        |rng| {
            let p = rng.range(2, 24);
            let n_ops = rng.range(2, 6);
            let mix = traffic_mix(rng, p, n_ops, &MixOptions::default());
            // A random permutation of 0..n_ops (Fisher–Yates).
            let mut perm: Vec<usize> = (0..mix.ops.len()).collect();
            for i in (1..perm.len()).rev() {
                perm.swap(i, rng.range(0, i));
            }
            (mix, perm)
        },
        |(mix, perm)| {
            let (base, _) = run_mix_batched(mix, 2)?;
            let shuffled = TrafficMix {
                p: mix.p,
                ops: perm.iter().map(|&i| mix.ops[i].clone()).collect(),
            };
            let (permuted, _) = run_mix_batched(&shuffled, 2)?;
            for (pos, &orig) in perm.iter().enumerate() {
                if permuted[pos] != base[orig] {
                    return Err(format!(
                        "op {orig} changed under permutation {perm:?}:\n  base:     {:?}\n  \
                         permuted: {:?}",
                        base[orig], permuted[pos]
                    ));
                }
            }
            Ok(())
        },
        |(mix, perm)| {
            let mut out = Vec::new();
            if mix.ops.len() > 2 {
                // Drop the last op (keeping the permutation valid by
                // dropping its index too).
                let keep = mix.ops.len() - 1;
                let ops: Vec<_> = mix.ops[..keep].to_vec();
                let perm: Vec<usize> = perm.iter().copied().filter(|&i| i < keep).collect();
                out.push((TrafficMix { p: mix.p, ops }, perm));
            }
            out
        },
    );
}

#[test]
fn prop_cache_never_recomputes_across_cases() {
    // After the random sweeps above the shared cache invariant holds on a
    // fresh cache too: total misses across arbitrary repeated traffic is
    // bounded by the number of distinct (p, rel) pairs ever requested.
    let cache = Arc::new(ScheduleCache::new());
    let mut distinct = std::collections::HashSet::new();
    let mut rng = Rng::from_env();
    for _ in 0..60 {
        let p = rng.range(1, 24);
        let root = rng.range(0, p - 1);
        let data: Vec<i64> = (0..50).collect();
        // Backend-independent invariant: the engine's schedule arena is
        // served through the same cache at service scale, so the miss
        // accounting is identical whichever backend handled the call.
        comm_for(&cache, p, gen_backend(&mut rng))
            .bcast(BcastReq::new(root, &data).algo(Algo::Circulant).blocks(3).elem_bytes(8))
            .unwrap();
        for rel in 0..p {
            distinct.insert((p, rel));
        }
    }
    let (hits, misses) = cache.stats();
    assert_eq!(misses as usize, distinct.len(), "one miss per distinct (p, rel)");
    assert!(hits > 0, "repeated traffic must hit the cache");
}
