//! The `Communicator` API contract: schedule reuse across repeated calls
//! and roots (with cache hit/miss receipts), result stability, degenerate
//! `p = 1` and nonzero-root cases through the typed interface, and
//! backend parity. (The legacy `*_sim` wrappers finished their
//! deprecation cycle and are gone; the typed API is the only entry.)

use std::sync::Arc;

use circulant_bcast::collectives::SumOp;
use circulant_bcast::comm::{
    Algo, AllgathervReq, AllreduceReq, BackendKind, BcastReq, CommBuilder, CommError,
    Communicator, Kind, Outcome, ReduceReq, ReduceScatterBlockReq, ReduceScatterReq,
};
use circulant_bcast::schedule::ScheduleCache;
use circulant_bcast::sim::UnitCost;

fn comm(p: usize) -> Communicator {
    CommBuilder::new(p).cost_model(UnitCost).build()
}

// -------------------------------------------------------------------
// Schedule reuse: the tentpole claim.
// -------------------------------------------------------------------

#[test]
fn repeated_bcasts_hit_the_cache_and_agree() {
    let p = 17usize;
    let c = comm(p);
    let data: Vec<i64> = (0..340).map(|i| i * 7 % 1009).collect();

    // Call 1 (root 0): populates the cache — one miss per relative rank.
    let first = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(5)).unwrap();
    let (h1, m1) = c.cache().stats();
    assert_eq!(m1 as usize, p, "first call misses once per relative rank");
    assert_eq!(h1, 0);

    // Call 2 (same root): identical results, zero new misses.
    let second = c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(5)).unwrap();
    assert_eq!(first.buffers, second.buffers);
    assert_eq!(first.stats.messages, second.stats.messages);
    assert_eq!(first.stats.bytes, second.stats.bytes);
    assert_eq!(first.rounds, second.rounds);
    let (h2, m2) = c.cache().stats();
    assert_eq!(m2, m1, "repeat call must not recompute schedules");
    assert_eq!(h2 as usize, p);

    // Calls at every *other* root: schedules are root-relative, so the
    // same p cache entries serve all of them — still zero new misses.
    for root in 1..p {
        let out = c.bcast(BcastReq::new(root, &data).algo(Algo::Circulant).blocks(5)).unwrap();
        assert!(out.all_received());
        assert!(out.buffers.iter().all(|b| b == &data), "root {root}");
        assert_eq!(out.rounds, first.rounds, "root {root}");
    }
    let (h3, m3) = c.cache().stats();
    assert_eq!(m3, m1, "varying roots must not recompute schedules");
    assert_eq!(h3 as usize, p * p, "every root-sweep call fully cache-served");
}

#[test]
fn hit_counter_grows_monotonically_across_collectives() {
    // One handle, all collectives: every call after the first is pure
    // cache traffic (bcast/reduce use per-rank phased schedules; the
    // all-collectives build their table from the same entries).
    let p = 9usize;
    let c = comm(p);
    let data: Vec<i64> = (0..90).collect();
    let inputs: Vec<Vec<i64>> = (0..p).map(|_| data.clone()).collect();
    let counts = vec![10usize; p];

    c.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(3)).unwrap();
    let (_, misses) = c.cache().stats();
    assert_eq!(misses as usize, p);

    c.reduce(ReduceReq::new(4, &inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(3))
        .unwrap();
    let (hits, m) = c.cache().stats();
    assert_eq!(m as usize, p, "reduce reuses bcast's schedules");
    assert!(hits > 0);
    let last_hits = hits;

    c.allgatherv(AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(2)).unwrap();
    let (hits, m) = c.cache().stats();
    assert_eq!(m as usize, p, "allgatherv reuses the same relative-rank entries");
    assert!(hits > last_hits, "the n=2 table is built from cached schedules");
    let last_hits = hits;

    c.reduce_scatter(
        ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp)).algo(Algo::Circulant).blocks(2),
    )
    .unwrap();
    c.allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(2))
        .unwrap();
    let (hits, m) = c.cache().stats();
    assert_eq!(m as usize, p, "one schedule family serves all four collectives");
    // The n=2 ScheduleTable is memoized on the handle, so reduce_scatter
    // and allreduce recompute nothing — not even cache lookups.
    assert_eq!(hits, last_hits, "memoized table: zero additional schedule work");
}

#[test]
fn shared_cache_across_communicators() {
    // Two communicators over the same cache (the service pattern): the
    // second sees a warm cache even for its first call.
    let cache = Arc::new(ScheduleCache::new());
    let data: Vec<i32> = (0..60).collect();
    let a = CommBuilder::new(13).cache(cache.clone()).cost_model(UnitCost).build();
    a.bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(4)).unwrap();
    let (_, misses) = cache.stats();
    let b = CommBuilder::new(13).cache(cache.clone()).cost_model(UnitCost).build();
    b.bcast(BcastReq::new(7, &data).algo(Algo::Circulant).blocks(4)).unwrap();
    let (hits, misses2) = cache.stats();
    assert_eq!(misses2, misses, "second communicator inherits warm cache");
    assert!(hits >= 13);
}

// -------------------------------------------------------------------
// Degenerate and nonzero-root cases through the typed API.
// -------------------------------------------------------------------

#[test]
fn reduce_nonzero_roots_all_p() {
    for p in [1usize, 5, 9, 18] {
        let c = comm(p);
        let m = 33usize;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| (r * 100 + i) as i64).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        for root in 0..p {
            let out = c
                .reduce(
                    ReduceReq::new(root, &inputs, Arc::new(SumOp))
                        .algo(Algo::Circulant)
                        .blocks(4),
                )
                .unwrap();
            assert_eq!(out.buffers, expect, "p={p} root={root}");
            assert!(out.complete);
        }
    }
}

#[test]
fn reduce_p1_is_identity() {
    let c = comm(1);
    let inputs = vec![vec![5i64, -3, 8]];
    let out = c.reduce(ReduceReq::new(0, &inputs, Arc::new(SumOp))).unwrap();
    assert_eq!(out.buffers, inputs[0]);
    assert_eq!(out.rounds, 0);
    assert_eq!(out.stats.messages, 0);
}

#[test]
fn reduce_scatter_p1_and_degenerate_counts() {
    // p = 1: the single rank keeps its (fully "reduced") chunk.
    let c = comm(1);
    let inputs = vec![vec![4i64, 4, 4, 4]];
    let out = c
        .reduce_scatter(ReduceScatterReq::new(&inputs, &[4], Arc::new(SumOp)))
        .unwrap();
    assert_eq!(out.buffers, vec![vec![4i64, 4, 4, 4]]);
    assert_eq!(out.rounds, 0);

    // Degenerate counts: one destination owns everything, others nothing.
    let p = 7usize;
    let c = comm(p);
    let mut counts = vec![0usize; p];
    counts[3] = 21;
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..21).map(|i| (r + i) as i64).collect()).collect();
    let sums: Vec<i64> = (0..21).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
    let out = c
        .reduce_scatter(
            ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(3),
        )
        .unwrap();
    for (r, chunk) in out.buffers.iter().enumerate() {
        if r == 3 {
            assert_eq!(chunk, &sums);
        } else {
            assert!(chunk.is_empty(), "rank {r}");
        }
    }
}

#[test]
fn bcast_p1_and_empty_payloads() {
    let c = comm(1);
    let data = vec![9i32; 5];
    let out = c.bcast(BcastReq::new(0, &data)).unwrap();
    assert_eq!(out.buffers, vec![data.clone()]);
    assert_eq!(out.rounds, 0);

    // Zero-length payload over many ranks: still well-formed.
    let c = comm(9);
    let empty: Vec<i32> = Vec::new();
    let out = c.bcast(BcastReq::new(2, &empty).algo(Algo::Circulant).blocks(4)).unwrap();
    assert!(out.all_received());
    assert!(out.buffers.iter().all(|b| b.is_empty()));
}

// -------------------------------------------------------------------
// Uniform Outcome + error surface.
// -------------------------------------------------------------------

#[test]
fn outcome_is_uniform_across_collectives() {
    fn check<B>(out: &Outcome<B>) {
        assert!(out.all_received());
        assert_eq!(out.rounds, out.stats.rounds);
        assert_ne!(out.algo, Algo::Auto, "outcome always carries the resolved algo");
    }
    let p = 9usize;
    let c = comm(p);
    let data: Vec<i64> = (0..45).collect();
    let inputs: Vec<Vec<i64>> = (0..p).map(|_| data.clone()).collect();
    check(&c.bcast(BcastReq::new(0, &data)).unwrap());
    check(&c.reduce(ReduceReq::new(0, &inputs, Arc::new(SumOp))).unwrap());
    check(&c.allgatherv(AllgathervReq::new(&inputs)).unwrap());
    check(&c.allgather(AllgathervReq::new(&inputs)).unwrap());
    check(
        &c.reduce_scatter_block(ReduceScatterBlockReq::new(&inputs, 5, Arc::new(SumOp)))
            .unwrap(),
    );
    // Allreduce aggregates both phases; rounds still equals stats.rounds.
    check(&c.allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp))).unwrap());
}

#[test]
fn error_surface_is_typed() {
    let c = comm(4);
    let data = vec![1i32; 8];
    let inputs: Vec<Vec<i64>> = (0..4).map(|_| vec![1i64; 8]).collect();
    // Out-of-range root.
    assert!(matches!(c.bcast(BcastReq::new(9, &data)), Err(CommError::BadRequest(_))));
    // Unsupported algorithm for the kind.
    match c.allgatherv(AllgathervReq::new(&inputs).algo(Algo::VanDeGeijn)) {
        Err(CommError::Unsupported { kind, algo }) => {
            assert_eq!(kind, Kind::Allgatherv);
            assert_eq!(algo, Algo::VanDeGeijn);
        }
        other => panic!("expected Unsupported, got {:?}", other.map(|o| o.rounds)),
    }
    // Recursive halving demands equal chunks.
    let counts = [3usize, 5, 0, 0];
    let rs_inputs: Vec<Vec<i64>> = (0..4).map(|_| vec![1i64; 8]).collect();
    assert!(matches!(
        c.reduce_scatter(
            ReduceScatterReq::new(&rs_inputs, &counts, Arc::new(SumOp))
                .algo(Algo::RecursiveHalving)
        ),
        Err(CommError::BadRequest(_))
    ));
}

// -------------------------------------------------------------------
// Backend parity through the public API.
// -------------------------------------------------------------------

#[test]
fn threaded_backend_full_parity_on_reduce_scatter() {
    let p = 8usize;
    let chunk = 6usize;
    let inputs: Vec<Vec<i64>> = (0..p)
        .map(|r| (0..p * chunk).map(|i| ((r + 1) * (i + 1)) as i64 % 251).collect())
        .collect();
    let mk = || {
        ReduceScatterBlockReq::new(&inputs, chunk, Arc::new(SumOp))
            .algo(Algo::Circulant)
            .blocks(2)
    };
    let lockstep = comm(p).reduce_scatter_block(mk()).unwrap();
    let threaded = CommBuilder::new(p)
        .cost_model(UnitCost)
        .backend(BackendKind::Threaded)
        .build()
        .reduce_scatter_block(mk())
        .unwrap();
    assert_eq!(lockstep.buffers, threaded.buffers);
    assert_eq!(lockstep.stats.messages, threaded.stats.messages);
    assert_eq!(lockstep.stats.bytes, threaded.stats.bytes);
    assert!((lockstep.stats.time - threaded.stats.time).abs() < 1e-12);
}

// -------------------------------------------------------------------
// SPMD backend through the public API.
// -------------------------------------------------------------------

#[test]
fn spmd_backend_full_parity_on_bcast_and_reduce() {
    let p = 11usize;
    let data: Vec<i64> = (0..121).collect();
    let spmd = || CommBuilder::new(p).cost_model(UnitCost).backend(BackendKind::Spmd).build();
    let a = comm(p)
        .bcast(BcastReq::new(4, &data).algo(Algo::Circulant).blocks(5).elem_bytes(8))
        .unwrap();
    let b = spmd()
        .bcast(BcastReq::new(4, &data).algo(Algo::Circulant).blocks(5).elem_bytes(8))
        .unwrap();
    assert_eq!(a.buffers, b.buffers);
    assert_eq!(a.stats.messages, b.stats.messages);
    assert_eq!(a.stats.bytes, b.stats.bytes);
    assert_eq!(a.rounds, b.rounds);
    assert!(b.all_received());

    let inputs: Vec<Vec<i64>> = (0..p).map(|_| data.clone()).collect();
    let ra = comm(p)
        .reduce(
            ReduceReq::new(4, &inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(5)
                .elem_bytes(8),
        )
        .unwrap();
    let rb = spmd()
        .reduce(
            ReduceReq::new(4, &inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(5)
                .elem_bytes(8),
        )
        .unwrap();
    assert_eq!(ra.buffers, rb.buffers);
    assert_eq!(ra.stats.messages, rb.stats.messages);
}
