//! The uniform result type of every [`super::Communicator`] collective,
//! and the unified error type.

use crate::sim::network::{RunStats, SimError};

use super::request::{Algo, Kind};
use super::transport::TransportError;

/// What every collective returns: run statistics, the result buffers
/// (shape depends on the collective — see each method's docs), the
/// algorithm that actually ran (after [`Algo::Auto`] resolution) and the
/// executed round count.
#[derive(Debug, Clone)]
pub struct Outcome<B> {
    pub stats: RunStats,
    pub buffers: B,
    /// The resolved algorithm (never [`Algo::Auto`]).
    pub algo: Algo,
    /// Rounds executed (`stats.rounds`; for all-reduce the sum over both
    /// phases).
    pub rounds: usize,
    /// True iff every rank finished with every block it was due — the
    /// per-rank completion check (collectives whose state machines cannot
    /// assemble an incomplete result return
    /// [`CommError::Incomplete`] instead of a `false` flag).
    pub complete: bool,
    /// Per-op machine-round accounting of the traffic plane: the
    /// `(first, last)` *machine* rounds this operation was scheduled in
    /// when executed as part of a batch
    /// ([`crate::comm::traffic::TrafficEngine`]). `None` for blocking
    /// calls, and for batched operations that needed no rounds at all
    /// (`p = 1` windows). Everything else in an `Outcome` — payloads,
    /// statistics, rounds, errors — is in the operation's own (local)
    /// frame and bit-identical to a sequential run; only this field
    /// records where the batch scheduler placed the op.
    pub machine_span: Option<(usize, usize)>,
}

impl<B> Outcome<B> {
    /// Per-rank completion of the whole collective. Unlike the
    /// long-removed legacy `BcastResult::all_received` (which only
    /// checked that *some* buffers existed), this reflects the actual
    /// per-rank block bookkeeping.
    pub fn all_received(&self) -> bool {
        self.complete
    }

    /// Simulated completion time under the run's cost model, seconds.
    pub fn time(&self) -> f64 {
        self.stats.time
    }
}

/// Per-tenant usage accounting of one traffic-plane batch.
///
/// The collective service daemon ([`crate::service`]) tags every job it
/// admits with the submitting client's tenant label; the traffic engine
/// folds the per-op message/byte counters into one row per tenant and
/// reports them on [`crate::comm::BatchReport::tenants`]. Admission
/// rejections never reach the engine, so the daemon folds those in after
/// the batch via `BatchReport::note_rejected`. Untagged (library-level)
/// submissions carry no tenant and produce no row.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantUsage {
    /// The tenant label from the client handshake.
    pub tenant: String,
    /// Operations admitted into the batch for this tenant.
    pub ops: usize,
    /// Of those, operations that finished complete and error-free.
    pub ok: usize,
    /// Point-to-point messages sent on behalf of this tenant.
    pub messages: usize,
    /// Payload bytes moved on behalf of this tenant.
    pub bytes: usize,
    /// Requests refused at admission (queue saturated) — folded in by
    /// the daemon, not the engine.
    pub rejected: usize,
    /// Operations that were disrupted by a membership change (a rank
    /// died in the daemon's world) and re-admitted onto the rebuilt,
    /// shrunken communicator — folded in by the daemon's recovery path,
    /// not the engine. A restarted op is billed here *and* in
    /// [`TenantUsage::ops`] when it eventually runs.
    pub restarted: usize,
}

/// Reliable-delivery counters of a healing wire layer
/// ([`crate::comm::SocketTransport`]'s protocol-v3 seq/ack/CRC
/// machinery). These count *transient* faults that were absorbed in
/// place — deliberately kept **out** of [`RunStats`]/[`Outcome`], so a
/// run over a lossy wire stays bit-identical to a fault-free run (the
/// differential chaos grid pins exactly that). Surfaced instead via
/// [`crate::comm::Transport::wire_faults`] and the service plane's
/// metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireFaults {
    /// DATA frames re-sent because no cumulative ACK covered them
    /// within the retransmission timeout.
    pub retransmits: u64,
    /// Received frames discarded by the per-link dedup window
    /// (duplicated by the wire, or a retransmit whose original won).
    pub dup_drops: u64,
    /// Received frames discarded because their CRC32 trailer did not
    /// match — healed by the sender's retransmission, not a poison.
    pub crc_fails: u64,
    /// Peers declared crashed after the retry budget was exhausted —
    /// the hand-off from the healing layer to the
    /// [`crate::comm::Membership`] shrink path.
    pub escalations: u64,
}

impl WireFaults {
    /// Did the wire layer see (and absorb or escalate) anything?
    pub fn any(&self) -> bool {
        self.retransmits + self.dup_drops + self.crc_fails + self.escalations > 0
    }

    /// Fold another endpoint's counters into this accumulator.
    pub fn merge(&mut self, other: &WireFaults) {
        self.retransmits += other.retransmits;
        self.dup_drops += other.dup_drops;
        self.crc_fails += other.crc_fails;
        self.escalations += other.escalations;
    }
}

impl std::fmt::Display for WireFaults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retransmits={} dup_drops={} crc_fails={} escalations={}",
            self.retransmits, self.dup_drops, self.crc_fails, self.escalations
        )
    }
}

/// Unified error type of the `comm` layer.
#[derive(Debug)]
pub enum CommError {
    /// The machine model was violated mid-run — a broken schedule.
    Sim(SimError),
    /// The (kind, algorithm) combination is not implemented.
    Unsupported { kind: Kind, algo: Algo },
    /// The request is malformed (wrong lengths, out-of-range root, …).
    BadRequest(String),
    /// A rank ended the run missing blocks (per-rank completion check).
    Incomplete { kind: Kind, rank: usize },
    /// The SPMD rank plane's transport failed: a machine-model
    /// violation surfaced by a [`crate::comm::Transport`] (in the same
    /// [`SimError`] vocabulary as [`CommError::Sim`]), a round-discipline
    /// misuse, a shutdown echo, or a timeout.
    Transport(TransportError),
    /// Ranks died and the world **shrank** instead of terminating: the
    /// recovery plane ([`crate::comm::membership`]) detected the listed
    /// `failed` ranks, the `survivors` rebuilt a smaller world under the
    /// new `epoch`, but the requested operation could not be completed
    /// within its shrink budget (or vanished with the failures, e.g. a
    /// window whose every rank died). Unlike every other variant this is
    /// not a terminal machine fault — the caller can retry on the
    /// survivors' world. All ranks are **original-world** (epoch-0
    /// global) ids.
    MembershipChanged { epoch: u64, failed: Vec<usize>, survivors: Vec<usize> },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Sim(e) => write!(f, "machine-model violation: {e}"),
            CommError::Unsupported { kind, algo } => {
                write!(f, "unsupported combination: {kind:?} with {algo:?}")
            }
            CommError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            CommError::Incomplete { kind, rank } => {
                write!(f, "{kind:?}: rank {rank} finished incomplete (missing blocks)")
            }
            CommError::Transport(e) => write!(f, "rank-plane transport failure: {e}"),
            CommError::MembershipChanged { epoch, failed, survivors } => write!(
                f,
                "membership changed (epoch {epoch}): ranks {failed:?} failed, \
                 {} survivors remain",
                survivors.len()
            ),
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Sim(e) => Some(e),
            CommError::Transport(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SimError> for CommError {
    fn from(e: SimError) -> Self {
        CommError::Sim(e)
    }
}

impl From<TransportError> for CommError {
    fn from(e: TransportError) -> Self {
        CommError::Transport(e)
    }
}
