//! The chaos plane: deterministic, replayable wire-fault injection.
//!
//! A [`FaultPlan`] is a seeded, *stateless* fault oracle: for every
//! `(src, dst, frame-index)` triple it returns the same [`Verdict`] —
//! deliver, drop, duplicate, reorder, delay, or corrupt-k-bits — so an
//! entire fault sequence is replayable from a single `TESTKIT_SEED`.
//! No RNG state is threaded through the transport; the verdict is a
//! pure hash of `(seed, link, index)`, which is what makes the
//! differential chaos grid in `tests/chaos.rs` deterministic across
//! threads, processes and reruns.
//!
//! Two injection points consume a plan:
//!
//! * **Byte level** — [`super::socket::SocketTransport`] threads the
//!   plan into each link's raw write path (see
//!   [`super::socket::SocketTransport::pair_world_chaos`] and
//!   [`super::rank::TransportKind::ChaosSocket`]). Every verdict is
//!   expressible there: duplicated, reordered and bit-flipped frames
//!   hit the wire for real, and the v3 reliable-delivery layer
//!   (CRC + seq/ack + retransmission) is what heals them.
//! * **Verb level** — [`ChaosTransport`] wraps *any*
//!   [`Transport`]. Verbs have no bytes to corrupt, so only the
//!   verb-expressible subset applies (drop = swallow the send,
//!   delay = sleep); the rest deliver unchanged. In-process transports
//!   have no healing layer underneath, so a dropped verb surfaces as
//!   the receiver's timeout — useful for failure-path tests, not for
//!   parity.
//!
//! Faults are injected on the *send* side only and never touch the
//! control frames (`HELLO`/`BYE`/`ABORT`): chaos models a lossy wire
//! under an established link, not a hostile rendezvous (that is
//! `tests/wire_failures.rs` territory).

use std::time::Duration;

use super::outcome::WireFaults;
use super::transport::{Transport, TransportError};

/// The fault-rate denominator: all rates are per-10 000 frames.
const DENOM: u64 = 10_000;

/// What the chaos plane does to one frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Pass the frame through untouched.
    Deliver,
    /// Swallow the frame; it never reaches the wire.
    Drop,
    /// Emit the frame twice back to back.
    Duplicate,
    /// Hold the frame and emit it *after* the link's next frame
    /// (reorder-within-window, window = 1).
    Reorder,
    /// Emit the frame after sleeping this long.
    Delay(Duration),
    /// Flip `bits` bits at `entropy`-derived offsets in the frame
    /// body (never the length prefix — corrupting the length would
    /// desync the byte stream, which no checksum can heal).
    Corrupt { bits: u32, entropy: u64 },
}

/// A seeded, deterministic fault plan: per-(link, frame-index)
/// verdicts with configurable per-10k rates. `Copy` and comparable so
/// it can ride inside [`super::rank::TransportKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    drop: u32,
    dup: u32,
    reorder: u32,
    delay: u32,
    delay_max_ms: u32,
    corrupt: u32,
    corrupt_bits: u32,
    /// A rank whose every link drops everything, both directions —
    /// the "provably gone" peer that must exhaust the retry budget
    /// and escalate into the membership shrink path.
    blackhole: Option<usize>,
}

impl FaultPlan {
    /// A quiet plan: every verdict is `Deliver` until rates are added
    /// with the builder methods.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            drop: 0,
            dup: 0,
            reorder: 0,
            delay: 0,
            delay_max_ms: 0,
            corrupt: 0,
            corrupt_bits: 1,
            blackhole: None,
        }
    }

    /// Drop `n` frames per 10k.
    pub fn drop_per_10k(mut self, n: u32) -> FaultPlan {
        self.drop = n;
        self.check()
    }

    /// Duplicate `n` frames per 10k.
    pub fn dup_per_10k(mut self, n: u32) -> FaultPlan {
        self.dup = n;
        self.check()
    }

    /// Reorder `n` frames per 10k (held past the link's next frame).
    pub fn reorder_per_10k(mut self, n: u32) -> FaultPlan {
        self.reorder = n;
        self.check()
    }

    /// Delay `n` frames per 10k by up to `max_ms` milliseconds.
    pub fn delay_per_10k(mut self, n: u32, max_ms: u32) -> FaultPlan {
        self.delay = n;
        self.delay_max_ms = max_ms;
        self.check()
    }

    /// Corrupt `n` frames per 10k by flipping `bits` bits each.
    pub fn corrupt_per_10k(mut self, n: u32, bits: u32) -> FaultPlan {
        self.corrupt = n;
        self.corrupt_bits = bits.max(1);
        self.check()
    }

    /// Drop *everything* on every link touching `rank` — the
    /// unreachable-peer scenario that must escalate into a shrink.
    pub fn blackhole(mut self, rank: usize) -> FaultPlan {
        self.blackhole = Some(rank);
        self
    }

    /// The seed the verdicts hash from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Does this plan ever inject anything?
    pub fn is_active(&self) -> bool {
        self.drop + self.dup + self.reorder + self.delay + self.corrupt > 0
            || self.blackhole.is_some()
    }

    fn check(self) -> FaultPlan {
        let total =
            u64::from(self.drop + self.dup + self.reorder + self.delay + self.corrupt);
        assert!(
            total <= DENOM,
            "fault rates sum to {total} per 10k (more than every frame)"
        );
        self
    }

    /// The verdict for the `frame_idx`-th frame on the `src -> dst`
    /// link. Pure: same plan, same triple, same verdict.
    pub fn verdict(&self, src: usize, dst: usize, frame_idx: u64) -> Verdict {
        if let Some(v) = self.blackhole {
            if src == v || dst == v {
                return Verdict::Drop;
            }
        }
        let link = ((src as u64) << 32) | (dst as u64 & 0xFFFF_FFFF);
        let h = mix64(
            self.seed
                .wrapping_add(mix64(link))
                .wrapping_add(frame_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        let draw = (h % DENOM) as u32;
        let mut edge = self.drop;
        if draw < edge {
            return Verdict::Drop;
        }
        edge += self.dup;
        if draw < edge {
            return Verdict::Duplicate;
        }
        edge += self.reorder;
        if draw < edge {
            return Verdict::Reorder;
        }
        edge += self.delay;
        if draw < edge {
            let ms = (h >> 32) % u64::from(self.delay_max_ms).max(1);
            return Verdict::Delay(Duration::from_millis(ms));
        }
        edge += self.corrupt;
        if draw < edge {
            return Verdict::Corrupt { bits: self.corrupt_bits, entropy: h };
        }
        Verdict::Deliver
    }
}

/// splitmix64 finalizer — the same mixer testkit's generators build
/// on, good enough to decorrelate (seed, link, index) triples.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Verb-level chaos: wraps any [`Transport`] and applies the
/// verb-expressible subset of a [`FaultPlan`] to `send` — `Drop`
/// swallows the send, `Delay` sleeps first, everything else delivers
/// (verbs carry no bytes to duplicate, reorder or corrupt; those
/// verdicts only exist under [`super::socket::SocketTransport`]'s
/// byte-level shim, where the reliable-delivery layer heals them).
pub struct ChaosTransport<Tr> {
    inner: Tr,
    plan: FaultPlan,
    /// Per-peer frame-index cursors, so verdicts line up with the
    /// plan's per-link sequences.
    sent: Vec<u64>,
    injected: u64,
}

impl<Tr> ChaosTransport<Tr> {
    pub fn new<T>(inner: Tr, plan: FaultPlan) -> ChaosTransport<Tr>
    where
        Tr: Transport<T>,
    {
        let p = inner.p();
        ChaosTransport { inner, plan, sent: vec![0; p], injected: 0 }
    }

    /// How many verdicts actually changed behaviour (drops + delays).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    pub fn into_inner(self) -> Tr {
        self.inner
    }
}

impl<T, Tr: Transport<T>> Transport<T> for ChaosTransport<Tr> {
    fn p(&self) -> usize {
        self.inner.p()
    }

    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        // Invalid targets bypass chaos so the machine-model errors
        // (self-message, bad target, discipline) stay exact.
        if peer >= self.inner.p() || peer == self.inner.rank() {
            return self.inner.send(round, peer, data);
        }
        let idx = self.sent[peer];
        self.sent[peer] += 1;
        match self.plan.verdict(self.inner.rank(), peer, idx) {
            Verdict::Drop => {
                self.injected += 1;
                Ok(())
            }
            Verdict::Delay(d) => {
                self.injected += 1;
                std::thread::sleep(d.min(Duration::from_millis(50)));
                self.inner.send(round, peer, data)
            }
            _ => self.inner.send(round, peer, data),
        }
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        self.inner.flush(round)
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        self.inner.recv(round, peer)
    }

    fn failed_peers(&self) -> Vec<usize> {
        self.inner.failed_peers()
    }

    fn wire_faults(&self) -> Option<WireFaults> {
        self.inner.wire_faults()
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        self.inner.close(error)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::ThreadTransport;

    #[test]
    fn verdicts_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::new(7).drop_per_10k(2_000).corrupt_per_10k(1_000, 2);
        let b = FaultPlan::new(7).drop_per_10k(2_000).corrupt_per_10k(1_000, 2);
        let c = FaultPlan::new(8).drop_per_10k(2_000).corrupt_per_10k(1_000, 2);
        let seq = |p: &FaultPlan| (0..200).map(|i| p.verdict(1, 2, i)).collect::<Vec<_>>();
        assert_eq!(seq(&a), seq(&b), "same seed, same plan, same verdicts");
        assert_ne!(seq(&a), seq(&c), "a different seed draws differently");
        assert_ne!(
            seq(&a),
            (0..200).map(|i| a.verdict(2, 1, i)).collect::<Vec<_>>(),
            "each link direction draws independently"
        );
    }

    #[test]
    fn rates_land_near_their_nominal_values() {
        let plan = FaultPlan::new(42).drop_per_10k(1_000);
        let drops = (0..10_000u64)
            .filter(|&i| plan.verdict(0, 1, i) == Verdict::Drop)
            .count();
        assert!(
            (800..1_200).contains(&drops),
            "10% nominal drew {drops} drops in 10k frames"
        );
    }

    #[test]
    fn a_quiet_plan_delivers_everything() {
        let plan = FaultPlan::new(1);
        assert!(!plan.is_active());
        assert!((0..1_000).all(|i| plan.verdict(3, 4, i) == Verdict::Deliver));
    }

    #[test]
    fn blackhole_swallows_both_directions() {
        let plan = FaultPlan::new(9).blackhole(2);
        assert!(plan.is_active());
        assert_eq!(plan.verdict(2, 0, 5), Verdict::Drop);
        assert_eq!(plan.verdict(1, 2, 5), Verdict::Drop);
        assert_eq!(plan.verdict(0, 1, 5), Verdict::Deliver);
    }

    #[test]
    fn corrupt_verdicts_carry_the_requested_bit_count() {
        let plan = FaultPlan::new(3).corrupt_per_10k(10_000, 3);
        match plan.verdict(0, 1, 0) {
            Verdict::Corrupt { bits, .. } => assert_eq!(bits, 3),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn dropped_verbs_surface_as_the_receivers_timeout() {
        let mut w =
            ThreadTransport::<i64>::world_with_timeout(2, Duration::from_millis(100));
        let t1 = w.pop().unwrap();
        let t0 = w.pop().unwrap();
        let mut c0 = ChaosTransport::new(t0, FaultPlan::new(5).drop_per_10k(10_000));
        let h = std::thread::spawn(move || {
            let mut t1 = t1;
            t1.recv(0, 0)
        });
        c0.send(0, 1, vec![7i64]).unwrap(); // swallowed
        c0.flush(0).unwrap();
        assert_eq!(c0.injected(), 1);
        let e = h.join().unwrap().unwrap_err();
        assert!(
            matches!(e, TransportError::Timeout { .. }),
            "no healing layer under a verb-level drop: {e:?}"
        );
    }
}
