//! The unified `Communicator` API — one typed, schedule-caching entry
//! point for all of the paper's collectives.
//!
//! The paper's central observation (Observation 1) is that *one* schedule
//! family — the O(log p) circulant-graph send/receive schedules — serves
//! broadcast, all-broadcast, reduction and all-reduction alike. This
//! module gives that observation an API: a [`Communicator`] is a
//! persistent, MPI-communicator-style handle built once per `p` (via
//! [`CommBuilder`]) that owns
//!
//! * the circulant skip table ([`crate::schedule::Skips`], shared `Arc`),
//! * a shared [`crate::schedule::ScheduleCache`] holding one
//!   parallel-built all-ranks [`crate::schedule::ScheduleTable`] per `p`,
//!   so repeated calls — and calls with *different roots*, since
//!   schedules are root-relative — reuse the one flat schedule plane
//!   instead of recomputing anything,
//! * a pluggable execution backend ([`ExecBackend`]: the lockstep
//!   round-based [`crate::sim::Network`] simulator, the
//!   [`crate::sim::threads`] runtime where every rank is an OS thread, or
//!   the sparse million-rank [`crate::sim::engine`] — circulant
//!   broadcast/reduce run on the engine's active-set/arena fast path,
//!   every other (kind, algorithm) combination on the lockstep driver),
//! * a default [`crate::sim::CostModel`] and [`TuningParams`] for the
//!   paper's block-count rules.
//!
//! Every collective takes a typed request ([`BcastReq`], [`ReduceReq`],
//! [`AllgathervReq`], [`ReduceScatterReq`], [`ReduceScatterBlockReq`],
//! [`AllreduceReq`]) carrying the root, the data, an optional block-count
//! override and an [`Algo`] selection (with an [`Algo::Auto`] variant that
//! reuses the `tuning::*` block-count models), and every collective
//! returns the same uniform [`Outcome`] — run statistics, result buffers,
//! the resolved algorithm and the round count.
//!
//! ```no_run
//! use circulant_bcast::comm::{BcastReq, Communicator};
//!
//! let comm = Communicator::new(17);            // once per p
//! let data: Vec<i64> = (0..1000).collect();
//! let out = comm.bcast(BcastReq::new(0, &data)).unwrap();   // many calls
//! assert!(out.all_received());
//! assert_eq!(out.buffers[5], data);
//! ```
//!
//! (The legacy `*_sim` free functions that once wrapped a throwaway
//! `Communicator` completed their deprecation cycle and are gone; build
//! one handle and keep it.)
//!
//! ## The rank plane
//!
//! The `Communicator` is a *god view*: one caller owns every rank's
//! buffers. The paper's programming model is the opposite — each
//! processor computes its own O(log p) schedule independently, with no
//! communication — and the SPMD rank plane gives it an API:
//! [`RankComm`] is a per-rank handle (built from `(p, r)` + a shared
//! `Arc<Skips>`) exposing rank-local `bcast`/`reduce`/`allgatherv`/
//! `reduce_scatter`/`allreduce` over caller-owned `&mut [T]` buffers,
//! driven round by round through a pluggable [`Transport`]:
//! [`ThreadTransport`] (a real one-thread-per-rank runtime with
//! mutex/condvar mailboxes) or [`LoopbackTransport`] (a lockstep
//! round-barrier replay with the full machine-model check set). The
//! god view is one client of the same plane:
//! [`BackendKind::Spmd`] fans each circulant request out to `p`
//! `RankComm`s over `ThreadTransport` and reassembles the usual
//! [`Outcome`] — bit-identical to the lockstep backend
//! (`tests/spmd_parity.rs`). See [`rank`] and [`transport`].
//!
//! ## The wire plane
//!
//! [`SocketTransport`] carries the same rank plane across real OS
//! sockets: length-prefixed frames over Unix-domain socketpairs
//! in-process ([`SocketTransport::pair_world`]) or over UDS/TCP
//! rendezvous between processes, with a versioned handshake pinning
//! `(p, rank, world_id)` and wire faults mapped into the same
//! [`TransportError`] vocabulary. Protocol v3 layers reliable
//! delivery underneath — CRC32-trailed frames, per-link seq/ack,
//! retransmission with capped backoff, a dedup window — so transient
//! wire faults heal in place and only a provably-gone peer escalates
//! to the recovery plane; the deterministic [`chaos`] plane
//! ([`FaultPlan`], [`ChaosTransport`],
//! [`SocketTransport::pair_world_chaos`]) injects replayable fault
//! sequences to pin exactly that. [`BackendKind::Socket`] runs the
//! god-view API on top of it — still bit-identical to lockstep — and
//! [`crate::service`] builds a long-lived collective daemon over the
//! same framing. See [`socket`].
//!
//! ## The recovery plane
//!
//! When a rank **dies** mid-collective the world no longer terminates
//! the computation: the surviving transports detect the crash
//! ([`Transport::failed_peers`] — a wait-chain-walking suspicion board
//! on [`ThreadTransport`], EOF-without-farewell link accounting on
//! [`SocketTransport`]), the survivors agree on the shrunken rank set
//! with **no coordinator** (the detectors are world-shared /
//! full-mesh-symmetric by construction), and an epoch-stamped
//! [`Membership`] renumbers them densely so each survivor rebuilds its
//! O(log p) schedule rows locally — the paper's communication-free
//! schedule computation is exactly what makes the shrink cheap.
//! Affected operations restart on the rebuilt world (a dead root is
//! replaced by the lowest surviving rank) and the event surfaces as
//! [`CommError::MembershipChanged`]. See [`membership`] for the
//! elastic driver, [`CrashAfter`] fault injection, and the recovery
//! guarantee pinned by `tests/recovery.rs`: the surviving world's
//! payloads are bit-identical to a fresh run at the shrunken size.
//!
//! ## The traffic plane
//!
//! Beyond one blocking collective at a time, a communicator serves
//! *workloads*: [`Communicator::traffic`] opens a batch, the typed
//! nonblocking requests ([`IbcastReq`], [`IreduceReq`],
//! [`IallgathervReq`], [`IreduceScatterReq`], [`IallreduceReq`] — each
//! optionally restricted to a rank [`Window`]) submit into it returning
//! [`Pending`] handles, and [`TrafficEngine::run`] executes the whole
//! batch overlapped: disjoint-window operations run truly concurrently,
//! rank-sharing operations round-interleave under a cross-operation
//! port ledger that preserves the paper's one-ported discipline. Every
//! batched operation's [`Outcome`] is bit-identical to running it alone
//! — see [`traffic`] for the model and guarantees.

pub mod backend;
pub mod chaos;
pub mod communicator;
pub mod membership;
pub mod nonblocking;
pub mod outcome;
pub mod rank;
pub mod request;
pub mod socket;
pub mod traffic;
pub mod transport;

pub use backend::{
    build_procs, BackendKind, EngineBackend, ExecBackend, LockstepBackend, SocketBackend,
    SpmdBackend, ThreadedBackend,
};
pub use chaos::{ChaosTransport, FaultPlan, Verdict};
pub use membership::{
    elastic_bcast, elastic_reduce, suspect_of, CrashAfter, CrashPlan, ElasticReport,
    Membership, MembershipChange,
};
pub use rank::{RankComm, RankRun, TransportKind};
pub use socket::{fresh_world_id, global_wire_faults, SocketTransport};
pub use transport::{
    configured_timeout, LoopbackTransport, ThreadTransport, Transport, TransportError,
};
pub use communicator::{CommBuilder, Communicator};
pub use nonblocking::{
    IallgathervReq, IallreduceReq, IbcastReq, IreduceReq, IreduceScatterReq, Pending, Window,
};
pub use outcome::{CommError, Outcome, TenantUsage, WireFaults};
pub use request::{
    resolve_blocks, Algo, AllgathervReq, AllreduceReq, BcastReq, Kind, ReduceReq,
    ReduceScatterBlockReq, ReduceScatterReq, TuningParams, SMALL_MSG_BYTES,
};
pub use traffic::{BatchReport, OpReport, SubmitRequest, TrafficEngine};
