//! The traffic plane: batched execution of many concurrent collectives
//! on one machine — [`TrafficEngine`], the cross-operation round
//! scheduler behind [`super::Communicator::traffic`].
//!
//! ## The model
//!
//! A blocking collective owns the whole machine for its run. Real
//! workloads overlap operations, so the traffic plane extends the
//! paper's one-ported round-synchronous model *across* operations: in
//! every **machine round** each rank may serve at most one send and at
//! most one receive, counted over **all** co-scheduled operations. The
//! scheduler enforces this with an explicit **port ledger**: each
//! machine round, operations are visited in submission order; an
//! operation whose next (local) round's ports are all free claims them
//! and executes that round, otherwise it stalls to the next machine
//! round. Consequences:
//!
//! * operations over **disjoint rank windows** never share a port, so
//!   every one of them advances every machine round — the batch
//!   completes in the *max* of their round counts, not the sum — and
//!   their rounds execute truly concurrently across the scoped-thread
//!   pool (operation state is private, so sharding is free);
//! * operations **sharing ranks** time-share ports deterministically by
//!   submission order (the earliest-submitted unfinished operation
//!   always advances, which also guarantees termination);
//! * every operation's own execution is exactly the blocking lockstep
//!   run, stepped round by round ([`StepNet`] /
//!   [`crate::sim::EngineStep`] share the blocking drivers' round
//!   bodies), so each per-op [`Outcome`] — payloads, statistics, error
//!   values and rounds, all in the operation's local frame — is
//!   **bit-identical** to running that operation alone on a fresh
//!   communicator of its window size. The differential suite
//!   (`tests/traffic_parity.rs`) pins this.
//!
//! ## Accounting
//!
//! Per-op accounting lives in each [`Outcome`] (local frame, plus
//! [`Outcome::machine_span`] recording where the scheduler placed the
//! op). Aggregate accounting lives in [`BatchReport`]: machine-round
//! count, total messages/bytes, per-machine-rank bottleneck volume, and
//! the overlap completion time — the sum over machine rounds of the max
//! per-message cost across every co-scheduled operation
//! ([`crate::sim::OverlapClock`]), evaluated on *machine* ranks so
//! hierarchical cost models see true locality.
//!
//! ## Enforcement
//!
//! The ledger is a scheduling device *and* a checkable invariant:
//! enable [`TrafficEngine::record_trace`] and the executed
//! `(from, to)` pairs of every machine round come back in the
//! [`BatchReport`], ready for the cross-op oracle
//! [`crate::schedule::verify_one_ported_trace`]. A broken operation
//! (corrupt schedule, tampered rank) fails *itself* — same error, same
//! local round as its sequential run, surfaced through its own
//! [`Pending`] — while co-scheduled operations complete unaffected;
//! an erroring round's messages are discarded from the trace, exactly
//! as the lockstep simulator aborts a round mid-flight.

use std::collections::HashMap;
use std::sync::Arc;

use crate::collectives::allgatherv::{build_allgatherv_procs, AllgathervProc};
use crate::collectives::baselines::{
    BinomialBcastProc, BinomialReduceProc, OptTreeBcastProc, OptTreeReduceProc,
    RingAllgathervProc, RingReduceScatterProc, VdgBcastProc,
};
use crate::collectives::bcast::{build_bcast_procs, BcastProc};
use crate::collectives::common::{BlockGeometry, Element};
use crate::collectives::reduce::{build_reduce_procs, ReduceProc};
use crate::collectives::reduce_scatter::{build_reduce_scatter_procs, ReduceScatterProc};
use crate::collectives::rhalving::RhalvingProc;
use crate::schedule::configured_threads;
use crate::sim::cost::{CostModel, LogPClock, OverlapClock};
use crate::sim::engine::{CirculantEngine, EngineStep, ScratchPool};
use crate::sim::network::{RankProc, RunStats, SimError, StepNet};

use super::backend::{build_procs, BackendKind};
use super::communicator::{combine_stats, concat_rows, Communicator};
use super::nonblocking::{
    IallgathervReq, IallreduceReq, IbcastReq, IreduceReq, IreduceScatterReq, Pending, Slot,
    Window,
};
use super::outcome::{CommError, Outcome, TenantUsage};
use super::request::{Algo, Kind};

/// One executed message in the machine frame: `(from, to, bytes)`.
type TraceMsg = (usize, usize, usize);

/// Boxed outcome assembly of a single-phase proc op.
type Assemble<P, B> =
    Box<dyn FnOnce(RunStats, Vec<P>) -> Result<Outcome<B>, CommError> + Send>;

/// Boxed outcome assembly of a two-phase op (both phases' stats).
type Assemble2<P2, B> =
    Box<dyn FnOnce(RunStats, RunStats, Vec<P2>) -> Result<Outcome<B>, CommError> + Send>;

/// A submitted operation as the scheduler sees it: round-steppable,
/// port-predictable, result-delivering. Object-safe so one batch mixes
/// kinds and element types freely.
trait OpDriver: Send {
    /// True once every local round has executed — or the operation
    /// failed (a failed op stops claiming ports immediately).
    fn done(&self) -> bool;

    /// The machine-frame `(from, to)` port pairs of the next local
    /// round. Callable repeatedly; must not advance the operation.
    fn ports(&mut self, out: &mut Vec<(usize, usize)>);

    /// Execute the next local round. Errors are recorded internally
    /// (surfacing later through the op's `Pending`), never propagated to
    /// the scheduler.
    fn step(&mut self, cost: &dyn CostModel);

    /// Move the last executed round's machine-frame messages into `out`
    /// (empty after an erroring round — the round aborted).
    fn drain(&mut self, out: &mut Vec<TraceMsg>);

    /// Record the machine-round span the scheduler gave this op.
    fn set_span(&mut self, span: Option<(usize, usize)>);

    /// Assemble and deliver the final `Outcome` (or error) into the
    /// operation's `Pending` slot.
    fn finish(&mut self);

    /// After `finish`: did the operation succeed?
    fn ok(&self) -> bool;

    /// Local rounds actually executed (partial for failed ops).
    fn executed(&self) -> usize;
}

// ---------------------------------------------------------------------
// Proc-based driver (lockstep round stepping)
// ---------------------------------------------------------------------

/// Driver over a [`StepNet`] of per-rank state machines — the batched
/// mirror of the blocking lockstep backend, one per submitted op.
struct ProcOp<T, P, B> {
    net: Option<StepNet<T, P>>,
    assemble: Option<Assemble<P, B>>,
    slot: Slot<B>,
    elem_bytes: usize,
    base: usize,
    err: Option<SimError>,
    round_msgs: Vec<TraceMsg>,
    span: Option<(usize, usize)>,
    executed: usize,
    ok: bool,
}

impl<T, P, B> OpDriver for ProcOp<T, P, B>
where
    T: Element,
    P: RankProc<T> + Send + 'static,
    B: Send + 'static,
{
    fn done(&self) -> bool {
        self.err.is_some() || self.net.as_ref().map_or(true, |n| n.is_done())
    }

    fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        if self.done() {
            return;
        }
        let start = out.len();
        self.net.as_ref().unwrap().expected_ports(out);
        if self.base != 0 {
            for port in &mut out[start..] {
                port.0 += self.base;
                port.1 += self.base;
            }
        }
    }

    fn step(&mut self, cost: &dyn CostModel) {
        self.round_msgs.clear();
        let net = self.net.as_mut().expect("step on a finished op");
        match net.step(self.elem_bytes, cost, Some(&mut self.round_msgs)) {
            Ok(()) => {
                self.executed += 1;
                if self.base != 0 {
                    for msg in &mut self.round_msgs {
                        msg.0 += self.base;
                        msg.1 += self.base;
                    }
                }
            }
            Err(e) => {
                self.err = Some(e);
                self.round_msgs.clear();
            }
        }
    }

    fn drain(&mut self, out: &mut Vec<TraceMsg>) {
        out.append(&mut self.round_msgs);
    }

    fn set_span(&mut self, span: Option<(usize, usize)>) {
        self.span = span;
    }

    fn finish(&mut self) {
        let res = match self.err.take() {
            Some(e) => Err(CommError::Sim(e)),
            None => {
                let (stats, procs) = self.net.take().expect("finish twice").finish();
                (self.assemble.take().expect("finish twice"))(stats, procs)
            }
        };
        let res = res.map(|mut out| {
            out.machine_span = self.span;
            out
        });
        self.ok = res.is_ok();
        *self.slot.lock().unwrap() = Some(res);
    }

    fn ok(&self) -> bool {
        self.ok
    }

    fn executed(&self) -> usize {
        self.executed
    }
}

/// Box a proc set + assembly closure as a driver — shared by the five
/// submit paths and [`TrafficEngine::submit_procs`].
fn proc_op<T, P, B, F>(
    procs: Vec<P>,
    elem_bytes: usize,
    slot: Slot<B>,
    base: usize,
    assemble: F,
) -> Box<dyn OpDriver>
where
    T: Element,
    P: RankProc<T> + Send + 'static,
    B: Send + 'static,
    F: FnOnce(RunStats, Vec<P>) -> Result<Outcome<B>, CommError> + Send + 'static,
{
    Box::new(ProcOp {
        net: Some(StepNet::new(procs)),
        assemble: Some(Box::new(assemble)),
        slot,
        elem_bytes,
        base,
        err: None,
        round_msgs: Vec::new(),
        span: None,
        executed: 0,
        ok: false,
    })
}

// ---------------------------------------------------------------------
// Two-phase driver (all-reduce = reduce-scatter, then all-gather)
// ---------------------------------------------------------------------

/// Driver for the composed all-reduce: phase 1's state machines run to
/// completion, the bridge builds phase 2's from their chunks, and the
/// assembly combines both phases' statistics — exactly the blocking
/// [`Communicator::allreduce`] composition, stepped round by round.
/// Phase-2 local rounds restart at 0, matching the sequential run's
/// error-round frame.
struct TwoPhaseOp<T, P1, P2, B> {
    phase1: Option<StepNet<T, P1>>,
    #[allow(clippy::type_complexity)]
    bridge: Option<Box<dyn FnOnce(Vec<P1>) -> Vec<P2> + Send>>,
    phase2: Option<StepNet<T, P2>>,
    phase1_stats: Option<RunStats>,
    assemble: Option<Assemble2<P2, B>>,
    slot: Slot<B>,
    elem_bytes: usize,
    base: usize,
    err: Option<SimError>,
    round_msgs: Vec<TraceMsg>,
    span: Option<(usize, usize)>,
    executed: usize,
    ok: bool,
}

impl<T, P1, P2, B> TwoPhaseOp<T, P1, P2, B>
where
    T: Element,
    P1: RankProc<T> + Send + 'static,
    P2: RankProc<T> + Send + 'static,
    B: Send + 'static,
{
    fn boxed<F1, F2>(
        phase1: Vec<P1>,
        bridge: F1,
        assemble: F2,
        elem_bytes: usize,
        slot: Slot<B>,
        base: usize,
    ) -> Box<dyn OpDriver>
    where
        F1: FnOnce(Vec<P1>) -> Vec<P2> + Send + 'static,
        F2: FnOnce(RunStats, RunStats, Vec<P2>) -> Result<Outcome<B>, CommError> + Send + 'static,
    {
        let mut op = TwoPhaseOp {
            phase1: Some(StepNet::new(phase1)),
            bridge: Some(Box::new(bridge)),
            phase2: None,
            phase1_stats: None,
            assemble: Some(Box::new(assemble)),
            slot,
            elem_bytes,
            base,
            err: None,
            round_msgs: Vec::new(),
            span: None,
            executed: 0,
            ok: false,
        };
        op.advance(); // zero-round phase 1 (p = 1 windows) bridges now
        Box::new(op)
    }

    /// Bridge into phase 2 once phase 1 has stepped its last round.
    fn advance(&mut self) {
        if self.err.is_some() || self.phase2.is_some() {
            return;
        }
        if self.phase1.as_ref().is_some_and(|n| n.is_done()) {
            let (stats, procs) = self.phase1.take().unwrap().finish();
            self.phase1_stats = Some(stats);
            self.phase2 = Some(StepNet::new((self.bridge.take().unwrap())(procs)));
        }
    }
}

impl<T, P1, P2, B> OpDriver for TwoPhaseOp<T, P1, P2, B>
where
    T: Element,
    P1: RankProc<T> + Send + 'static,
    P2: RankProc<T> + Send + 'static,
    B: Send + 'static,
{
    fn done(&self) -> bool {
        self.err.is_some() || self.phase2.as_ref().is_some_and(|n| n.is_done())
    }

    fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        if self.done() {
            return;
        }
        let start = out.len();
        match (&self.phase1, &self.phase2) {
            (Some(net), _) => net.expected_ports(out),
            (None, Some(net)) => net.expected_ports(out),
            (None, None) => unreachable!("two-phase op with neither phase live"),
        }
        if self.base != 0 {
            for port in &mut out[start..] {
                port.0 += self.base;
                port.1 += self.base;
            }
        }
    }

    fn step(&mut self, cost: &dyn CostModel) {
        self.round_msgs.clear();
        let res = match (&mut self.phase1, &mut self.phase2) {
            (Some(net), _) => net.step(self.elem_bytes, cost, Some(&mut self.round_msgs)),
            (None, Some(net)) => net.step(self.elem_bytes, cost, Some(&mut self.round_msgs)),
            (None, None) => unreachable!("step on a bridged-out op"),
        };
        match res {
            Ok(()) => {
                self.executed += 1;
                if self.base != 0 {
                    for msg in &mut self.round_msgs {
                        msg.0 += self.base;
                        msg.1 += self.base;
                    }
                }
                self.advance();
            }
            Err(e) => {
                self.err = Some(e);
                self.round_msgs.clear();
            }
        }
    }

    fn drain(&mut self, out: &mut Vec<TraceMsg>) {
        out.append(&mut self.round_msgs);
    }

    fn set_span(&mut self, span: Option<(usize, usize)>) {
        self.span = span;
    }

    fn finish(&mut self) {
        let res = match self.err.take() {
            Some(e) => Err(CommError::Sim(e)),
            None => {
                let (ag_stats, procs) = self.phase2.take().expect("finish twice").finish();
                let rs_stats = self.phase1_stats.take().expect("finish twice");
                (self.assemble.take().expect("finish twice"))(rs_stats, ag_stats, procs)
            }
        };
        let res = res.map(|mut out| {
            out.machine_span = self.span;
            out
        });
        self.ok = res.is_ok();
        *self.slot.lock().unwrap() = Some(res);
    }

    fn ok(&self) -> bool {
        self.ok
    }

    fn executed(&self) -> usize {
        self.executed
    }
}

// ---------------------------------------------------------------------
// Engine-backed drivers (circulant bcast/reduce under BackendKind::Engine)
// ---------------------------------------------------------------------

/// Shared bookkeeping of the two engine drivers.
struct EngineOpCore<T: Element> {
    step: Option<EngineStep<T>>,
    pool: Arc<ScratchPool>,
    base: usize,
    err: Option<SimError>,
    round_msgs: Vec<TraceMsg>,
    span: Option<(usize, usize)>,
    executed: usize,
    ok: bool,
}

impl<T: Element> EngineOpCore<T> {
    fn new(step: EngineStep<T>, pool: Arc<ScratchPool>, base: usize) -> Self {
        EngineOpCore {
            step: Some(step),
            pool,
            base,
            err: None,
            round_msgs: Vec::new(),
            span: None,
            executed: 0,
            ok: false,
        }
    }

    fn done(&self) -> bool {
        self.err.is_some() || self.step.as_ref().map_or(true, |s| s.is_done())
    }

    fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        if self.done() {
            return;
        }
        let start = out.len();
        self.step.as_mut().unwrap().ports(out);
        if self.base != 0 {
            for port in &mut out[start..] {
                port.0 += self.base;
                port.1 += self.base;
            }
        }
    }

    fn step(&mut self, cost: &dyn CostModel) {
        self.round_msgs.clear();
        let step = self.step.as_mut().expect("step on a finished op");
        match step.step(cost, Some(&mut self.round_msgs)) {
            Ok(()) => {
                self.executed += 1;
                if self.base != 0 {
                    for msg in &mut self.round_msgs {
                        msg.0 += self.base;
                        msg.1 += self.base;
                    }
                }
            }
            Err(e) => {
                self.err = Some(e);
                self.round_msgs.clear();
            }
        }
    }

    /// Close the engine run (deferred checks) and pool the scratch.
    /// `Err` carries the mid-run error when one was recorded.
    fn finish_engine(&mut self) -> Result<(RunStats, Option<Vec<T>>), SimError> {
        if let Some(e) = self.err.take() {
            // The run aborted mid-round; the scratch inside the
            // EngineStep is dropped with it (error paths are rare).
            self.step = None;
            return Err(e);
        }
        let (res, scratch) = self.step.take().expect("finish twice").finish();
        self.pool.put(scratch);
        res
    }
}

/// Circulant broadcast on the sparse engine: payload-free simulation;
/// the outcome's buffers are copies of the root data, exactly as the
/// blocking engine dispatch assembles them.
struct EngineBcastOp<T: Element> {
    core: EngineOpCore<T>,
    data: Vec<T>,
    p: usize,
    m: usize,
    algo: Algo,
    slot: Slot<Vec<Vec<T>>>,
}

impl<T: Element> OpDriver for EngineBcastOp<T> {
    fn done(&self) -> bool {
        self.core.done()
    }
    fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        self.core.ports(out)
    }
    fn step(&mut self, cost: &dyn CostModel) {
        self.core.step(cost)
    }
    fn drain(&mut self, out: &mut Vec<TraceMsg>) {
        out.append(&mut self.core.round_msgs);
    }
    fn set_span(&mut self, span: Option<(usize, usize)>) {
        self.core.span = span;
    }

    fn finish(&mut self) {
        let res = match self.core.finish_engine() {
            Err(e) => Err(CommError::Sim(e)),
            Ok((stats, _)) => {
                let buffers: Vec<Vec<T>> = (0..self.p).map(|_| self.data.clone()).collect();
                let complete =
                    buffers.len() == self.p && buffers.iter().all(|b| b.len() == self.m);
                Ok(Outcome {
                    rounds: stats.rounds,
                    stats,
                    buffers,
                    algo: self.algo,
                    complete,
                    machine_span: self.core.span,
                })
            }
        };
        self.core.ok = res.is_ok();
        *self.slot.lock().unwrap() = Some(res);
    }

    fn ok(&self) -> bool {
        self.core.ok
    }
    fn executed(&self) -> usize {
        self.core.executed
    }
}

// (EngineReduceOp follows the same shape for the reduction path.)

/// Circulant rooted reduction on the sparse engine.
struct EngineReduceOp<T: Element> {
    core: EngineOpCore<T>,
    m: usize,
    algo: Algo,
    slot: Slot<Vec<T>>,
}

impl<T: Element> OpDriver for EngineReduceOp<T> {
    fn done(&self) -> bool {
        self.core.done()
    }
    fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        self.core.ports(out)
    }
    fn step(&mut self, cost: &dyn CostModel) {
        self.core.step(cost)
    }
    fn drain(&mut self, out: &mut Vec<TraceMsg>) {
        out.append(&mut self.core.round_msgs);
    }
    fn set_span(&mut self, span: Option<(usize, usize)>) {
        self.core.span = span;
    }

    fn finish(&mut self) {
        let res = match self.core.finish_engine() {
            Err(e) => Err(CommError::Sim(e)),
            Ok((stats, buffer)) => {
                let buffer = buffer.expect("engine reduce returns the root buffer");
                let complete = buffer.len() == self.m;
                Ok(Outcome {
                    rounds: stats.rounds,
                    stats,
                    buffers: buffer,
                    algo: self.algo,
                    complete,
                    machine_span: self.core.span,
                })
            }
        };
        self.core.ok = res.is_ok();
        *self.slot.lock().unwrap() = Some(res);
    }

    fn ok(&self) -> bool {
        self.core.ok
    }
    fn executed(&self) -> usize {
        self.core.executed
    }
}

// ---------------------------------------------------------------------
// The batch scheduler
// ---------------------------------------------------------------------

/// One submitted operation's scheduling record.
struct OpEntry {
    driver: Box<dyn OpDriver>,
    kind: Option<Kind>,
    window: Window,
    span: Option<(usize, usize)>,
    /// The tenant label in force at submission time ([`TrafficEngine::
    /// for_tenant`]); `None` for untagged library-level submissions.
    tenant: Option<Arc<str>>,
    /// Machine-frame messages this op put on the wire (drained rounds).
    messages: usize,
    /// Machine-frame payload bytes this op put on the wire.
    bytes: usize,
}

/// Per-op summary in a [`BatchReport`].
#[derive(Debug, Clone)]
pub struct OpReport {
    /// The collective kind; `None` for a custom
    /// [`TrafficEngine::submit_procs`] operation.
    pub kind: Option<Kind>,
    /// The machine-rank window the operation ran over.
    pub window: Window,
    /// `(first, last)` machine rounds the scheduler placed the op in
    /// (`None` if it needed no rounds).
    pub machine_span: Option<(usize, usize)>,
    /// Local rounds actually executed (partial when the op failed).
    pub rounds: usize,
    /// Did the operation deliver an `Ok` outcome?
    pub ok: bool,
    /// The tenant label the op was submitted under (`None` for untagged
    /// library-level submissions).
    pub tenant: Option<Arc<str>>,
    /// Machine-frame messages this op sent.
    pub messages: usize,
    /// Machine-frame payload bytes this op moved.
    pub bytes: usize,
}

/// Aggregate result of one [`TrafficEngine::run`].
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// Batch-wide accounting in the **machine** frame: `rounds` =
    /// machine rounds until the batch drained, `time` = the overlap
    /// completion time (sum over machine rounds of the max per-message
    /// cost across every co-scheduled op), `messages`/`bytes` totals,
    /// `max_rank_bytes` the per-machine-rank bottleneck volume,
    /// `active_rounds` the machine rounds in which any message flew.
    pub agg: RunStats,
    /// Per-op summaries, in submission order.
    pub ops: Vec<OpReport>,
    /// The executed `(from, to)` pairs of every machine round, when
    /// [`TrafficEngine::record_trace`] was enabled — the input to
    /// [`crate::schedule::verify_one_ported_trace`].
    pub trace: Option<Vec<Vec<(usize, usize)>>>,
    /// Per-tenant usage rows, in first-submission order — one row per
    /// distinct [`TrafficEngine::for_tenant`] label seen in the batch
    /// (empty when no op was tagged). Admission rejections are folded in
    /// after the run by the service daemon via
    /// [`BatchReport::note_rejected`].
    pub tenants: Vec<TenantUsage>,
}

impl BatchReport {
    /// Machine rounds until the whole batch drained.
    #[inline]
    pub fn machine_rounds(&self) -> usize {
        self.agg.rounds
    }

    /// How many operations failed.
    pub fn failed(&self) -> usize {
        self.ops.iter().filter(|o| !o.ok).count()
    }

    /// Fold `n` admission rejections into `tenant`'s usage row (creating
    /// an otherwise-zero row if the tenant got nothing admitted). The
    /// engine never sees rejected requests — the service daemon calls
    /// this after the batch with its own admission counters.
    pub fn note_rejected(&mut self, tenant: &str, n: usize) {
        if let Some(row) = self.tenants.iter_mut().find(|u| u.tenant == tenant) {
            row.rejected += n;
        } else {
            self.tenants.push(TenantUsage {
                tenant: tenant.to_string(),
                rejected: n,
                ..TenantUsage::default()
            });
        }
    }

    /// The usage row for `tenant`, if any op (or rejection) carried it.
    pub fn tenant(&self, tenant: &str) -> Option<&TenantUsage> {
        self.tenants.iter().find(|u| u.tenant == tenant)
    }

    /// Checkpoint accessor for the recovery plane: indices (submission
    /// order) of the ops that delivered an `Ok` outcome. After a
    /// membership change these are settled — their results were taken
    /// from the *old* world before it died and need no replay.
    pub fn completed_ops(&self) -> Vec<usize> {
        self.ops
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.ok.then_some(i))
            .collect()
    }

    /// Checkpoint accessor for the recovery plane: indices (submission
    /// order) of the ops that must be replayed on the rebuilt world
    /// after the listed machine ranks `failed` — every op that failed
    /// outright, plus every op (even an apparently-complete one) whose
    /// window contains a failed rank: its result may have been
    /// assembled from a rank that was already dying, so it is restarted
    /// rather than trusted. Ops over windows **disjoint** from every
    /// failed rank and finished `Ok` are untouched — the property the
    /// mid-batch recovery test pins.
    pub fn restart_set(&self, failed: &[usize]) -> Vec<usize> {
        self.ops
            .iter()
            .enumerate()
            .filter(|(_, o)| {
                !o.ok || failed.iter().any(|&f| f >= o.window.base && f < o.window.end())
            })
            .map(|(i, _)| i)
            .collect()
    }
}

/// A batch of nonblocking collectives over one [`Communicator`]'s
/// machine: submit operations ([`TrafficEngine::submit`], typed
/// `I*Req`s), then [`TrafficEngine::run`] executes them under the
/// cross-op port ledger. See the module docs for the scheduling model.
pub struct TrafficEngine<'c> {
    comm: &'c Communicator,
    ops: Vec<OpEntry>,
    /// Window-sized sub-communicators, keyed by window length, sharing
    /// the parent's cache/cost/tuning/backend — so every window size
    /// pays schedule computation once per batch (and nothing at all when
    /// the shared cache already holds the table).
    subs: HashMap<usize, Communicator>,
    /// Scratch pool shared by the batch's engine-backed operations.
    pool: Arc<ScratchPool>,
    threads: Option<usize>,
    record_trace: bool,
    ran: bool,
    /// The tenant label stamped onto subsequent submissions
    /// ([`TrafficEngine::for_tenant`]); `None` = untagged.
    tenant: Option<Arc<str>>,
}

impl<'c> TrafficEngine<'c> {
    /// A fresh batch over `comm`'s machine (prefer
    /// [`Communicator::traffic`]).
    pub fn new(comm: &'c Communicator) -> Self {
        TrafficEngine {
            comm,
            ops: Vec::new(),
            subs: HashMap::new(),
            pool: Arc::new(ScratchPool::new()),
            threads: None,
            record_trace: false,
            ran: false,
            tenant: None,
        }
    }

    /// The communicator this batch executes on.
    #[inline]
    pub fn comm(&self) -> &Communicator {
        self.comm
    }

    /// Operations submitted so far.
    #[inline]
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Override the scoped-thread count used to step co-scheduled ops
    /// (default: `CBCAST_THREADS`/all cores, the schedule-plane rule).
    /// `1` is the exact serial path — results are identical either way
    /// (operation state is private; only wall-clock changes).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Record every machine round's executed `(from, to)` pairs into the
    /// [`BatchReport`] (for the one-ported-trace oracle). Off by default:
    /// a large batch's trace is O(total messages).
    pub fn record_trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }

    /// Tag every *subsequent* submission with a tenant label. The batch
    /// report then carries one [`TenantUsage`] row per distinct label —
    /// ops admitted, ops completed ok, messages and bytes moved — which
    /// is how the collective service daemon ([`crate::service`]) bills
    /// interleaved client work out of one shared batch. Call again to
    /// switch labels mid-batch; scheduling and results are completely
    /// unaffected by tagging.
    pub fn for_tenant(&mut self, label: &str) {
        self.tenant = Some(Arc::from(label));
    }

    /// Submit a typed nonblocking collective (`IbcastReq`, `IreduceReq`,
    /// `IallgathervReq`, `IreduceScatterReq`, `IallreduceReq`); returns
    /// the typed handle. Malformed requests (bad window/root/lengths,
    /// unsupported algorithm) fail here, mirroring the blocking
    /// validation; runtime violations surface later through the handle.
    pub fn submit<T: Element, R: SubmitRequest<T>>(
        &mut self,
        req: R,
    ) -> Result<Pending<R::Buffers>, CommError> {
        assert!(!self.ran, "submit after run: open a new batch");
        req.submit_into(self)
    }

    /// Advanced: submit a custom proc-based operation — `procs[r]` is
    /// window rank `r`'s state machine — with `assemble` turning the
    /// final `(stats, procs)` into the op's `Outcome`. This is the
    /// extension point for collectives beyond the built-in five (and the
    /// failure-injection hook the traffic tests use).
    pub fn submit_procs<T, P, B, F>(
        &mut self,
        win: Option<Window>,
        procs: Vec<P>,
        elem_bytes: usize,
        assemble: F,
    ) -> Result<Pending<B>, CommError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
        B: Send + 'static,
        F: FnOnce(RunStats, Vec<P>) -> Result<Outcome<B>, CommError> + Send + 'static,
    {
        assert!(!self.ran, "submit after run: open a new batch");
        let window = self.resolve_window(win)?;
        if procs.len() != window.len {
            return Err(CommError::BadRequest(format!(
                "submit_procs needs one proc per window rank ({}), got {}",
                window.len,
                procs.len()
            )));
        }
        let (pending, slot) = Pending::new_pair();
        let driver = proc_op(procs, elem_bytes, slot, window.base, assemble);
        self.push(driver, None, window);
        Ok(pending)
    }

    /// Validate and default an op's window against the machine.
    fn resolve_window(&self, win: Option<Window>) -> Result<Window, CommError> {
        let p = self.comm.p();
        let w = win.unwrap_or_else(|| Window::full(p));
        // checked_add: `base + len` must not wrap (release builds don't
        // trap overflow, and a wrapped end would slip past the bound
        // check only to crash the ledger indexing later).
        let in_range = w.base.checked_add(w.len).is_some_and(|end| end <= p);
        if w.len == 0 || !in_range {
            return Err(CommError::BadRequest(format!(
                "window [{}, {} ranks) out of range for p = {p}",
                w.base, w.len
            )));
        }
        Ok(w)
    }

    /// The communicator serving a window of `len` ranks: the parent for
    /// full-machine ops, else a cached window-sized sub-communicator.
    fn sub_comm(&mut self, len: usize) -> &Communicator {
        if len == self.comm.p() {
            self.comm
        } else {
            self.subs.entry(len).or_insert_with(|| self.comm.windowed(len))
        }
    }

    fn push(&mut self, driver: Box<dyn OpDriver>, kind: Option<Kind>, window: Window) {
        self.ops.push(OpEntry {
            driver,
            kind,
            window,
            span: None,
            tenant: self.tenant.clone(),
            messages: 0,
            bytes: 0,
        });
    }

    /// Execute the batch: round-interleave every submitted operation
    /// under the port ledger, deliver each op's `Outcome` into its
    /// [`Pending`], and return the aggregate [`BatchReport`]. Per-op
    /// failures do **not** fail the batch — they surface through the
    /// failing op's handle while co-scheduled ops complete unaffected.
    pub fn run(&mut self) -> Result<BatchReport, CommError> {
        assert!(!self.ran, "TrafficEngine::run may only be called once per batch");
        self.ran = true;
        let p = self.comm.p();
        let threads = self.threads.unwrap_or_else(configured_threads).max(1);
        let cost = self.comm.cost().clone();
        let cost: &dyn CostModel = cost.as_ref();

        // The port ledger: one send and one recv stamp per machine rank,
        // versioned by round (no clearing between rounds).
        let mut send_stamp = vec![0u32; p];
        let mut recv_stamp = vec![0u32; p];
        let mut ports: Vec<(usize, usize)> = Vec::new();
        let mut scheduled: Vec<usize> = Vec::new();
        let mut drained: Vec<TraceMsg> = Vec::new();
        let mut trace: Vec<Vec<(usize, usize)>> = Vec::new();
        let mut clock = OverlapClock::new();
        // The cost plane's clock rides along when LogP parameters are
        // configured: the whole batch's machine-frame trace — every
        // co-scheduled op together — is priced as one schedule, so
        // `agg.logp_time` is the predicted completion of the batch,
        // overlap included.
        let mut logp_clock = self.comm.tuning().logp.map(LogPClock::new);
        let mut agg = RunStats::default();
        let mut rank_bytes = vec![0usize; p];
        let mut round = 0usize;

        while self.ops.iter().any(|e| !e.driver.done()) {
            let stamp = round as u32 + 1;
            scheduled.clear();
            let mut first_unfinished = true;
            for (i, entry) in self.ops.iter_mut().enumerate() {
                if entry.driver.done() {
                    continue;
                }
                ports.clear();
                entry.driver.ports(&mut ports);
                let free = ports
                    .iter()
                    .all(|&(f, t)| send_stamp[f] != stamp && recv_stamp[t] != stamp);
                // The earliest-submitted unfinished op always runs: its
                // ports were checked against an empty ledger, so `free`
                // can only be false through a *self*-conflict — a broken
                // op, which must execute to surface its violation (and
                // which would otherwise stall the batch forever).
                if free || first_unfinished {
                    for &(f, t) in &ports {
                        send_stamp[f] = stamp;
                        recv_stamp[t] = stamp;
                    }
                    scheduled.push(i);
                }
                first_unfinished = false;
            }
            assert!(
                !scheduled.is_empty(),
                "traffic scheduler stalled with unfinished operations"
            );

            // Execute the scheduled rounds. Operation state is private,
            // so co-scheduled ops shard freely across scoped threads —
            // bit-identical to the serial order.
            if threads <= 1 || scheduled.len() <= 1 {
                for &i in &scheduled {
                    self.ops[i].driver.step(cost);
                }
            } else {
                let mut want = scheduled.iter().copied().peekable();
                let mut refs: Vec<&mut OpEntry> = Vec::with_capacity(scheduled.len());
                for (i, e) in self.ops.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        refs.push(e);
                    }
                }
                let per = (refs.len() + threads - 1) / threads;
                std::thread::scope(|s| {
                    for group in refs.chunks_mut(per) {
                        s.spawn(move || {
                            for e in group.iter_mut() {
                                e.driver.step(cost);
                            }
                        });
                    }
                });
            }

            // Drain in submission order: spans, trace, aggregate
            // accounting (machine frame).
            let mut round_trace = Vec::new();
            for &i in &scheduled {
                let e = &mut self.ops[i];
                e.span = Some((e.span.map_or(round, |(f, _)| f), round));
                drained.clear();
                e.driver.drain(&mut drained);
                for &(f, t, bytes) in &drained {
                    agg.messages += 1;
                    agg.bytes += bytes;
                    e.messages += 1;
                    e.bytes += bytes;
                    rank_bytes[f] += bytes;
                    rank_bytes[t] += bytes;
                    clock.msg(cost, f, t, bytes);
                    if let Some(c) = logp_clock.as_mut() {
                        c.msg(f, t, bytes);
                    }
                    if self.record_trace {
                        round_trace.push((f, t));
                    }
                }
            }
            clock.end_round();
            if let Some(c) = logp_clock.as_mut() {
                c.end_round();
            }
            if self.record_trace {
                trace.push(round_trace);
            }
            round += 1;
        }

        agg.rounds = round;
        agg.active_rounds = clock.active_rounds();
        agg.time = clock.total();
        agg.max_rank_bytes = rank_bytes.into_iter().max().unwrap_or(0);
        agg.logp_time = logp_clock.map(|c| c.total());

        let ops: Vec<OpReport> = self
            .ops
            .iter_mut()
            .map(|e| {
                e.driver.set_span(e.span);
                e.driver.finish();
                OpReport {
                    kind: e.kind,
                    window: e.window,
                    machine_span: e.span,
                    rounds: e.driver.executed(),
                    ok: e.driver.ok(),
                    tenant: e.tenant.clone(),
                    messages: e.messages,
                    bytes: e.bytes,
                }
            })
            .collect();

        // Fold tagged ops into per-tenant rows (first-submission order).
        let mut tenants: Vec<TenantUsage> = Vec::new();
        for op in &ops {
            let Some(label) = op.tenant.as_deref() else { continue };
            let idx = match tenants.iter().position(|u| u.tenant == label) {
                Some(i) => i,
                None => {
                    tenants.push(TenantUsage {
                        tenant: label.to_string(),
                        ..TenantUsage::default()
                    });
                    tenants.len() - 1
                }
            };
            let row = &mut tenants[idx];
            row.ops += 1;
            row.ok += op.ok as usize;
            row.messages += op.messages;
            row.bytes += op.bytes;
        }

        Ok(BatchReport {
            agg,
            ops,
            trace: if self.record_trace { Some(trace) } else { None },
            tenants,
        })
    }
}

// ---------------------------------------------------------------------
// Typed submission: the five nonblocking requests
// ---------------------------------------------------------------------

mod sealed {
    pub trait Sealed {}
    impl<T> Sealed for super::IbcastReq<T> {}
    impl<T> Sealed for super::IreduceReq<T> {}
    impl<T> Sealed for super::IallgathervReq<T> {}
    impl<T> Sealed for super::IreduceScatterReq<T> {}
    impl<T> Sealed for super::IallreduceReq<T> {}
}

/// A typed nonblocking request [`TrafficEngine::submit`] accepts — the
/// five `I*Req` types of [`super::nonblocking`] (sealed). `Buffers` is
/// the blocking mirror's `Outcome` buffer type, so a batched op's result
/// has exactly the blocking shape.
pub trait SubmitRequest<T: Element>: sealed::Sealed {
    type Buffers: Send + 'static;

    #[doc(hidden)]
    fn submit_into(self, traffic: &mut TrafficEngine<'_>)
        -> Result<Pending<Self::Buffers>, CommError>;
}

impl<T: Element> SubmitRequest<T> for IbcastReq<T> {
    type Buffers = Vec<Vec<T>>;

    fn submit_into(
        self,
        traffic: &mut TrafficEngine<'_>,
    ) -> Result<Pending<Vec<Vec<T>>>, CommError> {
        let window = traffic.resolve_window(self.win)?;
        let pool = traffic.pool.clone();
        let (driver, pending) =
            build_bcast_driver(traffic.sub_comm(window.len), window.base, &pool, self)?;
        traffic.push(driver, Some(Kind::Bcast), window);
        Ok(pending)
    }
}

impl<T: Element> SubmitRequest<T> for IreduceReq<T> {
    type Buffers = Vec<T>;

    fn submit_into(self, traffic: &mut TrafficEngine<'_>) -> Result<Pending<Vec<T>>, CommError> {
        let window = traffic.resolve_window(self.win)?;
        let pool = traffic.pool.clone();
        let (driver, pending) =
            build_reduce_driver(traffic.sub_comm(window.len), window.base, &pool, self)?;
        traffic.push(driver, Some(Kind::Reduce), window);
        Ok(pending)
    }
}

impl<T: Element> SubmitRequest<T> for IallgathervReq<T> {
    type Buffers = Vec<Vec<Vec<T>>>;

    fn submit_into(
        self,
        traffic: &mut TrafficEngine<'_>,
    ) -> Result<Pending<Vec<Vec<Vec<T>>>>, CommError> {
        let window = traffic.resolve_window(self.win)?;
        let (driver, pending) =
            build_allgatherv_driver(traffic.sub_comm(window.len), window.base, self)?;
        traffic.push(driver, Some(Kind::Allgatherv), window);
        Ok(pending)
    }
}

impl<T: Element> SubmitRequest<T> for IreduceScatterReq<T> {
    type Buffers = Vec<Vec<T>>;

    fn submit_into(
        self,
        traffic: &mut TrafficEngine<'_>,
    ) -> Result<Pending<Vec<Vec<T>>>, CommError> {
        let window = traffic.resolve_window(self.win)?;
        let (driver, pending) =
            build_reduce_scatter_driver(traffic.sub_comm(window.len), window.base, self)?;
        traffic.push(driver, Some(Kind::ReduceScatter), window);
        Ok(pending)
    }
}

impl<T: Element> SubmitRequest<T> for IallreduceReq<T> {
    type Buffers = Vec<Vec<T>>;

    fn submit_into(
        self,
        traffic: &mut TrafficEngine<'_>,
    ) -> Result<Pending<Vec<Vec<T>>>, CommError> {
        let window = traffic.resolve_window(self.win)?;
        let (driver, pending) =
            build_allreduce_driver(traffic.sub_comm(window.len), window.base, self)?;
        traffic.push(driver, Some(Kind::Allreduce), window);
        Ok(pending)
    }
}

// ---------------------------------------------------------------------
// Per-kind driver construction — mirrors the blocking `Communicator`
// methods (validation order, algorithm dispatch, outcome assembly), so
// batched outcomes are bit-identical to sequential ones. The parity
// suite `tests/traffic_parity.rs` pins the mirror.
// ---------------------------------------------------------------------

type Built<B> = (Box<dyn OpDriver>, Pending<B>);

fn build_bcast_driver<T: Element>(
    sub: &Communicator,
    base: usize,
    pool: &Arc<ScratchPool>,
    req: IbcastReq<T>,
) -> Result<Built<Vec<Vec<T>>>, CommError> {
    let p = sub.p();
    if req.root >= p {
        return Err(CommError::BadRequest(format!(
            "bcast root {} out of range for p = {p}",
            req.root
        )));
    }
    let m = req.data.len();
    let algo = req.algo.resolve_with(Kind::Bcast, p, m, req.elem_bytes, req.blocks, sub.tuning());
    let (pending, slot) = Pending::new_pair();
    let driver: Box<dyn OpDriver> = match algo {
        Algo::Circulant if sub.backend() == BackendKind::Engine => {
            let n = sub.blocks_for(Kind::Bcast, m, req.blocks);
            let geom = BlockGeometry::new(m, n);
            let eng = CirculantEngine::new(sub.rows(), req.root, geom);
            let mut scratch = pool.take::<T>();
            // The batch already parallelises across operations; keep each
            // op's delivery application serial so co-scheduled engine ops
            // don't nest thread scopes.
            scratch.delivery_threads = Some(1);
            let step = EngineStep::bcast(eng, scratch, req.elem_bytes);
            Box::new(EngineBcastOp {
                core: EngineOpCore::new(step, pool.clone(), base),
                data: req.data,
                p,
                m,
                algo,
                slot,
            })
        }
        Algo::Circulant => {
            let n = sub.blocks_for(Kind::Bcast, m, req.blocks);
            let geom = BlockGeometry::new(m, n);
            let procs = build_bcast_procs(&sub.schedules(), req.root, geom, &req.data);
            proc_op(procs, req.elem_bytes, slot, base, move |stats, procs: Vec<BcastProc<T>>| {
                if let Some(pr) = procs.iter().find(|pr| !pr.complete()) {
                    return Err(CommError::Incomplete { kind: Kind::Bcast, rank: pr.rank });
                }
                let buffers: Vec<Vec<T>> =
                    procs.into_iter().map(|pr| pr.into_buffer()).collect();
                Ok(bcast_outcome(p, m, algo, stats, buffers))
            })
        }
        Algo::Binomial => {
            let procs = build_procs(p, |r| {
                let data = if r == req.root { Some(&req.data[..]) } else { None };
                BinomialBcastProc::new(p, r, req.root, data)
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<BinomialBcastProc<T>>| {
                    let buffers: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_buffer()).collect();
                    Ok(bcast_outcome(p, m, algo, stats, buffers))
                },
            )
        }
        Algo::VanDeGeijn => {
            let procs = build_procs(p, |r| {
                let data = if r == req.root { Some(&req.data[..]) } else { None };
                VdgBcastProc::new(p, r, req.root, m, data)
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<VdgBcastProc<T>>| {
                    let buffers: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_buffer()).collect();
                    Ok(bcast_outcome(p, m, algo, stats, buffers))
                },
            )
        }
        Algo::OptTree => {
            let tree = sub.opttree_for(m, req.elem_bytes);
            let procs = build_procs(p, |r| {
                let data = if r == req.root { Some(&req.data[..]) } else { None };
                OptTreeBcastProc::new(tree.clone(), p, r, req.root, data)
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<OptTreeBcastProc<T>>| {
                    let buffers: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_buffer()).collect();
                    Ok(bcast_outcome(p, m, algo, stats, buffers))
                },
            )
        }
        algo => return Err(CommError::Unsupported { kind: Kind::Bcast, algo }),
    };
    Ok((driver, pending))
}

/// The blocking bcast's uniform completion check + outcome shape.
fn bcast_outcome<T: Element>(
    p: usize,
    m: usize,
    algo: Algo,
    stats: RunStats,
    buffers: Vec<Vec<T>>,
) -> Outcome<Vec<Vec<T>>> {
    let complete = buffers.len() == p && buffers.iter().all(|b| b.len() == m);
    Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None }
}

fn build_reduce_driver<T: Element>(
    sub: &Communicator,
    base: usize,
    pool: &Arc<ScratchPool>,
    req: IreduceReq<T>,
) -> Result<Built<Vec<T>>, CommError> {
    let p = sub.p();
    if req.inputs.len() != p {
        return Err(CommError::BadRequest(format!(
            "reduce needs {p} contributions, got {}",
            req.inputs.len()
        )));
    }
    if req.root >= p {
        return Err(CommError::BadRequest(format!(
            "reduce root {} out of range for p = {p}",
            req.root
        )));
    }
    let m = req.inputs[0].len();
    if req.inputs.iter().any(|v| v.len() != m) {
        return Err(CommError::BadRequest(
            "reduce requires equal-length contributions".to_string(),
        ));
    }
    let algo = req.algo.resolve_with(Kind::Reduce, p, m, req.elem_bytes, req.blocks, sub.tuning());
    let (pending, slot) = Pending::new_pair();
    let root = req.root;
    let driver: Box<dyn OpDriver> = match algo {
        Algo::Circulant if sub.backend() == BackendKind::Engine => {
            let n = sub.blocks_for(Kind::Reduce, m, req.blocks);
            let geom = BlockGeometry::new(m, n);
            let eng = CirculantEngine::new(sub.rows(), root, geom);
            let mut scratch = pool.take::<T>();
            scratch.delivery_threads = Some(1);
            let step =
                EngineStep::reduce(eng, scratch, &req.inputs, req.op.clone(), req.elem_bytes);
            Box::new(EngineReduceOp {
                core: EngineOpCore::new(step, pool.clone(), base),
                m,
                algo,
                slot,
            })
        }
        Algo::Circulant => {
            let n = sub.blocks_for(Kind::Reduce, m, req.blocks);
            let geom = BlockGeometry::new(m, n);
            let procs =
                build_reduce_procs(&sub.schedules(), root, geom, &req.inputs, req.op.clone());
            proc_op(procs, req.elem_bytes, slot, base, move |stats, procs: Vec<ReduceProc<T>>| {
                let buffer = procs.into_iter().nth(root).unwrap().into_buffer();
                Ok(reduce_outcome(m, algo, stats, buffer))
            })
        }
        Algo::Binomial => {
            let procs = build_procs(p, |r| {
                BinomialReduceProc::new(p, r, root, &req.inputs[r], req.op.clone())
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<BinomialReduceProc<T>>| {
                    let buffer = procs.into_iter().nth(root).unwrap().into_buffer();
                    Ok(reduce_outcome(m, algo, stats, buffer))
                },
            )
        }
        Algo::OptTree => {
            let tree = sub.opttree_for(m, req.elem_bytes);
            let procs = build_procs(p, |r| {
                OptTreeReduceProc::new(tree.clone(), p, r, root, &req.inputs[r], req.op.clone())
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<OptTreeReduceProc<T>>| {
                    let buffer = procs.into_iter().nth(root).unwrap().into_buffer();
                    Ok(reduce_outcome(m, algo, stats, buffer))
                },
            )
        }
        algo => return Err(CommError::Unsupported { kind: Kind::Reduce, algo }),
    };
    Ok((driver, pending))
}

/// The blocking reduce's uniform completion check + outcome shape.
fn reduce_outcome<T: Element>(
    m: usize,
    algo: Algo,
    stats: RunStats,
    buffer: Vec<T>,
) -> Outcome<Vec<T>> {
    let complete = buffer.len() == m;
    Outcome { rounds: stats.rounds, stats, buffers: buffer, algo, complete, machine_span: None }
}

fn build_allgatherv_driver<T: Element>(
    sub: &Communicator,
    base: usize,
    req: IallgathervReq<T>,
) -> Result<Built<Vec<Vec<Vec<T>>>>, CommError> {
    let p = sub.p();
    if req.inputs.len() != p {
        return Err(CommError::BadRequest(format!(
            "allgatherv needs {p} contributions, got {}",
            req.inputs.len()
        )));
    }
    let total: usize = req.inputs.iter().map(|v| v.len()).sum();
    let counts = Arc::new(req.inputs.iter().map(|v| v.len()).collect::<Vec<_>>());
    let algo =
        req.algo.resolve_with(Kind::Allgatherv, p, total, req.elem_bytes, req.blocks, sub.tuning());
    let (pending, slot) = Pending::new_pair();
    let lens = counts.clone();
    let assemble_check = move |stats: RunStats, buffers: Vec<Vec<Vec<T>>>| {
        // The blocking allgatherv's uniform completion check: every rank
        // holds every root's full contribution.
        let complete = buffers.len() == p
            && buffers.iter().all(|rows| {
                rows.len() == p
                    && rows.iter().zip(lens.iter()).all(|(row, &len)| row.len() == len)
            });
        Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None }
    };
    let driver: Box<dyn OpDriver> = match algo {
        Algo::Circulant => {
            let n = sub.blocks_for(Kind::Allgatherv, total, req.blocks);
            let table = sub.table(n);
            let procs = build_allgatherv_procs(table, counts, &req.inputs);
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<AllgathervProc<T>>| {
                    if let Some(pr) = procs.iter().find(|pr| !pr.complete()) {
                        return Err(CommError::Incomplete {
                            kind: Kind::Allgatherv,
                            rank: pr.rank,
                        });
                    }
                    let buffers: Vec<Vec<Vec<T>>> =
                        procs.into_iter().map(|pr| pr.into_buffers()).collect();
                    Ok(assemble_check(stats, buffers))
                },
            )
        }
        Algo::Ring => {
            let procs = build_procs(p, |r| {
                RingAllgathervProc::new(p, r, counts.clone(), &req.inputs[r])
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<RingAllgathervProc<T>>| {
                    let buffers: Vec<Vec<Vec<T>>> =
                        procs.into_iter().map(|pr| pr.into_buffers()).collect();
                    Ok(assemble_check(stats, buffers))
                },
            )
        }
        algo => return Err(CommError::Unsupported { kind: Kind::Allgatherv, algo }),
    };
    Ok((driver, pending))
}

fn build_reduce_scatter_driver<T: Element>(
    sub: &Communicator,
    base: usize,
    req: IreduceScatterReq<T>,
) -> Result<Built<Vec<Vec<T>>>, CommError> {
    let p = sub.p();
    if req.inputs.len() != p || req.counts.len() != p {
        return Err(CommError::BadRequest(format!(
            "reduce_scatter needs {p} contributions and {p} counts, got {} and {}",
            req.inputs.len(),
            req.counts.len()
        )));
    }
    let total: usize = req.counts.iter().sum();
    if req.inputs.iter().any(|v| v.len() != total) {
        return Err(CommError::BadRequest(format!(
            "reduce_scatter contributions must have sum(counts) = {total} elements"
        )));
    }
    let counts = Arc::new(req.counts.clone());
    let algo = req.algo.resolve_with(
        Kind::ReduceScatter,
        p,
        total,
        req.elem_bytes,
        req.blocks,
        sub.tuning(),
    );
    let (pending, slot) = Pending::new_pair();
    let lens = counts.clone();
    let assemble_check = move |stats: RunStats, chunks: Vec<Vec<T>>| {
        // The blocking reduce_scatter's uniform completion check: rank j
        // holds its counts[j]-element chunk.
        let complete = chunks.len() == p
            && chunks.iter().zip(lens.iter()).all(|(chunk, &c)| chunk.len() == c);
        Outcome {
            rounds: stats.rounds,
            stats,
            buffers: chunks,
            algo,
            complete,
            machine_span: None,
        }
    };
    let driver: Box<dyn OpDriver> = match algo {
        Algo::Circulant => {
            let n = sub.blocks_for(Kind::ReduceScatter, total, req.blocks);
            let table = sub.table(n);
            let procs =
                build_reduce_scatter_procs(table, counts, &req.inputs, req.op.clone());
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<ReduceScatterProc<T>>| {
                    let chunks: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_chunk()).collect();
                    Ok(assemble_check(stats, chunks))
                },
            )
        }
        Algo::Ring => {
            let procs = build_procs(p, |r| {
                RingReduceScatterProc::new(p, r, counts.clone(), &req.inputs[r], req.op.clone())
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<RingReduceScatterProc<T>>| {
                    let chunks: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_chunk()).collect();
                    Ok(assemble_check(stats, chunks))
                },
            )
        }
        Algo::RecursiveHalving => {
            let chunk = req.counts[0];
            if req.counts.iter().any(|&c| c != chunk) {
                return Err(CommError::BadRequest(
                    "recursive halving requires equal chunks (reduce_scatter_block)".to_string(),
                ));
            }
            let procs = build_procs(p, |r| {
                RhalvingProc::new(p, r, chunk, &req.inputs[r], req.op.clone())
            });
            proc_op(
                procs,
                req.elem_bytes,
                slot,
                base,
                move |stats, procs: Vec<RhalvingProc<T>>| {
                    let chunks: Vec<Vec<T>> =
                        procs.into_iter().map(|pr| pr.into_chunk()).collect();
                    Ok(assemble_check(stats, chunks))
                },
            )
        }
        algo => return Err(CommError::Unsupported { kind: Kind::ReduceScatter, algo }),
    };
    Ok((driver, pending))
}

fn build_allreduce_driver<T: Element>(
    sub: &Communicator,
    base: usize,
    req: IallreduceReq<T>,
) -> Result<Built<Vec<Vec<T>>>, CommError> {
    let p = sub.p();
    if req.inputs.len() != p {
        return Err(CommError::BadRequest(format!(
            "allreduce needs {p} contributions, got {}",
            req.inputs.len()
        )));
    }
    let m = req.inputs.first().map(|v| v.len()).unwrap_or(0);
    if req.inputs.iter().any(|v| v.len() != m) {
        return Err(CommError::BadRequest(
            "allreduce requires equal-length contributions".to_string(),
        ));
    }
    // Chunk m over p ranks as equally as possible — the blocking split.
    let chunk_base = m / p;
    let rem = m % p;
    let counts: Vec<usize> = (0..p).map(|j| chunk_base + usize::from(j < rem)).collect();
    let counts = Arc::new(counts);
    let algo =
        req.algo.resolve_with(Kind::Allreduce, p, m, req.elem_bytes, req.blocks, sub.tuning());
    let (pending, slot) = Pending::new_pair();
    let assemble = move |rs_stats: RunStats, ag_stats: RunStats, buffers: Vec<Vec<T>>| {
        let stats = combine_stats(&rs_stats, &ag_stats);
        let complete = buffers.len() == p && buffers.iter().all(|b| b.len() == m);
        Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None }
    };
    let driver: Box<dyn OpDriver> = match algo {
        Algo::Circulant => {
            let n = sub.blocks_for(Kind::Allreduce, m, req.blocks);
            let table = sub.table(n);
            let rs_procs = build_reduce_scatter_procs(
                table.clone(),
                counts.clone(),
                &req.inputs,
                req.op.clone(),
            );
            let bridge_counts = counts.clone();
            TwoPhaseOp::boxed(
                rs_procs,
                move |rs_procs: Vec<ReduceScatterProc<T>>| {
                    let chunks: Vec<Vec<T>> =
                        rs_procs.into_iter().map(|pr| pr.into_chunk()).collect();
                    build_allgatherv_procs(table, bridge_counts, &chunks)
                },
                move |rs_stats, ag_stats, ag_procs: Vec<AllgathervProc<T>>| {
                    if let Some(pr) = ag_procs.iter().find(|pr| !pr.complete()) {
                        return Err(CommError::Incomplete {
                            kind: Kind::Allreduce,
                            rank: pr.rank,
                        });
                    }
                    let buffers =
                        concat_rows(ag_procs.into_iter().map(|pr| pr.into_buffers()), m);
                    Ok(assemble(rs_stats, ag_stats, buffers))
                },
                req.elem_bytes,
                slot,
                base,
            )
        }
        Algo::Ring => {
            let rs_procs = build_procs(p, |r| {
                RingReduceScatterProc::new(p, r, counts.clone(), &req.inputs[r], req.op.clone())
            });
            let bridge_counts = counts.clone();
            TwoPhaseOp::boxed(
                rs_procs,
                move |rs_procs: Vec<RingReduceScatterProc<T>>| {
                    let chunks: Vec<Vec<T>> =
                        rs_procs.into_iter().map(|pr| pr.into_chunk()).collect();
                    build_procs(p, |r| {
                        RingAllgathervProc::new(p, r, bridge_counts.clone(), &chunks[r])
                    })
                },
                move |rs_stats, ag_stats, ag_procs: Vec<RingAllgathervProc<T>>| {
                    let buffers =
                        concat_rows(ag_procs.into_iter().map(|pr| pr.into_buffers()), m);
                    Ok(assemble(rs_stats, ag_stats, buffers))
                },
                req.elem_bytes,
                slot,
                base,
            )
        }
        algo => return Err(CommError::Unsupported { kind: Kind::Allreduce, algo }),
    };
    Ok((driver, pending))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::comm::CommBuilder;
    use crate::sim::UnitCost;

    fn comm(p: usize) -> Communicator {
        CommBuilder::new(p).cost_model(UnitCost).build()
    }

    fn stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
        assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
        assert_eq!(a.active_rounds, b.active_rounds, "{ctx}: active_rounds");
        assert_eq!(a.messages, b.messages, "{ctx}: messages");
        assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
        assert_eq!(a.max_rank_bytes, b.max_rank_bytes, "{ctx}: max_rank_bytes");
        assert!((a.time - b.time).abs() < 1e-12, "{ctx}: time");
    }

    #[test]
    fn single_op_batches_match_blocking_calls() {
        use crate::comm::{AllreduceReq, BcastReq, ReduceReq};
        let p = 17usize;
        let c = comm(p);
        let data: Vec<i64> = (0..90).map(|i| i * 3 - 7).collect();
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..40).map(|i| ((r + 1) * (i + 2)) as i64 % 97).collect())
            .collect();

        let mut traffic = c.traffic().threads(1).record_trace(true);
        let hb = traffic
            .submit(IbcastReq::new(3, data.clone()).algo(Algo::Circulant).blocks(5))
            .unwrap();
        let report = traffic.run().unwrap();
        let batched = hb.wait().unwrap();
        let blocking = c
            .bcast(BcastReq::new(3, &data).algo(Algo::Circulant).blocks(5))
            .unwrap();
        assert_eq!(batched.buffers, blocking.buffers);
        assert_eq!(batched.algo, blocking.algo);
        assert_eq!(batched.complete, blocking.complete);
        stats_eq(&batched.stats, &blocking.stats, "single bcast");
        // Alone in the batch, the op never stalls: machine rounds equal
        // its local rounds, the span covers them all.
        assert_eq!(report.machine_rounds(), blocking.rounds);
        assert_eq!(batched.machine_span, Some((0, blocking.rounds - 1)));
        assert_eq!(report.ops.len(), 1);
        assert!(report.ops[0].ok);
        assert_eq!(report.ops[0].kind, Some(Kind::Bcast));

        // Reduce and allreduce the same way.
        let mut traffic = c.traffic().threads(1);
        let hr = traffic
            .submit(
                IreduceReq::new(4, inputs.clone(), Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(3),
            )
            .unwrap();
        let ha = traffic
            .submit(
                IallreduceReq::new(inputs.clone(), Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(2),
            )
            .unwrap();
        traffic.run().unwrap();
        let br = hr.wait().unwrap();
        let wr = c
            .reduce(ReduceReq::new(4, &inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(3))
            .unwrap();
        assert_eq!(br.buffers, wr.buffers);
        stats_eq(&br.stats, &wr.stats, "reduce in batch");
        let ba = ha.wait().unwrap();
        let wa = c
            .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(2))
            .unwrap();
        assert_eq!(ba.buffers, wa.buffers);
        stats_eq(&ba.stats, &wa.stats, "allreduce in batch");
    }

    #[test]
    fn disjoint_windows_run_concurrently() {
        // Four broadcasts over disjoint 8-rank windows: every op advances
        // every machine round, so the batch takes max (not sum) rounds.
        let c = comm(32);
        for threads in [1usize, 4] {
            let mut traffic = c.traffic().threads(threads).record_trace(true);
            let data: Vec<i64> = (0..64).collect();
            let handles: Vec<_> = (0..4)
                .map(|w| {
                    traffic
                        .submit(
                            IbcastReq::new(w, data.clone())
                                .algo(Algo::Circulant)
                                .blocks(4)
                                .window(8 * w, 8),
                        )
                        .unwrap()
                })
                .collect();
            let report = traffic.run().unwrap();
            // Each op: n - 1 + q = 4 - 1 + 3 = 6 local rounds.
            assert_eq!(report.machine_rounds(), 6, "threads={threads}");
            let seq: Communicator = comm(8);
            for (w, h) in handles.into_iter().enumerate() {
                let out = h.wait().unwrap();
                assert_eq!(out.machine_span, Some((0, 5)), "window {w}");
                let blocking = seq
                    .bcast(crate::comm::BcastReq::new(w, &data).algo(Algo::Circulant).blocks(4))
                    .unwrap();
                assert_eq!(out.buffers, blocking.buffers, "window {w}");
                stats_eq(&out.stats, &blocking.stats, &format!("window {w}"));
            }
            // The trace respects cross-op one-portedness.
            let trace = report.trace.as_ref().unwrap();
            crate::schedule::verify_one_ported_trace(32, trace).unwrap();
        }
    }

    #[test]
    fn shared_ranks_interleave_with_parity() {
        // Two full-machine ops + one windowed op sharing ranks: the batch
        // takes more machine rounds than any single op, fewer than the
        // sum, and every per-op outcome matches its sequential run.
        let p = 9usize;
        let c = comm(p);
        let data: Vec<i64> = (0..45).collect();
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..30).map(|i| (r * 7 + i) as i64).collect()).collect();
        let win_inputs: Vec<Vec<i64>> =
            (0..4).map(|r| (0..12).map(|i| (r * 11 + i) as i64).collect()).collect();

        let mut traffic = c.traffic().threads(2).record_trace(true);
        let h1 = traffic
            .submit(IbcastReq::new(0, data.clone()).algo(Algo::Circulant).blocks(4))
            .unwrap();
        let h2 = traffic
            .submit(
                IreduceReq::new(2, inputs.clone(), Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(3),
            )
            .unwrap();
        let h3 = traffic
            .submit(
                IallgathervReq::new(win_inputs.clone())
                    .algo(Algo::Circulant)
                    .blocks(2)
                    .window(3, 4),
            )
            .unwrap();
        let report = traffic.run().unwrap();
        crate::schedule::verify_one_ported_trace(p, report.trace.as_ref().unwrap()).unwrap();

        let b1 = h1.wait().unwrap();
        let s1 = c
            .bcast(crate::comm::BcastReq::new(0, &data).algo(Algo::Circulant).blocks(4))
            .unwrap();
        assert_eq!(b1.buffers, s1.buffers);
        stats_eq(&b1.stats, &s1.stats, "bcast");

        let b2 = h2.wait().unwrap();
        let s2 = c
            .reduce(
                crate::comm::ReduceReq::new(2, &inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(3),
            )
            .unwrap();
        assert_eq!(b2.buffers, s2.buffers);
        stats_eq(&b2.stats, &s2.stats, "reduce");

        let b3 = h3.wait().unwrap();
        let s3 = comm(4)
            .allgatherv(
                crate::comm::AllgathervReq::new(&win_inputs).algo(Algo::Circulant).blocks(2),
            )
            .unwrap();
        assert_eq!(b3.buffers, s3.buffers);
        stats_eq(&b3.stats, &s3.stats, "windowed allgatherv");

        let sum = s1.rounds + s2.rounds + s3.rounds;
        let longest = s1.rounds.max(s2.rounds).max(s3.rounds);
        assert!(report.machine_rounds() >= longest);
        assert!(report.machine_rounds() < sum, "interleaving must beat serialisation");
    }

    #[test]
    fn engine_backend_batch_matches_blocking_engine() {
        let p = 13usize;
        let c = CommBuilder::new(p).cost_model(UnitCost).backend(BackendKind::Engine).build();
        let data: Vec<i64> = (0..77).map(|i| i * 5 % 89).collect();
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..31).map(|i| ((r + 2) * (i + 1)) as i64 % 53).collect())
            .collect();
        let mut traffic = c.traffic().threads(1).record_trace(true);
        let hb = traffic
            .submit(IbcastReq::new(5, data.clone()).algo(Algo::Circulant).blocks(6))
            .unwrap();
        let hr = traffic
            .submit(
                IreduceReq::new(1, inputs.clone(), Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(4),
            )
            .unwrap();
        let report = traffic.run().unwrap();
        crate::schedule::verify_one_ported_trace(p, report.trace.as_ref().unwrap()).unwrap();
        let bb = hb.wait().unwrap();
        let sb = c
            .bcast(crate::comm::BcastReq::new(5, &data).algo(Algo::Circulant).blocks(6))
            .unwrap();
        assert_eq!(bb.buffers, sb.buffers);
        stats_eq(&bb.stats, &sb.stats, "engine bcast");
        let br = hr.wait().unwrap();
        let sr = c
            .reduce(
                crate::comm::ReduceReq::new(1, &inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(4),
            )
            .unwrap();
        assert_eq!(br.buffers, sr.buffers);
        stats_eq(&br.stats, &sr.stats, "engine reduce");
        // Finished engine ops pooled their scratch.
        assert!(traffic.pool.idle() >= 1);
    }

    #[test]
    fn p1_windows_and_empty_batches() {
        let c = comm(5);
        let report = c.traffic().run().unwrap();
        assert_eq!(report.machine_rounds(), 0);
        assert!(report.ops.is_empty());

        let mut traffic = c.traffic();
        let h = traffic
            .submit(IbcastReq::new(0, vec![7i64; 9]).algo(Algo::Circulant).blocks(2).window(4, 1))
            .unwrap();
        let report = traffic.run().unwrap();
        let out = h.wait().unwrap();
        assert_eq!(out.buffers, vec![vec![7i64; 9]]);
        assert!(out.all_received());
        assert_eq!(out.rounds, 0);
        assert_eq!(out.machine_span, None, "zero-round ops occupy no machine round");
        assert_eq!(report.machine_rounds(), 0);
    }

    #[test]
    fn bad_submissions_rejected_like_blocking() {
        let c = comm(8);
        let mut traffic = c.traffic();
        assert!(matches!(
            traffic.submit(IbcastReq::new(9, vec![1i64; 4])),
            Err(CommError::BadRequest(_))
        ));
        assert!(matches!(
            traffic.submit(IbcastReq::new(0, vec![1i64; 4]).window(6, 4)),
            Err(CommError::BadRequest(_))
        ));
        // Overflowing windows must be rejected, not wrapped past the
        // bound check.
        assert!(matches!(
            traffic.submit(IbcastReq::new(0, vec![1i64; 4]).window(usize::MAX - 1, 4)),
            Err(CommError::BadRequest(_))
        ));
        assert!(matches!(
            traffic.submit(IbcastReq::new(0, vec![1i64; 4]).algo(Algo::Ring)),
            Err(CommError::Unsupported { kind: Kind::Bcast, algo: Algo::Ring })
        ));
        let short: Vec<Vec<i64>> = vec![vec![1]; 3];
        assert!(matches!(
            traffic.submit(IreduceReq::new(0, short, Arc::new(SumOp))),
            Err(CommError::BadRequest(_))
        ));
        // Rejected submissions leave the batch runnable.
        let h = traffic
            .submit(IbcastReq::new(2, vec![5i64; 16]).blocks(2).algo(Algo::Circulant))
            .unwrap();
        traffic.run().unwrap();
        assert!(h.wait().unwrap().all_received());
    }

    #[test]
    fn windowed_ops_resolve_blocks_at_window_size() {
        // Auto block counts and auto algorithm selection must see the
        // window size, exactly like a fresh Communicator of that size.
        let c = comm(40);
        let data: Vec<i64> = (0..4000).collect();
        let mut traffic = c.traffic();
        let h = traffic.submit(IbcastReq::new(0, data.clone()).window(10, 17)).unwrap();
        traffic.run().unwrap();
        let batched = h.wait().unwrap();
        let blocking = comm(17).bcast(crate::comm::BcastReq::new(0, &data)).unwrap();
        assert_eq!(batched.algo, blocking.algo);
        assert_eq!(batched.rounds, blocking.rounds);
        assert_eq!(batched.buffers, blocking.buffers);
        stats_eq(&batched.stats, &blocking.stats, "auto window");
    }

    #[test]
    fn tenant_rows_partition_the_batch_accounting() {
        // Two tenants interleaved in one batch: the per-tenant rows must
        // partition the aggregate message/byte totals exactly, and
        // tagging must not perturb results (parity pinned elsewhere).
        let p = 9usize;
        let c = comm(p);
        let data: Vec<i64> = (0..36).collect();
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..18).map(|i| (r * 5 + i) as i64).collect()).collect();

        let mut traffic = c.traffic().threads(1);
        traffic.for_tenant("alice");
        let ha = traffic
            .submit(IbcastReq::new(0, data.clone()).algo(Algo::Circulant).blocks(3))
            .unwrap();
        traffic.for_tenant("bob");
        let hb = traffic
            .submit(
                IreduceReq::new(2, inputs.clone(), Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(2),
            )
            .unwrap();
        traffic.for_tenant("alice");
        let hc = traffic
            .submit(IbcastReq::new(4, data.clone()).algo(Algo::Circulant).blocks(2))
            .unwrap();
        let mut report = traffic.run().unwrap();
        assert!(ha.wait().unwrap().all_received());
        hb.wait().unwrap();
        assert!(hc.wait().unwrap().all_received());

        assert_eq!(report.tenants.len(), 2);
        let alice = report.tenant("alice").unwrap().clone();
        let bob = report.tenant("bob").unwrap().clone();
        assert_eq!((alice.ops, alice.ok), (2, 2));
        assert_eq!((bob.ops, bob.ok), (1, 1));
        assert_eq!(alice.messages + bob.messages, report.agg.messages);
        assert_eq!(alice.bytes + bob.bytes, report.agg.bytes);
        assert!(alice.messages > 0 && bob.messages > 0);
        assert_eq!(alice.rejected + bob.rejected, 0);
        // Per-op rows carry the same labels in submission order.
        let labels: Vec<Option<&str>> =
            report.ops.iter().map(|o| o.tenant.as_deref()).collect();
        assert_eq!(labels, vec![Some("alice"), Some("bob"), Some("alice")]);

        // Admission rejections fold into existing rows or create new ones.
        report.note_rejected("bob", 3);
        report.note_rejected("carol", 1);
        assert_eq!(report.tenant("bob").unwrap().rejected, 3);
        let carol = report.tenant("carol").unwrap();
        assert_eq!((carol.ops, carol.rejected), (0, 1));
    }

    #[test]
    fn untagged_batches_report_no_tenants() {
        let c = comm(5);
        let mut traffic = c.traffic();
        let h = traffic
            .submit(IbcastReq::new(0, vec![1i64; 10]).algo(Algo::Circulant).blocks(2))
            .unwrap();
        let report = traffic.run().unwrap();
        assert!(h.wait().unwrap().all_received());
        assert!(report.tenants.is_empty());
        assert!(report.ops[0].tenant.is_none());
        assert_eq!(report.ops[0].messages, report.agg.messages);
        assert_eq!(report.ops[0].bytes, report.agg.bytes);
    }
}
