//! The wire plane: [`SocketTransport`] carries the SPMD rank plane's
//! messages across real OS sockets — Unix-domain or TCP — one process
//! (or thread) per rank. The paper's point makes this cheap: schedule
//! computation is communication-free and O(log p) per rank, so each
//! endpoint derives its own 2·⌈log₂ p⌉ schedule entries locally and
//! only payload blocks cross the wire.
//!
//! **The one-ported round discipline holds across the wire.** Each
//! endpoint enforces the caller side of the
//! [`super::transport`] contract with the same `Discipline`
//! bookkeeping as the in-process transports, and machine-model
//! violations surface in the lockstep [`SimError`] vocabulary wrapped
//! as [`TransportError::Machine`] — the SPMD parity suite pins
//! `SocketTransport` bit-identical (buffers *and* stats) to lockstep.
//!
//! # Frames (protocol v3)
//!
//! Everything on a wire-plane connection is a length-prefixed frame
//! (all integers little-endian; `len` counts the type byte, the body
//! and the trailing checksum):
//!
//! ```text
//! [ len: u32 ][ type: u8 ][ body ][ crc32: u32 ]
//!
//! HELLO (1)  magic u32, version u16, p u32, rank u32,
//!            world_id u64, elem_bytes u32, epoch u64
//! DATA  (2)  seq u64, ack u64, round u32, src u32, dst u32,
//!            count u32, payload: count * elem_bytes bytes
//! BYE   (3)  (empty) — clean close of the sender's write side
//! ABORT (4)  reason: utf-8 — the sender's world was poisoned
//! ACK   (5)  ack u64 — cumulative acknowledgement (idle fallback)
//! ```
//!
//! The CRC32 (IEEE, reflected) covers `[type][body]`. v3 added the
//! checksum trailer, the `seq`/`ack` fields on `DATA` and the `ACK`
//! frame; v2 had appended the membership `epoch` field to `HELLO`.
//!
//! # Reliable delivery
//!
//! Transient wire faults — a dropped, duplicated, reordered, delayed
//! or bit-flipped frame — must not be confused with a crashed peer.
//! v3 layers a retransmission protocol under the mailbox:
//!
//! * every `DATA` frame carries a per-link sequence number (`seq`,
//!   from 1) and a cumulative acknowledgement (`ack`) of the highest
//!   contiguously-delivered sequence in the opposite direction;
//! * the sender keeps each unacknowledged frame in a bounded
//!   retransmission queue and re-emits it on a capped exponential
//!   backoff ([`rto_for`]) driven by a per-endpoint ticker thread;
//! * a receiver with ACK debt and no outgoing `DATA` to piggyback on
//!   announces progress with an idle `ACK` frame;
//! * a per-link dedup window (`rx_seen` above the contiguous
//!   `rx_delivered` floor) drops duplicates — wire-duplicated frames
//!   and retransmissions whose original won — and re-announces the
//!   cumulative ACK so the sender's queue drains;
//! * a frame whose checksum fails is discarded silently: the sender's
//!   retransmission heals it.
//!
//! Corruption and loss therefore become retransmits, not a poisoned
//! world. Only *retry-budget exhaustion* ([`MAX_ATTEMPTS`]) — or a
//! hard write error on a link whose peer never announced departure —
//! escalates: the peer is marked **crashed**, feeding the existing
//! [`Transport::failed_peers`] → `Membership::shrink` ladder. The
//! counters ([`Transport::wire_faults`], [`global_wire_faults`]) are
//! deliberately kept out of run statistics so a run over a lossy wire
//! stays bit-identical to a fault-free run.
//!
//! # The chaos shim
//!
//! [`SocketTransport::pair_world_chaos`] threads a deterministic
//! [`FaultPlan`] into every link's raw write path: each emission
//! draws a [`Verdict`] (drop / duplicate / reorder / delay /
//! corrupt-k-bits) from the seeded plan, so whole fault sequences are
//! replayable. Faults apply to `DATA`/`ACK` emissions only — control
//! frames (`HELLO`/`BYE`/`ABORT`) model the established link, not the
//! rendezvous — and retransmissions draw fresh verdicts. Corruption
//! never touches the length prefix: a desynced byte stream is the one
//! fault no checksum can heal.
//!
//! # Handshake
//!
//! The first frame on every link is a versioned `HELLO` pinning
//! `(p, rank, world_id, elem_bytes, epoch)`. A mismatch — wrong world,
//! wrong protocol version, wrong element width, wrong membership
//! epoch — is a typed failure: at rendezvous time it is an
//! [`io::Error`] from the constructor; after assembly the link's
//! reader poisons the local world and every blocked verb fails with
//! [`TransportError::Shutdown`]. The epoch field lets the recovery
//! plane rebuild a shrunken world under `epoch + 1` and have
//! stragglers from the dead epoch refused at the door instead of
//! corrupting the new world.
//!
//! # Crash detection
//!
//! Every link terminates exactly one of two ways, and the reader keeps
//! the distinction: a **deliberate** departure announces itself (`BYE`
//! on clean completion, `ABORT` on failure) before the socket closes,
//! while a **crash** — the process died, the endpoint was dropped
//! without [`Transport::close`] — slams the socket shut with no
//! farewell frame (plain EOF) or mid-frame (truncation / reset). The
//! reliability layer adds the third detector: a peer that acknowledges
//! nothing for [`MAX_ATTEMPTS`] retransmissions of one frame is
//! declared crashed even though its socket is formally open.
//! [`Transport::failed_peers`] reports all of them. Because the mesh
//! is full, every survivor observes a dead peer's silence on its *own*
//! direct link — the survivors' failed sets agree without any
//! coordinator or extra exchange.
//!
//! # Failure mapping
//!
//! Wire faults land in the same vocabulary the in-process transports
//! use, never as raw I/O errors from `send`/`recv`:
//!
//! * transient faults (drop / duplicate / reorder / delay / corrupt)
//!   → healed in place by the reliability layer; no error at all;
//! * peer closed cleanly (`BYE` or EOF at a frame boundary) but the
//!   schedule still expects a message from it →
//!   [`SimError::MissingMessage`];
//! * peer silent past the receive deadline →
//!   [`TransportError::Timeout`];
//! * truncated frame, torn payload, misrouted frame, port collision →
//!   world poisoned with the diagnosis, verbs fail as
//!   [`TransportError::Shutdown`] (collisions use the
//!   [`SimError::ReceivePortBusy`] text);
//! * a reader or ticker thread that panics poisons the endpoint state
//!   mutex; every lock site recovers the guard and converts the panic
//!   into a world-poisoning `Shutdown` diagnosis instead of silent
//!   thread death;
//! * a rank that fails broadcasts `ABORT` on [`Transport::close`], so
//!   poisoning propagates across process boundaries too.
//!
//! # Topologies
//!
//! * [`SocketTransport::pair_world`] — all `p` endpoints in one
//!   process over `UnixStream::pair` meshes (the parity suite's
//!   harness). A full mesh holds p·(p−1) descriptor ends: p = 24 fits
//!   a 1024-fd soft limit, p = 64 wants `ulimit -n` ≥ 8192.
//! * [`SocketTransport::uds_world`] / [`SocketTransport::tcp_world`] —
//!   one endpoint per *process*, rendezvous by dialing every lower
//!   rank and accepting from every higher rank (acceptors identify
//!   peers by their `HELLO`, so accept order never matters).

use std::any::TypeId;
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::chaos::{FaultPlan, Verdict};
use super::outcome::WireFaults;
use super::transport::{configured_timeout, Discipline, Transport, TransportError};
use crate::sim::network::SimError;

/// Wire protocol magic ("CBW1") — first field of every `HELLO`.
pub(crate) const MAGIC: u32 = 0x4342_5731;
/// Wire protocol version; bumped on any frame-format change.
/// v2 appended the membership `epoch` field to `HELLO`; v3 added the
/// CRC32 trailer, `seq`/`ack` on `DATA`, and the `ACK` frame.
pub(crate) const VERSION: u16 = 3;
/// Sanity bound on a single frame (256 MiB) — anything larger is a
/// corrupt length prefix, not a payload.
pub(crate) const MAX_FRAME: usize = 1 << 28;

const FT_HELLO: u8 = 1;
const FT_DATA: u8 = 2;
const FT_BYE: u8 = 3;
const FT_ABORT: u8 = 4;
const FT_ACK: u8 = 5;

// ---------------------------------------------------------------------
// Reliability parameters
// ---------------------------------------------------------------------

/// Transmissions of one frame before the peer is declared crashed:
/// the original send plus `MAX_ATTEMPTS` retransmissions.
const MAX_ATTEMPTS: u32 = 8;
/// First retransmission timeout; doubles per attempt up to
/// [`RTO_CAP`].
const RTO_BASE: Duration = Duration::from_millis(25);
/// Retransmission timeout ceiling.
const RTO_CAP: Duration = Duration::from_millis(200);
/// The ticker's cadence: retransmission scan + idle-ACK fallback.
const TICK: Duration = Duration::from_millis(5);
/// Unacknowledged frames a link will buffer before concluding the
/// peer is not consuming at all (treated like budget exhaustion).
const RETX_QUEUE_MAX: usize = 1024;
/// Bound on [`Transport::close`]'s settle wait for in-flight
/// retransmissions and ACK debt.
const LINGER_MAX: Duration = Duration::from_secs(2);

/// Capped exponential backoff: 25 ms, 50, 100, then 200 ms flat.
/// Full budget to escalation ≈ 1.4 s — well under the receive
/// deadlines the tests and the daemon run with.
fn rto_for(attempts: u32) -> Duration {
    (RTO_BASE * 2u32.pow(attempts.min(3))).min(RTO_CAP)
}

/// The diagnosis every lock site reports when a reader/ticker thread
/// panicked while holding the endpoint state — the panic poisons the
/// world instead of dying silently.
const POISONED_MUTEX: &str = "wire: endpoint state mutex poisoned by a panicked thread";

// ---------------------------------------------------------------------
// Byte helpers shared with the service plane
// ---------------------------------------------------------------------

pub(crate) fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed utf-8 string (u32 length + bytes).
pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Cursor over a frame body; every getter fails with a typed
/// `InvalidData` on a short body instead of panicking.
pub(crate) struct Body<'a> {
    b: &'a [u8],
}

impl<'a> Body<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Body<'a> {
        Body { b }
    }

    pub(crate) fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.b.len() < n {
            return Err(bad_data("wire: short frame body".into()));
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    pub(crate) fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> io::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Counterpart of [`put_str`].
    pub(crate) fn str(&mut self) -> io::Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| bad_data("wire: invalid utf-8".into()))
    }

    pub(crate) fn rest(&mut self) -> &'a [u8] {
        std::mem::take(&mut self.b)
    }
}

/// Seal `body` into a full `[len][type][body]` frame ready to write.
/// The service plane's frames use this (no checksum — they ride a
/// request/response protocol that retries at the call layer); the
/// rank plane seals with [`seal_crc`].
pub(crate) fn seal(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 5);
    put_u32(&mut out, (body.len() + 1) as u32);
    out.push(kind);
    out.extend_from_slice(body);
    out
}

/// CRC32 (IEEE 802.3, reflected, init/xorout `0xFFFFFFFF`) over
/// `[kind][body]` — bitwise, no table; frames are small and the wire
/// is not the bottleneck. Check value: `crc32` of `"123456789"` is
/// `0xCBF43926`.
pub(crate) fn crc32(kind: u8, body: &[u8]) -> u32 {
    fn crc_byte(mut c: u32, b: u8) -> u32 {
        c ^= u32::from(b);
        for _ in 0..8 {
            let mask = (c & 1).wrapping_neg();
            c = (c >> 1) ^ (0xEDB8_8320 & mask);
        }
        c
    }
    let mut c = 0xFFFF_FFFFu32;
    c = crc_byte(c, kind);
    for &b in body {
        c = crc_byte(c, b);
    }
    !c
}

/// Seal `body` into a v3 rank-plane frame:
/// `[len][type][body][crc32 of type+body]`.
pub(crate) fn seal_crc(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 9);
    put_u32(&mut out, (body.len() + 5) as u32);
    out.push(kind);
    out.extend_from_slice(body);
    put_u32(&mut out, crc32(kind, body));
    out
}

/// Outcome of reading one checksummed rank-plane frame.
#[derive(Debug)]
pub(crate) enum WireRead {
    /// Clean EOF at a frame boundary.
    Eof,
    /// The frame arrived whole but its CRC32 trailer did not match:
    /// discard it — the sender's retransmission heals the loss.
    CrcMismatch,
    /// A verified `(type, body)` with the trailer stripped.
    Frame(u8, Vec<u8>),
}

/// Read exactly `buf.len()` bytes. `Ok(false)` means EOF *before any
/// byte* — a clean stop at a frame boundary when called on a length
/// prefix. EOF mid-buffer is the typed truncation error.
pub(crate) fn fill(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "wire: truncated frame",
                ));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one `[len][type][body]` frame. `Ok(None)` is a clean EOF at a
/// frame boundary; EOF anywhere inside a frame is `UnexpectedEof`.
pub(crate) fn read_raw_frame(r: &mut impl Read) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut len4 = [0u8; 4];
    if !fill(r, &mut len4)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(bad_data(format!("wire: bad frame length {len}")));
    }
    let mut kind1 = [0u8; 1];
    if !fill(r, &mut kind1)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "wire: truncated frame",
        ));
    }
    let mut body = vec![0u8; len - 1];
    if !fill(r, &mut body)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "wire: truncated frame",
        ));
    }
    Ok(Some((kind1[0], body)))
}

/// Read one v3 rank-plane frame and verify its checksum trailer.
pub(crate) fn read_wire_frame(r: &mut impl Read) -> io::Result<WireRead> {
    let Some((kind, mut body)) = read_raw_frame(r)? else {
        return Ok(WireRead::Eof);
    };
    if body.len() < 4 {
        return Err(bad_data(
            "wire: frame too short for its checksum trailer".into(),
        ));
    }
    let split = body.len() - 4;
    let want = u32::from_le_bytes(body[split..].try_into().unwrap());
    body.truncate(split);
    if crc32(kind, &body) != want {
        return Ok(WireRead::CrcMismatch);
    }
    Ok(WireRead::Frame(kind, body))
}

// ---------------------------------------------------------------------
// Stream: one enum over the two socket families
// ---------------------------------------------------------------------

/// A bidirectional byte stream over either socket family. The service
/// plane reuses this for client connections.
pub(crate) enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub(crate) fn try_clone(&self) -> io::Result<Stream> {
        Ok(match self {
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }

    pub(crate) fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.shutdown(how),
            Stream::Tcp(s) => s.shutdown(how),
        }
    }

    pub(crate) fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(dur),
            Stream::Tcp(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------
// Codec: elements <-> little-endian bytes, resolved once per world
// ---------------------------------------------------------------------

/// Fixed-width primitives the wire can carry. Payloads are encoded
/// per-element little-endian, so frames are byte-identical across
/// endianness and process boundaries.
trait Prim: Copy + 'static {
    const WIDTH: usize;
    fn put(self, out: &mut Vec<u8>);
    fn take(bytes: &[u8]) -> Self;
}

macro_rules! impl_prim {
    ($($t:ty),*) => {$(
        impl Prim for $t {
            const WIDTH: usize = std::mem::size_of::<$t>();
            fn put(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn take(bytes: &[u8]) -> Self {
                Self::from_le_bytes(bytes.try_into().unwrap())
            }
        }
    )*};
}

impl_prim!(i8, u8, i16, u16, i32, u32, i64, u64, f32, f64);

fn enc_as<W: Prim, T: 'static>(xs: &[T], out: &mut Vec<u8>) {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<W>());
    // SAFETY: `Codec::resolve` installs this function only after
    // proving TypeId::of::<T>() == TypeId::of::<W>(), so the slice
    // cast is an identity cast.
    let ws: &[W] = unsafe { &*(xs as *const [T] as *const [W]) };
    for w in ws {
        w.put(out);
    }
}

fn dec_as<W: Prim, T: 'static>(bytes: &[u8], out: &mut Vec<T>) {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<W>());
    for chunk in bytes.chunks_exact(W::WIDTH) {
        let w = W::take(chunk);
        // SAFETY: T == W (proven by `Codec::resolve`), so this is an
        // identity copy.
        out.push(unsafe { std::mem::transmute_copy::<W, T>(&w) });
    }
}

/// The per-world element codec: a pair of monomorphised encode/decode
/// fns plus the wire width, resolved by `TypeId` probe so the
/// transport stays generic over [`crate::collectives::Element`]
/// without asking element types to know about serialization.
struct Codec<T> {
    elem_bytes: usize,
    enc: fn(&[T], &mut Vec<u8>),
    dec: fn(&[u8], &mut Vec<T>),
}

impl<T> Clone for Codec<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for Codec<T> {}

impl<T: 'static> Codec<T> {
    /// `None` when `T` is not one of the fixed-width primitives the
    /// wire can carry.
    fn resolve() -> Option<Codec<T>> {
        macro_rules! probe {
            ($($w:ty),*) => {$(
                if TypeId::of::<T>() == TypeId::of::<$w>() {
                    return Some(Codec {
                        elem_bytes: <$w as Prim>::WIDTH,
                        enc: enc_as::<$w, T>,
                        dec: dec_as::<$w, T>,
                    });
                }
            )*};
        }
        probe!(i8, u8, i16, u16, i32, u32, i64, u64, f32, f64);
        None
    }
}

fn not_encodable() -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidInput,
        "element type is not wire-encodable (not a fixed-width primitive)",
    )
}

// ---------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------

struct Hello {
    magic: u32,
    version: u16,
    p: u32,
    rank: u32,
    world_id: u64,
    elem_bytes: u32,
    epoch: u64,
}

enum Frame {
    Hello(Hello),
    Data { seq: u64, ack: u64, round: u32, src: u32, dst: u32, count: u32, payload: Vec<u8> },
    Ack { ack: u64 },
    Bye,
    Abort(String),
}

fn hello_frame(p: usize, rank: usize, world_id: u64, elem_bytes: usize, epoch: u64) -> Vec<u8> {
    let mut body = Vec::with_capacity(34);
    put_u32(&mut body, MAGIC);
    put_u16(&mut body, VERSION);
    put_u32(&mut body, p as u32);
    put_u32(&mut body, rank as u32);
    put_u64(&mut body, world_id);
    put_u32(&mut body, elem_bytes as u32);
    put_u64(&mut body, epoch);
    seal_crc(FT_HELLO, &body)
}

fn data_frame<T>(
    codec: &Codec<T>,
    seq: u64,
    ack: u64,
    round: usize,
    src: usize,
    dst: usize,
    data: &[T],
) -> Vec<u8> {
    let mut body = Vec::with_capacity(32 + data.len() * codec.elem_bytes);
    put_u64(&mut body, seq);
    put_u64(&mut body, ack);
    put_u32(&mut body, round as u32);
    put_u32(&mut body, src as u32);
    put_u32(&mut body, dst as u32);
    put_u32(&mut body, data.len() as u32);
    (codec.enc)(data, &mut body);
    seal_crc(FT_DATA, &body)
}

fn ack_frame(ack: u64) -> Vec<u8> {
    let mut body = Vec::with_capacity(8);
    put_u64(&mut body, ack);
    seal_crc(FT_ACK, &body)
}

fn bye_frame() -> Vec<u8> {
    seal_crc(FT_BYE, &[])
}

fn abort_frame(reason: &str) -> Vec<u8> {
    seal_crc(FT_ABORT, reason.as_bytes())
}

fn parse_hello(body: &[u8]) -> io::Result<Hello> {
    let mut b = Body::new(body);
    let magic = b.u32()?;
    let version = b.u16()?;
    let p = b.u32()?;
    let rank = b.u32()?;
    let world_id = b.u64()?;
    let elem_bytes = b.u32()?;
    // The epoch field arrived in v2; tolerate its absence here so a
    // v1 peer fails `vet_hello`'s version check with the useful
    // diagnosis instead of a bare short-body parse error.
    let epoch = if version >= 2 { b.u64()? } else { 0 };
    Ok(Hello { magic, version, p, rank, world_id, elem_bytes, epoch })
}

fn parse_frame(kind: u8, body: Vec<u8>) -> io::Result<Frame> {
    match kind {
        FT_HELLO => Ok(Frame::Hello(parse_hello(&body)?)),
        FT_DATA => {
            let mut b = Body::new(&body);
            let seq = b.u64()?;
            let ack = b.u64()?;
            let round = b.u32()?;
            let src = b.u32()?;
            let dst = b.u32()?;
            let count = b.u32()?;
            let payload = b.rest().to_vec();
            Ok(Frame::Data { seq, ack, round, src, dst, count, payload })
        }
        FT_ACK => {
            let mut b = Body::new(&body);
            Ok(Frame::Ack { ack: b.u64()? })
        }
        FT_BYE => Ok(Frame::Bye),
        FT_ABORT => Ok(Frame::Abort(String::from_utf8_lossy(&body).into_owned())),
        other => Err(bad_data(format!("wire: unknown frame type {other}"))),
    }
}

/// Validate a peer's `HELLO` against this world; returns the peer's
/// claimed rank.
fn vet_hello(
    h: &Hello,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
) -> Result<usize, String> {
    if h.magic != MAGIC {
        return Err(format!("handshake: bad magic {:#010x}", h.magic));
    }
    if h.version != VERSION {
        return Err(format!(
            "handshake: protocol version {} (this side speaks {VERSION})",
            h.version
        ));
    }
    if h.p as usize != p {
        return Err(format!("handshake: world size {} (expected {p})", h.p));
    }
    if h.world_id != world_id {
        return Err(format!(
            "handshake: world id {:#018x} (expected {world_id:#018x})",
            h.world_id
        ));
    }
    if h.elem_bytes as usize != elem_bytes {
        return Err(format!(
            "handshake: element width {} (expected {elem_bytes})",
            h.elem_bytes
        ));
    }
    if h.epoch != epoch {
        return Err(format!(
            "handshake: membership epoch {} (this world is epoch {epoch}) — \
             a straggler from a pre-shrink world",
            h.epoch
        ));
    }
    if h.rank as usize >= p {
        return Err(format!("handshake: rank {} out of range for p = {p}", h.rank));
    }
    Ok(h.rank as usize)
}

// ---------------------------------------------------------------------
// Wire-fault counters
// ---------------------------------------------------------------------

static GLOBAL_RETRANSMITS: AtomicU64 = AtomicU64::new(0);
static GLOBAL_DUP_DROPS: AtomicU64 = AtomicU64::new(0);
static GLOBAL_CRC_FAILS: AtomicU64 = AtomicU64::new(0);
static GLOBAL_ESCALATIONS: AtomicU64 = AtomicU64::new(0);

/// Process-wide [`WireFaults`] accumulated across every
/// [`SocketTransport`] endpoint this process ever assembled — live, so
/// a supervisor (the `cbcastd` stats line) can report wire health
/// without holding transport handles.
pub fn global_wire_faults() -> WireFaults {
    WireFaults {
        retransmits: GLOBAL_RETRANSMITS.load(Ordering::Relaxed),
        dup_drops: GLOBAL_DUP_DROPS.load(Ordering::Relaxed),
        crc_fails: GLOBAL_CRC_FAILS.load(Ordering::Relaxed),
        escalations: GLOBAL_ESCALATIONS.load(Ordering::Relaxed),
    }
}

/// Per-endpoint fault counters; every increment also feeds the
/// process-global accumulators behind [`global_wire_faults`].
#[derive(Default)]
struct WireCounters {
    retransmits: AtomicU64,
    dup_drops: AtomicU64,
    crc_fails: AtomicU64,
    escalations: AtomicU64,
}

impl WireCounters {
    fn retransmit(&self) {
        self.retransmits.fetch_add(1, Ordering::Relaxed);
        GLOBAL_RETRANSMITS.fetch_add(1, Ordering::Relaxed);
    }

    fn dup_drop(&self) {
        self.dup_drops.fetch_add(1, Ordering::Relaxed);
        GLOBAL_DUP_DROPS.fetch_add(1, Ordering::Relaxed);
    }

    fn crc_fail(&self) {
        self.crc_fails.fetch_add(1, Ordering::Relaxed);
        GLOBAL_CRC_FAILS.fetch_add(1, Ordering::Relaxed);
    }

    fn escalation(&self) {
        self.escalations.fetch_add(1, Ordering::Relaxed);
        GLOBAL_ESCALATIONS.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> WireFaults {
        WireFaults {
            retransmits: self.retransmits.load(Ordering::Relaxed),
            dup_drops: self.dup_drops.load(Ordering::Relaxed),
            crc_fails: self.crc_fails.load(Ordering::Relaxed),
            escalations: self.escalations.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------
// Per-link reliability state
// ---------------------------------------------------------------------

/// One unacknowledged frame in a link's retransmission queue. The
/// sealed bytes are immutable; a retransmission re-emits them as-is
/// (the stale piggybacked `ack` is harmless — cumulative ACKs are
/// monotone and the receiver takes the max).
struct Retx {
    seq: u64,
    frame: Vec<u8>,
    sent_at: Instant,
    attempts: u32,
}

/// The chaos shim threaded into one link's write path: a shared
/// [`FaultPlan`] plus this link's frame-index cursor and the
/// reorder-hold buffer.
struct LinkChaos {
    plan: FaultPlan,
    src: usize,
    dst: usize,
    next_idx: u64,
    /// Frames held by a `Reorder` verdict; emitted after the link's
    /// next frame (or by the ticker, whichever comes first).
    held: Vec<Vec<u8>>,
}

/// One link's write side plus its reliability state, shared by the
/// app thread (send), the link's reader thread (ACK/dedup processing)
/// and the endpoint's ticker (retransmission, idle-ACK).
struct LinkTx {
    stream: Stream,
    /// Next outgoing sequence number (from 1; 0 means "nothing").
    next_seq: u64,
    /// Highest cumulative ACK the peer has announced.
    acked: u64,
    /// Sent-but-unacknowledged frames, seq-ascending.
    queue: VecDeque<Retx>,
    /// Highest contiguously-delivered incoming sequence.
    rx_delivered: u64,
    /// Delivered sequences above the contiguous floor (the dedup
    /// window's sparse part).
    rx_seen: BTreeSet<u64>,
    /// Highest cumulative ACK we have announced to the peer.
    ack_sent: u64,
    /// A duplicate arrived: our last ACK may have been lost —
    /// re-announce it even if `ack_sent` already covers everything.
    reack: bool,
    chaos: Option<LinkChaos>,
    /// The peer was declared crashed on this link (budget exhausted or
    /// hard write error); stop writing, let the schedule surface it.
    dead: bool,
}

impl LinkTx {
    /// Emit one chaos-eligible frame (`DATA`/`ACK`): draw a verdict
    /// from the plan (if any) and apply it. Retransmissions pass
    /// through here too, drawing fresh verdicts.
    fn emit(&mut self, frame: &[u8]) -> io::Result<()> {
        let Some(ch) = self.chaos.as_mut() else {
            return self.stream.write_all(frame);
        };
        let idx = ch.next_idx;
        ch.next_idx += 1;
        let verdict = ch.plan.verdict(ch.src, ch.dst, idx);
        match verdict {
            Verdict::Deliver => self.stream.write_all(frame)?,
            Verdict::Drop => {}
            Verdict::Duplicate => {
                self.stream.write_all(frame)?;
                self.stream.write_all(frame)?;
            }
            Verdict::Reorder => {
                self.chaos.as_mut().unwrap().held.push(frame.to_vec());
            }
            Verdict::Delay(d) => {
                std::thread::sleep(d.min(Duration::from_millis(20)));
                self.stream.write_all(frame)?;
            }
            Verdict::Corrupt { bits, entropy } => {
                let mut copy = frame.to_vec();
                flip_bits(&mut copy, bits, entropy);
                self.stream.write_all(&copy)?;
            }
        }
        if !matches!(verdict, Verdict::Reorder) {
            self.flush_held()?;
        }
        Ok(())
    }

    /// Release any reorder-held frames, in hold order.
    fn flush_held(&mut self) -> io::Result<()> {
        let held = match self.chaos.as_mut() {
            Some(ch) if !ch.held.is_empty() => std::mem::take(&mut ch.held),
            _ => return Ok(()),
        };
        for f in held {
            self.stream.write_all(&f)?;
        }
        Ok(())
    }

    /// Control frames (`HELLO`/`BYE`/`ABORT`) bypass chaos and the
    /// retransmission queue: chaos models a lossy wire under an
    /// established link, and control frames are never sequenced.
    fn write_control(&mut self, frame: &[u8]) -> io::Result<()> {
        self.stream.write_all(frame)
    }
}

/// Flip `bits` bits at `entropy`-derived offsets, never touching the
/// 4-byte length prefix — a corrupted length desyncs the byte stream,
/// which no checksum can heal (offset collisions may cancel a flip;
/// the verdict then degenerates to `Deliver`, which is fine).
fn flip_bits(frame: &mut [u8], bits: u32, entropy: u64) {
    let span = frame.len().saturating_sub(4);
    if span == 0 {
        return;
    }
    let mut e = entropy;
    for _ in 0..bits {
        e = e.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut h = e;
        h ^= h >> 30;
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 27;
        let off = 4 + (h as usize) % span;
        let bit = ((h >> 59) & 7) as u32;
        frame[off] ^= 1u8 << bit;
    }
}

/// Lock a link, recovering the guard if a thread panicked while
/// holding it — link state is plain bookkeeping, safe to continue
/// with; the world-level diagnosis happens at the state mutex.
fn lock_link(l: &Mutex<LinkTx>) -> MutexGuard<'_, LinkTx> {
    match l.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Fold a peer's cumulative ACK into the link: advance the high-water
/// mark and drop covered frames off the retransmission queue.
fn process_ack(link: &Mutex<LinkTx>, ack: u64) {
    let mut tx = lock_link(link);
    if ack > tx.acked {
        tx.acked = ack;
    }
    while tx.queue.front().map_or(false, |r| r.seq <= tx.acked) {
        tx.queue.pop_front();
    }
}

/// Dedup-window check for an incoming `DATA` sequence. `true` means
/// first sighting — deliver it; `false` means duplicate — drop it and
/// schedule an ACK re-announcement (the duplicate usually means our
/// ACK was lost). Runs even when the world is poisoned, so peer
/// retransmission queues keep draining without spurious escalations
/// during teardown.
fn note_fresh(link: &Mutex<LinkTx>, seq: u64) -> bool {
    let mut tx = lock_link(link);
    if seq <= tx.rx_delivered || tx.rx_seen.contains(&seq) {
        tx.reack = true;
        return false;
    }
    tx.rx_seen.insert(seq);
    while tx.rx_seen.remove(&(tx.rx_delivered + 1)) {
        tx.rx_delivered += 1;
    }
    true
}

// ---------------------------------------------------------------------
// Mailbox + reader threads
// ---------------------------------------------------------------------

struct SockState<T> {
    /// round -> (from, payload); one-portedness means at most one live
    /// entry per round on a valid schedule.
    msgs: HashMap<usize, (usize, Vec<T>)>,
    /// `gone[r]`: rank `r`'s link reached EOF or said `BYE` — nothing
    /// further will ever arrive from it.
    gone: Vec<bool>,
    /// `crashed[r]`: rank `r`'s link died *without* a deliberate
    /// farewell (`BYE`/`ABORT`) — EOF out of nowhere, truncation, a
    /// reset, or an exhausted retransmission budget: the signature of
    /// a killed process, as opposed to a rank that finished or failed
    /// politely. Feeds [`Transport::failed_peers`].
    crashed: Vec<bool>,
    poisoned: Option<String>,
}

struct SockShared<T> {
    state: Mutex<SockState<T>>,
    cv: Condvar,
}

impl<T> SockShared<T> {
    /// Lock the endpoint state, converting a poisoned mutex (a reader
    /// or ticker thread panicked mid-update) into a world-poisoning
    /// diagnosis instead of propagating the panic or dying silently.
    fn lock_state(&self) -> MutexGuard<'_, SockState<T>> {
        match self.state.lock() {
            Ok(g) => g,
            Err(e) => {
                let mut g = e.into_inner();
                if g.poisoned.is_none() {
                    g.poisoned = Some(POISONED_MUTEX.to_string());
                }
                g
            }
        }
    }

    /// Set-once local poison + wake every waiter.
    fn poison(&self, reason: &str) {
        let mut st = self.lock_state();
        if st.poisoned.is_none() {
            st.poisoned = Some(reason.to_string());
        }
        drop(st);
        self.cv.notify_all();
    }

    /// `crashed` records whether the link died without a deliberate
    /// `BYE`/`ABORT` first — the crash signature.
    fn mark_gone(&self, peer: usize, crashed: bool) {
        let mut st = self.lock_state();
        st.gone[peer] = true;
        if crashed {
            st.crashed[peer] = true;
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Send-side death declaration (retry budget exhausted, queue
    /// overflow, or a hard write error): mark the peer crashed —
    /// *unless* it already departed deliberately (`BYE`/`ABORT`), in
    /// which case the broken pipe is expected teardown, not a crash.
    /// Returns whether the peer was newly marked.
    fn mark_send_dead(&self, peer: usize) -> bool {
        let mut st = self.lock_state();
        if st.gone[peer] {
            return false;
        }
        st.gone[peer] = true;
        st.crashed[peer] = true;
        drop(st);
        self.cv.notify_all();
        true
    }
}

struct ReaderCtx<T> {
    shared: Arc<SockShared<T>>,
    link: Arc<Mutex<LinkTx>>,
    counters: Arc<WireCounters>,
    codec: Codec<T>,
    me: usize,
    p: usize,
    world_id: u64,
    epoch: u64,
    peer: usize,
    /// The link's first frame must be a valid `HELLO` (false when the
    /// rendezvous already validated it synchronously).
    expect_hello: bool,
}

/// One reader thread per peer link: verifies each frame's checksum,
/// runs the ACK/dedup machinery, and drains verified `DATA` into the
/// shared mailbox under the same round-tag matching as
/// `ThreadTransport`'s mailboxes. After a poison it keeps draining
/// (and discarding) so a remote sender's `write_all` never blocks on
/// a full socket buffer — and keeps ACKing, so peer retransmission
/// queues settle without spurious escalations during teardown.
///
/// The reader also runs the crash detector: a link that terminates
/// without the peer having announced its departure first (`BYE` on
/// clean completion, `ABORT` on failure) is marked **crashed** — a
/// killed process never says goodbye, a deliberate one always does.
fn reader_loop<T: Send + 'static>(mut rx: Stream, mut ctx: ReaderCtx<T>) {
    // Has the peer announced its departure (BYE or ABORT)? Link death
    // after an announcement is expected teardown; before one, a crash.
    let mut deliberate = false;
    loop {
        let frame = match read_wire_frame(&mut rx) {
            // EOF at a frame boundary: the peer is gone. Without a
            // prior BYE/ABORT this is the crash signature — a dropped
            // endpoint slams the socket with no farewell frame.
            Ok(WireRead::Eof) => {
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
            // A corrupted frame is a transient fault, not a protocol
            // violation: discard it and let the sender's
            // retransmission heal the loss.
            Ok(WireRead::CrcMismatch) => {
                ctx.counters.crc_fail();
                continue;
            }
            Ok(WireRead::Frame(kind, body)) => match parse_frame(kind, body) {
                Ok(f) => f,
                Err(e) => {
                    ctx.shared.poison(&format!("wire: rank {}: {e}", ctx.peer));
                    continue;
                }
            },
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                ctx.shared
                    .poison(&format!("wire: truncated frame from rank {}", ctx.peer));
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
            // Reset / broken pipe etc.: the link is dead.
            Err(_) => {
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
        };
        match frame {
            Frame::Hello(h) => {
                if !ctx.expect_hello {
                    ctx.shared
                        .poison(&format!("wire: duplicate HELLO from rank {}", ctx.peer));
                } else {
                    match vet_hello(&h, ctx.p, ctx.world_id, ctx.codec.elem_bytes, ctx.epoch) {
                        Ok(r) if r == ctx.peer => ctx.expect_hello = false,
                        Ok(r) => ctx.shared.poison(&format!(
                            "wire: link to rank {} answered as rank {r}",
                            ctx.peer
                        )),
                        Err(m) => ctx.shared.poison(&format!("wire: rank {}: {m}", ctx.peer)),
                    }
                }
            }
            Frame::Ack { ack } => process_ack(&ctx.link, ack),
            Frame::Data { .. } if ctx.expect_hello => {
                ctx.shared
                    .poison(&format!("wire: rank {} sent data before HELLO", ctx.peer));
            }
            Frame::Data { seq, ack, round, src, dst, count, payload } => {
                // The piggybacked ACK is good even when the data
                // itself turns out to be a duplicate or torn.
                process_ack(&ctx.link, ack);
                if src as usize != ctx.peer || dst as usize != ctx.me {
                    ctx.shared.poison(&format!(
                        "wire: misrouted frame (round {round}, {src} -> {dst}) on link {} <- {}",
                        ctx.me, ctx.peer
                    ));
                    continue;
                }
                if count as usize * ctx.codec.elem_bytes != payload.len() {
                    ctx.shared.poison(&format!(
                        "wire: torn payload from rank {} in round {round} \
                         ({} bytes for count {count})",
                        ctx.peer,
                        payload.len()
                    ));
                    continue;
                }
                if !note_fresh(&ctx.link, seq) {
                    // Duplicate: the wire (or a retransmission whose
                    // original won) replayed it. Dropped exactly here;
                    // the mailbox never sees it, so ReceivePortBusy
                    // still means a genuinely broken schedule.
                    ctx.counters.dup_drop();
                    continue;
                }
                let mut data = Vec::with_capacity(count as usize);
                (ctx.codec.dec)(&payload, &mut data);
                let round = round as usize;
                let mut st = ctx.shared.lock_state();
                if st.poisoned.is_some() {
                    // Drain-and-discard: keep the peer's writes moving.
                    continue;
                }
                match st.msgs.get(&round).map(|(f, _)| *f) {
                    Some(first_from) => {
                        let e = SimError::ReceivePortBusy {
                            round,
                            to: ctx.me,
                            first_from,
                            second_from: ctx.peer,
                        };
                        drop(st);
                        ctx.shared.poison(&e.to_string());
                    }
                    None => {
                        st.msgs.insert(round, (ctx.peer, data));
                        drop(st);
                        ctx.shared.cv.notify_all();
                    }
                }
            }
            Frame::Bye => {
                // "Nothing further from me" — but keep the reader
                // draining: post-BYE frames (re-ACKs of our data, a
                // retransmission racing the BYE) must still be
                // processed, or a chaos-lost final ACK could never be
                // re-announced and the peer's close-linger would
                // exhaust its budget and spuriously crash-mark us.
                deliberate = true;
                ctx.shared.mark_gone(ctx.peer, false);
            }
            Frame::Abort(reason) => {
                // Poison propagated from a failed remote rank; keep
                // draining until its write side closes. A failed rank
                // that *announced* its failure did not crash.
                deliberate = true;
                ctx.shared.poison(&reason);
            }
        }
    }
}

// ---------------------------------------------------------------------
// The ticker: retransmission + idle-ACK fallback
// ---------------------------------------------------------------------

struct TickerCtx<T> {
    shared: Arc<SockShared<T>>,
    links: Vec<Option<Arc<Mutex<LinkTx>>>>,
    counters: Arc<WireCounters>,
    stop: Arc<AtomicBool>,
}

/// One ticker thread per endpoint. Every [`TICK`] it sweeps the
/// links: releases reorder-held frames, announces ACK debt that has
/// no outgoing `DATA` to piggyback on, and retransmits overdue queue
/// entries under the capped backoff. A link whose budget is exhausted
/// (or whose stream errors) is declared dead and its peer escalated
/// to `crashed` — the hand-off to the membership shrink path.
fn ticker_loop<T: Send + 'static>(ctx: TickerCtx<T>) {
    loop {
        std::thread::sleep(TICK);
        if ctx.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut newly_dead: Vec<usize> = Vec::new();
        for (peer, slot) in ctx.links.iter().enumerate() {
            let Some(link) = slot else { continue };
            let mut tx = lock_link(link);
            if tx.dead {
                continue;
            }
            if tx.flush_held().is_err() {
                tx.dead = true;
                newly_dead.push(peer);
                continue;
            }
            if tx.rx_delivered > tx.ack_sent || tx.reack {
                let frame = ack_frame(tx.rx_delivered);
                match tx.emit(&frame) {
                    Ok(()) => {
                        tx.ack_sent = tx.rx_delivered;
                        tx.reack = false;
                    }
                    Err(_) => {
                        tx.dead = true;
                        newly_dead.push(peer);
                        continue;
                    }
                }
            }
            let now = Instant::now();
            for i in 0..tx.queue.len() {
                let (overdue, exhausted, frame) = {
                    let r = &tx.queue[i];
                    let overdue = now.duration_since(r.sent_at) >= rto_for(r.attempts);
                    let exhausted = overdue && r.attempts >= MAX_ATTEMPTS;
                    let frame = if overdue && !exhausted { r.frame.clone() } else { Vec::new() };
                    (overdue, exhausted, frame)
                };
                if !overdue {
                    continue;
                }
                if exhausted {
                    tx.dead = true;
                    newly_dead.push(peer);
                    break;
                }
                tx.queue[i].attempts += 1;
                tx.queue[i].sent_at = now;
                ctx.counters.retransmit();
                if tx.emit(&frame).is_err() {
                    tx.dead = true;
                    newly_dead.push(peer);
                    break;
                }
            }
        }
        if ctx.stop.load(Ordering::SeqCst) {
            return;
        }
        // All link guards are dropped; only now touch the state mutex
        // (lock order: link, then state — never both at once).
        for peer in newly_dead {
            if ctx.shared.mark_send_dead(peer) {
                ctx.counters.escalation();
            }
        }
    }
}

// ---------------------------------------------------------------------
// SocketTransport
// ---------------------------------------------------------------------

static WORLD_SEQ: AtomicU64 = AtomicU64::new(1);

/// A fresh process-unique world id for [`SocketTransport::pair_world`]
/// and hand-rolled rendezvous (multi-process worlds agree on one out
/// of band — CLI flag, env, launcher).
pub fn fresh_world_id() -> u64 {
    ((std::process::id() as u64) << 32) ^ WORLD_SEQ.fetch_add(1, Ordering::Relaxed)
}

/// One rank's endpoint of a socket world: a [`Transport`] whose
/// messages cross real OS sockets (Unix-domain or TCP). Per-peer
/// reader threads feed a mutex/condvar mailbox with the exact
/// round-tag matching of [`super::transport::ThreadTransport`]; a
/// per-endpoint ticker drives the v3 reliability layer (CRC,
/// seq/ack, retransmission, dedup — see the module docs); the
/// one-ported round discipline is enforced endpoint-side, and wire
/// faults surface as typed [`TransportError`]s.
pub struct SocketTransport<T> {
    rank: usize,
    p: usize,
    epoch: u64,
    links: Vec<Option<Arc<Mutex<LinkTx>>>>,
    shared: Arc<SockShared<T>>,
    counters: Arc<WireCounters>,
    stop: Arc<AtomicBool>,
    codec: Codec<T>,
    timeout: Duration,
    disc: Discipline,
    closed: bool,
}

impl<T: Send + 'static> SocketTransport<T> {
    /// Endpoints for all `p` ranks of a fresh in-process world over
    /// `UnixStream::pair` meshes — real sockets, zero rendezvous.
    /// Receive deadline from
    /// [`super::transport::configured_timeout`]. Fails when `T` is
    /// not wire-encodable or the process is out of descriptors.
    pub fn pair_world(p: usize) -> io::Result<Vec<SocketTransport<T>>> {
        Self::pair_world_with_timeout(p, configured_timeout())
    }

    /// [`SocketTransport::pair_world`] with an explicit receive
    /// deadline (failure-injection tests use a short one).
    pub fn pair_world_with_timeout(
        p: usize,
        timeout: Duration,
    ) -> io::Result<Vec<SocketTransport<T>>> {
        Self::pair_build(p, timeout, None)
    }

    /// [`SocketTransport::pair_world_with_timeout`] with a seeded
    /// [`FaultPlan`] threaded into every link's write path — the
    /// chaos plane's byte-level injection point. The reliability
    /// layer heals the injected faults in place; only a plan that
    /// starves a link past the retry budget (e.g.
    /// [`FaultPlan::blackhole`]) escalates into
    /// [`Transport::failed_peers`].
    pub fn pair_world_chaos(
        p: usize,
        timeout: Duration,
        plan: FaultPlan,
    ) -> io::Result<Vec<SocketTransport<T>>> {
        Self::pair_build(p, timeout, Some(plan))
    }

    fn pair_build(
        p: usize,
        timeout: Duration,
        chaos: Option<FaultPlan>,
    ) -> io::Result<Vec<SocketTransport<T>>> {
        assert!(p > 0);
        let world_id = fresh_world_id();
        let mut rows: Vec<Vec<Option<(Stream, bool)>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for i in 0..p {
            for j in (i + 1)..p {
                let (a, b) = UnixStream::pair()?;
                rows[i][j] = Some((Stream::Unix(a), true));
                rows[j][i] = Some((Stream::Unix(b), true));
            }
        }
        rows.into_iter()
            .enumerate()
            .map(|(rank, row)| Self::assemble(rank, p, world_id, 0, row, timeout, true, chaos))
            .collect()
    }

    /// This rank's endpoint of a multi-process world over Unix-domain
    /// sockets: rank `r` listens at `dir/rank-{r}.sock`, dials every
    /// lower rank and accepts from every higher rank. All ranks must
    /// agree on `(p, world_id, dir)`; `timeout` bounds the whole
    /// rendezvous and becomes the receive deadline.
    pub fn uds_world(
        rank: usize,
        p: usize,
        world_id: u64,
        dir: &Path,
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        Self::uds_world_epoch(rank, p, world_id, 0, dir, timeout)
    }

    /// [`SocketTransport::uds_world`] for a post-shrink world: the
    /// recovery plane rebuilds survivors under `epoch + 1` (with a
    /// fresh socket directory), and the epoch-stamped handshake
    /// refuses stragglers that still think they live in the dead
    /// epoch. `rank` and `p` are the *dense* (post-shrink) values.
    pub fn uds_world_epoch(
        rank: usize,
        p: usize,
        world_id: u64,
        epoch: u64,
        dir: &Path,
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        assert!(rank < p);
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let listener = if rank + 1 < p {
            let path = dir.join(format!("rank-{rank}.sock"));
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path)?;
            l.set_nonblocking(true)?;
            Some(l)
        } else {
            None
        };
        let deadline = Instant::now() + timeout;
        let row = mesh_rendezvous(
            rank,
            p,
            world_id,
            codec.elem_bytes,
            epoch,
            deadline,
            |peer| {
                UnixStream::connect(dir.join(format!("rank-{peer}.sock"))).map(Stream::Unix)
            },
            || {
                accept_deadline(deadline, || {
                    let (s, _) = listener.as_ref().unwrap().accept()?;
                    s.set_nonblocking(false)?;
                    Ok(Stream::Unix(s))
                })
            },
        )?;
        Self::assemble(rank, p, world_id, epoch, row, timeout, false, None)
    }

    /// This rank's endpoint of a multi-process world over TCP:
    /// `addrs[r]` is rank `r`'s listen address; rank `r` dials every
    /// lower rank and accepts from every higher rank. Same rendezvous
    /// contract as [`SocketTransport::uds_world`].
    pub fn tcp_world(
        rank: usize,
        p: usize,
        world_id: u64,
        addrs: &[SocketAddr],
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        assert!(rank < p && addrs.len() == p);
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let listener = if rank + 1 < p {
            let l = TcpListener::bind(addrs[rank])?;
            l.set_nonblocking(true)?;
            Some(l)
        } else {
            None
        };
        let deadline = Instant::now() + timeout;
        let row = mesh_rendezvous(
            rank,
            p,
            world_id,
            codec.elem_bytes,
            0,
            deadline,
            |peer| {
                let s = TcpStream::connect(addrs[peer])?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            },
            || {
                accept_deadline(deadline, || {
                    let (s, _) = listener.as_ref().unwrap().accept()?;
                    s.set_nonblocking(false)?;
                    s.set_nodelay(true)?;
                    Ok(Stream::Tcp(s))
                })
            },
        )?;
        Self::assemble(rank, p, world_id, 0, row, timeout, false, None)
    }

    /// Wire a resolved mesh into an endpoint: wrap each link in its
    /// reliability state (with the chaos shim, if any), spawn one
    /// reader thread per link (`expect_hello` links validate the
    /// peer's `HELLO` as their first frame), write our `HELLO` first
    /// when `send_hello`, and start the endpoint's ticker.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        rank: usize,
        p: usize,
        world_id: u64,
        epoch: u64,
        row: Vec<Option<(Stream, bool)>>,
        timeout: Duration,
        send_hello: bool,
        chaos: Option<FaultPlan>,
    ) -> io::Result<SocketTransport<T>> {
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let shared = Arc::new(SockShared {
            state: Mutex::new(SockState {
                msgs: HashMap::new(),
                gone: vec![false; p],
                crashed: vec![false; p],
                poisoned: None,
            }),
            cv: Condvar::new(),
        });
        let counters = Arc::new(WireCounters::default());
        let stop = Arc::new(AtomicBool::new(false));
        let hello = hello_frame(p, rank, world_id, codec.elem_bytes, epoch);
        let mut links: Vec<Option<Arc<Mutex<LinkTx>>>> = Vec::with_capacity(p);
        for (peer, slot) in row.into_iter().enumerate() {
            let Some((mut stream, expect_hello)) = slot else {
                links.push(None);
                continue;
            };
            if send_hello {
                // HELLO bypasses chaos: the shim models a lossy wire
                // under an established link, not a failed rendezvous.
                stream.write_all(&hello)?;
            }
            let rx = stream.try_clone()?;
            let link = Arc::new(Mutex::new(LinkTx {
                stream,
                next_seq: 1,
                acked: 0,
                queue: VecDeque::new(),
                rx_delivered: 0,
                rx_seen: BTreeSet::new(),
                ack_sent: 0,
                reack: false,
                chaos: chaos.map(|plan| LinkChaos {
                    plan,
                    src: rank,
                    dst: peer,
                    next_idx: 0,
                    held: Vec::new(),
                }),
                dead: false,
            }));
            let ctx = ReaderCtx {
                shared: shared.clone(),
                link: link.clone(),
                counters: counters.clone(),
                codec,
                me: rank,
                p,
                world_id,
                epoch,
                peer,
                expect_hello,
            };
            std::thread::Builder::new()
                .name(format!("cbwire-{rank}<-{peer}"))
                .stack_size(128 * 1024)
                .spawn(move || reader_loop(rx, ctx))?;
            links.push(Some(link));
        }
        let tctx = TickerCtx {
            shared: shared.clone(),
            links: links.clone(),
            counters: counters.clone(),
            stop: stop.clone(),
        };
        std::thread::Builder::new()
            .name(format!("cbtick-{rank}"))
            .stack_size(64 * 1024)
            .spawn(move || ticker_loop(tctx))?;
        Ok(SocketTransport {
            rank,
            p,
            epoch,
            links,
            shared,
            counters,
            stop,
            codec,
            timeout,
            disc: Discipline::default(),
            closed: false,
        })
    }

    /// The membership epoch this world was assembled under (0 for the
    /// original, pre-shrink world).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The poison reason, if this endpoint's world has been poisoned —
    /// lets a supervisor distinguish "this world is dead" from "this
    /// verb failed" without issuing another verb.
    pub fn poisoned(&self) -> Option<String> {
        self.shared.lock_state().poisoned.clone()
    }

    /// Poison the local world and broadcast `ABORT` so remote worlds
    /// poison too — every blocked and future verb on any endpoint of
    /// the world fails with [`TransportError::Shutdown`] instead of
    /// deadlocking.
    fn poison(&self, reason: &str) {
        self.shared.poison(reason);
        let frame = abort_frame(reason);
        for link in self.links.iter().flatten() {
            let mut tx = lock_link(link);
            if !tx.dead {
                let _ = tx.write_control(&frame);
            }
        }
    }

    /// Wait (bounded by [`LINGER_MAX`]) until every live link has
    /// settled: retransmission queue empty (everything acknowledged),
    /// no reorder-held frames, no unannounced ACK debt. Called before
    /// `BYE` on a clean close, so chaos-dropped final frames heal
    /// before we promise "nothing further from me".
    fn linger(&self) {
        let deadline = Instant::now() + LINGER_MAX;
        loop {
            let mut settled = true;
            for link in self.links.iter().flatten() {
                let tx = lock_link(link);
                if tx.dead {
                    continue;
                }
                let held_empty = tx.chaos.as_ref().map_or(true, |c| c.held.is_empty());
                if !tx.queue.is_empty()
                    || !held_empty
                    || tx.rx_delivered > tx.ack_sent
                    || tx.reack
                {
                    settled = false;
                    break;
                }
            }
            if settled || Instant::now() >= deadline {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl<T: Send + 'static> Transport<T> for SocketTransport<T> {
    fn p(&self) -> usize {
        self.p
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        self.disc.check_send(self.rank, round)?;
        if peer == self.rank {
            return Err(TransportError::Machine(SimError::SelfMessage {
                round,
                rank: self.rank,
            }));
        }
        if peer >= self.p {
            return Err(TransportError::Machine(SimError::BadTarget {
                round,
                rank: self.rank,
                to: peer,
            }));
        }
        {
            let st = self.shared.lock_state();
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
        }
        let link = self.links[peer].as_ref().expect("mesh link missing").clone();
        let mut tx = lock_link(&link);
        if tx.dead {
            // Posted semantics: the peer is already declared crashed;
            // the schedule surfaces that at the receiver as
            // MissingMessage, the detector as failed_peers().
            return Ok(());
        }
        if tx.queue.len() >= RETX_QUEUE_MAX {
            // The peer acknowledges nothing and we keep producing:
            // same conclusion as budget exhaustion, reached by volume.
            tx.dead = true;
            drop(tx);
            if self.shared.mark_send_dead(peer) {
                self.counters.escalation();
            }
            return Ok(());
        }
        let seq = tx.next_seq;
        tx.next_seq += 1;
        let ack = tx.rx_delivered;
        let frame = data_frame(&self.codec, seq, ack, round, self.rank, peer, &data);
        tx.queue.push_back(Retx {
            seq,
            frame: frame.clone(),
            sent_at: Instant::now(),
            attempts: 0,
        });
        // The piggybacked ACK covers any pending re-announcement.
        if ack > tx.ack_sent {
            tx.ack_sent = ack;
        }
        tx.reack = false;
        let res = tx.emit(&frame);
        if res.is_err() {
            tx.dead = true;
        }
        drop(tx);
        // A write error is the peer's problem, not the world's: mark
        // it crashed (unless it departed deliberately) and let the
        // schedule surface the gap — never poison on send.
        if res.is_err() && self.shared.mark_send_dead(peer) {
            self.counters.escalation();
        }
        Ok(())
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        // Free-running like ThreadTransport: the wire needs no seal;
        // keep the discipline honest.
        self.disc.check_flush(self.rank, round)
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        self.disc.check_recv(self.rank, round)?;
        let deadline = Instant::now() + self.timeout;
        let mut st = self.shared.lock_state();
        loop {
            // Abort semantics: once poisoned nothing more is
            // delivered, mirroring the lockstep mid-round abort.
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
            match st.msgs.get(&round).map(|(from, _)| *from) {
                Some(from) if from == peer => {
                    let (_, data) = st.msgs.remove(&round).unwrap();
                    return Ok(data);
                }
                Some(from) => {
                    // One-ported: a same-round message from anyone
                    // else means the schedules disagree.
                    let e = SimError::UnexpectedMessage {
                        round,
                        to: self.rank,
                        from,
                        expected: Some(peer),
                    };
                    drop(st);
                    self.poison(&e.to_string());
                    return Err(TransportError::Machine(e));
                }
                None => {}
            }
            if peer >= self.p || st.gone[peer] {
                // The peer's link is closed and its message for this
                // round never arrived: it is a rank that died (or a
                // schedule that references a message nobody sends) —
                // the lockstep vocabulary for that is MissingMessage.
                let e = SimError::MissingMessage {
                    round,
                    rank: self.rank,
                    expected_from: peer,
                };
                drop(st);
                self.poison(&e.to_string());
                return Err(TransportError::Machine(e));
            }
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                let e = TransportError::Timeout { rank: self.rank, round, from: peer };
                self.poison(&e.to_string());
                return Err(e);
            }
            st = match self.shared.cv.wait_timeout(st, deadline - now) {
                Ok((guard, _)) => guard,
                Err(e) => {
                    // A thread panicked while holding the state: turn
                    // the panic into the poisoned-world diagnosis.
                    let (mut guard, _) = e.into_inner();
                    if guard.poisoned.is_none() {
                        guard.poisoned = Some(POISONED_MUTEX.to_string());
                    }
                    guard
                }
            };
        }
    }

    fn failed_peers(&self) -> Vec<usize> {
        let st = self.shared.lock_state();
        st.crashed
            .iter()
            .enumerate()
            .filter_map(|(r, &c)| c.then_some(r))
            .collect()
    }

    fn wire_faults(&self) -> Option<WireFaults> {
        Some(self.counters.snapshot())
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        if self.closed {
            return Ok(());
        }
        self.closed = true;
        match error {
            Some(reason) => {
                // Failed rank: poison locally, tell every peer why
                // (ABORT). Write sides stay open — the reliability
                // layer keeps ACKing so peer queues drain; Drop tears
                // the sockets down.
                self.poison(reason);
            }
            None => {
                // Clean completion: settle in-flight retransmissions
                // and ACK debt first, then BYE tells peers "nothing
                // further from me" so a schedule still expecting a
                // message surfaces MissingMessage, not a 30 s timeout.
                self.linger();
                let bye = bye_frame();
                for link in self.links.iter().flatten() {
                    let mut tx = lock_link(link);
                    if !tx.dead {
                        let _ = tx.write_control(&bye);
                    }
                }
            }
        }
        Ok(())
    }
}

impl<T> Drop for SocketTransport<T> {
    fn drop(&mut self) {
        // Stop the ticker (it exits within one tick; not joined — a
        // teardown should not wait on a sleeper) and slam every
        // socket. shutdown(Both) reaches the reader/ticker fd clones
        // too. After a deliberate close() the peers already hold our
        // BYE/ABORT, so the EOF is expected teardown; without one,
        // the EOF-without-farewell is exactly the crash signature the
        // peers' readers are built to detect.
        self.stop.store(true, Ordering::SeqCst);
        for link in self.links.iter().flatten() {
            let tx = lock_link(link);
            let _ = tx.stream.shutdown(Shutdown::Both);
        }
    }
}

// ---------------------------------------------------------------------
// Rendezvous helpers
// ---------------------------------------------------------------------

/// Poll a nonblocking accept until `deadline`.
fn accept_deadline(
    deadline: Instant,
    mut accept_one: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Stream> {
    loop {
        match accept_one() {
            Ok(s) => return Ok(s),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "wire: accept timed out waiting for higher ranks",
                    ));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Retry a dial until `deadline` while the peer has not bound yet.
fn dial_retry(
    deadline: Instant,
    mut dial: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Stream> {
    loop {
        match dial() {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        format!("wire: connect timed out: {e}"),
                    ));
                }
                match e.kind() {
                    io::ErrorKind::ConnectionRefused
                    | io::ErrorKind::NotFound
                    | io::ErrorKind::AddrNotAvailable => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    _ => return Err(e),
                }
            }
        }
    }
}

/// Full-mesh rendezvous: dial every lower rank (sending our `HELLO`
/// immediately; theirs is validated asynchronously by the link's
/// reader), accept from every higher rank (reading and validating the
/// peer's `HELLO` synchronously to identify it — accept order is
/// arbitrary — then answering with ours).
#[allow(clippy::too_many_arguments)]
fn mesh_rendezvous(
    rank: usize,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
    deadline: Instant,
    dial: impl Fn(usize) -> io::Result<Stream>,
    mut accept: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Vec<Option<(Stream, bool)>>> {
    let hello = hello_frame(p, rank, world_id, elem_bytes, epoch);
    let mut row: Vec<Option<(Stream, bool)>> = (0..p).map(|_| None).collect();
    for peer in 0..rank {
        let mut s = dial_retry(deadline, || dial(peer))?;
        s.write_all(&hello)?;
        row[peer] = Some((s, true));
    }
    for _ in (rank + 1)..p {
        let mut s = accept()?;
        let left = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        s.set_read_timeout(Some(left))?;
        let peer = read_hello_sync(&mut s, p, world_id, elem_bytes, epoch)?;
        if peer <= rank || row[peer].is_some() {
            return Err(bad_data(format!(
                "handshake: unexpected connection from rank {peer}"
            )));
        }
        s.set_read_timeout(None)?;
        s.write_all(&hello)?;
        row[peer] = Some((s, false));
    }
    Ok(row)
}

/// Synchronously read and validate a peer's `HELLO`; returns its rank.
/// A checksum failure here is a hard error, not a retransmittable
/// miss: chaos never touches `HELLO`, so a corrupt one means a broken
/// or hostile dialer.
fn read_hello_sync(
    s: &mut Stream,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
) -> io::Result<usize> {
    let (kind, body) = match read_wire_frame(s)? {
        WireRead::Eof => {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "handshake: peer closed before HELLO",
            ));
        }
        WireRead::CrcMismatch => {
            return Err(bad_data("handshake: HELLO failed its checksum".into()));
        }
        WireRead::Frame(kind, body) => (kind, body),
    };
    if kind != FT_HELLO {
        return Err(bad_data(format!(
            "handshake: first frame type {kind}, expected HELLO"
        )));
    }
    let h = parse_hello(&body)?;
    vet_hello(&h, p, world_id, elem_bytes, epoch).map_err(bad_data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn world(p: usize) -> Vec<SocketTransport<i64>> {
        SocketTransport::pair_world(p).expect("pair world")
    }

    #[test]
    fn codec_roundtrips_every_primitive() {
        fn rt<T: PartialEq + std::fmt::Debug + Copy + Send + 'static>(xs: Vec<T>) {
            let c = Codec::<T>::resolve().unwrap();
            let mut bytes = Vec::new();
            (c.enc)(&xs, &mut bytes);
            assert_eq!(bytes.len(), xs.len() * c.elem_bytes);
            let mut back = Vec::new();
            (c.dec)(&bytes, &mut back);
            assert_eq!(back, xs);
        }
        rt(vec![-1i8, 7]);
        rt(vec![1u8, 255]);
        rt(vec![-300i16, 300]);
        rt(vec![9u16, 0]);
        rt(vec![-2i32, 9]);
        rt(vec![70_000u32, 3]);
        rt(vec![-5i64, 1 << 40]);
        rt(vec![u64::MAX, 0]);
        rt(vec![1.5f32, -0.25]);
        rt(vec![std::f64::consts::PI, -1e300]);
    }

    #[test]
    fn non_wire_encodable_elements_are_rejected() {
        let err = SocketTransport::<[u8; 3]>::pair_world(2).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    #[test]
    fn truncated_and_boundary_frames_are_detected() {
        // Claims 5 body+type bytes, carries 3: truncation.
        let mut short: &[u8] = &[5, 0, 0, 0, FT_DATA, 1, 2];
        let e = read_raw_frame(&mut short).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof);
        // EOF at a frame boundary is clean.
        let mut empty: &[u8] = &[];
        assert!(read_raw_frame(&mut empty).unwrap().is_none());
        // Zero-length frames are corrupt.
        let mut zero: &[u8] = &[0, 0, 0, 0];
        assert_eq!(
            read_raw_frame(&mut zero).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
    }

    #[test]
    fn crc32_matches_the_known_check_value() {
        // The IEEE CRC32 check value: crc of ascii "123456789".
        assert_eq!(crc32(b'1', b"23456789"), 0xCBF4_3926);
    }

    #[test]
    fn sealed_frames_carry_and_verify_their_checksum() {
        let f = seal_crc(FT_DATA, &[1, 2, 3]);
        assert_eq!(f.len(), 4 + 1 + 3 + 4);
        let mut r: &[u8] = &f;
        match read_wire_frame(&mut r).unwrap() {
            WireRead::Frame(kind, body) => {
                assert_eq!(kind, FT_DATA);
                assert_eq!(body, vec![1, 2, 3]);
            }
            other => panic!("expected a verified frame, got {other:?}"),
        }
        // A flipped body bit fails the checksum.
        let mut bad = f.clone();
        bad[6] ^= 0x40;
        let mut r: &[u8] = &bad;
        assert!(matches!(read_wire_frame(&mut r).unwrap(), WireRead::CrcMismatch));
        // The type byte is covered too.
        let mut badk = f.clone();
        badk[4] ^= 0x01;
        let mut r: &[u8] = &badk;
        assert!(matches!(read_wire_frame(&mut r).unwrap(), WireRead::CrcMismatch));
        // EOF at a boundary is still clean.
        let mut empty: &[u8] = &[];
        assert!(matches!(read_wire_frame(&mut empty).unwrap(), WireRead::Eof));
    }

    #[test]
    fn pair_world_moves_round_tagged_messages() {
        let mut w = world(3);
        let mut t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        let h1 = thread::spawn(move || {
            t1.send(0, 0, vec![7i64, 8]).unwrap();
            t1.flush(0).unwrap();
            let got = t1.recv(1, 2).unwrap();
            t1.close(None).unwrap();
            got
        });
        let h2 = thread::spawn(move || {
            // Out-of-order arrival relative to rank 1's round cursor is
            // fine: messages match on their round tag.
            t2.send(1, 1, vec![5i64]).unwrap();
            t2.flush(1).unwrap();
            t2.close(None).unwrap();
        });
        t0.flush(0).unwrap();
        let got = t0.recv(0, 1).unwrap();
        t0.close(None).unwrap();
        assert_eq!(got, vec![7, 8]);
        assert_eq!(h1.join().unwrap(), vec![5]);
        h2.join().unwrap();
    }

    #[test]
    fn timeout_poisons_the_world_across_the_wire() {
        let mut w =
            SocketTransport::<i64>::pair_world_with_timeout(2, Duration::from_millis(50)).unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        let e0 = t0.recv(0, 1).unwrap_err(); // nobody sends
        assert!(matches!(e0, TransportError::Timeout { rank: 0, round: 0, from: 1 }), "{e0:?}");
        // The ABORT broadcast poisons rank 1's world too.
        let e1 = t1.recv(0, 0).unwrap_err();
        assert!(matches!(e1, TransportError::Shutdown { .. }), "{e1:?}");
    }

    #[test]
    fn dropped_peer_surfaces_missing_message() {
        let mut w = world(2);
        let t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        drop(t1); // crash without close(): EOF without BYE
        let e = t0.recv(0, 1).unwrap_err();
        assert_eq!(
            e,
            TransportError::Machine(SimError::MissingMessage {
                round: 0,
                rank: 0,
                expected_from: 1
            })
        );
    }

    #[test]
    fn clean_close_without_expected_message_is_missing_message() {
        let mut w = world(2);
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t1.close(None).unwrap(); // BYE: "nothing further from me"
        let e = t0.recv(0, 1).unwrap_err();
        assert_eq!(
            e,
            TransportError::Machine(SimError::MissingMessage {
                round: 0,
                rank: 0,
                expected_from: 1
            })
        );
    }

    #[test]
    fn receive_port_collision_poisons_the_world() {
        let mut w = world(3);
        let mut t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t1.send(0, 0, vec![1]).unwrap();
        t2.send(0, 0, vec![2]).unwrap();
        // Rank 0's reader rejects whichever round-0 delivery lands
        // second; wait for both to land before receiving.
        thread::sleep(Duration::from_millis(100));
        let e = t0.recv(0, 1).unwrap_err();
        assert!(matches!(e, TransportError::Shutdown { .. }), "{e:?}");
    }

    #[test]
    fn round_discipline_is_enforced() {
        let mut w = world(2);
        let _t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t0.send(3, 1, vec![1]).unwrap();
        let e = t0.send(3, 1, vec![2]).unwrap_err();
        assert!(matches!(e, TransportError::OutOfRound { round: 3, .. }), "{e:?}");
        t0.flush(3).unwrap();
        let e = t0.send(3, 1, vec![2]).unwrap_err();
        assert!(matches!(e, TransportError::OutOfRound { .. }), "{e:?}");
    }

    #[test]
    fn self_and_bad_targets_are_machine_errors() {
        let mut w = world(2);
        let _t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        assert_eq!(
            t0.send(0, 0, vec![1]).unwrap_err(),
            TransportError::Machine(SimError::SelfMessage { round: 0, rank: 0 })
        );
        assert_eq!(
            t0.send(1, 9, vec![1]).unwrap_err(),
            TransportError::Machine(SimError::BadTarget { round: 1, rank: 0, to: 9 })
        );
    }

    #[test]
    fn failed_peers_reports_crashes_not_departures() {
        let mut w = world(3);
        let t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let t0 = w.pop().unwrap();
        drop(t2); // crash signature: EOF without BYE/ABORT
        t1.close(None).unwrap(); // deliberate: BYE first
        thread::sleep(Duration::from_millis(100)); // let the readers drain
        assert_eq!(t0.failed_peers(), vec![2], "only the crash is a failure");
        assert!(t0.poisoned().is_none(), "detection alone poisons nothing");
        assert_eq!(t0.epoch(), 0);
    }

    #[test]
    fn announced_failure_is_not_a_crash() {
        let mut w = world(2);
        let mut t1 = w.pop().unwrap();
        let t0 = w.pop().unwrap();
        // Rank 1 fails *politely*: ABORT broadcast, then teardown.
        t1.close(Some("rank 1 gave up")).unwrap();
        drop(t1);
        thread::sleep(Duration::from_millis(100));
        assert_eq!(t0.failed_peers(), Vec::<usize>::new());
        let reason = t0.poisoned().expect("the ABORT propagated");
        assert!(reason.contains("gave up"), "{reason}");
    }

    #[test]
    fn epoch_mismatch_is_refused_at_the_door() {
        let dir = std::env::temp_dir().join(format!("cbwire-epoch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let wid = fresh_world_id();
        let d2 = dir.clone();
        let h = thread::spawn(move || {
            // A straggler that still thinks it lives in epoch 0.
            SocketTransport::<i64>::uds_world_epoch(1, 2, wid, 0, &d2, Duration::from_secs(10))
        });
        // The rebuilt epoch-1 world refuses it during rendezvous.
        let err = SocketTransport::<i64>::uds_world_epoch(
            0,
            2,
            wid,
            1,
            &dir,
            Duration::from_secs(10),
        )
        .unwrap_err();
        assert!(err.to_string().contains("membership epoch"), "{err}");
        let _ = h.join();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uds_world_rendezvous_two_processes_worth() {
        let dir = std::env::temp_dir().join(format!("cbwire-uds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let wid = fresh_world_id();
        let d2 = dir.clone();
        let h = thread::spawn(move || {
            let mut t1 =
                SocketTransport::<i64>::uds_world(1, 2, wid, &d2, Duration::from_secs(10))
                    .unwrap();
            t1.send(0, 0, vec![42]).unwrap();
            t1.flush(0).unwrap();
            let got = t1.recv(1, 0).unwrap();
            t1.close(None).unwrap();
            got
        });
        let mut t0 =
            SocketTransport::<i64>::uds_world(0, 2, wid, &dir, Duration::from_secs(10)).unwrap();
        t0.flush(0).unwrap();
        assert_eq!(t0.recv(0, 1).unwrap(), vec![42]);
        t0.send(1, 1, vec![7]).unwrap();
        t0.flush(1).unwrap();
        t0.close(None).unwrap();
        assert_eq!(h.join().unwrap(), vec![7]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    // -----------------------------------------------------------------
    // Chaos + reliability
    // -----------------------------------------------------------------

    /// Run `rounds` one-way sends under `plan` (rank 0 -> rank 1 of a
    /// two-rank chaos world), assert every payload arrives intact and
    /// nobody is declared failed, and return both endpoints' merged
    /// fault counters.
    fn chaos_one_way(plan: FaultPlan, rounds: usize) -> WireFaults {
        let mut w =
            SocketTransport::<i64>::pair_world_chaos(2, Duration::from_secs(10), plan).unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        let h = thread::spawn(move || {
            for j in 0..rounds {
                t0.send(j, 1, vec![j as i64, -(j as i64)]).unwrap();
                t0.flush(j).unwrap();
            }
            t0.close(None).unwrap();
            t0
        });
        for j in 0..rounds {
            t1.flush(j).unwrap();
            let got = t1.recv(j, 0).unwrap();
            assert_eq!(got, vec![j as i64, -(j as i64)], "round {j} payload");
        }
        t1.close(None).unwrap();
        let t0 = h.join().unwrap();
        assert_eq!(t0.failed_peers(), Vec::<usize>::new());
        assert_eq!(t1.failed_peers(), Vec::<usize>::new());
        assert!(t0.poisoned().is_none(), "{:?}", t0.poisoned());
        assert!(t1.poisoned().is_none(), "{:?}", t1.poisoned());
        let mut faults = t0.wire_faults().unwrap();
        faults.merge(&t1.wire_faults().unwrap());
        faults
    }

    #[test]
    fn dropped_frames_heal_by_retransmission() {
        let faults = chaos_one_way(FaultPlan::new(0xD0).drop_per_10k(3_000), 60);
        assert!(faults.retransmits > 0, "30% drop must force retransmits: {faults}");
        assert_eq!(faults.escalations, 0, "{faults}");
    }

    #[test]
    fn duplicated_frames_are_dropped_by_the_dedup_window() {
        // Any duplicate reaching the mailbox would trip the
        // ReceivePortBusy poison, so clean delivery is itself the
        // assertion; the counter pins where the duplicates died.
        let faults = chaos_one_way(FaultPlan::new(0xD1).dup_per_10k(4_000), 40);
        assert!(faults.dup_drops > 0, "40% duplication must hit the window: {faults}");
        assert_eq!(faults.escalations, 0, "{faults}");
    }

    #[test]
    fn reordered_frames_are_absorbed_by_round_tag_matching() {
        let faults = chaos_one_way(FaultPlan::new(0xD2).reorder_per_10k(3_000), 40);
        assert_eq!(faults.escalations, 0, "{faults}");
        assert_eq!(faults.crc_fails, 0, "reordering corrupts nothing: {faults}");
    }

    #[test]
    fn corrupted_frames_heal_by_retransmission() {
        let faults = chaos_one_way(FaultPlan::new(0xD3).corrupt_per_10k(2_500, 3), 40);
        assert!(faults.crc_fails > 0, "25% corruption must fail checksums: {faults}");
        assert!(faults.retransmits > 0, "every corrupt frame needs a resend: {faults}");
        assert_eq!(faults.escalations, 0, "{faults}");
    }

    #[test]
    fn a_mixed_plan_heals_without_consuming_an_epoch() {
        let plan = FaultPlan::new(0xD4)
            .drop_per_10k(500)
            .dup_per_10k(500)
            .reorder_per_10k(500)
            .corrupt_per_10k(500, 2);
        let faults = chaos_one_way(plan, 80);
        assert!(faults.any(), "a 20% composite plan cannot be invisible: {faults}");
        assert_eq!(faults.escalations, 0, "{faults}");
    }

    #[test]
    fn a_blackholed_peer_exhausts_the_retry_budget_and_escalates() {
        let plan = FaultPlan::new(11).blackhole(1);
        let mut w =
            SocketTransport::<i64>::pair_world_chaos(2, Duration::from_secs(5), plan).unwrap();
        let _t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t0.send(0, 1, vec![1, 2, 3]).unwrap(); // posted; the wire eats it
        let deadline = Instant::now() + Duration::from_secs(4);
        while t0.failed_peers().is_empty() && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(t0.failed_peers(), vec![1], "budget exhaustion marks the peer crashed");
        let wf = t0.wire_faults().unwrap();
        assert!(wf.escalations >= 1, "{wf}");
        assert!(wf.retransmits >= u64::from(MAX_ATTEMPTS), "{wf}");
        assert!(
            t0.poisoned().is_none(),
            "escalation is detection, not poison: {:?}",
            t0.poisoned()
        );
    }
}
