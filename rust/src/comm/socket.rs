//! The wire plane: [`SocketTransport`] carries the SPMD rank plane's
//! messages across real OS sockets — Unix-domain or TCP — one process
//! (or thread) per rank. The paper's point makes this cheap: schedule
//! computation is communication-free and O(log p) per rank, so each
//! endpoint derives its own 2·⌈log₂ p⌉ schedule entries locally and
//! only payload blocks cross the wire.
//!
//! **The one-ported round discipline holds across the wire.** Each
//! endpoint enforces the caller side of the
//! [`super::transport`] contract with the same `Discipline`
//! bookkeeping as the in-process transports, and machine-model
//! violations surface in the lockstep [`SimError`] vocabulary wrapped
//! as [`TransportError::Machine`] — the SPMD parity suite pins
//! `SocketTransport` bit-identical (buffers *and* stats) to lockstep.
//!
//! # Frames
//!
//! Everything on a wire-plane connection is a length-prefixed frame
//! (all integers little-endian; `len` counts the type byte plus body):
//!
//! ```text
//! [ len: u32 ][ type: u8 ][ body: len - 1 bytes ]
//!
//! HELLO (1)  magic u32, version u16, p u32, rank u32,
//!            world_id u64, elem_bytes u32, epoch u64
//! DATA  (2)  round u32, src u32, dst u32, count u32,
//!            payload: count * elem_bytes bytes
//! BYE   (3)  (empty) — clean close of the sender's write side
//! ABORT (4)  reason: utf-8 — the sender's world was poisoned
//! ```
//!
//! # Handshake
//!
//! The first frame on every link is a versioned `HELLO` pinning
//! `(p, rank, world_id, elem_bytes, epoch)`. A mismatch — wrong world,
//! wrong protocol version, wrong element width, wrong membership
//! epoch — is a typed failure: at rendezvous time it is an
//! [`io::Error`] from the constructor; after assembly the link's
//! reader poisons the local world and every blocked verb fails with
//! [`TransportError::Shutdown`]. The epoch field (v2) lets the
//! recovery plane rebuild a shrunken world under `epoch + 1` and have
//! stragglers from the dead epoch refused at the door instead of
//! corrupting the new world.
//!
//! # Crash detection
//!
//! Every link terminates exactly one of two ways, and the reader keeps
//! the distinction: a **deliberate** departure announces itself (`BYE`
//! on clean completion, `ABORT` on failure) before the socket closes,
//! while a **crash** — the process died, the endpoint was dropped
//! without [`Transport::close`] — slams the socket shut with no
//! farewell frame (plain EOF) or mid-frame (truncation / reset).
//! [`Transport::failed_peers`] reports the peers whose links died the
//! second way. Because the mesh is full, every survivor observes a
//! dead peer's EOF on its *own* direct link — the survivors' failed
//! sets agree without any coordinator or extra exchange.
//!
//! # Failure mapping
//!
//! Wire faults land in the same vocabulary the in-process transports
//! use, never as raw I/O errors from `send`/`recv`:
//!
//! * peer closed cleanly (`BYE` or EOF at a frame boundary) but the
//!   schedule still expects a message from it →
//!   [`SimError::MissingMessage`];
//! * peer silent past the receive deadline →
//!   [`TransportError::Timeout`];
//! * truncated frame, torn payload, misrouted frame, port collision →
//!   world poisoned with the diagnosis, verbs fail as
//!   [`TransportError::Shutdown`] (collisions use the
//!   [`SimError::ReceivePortBusy`] text);
//! * a rank that fails broadcasts `ABORT` on [`Transport::close`], so
//!   poisoning propagates across process boundaries too.
//!
//! # Topologies
//!
//! * [`SocketTransport::pair_world`] — all `p` endpoints in one
//!   process over `UnixStream::pair` meshes (the parity suite's
//!   harness). A full mesh holds p·(p−1) descriptor ends: p = 24 fits
//!   a 1024-fd soft limit, p = 64 wants `ulimit -n` ≥ 8192.
//! * [`SocketTransport::uds_world`] / [`SocketTransport::tcp_world`] —
//!   one endpoint per *process*, rendezvous by dialing every lower
//!   rank and accepting from every higher rank (acceptors identify
//!   peers by their `HELLO`, so accept order never matters).

use std::any::TypeId;
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::transport::{configured_timeout, Discipline, Transport, TransportError};
use crate::sim::network::SimError;

/// Wire protocol magic ("CBW1") — first field of every `HELLO`.
pub(crate) const MAGIC: u32 = 0x4342_5731;
/// Wire protocol version; bumped on any frame-format change.
/// v2 appended the membership `epoch` field to `HELLO`.
pub(crate) const VERSION: u16 = 2;
/// Sanity bound on a single frame (256 MiB) — anything larger is a
/// corrupt length prefix, not a payload.
pub(crate) const MAX_FRAME: usize = 1 << 28;

const FT_HELLO: u8 = 1;
const FT_DATA: u8 = 2;
const FT_BYE: u8 = 3;
const FT_ABORT: u8 = 4;

// ---------------------------------------------------------------------
// Byte helpers shared with the service plane
// ---------------------------------------------------------------------

pub(crate) fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed utf-8 string (u32 length + bytes).
pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Cursor over a frame body; every getter fails with a typed
/// `InvalidData` on a short body instead of panicking.
pub(crate) struct Body<'a> {
    b: &'a [u8],
}

impl<'a> Body<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Body<'a> {
        Body { b }
    }

    pub(crate) fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.b.len() < n {
            return Err(bad_data("wire: short frame body".into()));
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    pub(crate) fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> io::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Counterpart of [`put_str`].
    pub(crate) fn str(&mut self) -> io::Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| bad_data("wire: invalid utf-8".into()))
    }

    pub(crate) fn rest(&mut self) -> &'a [u8] {
        std::mem::take(&mut self.b)
    }
}

/// Seal `body` into a full `[len][type][body]` frame ready to write.
pub(crate) fn seal(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 5);
    put_u32(&mut out, (body.len() + 1) as u32);
    out.push(kind);
    out.extend_from_slice(body);
    out
}

/// Read exactly `buf.len()` bytes. `Ok(false)` means EOF *before any
/// byte* — a clean stop at a frame boundary when called on a length
/// prefix. EOF mid-buffer is the typed truncation error.
pub(crate) fn fill(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "wire: truncated frame",
                ));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one `[len][type][body]` frame. `Ok(None)` is a clean EOF at a
/// frame boundary; EOF anywhere inside a frame is `UnexpectedEof`.
pub(crate) fn read_raw_frame(r: &mut impl Read) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut len4 = [0u8; 4];
    if !fill(r, &mut len4)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(bad_data(format!("wire: bad frame length {len}")));
    }
    let mut kind1 = [0u8; 1];
    if !fill(r, &mut kind1)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "wire: truncated frame",
        ));
    }
    let mut body = vec![0u8; len - 1];
    if !fill(r, &mut body)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "wire: truncated frame",
        ));
    }
    Ok(Some((kind1[0], body)))
}

// ---------------------------------------------------------------------
// Stream: one enum over the two socket families
// ---------------------------------------------------------------------

/// A bidirectional byte stream over either socket family. The service
/// plane reuses this for client connections.
pub(crate) enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub(crate) fn try_clone(&self) -> io::Result<Stream> {
        Ok(match self {
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }

    pub(crate) fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.shutdown(how),
            Stream::Tcp(s) => s.shutdown(how),
        }
    }

    pub(crate) fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(dur),
            Stream::Tcp(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------
// Codec: elements <-> little-endian bytes, resolved once per world
// ---------------------------------------------------------------------

/// Fixed-width primitives the wire can carry. Payloads are encoded
/// per-element little-endian, so frames are byte-identical across
/// endianness and process boundaries.
trait Prim: Copy + 'static {
    const WIDTH: usize;
    fn put(self, out: &mut Vec<u8>);
    fn take(bytes: &[u8]) -> Self;
}

macro_rules! impl_prim {
    ($($t:ty),*) => {$(
        impl Prim for $t {
            const WIDTH: usize = std::mem::size_of::<$t>();
            fn put(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn take(bytes: &[u8]) -> Self {
                Self::from_le_bytes(bytes.try_into().unwrap())
            }
        }
    )*};
}

impl_prim!(i8, u8, i16, u16, i32, u32, i64, u64, f32, f64);

fn enc_as<W: Prim, T: 'static>(xs: &[T], out: &mut Vec<u8>) {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<W>());
    // SAFETY: `Codec::resolve` installs this function only after
    // proving TypeId::of::<T>() == TypeId::of::<W>(), so the slice
    // cast is an identity cast.
    let ws: &[W] = unsafe { &*(xs as *const [T] as *const [W]) };
    for w in ws {
        w.put(out);
    }
}

fn dec_as<W: Prim, T: 'static>(bytes: &[u8], out: &mut Vec<T>) {
    debug_assert_eq!(TypeId::of::<T>(), TypeId::of::<W>());
    for chunk in bytes.chunks_exact(W::WIDTH) {
        let w = W::take(chunk);
        // SAFETY: T == W (proven by `Codec::resolve`), so this is an
        // identity copy.
        out.push(unsafe { std::mem::transmute_copy::<W, T>(&w) });
    }
}

/// The per-world element codec: a pair of monomorphised encode/decode
/// fns plus the wire width, resolved by `TypeId` probe so the
/// transport stays generic over [`crate::collectives::Element`]
/// without asking element types to know about serialization.
struct Codec<T> {
    elem_bytes: usize,
    enc: fn(&[T], &mut Vec<u8>),
    dec: fn(&[u8], &mut Vec<T>),
}

impl<T> Clone for Codec<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for Codec<T> {}

impl<T: 'static> Codec<T> {
    /// `None` when `T` is not one of the fixed-width primitives the
    /// wire can carry.
    fn resolve() -> Option<Codec<T>> {
        macro_rules! probe {
            ($($w:ty),*) => {$(
                if TypeId::of::<T>() == TypeId::of::<$w>() {
                    return Some(Codec {
                        elem_bytes: <$w as Prim>::WIDTH,
                        enc: enc_as::<$w, T>,
                        dec: dec_as::<$w, T>,
                    });
                }
            )*};
        }
        probe!(i8, u8, i16, u16, i32, u32, i64, u64, f32, f64);
        None
    }
}

fn not_encodable() -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidInput,
        "element type is not wire-encodable (not a fixed-width primitive)",
    )
}

// ---------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------

struct Hello {
    magic: u32,
    version: u16,
    p: u32,
    rank: u32,
    world_id: u64,
    elem_bytes: u32,
    epoch: u64,
}

enum Frame {
    Hello(Hello),
    Data { round: u32, src: u32, dst: u32, count: u32, payload: Vec<u8> },
    Bye,
    Abort(String),
}

fn hello_frame(p: usize, rank: usize, world_id: u64, elem_bytes: usize, epoch: u64) -> Vec<u8> {
    let mut body = Vec::with_capacity(34);
    put_u32(&mut body, MAGIC);
    put_u16(&mut body, VERSION);
    put_u32(&mut body, p as u32);
    put_u32(&mut body, rank as u32);
    put_u64(&mut body, world_id);
    put_u32(&mut body, elem_bytes as u32);
    put_u64(&mut body, epoch);
    seal(FT_HELLO, &body)
}

fn data_frame<T>(codec: &Codec<T>, round: usize, src: usize, dst: usize, data: &[T]) -> Vec<u8> {
    let mut body = Vec::with_capacity(16 + data.len() * codec.elem_bytes);
    put_u32(&mut body, round as u32);
    put_u32(&mut body, src as u32);
    put_u32(&mut body, dst as u32);
    put_u32(&mut body, data.len() as u32);
    (codec.enc)(data, &mut body);
    seal(FT_DATA, &body)
}

fn parse_hello(body: &[u8]) -> io::Result<Hello> {
    let mut b = Body::new(body);
    let magic = b.u32()?;
    let version = b.u16()?;
    let p = b.u32()?;
    let rank = b.u32()?;
    let world_id = b.u64()?;
    let elem_bytes = b.u32()?;
    // The epoch field arrived in v2; tolerate its absence here so a
    // v1 peer fails `vet_hello`'s version check with the useful
    // diagnosis instead of a bare short-body parse error.
    let epoch = if version >= 2 { b.u64()? } else { 0 };
    Ok(Hello { magic, version, p, rank, world_id, elem_bytes, epoch })
}

fn parse_frame(kind: u8, body: Vec<u8>) -> io::Result<Frame> {
    match kind {
        FT_HELLO => Ok(Frame::Hello(parse_hello(&body)?)),
        FT_DATA => {
            let mut b = Body::new(&body);
            let round = b.u32()?;
            let src = b.u32()?;
            let dst = b.u32()?;
            let count = b.u32()?;
            let payload = b.rest().to_vec();
            Ok(Frame::Data { round, src, dst, count, payload })
        }
        FT_BYE => Ok(Frame::Bye),
        FT_ABORT => Ok(Frame::Abort(String::from_utf8_lossy(&body).into_owned())),
        other => Err(bad_data(format!("wire: unknown frame type {other}"))),
    }
}

/// Validate a peer's `HELLO` against this world; returns the peer's
/// claimed rank.
fn vet_hello(
    h: &Hello,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
) -> Result<usize, String> {
    if h.magic != MAGIC {
        return Err(format!("handshake: bad magic {:#010x}", h.magic));
    }
    if h.version != VERSION {
        return Err(format!(
            "handshake: protocol version {} (this side speaks {VERSION})",
            h.version
        ));
    }
    if h.p as usize != p {
        return Err(format!("handshake: world size {} (expected {p})", h.p));
    }
    if h.world_id != world_id {
        return Err(format!(
            "handshake: world id {:#018x} (expected {world_id:#018x})",
            h.world_id
        ));
    }
    if h.elem_bytes as usize != elem_bytes {
        return Err(format!(
            "handshake: element width {} (expected {elem_bytes})",
            h.elem_bytes
        ));
    }
    if h.epoch != epoch {
        return Err(format!(
            "handshake: membership epoch {} (this world is epoch {epoch}) — \
             a straggler from a pre-shrink world",
            h.epoch
        ));
    }
    if h.rank as usize >= p {
        return Err(format!("handshake: rank {} out of range for p = {p}", h.rank));
    }
    Ok(h.rank as usize)
}

// ---------------------------------------------------------------------
// Mailbox + reader threads
// ---------------------------------------------------------------------

struct SockState<T> {
    /// round -> (from, payload); one-portedness means at most one live
    /// entry per round on a valid schedule.
    msgs: HashMap<usize, (usize, Vec<T>)>,
    /// `gone[r]`: rank `r`'s link reached EOF or said `BYE` — nothing
    /// further will ever arrive from it.
    gone: Vec<bool>,
    /// `crashed[r]`: rank `r`'s link died *without* a deliberate
    /// farewell (`BYE`/`ABORT`) — EOF out of nowhere, truncation, or a
    /// reset: the signature of a killed process, as opposed to a rank
    /// that finished or failed politely. Feeds
    /// [`Transport::failed_peers`].
    crashed: Vec<bool>,
    poisoned: Option<String>,
}

struct SockShared<T> {
    state: Mutex<SockState<T>>,
    cv: Condvar,
}

impl<T> SockShared<T> {
    /// Set-once local poison + wake every waiter.
    fn poison(&self, reason: &str) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_none() {
            st.poisoned = Some(reason.to_string());
        }
        drop(st);
        self.cv.notify_all();
    }

    /// `crashed` records whether the link died without a deliberate
    /// `BYE`/`ABORT` first — the crash signature.
    fn mark_gone(&self, peer: usize, crashed: bool) {
        let mut st = self.state.lock().unwrap();
        st.gone[peer] = true;
        if crashed {
            st.crashed[peer] = true;
        }
        drop(st);
        self.cv.notify_all();
    }
}

struct ReaderCtx<T> {
    shared: Arc<SockShared<T>>,
    codec: Codec<T>,
    me: usize,
    p: usize,
    world_id: u64,
    epoch: u64,
    peer: usize,
    /// The link's first frame must be a valid `HELLO` (false when the
    /// rendezvous already validated it synchronously).
    expect_hello: bool,
}

/// One reader thread per peer link: drains frames into the shared
/// mailbox under the same round-tag matching as `ThreadTransport`'s
/// mailboxes. After a poison it keeps draining (and discarding) so a
/// remote sender's `write_all` never blocks on a full socket buffer.
///
/// The reader also runs the crash detector: a link that terminates
/// without the peer having announced its departure first (`BYE` on
/// clean completion, `ABORT` on failure) is marked **crashed** — a
/// killed process never says goodbye, a deliberate one always does.
fn reader_loop<T: Send + 'static>(mut rx: Stream, mut ctx: ReaderCtx<T>) {
    // Has the peer announced its departure (BYE or ABORT)? Link death
    // after an announcement is expected teardown; before one, a crash.
    let mut deliberate = false;
    loop {
        let frame = match read_raw_frame(&mut rx) {
            // EOF at a frame boundary: the peer is gone. Without a
            // prior BYE/ABORT this is the crash signature — a dropped
            // endpoint slams the socket with no farewell frame.
            Ok(None) => {
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
            Ok(Some((kind, body))) => match parse_frame(kind, body) {
                Ok(f) => f,
                Err(e) => {
                    ctx.shared.poison(&format!("wire: rank {}: {e}", ctx.peer));
                    continue;
                }
            },
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                ctx.shared
                    .poison(&format!("wire: truncated frame from rank {}", ctx.peer));
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
            // Reset / broken pipe etc.: the link is dead.
            Err(_) => {
                ctx.shared.mark_gone(ctx.peer, !deliberate);
                return;
            }
        };
        match frame {
            Frame::Hello(h) => {
                if !ctx.expect_hello {
                    ctx.shared
                        .poison(&format!("wire: duplicate HELLO from rank {}", ctx.peer));
                } else {
                    match vet_hello(&h, ctx.p, ctx.world_id, ctx.codec.elem_bytes, ctx.epoch) {
                        Ok(r) if r == ctx.peer => ctx.expect_hello = false,
                        Ok(r) => ctx.shared.poison(&format!(
                            "wire: link to rank {} answered as rank {r}",
                            ctx.peer
                        )),
                        Err(m) => ctx.shared.poison(&format!("wire: rank {}: {m}", ctx.peer)),
                    }
                }
            }
            Frame::Data { .. } if ctx.expect_hello => {
                ctx.shared
                    .poison(&format!("wire: rank {} sent data before HELLO", ctx.peer));
            }
            Frame::Data { round, src, dst, count, payload } => {
                if src as usize != ctx.peer || dst as usize != ctx.me {
                    ctx.shared.poison(&format!(
                        "wire: misrouted frame (round {round}, {src} -> {dst}) on link {} <- {}",
                        ctx.me, ctx.peer
                    ));
                    continue;
                }
                if count as usize * ctx.codec.elem_bytes != payload.len() {
                    ctx.shared.poison(&format!(
                        "wire: torn payload from rank {} in round {round} \
                         ({} bytes for count {count})",
                        ctx.peer,
                        payload.len()
                    ));
                    continue;
                }
                let mut data = Vec::with_capacity(count as usize);
                (ctx.codec.dec)(&payload, &mut data);
                let round = round as usize;
                let mut st = ctx.shared.state.lock().unwrap();
                if st.poisoned.is_some() {
                    // Drain-and-discard: keep the peer's writes moving.
                    continue;
                }
                match st.msgs.get(&round).map(|(f, _)| *f) {
                    Some(first_from) => {
                        let e = SimError::ReceivePortBusy {
                            round,
                            to: ctx.me,
                            first_from,
                            second_from: ctx.peer,
                        };
                        drop(st);
                        ctx.shared.poison(&e.to_string());
                    }
                    None => {
                        st.msgs.insert(round, (ctx.peer, data));
                        drop(st);
                        ctx.shared.cv.notify_all();
                    }
                }
            }
            Frame::Bye => {
                ctx.shared.mark_gone(ctx.peer, false);
                return;
            }
            Frame::Abort(reason) => {
                // Poison propagated from a failed remote rank; keep
                // draining until its write side closes. A failed rank
                // that *announced* its failure did not crash.
                deliberate = true;
                ctx.shared.poison(&reason);
            }
        }
    }
}

// ---------------------------------------------------------------------
// SocketTransport
// ---------------------------------------------------------------------

static WORLD_SEQ: AtomicU64 = AtomicU64::new(1);

/// A fresh process-unique world id for [`SocketTransport::pair_world`]
/// and hand-rolled rendezvous (multi-process worlds agree on one out
/// of band — CLI flag, env, launcher).
pub fn fresh_world_id() -> u64 {
    ((std::process::id() as u64) << 32) ^ WORLD_SEQ.fetch_add(1, Ordering::Relaxed)
}

/// One rank's endpoint of a socket world: a [`Transport`] whose
/// messages cross real OS sockets (Unix-domain or TCP). Per-peer
/// reader threads feed a mutex/condvar mailbox with the exact
/// round-tag matching of [`super::transport::ThreadTransport`]; the
/// one-ported round discipline is enforced endpoint-side, and wire
/// faults surface as typed [`TransportError`]s (see the module docs
/// for the mapping).
pub struct SocketTransport<T> {
    rank: usize,
    p: usize,
    epoch: u64,
    links: Vec<Option<Stream>>,
    shared: Arc<SockShared<T>>,
    codec: Codec<T>,
    timeout: Duration,
    disc: Discipline,
    closed: bool,
}

impl<T: Send + 'static> SocketTransport<T> {
    /// Endpoints for all `p` ranks of a fresh in-process world over
    /// `UnixStream::pair` meshes — real sockets, zero rendezvous.
    /// Receive deadline from
    /// [`super::transport::configured_timeout`]. Fails when `T` is
    /// not wire-encodable or the process is out of descriptors.
    pub fn pair_world(p: usize) -> io::Result<Vec<SocketTransport<T>>> {
        Self::pair_world_with_timeout(p, configured_timeout())
    }

    /// [`SocketTransport::pair_world`] with an explicit receive
    /// deadline (failure-injection tests use a short one).
    pub fn pair_world_with_timeout(
        p: usize,
        timeout: Duration,
    ) -> io::Result<Vec<SocketTransport<T>>> {
        assert!(p > 0);
        let world_id = fresh_world_id();
        let mut rows: Vec<Vec<Option<(Stream, bool)>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for i in 0..p {
            for j in (i + 1)..p {
                let (a, b) = UnixStream::pair()?;
                rows[i][j] = Some((Stream::Unix(a), true));
                rows[j][i] = Some((Stream::Unix(b), true));
            }
        }
        rows.into_iter()
            .enumerate()
            .map(|(rank, row)| Self::assemble(rank, p, world_id, 0, row, timeout, true))
            .collect()
    }

    /// This rank's endpoint of a multi-process world over Unix-domain
    /// sockets: rank `r` listens at `dir/rank-{r}.sock`, dials every
    /// lower rank and accepts from every higher rank. All ranks must
    /// agree on `(p, world_id, dir)`; `timeout` bounds the whole
    /// rendezvous and becomes the receive deadline.
    pub fn uds_world(
        rank: usize,
        p: usize,
        world_id: u64,
        dir: &Path,
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        Self::uds_world_epoch(rank, p, world_id, 0, dir, timeout)
    }

    /// [`SocketTransport::uds_world`] for a post-shrink world: the
    /// recovery plane rebuilds survivors under `epoch + 1` (with a
    /// fresh socket directory), and the epoch-stamped handshake
    /// refuses stragglers that still think they live in the dead
    /// epoch. `rank` and `p` are the *dense* (post-shrink) values.
    pub fn uds_world_epoch(
        rank: usize,
        p: usize,
        world_id: u64,
        epoch: u64,
        dir: &Path,
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        assert!(rank < p);
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let listener = if rank + 1 < p {
            let path = dir.join(format!("rank-{rank}.sock"));
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path)?;
            l.set_nonblocking(true)?;
            Some(l)
        } else {
            None
        };
        let deadline = Instant::now() + timeout;
        let row = mesh_rendezvous(
            rank,
            p,
            world_id,
            codec.elem_bytes,
            epoch,
            deadline,
            |peer| {
                UnixStream::connect(dir.join(format!("rank-{peer}.sock"))).map(Stream::Unix)
            },
            || {
                accept_deadline(deadline, || {
                    let (s, _) = listener.as_ref().unwrap().accept()?;
                    s.set_nonblocking(false)?;
                    Ok(Stream::Unix(s))
                })
            },
        )?;
        Self::assemble(rank, p, world_id, epoch, row, timeout, false)
    }

    /// This rank's endpoint of a multi-process world over TCP:
    /// `addrs[r]` is rank `r`'s listen address; rank `r` dials every
    /// lower rank and accepts from every higher rank. Same rendezvous
    /// contract as [`SocketTransport::uds_world`].
    pub fn tcp_world(
        rank: usize,
        p: usize,
        world_id: u64,
        addrs: &[SocketAddr],
        timeout: Duration,
    ) -> io::Result<SocketTransport<T>> {
        assert!(rank < p && addrs.len() == p);
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let listener = if rank + 1 < p {
            let l = TcpListener::bind(addrs[rank])?;
            l.set_nonblocking(true)?;
            Some(l)
        } else {
            None
        };
        let deadline = Instant::now() + timeout;
        let row = mesh_rendezvous(
            rank,
            p,
            world_id,
            codec.elem_bytes,
            0,
            deadline,
            |peer| {
                let s = TcpStream::connect(addrs[peer])?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            },
            || {
                accept_deadline(deadline, || {
                    let (s, _) = listener.as_ref().unwrap().accept()?;
                    s.set_nonblocking(false)?;
                    s.set_nodelay(true)?;
                    Ok(Stream::Tcp(s))
                })
            },
        )?;
        Self::assemble(rank, p, world_id, 0, row, timeout, false)
    }

    /// Wire a resolved mesh into an endpoint: spawn one reader thread
    /// per link (`expect_hello` links validate the peer's `HELLO` as
    /// their first frame) and, when `send_hello`, write ours on every
    /// link first.
    fn assemble(
        rank: usize,
        p: usize,
        world_id: u64,
        epoch: u64,
        row: Vec<Option<(Stream, bool)>>,
        timeout: Duration,
        send_hello: bool,
    ) -> io::Result<SocketTransport<T>> {
        let codec = Codec::<T>::resolve().ok_or_else(not_encodable)?;
        let shared = Arc::new(SockShared {
            state: Mutex::new(SockState {
                msgs: HashMap::new(),
                gone: vec![false; p],
                crashed: vec![false; p],
                poisoned: None,
            }),
            cv: Condvar::new(),
        });
        let hello = hello_frame(p, rank, world_id, codec.elem_bytes, epoch);
        let mut links: Vec<Option<Stream>> = Vec::with_capacity(p);
        for (peer, slot) in row.into_iter().enumerate() {
            let Some((mut stream, expect_hello)) = slot else {
                links.push(None);
                continue;
            };
            if send_hello {
                stream.write_all(&hello)?;
            }
            let rx = stream.try_clone()?;
            let ctx = ReaderCtx {
                shared: shared.clone(),
                codec,
                me: rank,
                p,
                world_id,
                epoch,
                peer,
                expect_hello,
            };
            std::thread::Builder::new()
                .name(format!("cbwire-{rank}<-{peer}"))
                .stack_size(128 * 1024)
                .spawn(move || reader_loop(rx, ctx))?;
            links.push(Some(stream));
        }
        Ok(SocketTransport {
            rank,
            p,
            epoch,
            links,
            shared,
            codec,
            timeout,
            disc: Discipline::default(),
            closed: false,
        })
    }

    /// The membership epoch this world was assembled under (0 for the
    /// original, pre-shrink world).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The poison reason, if this endpoint's world has been poisoned —
    /// lets a supervisor distinguish "this world is dead" from "this
    /// verb failed" without issuing another verb.
    pub fn poisoned(&self) -> Option<String> {
        self.shared.state.lock().unwrap().poisoned.clone()
    }

    /// Poison the local world and broadcast `ABORT` so remote worlds
    /// poison too — every blocked and future verb on any endpoint of
    /// the world fails with [`TransportError::Shutdown`] instead of
    /// deadlocking.
    fn poison(&mut self, reason: &str) {
        self.shared.poison(reason);
        let frame = seal(FT_ABORT, reason.as_bytes());
        for link in self.links.iter_mut().flatten() {
            let _ = link.write_all(&frame);
        }
    }
}

impl<T: Send + 'static> Transport<T> for SocketTransport<T> {
    fn p(&self) -> usize {
        self.p
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        self.disc.check_send(self.rank, round)?;
        if peer == self.rank {
            return Err(TransportError::Machine(SimError::SelfMessage {
                round,
                rank: self.rank,
            }));
        }
        if peer >= self.p {
            return Err(TransportError::Machine(SimError::BadTarget {
                round,
                rank: self.rank,
                to: peer,
            }));
        }
        {
            let st = self.shared.state.lock().unwrap();
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
        }
        let frame = data_frame(&self.codec, round, self.rank, peer, &data);
        let res = match self.links[peer].as_mut() {
            Some(link) => link.write_all(&frame),
            None => unreachable!("mesh link missing for peer {peer}"),
        };
        if let Err(e) = res {
            let reason = format!("wire: send to rank {peer} in round {round} failed: {e}");
            self.poison(&reason);
            return Err(TransportError::Shutdown { rank: self.rank, round, reason });
        }
        Ok(())
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        // Free-running like ThreadTransport: the wire needs no seal;
        // keep the discipline honest.
        self.disc.check_flush(self.rank, round)
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        self.disc.check_recv(self.rank, round)?;
        let deadline = Instant::now() + self.timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            // Abort semantics: once poisoned nothing more is
            // delivered, mirroring the lockstep mid-round abort.
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
            match st.msgs.get(&round).map(|(from, _)| *from) {
                Some(from) if from == peer => {
                    let (_, data) = st.msgs.remove(&round).unwrap();
                    return Ok(data);
                }
                Some(from) => {
                    // One-ported: a same-round message from anyone
                    // else means the schedules disagree.
                    let e = SimError::UnexpectedMessage {
                        round,
                        to: self.rank,
                        from,
                        expected: Some(peer),
                    };
                    drop(st);
                    self.poison(&e.to_string());
                    return Err(TransportError::Machine(e));
                }
                None => {}
            }
            if peer >= self.p || st.gone[peer] {
                // The peer's link is closed and its message for this
                // round never arrived: it is a rank that died (or a
                // schedule that references a message nobody sends) —
                // the lockstep vocabulary for that is MissingMessage.
                let e = SimError::MissingMessage {
                    round,
                    rank: self.rank,
                    expected_from: peer,
                };
                drop(st);
                self.poison(&e.to_string());
                return Err(TransportError::Machine(e));
            }
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                let e = TransportError::Timeout { rank: self.rank, round, from: peer };
                self.poison(&e.to_string());
                return Err(e);
            }
            let (guard, _) = self.shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn failed_peers(&self) -> Vec<usize> {
        let st = self.shared.state.lock().unwrap();
        st.crashed
            .iter()
            .enumerate()
            .filter_map(|(r, &c)| c.then_some(r))
            .collect()
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        if self.closed {
            return Ok(());
        }
        self.closed = true;
        match error {
            Some(reason) => {
                // Failed rank: poison locally, tell every peer why
                // (ABORT), then close our write sides.
                self.poison(reason);
                for link in self.links.iter_mut().flatten() {
                    let _ = link.shutdown(Shutdown::Write);
                }
            }
            None => {
                // Clean completion: BYE tells peers "nothing further
                // from me" so a schedule still expecting a message
                // surfaces MissingMessage, not a 30 s timeout.
                let bye = seal(FT_BYE, &[]);
                for link in self.links.iter_mut().flatten() {
                    let _ = link.write_all(&bye);
                    let _ = link.shutdown(Shutdown::Write);
                }
            }
        }
        Ok(())
    }
}

impl<T> Drop for SocketTransport<T> {
    fn drop(&mut self) {
        if !self.closed {
            // Dropped without close(): a crashed rank. Tear the links
            // down so peer readers observe EOF-without-BYE and report
            // this rank gone (their recv -> MissingMessage) instead of
            // waiting out the deadline.
            for link in self.links.iter_mut().flatten() {
                let _ = link.shutdown(Shutdown::Both);
            }
        } else {
            // Already closed: reap our reader threads by closing the
            // read sides too.
            for link in self.links.iter_mut().flatten() {
                let _ = link.shutdown(Shutdown::Read);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rendezvous helpers
// ---------------------------------------------------------------------

/// Poll a nonblocking accept until `deadline`.
fn accept_deadline(
    deadline: Instant,
    mut accept_one: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Stream> {
    loop {
        match accept_one() {
            Ok(s) => return Ok(s),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "wire: accept timed out waiting for higher ranks",
                    ));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Retry a dial until `deadline` while the peer has not bound yet.
fn dial_retry(
    deadline: Instant,
    mut dial: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Stream> {
    loop {
        match dial() {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        format!("wire: connect timed out: {e}"),
                    ));
                }
                match e.kind() {
                    io::ErrorKind::ConnectionRefused
                    | io::ErrorKind::NotFound
                    | io::ErrorKind::AddrNotAvailable => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    _ => return Err(e),
                }
            }
        }
    }
}

/// Full-mesh rendezvous: dial every lower rank (sending our `HELLO`
/// immediately; theirs is validated asynchronously by the link's
/// reader), accept from every higher rank (reading and validating the
/// peer's `HELLO` synchronously to identify it — accept order is
/// arbitrary — then answering with ours).
fn mesh_rendezvous(
    rank: usize,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
    deadline: Instant,
    dial: impl Fn(usize) -> io::Result<Stream>,
    mut accept: impl FnMut() -> io::Result<Stream>,
) -> io::Result<Vec<Option<(Stream, bool)>>> {
    let hello = hello_frame(p, rank, world_id, elem_bytes, epoch);
    let mut row: Vec<Option<(Stream, bool)>> = (0..p).map(|_| None).collect();
    for peer in 0..rank {
        let mut s = dial_retry(deadline, || dial(peer))?;
        s.write_all(&hello)?;
        row[peer] = Some((s, true));
    }
    for _ in (rank + 1)..p {
        let mut s = accept()?;
        let left = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        s.set_read_timeout(Some(left))?;
        let peer = read_hello_sync(&mut s, p, world_id, elem_bytes, epoch)?;
        if peer <= rank || row[peer].is_some() {
            return Err(bad_data(format!(
                "handshake: unexpected connection from rank {peer}"
            )));
        }
        s.set_read_timeout(None)?;
        s.write_all(&hello)?;
        row[peer] = Some((s, false));
    }
    Ok(row)
}

/// Synchronously read and validate a peer's `HELLO`; returns its rank.
fn read_hello_sync(
    s: &mut Stream,
    p: usize,
    world_id: u64,
    elem_bytes: usize,
    epoch: u64,
) -> io::Result<usize> {
    let Some((kind, body)) = read_raw_frame(s)? else {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "handshake: peer closed before HELLO",
        ));
    };
    if kind != FT_HELLO {
        return Err(bad_data(format!(
            "handshake: first frame type {kind}, expected HELLO"
        )));
    }
    let h = parse_hello(&body)?;
    vet_hello(&h, p, world_id, elem_bytes, epoch).map_err(bad_data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn world(p: usize) -> Vec<SocketTransport<i64>> {
        SocketTransport::pair_world(p).expect("pair world")
    }

    #[test]
    fn codec_roundtrips_every_primitive() {
        fn rt<T: PartialEq + std::fmt::Debug + Copy + Send + 'static>(xs: Vec<T>) {
            let c = Codec::<T>::resolve().unwrap();
            let mut bytes = Vec::new();
            (c.enc)(&xs, &mut bytes);
            assert_eq!(bytes.len(), xs.len() * c.elem_bytes);
            let mut back = Vec::new();
            (c.dec)(&bytes, &mut back);
            assert_eq!(back, xs);
        }
        rt(vec![-1i8, 7]);
        rt(vec![1u8, 255]);
        rt(vec![-300i16, 300]);
        rt(vec![9u16, 0]);
        rt(vec![-2i32, 9]);
        rt(vec![70_000u32, 3]);
        rt(vec![-5i64, 1 << 40]);
        rt(vec![u64::MAX, 0]);
        rt(vec![1.5f32, -0.25]);
        rt(vec![std::f64::consts::PI, -1e300]);
    }

    #[test]
    fn non_wire_encodable_elements_are_rejected() {
        let err = SocketTransport::<[u8; 3]>::pair_world(2).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    #[test]
    fn truncated_and_boundary_frames_are_detected() {
        // Claims 5 body+type bytes, carries 3: truncation.
        let mut short: &[u8] = &[5, 0, 0, 0, FT_DATA, 1, 2];
        let e = read_raw_frame(&mut short).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof);
        // EOF at a frame boundary is clean.
        let mut empty: &[u8] = &[];
        assert!(read_raw_frame(&mut empty).unwrap().is_none());
        // Zero-length frames are corrupt.
        let mut zero: &[u8] = &[0, 0, 0, 0];
        assert_eq!(
            read_raw_frame(&mut zero).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
    }

    #[test]
    fn pair_world_moves_round_tagged_messages() {
        let mut w = world(3);
        let mut t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        let h1 = thread::spawn(move || {
            t1.send(0, 0, vec![7i64, 8]).unwrap();
            t1.flush(0).unwrap();
            let got = t1.recv(1, 2).unwrap();
            t1.close(None).unwrap();
            got
        });
        let h2 = thread::spawn(move || {
            // Out-of-order arrival relative to rank 1's round cursor is
            // fine: messages match on their round tag.
            t2.send(1, 1, vec![5i64]).unwrap();
            t2.flush(1).unwrap();
            t2.close(None).unwrap();
        });
        t0.flush(0).unwrap();
        let got = t0.recv(0, 1).unwrap();
        t0.close(None).unwrap();
        assert_eq!(got, vec![7, 8]);
        assert_eq!(h1.join().unwrap(), vec![5]);
        h2.join().unwrap();
    }

    #[test]
    fn timeout_poisons_the_world_across_the_wire() {
        let mut w =
            SocketTransport::<i64>::pair_world_with_timeout(2, Duration::from_millis(50)).unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        let e0 = t0.recv(0, 1).unwrap_err(); // nobody sends
        assert!(matches!(e0, TransportError::Timeout { rank: 0, round: 0, from: 1 }), "{e0:?}");
        // The ABORT broadcast poisons rank 1's world too.
        let e1 = t1.recv(0, 0).unwrap_err();
        assert!(matches!(e1, TransportError::Shutdown { .. }), "{e1:?}");
    }

    #[test]
    fn dropped_peer_surfaces_missing_message() {
        let mut w = world(2);
        let t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        drop(t1); // crash without close(): EOF without BYE
        let e = t0.recv(0, 1).unwrap_err();
        assert_eq!(
            e,
            TransportError::Machine(SimError::MissingMessage {
                round: 0,
                rank: 0,
                expected_from: 1
            })
        );
    }

    #[test]
    fn clean_close_without_expected_message_is_missing_message() {
        let mut w = world(2);
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t1.close(None).unwrap(); // BYE: "nothing further from me"
        let e = t0.recv(0, 1).unwrap_err();
        assert_eq!(
            e,
            TransportError::Machine(SimError::MissingMessage {
                round: 0,
                rank: 0,
                expected_from: 1
            })
        );
    }

    #[test]
    fn receive_port_collision_poisons_the_world() {
        let mut w = world(3);
        let mut t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t1.send(0, 0, vec![1]).unwrap();
        t2.send(0, 0, vec![2]).unwrap();
        // Rank 0's reader rejects whichever round-0 delivery lands
        // second; wait for both to land before receiving.
        thread::sleep(Duration::from_millis(100));
        let e = t0.recv(0, 1).unwrap_err();
        assert!(matches!(e, TransportError::Shutdown { .. }), "{e:?}");
    }

    #[test]
    fn round_discipline_is_enforced() {
        let mut w = world(2);
        let _t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        t0.send(3, 1, vec![1]).unwrap();
        let e = t0.send(3, 1, vec![2]).unwrap_err();
        assert!(matches!(e, TransportError::OutOfRound { round: 3, .. }), "{e:?}");
        t0.flush(3).unwrap();
        let e = t0.send(3, 1, vec![2]).unwrap_err();
        assert!(matches!(e, TransportError::OutOfRound { .. }), "{e:?}");
    }

    #[test]
    fn self_and_bad_targets_are_machine_errors() {
        let mut w = world(2);
        let _t1 = w.pop().unwrap();
        let mut t0 = w.pop().unwrap();
        assert_eq!(
            t0.send(0, 0, vec![1]).unwrap_err(),
            TransportError::Machine(SimError::SelfMessage { round: 0, rank: 0 })
        );
        assert_eq!(
            t0.send(1, 9, vec![1]).unwrap_err(),
            TransportError::Machine(SimError::BadTarget { round: 1, rank: 0, to: 9 })
        );
    }

    #[test]
    fn failed_peers_reports_crashes_not_departures() {
        let mut w = world(3);
        let t2 = w.pop().unwrap();
        let mut t1 = w.pop().unwrap();
        let t0 = w.pop().unwrap();
        drop(t2); // crash signature: EOF without BYE/ABORT
        t1.close(None).unwrap(); // deliberate: BYE first
        thread::sleep(Duration::from_millis(100)); // let the readers drain
        assert_eq!(t0.failed_peers(), vec![2], "only the crash is a failure");
        assert!(t0.poisoned().is_none(), "detection alone poisons nothing");
        assert_eq!(t0.epoch(), 0);
    }

    #[test]
    fn announced_failure_is_not_a_crash() {
        let mut w = world(2);
        let mut t1 = w.pop().unwrap();
        let t0 = w.pop().unwrap();
        // Rank 1 fails *politely*: ABORT broadcast, then teardown.
        t1.close(Some("rank 1 gave up")).unwrap();
        drop(t1);
        thread::sleep(Duration::from_millis(100));
        assert_eq!(t0.failed_peers(), Vec::<usize>::new());
        let reason = t0.poisoned().expect("the ABORT propagated");
        assert!(reason.contains("gave up"), "{reason}");
    }

    #[test]
    fn epoch_mismatch_is_refused_at_the_door() {
        let dir = std::env::temp_dir().join(format!("cbwire-epoch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let wid = fresh_world_id();
        let d2 = dir.clone();
        let h = thread::spawn(move || {
            // A straggler that still thinks it lives in epoch 0.
            SocketTransport::<i64>::uds_world_epoch(1, 2, wid, 0, &d2, Duration::from_secs(10))
        });
        // The rebuilt epoch-1 world refuses it during rendezvous.
        let err = SocketTransport::<i64>::uds_world_epoch(
            0,
            2,
            wid,
            1,
            &dir,
            Duration::from_secs(10),
        )
        .unwrap_err();
        assert!(err.to_string().contains("membership epoch"), "{err}");
        let _ = h.join();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uds_world_rendezvous_two_processes_worth() {
        let dir = std::env::temp_dir().join(format!("cbwire-uds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let wid = fresh_world_id();
        let d2 = dir.clone();
        let h = thread::spawn(move || {
            let mut t1 =
                SocketTransport::<i64>::uds_world(1, 2, wid, &d2, Duration::from_secs(10))
                    .unwrap();
            t1.send(0, 0, vec![42]).unwrap();
            t1.flush(0).unwrap();
            let got = t1.recv(1, 0).unwrap();
            t1.close(None).unwrap();
            got
        });
        let mut t0 =
            SocketTransport::<i64>::uds_world(0, 2, wid, &dir, Duration::from_secs(10)).unwrap();
        t0.flush(0).unwrap();
        assert_eq!(t0.recv(0, 1).unwrap(), vec![42]);
        t0.send(1, 1, vec![7]).unwrap();
        t0.flush(1).unwrap();
        t0.close(None).unwrap();
        assert_eq!(h.join().unwrap(), vec![7]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
