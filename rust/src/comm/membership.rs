//! The recovery plane: elastic membership over the rank-plane worlds.
//!
//! The paper's headline property — every rank computes its own O(log p)
//! schedule rows independently, with **no communication** — is exactly
//! what makes membership *shrink* cheap: when a rank dies, each survivor
//! rebuilds its (p−1)-world rows locally in microseconds
//! ([`super::rank::RankComm::shrink`]); nothing is redistributed, no
//! coordinator holds schedule state. This module supplies the pieces
//! around that observation:
//!
//! * [`Membership`] — the epoch-stamped survivor set. Ranks keep two
//!   identities: the **global** id they were born with in the original
//!   (epoch-0) world, and the **dense** rank `0..p′` they occupy in the
//!   current epoch's world (the circulant schedules need dense ranks).
//!   A [`Membership::shrink`] bumps the epoch and yields the
//!   [`MembershipChange`] receipt that also rides on
//!   [`CommError::MembershipChanged`].
//! * **Failure detection without a coordinator.** Survivors learn who
//!   died from their transports ([`Transport::failed_peers`]):
//!   [`ThreadTransport`] keeps a world-shared suspicion board fed by
//!   wait-chain-walking timeout accusations, and
//!   [`super::socket::SocketTransport`] marks peers whose link hit
//!   EOF/error without a deliberate BYE/ABORT — and because the wire
//!   mesh is full, *every* survivor observes a dead peer's EOF on its
//!   own direct link, so the survivors' failed sets agree without any
//!   exchange. Detection is completed by the existing poison/ABORT
//!   storm: one survivor noticing is enough to wake all of them.
//! * [`CrashAfter`] — the fault injector: a [`Transport`] wrapper whose
//!   endpoint dies at a chosen round and, crucially, **does not close**
//!   the inner endpoint, so the world sees a genuine crash signature
//!   (silence in-process; EOF-without-BYE on the wire), not a polite
//!   departure.
//! * [`elastic_bcast`] / [`elastic_reduce`] — the god-view
//!   shrink-and-recover drivers used by the recovery suite: run the
//!   collective, harvest suspects on failure, [`Membership::shrink`],
//!   re-elect the root if it died (lowest surviving global rank), and
//!   restart on the smaller world until the run completes or the shrink
//!   budget is exhausted ([`CommError::MembershipChanged`]). Both share
//!   one driver skeleton and differ only in how each epoch's starting
//!   buffers are laid out (a broadcast reseeds from the root's payload;
//!   a reduction re-contributes every survivor's original input).
//!   Because each epoch restarts the collective from scratch, the
//!   surviving world's result is **bit-identical to a fresh run at the
//!   shrunken size** — the recovery guarantee the tests pin.
//!
//! Injected faults here are *crashes* ([`CrashPlan`]): ranks that die
//! and stay dead, consuming a membership epoch. The other fault family
//! — transient wire faults that the protocol-v3 socket layer heals in
//! place without shrinking anything — lives in [`super::chaos`]
//! (whose `FaultPlan` names frame-level drop/corrupt/reorder verdicts,
//! not deaths).
//!
//! The multi-process analogue (one OS process per rank, real kills)
//! lives in the `cbcastd rank` subcommand and the CI `recovery-smoke`
//! job; the daemon's batch-granular recovery lives in
//! [`crate::service`].

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use crate::collectives::common::{Element, ReduceOp};
use crate::schedule::Skips;
use crate::sim::network::SimError;

use super::outcome::{CommError, WireFaults};
use super::rank::{RankComm, TransportKind};
use super::socket::SocketTransport;
use super::transport::{ThreadTransport, Transport, TransportError};

/// The `reason` string a [`CrashAfter`] endpoint reports to its own
/// caller when it dies. Only the victim ever sees it — survivors see
/// the crash, not the label.
pub const INJECTED_CRASH: &str = "injected crash: rank killed by fault plan";

// ---------------------------------------------------------------------
// Membership: the epoch-stamped survivor set
// ---------------------------------------------------------------------

/// The survivor set of one world, stamped with the epoch it belongs to.
///
/// `members` holds **global** (original-world) rank ids, sorted; a
/// member's position in the list is its **dense** rank in the current
/// epoch's world. Epoch 0 is the full original world, where dense and
/// global coincide. Every shrink bumps the epoch — wire worlds embed
/// the epoch in their handshake so stragglers from a dead epoch are
/// refused at the door rather than corrupting the new world.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    epoch: u64,
    members: Vec<usize>,
}

/// The receipt of one [`Membership::shrink`] — also the payload of
/// [`CommError::MembershipChanged`]. All ranks are global ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipChange {
    /// The epoch the shrink created.
    pub epoch: u64,
    /// Ranks removed by this shrink, sorted.
    pub failed: Vec<usize>,
    /// Ranks remaining after this shrink, sorted.
    pub survivors: Vec<usize>,
}

impl Membership {
    /// The full epoch-0 world: members `0..p`.
    pub fn new(p: usize) -> Self {
        assert!(p > 0, "a world needs at least one rank");
        Membership { epoch: 0, members: (0..p).collect() }
    }

    /// Current world size (`p′`).
    #[inline]
    pub fn p(&self) -> usize {
        self.members.len()
    }

    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The surviving global ids, sorted (dense rank = position).
    #[inline]
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Global id of the member at dense rank `dense`.
    #[inline]
    pub fn global(&self, dense: usize) -> usize {
        self.members[dense]
    }

    /// Dense rank of global id `global`, or `None` if it is not (or no
    /// longer) a member.
    pub fn dense(&self, global: usize) -> Option<usize> {
        self.members.binary_search(&global).ok()
    }

    /// Remove the listed **global** ids: the survivors keep their
    /// relative order and are renumbered densely, the epoch advances.
    /// Non-members in `failed` are ignored. Returns the new membership
    /// and the change receipt. Panics if nobody would survive — a
    /// world cannot shrink to zero ranks.
    pub fn shrink(&self, failed: &[usize]) -> (Membership, MembershipChange) {
        let dead: BTreeSet<usize> =
            failed.iter().copied().filter(|g| self.dense(*g).is_some()).collect();
        let members: Vec<usize> =
            self.members.iter().copied().filter(|g| !dead.contains(g)).collect();
        assert!(!members.is_empty(), "membership cannot shrink to an empty world");
        let epoch = self.epoch + 1;
        let change = MembershipChange {
            epoch,
            failed: dead.into_iter().collect(),
            survivors: members.clone(),
        };
        (Membership { epoch, members }, change)
    }

    /// The root for restarted rooted ops: `preferred` (a global id) if
    /// it survived, else the **lowest surviving global rank** — the
    /// deterministic election every survivor computes identically with
    /// no exchange (they agree on the member list, so they agree on its
    /// minimum).
    pub fn elect_root(&self, preferred: usize) -> usize {
        if self.dense(preferred).is_some() {
            preferred
        } else {
            self.members[0]
        }
    }

    /// Remap a rank window given in the **original (global) frame** into
    /// this membership's dense frame: the window keeps every surviving
    /// member whose global id falls in `[base, base + len)`. Because
    /// members are sorted, those survivors are contiguous in the dense
    /// numbering. Returns `None` when the window lost *all* its ranks —
    /// the op has no world left to run on.
    pub fn remap_window(&self, base: usize, len: usize) -> Option<(usize, usize)> {
        let base_d = self.members.iter().filter(|&&g| g < base).count();
        let len_d = self.members.iter().filter(|&&g| g >= base && g < base + len).count();
        if len_d == 0 {
            None
        } else {
            Some((base_d, len_d))
        }
    }
}

/// The failed rank a detected failure names, if the error carries one:
/// a transport [`TransportError::Timeout`] names the rank it starved
/// waiting for, and a [`SimError::MissingMessage`] (raw or
/// transport-wrapped) names the sender that never sent. Shutdown echoes
/// and machine-model violations name nobody — they are consequences,
/// not causes.
pub fn suspect_of(e: &CommError) -> Option<usize> {
    match e {
        CommError::Transport(TransportError::Timeout { from, .. }) => Some(*from),
        CommError::Transport(TransportError::Machine(SimError::MissingMessage {
            expected_from,
            ..
        })) => Some(*expected_from),
        CommError::Sim(SimError::MissingMessage { expected_from, .. }) => Some(*expected_from),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// CrashAfter: the fault injector with a real crash signature
// ---------------------------------------------------------------------

/// A [`Transport`] wrapper that kills its endpoint at a chosen round:
/// every verb at `round >= crash_round` fails with
/// [`TransportError::Shutdown`] (reason [`INJECTED_CRASH`]) and — the
/// important part — [`Transport::close`] after the crash is a **no-op**
/// that drops the inner endpoint unclosed. A dead process doesn't say
/// goodbye: on [`ThreadTransport`] the victim simply falls silent (its
/// peers' receives time out), and on
/// [`super::socket::SocketTransport`] the unclosed drop slams the
/// sockets shut so every peer reads EOF without a BYE/ABORT — the exact
/// signature of a killed process, which is what the survivors'
/// [`Transport::failed_peers`] detectors key on. The victim's own
/// error return must never feed detection (a real corpse reports
/// nothing); only the survivors' observations count.
pub struct CrashAfter<Tr> {
    inner: Tr,
    crash_round: usize,
    crashed: bool,
}

impl<Tr> CrashAfter<Tr> {
    /// Wrap `inner`; it dies at the first verb tagged `crash_round` or
    /// later (`0` = before it ever communicates).
    pub fn new(inner: Tr, crash_round: usize) -> Self {
        CrashAfter { inner, crash_round, crashed: false }
    }

    /// Has the injected crash fired yet?
    pub fn crashed(&self) -> bool {
        self.crashed
    }
}

impl<Tr> CrashAfter<Tr> {
    fn die<T>(&mut self, round: usize) -> TransportError
    where
        Tr: Transport<T>,
    {
        self.crashed = true;
        TransportError::Shutdown {
            rank: self.inner.rank(),
            round,
            reason: INJECTED_CRASH.to_string(),
        }
    }
}

impl<T, Tr: Transport<T>> Transport<T> for CrashAfter<Tr> {
    fn p(&self) -> usize {
        self.inner.p()
    }

    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        if self.crashed || round >= self.crash_round {
            return Err(self.die(round));
        }
        self.inner.send(round, peer, data)
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        if self.crashed || round >= self.crash_round {
            return Err(self.die(round));
        }
        self.inner.flush(round)
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        if self.crashed || round >= self.crash_round {
            return Err(self.die(round));
        }
        self.inner.recv(round, peer)
    }

    fn failed_peers(&self) -> Vec<usize> {
        self.inner.failed_peers()
    }

    fn wire_faults(&self) -> Option<WireFaults> {
        // The wrapper kills the rank, not the wire: whatever reliable-
        // delivery work the inner endpoint did before (and after) the
        // crash stays attributable to this world's accounting.
        self.inner.wire_faults()
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        if self.crashed {
            // The corpse sends nothing — no BYE, no ABORT. Dropping the
            // inner endpoint unclosed produces the crash signature the
            // survivors' detectors look for.
            return Ok(());
        }
        self.inner.close(error)
    }
}

/// Which ranks to kill, and when: `(epoch, global rank, crash round)`
/// triples consumed by [`elastic_bcast`]/[`elastic_reduce`]. Entries
/// for ranks already dead in the given epoch are ignored.
///
/// Named for what it injects: permanent **crashes** that consume a
/// membership epoch. Transient wire faults (which heal without a
/// shrink) are planned by the frame-level [`super::chaos::FaultPlan`]
/// instead.
#[derive(Debug, Clone, Default)]
pub struct CrashPlan {
    crashes: Vec<(u64, usize, usize)>,
}

impl CrashPlan {
    /// An empty plan (no faults — the elastic drivers then degenerate
    /// to a plain fan-out run).
    pub fn none() -> Self {
        CrashPlan::default()
    }

    /// Kill global rank `global` at transport round `round` of epoch
    /// `epoch` (builder-style).
    pub fn crash(mut self, epoch: u64, global: usize, round: usize) -> Self {
        self.crashes.push((epoch, global, round));
        self
    }

    /// The victims of `epoch`, as `(global, crash_round)`.
    fn at(&self, epoch: u64) -> Vec<(usize, usize)> {
        self.crashes
            .iter()
            .filter(|(e, _, _)| *e == epoch)
            .map(|&(_, g, r)| (g, r))
            .collect()
    }
}

// ---------------------------------------------------------------------
// The elastic driver: run, detect, shrink, restart
// ---------------------------------------------------------------------

/// The outcome of an [`elastic_bcast`]: how the world ended up, every
/// shrink it took to get there, and the survivors' payloads.
#[derive(Debug)]
pub struct ElasticReport<T> {
    /// The final (surviving) membership.
    pub membership: Membership,
    /// One receipt per shrink, in order (empty = no failures).
    pub changes: Vec<MembershipChange>,
    /// The global rank that served as root in the successful epoch
    /// (the original root unless it died — then the lowest survivor).
    pub root: usize,
    /// `(global rank, payload)` per survivor, in global-rank order.
    /// Restarted epochs rerun the collective from scratch on the
    /// shrunken world, so these are bit-identical to a fresh run at
    /// the final size.
    pub buffers: Vec<(usize, Vec<T>)>,
    /// Reliable-wire fault counters summed over **this run's**
    /// endpoints (every rank of every epoch, victims included) — the
    /// per-world accounting, independent of whatever other transports
    /// in the process are doing. All-zero on transports without wire
    /// counters (threads, loopback).
    pub wire: WireFaults,
}

/// One rank's observation of one epoch, as harvested by the driver.
struct Obs<T> {
    /// The rank's result payload (`Some` iff its collective returned Ok).
    buf: Option<Vec<T>>,
    /// The rank's detector output ([`Transport::failed_peers`]), dense.
    harvest: Vec<usize>,
    /// The rank's error, if any.
    err: Option<CommError>,
    /// Was this rank a planned victim? Victims' reports are discarded —
    /// a real corpse reports nothing.
    victim: bool,
    /// The endpoint's reliable-wire counters ([`Transport::wire_faults`];
    /// `None` on transports without a wire). Harvested even from
    /// victims — the counters describe the wire, not the rank's vote.
    wire: Option<WireFaults>,
}

/// How long survivors wait after an error before harvesting their
/// detectors — lets socket reader threads drain the EOFs/ABORTs still
/// in flight. In-process boards are updated synchronously, so this only
/// pads the wire case.
const SETTLE: Duration = Duration::from_millis(150);

/// Which rooted collective an elastic epoch runs — the selector
/// [`run_epoch`] dispatches on. `Reduce` carries the shared operator
/// (already `Send + Sync` by the [`ReduceOp`] contract, so one `Arc`
/// serves every rank thread).
enum Collective<T> {
    Bcast,
    Reduce { op: Arc<dyn ReduceOp<T>> },
}

/// Run one epoch's collective over a concrete transport world, injecting
/// the planned crashes, and collect every rank's observation. `inits`
/// holds each dense rank's starting buffer (the driver lays these out
/// per collective: a broadcast seeds only the root, a reduction seeds
/// every rank with its own contribution). Never fails as a whole —
/// per-rank errors ride inside the observations so the driver sees all
/// of them.
fn run_epoch<T, Tr>(
    world: Vec<Tr>,
    root_d: usize,
    inits: &[Vec<T>],
    blocks: usize,
    coll: &Collective<T>,
    victims: &BTreeMap<usize, usize>,
) -> Vec<Obs<T>>
where
    T: Element,
    Tr: Transport<T>,
{
    let pp = world.len();
    debug_assert_eq!(inits.len(), pp);
    let sk = Arc::new(Skips::new(pp));
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(r, tr)| {
                let sk = sk.clone();
                s.spawn(move || {
                    let rc = RankComm::new(pp, r, sk);
                    let mut buf = inits[r].clone();
                    if let Some(&cr) = victims.get(&r) {
                        let mut dead = CrashAfter::new(tr, cr);
                        let err = match coll {
                            Collective::Bcast => {
                                rc.bcast(&mut dead, root_d, &mut buf, blocks).err()
                            }
                            Collective::Reduce { op } => rc
                                .reduce(&mut dead, root_d, &mut buf, blocks, op.clone())
                                .err(),
                        };
                        // `dead` drops here WITHOUT closing the inner
                        // endpoint — the crash signature. Its wire
                        // counters are still this world's traffic.
                        let wire = dead.wire_faults();
                        Obs { buf: None, harvest: Vec::new(), err, victim: true, wire }
                    } else {
                        let mut tr = tr;
                        let res = match coll {
                            Collective::Bcast => rc.bcast(&mut tr, root_d, &mut buf, blocks),
                            Collective::Reduce { op } => {
                                rc.reduce(&mut tr, root_d, &mut buf, blocks, op.clone())
                            }
                        };
                        let (buf, err) = match res {
                            Ok(_) => (Some(buf), None),
                            Err(e) => (None, Some(e)),
                        };
                        if err.is_some() {
                            std::thread::sleep(SETTLE);
                        }
                        let harvest = tr.failed_peers();
                        let wire = tr.wire_faults();
                        Obs { buf, harvest, err, victim: false, wire }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("elastic rank thread panicked"))
            .collect()
    })
}

/// The shared shrink-and-recover skeleton behind [`elastic_bcast`] and
/// [`elastic_reduce`]: run the collective (injecting `plan`'s crashes
/// for the current epoch), and on failure harvest the survivors'
/// failure detectors, [`Membership::shrink`] by their union, re-elect
/// the root if it died, and restart on the rebuilt world — until an
/// epoch completes cleanly or `max_shrinks` is exhausted. `make_inits`
/// lays out each epoch's dense starting buffers from the current
/// membership and dense root — the only point where the two collectives
/// differ in recovery semantics.
#[allow(clippy::too_many_arguments)]
fn elastic_drive<T: Element>(
    p: usize,
    root: usize,
    blocks: usize,
    kind: TransportKind,
    plan: &CrashPlan,
    max_shrinks: usize,
    timeout: Duration,
    coll: Collective<T>,
    make_inits: impl Fn(&Membership, usize) -> Vec<Vec<T>>,
) -> Result<ElasticReport<T>, CommError> {
    assert!(p > 0, "a world needs at least one rank");
    assert!(root < p, "root {root} out of range for p = {p}");
    let mut membership = Membership::new(p);
    let mut changes: Vec<MembershipChange> = Vec::new();
    let mut root_g = root;
    let mut wire = WireFaults::default();

    loop {
        let pp = membership.p();
        let root_d = membership
            .dense(root_g)
            .expect("elected root is always a member");
        // The current epoch's victims, in the dense frame.
        let victims: BTreeMap<usize, usize> = plan
            .at(membership.epoch())
            .into_iter()
            .filter_map(|(g, r)| membership.dense(g).map(|d| (d, r)))
            .collect();
        let inits = make_inits(&membership, root_d);

        let obs: Vec<Obs<T>> = match kind {
            TransportKind::Threads => run_epoch(
                ThreadTransport::<T>::world_with_timeout(pp, timeout),
                root_d,
                &inits,
                blocks,
                &coll,
                &victims,
            ),
            TransportKind::Socket => run_epoch(
                SocketTransport::<T>::pair_world_with_timeout(pp, timeout).map_err(|e| {
                    CommError::BadRequest(format!("socket world (p = {pp}): {e}"))
                })?,
                root_d,
                &inits,
                blocks,
                &coll,
                &victims,
            ),
            TransportKind::ChaosSocket(chaos) => run_epoch(
                SocketTransport::<T>::pair_world_chaos(pp, timeout, chaos).map_err(
                    |e| {
                        CommError::BadRequest(format!(
                            "chaos socket world (p = {pp}): {e}"
                        ))
                    },
                )?,
                root_d,
                &inits,
                blocks,
                &coll,
                &victims,
            ),
            TransportKind::Loopback => {
                return Err(CommError::BadRequest(
                    "elastic recovery needs a failure detector; the loopback replay \
                     has none (use Threads or Socket)"
                        .to_string(),
                ))
            }
        };

        // Per-world wire accounting: fold every endpoint's counters
        // (victims included — their wire traffic is this run's) into
        // the run total before the observations are consumed.
        for o in &obs {
            if let Some(w) = &o.wire {
                wire.merge(w);
            }
        }

        // Detection: the union of the *survivors'* detector outputs —
        // except reporters that accuse **more than half the world**,
        // whose own wire is the likelier culprit. (A blackholed rank
        // exhausts its retry budget toward *every* peer and would
        // otherwise vote the whole world dead; meanwhile every peer's
        // budget exhausts toward *it*, and that majority accusation is
        // the signal that survives the filter.) Victims' observations
        // are discarded wholesale — a dead rank reports nothing. Only
        // if no detector fired do we fall back to what the survivor
        // errors themselves name (the muted-rank case: a peer that is
        // silent but never closed a socket).
        let mut suspects_d: BTreeSet<usize> = BTreeSet::new();
        for o in obs.iter().filter(|o| !o.victim) {
            if o.harvest.len() * 2 > pp {
                continue;
            }
            suspects_d.extend(o.harvest.iter().copied());
        }
        if suspects_d.is_empty() {
            for o in obs.iter().filter(|o| !o.victim) {
                if let Some(e) = &o.err {
                    suspects_d.extend(suspect_of(e));
                }
            }
        }
        let errored = obs.iter().any(|o| !o.victim && o.err.is_some());

        if !errored && suspects_d.is_empty() {
            // Clean epoch: assemble the survivor payloads.
            let buffers = obs
                .into_iter()
                .enumerate()
                .filter(|(_, o)| !o.victim)
                .map(|(d, o)| {
                    (membership.global(d), o.buf.expect("clean epoch has every payload"))
                })
                .collect();
            return Ok(ElasticReport { membership, changes, root: root_g, buffers, wire });
        }

        if suspects_d.is_empty() {
            // Errors nobody attributes to a death: terminal. Surface the
            // most informative one (reuse the rank plane's triage).
            let errs: Vec<Result<(), CommError>> = obs
                .into_iter()
                .filter(|o| !o.victim)
                .filter_map(|o| o.err.map(Err))
                .collect();
            return Err(super::rank::collect_ranks(errs)
                .expect_err("at least one rank errored"));
        }

        // A shrink is due. Out of budget — or a suspects set covering
        // *every* member, which no world can shrink past (mutual
        // accusation under symmetric faults, e.g. a blackholed two-rank
        // world) — → typed membership error.
        let suspects_g: Vec<usize> =
            suspects_d.iter().map(|&d| membership.global(d)).collect();
        if changes.len() >= max_shrinks || suspects_g.len() >= membership.p() {
            let survivors: Vec<usize> = membership
                .members()
                .iter()
                .copied()
                .filter(|g| !suspects_g.contains(g))
                .collect();
            return Err(CommError::MembershipChanged {
                epoch: membership.epoch() + 1,
                failed: suspects_g,
                survivors,
            });
        }
        let (next, change) = membership.shrink(&suspects_g);
        membership = next;
        root_g = membership.elect_root(root_g);
        changes.push(change);
    }
}

/// Shrink-and-recover broadcast: the god-view elastic driver.
///
/// Starts at the full `p`-rank world and repeats — run the broadcast
/// (injecting `plan`'s crashes for the current epoch), and on failure
/// harvest the survivors' failure detectors, [`Membership::shrink`] by
/// their union, re-elect the root if it died (lowest surviving global
/// rank takes over and serves `data`), and restart on the rebuilt
/// world — until an epoch completes cleanly or `max_shrinks` is
/// exhausted ([`CommError::MembershipChanged`] with the last change's
/// receipt). Failures nobody can attribute to a dead rank (genuine
/// schedule violations, misuse) stay terminal and are returned as-is.
///
/// Supported on [`TransportKind::Threads`], [`TransportKind::Socket`]
/// and [`TransportKind::ChaosSocket`] — the worlds with failure
/// detectors (the chaos world additionally injects transient wire
/// faults, which the v3 socket layer heals *without* consuming a
/// shrink). `timeout` is the per-world receive deadline (keep it well
/// above the scheduler noise of the host; it bounds how long detection
/// takes).
#[allow(clippy::too_many_arguments)]
pub fn elastic_bcast<T: Element>(
    p: usize,
    root: usize,
    data: &[T],
    blocks: usize,
    kind: TransportKind,
    plan: &CrashPlan,
    max_shrinks: usize,
    timeout: Duration,
) -> Result<ElasticReport<T>, CommError> {
    elastic_drive(
        p,
        root,
        blocks,
        kind,
        plan,
        max_shrinks,
        timeout,
        Collective::Bcast,
        |m, root_d| {
            (0..m.p())
                .map(|d| {
                    if d == root_d {
                        data.to_vec()
                    } else {
                        vec![T::default(); data.len()]
                    }
                })
                .collect()
        },
    )
}

/// Shrink-and-recover reduction: [`elastic_bcast`]'s sibling on the
/// same harvest → shrink → re-elect → restart skeleton.
///
/// `inputs` holds one contribution per **global** (epoch-0) rank;
/// every epoch re-contributes each *survivor's* original input, so
/// a recovered run's result is bit-identical to a fresh reduction at
/// the shrunken size over the survivors' inputs — a dead rank's
/// contribution is genuinely lost, exactly as if it had never joined.
/// The root's entry in [`ElasticReport::buffers`] holds the reduction;
/// non-root entries hold whatever partial accumulations the circulant
/// schedule left behind (deterministic, but not meaningful). If the
/// root dies, the lowest surviving global rank takes over and the
/// reduction restarts toward it.
#[allow(clippy::too_many_arguments)]
pub fn elastic_reduce<T: Element>(
    p: usize,
    root: usize,
    inputs: &[Vec<T>],
    blocks: usize,
    op: Arc<dyn ReduceOp<T>>,
    kind: TransportKind,
    plan: &CrashPlan,
    max_shrinks: usize,
    timeout: Duration,
) -> Result<ElasticReport<T>, CommError> {
    if inputs.len() != p {
        return Err(CommError::BadRequest(format!(
            "elastic reduce needs one input per rank: got {} for p = {p}",
            inputs.len()
        )));
    }
    if let Some(bad) = inputs.iter().position(|i| i.len() != inputs[0].len()) {
        return Err(CommError::BadRequest(format!(
            "elastic reduce inputs must agree in length: rank {bad} has {} elements, \
             rank 0 has {}",
            inputs[bad].len(),
            inputs[0].len()
        )));
    }
    elastic_drive(
        p,
        root,
        blocks,
        kind,
        plan,
        max_shrinks,
        timeout,
        Collective::Reduce { op },
        |m, _| m.members().iter().map(|&g| inputs[g].clone()).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership_shrink_renumbers_densely() {
        let m = Membership::new(8);
        assert_eq!(m.p(), 8);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.dense(5), Some(5));
        let (m1, change) = m.shrink(&[3, 6]);
        assert_eq!(m1.p(), 6);
        assert_eq!(m1.epoch(), 1);
        assert_eq!(change.epoch, 1);
        assert_eq!(change.failed, vec![3, 6]);
        assert_eq!(change.survivors, vec![0, 1, 2, 4, 5, 7]);
        assert_eq!(m1.dense(3), None);
        assert_eq!(m1.dense(4), Some(3));
        assert_eq!(m1.dense(7), Some(5));
        assert_eq!(m1.global(5), 7);
        // A second shrink composes: global ids are stable across epochs.
        let (m2, c2) = m1.shrink(&[0, 3]); // 3 already dead: ignored
        assert_eq!(c2.failed, vec![0]);
        assert_eq!(m2.p(), 5);
        assert_eq!(m2.epoch(), 2);
        assert_eq!(m2.members(), &[1, 2, 4, 5, 7]);
    }

    #[test]
    fn root_election_prefers_the_incumbent() {
        let (m, _) = Membership::new(8).shrink(&[0, 2]);
        assert_eq!(m.elect_root(5), 5, "a surviving root keeps the job");
        assert_eq!(m.elect_root(2), 1, "a dead root is replaced by the lowest survivor");
        assert_eq!(m.elect_root(0), 1);
    }

    #[test]
    fn window_remap_keeps_surviving_contiguity() {
        let (m, _) = Membership::new(10).shrink(&[4, 7]);
        // Window [4, 8) in the global frame loses 4 and 7, keeps 5, 6.
        assert_eq!(m.remap_window(4, 4), Some((4, 2)));
        // Window [0, 4) is untouched and stays where it was.
        assert_eq!(m.remap_window(0, 4), Some((0, 4)));
        // Window [8, 2) shifts down by the two dead ranks below it.
        assert_eq!(m.remap_window(8, 2), Some((6, 2)));
        // A window that lost everyone has no world left.
        let (m2, _) = Membership::new(4).shrink(&[2, 3]);
        assert_eq!(m2.remap_window(2, 2), None);
    }

    #[test]
    fn suspects_come_from_timeouts_and_missing_messages() {
        assert_eq!(
            suspect_of(&CommError::Transport(TransportError::Timeout {
                rank: 0,
                round: 3,
                from: 5
            })),
            Some(5)
        );
        assert_eq!(
            suspect_of(&CommError::Sim(SimError::MissingMessage {
                round: 2,
                rank: 1,
                expected_from: 4
            })),
            Some(4)
        );
        assert_eq!(
            suspect_of(&CommError::Transport(TransportError::Shutdown {
                rank: 0,
                round: 0,
                reason: "echo".to_string()
            })),
            None,
            "shutdown echoes accuse nobody"
        );
        assert_eq!(suspect_of(&CommError::BadRequest("nope".to_string())), None);
    }

    #[test]
    fn crash_after_dies_on_schedule_and_never_says_goodbye() {
        let mut world = ThreadTransport::<u8>::world_with_timeout(
            2,
            Duration::from_millis(50),
        );
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let mut dead = CrashAfter::new(t0, 1);
        dead.send(0, 1, vec![7]).unwrap();
        dead.flush(0).unwrap();
        assert!(!dead.crashed());
        match dead.flush(1) {
            Err(TransportError::Shutdown { rank: 0, round: 1, reason }) => {
                assert_eq!(reason, INJECTED_CRASH)
            }
            other => panic!("expected the injected crash, got {other:?}"),
        }
        assert!(dead.crashed());
        // Post-crash close is swallowed: the world is NOT poisoned by a
        // polite ABORT — the victim simply falls silent...
        dead.close(Some("should never reach the world")).unwrap();
        drop(dead);
        // ...so the survivor's receive times out (and accuses rank 0)
        // instead of seeing a shutdown echo.
        let mut t1 = t1;
        assert_eq!(t1.recv(0, 0).ok(), Some(vec![7]), "pre-crash sends delivered");
        t1.flush(0).unwrap();
        t1.flush(1).unwrap();
        assert!(matches!(
            t1.recv(1, 0),
            Err(TransportError::Timeout { rank: 1, round: 1, from: 0 })
        ));
        assert_eq!(t1.failed_peers(), vec![0]);
    }

    #[test]
    fn elastic_bcast_without_faults_is_a_plain_run() {
        let data: Vec<i64> = (0..40).map(|i| i * 11 - 3).collect();
        let report = elastic_bcast(
            8,
            0,
            &data,
            4,
            TransportKind::Threads,
            &CrashPlan::none(),
            2,
            Duration::from_secs(5),
        )
        .unwrap();
        assert!(report.changes.is_empty());
        assert_eq!(report.membership.p(), 8);
        assert_eq!(report.root, 0);
        assert_eq!(report.buffers.len(), 8);
        for (g, buf) in &report.buffers {
            assert_eq!(buf, &data, "rank {g}");
        }
    }

    #[test]
    fn elastic_reduce_without_faults_sums_every_contribution() {
        use crate::collectives::SumOp;
        let p = 8;
        let n = 40usize;
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..n).map(|i| (r * 1000 + i) as i64).collect()).collect();
        let expect: Vec<i64> =
            (0..n).map(|i| inputs.iter().map(|row| row[i]).sum()).collect();
        let report = elastic_reduce(
            p,
            3,
            &inputs,
            4,
            Arc::new(SumOp),
            TransportKind::Threads,
            &CrashPlan::none(),
            2,
            Duration::from_secs(5),
        )
        .unwrap();
        assert!(report.changes.is_empty());
        assert_eq!(report.root, 3);
        let (g, buf) =
            report.buffers.iter().find(|(g, _)| *g == 3).expect("root payload present");
        assert_eq!(*g, 3);
        assert_eq!(buf, &expect);
    }

    #[test]
    fn elastic_reduce_rejects_mismatched_inputs() {
        use crate::collectives::SumOp;
        let inputs = vec![vec![1i64; 8], vec![2i64; 7]];
        let err = elastic_reduce(
            2,
            0,
            &inputs,
            2,
            Arc::new(SumOp),
            TransportKind::Threads,
            &CrashPlan::none(),
            1,
            Duration::from_secs(5),
        )
        .unwrap_err();
        assert!(matches!(err, CommError::BadRequest(_)));
        let err = elastic_reduce(
            4,
            0,
            &inputs,
            2,
            Arc::new(SumOp),
            TransportKind::Threads,
            &CrashPlan::none(),
            1,
            Duration::from_secs(5),
        )
        .unwrap_err();
        assert!(matches!(err, CommError::BadRequest(_)));
    }
}
