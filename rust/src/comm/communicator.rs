//! The [`Communicator`] — persistent, schedule-caching handle serving
//! every collective through typed requests — and its [`CommBuilder`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::collectives::allgatherv::{build_allgatherv_procs, AllgathervProc, ScheduleTable};
use crate::collectives::baselines::{
    BinomialBcastProc, BinomialReduceProc, OptTreeBcastProc, OptTreeReduceProc,
    RingAllgathervProc, RingReduceScatterProc, VdgBcastProc,
};
use crate::collectives::bcast::{build_bcast_procs, BcastProc};
use crate::collectives::common::{BlockGeometry, Element, ScheduleSource};
use crate::collectives::reduce::{build_reduce_procs, ReduceProc};
use crate::collectives::reduce_scatter::{build_reduce_scatter_procs, ReduceScatterProc};
use crate::collectives::rhalving::RhalvingProc;
use crate::schedule::table::ScheduleTable as RowTable;
use crate::schedule::{OptTree, ScheduleCache, Skips};
use crate::sim::cost::{CostModel, LinearCost, LogPParams};
use crate::sim::engine::{CirculantEngine, EngineScratch};
use crate::sim::network::{RankProc, RunStats, SimError};

use super::backend::{build_procs, BackendKind};
use super::nonblocking::Pending;
use super::outcome::{CommError, Outcome};
use super::rank::{
    spmd_allgatherv, spmd_allreduce, spmd_bcast, spmd_reduce, spmd_reduce_scatter,
};
use super::request::{
    Algo, AllgathervReq, AllreduceReq, BcastReq, Kind, ReduceReq, ReduceScatterBlockReq,
    ReduceScatterReq, TuningParams,
};
use super::traffic::{SubmitRequest, TrafficEngine};

/// Builder for a [`Communicator`].
///
/// ```no_run
/// use std::sync::Arc;
/// use circulant_bcast::comm::{BackendKind, CommBuilder};
/// use circulant_bcast::schedule::ScheduleCache;
/// use circulant_bcast::sim::LinearCost;
///
/// let cache = Arc::new(ScheduleCache::new());   // shared across comms
/// let comm = CommBuilder::new(1000)
///     .cache(cache)
///     .cost_model(LinearCost::hpc_default())
///     .backend(BackendKind::Lockstep)
///     .build();
/// ```
pub struct CommBuilder {
    p: usize,
    cache: Option<Arc<ScheduleCache>>,
    cost: Option<Arc<dyn CostModel>>,
    tuning: TuningParams,
    backend: BackendKind,
}

impl CommBuilder {
    pub fn new(p: usize) -> Self {
        assert!(p > 0, "a communicator needs at least one rank");
        CommBuilder {
            p,
            cache: None,
            cost: None,
            tuning: TuningParams::default(),
            backend: BackendKind::Lockstep,
        }
    }

    /// Share a schedule cache across communicators (e.g. one per service).
    pub fn cache(mut self, cache: Arc<ScheduleCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Default cost model used by the typed collective methods.
    pub fn cost(mut self, cost: Arc<dyn CostModel>) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Convenience: wrap a concrete cost model.
    pub fn cost_model(self, cost: impl CostModel + 'static) -> Self {
        self.cost(Arc::new(cost))
    }

    /// Block-count tuning constants (the paper's F and G).
    pub fn tuning(mut self, tuning: TuningParams) -> Self {
        self.tuning = tuning;
        self
    }

    /// Execution backend (lockstep simulator or threaded runtime).
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    pub fn build(self) -> Communicator {
        let cache = self.cache.unwrap_or_default();
        let sk = cache.skips(self.p);
        Communicator {
            p: self.p,
            sk,
            cache,
            cost: self.cost.unwrap_or_else(|| Arc::new(LinearCost::hpc_default())),
            tuning: self.tuning,
            backend: self.backend,
            tables: Mutex::new(HashMap::new()),
            rows_uncached: OnceLock::new(),
        }
    }
}

/// A persistent, MPI-communicator-style handle over `p` simulated ranks.
///
/// Built once per `p` (cheap: the skip table is O(log p)); every
/// collective call reuses the owned [`Skips`] and the shared
/// [`ScheduleCache`], so repeated traffic — including calls with varying
/// roots, since schedules are root-relative — amortises all schedule
/// computation. See the [`crate::comm`] module docs for the full tour.
pub struct Communicator {
    p: usize,
    sk: Arc<Skips>,
    cache: Arc<ScheduleCache>,
    cost: Arc<dyn CostModel>,
    tuning: TuningParams,
    backend: BackendKind,
    /// Memoized Algorithm-7 schedule tables, keyed by block count `n` —
    /// thin `n`-phase views over the shared all-ranks row table, so
    /// repeated all-collective traffic shares both layers.
    tables: Mutex<HashMap<usize, Arc<ScheduleTable>>>,
    /// The all-ranks row table when it exceeds the shared cache's
    /// admission cap (`tuning.table_cache_max_bytes`): built once on
    /// first use and kept for this handle's lifetime, so even
    /// million-rank traffic pays the parallel build exactly once.
    rows_uncached: OnceLock<Arc<RowTable>>,
}

impl Communicator {
    /// A communicator with all defaults (fresh cache, HPC-default linear
    /// cost model, lockstep backend). Prefer [`CommBuilder`] for shared
    /// caches and custom cost models.
    pub fn new(p: usize) -> Self {
        CommBuilder::new(p).build()
    }

    pub fn builder(p: usize) -> CommBuilder {
        CommBuilder::new(p)
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// `q = ceil(log2 p)`, the rounds per phase.
    #[inline]
    pub fn q(&self) -> usize {
        self.sk.q()
    }

    pub fn skips(&self) -> &Arc<Skips> {
        &self.sk
    }

    pub fn cache(&self) -> &Arc<ScheduleCache> {
        &self.cache
    }

    pub fn cost(&self) -> &Arc<dyn CostModel> {
        &self.cost
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    pub fn tuning(&self) -> &TuningParams {
        &self.tuning
    }

    /// The block count a request resolves to: the override if given, else
    /// the paper's §3 rule for the collective kind.
    pub fn blocks_for(&self, kind: Kind, m: usize, blocks: Option<usize>) -> usize {
        super::request::resolve_blocks(kind, self.p, m, &self.tuning, blocks)
    }

    /// The all-ranks schedule row table (the flat, parallel-built
    /// schedule plane — see [`crate::schedule::table`]) serving every
    /// collective at this `p`. Under the tuning cap it lives in the
    /// shared [`ScheduleCache`] (hit/miss receipts: build = `p` misses,
    /// every later fetch = `p` hits); above it, in this handle's private
    /// [`OnceLock`] — either way the build runs exactly once per `p`
    /// for this communicator's traffic.
    pub(crate) fn rows(&self) -> Arc<RowTable> {
        let cap = self.tuning.table_cache_max_bytes;
        if RowTable::bytes_for(&self.sk) <= cap {
            return self.cache.table_with_cap(&self.sk, cap);
        }
        // Over the cap the cache declines to store, so the OnceLock is
        // the once-only point: concurrent first callers block here
        // instead of racing duplicate O(p log p) builds.
        self.rows_uncached
            .get_or_init(|| self.cache.table_with_cap(&self.sk, cap))
            .clone()
    }

    /// Schedule source backed by the shared schedule plane: one table
    /// fetch per collective call, then every rank row is served from the
    /// flat arena with no further cache traffic.
    pub(crate) fn schedules(&self) -> ScheduleSource<'_> {
        ScheduleSource::Table(self.rows())
    }

    /// Cached Algorithm-7 table for `n` blocks: a thin `n`-phase view
    /// over the shared row table, built once per block count, then
    /// shared by every later call.
    pub(crate) fn table(&self, n: usize) -> Arc<ScheduleTable> {
        let mut tables = self.tables.lock().unwrap();
        tables
            .entry(n)
            .or_insert_with(|| ScheduleTable::build_from(&self.schedules(), n))
            .clone()
    }

    /// A window-sized communicator sharing this handle's cache, cost
    /// model, tuning and backend — how the traffic plane serves
    /// operations restricted to a rank window
    /// ([`crate::comm::nonblocking::Window`]): a window of `len` ranks
    /// behaves exactly like a `len`-rank communicator, and the shared
    /// cache means every window size pays schedule computation at most
    /// once.
    pub(crate) fn windowed(&self, len: usize) -> Communicator {
        CommBuilder::new(len)
            .cache(self.cache.clone())
            .cost(self.cost.clone())
            .tuning(self.tuning.clone())
            .backend(self.backend)
            .build()
    }

    /// Open a nonblocking batch on this machine: submit collectives
    /// ([`TrafficEngine::submit`] / [`Communicator::submit`]), then
    /// [`TrafficEngine::run`] executes them overlapped under the
    /// cross-operation port ledger. See [`crate::comm::traffic`].
    pub fn traffic(&self) -> TrafficEngine<'_> {
        TrafficEngine::new(self)
    }

    /// Submit a nonblocking collective (`IbcastReq`, `IreduceReq`,
    /// `IallgathervReq`, `IreduceScatterReq`, `IallreduceReq`) into a
    /// batch opened on this communicator; returns the typed
    /// [`Pending`] handle. Equivalent to [`TrafficEngine::submit`].
    pub fn submit<T: Element, R: SubmitRequest<T>>(
        &self,
        traffic: &mut TrafficEngine<'_>,
        req: R,
    ) -> Result<Pending<R::Buffers>, CommError> {
        if !std::ptr::eq(self, traffic.comm()) {
            return Err(CommError::BadRequest(
                "submit into a batch opened on a different communicator".to_string(),
            ));
        }
        traffic.submit(req)
    }

    fn run<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        // With LogP parameters configured, every run through the
        // communicator also carries the cost plane's clock
        // (`RunStats::logp_time`), whatever the backend.
        self.backend.execute_logp::<T, P>(procs, elem_bytes, cost, self.tuning.logp.as_ref())
    }

    /// The machine the cost plane prices this communicator against:
    /// configured LogP parameters, or the documented defaults — used
    /// only where an [`Algo::OptTree`] tree must be built even though
    /// no parameters were configured.
    fn logp_or_default(&self) -> LogPParams {
        self.tuning.logp.unwrap_or_default()
    }

    /// The [`OptTree`] for an `m`-element, `elem_bytes`-wide payload:
    /// the greedy build on the machine scaled for the full message
    /// size, shared by every rank's proc (and bit-identical across
    /// backends — the build is deterministic).
    pub(crate) fn opttree_for(&self, m: usize, elem_bytes: usize) -> Arc<OptTree> {
        Arc::new(OptTree::build(self.p, &self.logp_or_default().scaled_for(m * elem_bytes)))
    }

    // ---------------------------------------------------------------
    // Broadcast
    // ---------------------------------------------------------------

    /// `MPI_Bcast`: `req.data` at `req.root` reaches every rank.
    /// `buffers[r]` is rank `r`'s final buffer.
    pub fn bcast<T: Element>(
        &self,
        req: BcastReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let cost = self.cost.clone();
        self.bcast_with(req, cost.as_ref())
    }

    pub(crate) fn bcast_with<T: Element>(
        &self,
        req: BcastReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let p = self.p;
        if req.root >= p {
            return Err(CommError::BadRequest(format!(
                "bcast root {} out of range for p = {p}",
                req.root
            )));
        }
        let m = req.data.len();
        let algo =
            req.algo.resolve_with(Kind::Bcast, p, m, req.elem_bytes, req.blocks, &self.tuning);
        let (stats, buffers) = match algo {
            Algo::Circulant if self.backend == BackendKind::Engine => {
                // The sparse engine simulates the schedule directly (a
                // broadcast never transforms payloads) and errors if any
                // rank ends incomplete. NOTE: assembling `Outcome::buffers`
                // is O(p·m) — the API contract every backend shares — so
                // the million-rank regime belongs to `CirculantEngine`
                // directly (see `benches/engine_scale.rs`); through this
                // method the engine "only" removes the simulation cost.
                let n = self.blocks_for(Kind::Bcast, m, req.blocks);
                let geom = BlockGeometry::new(m, n);
                let eng = CirculantEngine::new(self.rows(), req.root, geom);
                let stats = eng.run_bcast_clocked(
                    &mut EngineScratch::<()>::new(),
                    req.elem_bytes,
                    cost,
                    self.tuning.logp.as_ref(),
                )?;
                let bufs: Vec<Vec<T>> = (0..p).map(|_| req.data.to_vec()).collect();
                (stats, bufs)
            }
            Algo::Circulant if self.backend.is_rank_plane() => {
                // The SPMD rank plane: p RankComms over the backend's
                // transport (mailbox threads for Spmd, real sockets
                // for Socket), each computing only its own O(log p)
                // schedule — the whole-machine ScheduleTable is never
                // touched.
                let n = self.blocks_for(Kind::Bcast, m, req.blocks);
                let (stats, bufs) = spmd_bcast(
                    &self.sk,
                    req.root,
                    req.data,
                    n,
                    req.elem_bytes,
                    cost,
                    self.backend.rank_plane_transport(),
                    self.tuning.logp.as_ref(),
                )?;
                (stats, bufs)
            }
            Algo::Circulant => {
                let n = self.blocks_for(Kind::Bcast, m, req.blocks);
                let geom = BlockGeometry::new(m, n);
                let procs = build_bcast_procs(&self.schedules(), req.root, geom, req.data);
                let (stats, procs) = self.run::<T, BcastProc<T>>(procs, req.elem_bytes, cost)?;
                if let Some(pr) = procs.iter().find(|pr| !pr.complete()) {
                    return Err(CommError::Incomplete { kind: Kind::Bcast, rank: pr.rank });
                }
                let bufs: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_buffer()).collect();
                (stats, bufs)
            }
            Algo::Binomial => {
                let procs = build_procs(p, |r| {
                    let data = if r == req.root { Some(req.data) } else { None };
                    BinomialBcastProc::new(p, r, req.root, data)
                });
                let (stats, procs) =
                    self.run::<T, BinomialBcastProc<T>>(procs, req.elem_bytes, cost)?;
                let bufs: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_buffer()).collect();
                (stats, bufs)
            }
            Algo::VanDeGeijn => {
                let procs = build_procs(p, |r| {
                    let data = if r == req.root { Some(req.data) } else { None };
                    VdgBcastProc::new(p, r, req.root, m, data)
                });
                let (stats, procs) =
                    self.run::<T, VdgBcastProc<T>>(procs, req.elem_bytes, cost)?;
                let bufs: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_buffer()).collect();
                (stats, bufs)
            }
            Algo::OptTree => {
                let tree = self.opttree_for(m, req.elem_bytes);
                let procs = build_procs(p, |r| {
                    let data = if r == req.root { Some(req.data) } else { None };
                    OptTreeBcastProc::new(tree.clone(), p, r, req.root, data)
                });
                let (stats, procs) =
                    self.run::<T, OptTreeBcastProc<T>>(procs, req.elem_bytes, cost)?;
                let bufs: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_buffer()).collect();
                (stats, bufs)
            }
            algo => return Err(CommError::Unsupported { kind: Kind::Bcast, algo }),
        };
        // Uniform per-rank completion check across every algorithm (the
        // corrected `all_received` notion): each rank holds the full
        // m-element buffer.
        let complete = buffers.len() == p && buffers.iter().all(|b| b.len() == m);
        Ok(Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None })
    }

    // ---------------------------------------------------------------
    // Reduce
    // ---------------------------------------------------------------

    /// `MPI_Reduce`: the elementwise ⊕ over every rank's contribution
    /// lands at `req.root`. `buffers` is the root's reduced vector.
    pub fn reduce<T: Element>(&self, req: ReduceReq<'_, T>) -> Result<Outcome<Vec<T>>, CommError> {
        let cost = self.cost.clone();
        self.reduce_with(req, cost.as_ref())
    }

    pub(crate) fn reduce_with<T: Element>(
        &self,
        req: ReduceReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<T>>, CommError> {
        let p = self.p;
        if req.inputs.len() != p {
            return Err(CommError::BadRequest(format!(
                "reduce needs {p} contributions, got {}",
                req.inputs.len()
            )));
        }
        if req.root >= p {
            return Err(CommError::BadRequest(format!(
                "reduce root {} out of range for p = {p}",
                req.root
            )));
        }
        let m = req.inputs[0].len();
        if req.inputs.iter().any(|v| v.len() != m) {
            return Err(CommError::BadRequest(
                "reduce requires equal-length contributions".to_string(),
            ));
        }
        let algo =
            req.algo.resolve_with(Kind::Reduce, p, m, req.elem_bytes, req.blocks, &self.tuning);
        let (stats, buffer) = match algo {
            Algo::Circulant if self.backend == BackendKind::Engine => {
                let n = self.blocks_for(Kind::Reduce, m, req.blocks);
                let geom = BlockGeometry::new(m, n);
                let eng = CirculantEngine::new(self.rows(), req.root, geom);
                let (stats, buffer) = eng.run_reduce_clocked(
                    &mut EngineScratch::new(),
                    req.inputs,
                    req.op.as_ref(),
                    req.elem_bytes,
                    cost,
                    self.tuning.logp.as_ref(),
                )?;
                (stats, buffer)
            }
            Algo::Circulant if self.backend.is_rank_plane() => {
                let n = self.blocks_for(Kind::Reduce, m, req.blocks);
                let (stats, buffer) = spmd_reduce(
                    &self.sk,
                    req.root,
                    req.inputs,
                    n,
                    req.op.clone(),
                    req.elem_bytes,
                    cost,
                    self.backend.rank_plane_transport(),
                    self.tuning.logp.as_ref(),
                )?;
                (stats, buffer)
            }
            Algo::Circulant => {
                let n = self.blocks_for(Kind::Reduce, m, req.blocks);
                let geom = BlockGeometry::new(m, n);
                let procs = build_reduce_procs(
                    &self.schedules(),
                    req.root,
                    geom,
                    req.inputs,
                    req.op.clone(),
                );
                let (stats, procs) = self.run::<T, ReduceProc<T>>(procs, req.elem_bytes, cost)?;
                let buffer = procs.into_iter().nth(req.root).unwrap().into_buffer();
                (stats, buffer)
            }
            Algo::Binomial => {
                let procs = build_procs(p, |r| {
                    BinomialReduceProc::new(p, r, req.root, &req.inputs[r], req.op.clone())
                });
                let (stats, procs) =
                    self.run::<T, BinomialReduceProc<T>>(procs, req.elem_bytes, cost)?;
                let buffer = procs.into_iter().nth(req.root).unwrap().into_buffer();
                (stats, buffer)
            }
            Algo::OptTree => {
                let tree = self.opttree_for(m, req.elem_bytes);
                let procs = build_procs(p, |r| {
                    OptTreeReduceProc::new(
                        tree.clone(),
                        p,
                        r,
                        req.root,
                        &req.inputs[r],
                        req.op.clone(),
                    )
                });
                let (stats, procs) =
                    self.run::<T, OptTreeReduceProc<T>>(procs, req.elem_bytes, cost)?;
                let buffer = procs.into_iter().nth(req.root).unwrap().into_buffer();
                (stats, buffer)
            }
            algo => return Err(CommError::Unsupported { kind: Kind::Reduce, algo }),
        };
        let complete = buffer.len() == m;
        Ok(Outcome {
            rounds: stats.rounds,
            stats,
            buffers: buffer,
            algo,
            complete,
            machine_span: None,
        })
    }

    // ---------------------------------------------------------------
    // All-broadcast
    // ---------------------------------------------------------------

    /// `MPI_Allgatherv`: every rank ends with every rank's contribution.
    /// `buffers[r][j]` is root `j`'s data as received by rank `r`.
    pub fn allgatherv<T: Element>(
        &self,
        req: AllgathervReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<Vec<T>>>>, CommError> {
        let cost = self.cost.clone();
        self.allgatherv_with(req, cost.as_ref())
    }

    /// `MPI_Allgather`: [`Self::allgatherv`] with equal counts enforced.
    pub fn allgather<T: Element>(
        &self,
        req: AllgathervReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<Vec<T>>>>, CommError> {
        let len = req.inputs.first().map(|v| v.len()).unwrap_or(0);
        if req.inputs.iter().any(|v| v.len() != len) {
            return Err(CommError::BadRequest(
                "allgather requires equal counts; use allgatherv for irregular inputs"
                    .to_string(),
            ));
        }
        self.allgatherv(req)
    }

    pub(crate) fn allgatherv_with<T: Element>(
        &self,
        req: AllgathervReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<Vec<Vec<T>>>>, CommError> {
        let p = self.p;
        if req.inputs.len() != p {
            return Err(CommError::BadRequest(format!(
                "allgatherv needs {p} contributions, got {}",
                req.inputs.len()
            )));
        }
        let total: usize = req.inputs.iter().map(|v| v.len()).sum();
        let counts = Arc::new(req.inputs.iter().map(|v| v.len()).collect::<Vec<_>>());
        let algo = req.algo.resolve_with(
            Kind::Allgatherv,
            p,
            total,
            req.elem_bytes,
            req.blocks,
            &self.tuning,
        );
        let (stats, buffers) = match algo {
            Algo::Circulant if self.backend.is_rank_plane() => {
                let n = self.blocks_for(Kind::Allgatherv, total, req.blocks);
                let (stats, bufs) = spmd_allgatherv(
                    &self.sk,
                    req.inputs,
                    n,
                    req.elem_bytes,
                    cost,
                    self.backend.rank_plane_transport(),
                    self.tuning.logp.as_ref(),
                )?;
                (stats, bufs)
            }
            Algo::Circulant => {
                let n = self.blocks_for(Kind::Allgatherv, total, req.blocks);
                let table = self.table(n);
                let procs = build_allgatherv_procs(table, counts, req.inputs);
                let (stats, procs) =
                    self.run::<T, AllgathervProc<T>>(procs, req.elem_bytes, cost)?;
                if let Some(pr) = procs.iter().find(|pr| !pr.complete()) {
                    return Err(CommError::Incomplete { kind: Kind::Allgatherv, rank: pr.rank });
                }
                let bufs: Vec<Vec<Vec<T>>> =
                    procs.into_iter().map(|pr| pr.into_buffers()).collect();
                (stats, bufs)
            }
            Algo::Ring => {
                let procs = build_procs(p, |r| {
                    RingAllgathervProc::new(p, r, counts.clone(), &req.inputs[r])
                });
                let (stats, procs) =
                    self.run::<T, RingAllgathervProc<T>>(procs, req.elem_bytes, cost)?;
                let bufs: Vec<Vec<Vec<T>>> =
                    procs.into_iter().map(|pr| pr.into_buffers()).collect();
                (stats, bufs)
            }
            algo => return Err(CommError::Unsupported { kind: Kind::Allgatherv, algo }),
        };
        // Uniform completion check: every rank holds every root's full
        // contribution.
        let complete = buffers.len() == p
            && buffers.iter().all(|rows| {
                rows.len() == p
                    && rows.iter().zip(req.inputs).all(|(row, inp)| row.len() == inp.len())
            });
        Ok(Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None })
    }

    // ---------------------------------------------------------------
    // Reduce-scatter
    // ---------------------------------------------------------------

    /// `MPI_Reduce_scatter`: rank `j` ends with the fully reduced chunk
    /// `j` (sized `req.counts[j]`). `buffers[r]` is rank `r`'s chunk.
    pub fn reduce_scatter<T: Element>(
        &self,
        req: ReduceScatterReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let cost = self.cost.clone();
        self.reduce_scatter_with(req, cost.as_ref())
    }

    pub(crate) fn reduce_scatter_with<T: Element>(
        &self,
        req: ReduceScatterReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let p = self.p;
        if req.inputs.len() != p || req.counts.len() != p {
            return Err(CommError::BadRequest(format!(
                "reduce_scatter needs {p} contributions and {p} counts, got {} and {}",
                req.inputs.len(),
                req.counts.len()
            )));
        }
        let total: usize = req.counts.iter().sum();
        if req.inputs.iter().any(|v| v.len() != total) {
            return Err(CommError::BadRequest(format!(
                "reduce_scatter contributions must have sum(counts) = {total} elements"
            )));
        }
        let counts = Arc::new(req.counts.to_vec());
        let algo = req.algo.resolve_with(
            Kind::ReduceScatter,
            p,
            total,
            req.elem_bytes,
            req.blocks,
            &self.tuning,
        );
        let (stats, chunks) = match algo {
            Algo::Circulant if self.backend.is_rank_plane() => {
                let n = self.blocks_for(Kind::ReduceScatter, total, req.blocks);
                let (stats, chunks) = spmd_reduce_scatter(
                    &self.sk,
                    req.inputs,
                    req.counts,
                    n,
                    req.op.clone(),
                    req.elem_bytes,
                    cost,
                    self.backend.rank_plane_transport(),
                    self.tuning.logp.as_ref(),
                )?;
                (stats, chunks)
            }
            Algo::Circulant => {
                let n = self.blocks_for(Kind::ReduceScatter, total, req.blocks);
                let table = self.table(n);
                let procs =
                    build_reduce_scatter_procs(table, counts, req.inputs, req.op.clone());
                let (stats, procs) =
                    self.run::<T, ReduceScatterProc<T>>(procs, req.elem_bytes, cost)?;
                let chunks: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_chunk()).collect();
                (stats, chunks)
            }
            Algo::Ring => {
                let procs = build_procs(p, |r| {
                    RingReduceScatterProc::new(p, r, counts.clone(), &req.inputs[r], req.op.clone())
                });
                let (stats, procs) =
                    self.run::<T, RingReduceScatterProc<T>>(procs, req.elem_bytes, cost)?;
                let chunks: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_chunk()).collect();
                (stats, chunks)
            }
            Algo::RecursiveHalving => {
                let chunk = req.counts[0];
                if req.counts.iter().any(|&c| c != chunk) {
                    return Err(CommError::BadRequest(
                        "recursive halving requires equal chunks (reduce_scatter_block)"
                            .to_string(),
                    ));
                }
                let procs = build_procs(p, |r| {
                    RhalvingProc::new(p, r, chunk, &req.inputs[r], req.op.clone())
                });
                let (stats, procs) =
                    self.run::<T, RhalvingProc<T>>(procs, req.elem_bytes, cost)?;
                let chunks: Vec<Vec<T>> = procs.into_iter().map(|pr| pr.into_chunk()).collect();
                (stats, chunks)
            }
            algo => return Err(CommError::Unsupported { kind: Kind::ReduceScatter, algo }),
        };
        // Uniform completion check: rank j holds its counts[j]-element chunk.
        let complete = chunks.len() == p
            && chunks.iter().zip(req.counts).all(|(chunk, &c)| chunk.len() == c);
        Ok(Outcome {
            rounds: stats.rounds,
            stats,
            buffers: chunks,
            algo,
            complete,
            machine_span: None,
        })
    }

    /// `MPI_Reduce_scatter_block`: equal chunk per rank.
    pub fn reduce_scatter_block<T: Element>(
        &self,
        req: ReduceScatterBlockReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let cost = self.cost.clone();
        self.reduce_scatter_block_with(req, cost.as_ref())
    }

    pub(crate) fn reduce_scatter_block_with<T: Element>(
        &self,
        req: ReduceScatterBlockReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let counts = vec![req.block_elems; self.p];
        self.reduce_scatter_with(
            ReduceScatterReq {
                inputs: req.inputs,
                counts: &counts,
                op: req.op,
                blocks: req.blocks,
                algo: req.algo,
                elem_bytes: req.elem_bytes,
            },
            cost,
        )
    }

    // ---------------------------------------------------------------
    // All-reduce
    // ---------------------------------------------------------------

    /// `MPI_Allreduce` as reduce-scatter + all-gather on the same
    /// circulant pattern (or both ring phases for [`Algo::Ring`]).
    /// `buffers[r]` is rank `r`'s fully reduced vector; `stats` and
    /// `rounds` aggregate both phases.
    pub fn allreduce<T: Element>(
        &self,
        req: AllreduceReq<'_, T>,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let cost = self.cost.clone();
        self.allreduce_with(req, cost.as_ref())
    }

    pub(crate) fn allreduce_with<T: Element>(
        &self,
        req: AllreduceReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<Outcome<Vec<Vec<T>>>, CommError> {
        let m = req.inputs.first().map(|v| v.len()).unwrap_or(0);
        let (rs_stats, ag_stats, buffers, algo) = self.allreduce_parts_with(req, cost)?;
        let stats = combine_stats(&rs_stats, &ag_stats);
        // Uniform completion check: every rank holds the full reduced vector.
        let complete =
            buffers.len() == self.p && buffers.iter().all(|b| b.len() == m);
        Ok(Outcome { rounds: stats.rounds, stats, buffers, algo, complete, machine_span: None })
    }

    /// The two phases' stats separately (the per-phase shape the
    /// traffic plane and the SPMD fan-out share).
    pub(crate) fn allreduce_parts_with<T: Element>(
        &self,
        req: AllreduceReq<'_, T>,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, RunStats, Vec<Vec<T>>, Algo), CommError> {
        let p = self.p;
        if req.inputs.len() != p {
            return Err(CommError::BadRequest(format!(
                "allreduce needs {p} contributions, got {}",
                req.inputs.len()
            )));
        }
        let m = req.inputs[0].len();
        if req.inputs.iter().any(|v| v.len() != m) {
            return Err(CommError::BadRequest(
                "allreduce requires equal-length contributions".to_string(),
            ));
        }
        // Chunk m over p ranks as equally as possible.
        let base = m / p;
        let rem = m % p;
        let counts: Vec<usize> = (0..p).map(|j| base + usize::from(j < rem)).collect();
        let counts = Arc::new(counts);
        let algo =
            req.algo.resolve_with(Kind::Allreduce, p, m, req.elem_bytes, req.blocks, &self.tuning);
        match algo {
            Algo::Circulant if self.backend.is_rank_plane() => {
                let n = self.blocks_for(Kind::Allreduce, m, req.blocks);
                let (rs_stats, ag_stats, buffers) = spmd_allreduce(
                    &self.sk,
                    req.inputs,
                    n,
                    req.op.clone(),
                    req.elem_bytes,
                    cost,
                    self.backend.rank_plane_transport(),
                    self.tuning.logp.as_ref(),
                )?;
                Ok((rs_stats, ag_stats, buffers, algo))
            }
            Algo::Circulant => {
                let n = self.blocks_for(Kind::Allreduce, m, req.blocks);
                let table = self.table(n);

                // Phase 1: reduce-scatter (reversed all-broadcast).
                let rs_procs = build_reduce_scatter_procs(
                    table.clone(),
                    counts.clone(),
                    req.inputs,
                    req.op.clone(),
                );
                let (rs_stats, rs_procs) =
                    self.run::<T, ReduceScatterProc<T>>(rs_procs, req.elem_bytes, cost)?;
                let chunks: Vec<Vec<T>> =
                    rs_procs.into_iter().map(|pr| pr.into_chunk()).collect();

                // Phase 2: all-gather of the reduced chunks.
                let ag_procs = build_allgatherv_procs(table, counts, &chunks);
                let (ag_stats, ag_procs) =
                    self.run::<T, AllgathervProc<T>>(ag_procs, req.elem_bytes, cost)?;
                if let Some(pr) = ag_procs.iter().find(|pr| !pr.complete()) {
                    return Err(CommError::Incomplete { kind: Kind::Allreduce, rank: pr.rank });
                }
                let buffers =
                    concat_rows(ag_procs.into_iter().map(|pr| pr.into_buffers()), m);
                Ok((rs_stats, ag_stats, buffers, algo))
            }
            Algo::Ring => {
                let rs_procs = build_procs(p, |r| {
                    RingReduceScatterProc::new(p, r, counts.clone(), &req.inputs[r], req.op.clone())
                });
                let (rs_stats, rs_procs) =
                    self.run::<T, RingReduceScatterProc<T>>(rs_procs, req.elem_bytes, cost)?;
                let chunks: Vec<Vec<T>> =
                    rs_procs.into_iter().map(|pr| pr.into_chunk()).collect();

                let ag_procs = build_procs(p, |r| {
                    RingAllgathervProc::new(p, r, counts.clone(), &chunks[r])
                });
                let (ag_stats, ag_procs) =
                    self.run::<T, RingAllgathervProc<T>>(ag_procs, req.elem_bytes, cost)?;
                let buffers =
                    concat_rows(ag_procs.into_iter().map(|pr| pr.into_buffers()), m);
                Ok((rs_stats, ag_stats, buffers, algo))
            }
            algo => Err(CommError::Unsupported { kind: Kind::Allreduce, algo }),
        }
    }
}

/// Concatenate each rank's per-root rows into one flat `m`-element
/// vector (the all-gather → all-reduce result assembly, shared by the
/// circulant and ring paths).
pub(crate) fn concat_rows<T: Element>(
    rows_per_rank: impl Iterator<Item = Vec<Vec<T>>>,
    m: usize,
) -> Vec<Vec<T>> {
    rows_per_rank
        .map(|rows| {
            let mut out = Vec::with_capacity(m);
            for row in rows {
                out.extend_from_slice(&row);
            }
            out
        })
        .collect()
}

/// Aggregate two phases' statistics: counts and times add;
/// `max_rank_bytes` adds too (an upper bound on the true per-rank
/// maximum over both phases, exact when the same rank is the bottleneck
/// in both — which the symmetric circulant phases make typical).
pub(crate) fn combine_stats(a: &RunStats, b: &RunStats) -> RunStats {
    RunStats {
        rounds: a.rounds + b.rounds,
        active_rounds: a.active_rounds + b.active_rounds,
        messages: a.messages + b.messages,
        bytes: a.bytes + b.bytes,
        max_rank_bytes: a.max_rank_bytes + b.max_rank_bytes,
        time: a.time + b.time,
        // Phases run back-to-back on the modelled machine, so their
        // predicted times add; a phase without the clock attached
        // leaves whatever the other phase measured.
        logp_time: match (a.logp_time, b.logp_time) {
            (Some(x), Some(y)) => Some(x + y),
            (x, y) => x.or(y),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::sim::cost::UnitCost;

    fn comm(p: usize) -> Communicator {
        CommBuilder::new(p).cost_model(UnitCost).build()
    }

    #[test]
    fn bcast_all_algos_deliver() {
        let data: Vec<i32> = (0..500).collect();
        for p in [1usize, 2, 9, 17] {
            let c = comm(p);
            for algo in [Algo::Circulant, Algo::Binomial, Algo::VanDeGeijn] {
                let out = c
                    .bcast(BcastReq::new(0, &data).algo(algo).blocks(4))
                    .unwrap();
                assert_eq!(out.algo, algo);
                assert!(out.all_received());
                for (r, b) in out.buffers.iter().enumerate() {
                    assert_eq!(b, &data, "p={p} algo={algo:?} rank={r}");
                }
            }
        }
    }

    #[test]
    fn bcast_round_optimal_via_comm() {
        let c = comm(17);
        let data: Vec<i64> = (0..340).collect();
        let out = c.bcast(BcastReq::new(3, &data).algo(Algo::Circulant).blocks(7)).unwrap();
        assert_eq!(out.rounds, 7 - 1 + 5);
        assert_eq!(out.rounds, out.stats.rounds);
    }

    #[test]
    fn reduce_circulant_and_binomial() {
        let p = 9usize;
        let m = 60usize;
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..m).map(|i| (r * 10 + i) as i64).collect()).collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let c = comm(p);
        for algo in [Algo::Circulant, Algo::Binomial] {
            let out = c
                .reduce(ReduceReq::new(4, &inputs, Arc::new(SumOp)).algo(algo).blocks(3))
                .unwrap();
            assert_eq!(out.buffers, expect, "{algo:?}");
        }
    }

    #[test]
    fn allgather_rejects_irregular() {
        let c = comm(3);
        let inputs = vec![vec![1i32, 2], vec![3], vec![4, 5]];
        assert!(matches!(
            c.allgather(AllgathervReq::new(&inputs)),
            Err(CommError::BadRequest(_))
        ));
        // allgatherv accepts the same inputs.
        let out = c.allgatherv(AllgathervReq::new(&inputs).blocks(2)).unwrap();
        for r in 0..3 {
            for j in 0..3 {
                assert_eq!(out.buffers[r][j], inputs[j]);
            }
        }
    }

    #[test]
    fn reduce_scatter_block_equals_counts_path() {
        let p = 8usize;
        let chunk = 5usize;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..p * chunk).map(|i| ((r + 2) * (i + 1)) as i64).collect())
            .collect();
        let sums: Vec<i64> =
            (0..p * chunk).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let c = comm(p);
        for algo in [Algo::Circulant, Algo::Ring, Algo::RecursiveHalving] {
            let out = c
                .reduce_scatter_block(
                    ReduceScatterBlockReq::new(&inputs, chunk, Arc::new(SumOp))
                        .algo(algo)
                        .blocks(2),
                )
                .unwrap();
            for r in 0..p {
                assert_eq!(
                    out.buffers[r],
                    sums[r * chunk..(r + 1) * chunk].to_vec(),
                    "{algo:?} rank {r}"
                );
            }
        }
    }

    #[test]
    fn allreduce_circulant_and_ring() {
        let p = 7usize;
        let m = 61usize;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r + 1) * (i + 1)) as i64 % 503).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let c = comm(p);
        for algo in [Algo::Circulant, Algo::Ring] {
            let out = c
                .allreduce(AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(algo).blocks(2))
                .unwrap();
            for r in 0..p {
                assert_eq!(out.buffers[r], expect, "{algo:?} rank {r}");
            }
        }
    }

    #[test]
    fn unsupported_combinations_error() {
        let c = comm(5);
        let data = vec![1i32; 10];
        let inputs: Vec<Vec<i64>> = (0..5).map(|_| vec![1i64; 10]).collect();
        assert!(matches!(
            c.bcast(BcastReq::new(0, &data).algo(Algo::Ring)),
            Err(CommError::Unsupported { kind: Kind::Bcast, algo: Algo::Ring })
        ));
        assert!(matches!(
            c.reduce(ReduceReq::new(0, &inputs, Arc::new(SumOp)).algo(Algo::VanDeGeijn)),
            Err(CommError::Unsupported { .. })
        ));
        assert!(matches!(
            c.allgatherv(AllgathervReq::new(&inputs).algo(Algo::Binomial)),
            Err(CommError::Unsupported { .. })
        ));
    }

    #[test]
    fn bad_requests_rejected() {
        let c = comm(4);
        let data = vec![0i32; 4];
        assert!(matches!(
            c.bcast(BcastReq::new(4, &data)),
            Err(CommError::BadRequest(_))
        ));
        let short: Vec<Vec<i64>> = vec![vec![1]; 3]; // 3 != p
        assert!(matches!(
            c.reduce(ReduceReq::new(0, &short, Arc::new(SumOp))),
            Err(CommError::BadRequest(_))
        ));
    }

    #[test]
    fn auto_resolves_by_size() {
        let c = comm(9);
        let small: Vec<i32> = (0..16).collect();
        let out = c.bcast(BcastReq::new(0, &small)).unwrap();
        assert_eq!(out.algo, Algo::Binomial);
        let large: Vec<i32> = (0..100_000).collect();
        let out = c.bcast(BcastReq::new(0, &large)).unwrap();
        assert_eq!(out.algo, Algo::Circulant);
    }

    #[test]
    fn engine_backend_matches_lockstep() {
        let p = 13usize;
        let data: Vec<i64> = (0..161).map(|i| i * 5 % 89).collect();
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..77).map(|i| ((r + 1) * (i + 2)) as i64 % 101).collect())
            .collect();
        let mk = |backend| CommBuilder::new(p).cost_model(UnitCost).backend(backend).build();
        for root in [0usize, 5, 12] {
            let a = mk(BackendKind::Lockstep)
                .bcast(BcastReq::new(root, &data).algo(Algo::Circulant).blocks(6))
                .unwrap();
            let b = mk(BackendKind::Engine)
                .bcast(BcastReq::new(root, &data).algo(Algo::Circulant).blocks(6))
                .unwrap();
            assert_eq!(a.buffers, b.buffers, "root={root}");
            assert_eq!(a.stats.rounds, b.stats.rounds, "root={root}");
            assert_eq!(a.stats.messages, b.stats.messages, "root={root}");
            assert_eq!(a.stats.bytes, b.stats.bytes, "root={root}");
            assert_eq!(a.stats.max_rank_bytes, b.stats.max_rank_bytes, "root={root}");
            assert!(a.all_received() && b.all_received());

            let req = || ReduceReq::new(root, &inputs, Arc::new(SumOp))
                .algo(Algo::Circulant)
                .blocks(4);
            let ra = mk(BackendKind::Lockstep).reduce(req()).unwrap();
            let rb = mk(BackendKind::Engine).reduce(req()).unwrap();
            assert_eq!(ra.buffers, rb.buffers, "root={root}");
            assert_eq!(ra.stats.messages, rb.stats.messages, "root={root}");
        }
    }

    #[test]
    fn threaded_backend_matches_lockstep() {
        let p = 11usize;
        let data: Vec<i64> = (0..121).map(|i| i * 3 % 97).collect();
        let lockstep = comm(p)
            .bcast(BcastReq::new(2, &data).algo(Algo::Circulant).blocks(5))
            .unwrap();
        let threaded = CommBuilder::new(p)
            .cost_model(UnitCost)
            .backend(BackendKind::Threaded)
            .build()
            .bcast(BcastReq::new(2, &data).algo(Algo::Circulant).blocks(5))
            .unwrap();
        assert_eq!(lockstep.buffers, threaded.buffers);
        assert_eq!(lockstep.stats.messages, threaded.stats.messages);
        assert_eq!(lockstep.stats.bytes, threaded.stats.bytes);
        assert_eq!(lockstep.stats.rounds, threaded.stats.rounds);
    }
}
