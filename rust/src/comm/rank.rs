//! The SPMD rank plane: a per-rank [`RankComm`] handle that runs the
//! paper's collectives the way the paper says processors do — **each
//! rank computes only its own O(log p) schedule, independently, without
//! communication**, and exchanges messages through a pluggable
//! [`Transport`].
//!
//! Every public entry point elsewhere in this crate is a "god view": one
//! caller owns all `p` ranks' inputs and a whole-machine schedule table
//! serves the backends. A `RankComm` is the opposite — the MPI-shaped
//! programming model: constructed per rank from `(p, r)` + a shared
//! `Arc<Skips>` (the O(log p) skip table every rank derives from `p`
//! alone), it computes **only its own** recv/send rows with the per-rank
//! cores ([`crate::schedule::recv_schedule_into`] /
//! [`crate::schedule::send_schedule_into`]) and binds only caller-owned
//! `&mut [T]` buffers:
//!
//! ```no_run
//! use std::sync::Arc;
//! use circulant_bcast::comm::{RankComm, ThreadTransport};
//! use circulant_bcast::schedule::Skips;
//!
//! let p = 8;
//! let sk = Arc::new(Skips::new(p));
//! std::thread::scope(|s| {
//!     for (r, mut tr) in ThreadTransport::<i64>::world(p).into_iter().enumerate() {
//!         let sk = sk.clone();
//!         s.spawn(move || {
//!             let rc = RankComm::new(p, r, sk);          // O(log p) state
//!             let mut buf = vec![r as i64; 1024];        // caller-owned
//!             rc.allreduce(&mut tr, &mut buf, 4,
//!                 Arc::new(circulant_bcast::collectives::SumOp)).unwrap();
//!         });
//!     }
//! });
//! ```
//!
//! # Per-rank schedule state
//!
//! * **Rooted collectives** ([`RankComm::bcast`], [`RankComm::reduce`])
//!   — the hot path: `2q` i8-sized entries (one recv row + one send row
//!   at this rank's root-relative position), recomputed in O(log p) per
//!   call. No [`crate::schedule::ScheduleTable`], no other rank's row,
//!   ever: per-rank schedule state is O(log p), not O(p log p) —
//!   exactly the paper's Theorem 2/3 discipline.
//! * **All-collectives** ([`RankComm::allgatherv`],
//!   [`RankComm::reduce_scatter`], [`RankComm::allreduce`]) — Algorithm
//!   7 has every processor participate in `p` concurrent broadcasts, so
//!   each rank computes its *own* relative row for every root `j`
//!   (positions `(r - j) mod p`, which sweep all `p` relative ranks):
//!   Θ(p log p) per rank, proportional to the `p` result buffers the
//!   rank must hold anyway, still rank-local and communication-free
//!   ([`crate::collectives::allgatherv::ScheduleTable::build_rank_local`]).
//!
//! # Driving and the round discipline
//!
//! Each collective is one pass of the one-ported round loop over the
//! [`Transport`]: per round — at most one send, a flush, at most one
//! receive — then a close. The transport chooses the execution style:
//! [`ThreadTransport`] is the real one-thread-per-rank runtime (ranks
//! genuinely concurrent, free-running), [`LoopbackTransport`] replays
//! the lockstep round barrier with the full machine-model check set.
//! The differential suite (`tests/spmd_parity.rs`) pins both
//! bit-identical to the god-view backends.
//!
//! # The fan-out bridge
//!
//! [`spmd_bcast`] and friends fan a god-view request out to `p`
//! `RankComm`s (one scoped thread per rank over the chosen transport)
//! and reassemble a god-view result with the lockstep statistics
//! accounting — this is what [`crate::comm::BackendKind::Spmd`] runs
//! under the [`crate::comm::Communicator`].

use std::sync::Arc;

use crate::collectives::allgatherv::{AllgathervProc, ScheduleTable as AgScheduleTable};
use crate::collectives::bcast::BcastProc;
use crate::collectives::common::{BlockGeometry, Element, PhasedSchedule, ReduceOp};
use crate::collectives::reduce::ReduceProc;
use crate::collectives::reduce_scatter::ReduceScatterProc;
use crate::schedule::recv::MAX_Q;
use crate::schedule::{recv_schedule_into, send_schedule_into, Skips};
use crate::sim::cost::{CostModel, LogPParams};
use crate::sim::network::{Msg, RankProc, RunStats};
use crate::sim::threads::fold_send_logs;

use super::chaos::FaultPlan;
use super::outcome::CommError;
use super::request::Kind;
use super::socket::SocketTransport;
use super::transport::{LoopbackTransport, ThreadTransport, Transport, TransportError};

/// Per-rank receipts of one collective run: what this rank did, in its
/// own frame. The fan-out helpers fold all `p` of these into the exact
/// god-view [`RunStats`] a lockstep run would report.
#[derive(Debug, Clone, Default)]
pub struct RankRun {
    /// Rounds this rank's state machine spans (including no-op rounds).
    pub rounds: usize,
    /// This rank's sends as `(round, to, payload elements)`, in round
    /// order (rounds are collective-local; multi-phase collectives
    /// report one `RankRun` per phase).
    pub sends: Vec<(usize, usize, usize)>,
    /// Messages this rank received.
    pub recvs: usize,
}

/// A per-rank communicator handle — see the module docs for the model.
///
/// State is `(p, rank, Arc<Skips>)`: O(log p). Schedules are computed
/// per call (they are root-relative), also in O(log p) for the rooted
/// collectives — the paper's headline cost, paid where the paper says
/// it is paid: on every processor, independently.
pub struct RankComm {
    p: usize,
    rank: usize,
    sk: Arc<Skips>,
}

impl RankComm {
    /// Handle for `rank` of a `p`-rank world sharing the skip table
    /// `sk` (every rank derives the same `Skips` from `p` alone — the
    /// `Arc` is an in-process convenience, not shared schedule state).
    pub fn new(p: usize, rank: usize, sk: Arc<Skips>) -> Self {
        assert!(p > 0, "a world needs at least one rank");
        assert!(rank < p, "rank {rank} out of range for p = {p}");
        assert_eq!(sk.p(), p, "skip table built for a different p");
        RankComm { p, rank, sk }
    }

    /// [`RankComm::new`] computing its own skip table (O(log p)).
    pub fn for_rank(p: usize, rank: usize) -> Self {
        Self::new(p, rank, Arc::new(Skips::new(p)))
    }

    /// Rebuild this handle for the **survivor world** after the listed
    /// ranks (dense ranks of *this* world) died: survivors are
    /// renumbered densely in rank order and the new handle derives a
    /// fresh `Skips` for `p − |failed|` — O(log p′) per rank,
    /// communication-free, which is exactly why the paper's schedules
    /// make membership shrink cheap (every survivor rebuilds locally;
    /// nobody redistributes schedule state). Returns `None` when this
    /// rank is itself among the failed or nobody survives; duplicate
    /// and out-of-range entries in `failed` are ignored. The epoch
    /// bookkeeping lives one layer up in
    /// [`super::membership::Membership`] — this is the per-rank
    /// renumbering it prescribes.
    pub fn shrink(&self, failed: &[usize]) -> Option<RankComm> {
        let mut dead = vec![false; self.p];
        for &f in failed {
            if f < self.p {
                dead[f] = true;
            }
        }
        if dead[self.rank] {
            return None;
        }
        let new_p = dead.iter().filter(|&&d| !d).count();
        let dead_below = dead[..self.rank].iter().filter(|&&d| d).count();
        Some(RankComm::new(new_p, self.rank - dead_below, Arc::new(Skips::new(new_p))))
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// `q = ceil(log2 p)`.
    #[inline]
    pub fn q(&self) -> usize {
        self.sk.q()
    }

    #[inline]
    pub fn skips(&self) -> &Arc<Skips> {
        &self.sk
    }

    /// This rank's own phased schedule for a collective rooted at
    /// `root` with `n` blocks: the per-rank cores fill two stack rows
    /// (zero heap beyond the returned O(log p) schedule), nothing else.
    fn own_phased(&self, root: usize, n: usize) -> PhasedSchedule {
        let rel = (self.rank + self.p - root % self.p) % self.p;
        let mut recv = [0i64; MAX_Q];
        let mut send = [0i64; MAX_Q];
        let bb = recv_schedule_into(&self.sk, rel, &mut recv);
        send_schedule_into(&self.sk, rel, bb, &mut send);
        PhasedSchedule::from_own_rows(self.sk.clone(), rel, &recv, &send, n)
    }

    fn check_call<T, Tr: Transport<T>>(
        &self,
        tr: &Tr,
        blocks: usize,
    ) -> Result<(), CommError> {
        if tr.p() != self.p || tr.rank() != self.rank {
            return Err(CommError::BadRequest(format!(
                "transport endpoint is rank {}/{} but this handle is rank {}/{}",
                tr.rank(),
                tr.p(),
                self.rank,
                self.p
            )));
        }
        if blocks == 0 {
            return Err(CommError::BadRequest("block count must be >= 1".to_string()));
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Rooted collectives: O(log p) schedule state
    // ---------------------------------------------------------------

    /// Rank-local `MPI_Bcast` (Algorithm 1, `blocks` pipeline blocks):
    /// at `root`, `buf` holds the payload; everywhere else its contents
    /// are overwritten with the received payload. All ranks must pass
    /// the same `root`, `buf.len()` and `blocks` — the SPMD contract.
    pub fn bcast<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        root: usize,
        buf: &mut [T],
        blocks: usize,
    ) -> Result<RankRun, CommError> {
        // Validation failures go through close_after too: an invalid
        // call on one rank must still bring the world down instead of
        // leaving siblings blocked until their timeout.
        let res = self.bcast_inner(tr, root, buf, blocks);
        close_after::<T, Tr, _>(tr, res)
    }

    fn bcast_inner<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        root: usize,
        buf: &mut [T],
        blocks: usize,
    ) -> Result<RankRun, CommError> {
        self.check_call::<T, Tr>(tr, blocks)?;
        self.check_root(root, "bcast")?;
        let geom = BlockGeometry::new(buf.len(), blocks);
        let ps = self.own_phased(root, blocks);
        let data = if self.rank == root { Some(&buf[..]) } else { None };
        let mut proc_ = BcastProc::with_schedule(ps, self.rank, root, geom, data);
        let run = drive_proc(&mut proc_, tr, 0).map_err(CommError::Transport)?;
        if !proc_.complete() {
            return Err(CommError::Incomplete { kind: Kind::Bcast, rank: self.rank });
        }
        buf.copy_from_slice(&proc_.into_buffer());
        Ok(run)
    }

    /// Rank-local `MPI_Reduce` (reversed schedules, Observation 1.3):
    /// every rank contributes `buf`; at `root`, `buf` is overwritten
    /// with the elementwise ⊕ over all ranks (non-root buffers are left
    /// untouched).
    pub fn reduce<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        root: usize,
        buf: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<RankRun, CommError> {
        let res = self.reduce_inner(tr, root, buf, blocks, op);
        close_after::<T, Tr, _>(tr, res)
    }

    fn reduce_inner<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        root: usize,
        buf: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<RankRun, CommError> {
        self.check_call::<T, Tr>(tr, blocks)?;
        self.check_root(root, "reduce")?;
        let geom = BlockGeometry::new(buf.len(), blocks);
        let ps = self.own_phased(root, blocks);
        let mut proc_ = ReduceProc::with_schedule(ps, self.rank, root, geom, buf, op);
        let run = drive_proc(&mut proc_, tr, 0).map_err(CommError::Transport)?;
        if self.rank == root {
            buf.copy_from_slice(&proc_.into_buffer());
        }
        Ok(run)
    }

    // ---------------------------------------------------------------
    // All-collectives: Θ(p log p) rank-local schedule state (Alg. 7)
    // ---------------------------------------------------------------

    /// Rank-local `MPI_Allgatherv` (Algorithm 7): `buf` is the full
    /// concatenated result buffer (`sum(counts)` elements) with this
    /// rank's own segment pre-filled; on success every segment is
    /// filled with its root's contribution.
    pub fn allgatherv<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        counts: &[usize],
        buf: &mut [T],
        blocks: usize,
    ) -> Result<RankRun, CommError> {
        let res = self.allgatherv_inner(tr, counts, buf, blocks);
        close_after::<T, Tr, _>(tr, res)
    }

    fn allgatherv_inner<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        counts: &[usize],
        buf: &mut [T],
        blocks: usize,
    ) -> Result<RankRun, CommError> {
        self.check_call::<T, Tr>(tr, blocks)?;
        self.check_counts(counts, buf.len(), "allgatherv")?;
        let table = AgScheduleTable::build_rank_local(&self.sk, blocks);
        let off_r: usize = counts[..self.rank].iter().sum();
        let own = &buf[off_r..off_r + counts[self.rank]];
        let mut proc_ =
            AllgathervProc::new(table, Arc::new(counts.to_vec()), self.rank, own);
        let run = drive_proc(&mut proc_, tr, 0).map_err(CommError::Transport)?;
        if !proc_.complete() {
            return Err(CommError::Incomplete { kind: Kind::Allgatherv, rank: self.rank });
        }
        scatter_rows(buf, counts, proc_.into_buffers());
        Ok(run)
    }

    /// Rank-local `MPI_Reduce_scatter` (reversed Algorithm 7,
    /// Observation 1.4): `input` is this rank's full contribution
    /// (`sum(counts)` elements, concatenated per destination); `out`
    /// (`counts[rank]` elements) receives this rank's fully reduced
    /// chunk.
    pub fn reduce_scatter<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        counts: &[usize],
        input: &[T],
        out: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<RankRun, CommError> {
        let res = self.reduce_scatter_inner(tr, counts, input, out, blocks, op);
        close_after::<T, Tr, _>(tr, res)
    }

    fn reduce_scatter_inner<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        counts: &[usize],
        input: &[T],
        out: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<RankRun, CommError> {
        self.check_call::<T, Tr>(tr, blocks)?;
        self.check_counts(counts, input.len(), "reduce_scatter")?;
        if out.len() != counts[self.rank] {
            return Err(CommError::BadRequest(format!(
                "reduce_scatter out buffer must hold counts[{}] = {} elements, got {}",
                self.rank,
                counts[self.rank],
                out.len()
            )));
        }
        let table = AgScheduleTable::build_rank_local(&self.sk, blocks);
        let mut proc_ =
            ReduceScatterProc::new(table, Arc::new(counts.to_vec()), self.rank, input, op);
        let run = drive_proc(&mut proc_, tr, 0).map_err(CommError::Transport)?;
        out.copy_from_slice(&proc_.into_chunk());
        Ok(run)
    }

    /// Rank-local `MPI_Allreduce` (reduce-scatter + all-gather on the
    /// same circulant pattern): `buf` contributes this rank's vector
    /// and is overwritten with the elementwise ⊕ over all ranks.
    /// Returns one [`RankRun`] per phase (their round tags are
    /// contiguous on the transport).
    pub fn allreduce<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        buf: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<(RankRun, RankRun), CommError> {
        let res = self.allreduce_inner(tr, buf, blocks, op);
        close_after::<T, Tr, _>(tr, res)
    }

    fn allreduce_inner<T: Element, Tr: Transport<T>>(
        &self,
        tr: &mut Tr,
        buf: &mut [T],
        blocks: usize,
        op: Arc<dyn ReduceOp<T>>,
    ) -> Result<(RankRun, RankRun), CommError> {
        self.check_call::<T, Tr>(tr, blocks)?;
        let counts = Arc::new(allreduce_counts(self.p, buf.len()));
        let table = AgScheduleTable::build_rank_local(&self.sk, blocks);

        // Phase 1: reduce-scatter (reversed all-broadcast).
        let mut rs =
            ReduceScatterProc::new(table.clone(), counts.clone(), self.rank, buf, op);
        let run_rs = drive_proc(&mut rs, tr, 0).map_err(CommError::Transport)?;
        let chunk = rs.into_chunk();

        // Phase 2: all-gather of the reduced chunks; round tags continue
        // where phase 1 stopped, so one transport world serves both.
        let mut ag = AllgathervProc::new(table, counts.clone(), self.rank, &chunk);
        let run_ag =
            drive_proc(&mut ag, tr, run_rs.rounds).map_err(CommError::Transport)?;
        if !ag.complete() {
            return Err(CommError::Incomplete { kind: Kind::Allreduce, rank: self.rank });
        }
        scatter_rows(buf, &counts, ag.into_buffers());
        Ok((run_rs, run_ag))
    }

    fn check_root(&self, root: usize, what: &str) -> Result<(), CommError> {
        if root >= self.p {
            return Err(CommError::BadRequest(format!(
                "{what} root {root} out of range for p = {}",
                self.p
            )));
        }
        Ok(())
    }

    fn check_counts(
        &self,
        counts: &[usize],
        have: usize,
        what: &str,
    ) -> Result<usize, CommError> {
        if counts.len() != self.p {
            return Err(CommError::BadRequest(format!(
                "{what} needs {} counts, got {}",
                self.p,
                counts.len()
            )));
        }
        let total: usize = counts.iter().sum();
        if have != total {
            return Err(CommError::BadRequest(format!(
                "{what} buffer must hold sum(counts) = {total} elements, got {have}"
            )));
        }
        Ok(total)
    }
}

/// The equal-as-possible chunking the god-view all-reduce uses — one
/// definition so the SPMD plane splits identically.
fn allreduce_counts(p: usize, m: usize) -> Vec<usize> {
    let base = m / p;
    let rem = m % p;
    (0..p).map(|j| base + usize::from(j < rem)).collect()
}

/// Copy per-root rows back into the flat concatenated buffer.
fn scatter_rows<T: Element>(buf: &mut [T], counts: &[usize], rows: Vec<Vec<T>>) {
    let mut off = 0usize;
    for (j, row) in rows.into_iter().enumerate() {
        buf[off..off + counts[j]].copy_from_slice(&row);
        off += counts[j];
    }
}

/// Retire the transport endpoint, preserving the collective's own error
/// (a failing rank must shut the world down so no sibling deadlocks).
/// Shared with the generic [`crate::comm::SpmdBackend`] driver.
pub(crate) fn close_after<T, Tr: Transport<T>, R>(
    tr: &mut Tr,
    res: Result<R, CommError>,
) -> Result<R, CommError> {
    match res {
        Ok(v) => match tr.close(None) {
            Ok(()) => Ok(v),
            Err(e) => Err(CommError::Transport(e)),
        },
        Err(e) => {
            let _ = tr.close(Some(&e.to_string()));
            Err(e)
        }
    }
}

/// The one-ported round loop: per round — at most one send, a flush, at
/// most one receive — exactly the discipline the [`Transport`] contract
/// states. Shared by every [`RankComm`] collective and the generic
/// [`crate::comm::SpmdBackend`] proc driver, so the rank plane has a
/// single definition of "drive a state machine over a transport".
pub(crate) fn drive_proc<T, P, Tr>(
    proc_: &mut P,
    tr: &mut Tr,
    base_round: usize,
) -> Result<RankRun, TransportError>
where
    T: Element,
    P: RankProc<T>,
    Tr: Transport<T>,
{
    let rounds = proc_.rounds();
    let mut sends = Vec::new();
    let mut recvs = 0usize;
    for j in 0..rounds {
        let tag = base_round + j;
        if let Some(Msg { to, data }) = proc_.send(j) {
            sends.push((j, to, data.len()));
            tr.send(tag, to, data)?;
        }
        tr.flush(tag)?;
        if let Some(from) = proc_.expects(j) {
            let data = tr.recv(tag, from)?;
            proc_.recv(j, from, data);
            recvs += 1;
        }
    }
    Ok(RankRun { rounds, sends, recvs })
}

// ---------------------------------------------------------------------
// The fan-out bridge: god-view request -> p RankComms -> god-view result
// ---------------------------------------------------------------------

/// Which transport a fan-out drives the ranks over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// [`ThreadTransport`]: the real one-thread-per-rank runtime,
    /// free-running — what [`crate::comm::BackendKind::Spmd`] uses.
    Threads,
    /// [`LoopbackTransport`]: the lockstep round-barrier replay with
    /// full machine-model checks — the differential mirror.
    Loopback,
    /// [`crate::comm::socket::SocketTransport`] over in-process
    /// `UnixStream::pair` meshes: the wire plane's real-socket
    /// endpoints — what [`crate::comm::BackendKind::Socket`] uses.
    Socket,
    /// [`TransportKind::Socket`] with a seeded [`FaultPlan`] threaded
    /// into every link's write path: the chaos plane's byte-level
    /// injection point. The v3 reliability layer heals the injected
    /// faults, so results stay bit-identical to [`TransportKind::Socket`]
    /// — the differential chaos grid (`tests/chaos.rs`) pins exactly
    /// that.
    ChaosSocket(FaultPlan),
}

/// Run `per_rank` on one scoped thread per world endpoint; a panicking
/// rank poisons its world (so siblings fail fast instead of
/// deadlocking) before the panic propagates.
fn fanout<T, Tr, R, F>(world: Vec<Tr>, per_rank: F) -> Vec<Result<R, CommError>>
where
    T: Element,
    Tr: Transport<T> + Send,
    R: Send,
    F: Fn(usize, &mut Tr) -> Result<R, CommError> + Sync,
{
    std::thread::scope(|s| {
        let f = &per_rank;
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(r, mut tr)| {
                s.spawn(move || {
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || f(r, &mut tr),
                    ));
                    match res {
                        Ok(v) => v,
                        Err(payload) => {
                            let _ = tr.close(Some("rank thread panicked"));
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("SPMD rank thread panicked"))
            .collect()
    })
}

/// Error-triage tiers for one rank's failure, from most to least
/// informative: a genuine violation or misuse beats a starved victim's
/// own timeout, which beats a shutdown echo of some *other* rank's
/// failure — the one policy shared by every SPMD fan-out (this module's
/// `spmd_*` helpers and the generic [`crate::comm::SpmdBackend`]).
fn triage(e: &CommError) -> u8 {
    match e {
        CommError::Transport(TransportError::Shutdown { .. }) => 2,
        CommError::Transport(TransportError::Timeout { .. }) => 1,
        _ => 0,
    }
}

/// All ranks' results, or the most informative error (ties broken by
/// rank order).
pub(crate) fn collect_ranks<R>(
    results: Vec<Result<R, CommError>>,
) -> Result<Vec<R>, CommError> {
    let mut ok = Vec::with_capacity(results.len());
    let mut best: Option<(u8, CommError)> = None;
    for res in results {
        match res {
            Ok(v) => ok.push(v),
            Err(e) => {
                let tier = triage(&e);
                if best.as_ref().map_or(true, |(t, _)| tier < *t) {
                    best = Some((tier, e));
                }
            }
        }
    }
    match best {
        Some((_, e)) => Err(e),
        None => Ok(ok),
    }
}

/// Fold per-rank [`RankRun`]s into god-view [`RunStats`] with the
/// lockstep accounting (shared with the threaded runtime); consumes the
/// runs so the send logs move instead of being cloned.
fn fold_runs(
    runs: Vec<RankRun>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> RunStats {
    let total_rounds = runs.iter().map(|r| r.rounds).max().unwrap_or(0);
    let logs: Vec<Vec<(usize, usize, usize)>> = runs.into_iter().map(|r| r.sends).collect();
    fold_send_logs(&logs, total_rounds, elem_bytes, cost, logp)
}

fn make_world<T: Element>(p: usize, kind: TransportKind) -> Result<WorldEndpoints<T>, CommError> {
    Ok(match kind {
        TransportKind::Threads => WorldEndpoints::Threads(ThreadTransport::world(p)),
        TransportKind::Loopback => WorldEndpoints::Loopback(LoopbackTransport::world(p)),
        // Socket worlds can genuinely fail to build: a non-wire-
        // encodable element type, or descriptor exhaustion (a full
        // mesh holds p·(p−1) socket ends).
        TransportKind::Socket => WorldEndpoints::Socket(
            SocketTransport::pair_world(p)
                .map_err(|e| CommError::BadRequest(format!("socket world (p = {p}): {e}")))?,
        ),
        TransportKind::ChaosSocket(plan) => WorldEndpoints::Socket(
            SocketTransport::pair_world_chaos(p, super::transport::configured_timeout(), plan)
                .map_err(|e| {
                    CommError::BadRequest(format!("chaos socket world (p = {p}): {e}"))
                })?,
        ),
    })
}

enum WorldEndpoints<T> {
    Threads(Vec<ThreadTransport<T>>),
    Loopback(Vec<LoopbackTransport<T>>),
    Socket(Vec<SocketTransport<T>>),
}

macro_rules! over_world {
    ($world:expr, $per_rank:expr) => {
        match $world {
            WorldEndpoints::Threads(w) => fanout(w, $per_rank),
            WorldEndpoints::Loopback(w) => fanout(w, $per_rank),
            WorldEndpoints::Socket(w) => fanout(w, $per_rank),
        }
    };
}

/// Fan a broadcast out to `p` [`RankComm`]s over `kind` and reassemble
/// the god-view `(stats, per-rank buffers)` — bit-identical to a
/// lockstep run on healthy schedules. `logp` attaches the cost plane's
/// [`crate::sim::LogPClock`] to the folded stats (`RunStats::logp_time`).
#[allow(clippy::too_many_arguments)]
pub fn spmd_bcast<T: Element>(
    sk: &Arc<Skips>,
    root: usize,
    data: &[T],
    blocks: usize,
    elem_bytes: usize,
    cost: &dyn CostModel,
    kind: TransportKind,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<Vec<T>>), CommError> {
    let p = sk.p();
    let m = data.len();
    let results = over_world!(make_world::<T>(p, kind)?, |r, tr: &mut _| {
        let rc = RankComm::new(p, r, sk.clone());
        let mut buf = if r == root { data.to_vec() } else { vec![T::default(); m] };
        let run = rc.bcast(tr, root, &mut buf, blocks)?;
        Ok((buf, run))
    });
    let (bufs, runs): (Vec<_>, Vec<_>) = collect_ranks(results)?.into_iter().unzip();
    let stats = fold_runs(runs, elem_bytes, cost, logp);
    Ok((stats, bufs))
}

/// Fan a rooted reduction out; returns `(stats, root buffer)`.
#[allow(clippy::too_many_arguments)]
pub fn spmd_reduce<T: Element>(
    sk: &Arc<Skips>,
    root: usize,
    inputs: &[Vec<T>],
    blocks: usize,
    op: Arc<dyn ReduceOp<T>>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    kind: TransportKind,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<T>), CommError> {
    let p = sk.p();
    let results = over_world!(make_world::<T>(p, kind)?, |r, tr: &mut _| {
        let rc = RankComm::new(p, r, sk.clone());
        let mut buf = inputs[r].clone();
        let run = rc.reduce(tr, root, &mut buf, blocks, op.clone())?;
        Ok((buf, run))
    });
    let (bufs, runs): (Vec<_>, Vec<_>) = collect_ranks(results)?.into_iter().unzip();
    let stats = fold_runs(runs, elem_bytes, cost, logp);
    let buffer = bufs.into_iter().nth(root).unwrap_or_default();
    Ok((stats, buffer))
}

/// Fan an all-broadcast out; returns `(stats, buffers[rank][root])`.
#[allow(clippy::too_many_arguments)]
pub fn spmd_allgatherv<T: Element>(
    sk: &Arc<Skips>,
    inputs: &[Vec<T>],
    blocks: usize,
    elem_bytes: usize,
    cost: &dyn CostModel,
    kind: TransportKind,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<Vec<Vec<T>>>), CommError> {
    let p = sk.p();
    let counts: Vec<usize> = inputs.iter().map(|v| v.len()).collect();
    let total: usize = counts.iter().sum();
    let counts = &counts;
    let results = over_world!(make_world::<T>(p, kind)?, |r, tr: &mut _| {
        let rc = RankComm::new(p, r, sk.clone());
        let mut buf = vec![T::default(); total];
        let off: usize = counts[..r].iter().sum();
        buf[off..off + counts[r]].copy_from_slice(&inputs[r]);
        let run = rc.allgatherv(tr, counts, &mut buf, blocks)?;
        Ok((buf, run))
    });
    let (flats, runs): (Vec<_>, Vec<_>) = collect_ranks(results)?.into_iter().unzip();
    let stats = fold_runs(runs, elem_bytes, cost, logp);
    let buffers = flats.into_iter().map(|flat| split_by_counts(&flat, counts)).collect();
    Ok((stats, buffers))
}

/// Fan an all-reduction (reduce-scatter) out; returns
/// `(stats, chunks[rank])`.
#[allow(clippy::too_many_arguments)]
pub fn spmd_reduce_scatter<T: Element>(
    sk: &Arc<Skips>,
    inputs: &[Vec<T>],
    counts: &[usize],
    blocks: usize,
    op: Arc<dyn ReduceOp<T>>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    kind: TransportKind,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<Vec<T>>), CommError> {
    let p = sk.p();
    let results = over_world!(make_world::<T>(p, kind)?, |r, tr: &mut _| {
        let rc = RankComm::new(p, r, sk.clone());
        let mut out = vec![T::default(); counts[r]];
        let run = rc.reduce_scatter(tr, counts, &inputs[r], &mut out, blocks, op.clone())?;
        Ok((out, run))
    });
    let (chunks, runs): (Vec<_>, Vec<_>) = collect_ranks(results)?.into_iter().unzip();
    let stats = fold_runs(runs, elem_bytes, cost, logp);
    Ok((stats, chunks))
}

/// Fan an all-reduce out; returns the two phases' stats separately
/// (the god view combines them with its usual phase-sum rule) plus
/// every rank's reduced vector.
#[allow(clippy::too_many_arguments)]
pub fn spmd_allreduce<T: Element>(
    sk: &Arc<Skips>,
    inputs: &[Vec<T>],
    blocks: usize,
    op: Arc<dyn ReduceOp<T>>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    kind: TransportKind,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, RunStats, Vec<Vec<T>>), CommError> {
    let p = sk.p();
    let results = over_world!(make_world::<T>(p, kind)?, |r, tr: &mut _| {
        let rc = RankComm::new(p, r, sk.clone());
        let mut buf = inputs[r].clone();
        let (run_rs, run_ag) = rc.allreduce(tr, &mut buf, blocks, op.clone())?;
        Ok((buf, run_rs, run_ag))
    });
    let per_rank = collect_ranks(results)?;
    let mut bufs = Vec::with_capacity(per_rank.len());
    let mut rs_runs = Vec::with_capacity(per_rank.len());
    let mut ag_runs = Vec::with_capacity(per_rank.len());
    for (buf, run_rs, run_ag) in per_rank {
        bufs.push(buf);
        rs_runs.push(run_rs);
        ag_runs.push(run_ag);
    }
    let rs_stats = fold_runs(rs_runs, elem_bytes, cost, logp);
    let ag_stats = fold_runs(ag_runs, elem_bytes, cost, logp);
    Ok((rs_stats, ag_stats, bufs))
}

fn split_by_counts<T: Element>(flat: &[T], counts: &[usize]) -> Vec<Vec<T>> {
    let mut out = Vec::with_capacity(counts.len());
    let mut off = 0usize;
    for &c in counts {
        out.push(flat[off..off + c].to_vec());
        off += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::sim::cost::UnitCost;

    /// One RankComm per thread over both transports: the per-rank API
    /// standalone, without any god-view driver.
    fn run_bcast_world(kind: TransportKind, p: usize, root: usize, m: usize, n: usize) {
        let sk = Arc::new(Skips::new(p));
        let data: Vec<i64> = (0..m as i64).map(|i| i * 3 - 7).collect();
        let (stats, bufs) =
            spmd_bcast(&sk, root, &data, n, 8, &UnitCost, kind, None).expect("spmd bcast");
        assert_eq!(bufs.len(), p);
        for (r, b) in bufs.iter().enumerate() {
            assert_eq!(b, &data, "kind={kind:?} p={p} rank={r}");
        }
        if p > 1 {
            assert_eq!(stats.rounds, n - 1 + sk.q());
            assert_eq!(stats.messages, (p - 1) * n);
        } else {
            assert_eq!(stats.messages, 0);
        }
    }

    #[test]
    fn spmd_bcast_all_transports_small_grid() {
        for p in [1usize, 2, 3, 5, 9, 17] {
            for kind in
                [TransportKind::Threads, TransportKind::Loopback, TransportKind::Socket]
            {
                run_bcast_world(kind, p, 0, 48, 4);
                if p > 2 {
                    run_bcast_world(kind, p, p - 1, 33, 3);
                }
            }
        }
    }

    #[test]
    fn spmd_reduce_sums_to_root() {
        let p = 9usize;
        let m = 40usize;
        let sk = Arc::new(Skips::new(p));
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..m).map(|i| (r * 100 + i) as i64).collect()).collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        for kind in [TransportKind::Threads, TransportKind::Loopback, TransportKind::Socket] {
            for root in [0usize, 4, 8] {
                let (_, buf) = spmd_reduce(
                    &sk,
                    root,
                    &inputs,
                    3,
                    Arc::new(SumOp),
                    8,
                    &UnitCost,
                    kind,
                    None,
                )
                .unwrap();
                assert_eq!(buf, expect, "kind={kind:?} root={root}");
            }
        }
    }

    #[test]
    fn spmd_allreduce_all_ranks_agree() {
        let p = 7usize;
        let m = 29usize;
        let sk = Arc::new(Skips::new(p));
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r + 1) * (i + 1)) as i64 % 97).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        for kind in [TransportKind::Threads, TransportKind::Loopback, TransportKind::Socket] {
            let (_, _, bufs) =
                spmd_allreduce(&sk, &inputs, 2, Arc::new(SumOp), 8, &UnitCost, kind, None)
                    .unwrap();
            for (r, b) in bufs.iter().enumerate() {
                assert_eq!(b, &expect, "kind={kind:?} rank={r}");
            }
        }
    }

    #[test]
    fn rank_comm_state_is_own_rows_only() {
        // The rooted hot path computes this rank's own 2q-entry schedule
        // and nothing else: the phased schedule agrees with the direct
        // per-rank computation for every (rank, root).
        let p = 17usize;
        let sk = Arc::new(Skips::new(p));
        for rank in 0..p {
            let rc = RankComm::new(p, rank, sk.clone());
            for root in [0usize, 3, 16] {
                let ps = rc.own_phased(root, 5);
                let want = crate::collectives::common::phased_for(&sk, rank, root, 5);
                assert_eq!(ps.rel, want.rel);
                for j in 0..want.rounds() {
                    assert_eq!(ps.recv_at(j), want.recv_at(j), "rank={rank} root={root} j={j}");
                    assert_eq!(ps.send_at(j), want.send_at(j), "rank={rank} root={root} j={j}");
                }
            }
        }
    }
}
