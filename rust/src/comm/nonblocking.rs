//! Nonblocking request vocabulary of the traffic plane: rank
//! [`Window`]s, the owned submit-request types (`IbcastReq` & co — the
//! `i`-prefixed nonblocking mirrors of [`super::request`]'s borrowed
//! blocking requests), and the typed [`Pending`] handle a submission
//! returns.
//!
//! A nonblocking request *owns* its payload (the operation outlives the
//! submitting call), carries the same options as its blocking mirror
//! (block-count override, [`Algo`] selection, element width) plus one
//! new dimension: an optional rank **window** restricting the operation
//! to a contiguous sub-range of the machine's ranks. Operations over
//! disjoint windows share no ports, so the batch scheduler
//! ([`super::traffic::TrafficEngine`]) runs their rounds truly
//! concurrently; operations sharing ranks are round-interleaved under
//! the cross-operation port ledger.
//!
//! ```no_run
//! use circulant_bcast::comm::{Communicator, IbcastReq, IallreduceReq};
//! use circulant_bcast::collectives::SumOp;
//! use std::sync::Arc;
//!
//! let comm = Communicator::new(64);
//! let mut traffic = comm.traffic();
//! // Two broadcasts over disjoint halves: truly concurrent rounds.
//! let a = traffic.submit(IbcastReq::new(0, vec![1i64; 512]).window(0, 32)).unwrap();
//! let b = traffic.submit(IbcastReq::new(5, vec![2i64; 512]).window(32, 32)).unwrap();
//! // A full-machine all-reduce, round-interleaved with both.
//! let grads: Vec<Vec<i64>> = (0..64).map(|r| vec![r as i64; 256]).collect();
//! let c = traffic.submit(IallreduceReq::new(grads, Arc::new(SumOp))).unwrap();
//! let report = traffic.run().unwrap();
//! assert!(a.is_ready() && b.is_ready());       // fulfilled by run()
//! let _ = (a.wait().unwrap(), b.wait().unwrap());
//! let out = c.wait().unwrap();
//! assert!(out.all_received());
//! // report.agg: aggregate machine rounds / overlap-model completion time.
//! assert!(report.agg.rounds > 0);
//! ```

use std::sync::{Arc, Mutex};

use crate::collectives::common::ReduceOp;

use super::outcome::{CommError, Outcome};
use super::request::Algo;

/// A contiguous window of machine ranks an operation runs over: machine
/// ranks `base .. base + len`. Window-local rank `r` is machine rank
/// `base + r`; the operation's schedules, roots, statistics and result
/// buffers are all in the window-local frame (a window of size `len`
/// behaves exactly like a `len`-rank communicator — which is what the
/// differential traffic suite compares against).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Window {
    pub base: usize,
    pub len: usize,
}

impl Window {
    pub fn new(base: usize, len: usize) -> Self {
        Window { base, len }
    }

    /// The whole machine: ranks `0..p`.
    pub fn full(p: usize) -> Self {
        Window { base: 0, len: p }
    }

    /// One past the last machine rank.
    #[inline]
    pub fn end(&self) -> usize {
        self.base + self.len
    }

    /// True iff the two windows share no machine rank.
    #[inline]
    pub fn disjoint(&self, other: &Window) -> bool {
        self.end() <= other.base || other.end() <= self.base
    }
}

/// The slot a [`Pending`] and its driver share: filled exactly once,
/// when the batch runs the operation to completion (or to its error).
pub(crate) type Slot<B> = Arc<Mutex<Option<Result<Outcome<B>, CommError>>>>;

/// Typed handle to a submitted nonblocking collective. The buffer type
/// `B` matches the blocking mirror's `Outcome` (e.g. `Vec<Vec<T>>` for a
/// broadcast, `Vec<T>` for a rooted reduction).
///
/// The result is delivered by [`super::traffic::TrafficEngine::run`];
/// [`Pending::wait`] then returns it ([`Pending::is_ready`] /
/// [`Pending::try_take`] are the non-consuming / non-panicking probes).
#[derive(Debug)]
pub struct Pending<B> {
    slot: Slot<B>,
}

impl<B> Pending<B> {
    pub(crate) fn new_pair() -> (Self, Slot<B>) {
        let slot: Slot<B> = Arc::new(Mutex::new(None));
        (Pending { slot: slot.clone() }, slot)
    }

    /// True once the batch has executed this operation.
    pub fn is_ready(&self) -> bool {
        self.slot.lock().unwrap().is_some()
    }

    /// Take the result if the batch has run, `None` otherwise.
    pub fn try_take(&self) -> Option<Result<Outcome<B>, CommError>> {
        self.slot.lock().unwrap().take()
    }

    /// The operation's outcome.
    ///
    /// # Panics
    ///
    /// If the owning [`super::traffic::TrafficEngine`] has not been
    /// [`run`](super::traffic::TrafficEngine::run) yet (the traffic
    /// plane executes batches synchronously; there is nothing to block
    /// on), or if the result was already taken via [`Pending::try_take`].
    pub fn wait(self) -> Result<Outcome<B>, CommError> {
        self.slot.lock().unwrap().take().expect(
            "Pending::wait before TrafficEngine::run (or after try_take): \
             run the batch first",
        )
    }
}

/// Nonblocking broadcast (`MPI_Ibcast`): owned mirror of
/// [`super::request::BcastReq`] plus a rank [`Window`].
#[derive(Debug, Clone)]
pub struct IbcastReq<T> {
    pub root: usize,
    pub data: Vec<T>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
    /// `None` = the whole machine.
    pub win: Option<Window>,
}

impl<T> IbcastReq<T> {
    pub fn new(root: usize, data: Vec<T>) -> Self {
        let elem_bytes = std::mem::size_of::<T>();
        IbcastReq { root, data, blocks: None, algo: Algo::Auto, elem_bytes, win: None }
    }
}

/// Nonblocking rooted reduction (`MPI_Ireduce`): `inputs` has one
/// window-local contribution per window rank.
#[derive(Clone)]
pub struct IreduceReq<T> {
    pub root: usize,
    pub inputs: Vec<Vec<T>>,
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
    pub win: Option<Window>,
}

impl<T> IreduceReq<T> {
    pub fn new(root: usize, inputs: Vec<Vec<T>>, op: Arc<dyn ReduceOp<T>>) -> Self {
        let elem_bytes = std::mem::size_of::<T>();
        IreduceReq { root, inputs, op, blocks: None, algo: Algo::Auto, elem_bytes, win: None }
    }
}

/// Nonblocking all-broadcast (`MPI_Iallgatherv`).
#[derive(Debug, Clone)]
pub struct IallgathervReq<T> {
    pub inputs: Vec<Vec<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
    pub win: Option<Window>,
}

impl<T> IallgathervReq<T> {
    pub fn new(inputs: Vec<Vec<T>>) -> Self {
        let elem_bytes = std::mem::size_of::<T>();
        IallgathervReq { inputs, blocks: None, algo: Algo::Auto, elem_bytes, win: None }
    }
}

/// Nonblocking irregular all-reduction (`MPI_Ireduce_scatter`).
#[derive(Clone)]
pub struct IreduceScatterReq<T> {
    pub inputs: Vec<Vec<T>>,
    pub counts: Vec<usize>,
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
    pub win: Option<Window>,
}

impl<T> IreduceScatterReq<T> {
    pub fn new(inputs: Vec<Vec<T>>, counts: Vec<usize>, op: Arc<dyn ReduceOp<T>>) -> Self {
        let elem_bytes = std::mem::size_of::<T>();
        IreduceScatterReq {
            inputs,
            counts,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes,
            win: None,
        }
    }
}

/// Nonblocking all-reduce (`MPI_Iallreduce`).
#[derive(Clone)]
pub struct IallreduceReq<T> {
    pub inputs: Vec<Vec<T>>,
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
    pub win: Option<Window>,
}

impl<T> IallreduceReq<T> {
    pub fn new(inputs: Vec<Vec<T>>, op: Arc<dyn ReduceOp<T>>) -> Self {
        let elem_bytes = std::mem::size_of::<T>();
        IallreduceReq { inputs, op, blocks: None, algo: Algo::Auto, elem_bytes, win: None }
    }
}

/// The options every nonblocking request carries — the same builder set
/// as the blocking requests plus `window` (one definition for all five,
/// the `impl_request_options!` trick of [`super::request`]).
macro_rules! impl_submit_options {
    ($($ty:ident),* $(,)?) => {$(
        impl<T> $ty<T> {
            /// Override the block count (`None` = the paper's §3 rule,
            /// applied at the *window* size).
            pub fn blocks(mut self, n: usize) -> Self {
                self.blocks = Some(n);
                self
            }

            /// Select the algorithm family (default [`Algo::Auto`]).
            pub fn algo(mut self, algo: Algo) -> Self {
                self.algo = algo;
                self
            }

            /// Element width in bytes for cost accounting (default
            /// `size_of::<T>()`).
            pub fn elem_bytes(mut self, bytes: usize) -> Self {
                self.elem_bytes = bytes;
                self
            }

            /// Restrict the operation to machine ranks
            /// `base .. base + len`.
            pub fn window(mut self, base: usize, len: usize) -> Self {
                self.win = Some(Window::new(base, len));
                self
            }
        }
    )*};
}

impl_submit_options!(IbcastReq, IreduceReq, IallgathervReq, IreduceScatterReq, IallreduceReq);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_geometry() {
        let a = Window::new(0, 8);
        let b = Window::new(8, 8);
        let c = Window::new(4, 8);
        assert_eq!(a.end(), 8);
        assert!(a.disjoint(&b) && b.disjoint(&a));
        assert!(!a.disjoint(&c) && !c.disjoint(&b));
        assert_eq!(Window::full(16), Window::new(0, 16));
    }

    #[test]
    fn submit_builders_default_to_auto_full_machine() {
        let req = IbcastReq::new(3, vec![1i64; 8]);
        assert_eq!(req.algo, Algo::Auto);
        assert_eq!(req.blocks, None);
        assert_eq!(req.elem_bytes, 8);
        assert_eq!(req.win, None);
        let req = req.blocks(4).algo(Algo::Circulant).elem_bytes(4).window(2, 6);
        assert_eq!(req.blocks, Some(4));
        assert_eq!(req.algo, Algo::Circulant);
        assert_eq!(req.elem_bytes, 4);
        assert_eq!(req.win, Some(Window::new(2, 6)));
    }

    #[test]
    fn pending_probes() {
        let (pending, slot) = Pending::<Vec<i32>>::new_pair();
        assert!(!pending.is_ready());
        assert!(pending.try_take().is_none());
        *slot.lock().unwrap() = Some(Err(CommError::BadRequest("x".into())));
        assert!(pending.is_ready());
        assert!(matches!(pending.wait(), Err(CommError::BadRequest(_))));
    }

    #[test]
    #[should_panic(expected = "run the batch first")]
    fn wait_before_run_panics() {
        let (pending, _slot) = Pending::<Vec<i32>>::new_pair();
        let _ = pending.wait();
    }
}
