//! Pluggable execution backends for the [`super::Communicator`].
//!
//! A collective is a vector of per-rank state machines
//! ([`RankProc`]); how those machines are *driven* is the backend's
//! business:
//!
//! * [`LockstepBackend`] — the round-based [`Network`] simulator with
//!   full machine-model enforcement (one-portedness, expectation
//!   cross-checks). Violations surface as [`SimError`]s; this is the
//!   correctness instrument.
//! * [`ThreadedBackend`] — every rank a real OS thread over channels
//!   ([`crate::sim::threads`]), ranks free-running without barriers —
//!   validates that the schedules need no global synchrony. Cost
//!   accounting is identical (same per-round max/sum), but schedule bugs
//!   panic the rank thread instead of returning an error.
//! * [`EngineBackend`] — the sparse, zero-copy engine
//!   ([`crate::sim::engine`]) for full-network simulation at up to
//!   millions of ranks. The engine evaluates the shared all-ranks
//!   [`crate::schedule::ScheduleTable`] directly (parallel-built flat
//!   schedule plane, active-set worklist, arena payloads), so it
//!   accelerates the schedule-driven collectives: the
//!   [`super::Communicator`] dispatches circulant broadcast and reduce
//!   onto it, and every other (kind, algorithm) combination — generic
//!   [`RankProc`] state machines whose activity the engine cannot know —
//!   runs on the lockstep [`Network`], which is what this trait impl
//!   does.
//!
//! * [`SpmdBackend`] — the SPMD rank plane ([`super::rank`]): one OS
//!   thread per rank over [`super::transport::ThreadTransport`]
//!   mailboxes, each rank driven through the one-ported round loop —
//!   the first backend where ranks genuinely execute concurrently over
//!   a pluggable transport rather than being simulated. For the
//!   circulant collectives the [`super::Communicator`] bypasses this
//!   generic entry point entirely and fans the request out to `p`
//!   [`super::RankComm`]s (each computing only its own O(log p)
//!   schedule — no table); generic state machines land here and are
//!   driven over the same transport.
//!
//! * [`SocketBackend`] — the wire plane: the same fan-out as
//!   [`SpmdBackend`], but over [`super::socket::SocketTransport`]
//!   endpoints whose messages cross real OS sockets (in-process
//!   `UnixStream::pair` meshes here; multi-process worlds rendezvous
//!   via [`super::socket`]'s `uds_world`/`tcp_world`). Falls back to
//!   [`ThreadTransport`] when the element type is not wire-encodable —
//!   mirroring the engine backend's documented lockstep fallback for
//!   requests its fast path cannot serve.
//!
//! All sit behind one [`ExecBackend`] trait; [`BackendKind`] is the
//! value-level selector a [`super::Communicator`] stores.

use crate::collectives::common::Element;
use crate::sim::cost::{CostModel, LogPParams};
use crate::sim::network::{Network, RankProc, RunStats, SimError};
use crate::sim::threads::{fold_send_logs, run_threaded_stats_logp};

use super::outcome::CommError;
use super::rank::{close_after, collect_ranks, drive_proc, TransportKind};
use super::socket::SocketTransport;
use super::transport::{ThreadTransport, Transport, TransportError};

/// A way of driving `p` rank state machines to completion.
pub trait ExecBackend {
    fn name(&self) -> &'static str;

    /// Run the collective; returns the run statistics and the final state
    /// machines (for result assembly).
    fn execute<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        self.execute_logp::<T, P>(procs, elem_bytes, cost, None)
    }

    /// [`ExecBackend::execute`] with the cost plane attached: when
    /// `logp` is given, the run's message trace is additionally clocked
    /// by a [`crate::sim::LogPClock`] and the predicted completion time
    /// lands in `RunStats::logp_time`. Every backend folds the *same*
    /// round-tagged send logs, so the clocked time is backend-invariant.
    fn execute_logp<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static;
}

/// The round-based lockstep simulator ([`Network`]) — default backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct LockstepBackend;

impl ExecBackend for LockstepBackend {
    fn name(&self) -> &'static str {
        "lockstep"
    }

    fn execute_logp<T, P>(
        &self,
        mut procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        let stats = Network::new(procs.len()).run_logp(&mut procs, elem_bytes, cost, logp)?;
        Ok((stats, procs))
    }
}

/// The threaded runtime: one OS thread per rank, round-tagged channel
/// messages, no barriers.
#[derive(Debug, Clone, Copy, Default)]
pub struct ThreadedBackend;

impl ExecBackend for ThreadedBackend {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn execute_logp<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        Ok(run_threaded_stats_logp(procs, elem_bytes, cost, logp))
    }
}

/// The sparse engine as an [`ExecBackend`].
///
/// The engine's fast paths are *schedule-driven*, not proc-driven, so the
/// [`super::Communicator`] routes circulant broadcast/reduce requests to
/// [`crate::sim::engine::CirculantEngine`] directly when this backend is
/// selected; the generic `execute` entry point — reached for every other
/// algorithm and collective — falls back to the lockstep [`Network`]
/// driver with full machine-model enforcement.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineBackend;

impl ExecBackend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn execute_logp<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        LockstepBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp)
    }
}

/// The SPMD rank plane as an [`ExecBackend`].
///
/// The typed circulant collectives never reach this generic entry point
/// under [`BackendKind::Spmd`] — the [`super::Communicator`] fans them
/// out to per-rank [`super::RankComm`]s directly (each rank computing
/// only its own O(log p) schedule). What lands here are generic
/// [`RankProc`] state machines (baseline algorithms, custom procs):
/// each runs on its own OS thread over a
/// [`super::transport::ThreadTransport`] endpoint, driven by the shared
/// one-ported round loop, with the lockstep statistics fold.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpmdBackend;

impl ExecBackend for SpmdBackend {
    fn name(&self) -> &'static str {
        "spmd"
    }

    fn execute_logp<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        run_transport_stats(procs, elem_bytes, cost, logp)
    }
}

/// The wire plane as an [`ExecBackend`].
///
/// Identical contract to [`SpmdBackend`] — the typed circulant
/// collectives fan out to per-rank [`super::RankComm`]s under
/// [`BackendKind::Socket`] and never reach this generic entry point —
/// but the ranks' messages cross real OS sockets
/// ([`super::socket::SocketTransport`] over in-process
/// `UnixStream::pair` meshes), length-prefixed frames, reader threads
/// and all. The one-ported round discipline holds across the wire; on
/// healthy schedules results and statistics are bit-identical to
/// lockstep.
#[derive(Debug, Clone, Copy, Default)]
pub struct SocketBackend;

impl ExecBackend for SocketBackend {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn execute_logp<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        run_socket_stats(procs, elem_bytes, cost, logp)
    }
}

/// Drive generic rank state machines over [`ThreadTransport`] — one OS
/// thread per rank, free-running, with the identical statistics fold as
/// the lockstep/threaded backends. World teardown (`close_after`) and
/// error triage (`collect_ranks`) are the rank plane's own machinery,
/// so the Spmd backend and the `RankComm` fan-outs surface identical
/// root causes; the selected error maps back onto [`SimError`] (they
/// share its vocabulary).
pub(crate) fn run_transport_stats<T, P>(
    procs: Vec<P>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<P>), SimError>
where
    T: Element,
    P: RankProc<T> + Send,
{
    let world = ThreadTransport::<T>::world(procs.len());
    drive_world(procs, world, elem_bytes, cost, logp)
}

/// [`run_transport_stats`] over the wire plane: generic rank state
/// machines on [`SocketTransport`] endpoints (in-process
/// `UnixStream::pair` meshes). A world that cannot be built — a
/// non-wire-encodable element type, descriptor exhaustion — falls back
/// to [`ThreadTransport`], keeping the backend total over every
/// [`Element`] exactly like the engine backend's lockstep fallback.
pub(crate) fn run_socket_stats<T, P>(
    procs: Vec<P>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<P>), SimError>
where
    T: Element,
    P: RankProc<T> + Send,
{
    match SocketTransport::<T>::pair_world(procs.len()) {
        Ok(world) => drive_world(procs, world, elem_bytes, cost, logp),
        Err(_) => run_transport_stats(procs, elem_bytes, cost, logp),
    }
}

/// The shared fan-out body: drive each proc over its endpoint on its
/// own scoped thread, then triage and fold.
fn drive_world<T, P, Tr>(
    procs: Vec<P>,
    world: Vec<Tr>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> Result<(RunStats, Vec<P>), SimError>
where
    T: Element,
    P: RankProc<T> + Send,
    Tr: Transport<T> + Send,
{
    let total_rounds = procs.iter().map(|pr| pr.rounds()).max().unwrap_or(0);
    let results: Vec<Result<(P, Vec<(usize, usize, usize)>), CommError>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = procs
                .into_iter()
                .zip(world)
                .map(|(mut pr, mut tr)| {
                    s.spawn(move || {
                        // A panicking proc (schedule-violation diagnostics
                        // panic, as on the threaded backend) must still
                        // bring the world down so siblings fail fast.
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || drive_proc(&mut pr, &mut tr, 0).map_err(CommError::Transport),
                        ));
                        match res {
                            Ok(inner) => {
                                close_after::<T, _, _>(&mut tr, inner).map(|run| (pr, run.sends))
                            }
                            Err(payload) => {
                                let _ = tr.close(Some("rank thread panicked"));
                                std::panic::resume_unwind(payload);
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("spmd rank thread panicked"))
                .collect()
        });

    let (done, logs): (Vec<_>, Vec<_>) = collect_ranks(results)
        .map_err(transport_root_to_sim)?
        .into_iter()
        .unzip();
    Ok((fold_send_logs(&logs, total_rounds, elem_bytes, cost, logp), done))
}

/// Map the triaged root cause of a generic SPMD run back onto the
/// lockstep error vocabulary ([`ExecBackend`]'s error type).
fn transport_root_to_sim(e: CommError) -> SimError {
    match e {
        CommError::Transport(TransportError::Machine(s)) => s,
        // The starved victim's own deadline: exactly a missing message.
        CommError::Transport(TransportError::Timeout { rank, round, from }) => {
            SimError::MissingMessage { round, rank, expected_from: from }
        }
        // Echoes / driver bugs that the triage only surfaces when no
        // better root cause exists anywhere in the world.
        CommError::Transport(
            TransportError::Shutdown { rank, round, .. }
            | TransportError::OutOfRound { rank, round, .. },
        ) => SimError::MissingMessage { round, rank, expected_from: rank },
        other => unreachable!("generic SPMD drive can only fail with transport errors: {other}"),
    }
}

/// Value-level backend selector stored by a [`super::Communicator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Lockstep,
    Threaded,
    /// The sparse million-rank engine (circulant bcast/reduce run on
    /// [`crate::sim::engine::CirculantEngine`]; everything else lockstep).
    Engine,
    /// The SPMD rank plane: circulant collectives fan out to per-rank
    /// [`super::RankComm`]s over
    /// [`super::transport::ThreadTransport`] (one real thread per rank,
    /// per-rank O(log p) schedules, no shared table); generic procs run
    /// on [`SpmdBackend`] over the same transport.
    Spmd,
    /// The wire plane: the SPMD fan-out over
    /// [`super::socket::SocketTransport`] — real OS sockets,
    /// length-prefixed frames, per-peer reader threads; generic procs
    /// run on [`SocketBackend`] over the same transport.
    Socket,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Lockstep => LockstepBackend.name(),
            BackendKind::Threaded => ThreadedBackend.name(),
            BackendKind::Engine => EngineBackend.name(),
            BackendKind::Spmd => SpmdBackend.name(),
            BackendKind::Socket => SocketBackend.name(),
        }
    }

    /// CLI/bench-edge parser (library code uses the enum directly).
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "lockstep" | "network" => BackendKind::Lockstep,
            "threaded" | "threads" => BackendKind::Threaded,
            "engine" | "sparse" => BackendKind::Engine,
            "spmd" | "rank" => BackendKind::Spmd,
            "socket" | "wire" => BackendKind::Socket,
            _ => return None,
        })
    }

    /// True for the backends that execute collectives on the SPMD rank
    /// plane (per-rank `RankComm`s over a [`Transport`]) rather than a
    /// god-view simulator.
    pub fn is_rank_plane(self) -> bool {
        matches!(self, BackendKind::Spmd | BackendKind::Socket)
    }

    /// True for the backends whose transports carry a failure detector
    /// ([`crate::comm::Transport::failed_peers`]) and can therefore
    /// drive the recovery plane ([`crate::comm::membership`]): a rank
    /// death is *detected* (suspicion board on the threaded world,
    /// crashed-link accounting on the wire), the survivors shrink to a
    /// dense (p − 1)-rank world, and affected ops restart there. The
    /// god-view simulators have no independent rank processes to lose,
    /// and the loopback replay has no detector — on those a failure
    /// stays terminal.
    pub fn supports_recovery(self) -> bool {
        matches!(self, BackendKind::Spmd | BackendKind::Socket)
    }

    /// Which transport this backend's rank-plane fan-outs drive
    /// (meaningful when [`BackendKind::is_rank_plane`]).
    pub(crate) fn rank_plane_transport(self) -> TransportKind {
        match self {
            BackendKind::Socket => TransportKind::Socket,
            _ => TransportKind::Threads,
        }
    }

    /// Backend selected by the `CBCAST_BACKEND` environment variable
    /// (`lockstep` | `threaded` | `engine` | `spmd` | `socket`),
    /// defaulting to lockstep — how the benches accept any backend
    /// without changing code.
    pub fn from_env() -> BackendKind {
        std::env::var("CBCAST_BACKEND")
            .ok()
            .and_then(|s| BackendKind::parse(s.trim()))
            .unwrap_or_default()
    }

    pub(crate) fn execute<T, P>(
        self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        self.execute_logp::<T, P>(procs, elem_bytes, cost, None)
    }

    pub(crate) fn execute_logp<T, P>(
        self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        match self {
            BackendKind::Lockstep => {
                LockstepBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp)
            }
            BackendKind::Threaded => {
                ThreadedBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp)
            }
            BackendKind::Engine => {
                EngineBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp)
            }
            BackendKind::Spmd => SpmdBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp),
            BackendKind::Socket => {
                SocketBackend.execute_logp::<T, P>(procs, elem_bytes, cost, logp)
            }
        }
    }
}

/// The one shared per-rank construction loop — previously copy-pasted
/// between every `*_sim` / `*_procs` pair in the collectives.
pub fn build_procs<P>(p: usize, make: impl FnMut(usize) -> P) -> Vec<P> {
    (0..p).map(make).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;
    use crate::sim::network::Msg;

    /// Trivial ring shift used to compare backends.
    struct Shift {
        rank: usize,
        p: usize,
        val: Vec<u32>,
    }

    impl RankProc<u32> for Shift {
        fn send(&mut self, _round: usize) -> Option<Msg<u32>> {
            Some(Msg { to: (self.rank + 1) % self.p, data: self.val.clone() })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            Some((self.rank + self.p - 1) % self.p)
        }
        fn recv(&mut self, _round: usize, _from: usize, data: Vec<u32>) {
            self.val = data;
        }
        fn rounds(&self) -> usize {
            self.p - 1
        }
    }

    fn shifts(p: usize) -> Vec<Shift> {
        build_procs(p, |r| Shift { rank: r, p, val: vec![r as u32] })
    }

    #[test]
    fn backends_agree_on_stats_and_results() {
        let p = 6usize;
        let (ls, lprocs) =
            LockstepBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        let (ts, tprocs) =
            ThreadedBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        assert_eq!(ls.rounds, ts.rounds);
        assert_eq!(ls.messages, ts.messages);
        assert_eq!(ls.bytes, ts.bytes);
        assert_eq!(ls.active_rounds, ts.active_rounds);
        assert_eq!(ls.max_rank_bytes, ts.max_rank_bytes);
        assert!((ls.time - ts.time).abs() < 1e-12);
        for (a, b) in lprocs.iter().zip(&tprocs) {
            assert_eq!(a.val, b.val);
        }
    }

    #[test]
    fn backend_kind_dispatch() {
        assert_eq!(BackendKind::Lockstep.name(), "lockstep");
        assert_eq!(BackendKind::Threaded.name(), "threaded");
        assert_eq!(BackendKind::Engine.name(), "engine");
        assert_eq!(BackendKind::Spmd.name(), "spmd");
        assert_eq!(BackendKind::default(), BackendKind::Lockstep);
        let (stats, _) =
            BackendKind::Threaded.execute::<u32, Shift>(shifts(4), 4, &UnitCost).unwrap();
        assert_eq!(stats.messages, 4 * 3);
        // Generic procs under the engine backend run the lockstep driver.
        let (stats, _) =
            BackendKind::Engine.execute::<u32, Shift>(shifts(4), 4, &UnitCost).unwrap();
        assert_eq!(stats.messages, 4 * 3);
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("lockstep"), Some(BackendKind::Lockstep));
        assert_eq!(BackendKind::parse("threaded"), Some(BackendKind::Threaded));
        assert_eq!(BackendKind::parse("engine"), Some(BackendKind::Engine));
        assert_eq!(BackendKind::parse("sparse"), Some(BackendKind::Engine));
        assert_eq!(BackendKind::parse("spmd"), Some(BackendKind::Spmd));
        assert_eq!(BackendKind::parse("rank"), Some(BackendKind::Spmd));
        assert_eq!(BackendKind::parse("socket"), Some(BackendKind::Socket));
        assert_eq!(BackendKind::parse("wire"), Some(BackendKind::Socket));
        assert!(BackendKind::parse("nope").is_none());
        assert!(BackendKind::Socket.is_rank_plane());
        assert!(BackendKind::Spmd.is_rank_plane());
        assert!(!BackendKind::Lockstep.is_rank_plane());
        assert_eq!(BackendKind::Socket.rank_plane_transport(), TransportKind::Socket);
        assert_eq!(BackendKind::Spmd.rank_plane_transport(), TransportKind::Threads);
    }

    #[test]
    fn spmd_backend_drives_generic_procs_like_lockstep() {
        let p = 6usize;
        let (ls, lprocs) =
            LockstepBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        let (ss, sprocs) = SpmdBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        assert_eq!(ls.rounds, ss.rounds);
        assert_eq!(ls.messages, ss.messages);
        assert_eq!(ls.bytes, ss.bytes);
        assert_eq!(ls.active_rounds, ss.active_rounds);
        assert_eq!(ls.max_rank_bytes, ss.max_rank_bytes);
        assert!((ls.time - ss.time).abs() < 1e-12);
        for (a, b) in lprocs.iter().zip(&sprocs) {
            assert_eq!(a.val, b.val);
        }
    }

    #[test]
    fn execute_logp_attaches_backend_invariant_time() {
        let p = 5usize;
        let params = LogPParams::default();
        let (ls, _) = LockstepBackend
            .execute_logp::<u32, Shift>(shifts(p), 4, &UnitCost, Some(&params))
            .unwrap();
        let t = ls.logp_time.expect("clock attached under Some(params)");
        assert!(t > 0.0);
        let (ts, _) = ThreadedBackend
            .execute_logp::<u32, Shift>(shifts(p), 4, &UnitCost, Some(&params))
            .unwrap();
        let (ss, _) = SpmdBackend
            .execute_logp::<u32, Shift>(shifts(p), 4, &UnitCost, Some(&params))
            .unwrap();
        let (ws, _) = SocketBackend
            .execute_logp::<u32, Shift>(shifts(p), 4, &UnitCost, Some(&params))
            .unwrap();
        assert_eq!(ts.logp_time, Some(t), "threaded");
        assert_eq!(ss.logp_time, Some(t), "spmd");
        assert_eq!(ws.logp_time, Some(t), "socket");
        // Without parameters the cost plane stays detached.
        let (plain, _) = LockstepBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        assert_eq!(plain.logp_time, None);
    }

    #[test]
    fn socket_backend_drives_generic_procs_like_lockstep() {
        let p = 6usize;
        let (ls, lprocs) =
            LockstepBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        let (ws, wprocs) =
            SocketBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        assert_eq!(ls.rounds, ws.rounds);
        assert_eq!(ls.messages, ws.messages);
        assert_eq!(ls.bytes, ws.bytes);
        assert_eq!(ls.active_rounds, ws.active_rounds);
        assert_eq!(ls.max_rank_bytes, ws.max_rank_bytes);
        assert!((ls.time - ws.time).abs() < 1e-12);
        for (a, b) in lprocs.iter().zip(&wprocs) {
            assert_eq!(a.val, b.val);
        }
    }
}
