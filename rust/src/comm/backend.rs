//! Pluggable execution backends for the [`super::Communicator`].
//!
//! A collective is a vector of per-rank state machines
//! ([`RankProc`]); how those machines are *driven* is the backend's
//! business:
//!
//! * [`LockstepBackend`] — the round-based [`Network`] simulator with
//!   full machine-model enforcement (one-portedness, expectation
//!   cross-checks). Violations surface as [`SimError`]s; this is the
//!   correctness instrument.
//! * [`ThreadedBackend`] — every rank a real OS thread over channels
//!   ([`crate::sim::threads`]), ranks free-running without barriers —
//!   validates that the schedules need no global synchrony. Cost
//!   accounting is identical (same per-round max/sum), but schedule bugs
//!   panic the rank thread instead of returning an error.
//! * [`EngineBackend`] — the sparse, zero-copy engine
//!   ([`crate::sim::engine`]) for full-network simulation at up to
//!   millions of ranks. The engine evaluates the shared all-ranks
//!   [`crate::schedule::ScheduleTable`] directly (parallel-built flat
//!   schedule plane, active-set worklist, arena payloads), so it
//!   accelerates the schedule-driven collectives: the
//!   [`super::Communicator`] dispatches circulant broadcast and reduce
//!   onto it, and every other (kind, algorithm) combination — generic
//!   [`RankProc`] state machines whose activity the engine cannot know —
//!   runs on the lockstep [`Network`], which is what this trait impl
//!   does.
//!
//! All sit behind one [`ExecBackend`] trait; [`BackendKind`] is the
//! value-level selector a [`super::Communicator`] stores.

use crate::collectives::common::Element;
use crate::sim::cost::CostModel;
use crate::sim::network::{Network, RankProc, RunStats, SimError};
use crate::sim::threads::run_threaded_stats;

/// A way of driving `p` rank state machines to completion.
pub trait ExecBackend {
    fn name(&self) -> &'static str;

    /// Run the collective; returns the run statistics and the final state
    /// machines (for result assembly).
    fn execute<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static;
}

/// The round-based lockstep simulator ([`Network`]) — default backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct LockstepBackend;

impl ExecBackend for LockstepBackend {
    fn name(&self) -> &'static str {
        "lockstep"
    }

    fn execute<T, P>(
        &self,
        mut procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        let stats = Network::new(procs.len()).run(&mut procs, elem_bytes, cost)?;
        Ok((stats, procs))
    }
}

/// The threaded runtime: one OS thread per rank, round-tagged channel
/// messages, no barriers.
#[derive(Debug, Clone, Copy, Default)]
pub struct ThreadedBackend;

impl ExecBackend for ThreadedBackend {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn execute<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        Ok(run_threaded_stats(procs, elem_bytes, cost))
    }
}

/// The sparse engine as an [`ExecBackend`].
///
/// The engine's fast paths are *schedule-driven*, not proc-driven, so the
/// [`super::Communicator`] routes circulant broadcast/reduce requests to
/// [`crate::sim::engine::CirculantEngine`] directly when this backend is
/// selected; the generic `execute` entry point — reached for every other
/// algorithm and collective — falls back to the lockstep [`Network`]
/// driver with full machine-model enforcement.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineBackend;

impl ExecBackend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn execute<T, P>(
        &self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        LockstepBackend.execute::<T, P>(procs, elem_bytes, cost)
    }
}

/// Value-level backend selector stored by a [`super::Communicator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Lockstep,
    Threaded,
    /// The sparse million-rank engine (circulant bcast/reduce run on
    /// [`crate::sim::engine::CirculantEngine`]; everything else lockstep).
    Engine,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Lockstep => LockstepBackend.name(),
            BackendKind::Threaded => ThreadedBackend.name(),
            BackendKind::Engine => EngineBackend.name(),
        }
    }

    /// CLI/bench-edge parser (library code uses the enum directly).
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "lockstep" | "network" => BackendKind::Lockstep,
            "threaded" | "threads" => BackendKind::Threaded,
            "engine" | "sparse" => BackendKind::Engine,
            _ => return None,
        })
    }

    /// Backend selected by the `CBCAST_BACKEND` environment variable
    /// (`lockstep` | `threaded` | `engine`), defaulting to lockstep —
    /// how the benches accept either backend without changing code.
    pub fn from_env() -> BackendKind {
        std::env::var("CBCAST_BACKEND")
            .ok()
            .and_then(|s| BackendKind::parse(s.trim()))
            .unwrap_or_default()
    }

    pub(crate) fn execute<T, P>(
        self,
        procs: Vec<P>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<P>), SimError>
    where
        T: Element,
        P: RankProc<T> + Send + 'static,
    {
        match self {
            BackendKind::Lockstep => LockstepBackend.execute::<T, P>(procs, elem_bytes, cost),
            BackendKind::Threaded => ThreadedBackend.execute::<T, P>(procs, elem_bytes, cost),
            BackendKind::Engine => EngineBackend.execute::<T, P>(procs, elem_bytes, cost),
        }
    }
}

/// The one shared per-rank construction loop — previously copy-pasted
/// between every `*_sim` / `*_procs` pair in the collectives.
pub fn build_procs<P>(p: usize, make: impl FnMut(usize) -> P) -> Vec<P> {
    (0..p).map(make).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;
    use crate::sim::network::Msg;

    /// Trivial ring shift used to compare backends.
    struct Shift {
        rank: usize,
        p: usize,
        val: Vec<u32>,
    }

    impl RankProc<u32> for Shift {
        fn send(&mut self, _round: usize) -> Option<Msg<u32>> {
            Some(Msg { to: (self.rank + 1) % self.p, data: self.val.clone() })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            Some((self.rank + self.p - 1) % self.p)
        }
        fn recv(&mut self, _round: usize, _from: usize, data: Vec<u32>) {
            self.val = data;
        }
        fn rounds(&self) -> usize {
            self.p - 1
        }
    }

    fn shifts(p: usize) -> Vec<Shift> {
        build_procs(p, |r| Shift { rank: r, p, val: vec![r as u32] })
    }

    #[test]
    fn backends_agree_on_stats_and_results() {
        let p = 6usize;
        let (ls, lprocs) =
            LockstepBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        let (ts, tprocs) =
            ThreadedBackend.execute::<u32, Shift>(shifts(p), 4, &UnitCost).unwrap();
        assert_eq!(ls.rounds, ts.rounds);
        assert_eq!(ls.messages, ts.messages);
        assert_eq!(ls.bytes, ts.bytes);
        assert_eq!(ls.active_rounds, ts.active_rounds);
        assert_eq!(ls.max_rank_bytes, ts.max_rank_bytes);
        assert!((ls.time - ts.time).abs() < 1e-12);
        for (a, b) in lprocs.iter().zip(&tprocs) {
            assert_eq!(a.val, b.val);
        }
    }

    #[test]
    fn backend_kind_dispatch() {
        assert_eq!(BackendKind::Lockstep.name(), "lockstep");
        assert_eq!(BackendKind::Threaded.name(), "threaded");
        assert_eq!(BackendKind::Engine.name(), "engine");
        assert_eq!(BackendKind::default(), BackendKind::Lockstep);
        let (stats, _) =
            BackendKind::Threaded.execute::<u32, Shift>(shifts(4), 4, &UnitCost).unwrap();
        assert_eq!(stats.messages, 4 * 3);
        // Generic procs under the engine backend run the lockstep driver.
        let (stats, _) =
            BackendKind::Engine.execute::<u32, Shift>(shifts(4), 4, &UnitCost).unwrap();
        assert_eq!(stats.messages, 4 * 3);
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("lockstep"), Some(BackendKind::Lockstep));
        assert_eq!(BackendKind::parse("threaded"), Some(BackendKind::Threaded));
        assert_eq!(BackendKind::parse("engine"), Some(BackendKind::Engine));
        assert_eq!(BackendKind::parse("sparse"), Some(BackendKind::Engine));
        assert!(BackendKind::parse("nope").is_none());
    }
}
