//! The pluggable message fabric under the SPMD rank plane
//! ([`super::rank::RankComm`]): a [`Transport`] moves one rank's
//! round-tagged messages, and nothing else — no schedules, no payload
//! interpretation, no global view.
//!
//! # The one-ported round discipline
//!
//! The paper's machine model is round-based and one-ported: per round a
//! rank sends at most one message and receives at most one message. A
//! [`Transport`] endpoint must be driven in exactly that discipline, per
//! round `j` in non-decreasing order:
//!
//! 1. at most one [`Transport::send`]`(j, peer, data)`,
//! 2. one [`Transport::flush`]`(j)` (seals the rank's sends for `j`),
//! 3. at most one [`Transport::recv`]`(j, peer)`,
//!
//! and one final [`Transport::close`] when the rank is done (or dead).
//! Both shipped transports *enforce* the caller side of this contract —
//! a second send or receive in one round, or a send into an already
//! sealed round, is rejected as [`TransportError::OutOfRound`] — and
//! surface machine-model violations (port collisions, self-messages,
//! wrong-peer deliveries, missing messages) in the lockstep simulator's
//! own vocabulary, [`crate::sim::SimError`], wrapped as
//! [`TransportError::Machine`].
//!
//! # The shipped transports
//!
//! * [`ThreadTransport`] — the real in-process runtime: one endpoint per
//!   rank, each typically owned by its own OS thread, with
//!   mutex/condvar mailboxes (zero dependencies). Ranks free-run — rank
//!   A may be several rounds ahead of rank B, exactly as MPI processes
//!   would be — and out-of-order arrivals match on their round tag.
//!   Like the [`crate::sim::threads`] runtime, detection of broken
//!   schedules is best-effort (port collisions and wrong-peer
//!   deliveries are caught; a message nobody ever sends surfaces as a
//!   [`TransportError::Timeout`]); a detected violation poisons the
//!   whole world so every blocked endpoint wakes with
//!   [`TransportError::Shutdown`] instead of deadlocking.
//! * [`LoopbackTransport`] — the lockstep replay: a barrier per round
//!   (receives wait until *every* active rank has sealed the round),
//!   after which delivery runs the same checks, in the same vocabulary,
//!   as the lockstep [`crate::sim::Network`] round body — port busy and
//!   self/bad-target at send, wrong-peer and missing-message at
//!   delivery, undeliverable leftovers once a round can no longer be
//!   received. This is the differential mirror: the SPMD parity suite
//!   pins `ThreadTransport` ≡ `LoopbackTransport` ≡ god-view backends.
//! * [`super::socket::SocketTransport`] — the wire plane: the same
//!   mailbox/round discipline as `ThreadTransport`, but messages cross
//!   real OS sockets (Unix-domain or TCP) as length-prefixed frames,
//!   so endpoints can live in different processes (see
//!   [`super::socket`]).
//!
//! One world serves one collective operation: round tags are only
//! meaningful within a single operation (multi-phase collectives like
//! all-reduce keep tags monotone across their phases), and [`close`]
//! consumes the endpoint's participation.
//!
//! [`close`]: Transport::close

use std::collections::{BTreeSet, HashMap};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::sim::network::SimError;

/// Default blocking-receive deadline — generous; a blown deadline means
/// a peer died or the schedule references a message nobody sends
/// (mirrors the threaded runtime's timeout).
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Parse one `CBCAST_TRANSPORT_TIMEOUT_MS` value: a whole number of
/// milliseconds with a **≥ 1 ms floor** (a zero deadline would make
/// every blocking receive fail instantly, which is never what a knob
/// typo means). Split out of [`configured_timeout`] so the rejection
/// rules are explicit and unit-testable rather than buried in an
/// `and_then` chain that silently swallows garbage.
fn parse_timeout_ms(raw: &str) -> Result<Duration, String> {
    match raw.trim().parse::<u64>() {
        Ok(0) => Err("0 is below the 1 ms floor (the deadline must be positive)".to_string()),
        Ok(ms) => Ok(Duration::from_millis(ms)),
        Err(e) => Err(format!("not a whole number of milliseconds: {e}")),
    }
}

/// The receive deadline shared by the in-process and wire transports:
/// `CBCAST_TRANSPORT_TIMEOUT_MS` (whole milliseconds, **≥ 1** — see
/// [`parse_timeout_ms`]'s floor) when set and valid, [`DEFAULT_TIMEOUT`]
/// otherwise — one timeout story for [`ThreadTransport::world`] and
/// [`super::socket::SocketTransport::pair_world`]. An **invalid** value
/// (unparsable, or `0`) no longer disappears silently: it is reported
/// once on stderr and the default is used, so a typo'd knob can't make
/// a test run "pass" under the wrong deadline unnoticed. Tests that
/// need a deterministic deadline pass one explicitly via the
/// `*_with_timeout` constructors instead of relying on the environment.
pub fn configured_timeout() -> Duration {
    match std::env::var("CBCAST_TRANSPORT_TIMEOUT_MS") {
        Err(_) => DEFAULT_TIMEOUT,
        Ok(raw) => match parse_timeout_ms(&raw) {
            Ok(d) => d,
            Err(why) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "cbcast: ignoring CBCAST_TRANSPORT_TIMEOUT_MS={raw:?} ({why}); \
                         using the {} s default",
                        DEFAULT_TIMEOUT.as_secs()
                    );
                });
                DEFAULT_TIMEOUT
            }
        },
    }
}

/// What a [`Transport`] can report. Machine-model violations reuse the
/// lockstep simulator's [`SimError`] vocabulary so the SPMD plane and
/// the god-view backends describe broken schedules identically.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportError {
    /// The machine model was violated (same meaning as on the lockstep
    /// [`crate::sim::Network`]): port collision, self-message, bad
    /// target, wrong-peer delivery, missing message.
    Machine(SimError),
    /// The *caller* broke the one-ported round discipline: a second
    /// send/receive in one round, a send or receive for a round that
    /// was already passed, or a receive before the round was flushed.
    OutOfRound { rank: usize, round: usize, what: &'static str },
    /// The world was shut down (another rank failed or closed with an
    /// error) while this endpoint was waiting.
    Shutdown { rank: usize, round: usize, reason: String },
    /// A blocking receive hit its deadline — the peer died without
    /// closing, or the schedule references a message nobody sends.
    Timeout { rank: usize, round: usize, from: usize },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Machine(e) => write!(f, "machine-model violation: {e}"),
            TransportError::OutOfRound { rank, round, what } => {
                write!(f, "rank {rank}: round-discipline violation in round {round}: {what}")
            }
            TransportError::Shutdown { rank, round, reason } => {
                write!(f, "rank {rank}: transport shut down in round {round}: {reason}")
            }
            TransportError::Timeout { rank, round, from } => write!(
                f,
                "rank {rank}: timed out waiting for (round {round}, from {from})"
            ),
        }
    }
}

impl std::error::Error for TransportError {}

/// Per-endpoint bookkeeping of the one-ported round discipline (shared
/// by both shipped transports). Tracks the highest round each verb was
/// issued for; re-issuing a verb at or below its high-water mark is the
/// caller's bug and is rejected before any shared state is touched.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Discipline {
    sent: Option<usize>,
    flushed: Option<usize>,
    recvd: Option<usize>,
}

impl Discipline {
    pub(crate) fn check_send(&mut self, rank: usize, round: usize) -> Result<(), TransportError> {
        if self.sent.is_some_and(|r| round <= r) {
            return Err(TransportError::OutOfRound {
                rank,
                round,
                what: "second send in or before an already-sent round",
            });
        }
        if self.flushed.is_some_and(|r| round <= r) {
            return Err(TransportError::OutOfRound {
                rank,
                round,
                what: "send into an already-flushed round",
            });
        }
        self.sent = Some(round);
        Ok(())
    }

    pub(crate) fn check_flush(&mut self, rank: usize, round: usize) -> Result<(), TransportError> {
        if self.flushed.is_some_and(|r| round < r) {
            return Err(TransportError::OutOfRound {
                rank,
                round,
                what: "flush for an earlier round",
            });
        }
        self.flushed = Some(round);
        Ok(())
    }

    pub(crate) fn check_recv(&mut self, rank: usize, round: usize) -> Result<(), TransportError> {
        if self.recvd.is_some_and(|r| round <= r) {
            return Err(TransportError::OutOfRound {
                rank,
                round,
                what: "second receive in or before an already-received round",
            });
        }
        self.recvd = Some(round);
        Ok(())
    }
}

/// One rank's view of the message fabric — see the module docs for the
/// round discipline every implementation enforces and every caller must
/// follow. [`super::rank::RankComm`] drives exactly this discipline;
/// custom transports (RDMA shims, recorded replays, fault injectors)
/// plug in here.
pub trait Transport<T>: Send {
    /// Ranks in the world this endpoint belongs to.
    fn p(&self) -> usize;

    /// This endpoint's rank.
    fn rank(&self) -> usize;

    /// Send `data` to `peer`, tagged with `round`. Must not block on the
    /// peer (one-ported schedules never need it to).
    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError>;

    /// Seal this rank's sends for `round` (called once per round, after
    /// the round's send if any, before its receive if any). The
    /// lockstep transport's round barrier counts these; the threaded
    /// transport ignores them.
    fn flush(&mut self, round: usize) -> Result<(), TransportError>;

    /// Blocking receive of the round-`round` message from `peer`.
    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError>;

    /// Peers this endpoint believes have **crashed** — died without the
    /// deliberate goodbye of a clean [`Transport::close`]. This is the
    /// recovery plane's detector output ([`super::membership`]): after a
    /// failed collective, survivors harvest each endpoint's suspects,
    /// shrink the [`super::membership::Membership`] by their union, and
    /// rebuild. [`ThreadTransport`] reports ranks the world timed out
    /// waiting on (shared-memory board, identical at every survivor);
    /// [`super::socket::SocketTransport`] reports peers whose link hit
    /// EOF/error *without* a BYE or ABORT frame — and since the wire
    /// mesh is full, every survivor observes a dead peer's EOF on its
    /// own link, so the sets agree without any coordinator. The default
    /// (no detector) suspects nobody.
    fn failed_peers(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Cumulative reliable-delivery counters for this endpoint, when a
    /// healing wire layer runs underneath
    /// ([`super::socket::SocketTransport`]'s protocol-v3
    /// CRC/seq/ack/retransmission machinery). `None` means the
    /// transport has no lossy wire to heal — the in-process transports
    /// deliver by construction. These counters never appear in
    /// run statistics: a healed run stays bit-identical to a
    /// fault-free one.
    fn wire_faults(&self) -> Option<super::outcome::WireFaults> {
        None
    }

    /// Retire this endpoint: `error` is `Some` when the rank aborted
    /// (shuts the world down so no sibling deadlocks), `None` on clean
    /// completion (may itself report a violation discovered at the end,
    /// e.g. a message this rank never received).
    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError>;
}

// ---------------------------------------------------------------------
// ThreadTransport: free-running mutex/condvar mailboxes
// ---------------------------------------------------------------------

struct BoxState<T> {
    /// round -> (from, payload); one-portedness means at most one live
    /// entry per round on a valid schedule.
    msgs: HashMap<usize, (usize, Vec<T>)>,
    poisoned: Option<String>,
    /// Who this rank is currently blocked waiting on (set for the
    /// duration of a blocking [`Transport::recv`]) — the wait-chain
    /// pointer the failure detector walks. A rank that timed out leaves
    /// a *blame marker* here (the rank it ultimately accused), so
    /// concurrent walkers passing through it still land on the real
    /// suspect instead of accusing this merely-starved rank.
    waiting_on: Option<usize>,
}

struct RankBox<T> {
    state: Mutex<BoxState<T>>,
    cv: Condvar,
}

/// The real in-process runtime endpoint: one per rank, mutex/condvar
/// mailboxes, ranks free-running (no barriers — the paper's schedules
/// are round-*numbered*, not barrier-synchronised, and this transport
/// is the second, independent proof of that after
/// [`crate::sim::threads`]). See the module docs for semantics.
pub struct ThreadTransport<T> {
    rank: usize,
    boxes: Arc<Vec<RankBox<T>>>,
    /// World-shared suspicion board: ranks some endpoint timed out
    /// waiting on. In-process the board is shared memory, so every
    /// survivor reads the identical failed set through
    /// [`Transport::failed_peers`] — the perfect-detector analogue of
    /// the socket plane's per-link EOF observations.
    suspects: Arc<Mutex<BTreeSet<usize>>>,
    timeout: Duration,
    disc: Discipline,
}

impl<T: Send> ThreadTransport<T> {
    /// Endpoints for all `p` ranks of a fresh world (receive deadline
    /// from [`configured_timeout`]: `CBCAST_TRANSPORT_TIMEOUT_MS` or
    /// [`DEFAULT_TIMEOUT`]).
    pub fn world(p: usize) -> Vec<ThreadTransport<T>> {
        Self::world_with_timeout(p, configured_timeout())
    }

    /// [`ThreadTransport::world`] with an explicit receive deadline
    /// (failure-injection tests use a short one).
    pub fn world_with_timeout(p: usize, timeout: Duration) -> Vec<ThreadTransport<T>> {
        assert!(p > 0);
        let boxes: Arc<Vec<RankBox<T>>> = Arc::new(
            (0..p)
                .map(|_| RankBox {
                    state: Mutex::new(BoxState {
                        msgs: HashMap::new(),
                        poisoned: None,
                        waiting_on: None,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
        );
        let suspects = Arc::new(Mutex::new(BTreeSet::new()));
        (0..p)
            .map(|rank| ThreadTransport {
                rank,
                boxes: boxes.clone(),
                suspects: suspects.clone(),
                timeout,
                disc: Discipline::default(),
            })
            .collect()
    }

    /// Walk the wait chain from `suspect` to the rank that is *not*
    /// blocked in a receive — the failure detector's accusation rule.
    /// When a rank dies mid-collective, the ranks starved of its
    /// messages cascade into blocked receives within microseconds of
    /// each other and their deadlines fire near-simultaneously; naively
    /// accusing one's direct peer would then indict a healthy,
    /// merely-starved rank. Following `waiting_on` pointers (capped at
    /// `p` hops for broken-schedule cycles) lands every accuser on the
    /// chain's root: the rank that stopped calling transport verbs —
    /// the dead one. Best-effort, like all of this runtime's detection:
    /// a rank caught computing between rounds at the instant of the
    /// walk can be blamed, which is the usual unreliable-detector
    /// caveat, vanishingly unlikely at sane timeouts.
    fn accuse(&self, mut suspect: usize) -> usize {
        for _ in 0..self.boxes.len() {
            match self.boxes[suspect].state.lock().unwrap().waiting_on {
                Some(next) if next != suspect => suspect = next,
                _ => break,
            }
        }
        suspect
    }

    /// Shut the whole world down: every blocked and future call on any
    /// endpoint fails with [`TransportError::Shutdown`] instead of
    /// waiting — the no-deadlocked-mailboxes guarantee.
    pub fn poison(&self, reason: &str) {
        for b in self.boxes.iter() {
            let mut st = b.state.lock().unwrap();
            if st.poisoned.is_none() {
                st.poisoned = Some(reason.to_string());
            }
            drop(st);
            b.cv.notify_all();
        }
    }
}

impl<T: Send> Transport<T> for ThreadTransport<T> {
    fn p(&self) -> usize {
        self.boxes.len()
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        self.disc.check_send(self.rank, round)?;
        if peer == self.rank {
            return Err(TransportError::Machine(SimError::SelfMessage {
                round,
                rank: self.rank,
            }));
        }
        if peer >= self.boxes.len() {
            return Err(TransportError::Machine(SimError::BadTarget {
                round,
                rank: self.rank,
                to: peer,
            }));
        }
        let err = {
            let mut st = self.boxes[peer].state.lock().unwrap();
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
            match st.msgs.get(&round).map(|(first_from, _)| *first_from) {
                Some(first_from) => Some(SimError::ReceivePortBusy {
                    round,
                    to: peer,
                    first_from,
                    second_from: self.rank,
                }),
                None => {
                    st.msgs.insert(round, (self.rank, data));
                    None
                }
            }
        };
        match err {
            Some(e) => {
                // A port collision is a broken schedule: abort the whole
                // world (the lockstep driver would abort mid-round too).
                self.poison(&e.to_string());
                Err(TransportError::Machine(e))
            }
            None => {
                self.boxes[peer].cv.notify_all();
                Ok(())
            }
        }
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        // Free-running: nothing to seal; keep the discipline honest.
        self.disc.check_flush(self.rank, round)
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        self.disc.check_recv(self.rank, round)?;
        let deadline = Instant::now() + self.timeout;
        let mybox = &self.boxes[self.rank];
        let mut st = mybox.state.lock().unwrap();
        // Publish the wait-chain pointer for the failure detector.
        st.waiting_on = Some(peer);
        loop {
            // Abort semantics: once the world is poisoned nothing more is
            // delivered, even if a matching message is already queued —
            // mirroring the lockstep driver's mid-round abort.
            if let Some(reason) = &st.poisoned {
                let reason = reason.clone();
                st.waiting_on = None;
                return Err(TransportError::Shutdown { rank: self.rank, round, reason });
            }
            match st.msgs.get(&round).map(|(from, _)| *from) {
                Some(from) if from == peer => {
                    let (_, data) = st.msgs.remove(&round).unwrap();
                    st.waiting_on = None;
                    return Ok(data);
                }
                Some(from) => {
                    // One-ported: a same-round message from anyone else
                    // means the send and receive schedules disagree.
                    let e = SimError::UnexpectedMessage {
                        round,
                        to: self.rank,
                        from,
                        expected: Some(peer),
                    };
                    st.waiting_on = None;
                    drop(st);
                    self.poison(&e.to_string());
                    return Err(TransportError::Machine(e));
                }
                None => {}
            }
            let now = Instant::now();
            if now >= deadline {
                // Keep our own waiting_on pointing at `peer` during the
                // walk — a concurrent walker passing through us must
                // still reach the chain's root — then overwrite it with
                // the blame marker (see `accuse`).
                drop(st);
                let suspect = self.accuse(peer);
                self.suspects.lock().unwrap().insert(suspect);
                mybox.state.lock().unwrap().waiting_on = Some(suspect);
                let e = TransportError::Timeout { rank: self.rank, round, from: peer };
                self.poison(&e.to_string());
                return Err(e);
            }
            let (guard, _) = mybox.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn failed_peers(&self) -> Vec<usize> {
        self.suspects.lock().unwrap().iter().copied().collect()
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        if let Some(reason) = error {
            self.poison(reason);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// LoopbackTransport: the lockstep replay (round barrier + full checks)
// ---------------------------------------------------------------------

struct LoopState<T> {
    /// round -> (to -> (from, payload)): the round's in-flight inbox,
    /// exactly the lockstep round body's delivery slots.
    msgs: HashMap<usize, HashMap<usize, (usize, Vec<T>)>>,
    /// `sealed[r]` = number of rounds rank `r` has flushed (rounds
    /// `0..sealed[r]` are sealed).
    sealed: Vec<usize>,
    retired: Vec<bool>,
    poisoned: Option<String>,
}

impl<T> LoopState<T> {
    /// Lowest seal count over live ranks (`usize::MAX` once all retired)
    /// — rounds below it minus one can no longer be received by anyone.
    fn min_active_sealed(&self) -> usize {
        self.sealed
            .iter()
            .zip(&self.retired)
            .filter(|(_, &r)| !r)
            .map(|(&s, _)| s)
            .min()
            .unwrap_or(usize::MAX)
    }

    /// A message of round `jj` still undelivered once every live rank
    /// has sealed `jj + 1` (i.e. passed its `recv(jj)` point) is exactly
    /// the lockstep `UnexpectedMessage` at a receiver that expected
    /// nothing.
    fn leftover(&self, before: usize) -> Option<SimError> {
        let mut worst: Option<(usize, usize, usize)> = None;
        for (&jj, slots) in &self.msgs {
            if jj + 2 <= before {
                for (&to, &(from, _)) in slots {
                    let cand = (jj, to, from);
                    if worst.map_or(true, |w| cand < w) {
                        worst = Some(cand);
                    }
                }
            }
        }
        worst.map(|(round, to, from)| SimError::UnexpectedMessage {
            round,
            to,
            from,
            expected: None,
        })
    }
}

struct LoopShared<T> {
    state: Mutex<LoopState<T>>,
    cv: Condvar,
}

/// The lockstep replay transport: a per-round barrier (receives wait
/// until every live rank has sealed the round), then delivery with the
/// full lockstep [`crate::sim::Network`] check set — the differential
/// mirror of [`ThreadTransport`]. See the module docs.
pub struct LoopbackTransport<T> {
    rank: usize,
    p: usize,
    shared: Arc<LoopShared<T>>,
    timeout: Duration,
    disc: Discipline,
}

impl<T: Send> LoopbackTransport<T> {
    /// Endpoints for all `p` ranks of a fresh lockstep world.
    pub fn world(p: usize) -> Vec<LoopbackTransport<T>> {
        Self::world_with_timeout(p, DEFAULT_TIMEOUT)
    }

    /// [`LoopbackTransport::world`] with an explicit barrier deadline.
    pub fn world_with_timeout(p: usize, timeout: Duration) -> Vec<LoopbackTransport<T>> {
        assert!(p > 0);
        let shared = Arc::new(LoopShared {
            state: Mutex::new(LoopState {
                msgs: HashMap::new(),
                sealed: vec![0; p],
                retired: vec![false; p],
                poisoned: None,
            }),
            cv: Condvar::new(),
        });
        (0..p)
            .map(|rank| LoopbackTransport {
                rank,
                p,
                shared: shared.clone(),
                timeout,
                disc: Discipline::default(),
            })
            .collect()
    }

    fn poison_locked(st: &mut LoopState<T>, reason: &str) {
        if st.poisoned.is_none() {
            st.poisoned = Some(reason.to_string());
        }
    }
}

impl<T: Send> Transport<T> for LoopbackTransport<T> {
    fn p(&self) -> usize {
        self.p
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&mut self, round: usize, peer: usize, data: Vec<T>) -> Result<(), TransportError> {
        self.disc.check_send(self.rank, round)?;
        if peer == self.rank {
            return Err(TransportError::Machine(SimError::SelfMessage {
                round,
                rank: self.rank,
            }));
        }
        if peer >= self.p {
            return Err(TransportError::Machine(SimError::BadTarget {
                round,
                rank: self.rank,
                to: peer,
            }));
        }
        let mut st = self.shared.state.lock().unwrap();
        if let Some(reason) = &st.poisoned {
            return Err(TransportError::Shutdown {
                rank: self.rank,
                round,
                reason: reason.clone(),
            });
        }
        let dup = st
            .msgs
            .get(&round)
            .and_then(|slots| slots.get(&peer))
            .map(|(first_from, _)| *first_from);
        if let Some(first_from) = dup {
            let e = SimError::ReceivePortBusy {
                round,
                to: peer,
                first_from,
                second_from: self.rank,
            };
            Self::poison_locked(&mut st, &e.to_string());
            drop(st);
            self.shared.cv.notify_all();
            return Err(TransportError::Machine(e));
        }
        st.msgs.entry(round).or_default().insert(peer, (self.rank, data));
        Ok(())
    }

    fn flush(&mut self, round: usize) -> Result<(), TransportError> {
        self.disc.check_flush(self.rank, round)?;
        let mut st = self.shared.state.lock().unwrap();
        if let Some(reason) = &st.poisoned {
            return Err(TransportError::Shutdown {
                rank: self.rank,
                round,
                reason: reason.clone(),
            });
        }
        if st.sealed[self.rank] < round + 1 {
            st.sealed[self.rank] = round + 1;
        }
        // Rounds nobody can receive anymore must be empty — the
        // lockstep "message at a rank that expected none" check.
        let horizon = st.min_active_sealed();
        if let Some(e) = st.leftover(horizon) {
            Self::poison_locked(&mut st, &e.to_string());
            drop(st);
            self.shared.cv.notify_all();
            return Err(TransportError::Machine(e));
        }
        drop(st);
        self.shared.cv.notify_all();
        Ok(())
    }

    fn recv(&mut self, round: usize, peer: usize) -> Result<Vec<T>, TransportError> {
        if !self.disc.flushed.is_some_and(|f| f >= round) {
            return Err(TransportError::OutOfRound {
                rank: self.rank,
                round,
                what: "receive before the round was flushed",
            });
        }
        self.disc.check_recv(self.rank, round)?;
        let deadline = Instant::now() + self.timeout;
        let mut st = self.shared.state.lock().unwrap();
        // The round barrier: wait until every live rank sealed `round`.
        loop {
            if let Some(reason) = &st.poisoned {
                return Err(TransportError::Shutdown {
                    rank: self.rank,
                    round,
                    reason: reason.clone(),
                });
            }
            let ready = st
                .sealed
                .iter()
                .zip(&st.retired)
                .all(|(&s, &r)| r || s > round);
            if ready {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                let e = TransportError::Timeout { rank: self.rank, round, from: peer };
                Self::poison_locked(&mut st, &e.to_string());
                drop(st);
                self.shared.cv.notify_all();
                return Err(e);
            }
            let (guard, _) = self.shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        // Delivery, with the lockstep cross-check.
        let taken = st.msgs.get_mut(&round).and_then(|slots| slots.remove(&self.rank));
        match taken {
            Some((from, data)) if from == peer => {
                if st.msgs.get(&round).is_some_and(|slots| slots.is_empty()) {
                    st.msgs.remove(&round);
                }
                Ok(data)
            }
            Some((from, _)) => {
                let e = SimError::UnexpectedMessage {
                    round,
                    to: self.rank,
                    from,
                    expected: Some(peer),
                };
                Self::poison_locked(&mut st, &e.to_string());
                drop(st);
                self.shared.cv.notify_all();
                Err(TransportError::Machine(e))
            }
            None => {
                let e = SimError::MissingMessage {
                    round,
                    rank: self.rank,
                    expected_from: peer,
                };
                Self::poison_locked(&mut st, &e.to_string());
                drop(st);
                self.shared.cv.notify_all();
                Err(TransportError::Machine(e))
            }
        }
    }

    fn close(&mut self, error: Option<&str>) -> Result<(), TransportError> {
        let mut st = self.shared.state.lock().unwrap();
        st.retired[self.rank] = true;
        if let Some(reason) = error {
            Self::poison_locked(&mut st, reason);
        }
        let mut res = Ok(());
        if st.poisoned.is_none() && st.retired.iter().all(|&r| r) {
            // Last one out checks the lights: undelivered messages are
            // schedule bugs (lockstep `UnexpectedMessage`).
            if let Some(e) = st.leftover(usize::MAX) {
                Self::poison_locked(&mut st, &e.to_string());
                res = Err(TransportError::Machine(e));
            }
        }
        drop(st);
        self.shared.cv.notify_all();
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn take2<T>(mut v: Vec<T>) -> (T, T) {
        let b = v.pop().unwrap();
        let a = v.pop().unwrap();
        (a, b)
    }

    #[test]
    fn thread_transport_delivers_out_of_order() {
        let (mut t0, mut t1) = take2(ThreadTransport::<u32>::world(2));
        t0.send(0, 1, vec![10]).unwrap();
        t1.flush(0).unwrap();
        t0.flush(0).unwrap();
        t0.send(1, 1, vec![11]).unwrap();
        // Round-tag matching: rank 1 takes round 1 first, then round 0...
        // one-ported discipline forbids recv going backwards, so take them
        // in order here; the out-of-order case is covered by the threaded
        // collectives (rank 0 ran two rounds ahead before rank 1 received).
        assert_eq!(t1.recv(0, 0).unwrap(), vec![10]);
        t1.flush(1).unwrap();
        assert_eq!(t1.recv(1, 0).unwrap(), vec![11]);
    }

    // NOTE: out-of-round-send, send-into-flushed-round and wrong-peer-recv
    // misuse coverage lives at the public-API level in
    // `tests/failure_injection.rs` (the SPMD section), alongside the
    // tampered-rank scenarios.

    #[test]
    fn self_message_and_bad_target_rejected() {
        let (mut t0, _t1) = take2(ThreadTransport::<u8>::world(2));
        assert!(matches!(
            t0.send(0, 0, vec![]),
            Err(TransportError::Machine(SimError::SelfMessage { round: 0, rank: 0 }))
        ));
        let (mut l0, _l1) = take2(LoopbackTransport::<u8>::world(2));
        assert!(matches!(
            l0.send(0, 5, vec![]),
            Err(TransportError::Machine(SimError::BadTarget { round: 0, rank: 0, to: 5 }))
        ));
    }

    #[test]
    fn thread_port_collision_detected_and_poisons() {
        let mut world = ThreadTransport::<u8>::world(3);
        let mut t2 = world.pop().unwrap();
        let mut t1 = world.pop().unwrap();
        let mut t0 = world.pop().unwrap();
        t0.send(0, 2, vec![1]).unwrap();
        match t1.send(0, 2, vec![2]) {
            Err(TransportError::Machine(SimError::ReceivePortBusy {
                round: 0,
                to: 2,
                first_from: 0,
                second_from: 1,
            })) => {}
            other => panic!("expected ReceivePortBusy, got {other:?}"),
        }
        // World poisoned: the victim does not hang, it sees the shutdown.
        t2.flush(0).unwrap();
        assert!(matches!(t2.recv(0, 0), Err(TransportError::Shutdown { .. })));
    }

    #[test]
    fn loopback_missing_message_detected_at_barrier() {
        // Two ranks, both flush round 0, rank 1 expects a message that
        // was never sent: the barrier completes and the lockstep check
        // fires (no timeout involved).
        let (mut t0, mut t1) = take2(LoopbackTransport::<u8>::world(2));
        t0.flush(0).unwrap();
        t1.flush(0).unwrap();
        match t1.recv(0, 0) {
            Err(TransportError::Machine(SimError::MissingMessage {
                round: 0,
                rank: 1,
                expected_from: 0,
            })) => {}
            other => panic!("expected MissingMessage, got {other:?}"),
        }
        // Poisoned world: rank 0's close is clean (it retires), but any
        // further blocking verb reports shutdown.
        assert!(matches!(
            t0.flush(1),
            Err(TransportError::Shutdown { .. })
        ));
    }

    #[test]
    fn loopback_recv_before_flush_rejected() {
        let (mut t0, _t1) = take2(LoopbackTransport::<u8>::world(2));
        assert!(matches!(
            t0.recv(0, 1),
            Err(TransportError::OutOfRound { .. })
        ));
    }

    #[test]
    fn loopback_leftover_surfaces_on_close() {
        // Rank 0 sends a message rank 1 never receives; both complete
        // "cleanly" — the last close reports the undelivered message as
        // the lockstep UnexpectedMessage it is.
        let (mut t0, mut t1) = take2(LoopbackTransport::<u8>::world(2));
        t0.send(0, 1, vec![9]).unwrap();
        t0.flush(0).unwrap();
        t1.flush(0).unwrap();
        t0.close(None).unwrap();
        match t1.close(None) {
            Err(TransportError::Machine(SimError::UnexpectedMessage {
                round: 0,
                to: 1,
                from: 0,
                expected: None,
            })) => {}
            other => panic!("expected leftover UnexpectedMessage, got {other:?}"),
        }
    }

    #[test]
    fn loopback_barrier_runs_a_real_exchange() {
        // Two threads, three rounds of ping-pong, all delivered in
        // lockstep with no errors.
        let (t0, t1) = take2(LoopbackTransport::<u32>::world(2));
        let a = std::thread::spawn(move || {
            let mut t = t0;
            for j in 0..3usize {
                t.send(j, 1, vec![j as u32]).unwrap();
                t.flush(j).unwrap();
                assert_eq!(t.recv(j, 1).unwrap(), vec![100 + j as u32]);
            }
            t.close(None).unwrap();
        });
        let b = std::thread::spawn(move || {
            let mut t = t1;
            for j in 0..3usize {
                t.send(j, 0, vec![100 + j as u32]).unwrap();
                t.flush(j).unwrap();
                assert_eq!(t.recv(j, 0).unwrap(), vec![j as u32]);
            }
            t.close(None).unwrap();
        });
        a.join().unwrap();
        b.join().unwrap();
    }

    #[test]
    fn thread_timeout_poisons_instead_of_deadlocking() {
        let mut world = ThreadTransport::<u8>::world_with_timeout(2, Duration::from_millis(50));
        let mut t1 = world.pop().unwrap();
        let mut t0 = world.pop().unwrap();
        t0.flush(0).unwrap();
        assert!(matches!(
            t0.recv(0, 1),
            Err(TransportError::Timeout { rank: 0, round: 0, from: 1 })
        ));
        // The timeout shut the world down for everyone.
        t1.flush(0).unwrap();
        assert!(matches!(t1.recv(0, 0), Err(TransportError::Shutdown { .. })));
    }

    #[test]
    fn thread_timeout_accuses_the_awaited_peer() {
        // The suspicion board is world-shared: after rank 0 times out
        // waiting on rank 1, *every* endpoint's failed_peers names
        // exactly rank 1 — the recovery plane's detector contract.
        let mut world = ThreadTransport::<u8>::world_with_timeout(3, Duration::from_millis(50));
        let t2 = world.pop().unwrap();
        let t1 = world.pop().unwrap();
        let mut t0 = world.pop().unwrap();
        assert!(t0.failed_peers().is_empty(), "fresh world suspects nobody");
        t0.flush(0).unwrap();
        assert!(matches!(t0.recv(0, 1), Err(TransportError::Timeout { .. })));
        assert_eq!(t0.failed_peers(), vec![1]);
        assert_eq!(t1.failed_peers(), vec![1]);
        assert_eq!(t2.failed_peers(), vec![1]);
    }

    #[test]
    fn timeout_knob_parser_enforces_the_floor() {
        assert_eq!(parse_timeout_ms("250"), Ok(Duration::from_millis(250)));
        assert_eq!(parse_timeout_ms(" 42 "), Ok(Duration::from_millis(42)));
        assert_eq!(parse_timeout_ms("1"), Ok(Duration::from_millis(1)));
        assert!(parse_timeout_ms("0").unwrap_err().contains("1 ms floor"));
        assert!(parse_timeout_ms("30s").is_err(), "units are not accepted");
        assert!(parse_timeout_ms("-5").is_err());
        assert!(parse_timeout_ms("").is_err());
    }
}
