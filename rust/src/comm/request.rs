//! Typed request vocabulary of the [`super::Communicator`]: the
//! collective kinds, the algorithm families (with automatic selection),
//! the tuning constants, and one request struct per collective.
//!
//! String parsing for [`Kind`] and [`Algo`] exists only for the CLI edge
//! (`cbcast run`/`serve`); library code always uses the enums directly.

use std::sync::Arc;

use crate::collectives::common::ReduceOp;
use crate::sim::LogPParams;

/// The collective operations a [`super::Communicator`] serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    Bcast,
    Reduce,
    Allgatherv,
    ReduceScatter,
    Allreduce,
}

impl Kind {
    /// CLI-edge parser (the typed API never goes through strings).
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "bcast" => Kind::Bcast,
            "reduce" => Kind::Reduce,
            "allgatherv" | "allgather" => Kind::Allgatherv,
            "reduce-scatter" | "reduce_scatter" => Kind::ReduceScatter,
            "allreduce" => Kind::Allreduce,
            _ => return None,
        })
    }
}

/// Payloads at or below this many bytes resolve [`Algo::Auto`] to the
/// binomial tree for the rooted collectives (the classical tuned-module
/// small-message regime; above it the circulant pipeline wins).
pub const SMALL_MSG_BYTES: usize = 2048;

/// Algorithm family to run a collective with.
///
/// # Backend-selection rules
///
/// The algorithm family and the execution backend
/// ([`crate::comm::BackendKind`], chosen per communicator) compose as
/// follows — [`Algo::Auto`] resolution is *backend-independent*, so all
/// three backends agree on which algorithm runs (the differential
/// backend-parity suite pins this):
///
/// * `Lockstep` drives every (kind, algorithm) pair on the round-based
///   [`crate::sim::Network`] with full machine-model enforcement.
/// * `Threaded` drives every pair on one OS thread per rank.
/// * `Engine` runs [`Algo::Circulant`] broadcast and reduce on the sparse
///   [`crate::sim::engine::CirculantEngine`]; every other pair — the
///   all-collectives' per-root packing and all baseline algorithms are
///   generic state machines — falls back to the lockstep driver with
///   identical results and statistics. Note that every backend's
///   `Outcome::buffers` assembly is inherently O(p·m); the true
///   million-rank regime is served by `CirculantEngine`'s own API (as in
///   `benches/engine_scale.rs`), which skips result materialization.
/// * `Spmd` runs every [`Algo::Circulant`] collective on the SPMD rank
///   plane: the request fans out to `p` per-rank
///   [`crate::comm::RankComm`]s over
///   [`crate::comm::ThreadTransport`], each rank computing **only its
///   own** O(log p) schedule (no shared table is built or fetched) and
///   genuinely executing on its own OS thread. Non-circulant pairs run
///   their generic state machines over the same transport
///   ([`crate::comm::SpmdBackend`]). Results and statistics are
///   bit-identical to the lockstep backend (`tests/spmd_parity.rs`).
/// * `Socket` is `Spmd` with the wire swapped in: the same per-rank
///   fan-out, but over [`crate::comm::SocketTransport`] — every
///   message crosses a real OS socket (`UnixStream::pair` meshes for
///   in-process worlds). Same bit-identity pin, same parity suite;
///   use it to validate wire behavior rather than for speed.
///
/// Whichever simulated backend runs (`Lockstep`/`Threaded`/`Engine`),
/// schedules are served from one shared all-ranks
/// [`crate::schedule::ScheduleTable`] per `p`: a flat, parallel-built
/// arena that the communicator fetches once per collective call
/// (resident in the shared [`crate::schedule::ScheduleCache`] up to
/// [`TuningParams::table_cache_max_bytes`]; held privately on the
/// handle beyond it). Backends differ only in how the rows are
/// *driven*, never in which rows they see — which is what keeps the
/// differential parity suites meaningful. The `Spmd` backend is the
/// deliberate exception: it never touches the shared plane for the
/// circulant collectives, because recomputing per-rank rows in O(log p)
/// *is* the paper's model — the parity suite proves the two roads yield
/// the same schedules.
///
/// # The nonblocking path
///
/// The traffic plane ([`crate::comm::traffic::TrafficEngine`]) applies
/// the same resolution rules at the operation's *window* size: an op
/// windowed to `len` ranks resolves `Auto` (and the §3 block-count
/// rules) exactly as a `len`-rank communicator would, so a batched op
/// always runs the same algorithm as its sequential mirror. Backend
/// dispatch is preserved too, with one nuance: batched execution is
/// round-stepped, so under `Lockstep`, `Threaded` *and* `Spmd` each
/// op's rounds are driven by the steppable lockstep driver
/// ([`crate::sim::StepNet`] — bit-identical to all three, as the
/// backend parity suite shows), while under `Engine` circulant
/// broadcast/reduce ops step the sparse engine
/// ([`crate::sim::EngineStep`]) and every other pair steps the lockstep
/// driver, mirroring the blocking dispatch. The traffic parity suite
/// pins batched ≡ sequential per backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Pick automatically.
    ///
    /// Without LogP parameters ([`TuningParams::logp`] `None`, the
    /// default when no `CBCAST_LOGP_*` env knob is set) this is the
    /// legacy §3 rule: the circulant pipeline with the paper's
    /// `tuning::*` block count, except for small rooted payloads
    /// (≤ [`SMALL_MSG_BYTES`]) where the binomial tree is selected.
    ///
    /// With LogP parameters configured, resolution is *cost-driven*:
    /// the closed-form predictors in [`crate::collectives::tuning`]
    /// (`predict_circulant`, `predict_binomial`, `predict_vdg`,
    /// `predict_ring`, `predict_opttree`) estimate each applicable
    /// family's completion time for this `(kind, p, payload)` and the
    /// argmin wins — ties prefer the circulant pipeline. An explicit
    /// block-count override still pins the pipeline either way.
    Auto,
    /// The paper's circulant-schedule pipelined algorithms.
    Circulant,
    /// Binomial tree (bcast/reduce) — the native small-message algorithm.
    Binomial,
    /// van de Geijn scatter+allgather (bcast) — native large-message.
    VanDeGeijn,
    /// Ring (allgatherv / reduce-scatter / allreduce) — native
    /// large-message.
    Ring,
    /// Recursive halving with power-of-two folding (reduce-scatter with
    /// equal chunks) — the Observation 1.4 volume comparator.
    RecursiveHalving,
    /// Karp et al.'s greedy LogP-optimal broadcast tree
    /// ([`crate::schedule::OptTree`]) — bcast (root → leaves) and
    /// reduce (the same tree reversed round-by-round). The tree shape
    /// depends only on `(p, LogP params, payload bytes)`, never on the
    /// backend, so results are bit-identical across all backends. Built
    /// for [`TuningParams::logp`] (or [`LogPParams::default`] when
    /// unset) scaled to the payload size.
    OptTree,
}

impl Algo {
    /// CLI-edge parser (the typed API never goes through strings).
    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "auto" => Algo::Auto,
            "circulant" | "new" => Algo::Circulant,
            "binomial" => Algo::Binomial,
            "vdg" | "native-large" => Algo::VanDeGeijn,
            "ring" => Algo::Ring,
            "rhalving" | "recursive-halving" => Algo::RecursiveHalving,
            "opttree" | "karp" => Algo::OptTree,
            _ => return None,
        })
    }

    /// Resolve [`Algo::Auto`] for a `kind` with an `m`-element,
    /// `elem_bytes`-per-element payload; every other variant is returned
    /// unchanged. Never returns `Auto`.
    ///
    /// An explicit block-count override (`blocks`) is a request for the
    /// pipeline, so it pins the circulant algorithm — small rooted
    /// payloads fall back to the binomial tree only when the block count
    /// is left automatic.
    pub fn resolve(self, kind: Kind, m: usize, elem_bytes: usize, blocks: Option<usize>) -> Algo {
        if self != Algo::Auto {
            return self;
        }
        match kind {
            Kind::Bcast | Kind::Reduce
                if blocks.is_none() && m * elem_bytes <= SMALL_MSG_BYTES =>
            {
                Algo::Binomial
            }
            _ => Algo::Circulant,
        }
    }

    /// Cost-driven [`Algo::Auto`] resolution — the communicator-side
    /// entry point. Explicit variants pass through; a block-count
    /// override pins the circulant pipeline; without LogP parameters
    /// ([`TuningParams::logp`] `None`) this is exactly the legacy
    /// [`Algo::resolve`] rule. With parameters configured, each
    /// applicable family's closed-form LogP prediction is computed for
    /// this `(kind, p, m·elem_bytes)` and the argmin wins (strict `<`
    /// with the circulant pipeline listed first, so ties keep the
    /// paper's algorithm). Never returns `Auto`.
    ///
    /// Candidate families per kind: bcast — circulant, binomial,
    /// van de Geijn, opttree; reduce — circulant, binomial, opttree;
    /// allgatherv / reduce-scatter — circulant, ring; allreduce —
    /// circulant, ring (both with their reduce-scatter + all-gather
    /// phases doubled). Recursive halving is never auto-picked: it
    /// rejects unequal chunk layouts, which `Auto` cannot rule out.
    pub fn resolve_with(
        self,
        kind: Kind,
        p: usize,
        m: usize,
        elem_bytes: usize,
        blocks: Option<usize>,
        tuning: &TuningParams,
    ) -> Algo {
        if self != Algo::Auto {
            return self;
        }
        if blocks.is_some() {
            return Algo::Circulant;
        }
        let params = match tuning.logp {
            Some(params) => params,
            None => return self.resolve(kind, m, elem_bytes, blocks),
        };
        use crate::collectives::tuning::{
            predict_binomial, predict_circulant, predict_opttree, predict_ring, predict_vdg,
        };
        let total = m * elem_bytes;
        let n = resolve_blocks(kind, p, m, tuning, None);
        let circulant = predict_circulant(p, n, total, &params);
        let candidates: Vec<(Algo, f64)> = match kind {
            Kind::Bcast => vec![
                (Algo::Circulant, circulant),
                (Algo::Binomial, predict_binomial(p, total, &params)),
                (Algo::VanDeGeijn, predict_vdg(p, total, &params)),
                (Algo::OptTree, predict_opttree(p, total, &params)),
            ],
            Kind::Reduce => vec![
                (Algo::Circulant, circulant),
                (Algo::Binomial, predict_binomial(p, total, &params)),
                (Algo::OptTree, predict_opttree(p, total, &params)),
            ],
            Kind::Allgatherv | Kind::ReduceScatter => vec![
                (Algo::Circulant, circulant),
                (Algo::Ring, predict_ring(p, total, &params)),
            ],
            // Allreduce = reduce-scatter + all-gather on the same
            // pattern: both families run two phases.
            Kind::Allreduce => vec![
                (Algo::Circulant, 2.0 * circulant),
                (Algo::Ring, 2.0 * predict_ring(p, total, &params)),
            ],
        };
        let mut best = candidates[0];
        for &cand in &candidates[1..] {
            if cand.1 < best.1 {
                best = cand;
            }
        }
        best.0
    }
}

/// Tuning constants: the paper's F and G from §3 (block size
/// `F·sqrt(m/q)` for bcast/reduce, `n = sqrt(m·q)/G` for the
/// all-collectives), plus the schedule-plane cache bound.
#[derive(Debug, Clone)]
pub struct TuningParams {
    pub f_const: f64,
    pub g_const: f64,
    /// Admission cap, in arena bytes (`2·p·q`), for keeping a
    /// communicator's all-ranks [`crate::schedule::ScheduleTable`]
    /// resident in the shared [`crate::schedule::ScheduleCache`]. The
    /// default ([`crate::schedule::DEFAULT_TABLE_CAP_BYTES`]) admits
    /// exactly what the historical `p ≤ 4096` rule admitted; above the
    /// cap the communicator still builds the table once and keeps it
    /// privately for its own lifetime — the cap only bounds what stays
    /// resident in the *shared* cache.
    pub table_cache_max_bytes: usize,
    /// LogP machine parameters for the cost plane. `Some` switches
    /// [`Algo::Auto`] to cost-driven resolution
    /// ([`Algo::resolve_with`]), attaches a [`crate::sim::LogPClock`]
    /// to every run (surfaced as `RunStats::logp_time`), and sets the
    /// machine [`Algo::OptTree`] builds its tree for. The default pulls
    /// [`LogPParams::from_env`]: `None` unless at least one
    /// `CBCAST_LOGP_{L,O,G}` env knob is set.
    pub logp: Option<LogPParams>,
}

impl Default for TuningParams {
    fn default() -> Self {
        // The paper's experimentally chosen constants (Fig. 1: F = 70,
        // Fig. 2: G = 40).
        TuningParams {
            f_const: 70.0,
            g_const: 40.0,
            table_cache_max_bytes: crate::schedule::DEFAULT_TABLE_CAP_BYTES,
            logp: LogPParams::from_env(),
        }
    }
}

/// The block count a request resolves to: the override if given, else the
/// paper's §3 rule for the collective kind — the single definition shared
/// by [`super::Communicator`] and the coordinator's planner.
pub fn resolve_blocks(
    kind: Kind,
    p: usize,
    m: usize,
    tp: &TuningParams,
    blocks: Option<usize>,
) -> usize {
    use crate::collectives::tuning;
    blocks
        .unwrap_or_else(|| match kind {
            Kind::Bcast | Kind::Reduce => tuning::bcast_blocks_paper(m, p, tp.f_const),
            Kind::Allgatherv | Kind::ReduceScatter | Kind::Allreduce => {
                tuning::allgatherv_blocks_paper(m, p, tp.g_const)
            }
        })
        .max(1)
}

/// Broadcast request: `data` at `root`, delivered to every rank.
#[derive(Debug, Clone)]
pub struct BcastReq<'a, T> {
    pub root: usize,
    pub data: &'a [T],
    /// `None` = the paper's block-count rule.
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> BcastReq<'a, T> {
    pub fn new(root: usize, data: &'a [T]) -> Self {
        BcastReq {
            root,
            data,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Rooted reduction request: every rank contributes `inputs[r]` (equal
/// lengths); the root ends with the elementwise ⊕ over all ranks.
#[derive(Clone)]
pub struct ReduceReq<'a, T> {
    pub root: usize,
    pub inputs: &'a [Vec<T>],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceReq<'a, T> {
    pub fn new(root: usize, inputs: &'a [Vec<T>], op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceReq {
            root,
            inputs,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// All-broadcast request (`MPI_Allgatherv`): rank `r` contributes
/// `inputs[r]` (arbitrary per-rank lengths); every rank ends with every
/// contribution. For the regular `MPI_Allgather`, use
/// [`super::Communicator::allgather`], which additionally validates equal
/// counts.
#[derive(Debug, Clone)]
pub struct AllgathervReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> AllgathervReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>]) -> Self {
        AllgathervReq {
            inputs,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Irregular all-reduction request (`MPI_Reduce_scatter`): every rank
/// contributes a full vector (the concatenation over destinations `j` of
/// `counts[j]` elements); rank `j` ends with the fully reduced chunk `j`.
#[derive(Clone)]
pub struct ReduceScatterReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub counts: &'a [usize],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceScatterReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], counts: &'a [usize], op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceScatterReq {
            inputs,
            counts,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Regular all-reduction request (`MPI_Reduce_scatter_block`): equal
/// chunk of `block_elems` elements per rank.
#[derive(Clone)]
pub struct ReduceScatterBlockReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub block_elems: usize,
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceScatterBlockReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], block_elems: usize, op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceScatterBlockReq {
            inputs,
            block_elems,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// The options every request carries: block-count override, algorithm
/// selection, element width. One definition for all six request types
/// (the same trick as `impl_sum!`/`impl_max!` in `collectives::common`).
macro_rules! impl_request_options {
    ($($ty:ident),* $(,)?) => {$(
        impl<T> $ty<'_, T> {
            /// Override the block count (`None` = the paper's §3 rule).
            pub fn blocks(mut self, n: usize) -> Self {
                self.blocks = Some(n);
                self
            }

            /// Select the algorithm family (default [`Algo::Auto`]).
            pub fn algo(mut self, algo: Algo) -> Self {
                self.algo = algo;
                self
            }

            /// Element width in bytes for cost accounting (default
            /// `size_of::<T>()`).
            pub fn elem_bytes(mut self, bytes: usize) -> Self {
                self.elem_bytes = bytes;
                self
            }
        }
    )*};
}

impl_request_options!(
    BcastReq,
    ReduceReq,
    AllgathervReq,
    ReduceScatterReq,
    ReduceScatterBlockReq,
    AllreduceReq,
);

/// All-reduce request: every rank contributes `inputs[r]` (equal
/// lengths); every rank ends with the elementwise ⊕ over all ranks.
/// Composed as reduce-scatter + all-gather on the same circulant pattern.
#[derive(Clone)]
pub struct AllreduceReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> AllreduceReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], op: Arc<dyn ReduceOp<T>>) -> Self {
        AllreduceReq {
            inputs,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_algo_parse() {
        assert_eq!(Kind::parse("bcast"), Some(Kind::Bcast));
        assert_eq!(Kind::parse("reduce-scatter"), Some(Kind::ReduceScatter));
        assert_eq!(Algo::parse("new"), Some(Algo::Circulant));
        assert_eq!(Algo::parse("auto"), Some(Algo::Auto));
        assert_eq!(Algo::parse("rhalving"), Some(Algo::RecursiveHalving));
        assert_eq!(Algo::parse("opttree"), Some(Algo::OptTree));
        assert_eq!(Algo::parse("karp"), Some(Algo::OptTree));
        assert!(Kind::parse("nope").is_none());
        assert!(Algo::parse("nope").is_none());
    }

    /// A `TuningParams` pinned to an explicit LogP setting — tests never
    /// go through `Default` (which reads the env) to stay immune to
    /// `CBCAST_LOGP_*` leaking between parallel tests.
    fn tuning_with(logp: Option<LogPParams>) -> TuningParams {
        TuningParams {
            f_const: 70.0,
            g_const: 40.0,
            table_cache_max_bytes: crate::schedule::DEFAULT_TABLE_CAP_BYTES,
            logp,
        }
    }

    #[test]
    fn resolve_with_no_logp_is_the_legacy_rule_verbatim() {
        let tuning = tuning_with(None);
        let kinds = [
            Kind::Bcast,
            Kind::Reduce,
            Kind::Allgatherv,
            Kind::ReduceScatter,
            Kind::Allreduce,
        ];
        for kind in kinds {
            for p in [2usize, 7, 64, 333] {
                for m in [1usize, 16, 512, 513, 1 << 16] {
                    for blocks in [None, Some(4)] {
                        assert_eq!(
                            Algo::Auto.resolve_with(kind, p, m, 4, blocks, &tuning),
                            Algo::Auto.resolve(kind, m, 4, blocks),
                            "kind={kind:?} p={p} m={m} blocks={blocks:?}"
                        );
                    }
                }
            }
        }
        // Explicit variants pass through untouched either way.
        assert_eq!(
            Algo::Ring.resolve_with(Kind::Bcast, 8, 16, 4, None, &tuning),
            Algo::Ring
        );
    }

    #[test]
    fn cost_driven_auto_follows_the_crossover() {
        let tuning = tuning_with(Some(LogPParams::default()));
        // Tiny rooted payload: a tree family must win.
        let pick = Algo::Auto.resolve_with(Kind::Bcast, 64, 16, 4, None, &tuning);
        assert!(
            pick == Algo::OptTree || pick == Algo::Binomial,
            "small bcast picked {pick:?}"
        );
        // Huge rooted payload: the pipelined circulant must win.
        assert_eq!(
            Algo::Auto.resolve_with(Kind::Bcast, 64, 1 << 22, 4, None, &tuning),
            Algo::Circulant
        );
        // Blocks override pins the pipeline even in cost-driven mode.
        assert_eq!(
            Algo::Auto.resolve_with(Kind::Bcast, 64, 16, 4, Some(8), &tuning),
            Algo::Circulant
        );
        // All-collectives only ever pick circulant or ring.
        for kind in [Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce] {
            for m in [64usize, 1 << 20] {
                let pick = Algo::Auto.resolve_with(kind, 8, m, 4, None, &tuning);
                assert!(
                    pick == Algo::Circulant || pick == Algo::Ring,
                    "kind={kind:?} m={m} picked {pick:?}"
                );
            }
        }
    }

    #[test]
    fn auto_resolution() {
        // Small rooted payloads go binomial, large go circulant.
        assert_eq!(Algo::Auto.resolve(Kind::Bcast, 16, 4, None), Algo::Binomial);
        assert_eq!(Algo::Auto.resolve(Kind::Reduce, 100, 4, None), Algo::Binomial);
        assert_eq!(Algo::Auto.resolve(Kind::Bcast, 1 << 20, 4, None), Algo::Circulant);
        // The all-collectives always resolve circulant.
        assert_eq!(Algo::Auto.resolve(Kind::Allgatherv, 16, 4, None), Algo::Circulant);
        assert_eq!(Algo::Auto.resolve(Kind::Allreduce, 16, 4, None), Algo::Circulant);
        // Explicit selections pass through.
        assert_eq!(Algo::Ring.resolve(Kind::Bcast, 16, 4, None), Algo::Ring);
    }

    #[test]
    fn request_builders_default_to_auto() {
        let data = vec![1i64; 8];
        let req = BcastReq::new(0, &data);
        assert_eq!(req.algo, Algo::Auto);
        assert_eq!(req.blocks, None);
        assert_eq!(req.elem_bytes, 8);
        let req = req.blocks(3).algo(Algo::Circulant).elem_bytes(4);
        assert_eq!(req.blocks, Some(3));
        assert_eq!(req.algo, Algo::Circulant);
        assert_eq!(req.elem_bytes, 4);
    }
}
