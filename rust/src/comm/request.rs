//! Typed request vocabulary of the [`super::Communicator`]: the
//! collective kinds, the algorithm families (with automatic selection),
//! the tuning constants, and one request struct per collective.
//!
//! String parsing for [`Kind`] and [`Algo`] exists only for the CLI edge
//! (`cbcast run`/`serve`); library code always uses the enums directly.

use std::sync::Arc;

use crate::collectives::common::ReduceOp;

/// The collective operations a [`super::Communicator`] serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    Bcast,
    Reduce,
    Allgatherv,
    ReduceScatter,
    Allreduce,
}

impl Kind {
    /// CLI-edge parser (the typed API never goes through strings).
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "bcast" => Kind::Bcast,
            "reduce" => Kind::Reduce,
            "allgatherv" | "allgather" => Kind::Allgatherv,
            "reduce-scatter" | "reduce_scatter" => Kind::ReduceScatter,
            "allreduce" => Kind::Allreduce,
            _ => return None,
        })
    }
}

/// Payloads at or below this many bytes resolve [`Algo::Auto`] to the
/// binomial tree for the rooted collectives (the classical tuned-module
/// small-message regime; above it the circulant pipeline wins).
pub const SMALL_MSG_BYTES: usize = 2048;

/// Algorithm family to run a collective with.
///
/// # Backend-selection rules
///
/// The algorithm family and the execution backend
/// ([`crate::comm::BackendKind`], chosen per communicator) compose as
/// follows — [`Algo::Auto`] resolution is *backend-independent*, so all
/// three backends agree on which algorithm runs (the differential
/// backend-parity suite pins this):
///
/// * `Lockstep` drives every (kind, algorithm) pair on the round-based
///   [`crate::sim::Network`] with full machine-model enforcement.
/// * `Threaded` drives every pair on one OS thread per rank.
/// * `Engine` runs [`Algo::Circulant`] broadcast and reduce on the sparse
///   [`crate::sim::engine::CirculantEngine`]; every other pair — the
///   all-collectives' per-root packing and all baseline algorithms are
///   generic state machines — falls back to the lockstep driver with
///   identical results and statistics. Note that every backend's
///   `Outcome::buffers` assembly is inherently O(p·m); the true
///   million-rank regime is served by `CirculantEngine`'s own API (as in
///   `benches/engine_scale.rs`), which skips result materialization.
/// * `Spmd` runs every [`Algo::Circulant`] collective on the SPMD rank
///   plane: the request fans out to `p` per-rank
///   [`crate::comm::RankComm`]s over
///   [`crate::comm::ThreadTransport`], each rank computing **only its
///   own** O(log p) schedule (no shared table is built or fetched) and
///   genuinely executing on its own OS thread. Non-circulant pairs run
///   their generic state machines over the same transport
///   ([`crate::comm::SpmdBackend`]). Results and statistics are
///   bit-identical to the lockstep backend (`tests/spmd_parity.rs`).
/// * `Socket` is `Spmd` with the wire swapped in: the same per-rank
///   fan-out, but over [`crate::comm::SocketTransport`] — every
///   message crosses a real OS socket (`UnixStream::pair` meshes for
///   in-process worlds). Same bit-identity pin, same parity suite;
///   use it to validate wire behavior rather than for speed.
///
/// Whichever simulated backend runs (`Lockstep`/`Threaded`/`Engine`),
/// schedules are served from one shared all-ranks
/// [`crate::schedule::ScheduleTable`] per `p`: a flat, parallel-built
/// arena that the communicator fetches once per collective call
/// (resident in the shared [`crate::schedule::ScheduleCache`] up to
/// [`TuningParams::table_cache_max_bytes`]; held privately on the
/// handle beyond it). Backends differ only in how the rows are
/// *driven*, never in which rows they see — which is what keeps the
/// differential parity suites meaningful. The `Spmd` backend is the
/// deliberate exception: it never touches the shared plane for the
/// circulant collectives, because recomputing per-rank rows in O(log p)
/// *is* the paper's model — the parity suite proves the two roads yield
/// the same schedules.
///
/// # The nonblocking path
///
/// The traffic plane ([`crate::comm::traffic::TrafficEngine`]) applies
/// the same resolution rules at the operation's *window* size: an op
/// windowed to `len` ranks resolves `Auto` (and the §3 block-count
/// rules) exactly as a `len`-rank communicator would, so a batched op
/// always runs the same algorithm as its sequential mirror. Backend
/// dispatch is preserved too, with one nuance: batched execution is
/// round-stepped, so under `Lockstep`, `Threaded` *and* `Spmd` each
/// op's rounds are driven by the steppable lockstep driver
/// ([`crate::sim::StepNet`] — bit-identical to all three, as the
/// backend parity suite shows), while under `Engine` circulant
/// broadcast/reduce ops step the sparse engine
/// ([`crate::sim::EngineStep`]) and every other pair steps the lockstep
/// driver, mirroring the blocking dispatch. The traffic parity suite
/// pins batched ≡ sequential per backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Pick automatically: the circulant pipeline with the paper's
    /// `tuning::*` block-count rule, except for small rooted payloads
    /// (≤ [`SMALL_MSG_BYTES`]) where the binomial tree is selected.
    Auto,
    /// The paper's circulant-schedule pipelined algorithms.
    Circulant,
    /// Binomial tree (bcast/reduce) — the native small-message algorithm.
    Binomial,
    /// van de Geijn scatter+allgather (bcast) — native large-message.
    VanDeGeijn,
    /// Ring (allgatherv / reduce-scatter / allreduce) — native
    /// large-message.
    Ring,
    /// Recursive halving with power-of-two folding (reduce-scatter with
    /// equal chunks) — the Observation 1.4 volume comparator.
    RecursiveHalving,
}

impl Algo {
    /// CLI-edge parser (the typed API never goes through strings).
    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "auto" => Algo::Auto,
            "circulant" | "new" => Algo::Circulant,
            "binomial" => Algo::Binomial,
            "vdg" | "native-large" => Algo::VanDeGeijn,
            "ring" => Algo::Ring,
            "rhalving" | "recursive-halving" => Algo::RecursiveHalving,
            _ => return None,
        })
    }

    /// Resolve [`Algo::Auto`] for a `kind` with an `m`-element,
    /// `elem_bytes`-per-element payload; every other variant is returned
    /// unchanged. Never returns `Auto`.
    ///
    /// An explicit block-count override (`blocks`) is a request for the
    /// pipeline, so it pins the circulant algorithm — small rooted
    /// payloads fall back to the binomial tree only when the block count
    /// is left automatic.
    pub fn resolve(self, kind: Kind, m: usize, elem_bytes: usize, blocks: Option<usize>) -> Algo {
        if self != Algo::Auto {
            return self;
        }
        match kind {
            Kind::Bcast | Kind::Reduce
                if blocks.is_none() && m * elem_bytes <= SMALL_MSG_BYTES =>
            {
                Algo::Binomial
            }
            _ => Algo::Circulant,
        }
    }
}

/// Tuning constants: the paper's F and G from §3 (block size
/// `F·sqrt(m/q)` for bcast/reduce, `n = sqrt(m·q)/G` for the
/// all-collectives), plus the schedule-plane cache bound.
#[derive(Debug, Clone)]
pub struct TuningParams {
    pub f_const: f64,
    pub g_const: f64,
    /// Admission cap, in arena bytes (`2·p·q`), for keeping a
    /// communicator's all-ranks [`crate::schedule::ScheduleTable`]
    /// resident in the shared [`crate::schedule::ScheduleCache`]. The
    /// default ([`crate::schedule::DEFAULT_TABLE_CAP_BYTES`]) admits
    /// exactly what the historical `p ≤ 4096` rule admitted; above the
    /// cap the communicator still builds the table once and keeps it
    /// privately for its own lifetime — the cap only bounds what stays
    /// resident in the *shared* cache.
    pub table_cache_max_bytes: usize,
}

impl Default for TuningParams {
    fn default() -> Self {
        // The paper's experimentally chosen constants (Fig. 1: F = 70,
        // Fig. 2: G = 40).
        TuningParams {
            f_const: 70.0,
            g_const: 40.0,
            table_cache_max_bytes: crate::schedule::DEFAULT_TABLE_CAP_BYTES,
        }
    }
}

/// The block count a request resolves to: the override if given, else the
/// paper's §3 rule for the collective kind — the single definition shared
/// by [`super::Communicator`] and the coordinator's planner.
pub fn resolve_blocks(
    kind: Kind,
    p: usize,
    m: usize,
    tp: &TuningParams,
    blocks: Option<usize>,
) -> usize {
    use crate::collectives::tuning;
    blocks
        .unwrap_or_else(|| match kind {
            Kind::Bcast | Kind::Reduce => tuning::bcast_blocks_paper(m, p, tp.f_const),
            Kind::Allgatherv | Kind::ReduceScatter | Kind::Allreduce => {
                tuning::allgatherv_blocks_paper(m, p, tp.g_const)
            }
        })
        .max(1)
}

/// Broadcast request: `data` at `root`, delivered to every rank.
#[derive(Debug, Clone)]
pub struct BcastReq<'a, T> {
    pub root: usize,
    pub data: &'a [T],
    /// `None` = the paper's block-count rule.
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> BcastReq<'a, T> {
    pub fn new(root: usize, data: &'a [T]) -> Self {
        BcastReq {
            root,
            data,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Rooted reduction request: every rank contributes `inputs[r]` (equal
/// lengths); the root ends with the elementwise ⊕ over all ranks.
#[derive(Clone)]
pub struct ReduceReq<'a, T> {
    pub root: usize,
    pub inputs: &'a [Vec<T>],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceReq<'a, T> {
    pub fn new(root: usize, inputs: &'a [Vec<T>], op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceReq {
            root,
            inputs,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// All-broadcast request (`MPI_Allgatherv`): rank `r` contributes
/// `inputs[r]` (arbitrary per-rank lengths); every rank ends with every
/// contribution. For the regular `MPI_Allgather`, use
/// [`super::Communicator::allgather`], which additionally validates equal
/// counts.
#[derive(Debug, Clone)]
pub struct AllgathervReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> AllgathervReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>]) -> Self {
        AllgathervReq {
            inputs,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Irregular all-reduction request (`MPI_Reduce_scatter`): every rank
/// contributes a full vector (the concatenation over destinations `j` of
/// `counts[j]` elements); rank `j` ends with the fully reduced chunk `j`.
#[derive(Clone)]
pub struct ReduceScatterReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub counts: &'a [usize],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceScatterReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], counts: &'a [usize], op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceScatterReq {
            inputs,
            counts,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// Regular all-reduction request (`MPI_Reduce_scatter_block`): equal
/// chunk of `block_elems` elements per rank.
#[derive(Clone)]
pub struct ReduceScatterBlockReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub block_elems: usize,
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> ReduceScatterBlockReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], block_elems: usize, op: Arc<dyn ReduceOp<T>>) -> Self {
        ReduceScatterBlockReq {
            inputs,
            block_elems,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

/// The options every request carries: block-count override, algorithm
/// selection, element width. One definition for all six request types
/// (the same trick as `impl_sum!`/`impl_max!` in `collectives::common`).
macro_rules! impl_request_options {
    ($($ty:ident),* $(,)?) => {$(
        impl<T> $ty<'_, T> {
            /// Override the block count (`None` = the paper's §3 rule).
            pub fn blocks(mut self, n: usize) -> Self {
                self.blocks = Some(n);
                self
            }

            /// Select the algorithm family (default [`Algo::Auto`]).
            pub fn algo(mut self, algo: Algo) -> Self {
                self.algo = algo;
                self
            }

            /// Element width in bytes for cost accounting (default
            /// `size_of::<T>()`).
            pub fn elem_bytes(mut self, bytes: usize) -> Self {
                self.elem_bytes = bytes;
                self
            }
        }
    )*};
}

impl_request_options!(
    BcastReq,
    ReduceReq,
    AllgathervReq,
    ReduceScatterReq,
    ReduceScatterBlockReq,
    AllreduceReq,
);

/// All-reduce request: every rank contributes `inputs[r]` (equal
/// lengths); every rank ends with the elementwise ⊕ over all ranks.
/// Composed as reduce-scatter + all-gather on the same circulant pattern.
#[derive(Clone)]
pub struct AllreduceReq<'a, T> {
    pub inputs: &'a [Vec<T>],
    pub op: Arc<dyn ReduceOp<T>>,
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub elem_bytes: usize,
}

impl<'a, T> AllreduceReq<'a, T> {
    pub fn new(inputs: &'a [Vec<T>], op: Arc<dyn ReduceOp<T>>) -> Self {
        AllreduceReq {
            inputs,
            op,
            blocks: None,
            algo: Algo::Auto,
            elem_bytes: std::mem::size_of::<T>(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_algo_parse() {
        assert_eq!(Kind::parse("bcast"), Some(Kind::Bcast));
        assert_eq!(Kind::parse("reduce-scatter"), Some(Kind::ReduceScatter));
        assert_eq!(Algo::parse("new"), Some(Algo::Circulant));
        assert_eq!(Algo::parse("auto"), Some(Algo::Auto));
        assert_eq!(Algo::parse("rhalving"), Some(Algo::RecursiveHalving));
        assert!(Kind::parse("nope").is_none());
        assert!(Algo::parse("nope").is_none());
    }

    #[test]
    fn auto_resolution() {
        // Small rooted payloads go binomial, large go circulant.
        assert_eq!(Algo::Auto.resolve(Kind::Bcast, 16, 4, None), Algo::Binomial);
        assert_eq!(Algo::Auto.resolve(Kind::Reduce, 100, 4, None), Algo::Binomial);
        assert_eq!(Algo::Auto.resolve(Kind::Bcast, 1 << 20, 4, None), Algo::Circulant);
        // The all-collectives always resolve circulant.
        assert_eq!(Algo::Auto.resolve(Kind::Allgatherv, 16, 4, None), Algo::Circulant);
        assert_eq!(Algo::Auto.resolve(Kind::Allreduce, 16, 4, None), Algo::Circulant);
        // Explicit selections pass through.
        assert_eq!(Algo::Ring.resolve(Kind::Bcast, 16, 4, None), Algo::Ring);
    }

    #[test]
    fn request_builders_default_to_auto() {
        let data = vec![1i64; 8];
        let req = BcastReq::new(0, &data);
        assert_eq!(req.algo, Algo::Auto);
        assert_eq!(req.blocks, None);
        assert_eq!(req.elem_bytes, 8);
        let req = req.blocks(3).algo(Algo::Circulant).elem_bytes(4);
        assert_eq!(req.blocks, Some(3));
        assert_eq!(req.algo, Algo::Circulant);
        assert_eq!(req.elem_bytes, 4);
    }
}
