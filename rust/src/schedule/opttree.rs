//! Karp et al.'s optimal broadcast tree in the LogP model.
//!
//! In LogP (latency `L`, per-endpoint overhead `o`, per-port gap `g`), a
//! single-item broadcast is optimal iff every processor forwards as soon
//! and as often as it can: the greedy construction repeatedly attaches
//! the next receiver to whichever already-informed node can complete a
//! send *earliest* ("Optimal broadcast and summation in the LogP model",
//! Karp, Sahay, Santos, Schauser 1993). A node that became informed at
//! time `t` can have its `i`-th child (0-indexed) fully informed at
//!
//! ```text
//! t + max(o, g)·i + L + 2o
//! ```
//!
//! The construction here is the O(p log p) incremental-frontier version:
//! a min-heap of candidate `(completion, sender)` pairs with lazy
//! deletion — attaching a child only invalidates that sender's own stale
//! entries, which are skipped when popped. Ties break on the lower node
//! index, so the tree is fully deterministic for a given `(p, params)`
//! and therefore bit-identical across every execution backend.
//!
//! Besides the time labels, the tree carries a *round mapping* for the
//! repo's one-ported round-synchronous machine: node `w`, attached as
//! the `i`-th child of `v`, receives in round `send_start(v) + i` and
//! starts sending in the next round (`send_start(root) = 0`). Each node
//! sends at most once and receives exactly once per round by
//! construction, so the mapped schedule passes the lockstep simulator's
//! machine-model enforcement unchanged; replaying the mapped trace
//! through a [`crate::sim::LogPClock`] reproduces the greedy labels
//! exactly (the cross-validation pinned in `tests/costmodel.rs`).
//!
//! [`crate::comm::Algo::OptTree`] runs this tree as a broadcast (root →
//! leaves) and, reversed round-by-round, as a reduction (leaves → root,
//! ⊕-combining at each parent) — see
//! `collectives::baselines::{OptTreeBcastProc, OptTreeReduceProc}`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::cost::LogPParams;

/// Min-heap candidate: the earliest completion of `node`'s next send.
/// Ordered *reversed* on (time, node) so `BinaryHeap` pops the minimum;
/// the node index tie-break keeps the construction deterministic.
#[derive(PartialEq)]
struct Cand {
    time: f64,
    node: usize,
}

impl Eq for Cand {}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.node.cmp(&self.node))
    }
}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The greedy LogP-optimal broadcast tree over `p` *relative* nodes
/// (node 0 = root; callers map node ↔ rank, typically
/// `rank = (root + node) % p`).
#[derive(Debug, Clone)]
pub struct OptTree {
    p: usize,
    params: LogPParams,
    /// Parent node of each node (`parent[0] == 0`).
    parent: Vec<usize>,
    /// Children of each node, in attach (= send) order.
    children: Vec<Vec<usize>>,
    /// First round each node sends in (`send_start[0] == 0`; otherwise
    /// `recv_round + 1`).
    send_start: Vec<usize>,
    /// Round each non-root node receives in (`recv_round[0] == 0`,
    /// unused — the root never receives).
    recv_round: Vec<usize>,
    /// Greedy time label: when each node is fully informed.
    labels: Vec<f64>,
    rounds: usize,
    completion: f64,
}

impl OptTree {
    /// Build the optimal tree for `p` nodes under `params` in
    /// O(p log p). For multi-packet payloads pass
    /// [`LogPParams::scaled_for`] the message size — the greedy run on
    /// the scaled single-packet machine is the optimal tree for that
    /// payload.
    pub fn build(p: usize, params: &LogPParams) -> OptTree {
        assert!(p > 0);
        let mut tree = OptTree {
            p,
            params: *params,
            parent: vec![0; p],
            children: vec![Vec::new(); p],
            send_start: vec![0; p],
            recv_round: vec![0; p],
            labels: vec![0.0; p],
            rounds: 0,
            completion: 0.0,
        };
        if p == 1 {
            return tree;
        }
        let spacing = params.g.max(params.o);
        let hop = params.l + 2.0 * params.o;
        // next_send(v) = label(v) + spacing·|children(v)| + hop.
        let mut heap = BinaryHeap::with_capacity(2 * p);
        heap.push(Cand { time: hop, node: 0 });
        let mut created = 1usize;
        while created < p {
            let Cand { time, node: v } = heap.pop().expect("frontier never runs dry");
            let cur = tree.labels[v] + spacing * tree.children[v].len() as f64 + hop;
            if time < cur {
                continue; // stale: v gained a child since this was pushed
            }
            let w = created;
            created += 1;
            tree.parent[w] = v;
            tree.labels[w] = time;
            tree.recv_round[w] = tree.send_start[v] + tree.children[v].len();
            tree.send_start[w] = tree.recv_round[w] + 1;
            tree.children[v].push(w);
            tree.rounds = tree.rounds.max(tree.recv_round[w] + 1);
            tree.completion = tree.completion.max(time);
            heap.push(Cand { time: time + hop, node: w });
            heap.push(Cand {
                time: tree.labels[v] + spacing * tree.children[v].len() as f64 + hop,
                node: v,
            });
        }
        tree
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// The machine parameters the tree was built for.
    #[inline]
    pub fn params(&self) -> &LogPParams {
        &self.params
    }

    /// Rounds of the one-ported round mapping (0 for `p == 1`).
    #[inline]
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Predicted LogP completion time of the broadcast, seconds: the
    /// max greedy label (when the last node is fully informed).
    #[inline]
    pub fn completion(&self) -> f64 {
        self.completion
    }

    /// Parent node of `node` (the root is its own parent).
    #[inline]
    pub fn parent(&self, node: usize) -> usize {
        self.parent[node]
    }

    /// Round the non-root `node` receives in (broadcast direction).
    #[inline]
    pub fn recv_round(&self, node: usize) -> usize {
        self.recv_round[node]
    }

    /// Greedy time label of `node` (when it is fully informed).
    #[inline]
    pub fn label(&self, node: usize) -> f64 {
        self.labels[node]
    }

    /// Broadcast: the child `node` sends to in `round`, if any.
    #[inline]
    pub fn bcast_send(&self, node: usize, round: usize) -> Option<usize> {
        let i = round.checked_sub(self.send_start[node])?;
        self.children[node].get(i).copied()
    }

    /// Broadcast: the parent `node` receives from in `round`, if any.
    #[inline]
    pub fn bcast_recv(&self, node: usize, round: usize) -> Option<usize> {
        (node != 0 && self.recv_round[node] == round).then_some(self.parent[node])
    }

    /// Reduction (the broadcast reversed round-by-round): the parent
    /// `node` sends its partial to in `round`, if any.
    #[inline]
    pub fn reduce_send(&self, node: usize, round: usize) -> Option<usize> {
        (node != 0 && self.rounds - 1 - self.recv_round[node] == round)
            .then_some(self.parent[node])
    }

    /// Reduction: the child `node` ⊕-combines from in `round`, if any.
    #[inline]
    pub fn reduce_recv(&self, node: usize, round: usize) -> Option<usize> {
        let i = (self.rounds - 1 - round).checked_sub(self.send_start[node])?;
        self.children[node].get(i).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_tree_is_empty() {
        let t = OptTree::build(1, &LogPParams::default());
        assert_eq!(t.rounds(), 0);
        assert_eq!(t.completion(), 0.0);
    }

    #[test]
    fn two_nodes_cost_one_hop() {
        let t = OptTree::build(2, &LogPParams::new(1.0, 0.25, 0.125));
        assert_eq!(t.rounds(), 1);
        assert_eq!(t.parent(1), 0);
        assert_eq!(t.recv_round(1), 0);
        assert!((t.completion() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn overhead_dominated_machine_grows_a_star() {
        // L + 2o = 1.5 ≫ spacing 0.25: the root informs all three
        // children itself before the first child could forward anything.
        let t = OptTree::build(4, &LogPParams::new(1.0, 0.25, 0.125));
        assert_eq!(t.parent(1), 0);
        assert_eq!(t.parent(2), 0);
        assert_eq!(t.parent(3), 0);
        assert_eq!(t.rounds(), 3);
        // Third child: 2 spacings + one hop.
        assert!((t.completion() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gap_dominated_machine_grows_deep() {
        // spacing = g = 2.0 > hop 1.5: re-sending from the root is
        // slower than forwarding, so the tree must chain.
        let t = OptTree::build(4, &LogPParams::new(1.0, 0.25, 2.0));
        assert_eq!(t.parent(1), 0);
        assert_eq!(t.parent(2), 1, "second node forwards before root resends");
        assert_eq!(t.parent(3), 0);
        assert!((t.completion() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn deterministic_and_mapped_rounds_are_one_ported() {
        for p in [2usize, 3, 7, 16, 33, 100] {
            let params = LogPParams::default();
            let a = OptTree::build(p, &params);
            let b = OptTree::build(p, &params);
            assert_eq!(a.parent, b.parent, "p={p}");
            assert_eq!(a.recv_round, b.recv_round, "p={p}");
            // Round mapping: every non-root receives exactly once; per
            // round each node sends ≤ 1 and receives ≤ 1, and a node
            // only sends after its receive round.
            for w in 1..p {
                assert!(a.recv_round(w) < a.rounds(), "p={p} node {w}");
                assert!(a.send_start[w] > a.recv_round[w]);
            }
            for round in 0..a.rounds() {
                let mut sending = vec![false; p];
                let mut receiving = vec![false; p];
                for v in 0..p {
                    if let Some(w) = a.bcast_send(v, round) {
                        assert!(!sending[v], "p={p} round {round}: double send");
                        sending[v] = true;
                        assert!(!receiving[w], "p={p} round {round}: port busy");
                        receiving[w] = true;
                        assert_eq!(a.bcast_recv(w, round), Some(v));
                    }
                }
            }
        }
    }

    #[test]
    fn labels_respect_the_greedy_recurrence() {
        let params = LogPParams::new(1.0, 0.25, 0.125);
        let spacing = params.g.max(params.o);
        let hop = params.l + 2.0 * params.o;
        let t = OptTree::build(37, &params);
        for w in 1..37 {
            let v = t.parent(w);
            // w's label is its parent's label plus the child-index
            // spacing plus one hop.
            let i = t.recv_round(w) - t.send_start[v];
            let want = t.label(v) + spacing * i as f64 + hop;
            assert!((t.label(w) - want).abs() < 1e-12, "node {w}");
            // Completion is the max label.
            assert!(t.label(w) <= t.completion() + 1e-12);
        }
    }

    #[test]
    fn reduce_mapping_reverses_the_broadcast() {
        let t = OptTree::build(19, &LogPParams::default());
        let r = t.rounds();
        for w in 1..19 {
            let round = r - 1 - t.recv_round(w);
            assert_eq!(t.reduce_send(w, round), Some(t.parent(w)));
            assert_eq!(t.reduce_recv(t.parent(w), round), Some(w));
            // A node's children all arrive strictly before it sends up.
            for &c in &t.children[w] {
                assert!(
                    r - 1 - t.recv_round(c) < round,
                    "child {c} must arrive before {w} sends"
                );
            }
        }
    }
}
