//! Baseline ("old-style") schedule computations, modelling the complexity
//! class of the prior algorithms the paper improves on ([13, 14, 17]).
//!
//! * [`send_schedule_from_recv`] — the "straightforward computation of
//!   send schedules from the receive schedules" the paper describes at the
//!   start of §2.3: `sendblock[k]_r = recvblock[k]_{t_r^k}` via `q`
//!   receive-schedule computations, i.e. `O(log² p)` per processor.
//! * [`recv_schedule_oldstyle`] — a receive-schedule computation with no
//!   incremental state reuse: round `k`'s entry is obtained by re-running
//!   the greedy search from scratch, `O(log² p)` per processor; paired
//!   with [`send_schedule_from_recv`] the per-processor cost is
//!   `O(log³ p)`, the bound of [13, 14].
//!
//! Both produce **identical schedules** to the `O(log p)` algorithms (the
//! paper emphasises the new algorithms compute the *same* schedules); the
//! test suite checks equality, and the Table 4 bench contrasts runtimes.

use super::recv::{recv_schedule, RecvSchedule};
use super::send::SendSchedule;
use super::skips::Skips;

/// Old-style send schedule: `q` receive-schedule computations, one per
/// to-processor. `O(log² p)` (with the fast receive schedule) — the
/// comparison point of §2.3.
pub fn send_schedule_from_recv(sk: &Skips, r: usize) -> SendSchedule {
    let q = sk.q();
    if q == 0 {
        return SendSchedule { blocks: Vec::new(), baseblock: 0, violations: 0 };
    }
    let mut blocks = vec![0i64; q];
    for (k, v) in blocks.iter_mut().enumerate() {
        let t = sk.to_proc(r, k);
        *v = recv_schedule(sk, t).blocks[k];
    }
    let baseblock = super::baseblock::baseblock(sk, r);
    SendSchedule { blocks, baseblock, violations: 0 }
}

/// Old-style receive schedule: recompute the full search for every round
/// prefix instead of reusing the linked-list state — `O(log² p)` per
/// processor, returning the identical schedule.
pub fn recv_schedule_oldstyle(sk: &Skips, r: usize) -> RecvSchedule {
    let q = sk.q();
    if q == 0 {
        return recv_schedule(sk, r);
    }
    // One full search per round: take entry k of the k-th recomputation.
    // (Models the prior work's repeated per-round searches; the constant
    // is q full searches rather than one.)
    let mut blocks = vec![0i64; q];
    let mut baseblock = 0usize;
    let mut stats = super::recv::SearchStats::default();
    for (k, v) in blocks.iter_mut().enumerate() {
        let s = recv_schedule(sk, r);
        stats.recursions += s.stats.recursions;
        stats.scans += s.stats.scans;
        *v = s.blocks[k];
        baseblock = s.baseblock;
    }
    RecvSchedule { blocks, baseblock, stats }
}

/// Old-style combined schedule computation for one processor: old-style
/// receive plus send-from-recv where each of the `q` neighbour receive
/// schedules is also computed old-style — `O(log³ p)` per processor, the
/// complexity of [13, 14]. Used by the Table 4 benchmark.
pub fn schedules_oldstyle(sk: &Skips, r: usize) -> (RecvSchedule, SendSchedule) {
    let recv = recv_schedule_oldstyle(sk, r);
    let q = sk.q();
    let mut blocks = vec![0i64; q];
    for (k, v) in blocks.iter_mut().enumerate() {
        let t = sk.to_proc(r, k);
        *v = recv_schedule_oldstyle(sk, t).blocks[k];
    }
    let baseblock = recv.baseblock;
    (recv, SendSchedule { blocks, baseblock, violations: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::send::send_schedule;

    #[test]
    fn send_from_recv_matches_fast() {
        for p in 2..400 {
            let sk = Skips::new(p);
            for r in 0..p {
                let fast = send_schedule(&sk, r);
                let slow = send_schedule_from_recv(&sk, r);
                assert_eq!(fast.blocks, slow.blocks, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn oldstyle_recv_matches_fast() {
        for p in 2..400 {
            let sk = Skips::new(p);
            for r in 0..p {
                let fast = recv_schedule(&sk, r);
                let slow = recv_schedule_oldstyle(&sk, r);
                assert_eq!(fast.blocks, slow.blocks, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn oldstyle_combined_matches_fast() {
        for p in [17usize, 100, 255, 256, 257] {
            let sk = Skips::new(p);
            for r in 0..p {
                let (recv, send) = schedules_oldstyle(&sk, r);
                assert_eq!(recv.blocks, recv_schedule(&sk, r).blocks);
                assert_eq!(send.blocks, send_schedule(&sk, r).blocks, "p={p} r={r}");
            }
        }
    }
}
