//! Send schedule computation in `O(log p)` time (Algorithm 6, Theorem 3).
//!
//! The send schedule is defined by `sendblock[k]_r = recvblock[k]_{t_r^k}`
//! (Correctness Conditions 1+2): what `r` sends in round `k` is exactly
//! what its to-processor `t_r^k = (r + skip[k]) mod p` is scheduled to
//! receive. Computing it that way costs `O(log^2 p)` (q receive schedules,
//! kept as [`crate::schedule::baseline::send_schedule_from_recv`]).
//!
//! Algorithm 6 instead walks rounds `k = q-1` down to `1` maintaining a
//! virtual processor index `r'` and a range bound `e` (invariant
//! `r' < e`), emitting for all but O(1) *violation* rounds a predetermined
//! block: lower-part processors (`r' < skip[k]`) resend the block `c` they
//! sent in round `k+1`, upper-part processors send `c = k - q` following
//! the power-of-two doubling structure (Observation 6). Violations fall
//! back to one receive-schedule computation for the to-processor; Theorem 3
//! bounds them by **4 per processor**, preserving `O(log p)` total.

use super::baseblock::{baseblock, LANES};
use super::recv::{recv_schedule_core, MAX_Q};
use super::skips::Skips;

/// A computed send schedule for one processor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SendSchedule {
    /// `sendblock[k]` for rounds `k = 0..q` (relative block indices; in
    /// phase `j` of Algorithm 1 the block sent in round `k` is
    /// `sendblock[k] + j*q`).
    pub blocks: Vec<i64>,
    /// The baseblock `b_r` of this processor.
    pub baseblock: usize,
    /// Number of violation rounds resolved via a receive-schedule
    /// computation (Theorem 3: at most 4).
    pub violations: usize,
}

/// Allocation-free core of Algorithm 6: fill `out[0..q]` with the send
/// schedule of `r` whose baseblock is `b` (pass `baseblock(sk, r)`);
/// returns the violation count. The per-rank hot path.
pub(crate) fn send_schedule_core(
    sk: &Skips,
    r: usize,
    b: usize,
    out: &mut [i64; MAX_Q],
) -> usize {
    send_schedule_core_with(sk, r, b, out, &mut |sk, t, k| {
        let mut buf = [0i64; MAX_Q];
        recv_schedule_core(sk, t, &mut buf);
        buf[k]
    })
}

/// [`send_schedule_core`] with a pluggable violation resolver: `recv_of`
/// must return `recvblock[k]` of processor `t` (a fresh `ALLBLOCKS`
/// search in the default resolver above). Theorem 3 bounds violations by
/// 4 per processor, and neighbouring ranks' violations often target the
/// *same* to-processor, so an all-ranks builder
/// ([`crate::schedule::table::ScheduleTable`]) passes a small memo here
/// and eliminates nearly all redundant searches.
pub(crate) fn send_schedule_core_with<F>(
    sk: &Skips,
    r: usize,
    b: usize,
    out: &mut [i64; MAX_Q],
    recv_of: &mut F,
) -> usize
where
    F: FnMut(&Skips, usize, usize) -> i64,
{
    debug_assert!(r < sk.p());
    let q = sk.q();
    let p = sk.p();
    if q == 0 {
        return 0;
    }
    let sb = &mut out[..q];
    if r == 0 {
        // The root greedily sends blocks 0, 1, ..., q-1.
        for (k, v) in sb.iter_mut().enumerate() {
            *v = k as i64;
        }
        return 0;
    }

    let mut rp = r; // virtual processor index r'
    let mut c = b as i64; // block the lower part keeps resending
    let mut e = p; // exclusive upper bound on r' (invariant r' < e)
    let mut violations = 0usize;

    for k in (1..q).rev() {
        if rp < sk.skip(k) {
            // ------ lower part ------
            if rp + sk.skip(k) < e || e < sk.skip(k - 1) || (k == 1 && b > 0) {
                sb[k] = c;
            } else {
                // Violation: the to-processor's missing block is not
                // predictable here; ask its receive schedule.
                violations += 1;
                let t = to_proc(p, r, sk.skip(k));
                sb[k] = recv_of(sk, t, k);
            }
            if e > sk.skip(k) {
                e = sk.skip(k);
            }
        } else {
            // ------ upper part (r' >= skip[k]) ------
            c = k as i64 - q as i64;
            if k == 1 || rp > sk.skip(k) || e - sk.skip(k) < sk.skip(k - 1) {
                sb[k] = c;
            } else if rp + sk.skip(k) > e {
                // Violation: only possible for r' == skip[k].
                violations += 1;
                let t = to_proc(p, r, sk.skip(k));
                sb[k] = recv_of(sk, t, k);
            } else {
                sb[k] = c;
            }
            rp -= sk.skip(k);
            e -= sk.skip(k);
        }
    }
    sb[0] = b as i64 - q as i64;
    violations
}

/// Branchless lane variant of the Algorithm-6 walk: the send rows of
/// [`LANES`] ranks at once, staged round-major (`stage[k][lane]`) so
/// each descent step is one straight-line pass over the lanes —
/// selects instead of branches, the shape the autovectorizer chews on.
///
/// `r` and `b` hold each lane's rank and baseblock
/// ([`super::baseblock::baseblock_lanes`]). Returns one violation
/// bitmask per lane (bit `k` set ⇔ the scalar walk would have taken
/// the round-`k` violation branch); the **caller** resolves those
/// entries through a receive-schedule lookup, exactly as the scalar
/// core's `recv_of` callback does — a violation only substitutes the
/// emitted entry, never the `r'`/`c`/`e` recursion, so the post-hoc
/// overwrite is exact. Two caveats the caller owns: a lane carrying
/// the **root** (`r = 0`) runs the non-root recursion and produces
/// garbage — overwrite its row with the scalar `0..q-1` and ignore its
/// mask; and `q = 0` (p = 1) must not reach this kernel.
pub(crate) fn send_lanes(
    sk: &Skips,
    r: &[i64; LANES],
    b: &[i64; LANES],
    stage: &mut [[i64; LANES]; MAX_Q],
) -> [u64; LANES] {
    let q = sk.q();
    let p = sk.p() as i64;
    debug_assert!(q >= 1);
    let mut rp = *r; // virtual processor index r'
    let mut c = *b; // block the lower part keeps resending
    let mut e = [p; LANES]; // exclusive upper bound on r'
    let mut viol = [0u64; LANES];
    for k in (1..q).rev() {
        let s_k = sk.skip(k) as i64;
        let s_km1 = sk.skip(k - 1) as i64;
        let kq = k as i64 - q as i64;
        let k1 = k == 1;
        let row = &mut stage[k];
        for i in 0..LANES {
            let lower = rp[i] < s_k;
            // The no-violation predicates of the two scalar branches.
            // Both sides are evaluated lane-wide; `lower` selects. The
            // upper-part `e - s_k` is dead for lower lanes but cannot
            // trap in i64.
            let lo_ok = rp[i] + s_k < e[i] || e[i] < s_km1 || (k1 && b[i] > 0);
            let up_ok = k1 || rp[i] > s_k || e[i] - s_k < s_km1 || rp[i] + s_k <= e[i];
            let ok = if lower { lo_ok } else { up_ok };
            let cv = if lower { c[i] } else { kq };
            row[i] = cv;
            c[i] = cv;
            viol[i] |= u64::from(!ok) << k;
            e[i] = if lower { e[i].min(s_k) } else { e[i] - s_k };
            rp[i] = if lower { rp[i] } else { rp[i] - s_k };
        }
    }
    for i in 0..LANES {
        stage[0][i] = b[i] - q as i64;
    }
    viol
}

/// Compute only the `sendblock` entries (no instrumentation wrapper) into
/// a caller-provided buffer; returns the violation count. The allocation-
/// free companion of [`crate::schedule::recv::recv_schedule_into`], used
/// by the sparse simulation engine's flat schedule arena.
///
/// `b` is the processor's baseblock as returned by `recv_schedule_into`
/// (the root's conventional `b = q` is substituted internally).
pub fn send_schedule_into(sk: &Skips, r: usize, b: usize, out: &mut [i64]) -> usize {
    let q = sk.q();
    let b = if r == 0 { q } else { b };
    let mut buf = [0i64; MAX_Q];
    let violations = send_schedule_core(sk, r, b, &mut buf);
    out[..q].copy_from_slice(&buf[..q]);
    violations
}

/// Algorithm 6: compute the send schedule for processor `r` in `O(log p)`.
pub fn send_schedule(sk: &Skips, r: usize) -> SendSchedule {
    let q = sk.q();
    let b = if r == 0 { q } else { baseblock(sk, r) };
    let mut buf = [0i64; MAX_Q];
    let violations = send_schedule_core(sk, r, b, &mut buf);
    SendSchedule { blocks: buf[..q].to_vec(), baseblock: b, violations }
}

#[inline]
fn to_proc(p: usize, r: usize, skip: usize) -> usize {
    let t = r + skip;
    if t >= p {
        t - p
    } else {
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::recv::recv_schedule;

    fn send_row(p: usize, k: usize) -> Vec<i64> {
        let sk = Skips::new(p);
        (0..p).map(|r| send_schedule(&sk, r).blocks[k]).collect()
    }

    #[test]
    fn paper_table1_send_p17() {
        assert_eq!(
            send_row(17, 0),
            vec![0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4]
        );
        assert_eq!(
            send_row(17, 1),
            vec![1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5, -4]
        );
        assert_eq!(
            send_row(17, 2),
            vec![2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2, -2, -2]
        );
        assert_eq!(
            send_row(17, 3),
            vec![3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1, -3, -3, -2, -2]
        );
        assert_eq!(
            send_row(17, 4),
            vec![4, 0, 1, 2, 0, 3, 0, 1, -3, -1, -1, -1, -1, -1, -1, -1, -1]
        );
    }

    #[test]
    fn paper_table2_send_p9() {
        assert_eq!(send_row(9, 0), vec![0, -4, -3, -2, -4, -1, -4, -3, -2]);
        assert_eq!(send_row(9, 1), vec![1, -4, -3, -2, -2, -1, -4, -3, -2]);
        assert_eq!(send_row(9, 2), vec![2, 0, -3, -3, -2, -1, -1, -3, -2]);
        assert_eq!(send_row(9, 3), vec![3, 0, 1, 2, -4, -1, -1, -1, -1]);
    }

    #[test]
    fn paper_table3_send_p18() {
        assert_eq!(
            send_row(18, 0),
            vec![0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4, -3]
        );
        assert_eq!(
            send_row(18, 1),
            vec![1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5, -4, -3]
        );
        assert_eq!(
            send_row(18, 2),
            vec![2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2, -2, -4, -3]
        );
        assert_eq!(
            send_row(18, 3),
            vec![3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1, -5, -2, -2, -2, -2]
        );
        assert_eq!(
            send_row(18, 4),
            vec![4, 0, 1, 2, 0, 3, 0, 1, 2, -1, -1, -1, -1, -1, -1, -1, -1, -1]
        );
    }

    #[test]
    fn send_equals_recv_of_to_processor() {
        // Correctness Conditions 1+2: sendblock[k]_r == recvblock[k]_{t_r^k}.
        for p in 2..600 {
            let sk = Skips::new(p);
            let recvs: Vec<_> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
            for r in 0..p {
                let s = send_schedule(&sk, r);
                for k in 0..sk.q() {
                    let t = sk.to_proc(r, k);
                    assert_eq!(
                        s.blocks[k], recvs[t].blocks[k],
                        "p={p} r={r} k={k} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn theorem3_violation_bound_small() {
        for p in 2..2000 {
            let sk = Skips::new(p);
            for r in 0..p {
                let s = send_schedule(&sk, r);
                assert!(s.violations <= 4, "p={p} r={r} violations={}", s.violations);
            }
        }
    }

    #[test]
    fn paper_examples_of_violations() {
        // Paper (end of §2.3): violations for p=17 occur e.g. for r=3
        // (round k=2) and r=8.
        let sk = Skips::new(17);
        assert!(send_schedule(&sk, 3).violations >= 1);
        assert!(send_schedule(&sk, 8).violations >= 1);
    }

    #[test]
    fn root_sends_consecutive() {
        for p in 2..200 {
            let sk = Skips::new(p);
            let s = send_schedule(&sk, 0);
            let want: Vec<i64> = (0..sk.q() as i64).collect();
            assert_eq!(s.blocks, want, "p={p}");
        }
    }

    #[test]
    fn sendblock0_is_baseblock_minus_q() {
        // Correctness Condition 4 corollary: sendblock[0]_r = b_r - q.
        for p in 2..500 {
            let sk = Skips::new(p);
            for r in 1..p {
                let s = send_schedule(&sk, r);
                assert_eq!(s.blocks[0], s.baseblock as i64 - sk.q() as i64);
            }
        }
    }

    #[test]
    fn lane_walk_matches_scalar_walk() {
        // Lane groups of consecutive ranks: after resolving the masked
        // violation entries the staged rows must equal the scalar core's
        // rows entry for entry, and each lane's popcount must equal the
        // scalar violation count (the masks name the same rounds).
        use crate::schedule::baseblock::baseblock_lanes;
        for p in [2usize, 3, 9, 17, 18, 100, 257, 1000] {
            let sk = Skips::new(p);
            let q = sk.q();
            let mut stage = [[0i64; LANES]; MAX_Q];
            let mut r = 0usize;
            while r < p {
                let mut rv = [0i64; LANES];
                for (i, v) in rv.iter_mut().enumerate() {
                    *v = ((r + i).min(p - 1)) as i64;
                }
                let bb = baseblock_lanes(&sk, &rv);
                let viol = send_lanes(&sk, &rv, &bb, &mut stage);
                for i in 0..LANES {
                    let rel = rv[i] as usize;
                    if rel == 0 {
                        continue; // the root lane's output is discarded by contract
                    }
                    let want = send_schedule(&sk, rel);
                    assert_eq!(
                        viol[i].count_ones() as usize, want.violations,
                        "p={p} r={rel}: violation mask"
                    );
                    let mut got: Vec<i64> = (0..q).map(|k| stage[k][i]).collect();
                    let mut vm = viol[i];
                    while vm != 0 {
                        let k = vm.trailing_zeros() as usize;
                        vm &= vm - 1;
                        let t = sk.to_proc(rel, k);
                        let mut buf = [0i64; MAX_Q];
                        recv_schedule_core(&sk, t, &mut buf);
                        got[k] = buf[k];
                    }
                    assert_eq!(got, want.blocks, "p={p} r={rel}");
                }
                r += LANES;
            }
        }
    }

    #[test]
    fn p2_send() {
        let sk = Skips::new(2);
        assert_eq!(send_schedule(&sk, 0).blocks, vec![0]);
        assert_eq!(send_schedule(&sk, 1).blocks, vec![-1]);
    }
}
