//! Round-optimal broadcast schedules on circulant graphs — the paper's
//! core contribution (Section 2).
//!
//! A `p`-processor system with `q = ceil(log2 p)` communicates over a
//! directed, `q`-regular circulant graph whose skips are computed by
//! repeated halving ([`skips::Skips`], Algorithm 2). Per processor, a
//! *receive schedule* ([`recv::recv_schedule`], Algorithms 4+5) and a
//! *send schedule* ([`send::send_schedule`], Algorithm 6) of `q` entries
//! each determine in O(1) per round which block is received and which is
//! sent — computed independently per processor in **O(log p)** time and
//! space (Theorems 2 and 3), with no communication.
//!
//! [`baseline`] holds the old-style `O(log² p)`–`O(log³ p)` computations
//! (identical schedules, slower — the Table 4 comparison), [`doubling`]
//! the Observation 2/6 constructions used as independent correctness
//! oracles, [`verify`] the exhaustive four-condition checker (Appendix B),
//! [`table`] the all-ranks schedule plane (one flat `i8` arena per `p`,
//! filled in parallel over rank chunks), [`cache`] the
//! communicator-style schedule cache (one shared table per `p`), and
//! [`opttree`] the greedy LogP-optimal broadcast tree of Karp et al. —
//! the cost plane's baseline schedule family (`Algo::OptTree`).

pub mod baseblock;
pub mod baseline;
pub mod cache;
pub mod doubling;
pub mod opttree;
pub mod recv;
pub mod send;
pub mod skips;
pub mod table;
pub mod verify;

pub use baseblock::{all_baseblocks, baseblock, canonical_sequence};
pub use cache::{Schedule, ScheduleCache, DEFAULT_TABLE_CAP_BYTES};
pub use opttree::OptTree;
pub use recv::{recv_schedule, recv_schedule_into, RecvSchedule};
pub use send::{send_schedule, send_schedule_into, SendSchedule};
pub use skips::{ceil_log2, Skips};
pub use table::{configured_build_kernel, configured_threads, BuildKernel, ScheduleTable};
pub use verify::{verify_all, verify_one_ported_trace, verify_sampled, VerifyReport};
