//! Schedule caching, the analogue of caching schedules with communicators
//! in NEC's MPI ([12] in the paper).
//!
//! With the `O(log p)` algorithms caching is no longer *required* for
//! performance (the paper's point), but a real MPI library still reuses a
//! communicator's schedules across repeated collective calls, and the
//! all-broadcast/all-reduction collectives need schedules for **all** `p`
//! roots at once. The cache stores, per `(p, relative rank)`, the combined
//! receive+send schedule; `Arc`-shared and thread-safe.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::recv::{recv_schedule_core, MAX_Q};
use super::send::send_schedule_core;
use super::skips::Skips;

/// Combined per-processor schedule, ready for Algorithm 1 / Algorithm 7.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    /// Number of processors.
    pub p: usize,
    /// `q = ceil(log2 p)`.
    pub q: usize,
    /// Relative rank (`(r - root) mod p` of the calling processor).
    pub rank: usize,
    /// `recvblock[0..q]`.
    pub recv: Vec<i64>,
    /// `sendblock[0..q]`.
    pub send: Vec<i64>,
    /// Baseblock `b_r` (`q` for the root).
    pub baseblock: usize,
}

impl Schedule {
    /// Compute both schedules for relative rank `r` of a `p`-processor
    /// system in `O(log p)` — the per-rank hot path: one baseblock walk,
    /// stack-array cores, exactly two heap allocations (the two result
    /// vectors).
    pub fn compute(sk: &Skips, r: usize) -> Self {
        let q = sk.q();
        let mut rbuf = [0i64; MAX_Q];
        let (bb, _) = recv_schedule_core(sk, r, &mut rbuf);
        let b = if r == 0 { q } else { bb };
        let mut sbuf = [0i64; MAX_Q];
        send_schedule_core(sk, r, b, &mut sbuf);
        Schedule {
            p: sk.p(),
            q,
            rank: r,
            recv: rbuf[..q].to_vec(),
            send: sbuf[..q].to_vec(),
            baseblock: bb,
        }
    }
}

/// Thread-safe cache of [`Schedule`]s keyed by `(p, relative rank)` and of
/// [`Skips`] keyed by `p`.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    skips: Mutex<HashMap<usize, Arc<Skips>>>,
    scheds: Mutex<HashMap<(usize, usize), Arc<Schedule>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScheduleCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The skip table for `p` (cached).
    pub fn skips(&self, p: usize) -> Arc<Skips> {
        let mut g = self.skips.lock().unwrap();
        g.entry(p).or_insert_with(|| Arc::new(Skips::new(p))).clone()
    }

    /// The schedule for relative rank `r` of a `p`-processor system
    /// (cached; computed on miss in `O(log p)`).
    ///
    /// Schedules are *root-relative*: `r` is `(rank - root) mod p`, so one
    /// entry per relative rank serves every root a
    /// [`crate::comm::Communicator`] is asked to broadcast from.
    pub fn get(&self, p: usize, r: usize) -> Arc<Schedule> {
        {
            let g = self.scheds.lock().unwrap();
            if let Some(s) = g.get(&(p, r)) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return s.clone();
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let sk = self.skips(p);
        let s = Arc::new(Schedule::compute(&sk, r));
        self.scheds.lock().unwrap().insert((p, r), s.clone());
        s
    }

    /// `(hits, misses)` counters — the observable that lets callers (and
    /// the repeated-traffic bench / tests) verify schedules are being
    /// *reused* rather than recomputed per call.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cached schedule entries.
    pub fn len(&self) -> usize {
        self.scheds.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached entries (counters are reset too).
    pub fn clear(&self) {
        self.skips.lock().unwrap().clear();
        self.scheds.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_returns_consistent_schedules() {
        let cache = ScheduleCache::new();
        let sk = Skips::new(17);
        for r in 0..17 {
            let cached = cache.get(17, r);
            let direct = Schedule::compute(&sk, r);
            assert_eq!(*cached, direct);
        }
        // Second pass hits.
        for r in 0..17 {
            cache.get(17, r);
        }
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 17);
        assert_eq!(hits, 17);
    }

    #[test]
    fn cache_multiple_p() {
        let cache = ScheduleCache::new();
        for p in [2usize, 9, 17, 64, 100] {
            for r in 0..p {
                let s = cache.get(p, r);
                assert_eq!(s.p, p);
                assert_eq!(s.rank, r);
                assert_eq!(s.recv.len(), s.q);
                assert_eq!(s.send.len(), s.q);
            }
        }
    }

    #[test]
    fn cache_threaded_access() {
        let cache = Arc::new(ScheduleCache::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                for p in [17usize, 100, 1000] {
                    for i in 0..p.min(50) {
                        let r = (i * 7 + t) % p;
                        let s = c.get(p, r);
                        assert_eq!(s.rank, r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
