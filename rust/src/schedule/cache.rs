//! Schedule caching, the analogue of caching schedules with communicators
//! in NEC's MPI ([12] in the paper).
//!
//! With the `O(log p)` algorithms caching is no longer *required* for
//! performance (the paper's point), but a real MPI library still reuses a
//! communicator's schedules across repeated collective calls, and the
//! all-broadcast/all-reduction collectives need schedules for **all** `p`
//! roots at once. The cache therefore stores one parallel-built
//! [`ScheduleTable`] per `p` — the flat all-ranks arena — instead of the
//! historical per-`(p, relative rank)` `HashMap` rows: after the one
//! build, every consumer (any rank, any root, any collective, any
//! backend) reads the shared arena through an `Arc` with no further
//! computation, and a whole-table fetch is one map lookup instead of `p`.
//!
//! **Counter semantics** (the observable the benches/tests pin): building
//! the table for a `p` charges `p` misses — it computed `p` rank
//! schedules — and serving an already-built table charges hits equal to
//! the rank rows served (`p` for a whole-table fetch via [`ScheduleCache::table`],
//! 1 for a single-rank [`ScheduleCache::get`]). This makes the receipts
//! identical to the old per-rank map for the standard traffic patterns
//! (first call at a `p`: `p` misses; every later call: `p` hits).
//!
//! **Memory bound**: tables are admitted by *bytes* (`2·p·q`, the arena
//! size) against a cap — [`DEFAULT_TABLE_CAP_BYTES`] reproduces the old
//! ad-hoc `p ≤ 4096` admission exactly; callers override it per fetch
//! ([`ScheduleCache::table_with_cap`], exposed through
//! `comm::TuningParams::table_cache_max_bytes`). Single-rank [`ScheduleCache::get`]s
//! above the cap fall back to per-rank entries in a small overflow map
//! (the historical behaviour, so legacy per-rank traffic at huge `p`
//! stays cached without admitting a multi-megabyte arena).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::recv::{recv_schedule_core, MAX_Q};
use super::send::send_schedule_core;
use super::skips::Skips;
use super::table::ScheduleTable;

/// Default table-admission cap in arena bytes (`2·p·q`): `2·4096·12`,
/// which admits exactly the tables the old `p ≤ 4096` rule admitted
/// (any `p ≤ 4096` has `q ≤ 12`; any larger `p` overshoots the cap).
pub const DEFAULT_TABLE_CAP_BYTES: usize = 2 * 4096 * 12;

/// Combined per-processor schedule, ready for Algorithm 1 / Algorithm 7.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    /// Number of processors.
    pub p: usize,
    /// `q = ceil(log2 p)`.
    pub q: usize,
    /// Relative rank (`(r - root) mod p` of the calling processor).
    pub rank: usize,
    /// `recvblock[0..q]`.
    pub recv: Vec<i64>,
    /// `sendblock[0..q]`.
    pub send: Vec<i64>,
    /// Baseblock `b_r` (`q` for the root).
    pub baseblock: usize,
}

impl Schedule {
    /// Compute both schedules for relative rank `r` of a `p`-processor
    /// system in `O(log p)` — the per-rank hot path: one baseblock walk,
    /// stack-array cores, exactly two heap allocations (the two result
    /// vectors).
    pub fn compute(sk: &Skips, r: usize) -> Self {
        let q = sk.q();
        let mut rbuf = [0i64; MAX_Q];
        let (bb, _) = recv_schedule_core(sk, r, &mut rbuf);
        let b = if r == 0 { q } else { bb };
        let mut sbuf = [0i64; MAX_Q];
        send_schedule_core(sk, r, b, &mut sbuf);
        Schedule {
            p: sk.p(),
            q,
            rank: r,
            recv: rbuf[..q].to_vec(),
            send: sbuf[..q].to_vec(),
            baseblock: bb,
        }
    }
}

/// Thread-safe cache of all-ranks [`ScheduleTable`]s keyed by `p` (plus
/// [`Skips`] keyed by `p`, and a per-rank overflow map for single-rank
/// requests above the table cap). Reads of a built table are one
/// `RwLock` read-lock + `Arc` clone; the build itself runs the parallel
/// chunked fill.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    skips: Mutex<HashMap<usize, Arc<Skips>>>,
    tables: RwLock<HashMap<usize, Arc<ScheduleTable>>>,
    /// Per-`(p, rank)` entries for `p` whose table exceeds the admission
    /// cap — the historical shape, kept so legacy single-rank traffic at
    /// huge `p` still caches without a multi-megabyte arena resident.
    overflow: Mutex<HashMap<(usize, usize), Arc<Schedule>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScheduleCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The skip table for `p` (cached).
    pub fn skips(&self, p: usize) -> Arc<Skips> {
        let mut g = self.skips.lock().unwrap();
        g.entry(p).or_insert_with(|| Arc::new(Skips::new(p))).clone()
    }

    /// The all-ranks table for `sk.p()` under the default admission cap.
    pub fn table(&self, sk: &Arc<Skips>) -> Arc<ScheduleTable> {
        self.table_with_cap(sk, DEFAULT_TABLE_CAP_BYTES)
    }

    /// The all-ranks table for `sk.p()`: served from the cache when
    /// built (charging `p` hits), else built in parallel (charging `p`
    /// misses) and stored iff its arena (`2·p·q` bytes) fits
    /// `cap_bytes`. Over-cap tables are still *returned* — the caller
    /// (e.g. a `Communicator`) is expected to hold the `Arc` itself so
    /// repeated traffic pays the build exactly once.
    pub fn table_with_cap(&self, sk: &Arc<Skips>, cap_bytes: usize) -> Arc<ScheduleTable> {
        let p = sk.p();
        if let Some(t) = self.tables.read().unwrap().get(&p) {
            self.hits.fetch_add(p as u64, Ordering::Relaxed);
            return t.clone();
        }
        let t = Arc::new(ScheduleTable::build(sk));
        if t.bytes() > cap_bytes {
            // Over-cap tables are never resident, so there is no winner
            // to dedupe against: every build really computed `p` rows.
            self.misses.fetch_add(p as u64, Ordering::Relaxed);
            return t;
        }
        // Charge under the write lock: exactly one concurrent builder
        // wins the race and charges `p` misses; every loser finds the
        // winner's table already resident, discards its own build, and
        // is billed as a serve (`p` hits) — so the hit/miss receipts
        // cannot drift however many threads build the same `p` at once.
        match self.tables.write().unwrap().entry(p) {
            Entry::Vacant(v) => {
                self.misses.fetch_add(p as u64, Ordering::Relaxed);
                v.insert(t.clone());
                t
            }
            Entry::Occupied(o) => {
                self.hits.fetch_add(p as u64, Ordering::Relaxed);
                o.get().clone()
            }
        }
    }

    /// The schedule for relative rank `r` of a `p`-processor system.
    ///
    /// Schedules are *root-relative*: `r` is `(rank - root) mod p`, so one
    /// entry per relative rank serves every root a
    /// [`crate::comm::Communicator`] is asked to broadcast from. Served
    /// from the all-ranks table whenever one is resident (however it was
    /// admitted — a table stored under a caller-raised cap serves `get`s
    /// too); on a full miss, the table is built if it fits the default
    /// cap, else the per-rank overflow map keeps the historical shape.
    /// The hit path is one `RwLock` read plus the O(log p) row
    /// materialisation — no `Skips` lookup.
    pub fn get(&self, p: usize, r: usize) -> Arc<Schedule> {
        if let Some(t) = self.tables.read().unwrap().get(&p) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::new(t.schedule(r));
        }
        if 2 * p * super::skips::ceil_log2(p) <= DEFAULT_TABLE_CAP_BYTES {
            let sk = self.skips(p);
            let t = Arc::new(ScheduleTable::build(&sk));
            // Same race rule as `table_with_cap`: only the builder that
            // wins the insert charges `p` misses; a loser is billed the
            // single table serve it actually got.
            return match self.tables.write().unwrap().entry(p) {
                Entry::Vacant(v) => {
                    self.misses.fetch_add(p as u64, Ordering::Relaxed);
                    let s = Arc::new(t.schedule(r));
                    v.insert(t);
                    s
                }
                Entry::Occupied(o) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    Arc::new(o.get().schedule(r))
                }
            };
        }
        // Above the table cap with no resident table: historical
        // per-(p, rank) caching. The row compute runs under the overflow
        // lock (O(log p), cheap) so racing threads on one `(p, r)` cannot
        // double-charge the miss.
        let sk = self.skips(p);
        match self.overflow.lock().unwrap().entry((p, r)) {
            Entry::Occupied(o) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                o.get().clone()
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                v.insert(Arc::new(Schedule::compute(&sk, r))).clone()
            }
        }
    }

    /// `(hits, misses)` counters — the observable that lets callers (and
    /// the repeated-traffic bench / tests) verify schedules are being
    /// *reused* rather than recomputed per call. See the module docs for
    /// the exact accounting (build = `p` misses; serves = rows served).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cached per-rank schedule rows: `p` per resident table, plus the
    /// overflow entries.
    pub fn len(&self) -> usize {
        let tabled: usize = self.tables.read().unwrap().keys().sum();
        tabled + self.overflow.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached entries (counters are reset too).
    pub fn clear(&self) {
        self.skips.lock().unwrap().clear();
        self.tables.write().unwrap().clear();
        self.overflow.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_returns_consistent_schedules() {
        let cache = ScheduleCache::new();
        let sk = Skips::new(17);
        for r in 0..17 {
            let cached = cache.get(17, r);
            let direct = Schedule::compute(&sk, r);
            assert_eq!(*cached, direct);
        }
        // First get built the whole 17-rank table (17 misses); the other
        // 16 gets of pass one and all 17 of pass two are table serves.
        for r in 0..17 {
            cache.get(17, r);
        }
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 17, "one build charging p misses");
        assert_eq!(hits, 16 + 17, "every later get is a table serve");
    }

    #[test]
    fn whole_table_fetch_counts_p_rows() {
        let cache = ScheduleCache::new();
        let sk = cache.skips(17);
        let t1 = cache.table(&sk);
        assert_eq!(t1.p(), 17);
        let (h, m) = cache.stats();
        assert_eq!((h, m), (0, 17), "build charges p misses, no hits");
        let t2 = cache.table(&sk);
        assert!(Arc::ptr_eq(&t1, &t2), "second fetch is the same arena");
        let (h, m) = cache.stats();
        assert_eq!((h, m), (17, 17), "second fetch charges p hits");
    }

    #[test]
    fn table_and_get_serve_identical_rows() {
        let cache = ScheduleCache::new();
        for p in [2usize, 9, 17, 64, 100] {
            let sk = cache.skips(p);
            let t = cache.table(&sk);
            for r in 0..p {
                let s = cache.get(p, r);
                assert_eq!(*s, t.schedule(r), "p={p} r={r}");
                assert_eq!(s.p, p);
                assert_eq!(s.rank, r);
                assert_eq!(s.recv.len(), s.q);
                assert_eq!(s.send.len(), s.q);
            }
        }
    }

    #[test]
    fn over_cap_tables_are_not_resident() {
        // p = 8192 (q = 13): 2pq = 212992 bytes > the default cap. The
        // table is returned but not stored; single-rank gets use the
        // overflow map with the historical 1-miss/1-hit accounting.
        let cache = ScheduleCache::new();
        let sk = cache.skips(8192);
        assert!(ScheduleTable::bytes_for(&sk) > DEFAULT_TABLE_CAP_BYTES);
        let t = cache.table(&sk);
        assert_eq!(t.p(), 8192);
        assert!(cache.tables.read().unwrap().is_empty());
        let (h0, m0) = cache.stats();
        assert_eq!((h0, m0), (0, 8192));
        cache.get(8192, 7);
        cache.get(8192, 7);
        let (h1, m1) = cache.stats();
        assert_eq!(m1 - m0, 1, "overflow miss per new rank");
        assert_eq!(h1 - h0, 1, "overflow hit on repeat");
        // A p under the cap still gets a resident table.
        let sk2 = cache.skips(4096);
        cache.table(&sk2);
        assert_eq!(cache.tables.read().unwrap().len(), 1);
    }

    #[test]
    fn custom_cap_controls_admission() {
        let cache = ScheduleCache::new();
        let sk = cache.skips(1024); // 2pq = 20480 bytes
        let t = cache.table_with_cap(&sk, 1024);
        assert_eq!(t.p(), 1024);
        assert!(cache.tables.read().unwrap().is_empty(), "declined by the tight cap");
        let t2 = cache.table_with_cap(&sk, usize::MAX);
        assert_eq!(t2.p(), 1024);
        assert_eq!(cache.tables.read().unwrap().len(), 1);
    }

    #[test]
    fn get_serves_resident_table_above_default_cap() {
        // A table admitted under a caller-raised cap (e.g. a communicator
        // with a larger TuningParams::table_cache_max_bytes) serves
        // single-rank gets too — no overflow recompute, 1 hit per serve.
        let cache = ScheduleCache::new();
        let sk = cache.skips(8192);
        let t = cache.table_with_cap(&sk, usize::MAX);
        assert_eq!(cache.tables.read().unwrap().len(), 1);
        let (h0, _) = cache.stats();
        let s = cache.get(8192, 31);
        assert_eq!(*s, t.schedule(31));
        let (h1, m1) = cache.stats();
        assert_eq!(h1 - h0, 1, "table-served get is a single hit");
        assert_eq!(m1, 8192, "no overflow miss for a resident table");
        assert!(cache.overflow.lock().unwrap().is_empty());
    }

    #[test]
    fn racing_table_builds_charge_one_miss_set() {
        // Regression: the pre-fix `table_with_cap` charged `p` misses
        // per *builder* — N threads racing the first build of a `p`
        // inflated the miss counter N-fold while storing one table.
        // Post-fix the receipts are deterministic under any
        // interleaving: one winner charges p misses, and each of the
        // N−1 others — whether it loses the insert race or arrives
        // after the winner's insert — is billed as a p-hit serve.
        use std::sync::Barrier;
        let cache = ScheduleCache::new();
        let sk = cache.skips(17);
        let n = 8usize;
        let barrier = Barrier::new(n);
        std::thread::scope(|s| {
            for _ in 0..n {
                s.spawn(|| {
                    barrier.wait();
                    assert_eq!(cache.table(&sk).p(), 17);
                });
            }
        });
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 17, "exactly one concurrent build may charge p misses");
        assert_eq!(hits, (n as u64 - 1) * 17, "every losing builder is billed as a serve");
        assert_eq!(cache.tables.read().unwrap().len(), 1);
    }

    #[test]
    fn racing_gets_build_once() {
        // The same race through `get`'s build path: one winner charges
        // the `p` misses; each loser gets a 1-hit table serve.
        use std::sync::Barrier;
        let cache = ScheduleCache::new();
        let n = 8usize;
        let barrier = Barrier::new(n);
        std::thread::scope(|s| {
            for t in 0..n {
                let cache = &cache;
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    assert_eq!(cache.get(17, t % 17).rank, t % 17);
                });
            }
        });
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 17, "one build, p misses, no double charge");
        assert_eq!(hits, n as u64 - 1, "losers are single table serves");
    }

    #[test]
    fn cache_threaded_access() {
        let cache = Arc::new(ScheduleCache::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                for p in [17usize, 100, 1000] {
                    for i in 0..p.min(50) {
                        let r = (i * 7 + t) % p;
                        let s = c.get(p, r);
                        assert_eq!(s.rank, r);
                    }
                    let sk = c.skips(p);
                    assert_eq!(c.table(&sk).p(), p);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn clear_resets_everything() {
        let cache = ScheduleCache::new();
        cache.get(17, 3);
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats(), (0, 0));
    }
}
