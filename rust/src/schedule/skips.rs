//! Circulant-graph skips (Algorithm 2 of the paper) and their structural
//! properties (Observations 3–5, Lemma 1).
//!
//! For a `p`-processor system with `q = ceil(log2 p)`, the skips are
//! computed by repeated halving with rounding up:
//!
//! ```text
//! skip[q] = p;  skip[k-1] = ceil(skip[k] / 2)
//! ```
//!
//! so that always `skip[0] = 1` and `skip[1] = 2` (for `p > 1`). The
//! directed, q-regular circulant communication graph has, for every
//! processor `r`, outgoing edges to `(r + skip[k]) mod p` and incoming
//! edges from `(r - skip[k] + p) mod p` for `k = 0..q-1`.

/// `ceil(log2 p)` — the number of communication rounds per phase and the
/// number of skips (graph regularity degree).
///
/// By convention `q(1) = 0` (a single processor needs no rounds).
#[inline]
pub fn ceil_log2(p: usize) -> usize {
    assert!(p > 0, "p must be positive");
    (usize::BITS - (p - 1).leading_zeros()) as usize
}

/// The skips (jumps) of the `p`-processor circulant graph, Algorithm 2.
///
/// Returns a vector of length `q + 1` with `skip[q] = p` (the convenience
/// entry used by the schedule computations) and `skip[k-1] = ceil(skip[k]/2)`.
/// For `p = 1` the result is just `[1]` (`q = 0`).
pub fn skips(p: usize) -> Vec<usize> {
    let q = ceil_log2(p);
    let mut skip = vec![0usize; q + 1];
    skip[q] = p;
    let mut k = q;
    while k > 0 {
        // skip[k-1] = skip[k] - floor(skip[k]/2) = ceil(skip[k]/2)
        skip[k - 1] = skip[k] - skip[k] / 2;
        k -= 1;
    }
    skip
}

/// Precomputed skip table for one `p`, shared by all schedule computations.
///
/// This is the "communication pattern" object: it owns `p`, `q` and the
/// `q+1` skips, and answers neighbour queries on the circulant graph.
/// (A fixed inline array was tried for the inner loop and measured within
/// noise of the Vec — see EXPERIMENTS.md §Perf — so the simpler Vec stays.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Skips {
    p: usize,
    q: usize,
    skip: Vec<usize>,
}

impl Skips {
    /// Compute the skip table for a `p`-processor system (Algorithm 2).
    pub fn new(p: usize) -> Self {
        let skip = skips(p);
        let q = skip.len() - 1;
        Skips { p, q, skip }
    }

    /// Number of processors.
    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// `q = ceil(log2 p)`: rounds per phase, schedule length, graph degree.
    #[inline]
    pub fn q(&self) -> usize {
        self.q
    }

    /// `skip[k]` for `0 <= k <= q` (`skip[q] = p`).
    #[inline]
    pub fn skip(&self, k: usize) -> usize {
        self.skip[k]
    }

    /// All `q+1` skips.
    #[inline]
    pub fn as_slice(&self) -> &[usize] {
        &self.skip
    }

    /// The to-processor `t_r^k = (r + skip[k]) mod p` for round index `k`.
    #[inline]
    pub fn to_proc(&self, r: usize, k: usize) -> usize {
        debug_assert!(r < self.p);
        let t = r + self.skip[k];
        if t >= self.p {
            t - self.p
        } else {
            t
        }
    }

    /// The from-processor `f_r^k = (r - skip[k] + p) mod p` for round `k`.
    #[inline]
    pub fn from_proc(&self, r: usize, k: usize) -> usize {
        debug_assert!(r < self.p);
        let s = self.skip[k];
        if r >= s {
            r - s
        } else {
            r + self.p - s
        }
    }
}

/// Check Observation 3: `skip[k+1] <= 2*skip[k] <= skip[k+1] + 1`.
pub fn check_observation3(sk: &Skips) -> bool {
    (0..sk.q()).all(|k| {
        let d = 2 * sk.skip(k);
        sk.skip(k + 1) <= d && d <= sk.skip(k + 1) + 1
    })
}

/// Check Lemma 1: `skip[k+1] - 1 <= sum_{i<=k} skip[i] < skip[k+1] + k`.
pub fn check_lemma1(sk: &Skips) -> bool {
    let mut sum = 0usize;
    for k in 0..sk.q() {
        sum += sk.skip(k);
        // sum over i = 0..=k
        if sum + 1 < sk.skip(k + 1) || sum >= sk.skip(k + 1) + k.max(1) {
            // lower: skip[k+1] - 1 <= sum ; upper: sum < skip[k+1] + k.
            // For k = 0 the paper's bound is sum = 1 < skip[1] + 0 = 2.
            if !(sum + 1 >= sk.skip(k + 1) && sum < sk.skip(k + 1) + k) {
                return false;
            }
        }
    }
    true
}

/// Count the `k > 1` with `skip[k-2] + skip[k-1] == skip[k]` (Observation 4
/// says there are at most two, and only via `skip[2] = 3` or `skip[3] = 5`).
pub fn observation4_count(sk: &Skips) -> usize {
    (2..=sk.q())
        .filter(|&k| sk.skip(k - 2) + sk.skip(k - 1) == sk.skip(k))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_small() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(ceil_log2(17), 5);
        assert_eq!(ceil_log2(1 << 20), 20);
        assert_eq!(ceil_log2((1 << 20) + 1), 21);
    }

    #[test]
    fn skips_p17() {
        // q = 5; repeated halving from 17: 17, 9, 5, 3, 2, 1.
        assert_eq!(skips(17), vec![1, 2, 3, 5, 9, 17]);
    }

    #[test]
    fn skips_p9() {
        assert_eq!(skips(9), vec![1, 2, 3, 5, 9]);
    }

    #[test]
    fn skips_p18() {
        // Doubling p = 9 -> 18 keeps all skips and appends skip[q+1] = 18
        // (Observation 2).
        assert_eq!(skips(18), vec![1, 2, 3, 5, 9, 18]);
    }

    #[test]
    fn skips_pow2() {
        assert_eq!(skips(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(skips(1), vec![1]);
        assert_eq!(skips(2), vec![1, 2]);
    }

    #[test]
    fn first_two_skips_always_1_2() {
        for p in 2..2000 {
            let sk = skips(p);
            assert_eq!(sk[0], 1, "p={p}");
            assert_eq!(sk[1], 2, "p={p}");
        }
    }

    #[test]
    fn observation3_holds_for_all_p() {
        for p in 2..5000 {
            assert!(check_observation3(&Skips::new(p)), "p={p}");
        }
    }

    #[test]
    fn lemma1_holds_for_all_p() {
        for p in 2..5000 {
            let sk = Skips::new(p);
            let mut sum = 0usize;
            for k in 0..sk.q() {
                sum += sk.skip(k);
                assert!(sum + 1 >= sk.skip(k + 1), "p={p} k={k} lower bound");
                if k >= 1 {
                    assert!(sum < sk.skip(k + 1) + k, "p={p} k={k} upper bound");
                }
            }
        }
    }

    #[test]
    fn observation4_at_most_two() {
        for p in 2..5000 {
            let sk = Skips::new(p);
            assert!(observation4_count(&sk) <= 2, "p={p}");
        }
    }

    #[test]
    fn neighbors_roundtrip() {
        for p in [2usize, 3, 9, 17, 18, 100, 1023, 1024, 1025] {
            let sk = Skips::new(p);
            for r in 0..p {
                for k in 0..sk.q() {
                    let t = sk.to_proc(r, k);
                    assert_eq!(sk.from_proc(t, k), r, "p={p} r={r} k={k}");
                }
            }
        }
    }

    #[test]
    fn skip_q_is_p() {
        for p in 1..1000 {
            let sk = Skips::new(p);
            assert_eq!(sk.skip(sk.q()), p);
        }
    }
}
