//! The doubling constructions of Observation 2 (receive schedules) and
//! Observation 6 (send schedules): a correct schedule for `2p` processors
//! from a correct schedule for `p` processors.
//!
//! These are not used on the hot path (they only exist for even processor
//! counts, which is exactly why the paper needs the harder Algorithms 4–6)
//! but they give a strong *independent* correctness check: the directly
//! computed `2p` schedule must equal the doubled `p` schedule, for every
//! `p` — machine-checked in the test suite. They also show constructively
//! that schedules exist for all powers of two.

use super::recv::{RecvSchedule, SearchStats};
use super::send::SendSchedule;
use super::skips::Skips;

#[cfg(test)]
use super::recv::recv_schedule;
#[cfg(test)]
use super::send::send_schedule;

/// Observation 2: receive schedules for `2p` processors from the receive
/// schedules (and baseblocks) of `p` processors.
///
/// For `r` in `p..2p`, copy processor `r - p`'s schedule; subtract 1 from
/// every negative entry (q grew by one); then fill round `q`: processor
/// `p` gets the brand-new baseblock `q`; large processors `p < r < 2p`
/// move their old positive baseblock `b` to round `q` and replace it by
/// `-1` in its old round; small processors `0 <= r < p` receive nothing
/// new (`-1`) in round `q`.
pub fn double_recv_schedules(p: usize, scheds: &[RecvSchedule]) -> Vec<RecvSchedule> {
    assert_eq!(scheds.len(), p);
    let q = Skips::new(p).q();
    debug_assert!(p >= 1);
    let p2 = 2 * p;
    let q2 = Skips::new(p2).q();
    assert_eq!(q2, q + 1, "doubling must grow q by exactly one");

    let mut out = Vec::with_capacity(p2);
    for r in 0..p2 {
        let src = &scheds[r % p];
        let mut blocks: Vec<i64> = src
            .blocks
            .iter()
            .map(|&v| if v < 0 { v - 1 } else { v })
            .collect();
        let baseblock;
        if r == p {
            // The new processor p receives the new baseblock q directly
            // from the root in the new round.
            blocks.push(q as i64);
            baseblock = q;
        } else if r > p {
            // Move the old positive baseblock b to round q, replace the
            // old occurrence with -1 (that block now arrives from r - p's
            // "mirror", one round earlier in relative terms).
            let b = src.baseblock as i64;
            let pos = blocks
                .iter()
                .position(|&v| v == b)
                .expect("non-root schedule must contain its positive baseblock");
            blocks[pos] = -1;
            blocks.push(b);
            baseblock = src.baseblock;
        } else {
            // Small processors (including the root) receive nothing new.
            blocks.push(-1);
            baseblock = if r == 0 { q + 1 } else { src.baseblock };
        }
        out.push(RecvSchedule { blocks, baseblock, stats: SearchStats::default() });
    }
    out
}

/// Observation 6: send schedules for `2p` processors from the send
/// schedules (and baseblocks) of `p` processors.
///
/// Copy `r - p`'s schedule for the large processors; subtract 1 from the
/// negatives; small processors send their baseblock in the new last round,
/// large processors replace **all** positive send blocks with `-1` and
/// send `-1` in the last round.
pub fn double_send_schedules(p: usize, scheds: &[SendSchedule]) -> Vec<SendSchedule> {
    assert_eq!(scheds.len(), p);
    let q = Skips::new(p).q();
    let p2 = 2 * p;
    let q2 = Skips::new(p2).q();
    assert_eq!(q2, q + 1);

    let sk = Skips::new(p);
    let mut out = Vec::with_capacity(p2);
    for r in 0..p2 {
        let src = &scheds[r % p];
        let mut blocks: Vec<i64>;
        let baseblock;
        if r < p {
            // Small processors keep their schedule (negatives shifted) and
            // send their baseblock in the new last round.
            blocks = src.blocks.iter().map(|&v| if v < 0 { v - 1 } else { v }).collect();
            let b = if r == 0 {
                // Root: baseblock convention is q; in the 2p schedule the
                // root's new-round send is block q (it sends 0,1,...,q).
                q as i64
            } else {
                src.baseblock as i64
            };
            blocks.push(b);
            baseblock = if r == 0 { q + 1 } else { src.baseblock };
        } else {
            // Large processors: all positive send blocks become -1.
            blocks = src
                .blocks
                .iter()
                .map(|&v| if v < 0 { v - 1 } else { -1 })
                .collect();
            blocks.push(-1);
            baseblock = if r == p { q } else { scheds[r - p].baseblock };
        }
        let _ = &sk;
        out.push(SendSchedule { blocks, baseblock, violations: 0 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recv_table(p: usize) -> Vec<RecvSchedule> {
        let sk = Skips::new(p);
        (0..p).map(|r| recv_schedule(&sk, r)).collect()
    }

    fn send_table(p: usize) -> Vec<SendSchedule> {
        let sk = Skips::new(p);
        (0..p).map(|r| send_schedule(&sk, r)).collect()
    }

    #[test]
    fn doubling_9_to_18_matches_direct_recv() {
        // The paper presents Tables 2 and 3 exactly as this doubling pair.
        let doubled = double_recv_schedules(9, &recv_table(9));
        let direct = recv_table(18);
        for r in 0..18 {
            assert_eq!(doubled[r].blocks, direct[r].blocks, "r={r}");
        }
    }

    #[test]
    fn doubling_9_to_18_matches_direct_send() {
        let doubled = double_send_schedules(9, &send_table(9));
        let direct = send_table(18);
        for r in 0..18 {
            assert_eq!(doubled[r].blocks, direct[r].blocks, "r={r}");
        }
    }

    #[test]
    fn doubling_matches_direct_all_small_p() {
        for p in 2..300 {
            let dr = double_recv_schedules(p, &recv_table(p));
            let direct_r = recv_table(2 * p);
            let ds = double_send_schedules(p, &send_table(p));
            let direct_s = send_table(2 * p);
            for r in 0..2 * p {
                assert_eq!(dr[r].blocks, direct_r[r].blocks, "recv p={p} r={r}");
                assert_eq!(ds[r].blocks, direct_s[r].blocks, "send p={p} r={r}");
            }
        }
    }

    #[test]
    fn doubled_tables_verify() {
        use crate::schedule::verify::verify_tables;
        for p in [5usize, 9, 12, 17, 33, 100] {
            let sk2 = Skips::new(2 * p);
            let dr = double_recv_schedules(p, &recv_table(p));
            let ds = double_send_schedules(p, &send_table(p));
            let rep = verify_tables(&sk2, &dr, &ds);
            assert!(rep.ok(), "p={p}: {:?}", &rep.failures[..rep.failures.len().min(3)]);
        }
    }
}
