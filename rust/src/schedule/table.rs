//! The all-ranks schedule plane: every processor's receive **and** send
//! schedule for one `p`, in a single flat `i8` arena, built in parallel.
//!
//! The paper's headline result is that one rank's schedule costs
//! `O(log p)`; a full-network consumer (the sparse simulation engine, the
//! Algorithm-7 all-collectives, the schedule cache) needs all `p` of
//! them. Filling them one `Vec` at a time, serially, makes the arena fill
//! the dominant cost at `p = 2^20` — ahead of the actual round
//! simulation. [`ScheduleTable`] fixes that on three axes:
//!
//! * **One allocation, `q`-strided rows.** All `2·p` rows live in one
//!   contiguous `i8` arena (`2·p·q` bytes — 40 MiB at `p = 2^20`),
//!   rank-major with the recv row immediately followed by the send row
//!   (`arena[rel·2q .. rel·2q+q]` / `arena[rel·2q+q .. (rel+1)·2q]`), so
//!   a consumer touching one rank's schedules touches one or two cache
//!   lines and a round-`k` sweep strides predictably.
//! * **Parallel build.** Ranks are independent (the paper's whole point:
//!   no communication), so the arena is filled with
//!   `std::thread::scope` over contiguous rank chunks — zero new
//!   dependencies, thread count from `CBCAST_THREADS` (default: all
//!   cores). Chunks own disjoint arena slices; no synchronisation.
//! * **Two serial algorithmic wins inside each chunk.**
//!   (a) The send-schedule violation path (Algorithm 6) falls back to a
//!   full `ALLBLOCKS` receive-schedule search for the to-processor;
//!   Theorem 3 bounds violations by 4 per rank, and neighbouring ranks'
//!   violations frequently target the *same* to-processor, so a
//!   `q`-entry LRU memo ([`RecvMemo`]) per chunk eliminates nearly all
//!   redundant searches. (b) The recv and send rows of one rank share a
//!   single baseblock computation: `recv_schedule_core` already walks
//!   Algorithm 3, and its result is handed straight to the send core
//!   instead of recomputed.
//!
//! Rows are *root-relative* and depend only on `p` (not on the block
//! count `n`, the root, or the collective), so one table serves every
//! broadcast/reduction/all-collective at its `p` — the
//! [`crate::schedule::ScheduleCache`] stores exactly one per `p`.

use std::sync::Arc;

use super::cache::Schedule;
use super::recv::{recv_schedule_core, MAX_Q};
use super::send::send_schedule_core_with;
use super::skips::Skips;

/// Thread count for the parallel schedule-plane paths (table build and
/// the engine's sharded delivery application): the `CBCAST_THREADS`
/// environment variable if set to a positive integer, else all available
/// cores. `CBCAST_THREADS=1` is the exact serial path (no scope, no
/// spawns) — the baseline the CI smoke compares against.
pub fn configured_threads() -> usize {
    std::env::var("CBCAST_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Small LRU memo of receive-schedule rows, keyed by processor — the
/// per-chunk violation-path cache. Capacity `q` (Theorem 3 gives ≤ 4
/// violations per rank, all targeting to-processors `r + skip[k]`, so a
/// handful of entries covers the reuse window of a contiguous rank
/// chunk). Move-to-front on hit, evict-last on insert; `q ≤ 64` keeps
/// the linear scan trivially cheap.
struct RecvMemo {
    cap: usize,
    entries: Vec<(usize, [i64; MAX_Q])>,
}

impl RecvMemo {
    fn new(q: usize) -> Self {
        RecvMemo { cap: q.max(4), entries: Vec::new() }
    }

    fn recv_at(&mut self, sk: &Skips, t: usize, k: usize) -> i64 {
        if let Some(pos) = self.entries.iter().position(|e| e.0 == t) {
            if pos != 0 {
                let e = self.entries.remove(pos);
                self.entries.insert(0, e);
            }
            return self.entries[0].1[k];
        }
        let mut buf = [0i64; MAX_Q];
        recv_schedule_core(sk, t, &mut buf);
        if self.entries.len() == self.cap {
            self.entries.pop();
        }
        self.entries.insert(0, (t, buf));
        buf[k]
    }
}

/// All `p` receive+send schedule rows for one `p`, flat and shareable.
///
/// Raw entries lie in `[-q, q]` and `q ≤ 64`, so `i8` holds them; the
/// phase-advanced value any consumer actually uses at network round `j`
/// is `row[k] + delta` with `(k, delta)` from
/// [`crate::collectives::common::phase_params`] — rank-independent, so
/// the table itself is block-count- and root-agnostic.
#[derive(Debug, Clone)]
pub struct ScheduleTable {
    sk: Arc<Skips>,
    /// Rank-major rows, stride `2q`: recv row then send row per rank.
    arena: Vec<i8>,
    /// Baseblock `b_rel` per rank (`q` for the root; fits `u8`).
    baseblocks: Vec<u8>,
    /// Total send-schedule violations resolved across all ranks
    /// (Theorem 3: ≤ 4·p; observable for the bench receipts).
    violations: usize,
}

impl ScheduleTable {
    /// Build the full table with the configured thread count
    /// ([`configured_threads`]).
    pub fn build(sk: &Arc<Skips>) -> Self {
        Self::build_with_threads(sk, configured_threads())
    }

    /// Build the full table, filling contiguous rank chunks on `threads`
    /// scoped threads (`threads = 1` runs strictly serially on the
    /// calling thread).
    pub fn build_with_threads(sk: &Arc<Skips>, threads: usize) -> Self {
        let p = sk.p();
        let q = sk.q();
        let mut arena = vec![0i8; p * 2 * q];
        let mut baseblocks = vec![0u8; p];
        if q == 0 {
            // p = 1: empty rows, baseblock 0 by the q = 0 convention.
            return ScheduleTable { sk: sk.clone(), arena, baseblocks, violations: 0 };
        }
        let threads = threads.clamp(1, p);
        let violations = if threads == 1 {
            fill_chunk(sk, 0, &mut arena, &mut baseblocks)
        } else {
            // ceil(p / threads) ranks per chunk; chunks own disjoint
            // slices of the arena and the baseblock vector, so the scoped
            // threads need no synchronisation at all.
            let chunk_ranks = (p + threads - 1) / threads; // ceil; div_ceil needs 1.73, MSRV is 1.70
            let mut total = 0usize;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(threads);
                for (i, (rows, bbs)) in arena
                    .chunks_mut(chunk_ranks * 2 * q)
                    .zip(baseblocks.chunks_mut(chunk_ranks))
                    .enumerate()
                {
                    let start = i * chunk_ranks;
                    handles.push(s.spawn(move || fill_chunk(sk, start, rows, bbs)));
                }
                for h in handles {
                    total += h.join().expect("schedule-table fill chunk panicked");
                }
            });
            total
        };
        ScheduleTable { sk: sk.clone(), arena, baseblocks, violations }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.sk.p()
    }

    #[inline]
    pub fn q(&self) -> usize {
        self.sk.q()
    }

    #[inline]
    pub fn skips(&self) -> &Arc<Skips> {
        &self.sk
    }

    /// Arena size in bytes (`2·p·q`) — what the cache's admission cap
    /// compares against.
    #[inline]
    pub fn bytes(&self) -> usize {
        self.arena.len()
    }

    /// [`Self::bytes`] without building: `2·p·q` for this `sk`.
    #[inline]
    pub fn bytes_for(sk: &Skips) -> usize {
        2 * sk.p() * sk.q()
    }

    /// Total send-schedule violations resolved during the build.
    #[inline]
    pub fn violations(&self) -> usize {
        self.violations
    }

    /// Raw `recvblock[k]` of relative rank `rel`.
    #[inline]
    pub fn recv_raw(&self, rel: usize, k: usize) -> i8 {
        self.arena[rel * 2 * self.sk.q() + k]
    }

    /// Raw `sendblock[k]` of relative rank `rel`.
    #[inline]
    pub fn send_raw(&self, rel: usize, k: usize) -> i8 {
        let q = self.sk.q();
        self.arena[rel * 2 * q + q + k]
    }

    /// The `q` raw recv entries of `rel`.
    #[inline]
    pub fn recv_row(&self, rel: usize) -> &[i8] {
        let q = self.sk.q();
        &self.arena[rel * 2 * q..rel * 2 * q + q]
    }

    /// The `q` raw send entries of `rel`.
    #[inline]
    pub fn send_row(&self, rel: usize) -> &[i8] {
        let q = self.sk.q();
        &self.arena[rel * 2 * q + q..(rel + 1) * 2 * q]
    }

    /// Baseblock `b_rel` (`q` for the root, matching
    /// [`Schedule::compute`]).
    #[inline]
    pub fn baseblock(&self, rel: usize) -> usize {
        self.baseblocks[rel] as usize
    }

    /// Materialise one rank's combined [`Schedule`] from the table rows
    /// (two `q`-element allocations — the compatibility shape served by
    /// [`crate::schedule::ScheduleCache::get`]).
    pub fn schedule(&self, rel: usize) -> Schedule {
        Schedule {
            p: self.p(),
            q: self.q(),
            rank: rel,
            recv: self.recv_row(rel).iter().map(|&v| v as i64).collect(),
            send: self.send_row(rel).iter().map(|&v| v as i64).collect(),
            baseblock: self.baseblock(rel),
        }
    }

    /// Test-only corruption hooks (schedule-violation enforcement tests).
    #[cfg(test)]
    pub(crate) fn recv_row_mut(&mut self, rel: usize) -> &mut [i8] {
        let q = self.sk.q();
        &mut self.arena[rel * 2 * q..rel * 2 * q + q]
    }

    #[cfg(test)]
    pub(crate) fn send_row_mut(&mut self, rel: usize) -> &mut [i8] {
        let q = self.sk.q();
        &mut self.arena[rel * 2 * q + q..(rel + 1) * 2 * q]
    }
}

/// Fill the rows of ranks `start..start + bbs.len()` into `rows` (a
/// `2q`-strided slice of the arena); returns the violation count. One
/// baseblock walk per rank (shared by its recv and send row) and one
/// [`RecvMemo`] for the whole chunk's violation fallbacks.
fn fill_chunk(sk: &Skips, start: usize, rows: &mut [i8], bbs: &mut [u8]) -> usize {
    let q = sk.q();
    debug_assert_eq!(rows.len(), bbs.len() * 2 * q);
    let mut memo = RecvMemo::new(q);
    let mut rbuf = [0i64; MAX_Q];
    let mut sbuf = [0i64; MAX_Q];
    let mut violations = 0usize;
    for (i, bb_out) in bbs.iter_mut().enumerate() {
        let rel = start + i;
        // (b)-win: the recv core's Algorithm-3 walk is the send core's
        // baseblock too — computed once per rank, not twice.
        let (bb, _) = recv_schedule_core(sk, rel, &mut rbuf);
        violations +=
            send_schedule_core_with(sk, rel, bb, &mut sbuf, &mut |sk, t, k| {
                memo.recv_at(sk, t, k)
            });
        debug_assert!(bb <= q, "baseblock {bb} out of range");
        *bb_out = bb as u8;
        let row = &mut rows[i * 2 * q..(i + 1) * 2 * q];
        for (dst, &v) in row[..q].iter_mut().zip(&rbuf[..q]) {
            debug_assert!((-(q as i64)..q as i64).contains(&v));
            *dst = v as i8;
        }
        for (dst, &v) in row[q..].iter_mut().zip(&sbuf[..q]) {
            debug_assert!((-(q as i64)..q as i64).contains(&v));
            *dst = v as i8;
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::recv::recv_schedule;
    use crate::schedule::send::send_schedule;

    fn assert_matches_serial(p: usize, threads: usize) {
        let sk = Arc::new(Skips::new(p));
        let t = ScheduleTable::build_with_threads(&sk, threads);
        assert_eq!(t.p(), p);
        assert_eq!(t.bytes(), 2 * p * sk.q());
        for r in 0..p {
            let rs = recv_schedule(&sk, r);
            let ss = send_schedule(&sk, r);
            let trecv: Vec<i64> = t.recv_row(r).iter().map(|&v| v as i64).collect();
            let tsend: Vec<i64> = t.send_row(r).iter().map(|&v| v as i64).collect();
            assert_eq!(trecv, rs.blocks, "recv p={p} r={r} threads={threads}");
            assert_eq!(tsend, ss.blocks, "send p={p} r={r} threads={threads}");
            assert_eq!(t.baseblock(r), rs.baseblock, "bb p={p} r={r}");
            let s = t.schedule(r);
            assert_eq!(s.recv, rs.blocks);
            assert_eq!(s.send, ss.blocks);
            assert_eq!(s.rank, r);
        }
    }

    #[test]
    fn matches_serial_cores_small_grid() {
        for p in [1usize, 2, 3, 4, 5, 8, 9, 16, 17, 18, 31, 32, 33, 100, 127, 128, 129] {
            for threads in [1usize, 2, 8] {
                assert_matches_serial(p, threads);
            }
        }
    }

    #[test]
    fn chunk_boundaries_are_invisible() {
        // Thread counts that do not divide p: the last chunk is short and
        // chunk-local memo state must not leak across boundaries.
        for p in [97usize, 1000, 1023] {
            for threads in [3usize, 7, 13, 97] {
                assert_matches_serial(p, threads);
            }
        }
    }

    #[test]
    fn violations_bounded_by_theorem3() {
        for p in [17usize, 100, 1000, 4097] {
            let sk = Arc::new(Skips::new(p));
            let t = ScheduleTable::build_with_threads(&sk, 4);
            assert!(t.violations() <= 4 * p, "p={p}: {}", t.violations());
        }
    }

    #[test]
    fn p1_table_is_empty() {
        let sk = Arc::new(Skips::new(1));
        let t = ScheduleTable::build(&sk);
        assert_eq!(t.bytes(), 0);
        assert!(t.recv_row(0).is_empty());
        assert!(t.send_row(0).is_empty());
        assert_eq!(t.baseblock(0), 0);
    }

    #[test]
    fn memo_hits_do_not_change_rows() {
        // A chunk of the whole rank range maximises memo reuse; the rows
        // must still be bit-identical to the memo-free serial cores
        // (covered rank by rank in assert_matches_serial, pinned here at
        // a p with many violations).
        assert_matches_serial(4099, 1);
    }
}
