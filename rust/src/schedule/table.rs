//! The all-ranks schedule plane: every processor's receive **and** send
//! schedule for one `p`, in a single flat `i8` arena, built in parallel.
//!
//! The paper's headline result is that one rank's schedule costs
//! `O(log p)`; a full-network consumer (the sparse simulation engine, the
//! Algorithm-7 all-collectives, the schedule cache) needs all `p` of
//! them. Filling them one `Vec` at a time, serially, makes the arena fill
//! the dominant cost at `p = 2^20` — ahead of the actual round
//! simulation. [`ScheduleTable`] fixes that on four axes:
//!
//! * **One allocation, `q`-strided rows.** All `2·p` rows live in one
//!   contiguous `i8` arena (`2·p·q` bytes — 40 MiB at `p = 2^20`),
//!   rank-major with the recv row immediately followed by the send row
//!   (`arena[rel·2q .. rel·2q+q]` / `arena[rel·2q+q .. (rel+1)·2q]`), so
//!   a consumer touching one rank's schedules touches one or two cache
//!   lines and a round-`k` sweep strides predictably.
//! * **Parallel build.** Ranks are independent (the paper's whole point:
//!   no communication), so the arena is filled with
//!   `std::thread::scope` over contiguous rank chunks — zero new
//!   dependencies, thread count from `CBCAST_THREADS`
//!   ([`configured_threads`]). Chunks own disjoint slices; no
//!   synchronisation.
//! * **Batch-vectorized construction** ([`BuildKernel::Lanes`], the
//!   default). The scalar cores walk Algorithm 3 and Algorithm 6 one
//!   rank at a time through data-dependent branches; the lane kernels
//!   ([`crate::schedule::baseblock::baseblock_lanes`],
//!   [`crate::schedule::send::send_lanes`]) instead push
//!   [`crate::schedule::baseblock::LANES`] consecutive ranks through
//!   the same walks as branchless `i64` lane arrays (selects instead of
//!   branches — the shape the autovectorizer chews on), recording the
//!   rare Algorithm-6 violations in per-lane bitmasks resolved
//!   afterwards through the per-chunk [`RecvMemo`]. The expensive
//!   `ALLBLOCKS` receive-schedule search is then skipped for the bulk
//!   build entirely: pass 1 fills every **send** row plus baseblocks,
//!   and pass 2 derives every **recv** row by the Correctness
//!   Conditions 1+2 identity `recvblock[k]_t = sendblock[k]_{(t + p −
//!   skip[k]) mod p}` — a pure gather (see
//!   [`crate::schedule::recv`]). Both kernels are pinned bit-identical
//!   to the scalar cores by `tests/table_parity.rs`;
//!   `CBCAST_BUILD_KERNEL=scalar` keeps the reference path selectable
//!   at run time.
//! * **Two serial algorithmic wins inside each chunk.**
//!   (a) The send-schedule violation path (Algorithm 6) falls back to a
//!   full `ALLBLOCKS` receive-schedule search for the to-processor;
//!   Theorem 3 bounds violations by 4 per rank, and neighbouring ranks'
//!   violations frequently target the *same* to-processor, so a
//!   `q`-entry LRU memo ([`RecvMemo`]) per chunk eliminates nearly all
//!   redundant searches. (b) On the scalar path, the recv and send rows
//!   of one rank share a single baseblock computation:
//!   `recv_schedule_core` already walks Algorithm 3, and its result is
//!   handed straight to the send core instead of recomputed.
//!
//! Rows are *root-relative* and depend only on `p` (not on the block
//! count `n`, the root, or the collective), so one table serves every
//! broadcast/reduction/all-collective at its `p` — the
//! [`crate::schedule::ScheduleCache`] stores exactly one per `p`.

use std::sync::Arc;

use super::baseblock::{baseblock_lanes, LANES};
use super::cache::Schedule;
use super::recv::{recv_schedule_core, MAX_Q};
use super::send::{send_lanes, send_schedule_core_with};
use super::skips::Skips;

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parse a `CBCAST_THREADS` value: a positive integer, nothing else.
/// `0` is rejected explicitly — a zero-thread build cannot run, and
/// silently treating it as "all cores" hid misconfiguration.
fn parse_threads(raw: &str) -> Result<usize, String> {
    let t: usize =
        raw.trim().parse().map_err(|e| format!("not an unsigned integer: {e}"))?;
    if t == 0 {
        return Err("thread count must be >= 1".to_string());
    }
    Ok(t)
}

/// Thread count for the parallel schedule-plane paths (table build and
/// the engine's sharded delivery application): the `CBCAST_THREADS`
/// environment variable if set to a **positive** integer, else all
/// available cores. `CBCAST_THREADS=1` is the exact serial path (no
/// scope, no spawns) — the baseline the CI smoke compares against.
///
/// Invalid values (`0`, garbage) are **rejected with a once-per-process
/// warning** and fall back to the all-cores default — the documented
/// floor is 1 thread. (Same contract shape as the transport's
/// `CBCAST_TRANSPORT_TIMEOUT_MS` parsing: misconfiguration signals
/// instead of silently meaning something else.)
pub fn configured_threads() -> usize {
    match std::env::var("CBCAST_THREADS") {
        Ok(raw) => match parse_threads(&raw) {
            Ok(t) => t,
            Err(why) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "cbcast: ignoring CBCAST_THREADS={raw:?} ({why}); \
                         using all {} cores",
                        default_threads()
                    );
                });
                default_threads()
            }
        },
        Err(_) => default_threads(),
    }
}

/// Which construction kernel [`ScheduleTable::build_with_threads`] runs.
/// Both produce bit-identical arenas (pinned by `tests/table_parity.rs`);
/// they differ only in speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuildKernel {
    /// The reference path: one rank at a time through the branchy
    /// scalar Algorithm 3/5/6 cores (recv rows via `ALLBLOCKS`).
    Scalar,
    /// The batch-vectorized path (default): branchless lane kernels
    /// fill send rows + baseblocks for [`LANES`] ranks at a time, then
    /// recv rows are gathered from send rows by Conditions 1+2.
    Lanes,
}

/// Parse a `CBCAST_BUILD_KERNEL` value (`"lanes"` or `"scalar"`,
/// case-insensitive).
fn parse_build_kernel(raw: &str) -> Result<BuildKernel, String> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "lanes" => Ok(BuildKernel::Lanes),
        "scalar" => Ok(BuildKernel::Scalar),
        other => Err(format!("unknown kernel {other:?} (expected \"lanes\" or \"scalar\")")),
    }
}

/// The construction kernel from the `CBCAST_BUILD_KERNEL` environment
/// variable: `lanes` (the default) or `scalar` (the reference path the
/// CI engine-scale smoke diffs against). Invalid values warn once and
/// fall back to the default, mirroring [`configured_threads`].
pub fn configured_build_kernel() -> BuildKernel {
    match std::env::var("CBCAST_BUILD_KERNEL") {
        Ok(raw) => match parse_build_kernel(&raw) {
            Ok(k) => k,
            Err(why) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "cbcast: ignoring CBCAST_BUILD_KERNEL={raw:?} ({why}); \
                         using the lanes kernel"
                    );
                });
                BuildKernel::Lanes
            }
        },
        Err(_) => BuildKernel::Lanes,
    }
}

/// Small LRU memo of receive-schedule rows, keyed by processor — the
/// per-chunk violation-path cache. Capacity `q` (Theorem 3 gives ≤ 4
/// violations per rank, all targeting to-processors `r + skip[k]`, so a
/// handful of entries covers the reuse window of a contiguous rank
/// chunk). Move-to-front on hit, evict-last on insert; `q ≤ 64` keeps
/// the linear scan trivially cheap.
struct RecvMemo {
    cap: usize,
    entries: Vec<(usize, [i64; MAX_Q])>,
}

impl RecvMemo {
    fn new(q: usize) -> Self {
        RecvMemo { cap: q.max(4), entries: Vec::new() }
    }

    fn recv_at(&mut self, sk: &Skips, t: usize, k: usize) -> i64 {
        if let Some(pos) = self.entries.iter().position(|e| e.0 == t) {
            if pos != 0 {
                let e = self.entries.remove(pos);
                self.entries.insert(0, e);
            }
            return self.entries[0].1[k];
        }
        let mut buf = [0i64; MAX_Q];
        recv_schedule_core(sk, t, &mut buf);
        if self.entries.len() == self.cap {
            self.entries.pop();
        }
        self.entries.insert(0, (t, buf));
        buf[k]
    }
}

/// All `p` receive+send schedule rows for one `p`, flat and shareable.
///
/// Raw entries lie in the half-open `[-q, q)` and `q ≤ 64`, so `i8`
/// holds them: a recv row carries one non-negative baseblock `< q` and
/// negatives from `{-1, …, -q}` (Condition 3), and every send entry
/// equals some rank's recv entry (Conditions 1+2) or, for the root row,
/// `0..q-1` — the value `q` itself never appears in a row (the root's
/// conventional baseblock `q` lives only in [`Self::baseblock`]). The
/// phase-advanced value any consumer actually uses at network round `j`
/// is `row[k] + delta` with `(k, delta)` from
/// [`crate::collectives::common::phase_params`] — rank-independent, so
/// the table itself is block-count- and root-agnostic.
#[derive(Debug, Clone)]
pub struct ScheduleTable {
    sk: Arc<Skips>,
    /// Rank-major rows, stride `2q`: recv row then send row per rank.
    arena: Vec<i8>,
    /// Baseblock `b_rel` per rank (`q` for the root; fits `u8`).
    baseblocks: Vec<u8>,
    /// Total send-schedule violations resolved across all ranks
    /// (Theorem 3: ≤ 4·p; observable for the bench receipts).
    violations: usize,
}

impl ScheduleTable {
    /// Build the full table with the configured thread count
    /// ([`configured_threads`]) and kernel ([`configured_build_kernel`]).
    pub fn build(sk: &Arc<Skips>) -> Self {
        Self::build_with_kernel(sk, configured_threads(), configured_build_kernel())
    }

    /// Build the full table, filling contiguous rank chunks on `threads`
    /// scoped threads (`threads = 1` runs strictly serially on the
    /// calling thread), with the kernel from the environment
    /// ([`configured_build_kernel`]).
    pub fn build_with_threads(sk: &Arc<Skips>, threads: usize) -> Self {
        Self::build_with_kernel(sk, threads, configured_build_kernel())
    }

    /// Build the full table with an explicit construction kernel — the
    /// programmatic knob behind the `CBCAST_BUILD_KERNEL` env var, used
    /// by the parity tests and the CI bench gate to diff the two paths.
    pub fn build_with_kernel(sk: &Arc<Skips>, threads: usize, kernel: BuildKernel) -> Self {
        let p = sk.p();
        let q = sk.q();
        let mut arena = vec![0i8; p * 2 * q];
        let mut baseblocks = vec![0u8; p];
        if q == 0 {
            // p = 1: empty rows, baseblock 0 by the q = 0 convention.
            return ScheduleTable { sk: sk.clone(), arena, baseblocks, violations: 0 };
        }
        let threads = threads.clamp(1, p);
        let violations = match kernel {
            BuildKernel::Scalar => {
                Self::fill_scalar(sk, threads, &mut arena, &mut baseblocks)
            }
            BuildKernel::Lanes => {
                Self::fill_lanes(sk, threads, &mut arena, &mut baseblocks)
            }
        };
        ScheduleTable { sk: sk.clone(), arena, baseblocks, violations }
    }

    /// The reference path: per-rank scalar cores straight into the
    /// arena, parallel over contiguous rank chunks.
    fn fill_scalar(
        sk: &Arc<Skips>,
        threads: usize,
        arena: &mut [i8],
        baseblocks: &mut [u8],
    ) -> usize {
        let p = sk.p();
        let q = sk.q();
        if threads == 1 {
            return fill_chunk(sk, 0, arena, baseblocks);
        }
        // ceil(p / threads) ranks per chunk; chunks own disjoint
        // slices of the arena and the baseblock vector, so the scoped
        // threads need no synchronisation at all.
        let chunk_ranks = (p + threads - 1) / threads; // ceil; div_ceil needs 1.73, MSRV is 1.70
        let mut total = 0usize;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            for (i, (rows, bbs)) in arena
                .chunks_mut(chunk_ranks * 2 * q)
                .zip(baseblocks.chunks_mut(chunk_ranks))
                .enumerate()
            {
                let start = i * chunk_ranks;
                handles.push(s.spawn(move || fill_chunk(sk, start, rows, bbs)));
            }
            for h in handles {
                total += h.join().expect("schedule-table fill chunk panicked");
            }
        });
        total
    }

    /// The batch-vectorized path: pass 1 fills a send-row staging
    /// buffer (stride `q`) plus baseblocks through the lane kernels;
    /// pass 2 assembles the arena, gathering each recv row from the
    /// staged send rows by Conditions 1+2. Both passes run parallel
    /// over contiguous rank chunks; pass 2 only *reads* the shared
    /// staging buffer, so the whole build is safe Rust.
    fn fill_lanes(
        sk: &Arc<Skips>,
        threads: usize,
        arena: &mut [i8],
        baseblocks: &mut [u8],
    ) -> usize {
        let p = sk.p();
        let q = sk.q();
        let mut send_tmp = vec![0i8; p * q];
        let chunk_ranks = (p + threads - 1) / threads;
        let violations = if threads == 1 {
            fill_send_chunk_lanes(sk, 0, &mut send_tmp, baseblocks)
        } else {
            let mut total = 0usize;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(threads);
                for (i, (rows, bbs)) in send_tmp
                    .chunks_mut(chunk_ranks * q)
                    .zip(baseblocks.chunks_mut(chunk_ranks))
                    .enumerate()
                {
                    let start = i * chunk_ranks;
                    handles.push(s.spawn(move || fill_send_chunk_lanes(sk, start, rows, bbs)));
                }
                for h in handles {
                    total += h.join().expect("schedule-table send chunk panicked");
                }
            });
            total
        };
        let send_tmp = &send_tmp;
        if threads == 1 {
            gather_arena_chunk(sk, 0, arena, send_tmp);
        } else {
            std::thread::scope(|s| {
                for (i, rows) in arena.chunks_mut(chunk_ranks * 2 * q).enumerate() {
                    let start = i * chunk_ranks;
                    s.spawn(move || gather_arena_chunk(sk, start, rows, send_tmp));
                }
            });
        }
        violations
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.sk.p()
    }

    #[inline]
    pub fn q(&self) -> usize {
        self.sk.q()
    }

    #[inline]
    pub fn skips(&self) -> &Arc<Skips> {
        &self.sk
    }

    /// Arena size in bytes (`2·p·q`) — what the cache's admission cap
    /// compares against.
    #[inline]
    pub fn bytes(&self) -> usize {
        self.arena.len()
    }

    /// [`Self::bytes`] without building: `2·p·q` for this `sk`.
    #[inline]
    pub fn bytes_for(sk: &Skips) -> usize {
        2 * sk.p() * sk.q()
    }

    /// Total send-schedule violations resolved during the build.
    #[inline]
    pub fn violations(&self) -> usize {
        self.violations
    }

    /// Raw `recvblock[k]` of relative rank `rel`.
    #[inline]
    pub fn recv_raw(&self, rel: usize, k: usize) -> i8 {
        self.arena[rel * 2 * self.sk.q() + k]
    }

    /// Raw `sendblock[k]` of relative rank `rel`.
    #[inline]
    pub fn send_raw(&self, rel: usize, k: usize) -> i8 {
        let q = self.sk.q();
        self.arena[rel * 2 * q + q + k]
    }

    /// The `q` raw recv entries of `rel`.
    #[inline]
    pub fn recv_row(&self, rel: usize) -> &[i8] {
        let q = self.sk.q();
        &self.arena[rel * 2 * q..rel * 2 * q + q]
    }

    /// The `q` raw send entries of `rel`.
    #[inline]
    pub fn send_row(&self, rel: usize) -> &[i8] {
        let q = self.sk.q();
        &self.arena[rel * 2 * q + q..(rel + 1) * 2 * q]
    }

    /// Baseblock `b_rel` (`q` for the root, matching
    /// [`Schedule::compute`]).
    #[inline]
    pub fn baseblock(&self, rel: usize) -> usize {
        self.baseblocks[rel] as usize
    }

    /// Materialise one rank's combined [`Schedule`] from the table rows
    /// (two `q`-element allocations — the compatibility shape served by
    /// [`crate::schedule::ScheduleCache::get`]).
    pub fn schedule(&self, rel: usize) -> Schedule {
        Schedule {
            p: self.p(),
            q: self.q(),
            rank: rel,
            recv: self.recv_row(rel).iter().map(|&v| v as i64).collect(),
            send: self.send_row(rel).iter().map(|&v| v as i64).collect(),
            baseblock: self.baseblock(rel),
        }
    }

    /// Test-only corruption hooks (schedule-violation enforcement tests).
    #[cfg(test)]
    pub(crate) fn recv_row_mut(&mut self, rel: usize) -> &mut [i8] {
        let q = self.sk.q();
        &mut self.arena[rel * 2 * q..rel * 2 * q + q]
    }

    #[cfg(test)]
    pub(crate) fn send_row_mut(&mut self, rel: usize) -> &mut [i8] {
        let q = self.sk.q();
        &mut self.arena[rel * 2 * q + q..(rel + 1) * 2 * q]
    }
}

/// Fill the rows of ranks `start..start + bbs.len()` into `rows` (a
/// `2q`-strided slice of the arena); returns the violation count. One
/// baseblock walk per rank (shared by its recv and send row) and one
/// [`RecvMemo`] for the whole chunk's violation fallbacks.
fn fill_chunk(sk: &Skips, start: usize, rows: &mut [i8], bbs: &mut [u8]) -> usize {
    let q = sk.q();
    debug_assert_eq!(rows.len(), bbs.len() * 2 * q);
    let mut memo = RecvMemo::new(q);
    let mut rbuf = [0i64; MAX_Q];
    let mut sbuf = [0i64; MAX_Q];
    let mut violations = 0usize;
    for (i, bb_out) in bbs.iter_mut().enumerate() {
        let rel = start + i;
        // (b)-win: the recv core's Algorithm-3 walk is the send core's
        // baseblock too — computed once per rank, not twice.
        let (bb, _) = recv_schedule_core(sk, rel, &mut rbuf);
        violations +=
            send_schedule_core_with(sk, rel, bb, &mut sbuf, &mut |sk, t, k| {
                memo.recv_at(sk, t, k)
            });
        debug_assert!(bb <= q, "baseblock {bb} out of range");
        *bb_out = bb as u8;
        let row = &mut rows[i * 2 * q..(i + 1) * 2 * q];
        for (dst, &v) in row[..q].iter_mut().zip(&rbuf[..q]) {
            debug_assert!((-(q as i64)..q as i64).contains(&v));
            *dst = v as i8;
        }
        for (dst, &v) in row[q..].iter_mut().zip(&sbuf[..q]) {
            debug_assert!((-(q as i64)..q as i64).contains(&v));
            *dst = v as i8;
        }
    }
    violations
}

/// Lane-kernel pass 1: fill the **send** rows of ranks
/// `start..start + bbs.len()` into `rows` (stride `q`) plus their
/// baseblocks; returns the violation count. Ranks go through the
/// branchless lane kernels [`LANES`] at a time (a short tail group pads
/// by clamping to the last rank; padded lanes' outputs are discarded).
/// Violations land in per-lane bitmasks and are resolved afterwards
/// through the chunk's [`RecvMemo`] — the memo returns pure schedule
/// values, so resolution order cannot change the rows.
fn fill_send_chunk_lanes(sk: &Skips, start: usize, rows: &mut [i8], bbs: &mut [u8]) -> usize {
    let q = sk.q();
    let p = sk.p();
    debug_assert_eq!(rows.len(), bbs.len() * q);
    let mut memo = RecvMemo::new(q);
    let mut stage = [[0i64; LANES]; MAX_Q];
    let mut violations = 0usize;
    let n = bbs.len();
    let mut base = 0usize;
    while base < n {
        let width = LANES.min(n - base);
        let mut rv = [0i64; LANES];
        for (i, v) in rv.iter_mut().enumerate() {
            *v = (start + base + i.min(width - 1)) as i64;
        }
        let bb = baseblock_lanes(sk, &rv);
        let viol = send_lanes(sk, &rv, &bb, &mut stage);
        for i in 0..width {
            let rel = start + base + i;
            debug_assert!(bb[i] >= 0 && bb[i] <= q as i64, "baseblock {} out of range", bb[i]);
            bbs[base + i] = bb[i] as u8;
            let row = &mut rows[(base + i) * q..(base + i + 1) * q];
            if rel == 0 {
                // The root's row is not produced by the non-root
                // recursion: it greedily sends 0..q-1 (zero violations).
                for (k, dst) in row.iter_mut().enumerate() {
                    *dst = k as i8;
                }
                continue;
            }
            let mut vm = viol[i];
            violations += vm.count_ones() as usize;
            while vm != 0 {
                let k = 63 - vm.leading_zeros() as usize; // descending, like the scalar walk
                vm &= !(1u64 << k);
                let t = rel + sk.skip(k);
                let t = if t >= p { t - p } else { t };
                stage[k][i] = memo.recv_at(sk, t, k);
            }
            for (k, dst) in row.iter_mut().enumerate() {
                let v = stage[k][i];
                debug_assert!((-(q as i64)..q as i64).contains(&v));
                *dst = v as i8;
            }
        }
        base += width;
    }
    violations
}

/// Lane-kernel pass 2: assemble the arena rows of ranks
/// `start..start + rows.len() / 2q` from the staged send rows. The send
/// row is a straight copy; the recv row is the Conditions 1+2 gather
/// `recvblock[k]_rel = sendblock[k]_{(rel + p − skip[k]) mod p}` — the
/// map `r ↦ (r + skip[k]) mod p` is a bijection per round, so every
/// recv entry is some staged send entry (see [`crate::schedule::recv`]
/// for why this identity is exact, violations included).
fn gather_arena_chunk(sk: &Skips, start: usize, rows: &mut [i8], send_tmp: &[i8]) {
    let q = sk.q();
    let p = sk.p();
    debug_assert_eq!(rows.len() % (2 * q), 0);
    let n = rows.len() / (2 * q);
    for i in 0..n {
        let rel = start + i;
        let row = &mut rows[i * 2 * q..(i + 1) * 2 * q];
        row[q..].copy_from_slice(&send_tmp[rel * q..(rel + 1) * q]);
        for (k, dst) in row[..q].iter_mut().enumerate() {
            // skip(k) < p for k < q, so one conditional subtract mods.
            let mut src = rel + p - sk.skip(k);
            if src >= p {
                src -= p;
            }
            *dst = send_tmp[src * q + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::recv::recv_schedule;
    use crate::schedule::send::send_schedule;

    fn assert_matches_serial(p: usize, threads: usize) {
        for kernel in [BuildKernel::Scalar, BuildKernel::Lanes] {
            let sk = Arc::new(Skips::new(p));
            let t = ScheduleTable::build_with_kernel(&sk, threads, kernel);
            assert_eq!(t.p(), p);
            assert_eq!(t.bytes(), 2 * p * sk.q());
            for r in 0..p {
                let rs = recv_schedule(&sk, r);
                let ss = send_schedule(&sk, r);
                let trecv: Vec<i64> = t.recv_row(r).iter().map(|&v| v as i64).collect();
                let tsend: Vec<i64> = t.send_row(r).iter().map(|&v| v as i64).collect();
                assert_eq!(trecv, rs.blocks, "recv p={p} r={r} threads={threads} {kernel:?}");
                assert_eq!(tsend, ss.blocks, "send p={p} r={r} threads={threads} {kernel:?}");
                assert_eq!(t.baseblock(r), rs.baseblock, "bb p={p} r={r} {kernel:?}");
                let s = t.schedule(r);
                assert_eq!(s.recv, rs.blocks);
                assert_eq!(s.send, ss.blocks);
                assert_eq!(s.rank, r);
            }
        }
    }

    #[test]
    fn matches_serial_cores_small_grid() {
        for p in [1usize, 2, 3, 4, 5, 8, 9, 16, 17, 18, 31, 32, 33, 100, 127, 128, 129] {
            for threads in [1usize, 2, 8] {
                assert_matches_serial(p, threads);
            }
        }
    }

    #[test]
    fn chunk_boundaries_are_invisible() {
        // Thread counts that do not divide p: the last chunk is short and
        // chunk-local memo state must not leak across boundaries.
        for p in [97usize, 1000, 1023] {
            for threads in [3usize, 7, 13, 97] {
                assert_matches_serial(p, threads);
            }
        }
    }

    #[test]
    fn kernels_agree_on_violation_counts() {
        // The lane kernel's violation mask must name exactly the rounds
        // the scalar walk resolves through the memo — same set, so the
        // same total (Theorem 3 keeps both ≤ 4·p).
        for p in [17usize, 100, 1000, 4097] {
            let sk = Arc::new(Skips::new(p));
            let a = ScheduleTable::build_with_kernel(&sk, 4, BuildKernel::Scalar);
            let b = ScheduleTable::build_with_kernel(&sk, 4, BuildKernel::Lanes);
            assert_eq!(a.violations(), b.violations(), "p={p}");
            assert!(a.violations() <= 4 * p, "p={p}: {}", a.violations());
        }
    }

    #[test]
    fn violations_bounded_by_theorem3() {
        for p in [17usize, 100, 1000, 4097] {
            let sk = Arc::new(Skips::new(p));
            let t = ScheduleTable::build_with_threads(&sk, 4);
            assert!(t.violations() <= 4 * p, "p={p}: {}", t.violations());
        }
    }

    #[test]
    fn p1_table_is_empty() {
        let sk = Arc::new(Skips::new(1));
        let t = ScheduleTable::build(&sk);
        assert_eq!(t.bytes(), 0);
        assert!(t.recv_row(0).is_empty());
        assert!(t.send_row(0).is_empty());
        assert_eq!(t.baseblock(0), 0);
    }

    #[test]
    fn memo_hits_do_not_change_rows() {
        // A chunk of the whole rank range maximises memo reuse; the rows
        // must still be bit-identical to the memo-free serial cores
        // (covered rank by rank in assert_matches_serial, pinned here at
        // a p with many violations).
        assert_matches_serial(4099, 1);
    }

    #[test]
    fn lane_group_boundaries_are_invisible() {
        // p around multiples of LANES: full groups, one-short tails, and
        // one-over heads all reduce to the same rows.
        for p in [LANES - 1, LANES, LANES + 1, 4 * LANES - 1, 4 * LANES, 4 * LANES + 1] {
            assert_matches_serial(p, 1);
            assert_matches_serial(p, 3);
        }
    }

    #[test]
    fn thread_knob_parses_with_a_floor_of_one() {
        assert_eq!(parse_threads("4"), Ok(4));
        assert_eq!(parse_threads(" 16 "), Ok(16));
        assert!(parse_threads("0").is_err(), "zero threads cannot run a build");
        assert!(parse_threads("").is_err());
        assert!(parse_threads("lots").is_err());
        assert!(parse_threads("-2").is_err());
        assert!(parse_threads("1.5").is_err());
    }

    #[test]
    fn kernel_knob_parses_both_names_only() {
        assert_eq!(parse_build_kernel("lanes"), Ok(BuildKernel::Lanes));
        assert_eq!(parse_build_kernel(" Scalar "), Ok(BuildKernel::Scalar));
        assert!(parse_build_kernel("simd").is_err());
        assert!(parse_build_kernel("").is_err());
    }

    #[test]
    fn raw_entries_stay_in_half_open_range() {
        // The documented contract: every raw entry lies in [-q, q) —
        // the value q never appears in a row (the root's conventional
        // baseblock q is metadata, not a row entry).
        for p in [2usize, 9, 17, 100, 1023] {
            for kernel in [BuildKernel::Scalar, BuildKernel::Lanes] {
                let sk = Arc::new(Skips::new(p));
                let q = sk.q() as i64;
                let t = ScheduleTable::build_with_kernel(&sk, 2, kernel);
                for r in 0..p {
                    for &v in t.recv_row(r).iter().chain(t.send_row(r)) {
                        assert!(
                            (-q..q).contains(&(v as i64)),
                            "p={p} r={r} {kernel:?}: entry {v} outside [-{q}, {q})"
                        );
                    }
                }
            }
        }
    }
}
