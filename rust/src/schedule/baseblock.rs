//! Baseblock computation (Algorithm 3) and the linear-time listing of all
//! baseblocks (the construction in the proof of Lemma 3).
//!
//! The *baseblock* `b_r` of processor `r` is the smallest (first) skip
//! index of the canonical skip sequence decomposing `r` into a sum of
//! distinct skips (Lemma 2). It is the first real (non-negative) block that
//! `r` receives during broadcast, in round `b_r`... more precisely in the
//! round given by the *largest* index of its canonical skip sequence.
//! By convention, the root `r = 0` has baseblock `q` (empty sequence).

use super::skips::Skips;

/// Algorithm 3: the baseblock of processor `r`, `0 <= r < p`, in `O(q)`.
///
/// Walks the skips from largest (`skip[q-1]`) to smallest, greedily adding
/// a skip whenever it does not overshoot `r`; the skip that lands exactly
/// on `r` is the baseblock. Only `r = 0` returns `q`.
pub fn baseblock(sk: &Skips, r: usize) -> usize {
    debug_assert!(r < sk.p());
    let q = sk.q();
    if q == 0 {
        return 0; // p = 1: single processor, trivially root
    }
    let mut k = q;
    let mut acc = 0usize;
    loop {
        k -= 1;
        let s = acc + sk.skip(k);
        if s == r {
            return k;
        } else if s < r {
            acc = s;
        }
        if k == 0 {
            break;
        }
    }
    // Only processor r = 0 falls through (empty canonical sequence).
    debug_assert_eq!(r, 0);
    q
}

/// Lane width of the batch-vectorized schedule builders: enough `i64`
/// lanes to fill a 512-bit vector, small enough that a tail group's
/// padded lanes waste little work.
pub(crate) const LANES: usize = 8;

/// Branchless lane variant of [`baseblock`]: the Algorithm-3 walk for
/// [`LANES`] ranks at once, each lane's data-dependent branches turned
/// into selects so the compiler can vectorize the whole descent.
///
/// The scalar walk *returns* at the first `acc + skip(k) == r` hit; a
/// lane cannot return early, so a per-lane `found` flag freezes its
/// accumulator and result instead — continuing the walk unfrozen could
/// match a second, wrong index (e.g. `r = 3` over skips 1,2,3 would hit
/// `k = 2` first and then the stale `acc` would land on `k = 0`).
/// Lanes that never match keep the fallthrough value `q` — exactly the
/// scalar convention for the root.
pub(crate) fn baseblock_lanes(sk: &Skips, r: &[i64; LANES]) -> [i64; LANES] {
    let q = sk.q();
    if q == 0 {
        return [0i64; LANES];
    }
    let mut acc = [0i64; LANES];
    let mut bb = [q as i64; LANES];
    let mut found = [false; LANES];
    for k in (0..q).rev() {
        let s_k = sk.skip(k) as i64;
        for i in 0..LANES {
            let s = acc[i] + s_k;
            let eq = !found[i] && s == r[i];
            bb[i] = if eq { k as i64 } else { bb[i] };
            found[i] |= eq;
            acc[i] = if !found[i] && s < r[i] { s } else { acc[i] };
        }
    }
    bb
}

/// The full canonical skip sequence for `r` (increasing skip indices),
/// i.e. the distinct skips summing to `r` chosen by the Algorithm-3 walk.
/// Empty for `r = 0`.
pub fn canonical_sequence(sk: &Skips, r: usize) -> Vec<usize> {
    debug_assert!(r < sk.p());
    let q = sk.q();
    let mut seq = Vec::with_capacity(q);
    let mut acc = 0usize;
    for k in (0..q).rev() {
        let s = acc + sk.skip(k);
        if s == r {
            seq.push(k);
            acc = s;
            break;
        } else if s < r {
            seq.push(k);
            acc = s;
        }
    }
    debug_assert_eq!(acc, r, "canonical sequence must sum to r");
    seq.reverse();
    seq
}

/// List the baseblocks of **all** processors `0..p` in `O(p)` total time,
/// following the doubling construction in the proof of Lemma 3:
///
/// start with `[0]`; to extend a prefix of length `skip[k]` to length
/// `skip[k+1]`, append the prefix to itself, truncate to `skip[k+1]`, and
/// bump the entry of processor 0 to `k+1`.
///
/// E.g. skips 1,2,3,6,11: `0 -> 10 -> 201 -> 301201 -> 40120130120`.
pub fn all_baseblocks(sk: &Skips) -> Vec<usize> {
    let p = sk.p();
    let q = sk.q();
    if q == 0 {
        return vec![0];
    }
    let mut bb = Vec::with_capacity(p);
    bb.push(0usize);
    for k in 0..q {
        // Extend from length skip[k] to length skip[k+1] <= 2*skip[k].
        let cur = bb.len();
        debug_assert_eq!(cur, sk.skip(k));
        let target = sk.skip(k + 1);
        for i in 0..target - cur {
            let v = bb[i];
            bb.push(v);
        }
        bb[0] = k + 1;
    }
    debug_assert_eq!(bb.len(), p);
    bb
}

/// Check the window property actually established by the proof of
/// Lemma 3: the baseblock sequences of length `skip[k]` starting at
/// processor `0` and at processor `skip[k]` each contain at least `k+1`
/// distinct baseblocks (the proof's doubling construction covers exactly
/// these two anchored windows; arbitrary windows can have fewer — e.g.
/// `p = 9`, processors 4..6 have baseblocks {0,3,0}).
pub fn check_lemma3(sk: &Skips) -> bool {
    let bb = all_baseblocks(sk);
    let p = sk.p();
    let q = sk.q();
    let distinct = |slice: &[usize]| {
        let mut seen = 0u64;
        let mut n = 0usize;
        for &b in slice {
            if seen & (1 << b) == 0 {
                seen |= 1 << b;
                n += 1;
            }
        }
        n
    };
    for k in 0..q {
        let w = sk.skip(k);
        if w > p {
            break;
        }
        // Window anchored at 0.
        if distinct(&bb[0..w]) < k + 1 {
            return false;
        }
        // Window anchored at skip[k], when complete within 0..p. (The
        // proof also argues a one-element-short variant; at the very end
        // of the list that window is truncated differently, so we check
        // only complete windows — the schedule correctness itself is
        // verified directly via the four conditions in `verify`.)
        if 2 * w <= p && distinct(&bb[w..2 * w]) < k + 1 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bb_table(p: usize) -> Vec<usize> {
        let sk = Skips::new(p);
        (0..p).map(|r| baseblock(&sk, r)).collect()
    }

    #[test]
    fn paper_table1_baseblocks_p17() {
        // Table 1 row b: 5 0 1 2 0 3 0 1 2 4 0 1 2 0 3 0 1
        assert_eq!(
            bb_table(17),
            vec![5, 0, 1, 2, 0, 3, 0, 1, 2, 4, 0, 1, 2, 0, 3, 0, 1]
        );
    }

    #[test]
    fn paper_table2_baseblocks_p9() {
        // Table 2 row b: 4 0 1 2 0 3 0 1 2
        assert_eq!(bb_table(9), vec![4, 0, 1, 2, 0, 3, 0, 1, 2]);
    }

    #[test]
    fn paper_table3_baseblocks_p18() {
        // Table 3 row b: 5 0 1 2 0 3 0 1 2 4 0 1 2 0 3 0 1 2
        assert_eq!(
            bb_table(18),
            vec![5, 0, 1, 2, 0, 3, 0, 1, 2, 4, 0, 1, 2, 0, 3, 0, 1, 2]
        );
    }

    #[test]
    fn lemma3_example_sequence() {
        // The paper's example uses skips 1,2,3,6,11 which arise for p = 11.
        let sk = Skips::new(11);
        assert_eq!(sk.as_slice(), &[1, 2, 3, 6, 11]);
        assert_eq!(all_baseblocks(&sk), vec![4, 0, 1, 2, 0, 1, 3, 0, 1, 2, 0]);
    }

    #[test]
    fn all_baseblocks_matches_per_processor() {
        for p in 1..2000 {
            let sk = Skips::new(p);
            let fast = all_baseblocks(&sk);
            for r in 0..p {
                assert_eq!(fast[r], baseblock(&sk, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn canonical_sequence_sums_to_r() {
        for p in [2usize, 9, 17, 18, 100, 1000, 4096, 4097] {
            let sk = Skips::new(p);
            for r in 0..p {
                let seq = canonical_sequence(&sk, r);
                let sum: usize = seq.iter().map(|&e| sk.skip(e)).sum();
                assert_eq!(sum, r, "p={p} r={r}");
                // Indices strictly increasing (distinct skips).
                for w in seq.windows(2) {
                    assert!(w[0] < w[1]);
                }
                // First element is the baseblock.
                if r > 0 {
                    assert_eq!(seq[0], baseblock(&sk, r), "p={p} r={r}");
                } else {
                    assert!(seq.is_empty());
                }
            }
        }
    }

    #[test]
    fn lemma3_holds_small_p() {
        for p in 1..512 {
            assert!(check_lemma3(&Skips::new(p)), "p={p}");
        }
    }

    #[test]
    fn root_baseblock_is_q() {
        for p in 2..100 {
            let sk = Skips::new(p);
            assert_eq!(baseblock(&sk, 0), sk.q());
        }
    }

    #[test]
    fn lane_walk_matches_scalar_walk() {
        // Every rank of every p through the lane kernel, in arbitrary
        // lane groupings (including groups mixing the root with
        // non-roots and groups of duplicated ranks, as tail padding
        // produces).
        for p in [1usize, 2, 3, 9, 17, 18, 100, 257, 1000] {
            let sk = Skips::new(p);
            let mut r = 0usize;
            while r < p {
                let mut rv = [0i64; LANES];
                for (i, v) in rv.iter_mut().enumerate() {
                    *v = ((r + i).min(p - 1)) as i64;
                }
                let bb = baseblock_lanes(&sk, &rv);
                for i in 0..LANES {
                    assert_eq!(
                        bb[i],
                        baseblock(&sk, rv[i] as usize) as i64,
                        "p={p} r={}",
                        rv[i]
                    );
                }
                r += LANES;
            }
        }
    }

    #[test]
    fn nonroot_baseblock_below_q() {
        for p in 2..1000 {
            let sk = Skips::new(p);
            for r in 1..p {
                assert!(baseblock(&sk, r) < sk.q(), "p={p} r={r}");
            }
        }
    }
}
