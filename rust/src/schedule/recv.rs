//! Receive schedule computation in `O(log p)` time (Algorithms 4 and 5,
//! Theorem 2 of the paper).
//!
//! For processor `r`, the receive schedule `recvblock[0..q]` determines in
//! O(1) per round which block `r` receives in round `k` (mod `q`): entry
//! values are relative block indices — exactly one entry is the
//! non-negative *baseblock* `b_r`, the others are the negative values
//! `{-1, ..., -q} \ {b_r - q}` (Correctness Condition 3). In phase `j` of
//! Algorithm 1, the block received in round `k` is `recvblock[k] + j*q`.
//!
//! The computation is a greedy backtracking search (`ALLBLOCKS`) over the
//! canonical skip sequences of the virtual processor `p + r`, with found
//! baseblocks removed from a doubly-linked list of skip indices so that
//! each is used once. Lemma 5 bounds the recursive calls by `q - 1`,
//! Lemma 6 the total scan count by `2q + R` — both are instrumented and
//! machine-checked in the test suite.
//!
//! ## The all-ranks gather identity
//!
//! A consumer that needs **every** rank's recv row (the
//! [`crate::schedule::ScheduleTable`] build) does not need `p` of these
//! searches. Correctness Conditions 1+2 define the send schedule as
//! `sendblock[k]_r = recvblock[k]_{(r + skip[k]) mod p}`, and Algorithm 6
//! computes exactly that value for every round — including its violation
//! rounds, whose fallback *is* a recv-schedule lookup of the
//! to-processor. Since `r ↦ (r + skip[k]) mod p` is a bijection on ranks
//! for each `k`, the identity inverts:
//!
//! ```text
//! recvblock[k]_t = sendblock[k]_{(t + p − skip[k]) mod p}
//! ```
//!
//! so once all send rows exist, every recv row is a pure gather — no
//! search at all. The table's lane-kernel build path does exactly this;
//! the equality over all `(r, k)` is pinned by the
//! `send_equals_recv_of_to_processor` test in
//! [`crate::schedule::send`] and the table-vs-serial parity grids.

use super::baseblock::baseblock;
use super::skips::Skips;

/// Instrumentation counters for one `ALLBLOCKS` search, used to verify the
/// complexity claims (Lemmas 5 and 6) experimentally.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Number of recursive calls (Lemma 5: `<= q - 1`).
    pub recursions: usize,
    /// Total while-loop iterations over all calls (Lemma 6: `<= 2q + R`).
    pub scans: usize,
}

/// A computed receive schedule for one processor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecvSchedule {
    /// `recvblock[k]` for rounds `k = 0..q`: one non-negative baseblock,
    /// the rest negative (see module docs).
    pub blocks: Vec<i64>,
    /// The baseblock `b_r` (`q` for the root by convention).
    pub baseblock: usize,
    /// Search instrumentation.
    pub stats: SearchStats,
}

/// Upper bound on `q = ceil(log2 p)` for any `usize` p — lets the whole
/// search run on fixed-size stack arrays with zero heap allocation (the
/// hot path is called once per rank per communicator).
pub(crate) const MAX_Q: usize = usize::BITS as usize;

/// Doubly-linked list over skip indices `0..=q` in decreasing order with a
/// sentinel `-1`, stored offset by one (`slot(e) = e + 1`).
struct SkipList {
    next: [i32; MAX_Q + 2],
    prev: [i32; MAX_Q + 2],
}

impl SkipList {
    #[inline]
    fn new(q: usize) -> Self {
        // next[e] = e - 1 (towards smaller skips), prev[e] = e + 1.
        let mut next = [0i32; MAX_Q + 2];
        let mut prev = [0i32; MAX_Q + 2];
        for e in 0..=q as i32 {
            next[(e + 1) as usize] = e - 1;
            prev[(e + 1) as usize] = e + 1;
        }
        prev[q + 1] = -1; // prev[q] = -1
        next[0] = q as i32; // next[-1] = q
        prev[0] = 0; // prev[-1] = 0
        SkipList { next, prev }
    }

    #[inline]
    fn next(&self, e: i32) -> i32 {
        self.next[(e + 1) as usize]
    }

    /// Remove `e` from the list in O(1) (neighbours re-linked; `e`'s own
    /// links are kept so an in-flight traversal can step past it).
    #[inline]
    fn unlink(&mut self, e: i32) {
        let n = self.next[(e + 1) as usize];
        let p = self.prev[(e + 1) as usize];
        self.next[(p + 1) as usize] = n;
        self.prev[(n + 1) as usize] = p;
    }
}

/// The recursive greedy search of Algorithm 4.
///
/// `r` is the (virtual) target processor `p + r`, `rp` the intermediate
/// processor reached so far (`r'` in the paper), `s` the previously found
/// intermediate processor `r'_{k-1}` (new ones must be strictly smaller),
/// `e` the skip index to start scanning from and `k` the next round to
/// fill. Returns the updated `k`; accepted skip indices land in `recv`.
struct Search<'a> {
    sk: &'a Skips,
    r: usize,
    list: SkipList,
    recv: [i32; MAX_Q],
    stats: SearchStats,
}

impl<'a> Search<'a> {
    fn allblocks(&mut self, rp: usize, mut s: usize, mut e: i32, mut k: usize) -> usize {
        let q = self.sk.q();
        while e != -1 {
            if k == q {
                // All q rounds filled; unwind (paper reaches the same exit
                // via the r' > r - skip[k+1] check with skip[q+1] = ∞).
                return k;
            }
            self.stats.scans += 1;
            let re = rp + self.sk.skip(e as usize);
            // Accept candidates r' + skip[e] <= r - skip[k], distinct from
            // the previously found intermediate processor (re < s).
            if re + self.sk.skip(k) <= self.r && re < s {
                if re + self.sk.skip(k + 1) <= self.r {
                    // Still below r - skip[k+1]: descend to find an
                    // intermediate processor closer to r - skip[k].
                    self.stats.recursions += 1;
                    k = self.allblocks(re, s, e, k);
                    if k == q {
                        return k;
                    }
                }
                if rp + self.sk.skip(k + 1) > self.r {
                    // r' > r - skip[k+1]: r' itself is out of round-k+1's
                    // interval; backtrack so an enclosing frame accepts.
                    return k;
                }
                // Accept e: its skip index is the baseblock of r'_k = re.
                s = re;
                self.recv[k] = e;
                k += 1;
                self.list.unlink(e);
            }
            e = self.list.next(e);
        }
        k
    }
}

/// Allocation-free core of Algorithm 5: fill `out[0..q]` with the receive
/// schedule of `r`; returns `(baseblock, stats)`. Everything runs on
/// stack arrays — this is the per-rank hot path.
pub(crate) fn recv_schedule_core(
    sk: &Skips,
    r: usize,
    out: &mut [i64; MAX_Q],
) -> (usize, SearchStats) {
    debug_assert!(r < sk.p());
    let q = sk.q();
    let p = sk.p();
    if q == 0 {
        return (0, SearchStats::default());
    }
    let b = baseblock(sk, r);
    let mut search = Search {
        sk,
        r: p + r,
        list: SkipList::new(q),
        recv: [0i32; MAX_Q],
        stats: SearchStats::default(),
    };
    // Exclude the canonical path to r itself (its baseblock b).
    search.list.unlink(b as i32);
    let filled = search.allblocks(0, p + p, q as i32, 0);
    debug_assert_eq!(filled, q, "ALLBLOCKS must fill all q rounds (r={r}, p={p})");
    let _ = filled;

    // Map skip indices to schedule entries: the index q (the direct skip
    // from the root p to p + r) becomes the positive baseblock b; all
    // others e become the negative value e - q (Condition 3).
    for k in 0..q {
        let e = search.recv[k];
        out[k] = if e == q as i32 { b as i64 } else { e as i64 - q as i64 };
    }
    (b, search.stats)
}

/// Algorithm 5: compute the receive schedule for processor `r` in
/// `O(log p)` operations.
pub fn recv_schedule(sk: &Skips, r: usize) -> RecvSchedule {
    let mut buf = [0i64; MAX_Q];
    let (baseblock, stats) = recv_schedule_core(sk, r, &mut buf);
    RecvSchedule { blocks: buf[..sk.q()].to_vec(), baseblock, stats }
}

/// Compute only the `recvblock` entries (no instrumentation wrapper) into a
/// caller-provided buffer; returns the baseblock. This is the allocation-
/// free hot-path variant used by the collectives engine.
pub fn recv_schedule_into(sk: &Skips, r: usize, out: &mut [i64]) -> usize {
    let mut buf = [0i64; MAX_Q];
    let (baseblock, _) = recv_schedule_core(sk, r, &mut buf);
    out[..sk.q()].copy_from_slice(&buf[..sk.q()]);
    baseblock
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recv_row(p: usize, k: usize) -> Vec<i64> {
        let sk = Skips::new(p);
        (0..p).map(|r| recv_schedule(&sk, r).blocks[k]).collect()
    }

    #[test]
    fn paper_table1_recv_p17() {
        // Table 1, recvblock rows for p = 17 (q = 5).
        assert_eq!(
            recv_row(17, 0),
            vec![-4, 0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5]
        );
        assert_eq!(
            recv_row(17, 1),
            vec![-5, -4, 1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2]
        );
        assert_eq!(
            recv_row(17, 2),
            vec![-2, -2, -2, 2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3]
        );
        assert_eq!(
            recv_row(17, 3),
            vec![-1, -3, -3, -2, -2, 3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1]
        );
        assert_eq!(
            recv_row(17, 4),
            vec![-3, -1, -1, -1, -1, -1, -1, -1, -1, 4, 0, 1, 2, 0, 3, 0, 1]
        );
    }

    #[test]
    fn paper_table2_recv_p9() {
        assert_eq!(recv_row(9, 0), vec![-2, 0, -4, -3, -2, -4, -1, -4, -3]);
        assert_eq!(recv_row(9, 1), vec![-3, -2, 1, -4, -3, -2, -2, -1, -4]);
        assert_eq!(recv_row(9, 2), vec![-1, -3, -2, 2, 0, -3, -3, -2, -1]);
        assert_eq!(recv_row(9, 3), vec![-4, -1, -1, -1, -1, 3, 0, 1, 2]);
    }

    #[test]
    fn paper_table3_recv_p18() {
        assert_eq!(
            recv_row(18, 0),
            vec![-3, 0, -5, -4, -3, -5, -2, -5, -4, -3, -1, -5, -4, -3, -5, -2, -5, -4]
        );
        assert_eq!(
            recv_row(18, 1),
            vec![-4, -3, 1, -5, -4, -3, -3, -2, -5, -4, -3, -1, -5, -4, -3, -3, -2, -5]
        );
        assert_eq!(
            recv_row(18, 2),
            vec![-2, -4, -3, 2, 0, -4, -4, -3, -2, -2, -4, -3, -1, -1, -4, -4, -3, -2]
        );
        assert_eq!(
            recv_row(18, 3),
            vec![-5, -2, -2, -2, -2, 3, 0, 1, 2, -5, -2, -2, -2, -2, -1, -1, -1, -1]
        );
        assert_eq!(
            recv_row(18, 4),
            vec![-1, -1, -1, -1, -1, -1, -1, -1, -1, 4, 0, 1, 2, 0, 3, 0, 1, 2]
        );
    }

    #[test]
    fn condition3_small_p() {
        // Over q rounds each processor receives q different blocks:
        // {-1..-q} \ {b-q} plus {b}.
        for p in 2..600 {
            let sk = Skips::new(p);
            let q = sk.q() as i64;
            for r in 0..p {
                let s = recv_schedule(&sk, r);
                let mut want: Vec<i64> = (-q..0).collect();
                if r != 0 {
                    let b = s.baseblock as i64;
                    want.retain(|&v| v != b - q);
                    want.push(b);
                }
                // Root keeps all negatives: its "positive" entry is b=q
                // mapped... the root's schedule contains exactly {-1..-q}.
                let mut got = s.blocks.clone();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn root_schedule_all_negative() {
        for p in 2..200 {
            let sk = Skips::new(p);
            let s = recv_schedule(&sk, 0);
            assert!(s.blocks.iter().all(|&v| v < 0), "p={p}");
        }
    }

    #[test]
    fn p1_trivial() {
        let sk = Skips::new(1);
        let s = recv_schedule(&sk, 0);
        assert!(s.blocks.is_empty());
    }

    #[test]
    fn p2_schedules() {
        let sk = Skips::new(2);
        assert_eq!(recv_schedule(&sk, 0).blocks, vec![-1]);
        assert_eq!(recv_schedule(&sk, 1).blocks, vec![0]);
    }

    #[test]
    fn lemma5_recursion_bound_small() {
        for p in 2..2000 {
            let sk = Skips::new(p);
            for r in 0..p {
                let s = recv_schedule(&sk, r);
                assert!(
                    s.stats.recursions <= sk.q().saturating_sub(1).max(1),
                    "p={p} r={r} R={}",
                    s.stats.recursions
                );
            }
        }
    }

    #[test]
    fn lemma6_scan_bound_small() {
        // Lemma 6 claims <= 2q + R with the paper's accounting of "scans";
        // our counter increments on *every* while-iteration (including the
        // re-examinations the paper's proof attributes to pending frames),
        // and measures <= 2.5q + R over all p <= 200000. We machine-check
        // the slightly relaxed 3q + R, which still certifies O(q).
        for p in 2..2000 {
            let sk = Skips::new(p);
            for r in 0..p {
                let s = recv_schedule(&sk, r);
                assert!(
                    s.stats.scans <= 3 * sk.q() + s.stats.recursions,
                    "p={p} r={r} scans={} R={}",
                    s.stats.scans,
                    s.stats.recursions
                );
            }
        }
    }
}
