//! Machine verification of the four correctness conditions of Section 2
//! and the complexity bounds of Theorems 2 and 3.
//!
//! The paper (Appendix B) validates its implementation by "finite,
//! exhaustive proof" over all p up to ~2^20; this module provides the same
//! check: [`verify_all`] computes every processor's receive and send
//! schedule for a given `p` and checks, in `O(p log p)`:
//!
//! 1. `recvblock[k]_r == sendblock[k]_{f_r^k}` — what `r` receives is what
//!    its from-processor sends;
//! 2. `sendblock[k]_r == recvblock[k]_{t_r^k}` — what `r` sends is what its
//!    to-processor receives;
//! 3. over `q` rounds each processor receives `q` different blocks:
//!    `{-1..-q} \ {b_r - q} ∪ {b_r}` (root: all of `{-1..-q}`);
//! 4. every sent block was previously received: `sendblock[k]_r =
//!    recvblock[j]_r` for some `j < k`, or `= b_r - q` (the baseblock of
//!    the previous phase). In particular `sendblock[0]_r = b_r - q`.
//!
//! plus the instrumented bounds: recursions `<= q-1` (Lemma 5), scans
//! `<= 2q + R` (Lemma 6), violations `<= 4` (Theorem 3).

use super::recv::{recv_schedule, RecvSchedule};
use super::send::{send_schedule, SendSchedule};
use super::skips::Skips;

/// One verification failure, with enough context to debug.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    Condition1 { r: usize, k: usize, from: usize, recv: i64, send: i64 },
    Condition2 { r: usize, k: usize, to: usize, send: i64, recv: i64 },
    Condition3 { r: usize, got: Vec<i64>, want: Vec<i64> },
    Condition4 { r: usize, k: usize, block: i64 },
    RecursionBound { r: usize, recursions: usize, limit: usize },
    ScanBound { r: usize, scans: usize, limit: usize },
    ViolationBound { r: usize, violations: usize },
    /// Cross-op trace check: a rank sent twice in one machine round.
    TraceSendBusy { round: usize, rank: usize },
    /// Cross-op trace check: a rank received twice in one machine round.
    TraceRecvBusy { round: usize, rank: usize },
    /// Cross-op trace check: a self-message or out-of-range rank.
    TraceBadRank { round: usize, from: usize, to: usize },
}

/// Summary statistics of one exhaustive verification run.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub p: usize,
    pub max_recursions: usize,
    pub max_scans: usize,
    pub max_violations: usize,
    pub total_violation_rounds: usize,
    pub failures: Vec<Violation>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compute all schedules for `p` processors and check all four conditions
/// plus the theorem bounds. `O(p log p)` time, `O(p log p)` space.
pub fn verify_all(p: usize) -> VerifyReport {
    let sk = Skips::new(p);
    let recvs: Vec<RecvSchedule> = (0..p).map(|r| recv_schedule(&sk, r)).collect();
    let sends: Vec<SendSchedule> = (0..p).map(|r| send_schedule(&sk, r)).collect();
    verify_tables(&sk, &recvs, &sends)
}

/// Verify precomputed schedule tables (shared by tests that construct
/// tables differently, e.g. via the doubling construction).
pub fn verify_tables(
    sk: &Skips,
    recvs: &[RecvSchedule],
    sends: &[SendSchedule],
) -> VerifyReport {
    let p = sk.p();
    let q = sk.q();
    let mut rep = VerifyReport { p, ..Default::default() };

    for r in 0..p {
        let recv = &recvs[r];
        let send = &sends[r];

        // Conditions 1 + 2.
        for k in 0..q {
            let f = sk.from_proc(r, k);
            if recv.blocks[k] != sends[f].blocks[k] {
                rep.failures.push(Violation::Condition1 {
                    r,
                    k,
                    from: f,
                    recv: recv.blocks[k],
                    send: sends[f].blocks[k],
                });
            }
            let t = sk.to_proc(r, k);
            if send.blocks[k] != recvs[t].blocks[k] {
                rep.failures.push(Violation::Condition2 {
                    r,
                    k,
                    to: t,
                    send: send.blocks[k],
                    recv: recvs[t].blocks[k],
                });
            }
        }

        // Condition 3: the multiset of receive blocks.
        let mut got: Vec<i64> = recv.blocks.clone();
        got.sort_unstable();
        let mut want: Vec<i64> = (-(q as i64)..0).collect();
        if r != 0 {
            let b = recv.baseblock as i64;
            want.retain(|&v| v != b - q as i64);
            want.push(b);
            want.sort_unstable();
        }
        if got != want {
            rep.failures.push(Violation::Condition3 { r, got, want });
        }

        // Condition 4: each sent block previously received (or baseblock of
        // the previous phase). The root owns everything; skip it.
        if r != 0 {
            let b = send.baseblock as i64;
            for k in 0..q {
                let v = send.blocks[k];
                let ok = v == b - q as i64
                    || (0..k).any(|j| recv.blocks[j] == v);
                if !ok {
                    rep.failures.push(Violation::Condition4 { r, k, block: v });
                }
            }
        }

        // Theorem bounds.
        let rlimit = q.saturating_sub(1);
        if recv.stats.recursions > rlimit {
            rep.failures.push(Violation::RecursionBound {
                r,
                recursions: recv.stats.recursions,
                limit: rlimit,
            });
        }
        // Lemma 6 bound, relaxed from 2q+R to 3q+R for our more inclusive
        // scan accounting (see recv.rs tests); certifies O(q) all the same.
        let slimit = 3 * q + recv.stats.recursions;
        if recv.stats.scans > slimit {
            rep.failures.push(Violation::ScanBound { r, scans: recv.stats.scans, limit: slimit });
        }
        if send.violations > 4 {
            rep.failures.push(Violation::ViolationBound { r, violations: send.violations });
        }

        rep.max_recursions = rep.max_recursions.max(recv.stats.recursions);
        rep.max_scans = rep.max_scans.max(recv.stats.scans);
        rep.max_violations = rep.max_violations.max(send.violations);
        rep.total_violation_rounds += send.violations;
    }
    rep
}

/// Verify schedules for a *sample* of processors of a (large) `p` — used
/// for the multi-million-processor spot checks where `O(p log p)` table
/// construction is fine but we want a cheap pass. Checks conditions 1/2
/// pairwise against directly computed neighbour schedules, condition 3
/// locally, condition 4 locally, and the theorem bounds.
pub fn verify_sampled(p: usize, ranks: &[usize]) -> VerifyReport {
    let sk = Skips::new(p);
    let q = sk.q();
    let mut rep = VerifyReport { p, ..Default::default() };
    for &r in ranks {
        let recv = recv_schedule(&sk, r);
        let send = send_schedule(&sk, r);
        for k in 0..q {
            let f = sk.from_proc(r, k);
            let fs = send_schedule(&sk, f);
            if recv.blocks[k] != fs.blocks[k] {
                rep.failures.push(Violation::Condition1 {
                    r,
                    k,
                    from: f,
                    recv: recv.blocks[k],
                    send: fs.blocks[k],
                });
            }
            let t = sk.to_proc(r, k);
            let tr = recv_schedule(&sk, t);
            if send.blocks[k] != tr.blocks[k] {
                rep.failures.push(Violation::Condition2 {
                    r,
                    k,
                    to: t,
                    send: send.blocks[k],
                    recv: tr.blocks[k],
                });
            }
        }
        let mut got = recv.blocks.clone();
        got.sort_unstable();
        let mut want: Vec<i64> = (-(q as i64)..0).collect();
        if r != 0 {
            let b = recv.baseblock as i64;
            want.retain(|&v| v != b - q as i64);
            want.push(b);
            want.sort_unstable();
        }
        if got != want {
            rep.failures.push(Violation::Condition3 { r, got, want });
        }
        if r != 0 {
            let b = send.baseblock as i64;
            for k in 0..q {
                let v = send.blocks[k];
                let ok = v == b - q as i64 || (0..k).any(|j| recv.blocks[j] == v);
                if !ok {
                    rep.failures.push(Violation::Condition4 { r, k, block: v });
                }
            }
        }
        if send.violations > 4 {
            rep.failures.push(Violation::ViolationBound { r, violations: send.violations });
        }
        rep.max_recursions = rep.max_recursions.max(recv.stats.recursions);
        rep.max_scans = rep.max_scans.max(recv.stats.scans);
        rep.max_violations = rep.max_violations.max(send.violations);
    }
    rep
}

/// Cross-operation one-portedness oracle for the traffic plane.
///
/// `trace[j]` holds the `(from, to)` pairs of every message executed in
/// machine round `j` of an interleaved batch (as recorded by
/// `comm::traffic::TrafficEngine` with trace recording on, across
/// **all** co-scheduled operations). The paper's machine model, extended
/// across operations, demands that in every machine round each rank
/// sends at most once and receives at most once — send and receive may
/// coincide, possibly with different partners and different operations.
/// Self-messages and out-of-range ranks are rejected too.
///
/// `O(total messages)` with two stamp arrays; returns the first
/// violation found (round-major, message order within a round).
pub fn verify_one_ported_trace(
    p: usize,
    trace: &[Vec<(usize, usize)>],
) -> Result<(), Violation> {
    let mut send_stamp = vec![0u32; p];
    let mut recv_stamp = vec![0u32; p];
    for (round, msgs) in trace.iter().enumerate() {
        let stamp = round as u32 + 1;
        for &(from, to) in msgs {
            if from == to || from >= p || to >= p {
                return Err(Violation::TraceBadRank { round, from, to });
            }
            if send_stamp[from] == stamp {
                return Err(Violation::TraceSendBusy { round, rank: from });
            }
            if recv_stamp[to] == stamp {
                return Err(Violation::TraceRecvBusy { round, rank: to });
            }
            send_stamp[from] = stamp;
            recv_stamp[to] = stamp;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_paper_table_sizes() {
        for p in [9usize, 17, 18] {
            let rep = verify_all(p);
            assert!(rep.ok(), "p={p}: {:?}", &rep.failures[..rep.failures.len().min(3)]);
        }
    }

    #[test]
    fn verify_all_up_to_700() {
        for p in 1..700 {
            let rep = verify_all(p);
            assert!(rep.ok(), "p={p}: {:?}", &rep.failures[..rep.failures.len().min(3)]);
        }
    }

    #[test]
    fn verify_powers_of_two() {
        for e in 1..14 {
            let rep = verify_all(1 << e);
            assert!(rep.ok(), "p=2^{e}");
            // For powers of two the schedule is the hypercube schedule:
            // no violations at all.
            assert_eq!(rep.max_violations, 0, "p=2^{e}");
        }
    }

    #[test]
    fn one_ported_trace_oracle() {
        // Clean: simultaneous send+recv per rank is the machine model.
        let clean = vec![
            vec![(0, 1), (1, 2), (2, 0)], // a ring round: every rank sends and receives once
            vec![],                       // idle machine rounds are fine
            vec![(3, 0)],
        ];
        assert!(verify_one_ported_trace(4, &clean).is_ok());

        // The same rank sending twice in one round (two ops claiming one
        // send port) is the cross-op violation the ledger must prevent.
        let double_send = vec![vec![(0, 1), (0, 2)]];
        assert_eq!(
            verify_one_ported_trace(3, &double_send),
            Err(Violation::TraceSendBusy { round: 0, rank: 0 })
        );
        let double_recv = vec![vec![(0, 2), (1, 2)]];
        assert_eq!(
            verify_one_ported_trace(3, &double_recv),
            Err(Violation::TraceRecvBusy { round: 0, rank: 2 })
        );
        // ...but the same ports are free again next round.
        let across_rounds = vec![vec![(0, 1)], vec![(0, 1)]];
        assert!(verify_one_ported_trace(2, &across_rounds).is_ok());

        assert_eq!(
            verify_one_ported_trace(3, &[vec![(1, 1)]]),
            Err(Violation::TraceBadRank { round: 0, from: 1, to: 1 })
        );
        assert_eq!(
            verify_one_ported_trace(3, &[vec![(1, 3)]]),
            Err(Violation::TraceBadRank { round: 0, from: 1, to: 3 })
        );
    }

    #[test]
    fn verify_sampled_large() {
        let p = (1 << 20) + 7;
        let ranks: Vec<usize> = (0..64).map(|i| (i * 16411) % p).collect();
        let rep = verify_sampled(p, &ranks);
        assert!(rep.ok(), "{:?}", &rep.failures[..rep.failures.len().min(3)]);
    }
}
